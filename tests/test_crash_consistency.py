"""Crash-consistency subsystem: PM write traces, crash injection, recovery.

The paper's §III-C claim verified operationally (repro.consistency):
every crash point of every traced batch op — all trace prefixes plus all
torn splits of non-atomic stores — recovers to a table where each op is
atomically visible or invisible.  Continuity must do it from the
indicator words alone (ZERO log records); level/pfarm exercise their
logging-based reference recoveries; dense's unprotected in-place update
is the negative control proving the checker detects real torn-write
corruption.  Plus the property-level guarantees: recovery idempotence
and serial-vs-wave trace equivalence (same durable states).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                     # hypothesis is a dev dep (CI installs it); the
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True                    # property tests skip without
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import api
from repro.consistency import (crash_states, matrix, run_case, trace_batch)
from repro.consistency.schemes import HANDLERS
from repro.consistency.trace import apply_trace
from repro.data import ycsb

OPS = ("insert", "update", "delete")


def _loaded_store(scheme, table_slots=240, n_base=24, seed=7):
    store = api.make_store(scheme, table_slots=table_slots)
    rng = np.random.RandomState(seed)
    K = ycsb.make_key(np.arange(n_base))
    V = ycsb.make_value(rng, n_base)
    t = store.create()
    t, res = store.insert(t, K, V)
    return store, t, K[np.asarray(res.ok)], rng


# ---------------------------------------------------------------------------
# the crash/scheme matrix (the CI gate, as a test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("scheme", list(matrix.SHAPES))
def test_crash_matrix_cell(scheme, op):
    """Every scheme x op sweeps all crash points and matches its
    expectation (consistent/log-free per the paper's contrast)."""
    r = matrix.run_cell(scheme, op)
    assert r.crash_points > 1
    assert matrix.cell_ok(r), (scheme, op, r.violations[:5],
                               r.log_used_points)


@pytest.mark.parametrize("op", OPS)
def test_continuity_every_crash_point_log_free(op):
    """The headline claim: continuity recovers from EVERY prefix and torn
    split with zero log records anywhere — none in the trace, none read
    by recovery — and recovery reads only the indicator words."""
    r = matrix.run_cell("continuity", op)
    assert r.consistent, r.violations[:5]
    assert r.log_records_in_trace == 0
    assert r.log_used_points == 0
    assert r.report.log_records_scanned == 0
    assert r.report.payload_slots_scanned == 0
    assert r.report.commit_words_scanned > 0


def test_pfarm_recovery_requires_log_records():
    """The RECIPE baseline contrast: every pfarm op logs, and mid-op
    crashes are only repaired by replaying log records."""
    r = matrix.run_cell("pfarm", "insert")
    assert r.consistent
    assert r.log_records_in_trace > 0
    assert r.log_used_points > 0
    assert r.report.log_records_used > 0


def test_level_logged_update_fallback_uses_undo_log():
    """At high load the level update batch must hit a full bucket (the
    logged in-place path) and recovery must roll entries back."""
    r = matrix.run_cell("level", "update")
    assert r.consistent
    assert "logged" in r.paths
    assert r.log_used_points > 0


def test_dense_inplace_update_torn_hazard_detected():
    """Negative control / checker mutation test: the unprotected dense
    in-place update MUST produce detected violations, and only at torn
    crash points."""
    r = matrix.run_cell("dense", "update")
    assert not r.consistent
    assert all("torn" in v for v in r.violations)
    assert r.torn_points > 0


# ---------------------------------------------------------------------------
# trace <-> scheme equivalence and ledger reconciliation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", list(matrix.SHAPES))
def test_traced_ops_match_untraced_ops(scheme):
    """store.trace_* returns the same ok flags, visible items, and
    Table-I PM-write count as the untraced op."""
    store, t, live, rng = _loaded_store(scheme)
    store = store.with_policy(api.ExecPolicy(engine="serial"))
    h = HANDLERS[scheme]
    K2 = ycsb.make_key(np.arange(500, 510))
    V2 = ycsb.make_value(rng, 10)
    for op, keys, vals in (("insert", K2, V2), ("update", live[:10], V2),
                           ("delete", live[5:15], None)):
        if op == "insert":
            t1, tres = store.trace_insert(t, keys, vals)
            t2, res = store.insert(t, keys, vals)
        elif op == "update":
            t1, tres = store.trace_update(t, keys, vals)
            t2, res = store.update(t, keys, vals)
        else:
            t1, tres = store.trace_delete(t, keys)
            t2, res = store.delete(t, keys)
        np.testing.assert_array_equal(tres.ok, np.asarray(res.ok))
        assert int(tres.ledger.pm_writes) == int(res.ledger.pm_writes)
        assert int(tres.ledger.ops) == int(res.ledger.ops)
        v1 = h.visible(store.cfg, h.init_state(store.cfg, t1))
        v2 = h.visible(store.cfg, h.init_state(store.cfg, t2))
        assert v1 == v2, (scheme, op)
        assert int(t1.count) == int(t2.count)


def test_trace_respects_exec_policy_order():
    store, t, live, rng = _loaded_store("continuity")
    K = ycsb.make_key(np.arange(500, 508))
    V = ycsb.make_value(rng, 8)
    _, wres = store.trace_insert(t, K, V)
    _, sres = store.with_policy(
        api.ExecPolicy(engine="serial")).trace_insert(t, K, V)
    assert wres.trace.order == "wave"
    assert sres.trace.order == "serial"


# ---------------------------------------------------------------------------
# recovery idempotence + serial/wave durable equivalence
# (deterministic versions always run; hypothesis widens the input space
# where the dev deps are installed, e.g. the CI tier1 job)
# ---------------------------------------------------------------------------

def _op_batch(op, live, rng, ids):
    """Build one batch for ``op`` from id choices (one op per key)."""
    ids = np.asarray(ids)
    if op == "insert":
        return ycsb.make_key(1000 + ids), ycsb.make_value(rng, len(ids))
    keys = live[ids % live.shape[0]]
    _, first = np.unique(keys, axis=0, return_index=True)
    keys = keys[np.sort(first)]
    vals = ycsb.make_value(rng, keys.shape[0]) if op == "update" else None
    return keys, vals


def _check_recover_idempotent(scheme, ids, op, crash_at):
    """recover(recover(s)) == recover(s) on arbitrary crash images."""
    store, t, live, rng = _loaded_store(scheme)
    h = HANDLERS[scheme]
    keys, vals = _op_batch(op, live, rng, ids)
    base = h.init_state(store.cfg, t)
    _, trace = trace_batch(h, store.cfg, base, op, keys, vals)
    states = list(crash_states(base, trace))
    cs = states[crash_at % len(states)]
    once, _ = h.recover(store.cfg, cs.state)
    twice, _ = h.recover(store.cfg, once)
    assert set(once) == set(twice)
    for f in once:
        np.testing.assert_array_equal(once[f], twice[f], err_msg=f)


def _check_serial_wave_equivalence(ids, op):
    """The wave engine's trace schedule (per wave: payloads then one-word
    commits) lands on the SAME durable final state as the serial batch
    order — the trace-level statement of the engine's byte-identity
    guarantee — and every wave crash point still recovers all-or-nothing."""
    store, t, live, rng = _loaded_store("continuity")
    h = HANDLERS["continuity"]
    keys, vals = _op_batch(op, live, rng, ids)
    base = h.init_state(store.cfg, t)
    st_serial, tr_serial = trace_batch(h, store.cfg, base, op, keys, vals,
                                       order="serial")
    _, tr_wave = trace_batch(h, store.cfg, base, op, keys, vals,
                             order="wave")
    assert tr_wave.pm_writes() == tr_serial.pm_writes()
    applied = apply_trace(base, tr_wave)
    for f in st_serial:
        np.testing.assert_array_equal(st_serial[f], applied[f], err_msg=f)
    r = run_case(store, t, op, keys, vals, order="wave")
    assert r.consistent, r.violations[:5]


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("scheme", list(matrix.SHAPES))
def test_recover_idempotent_fixed(scheme, op):
    for crash_at in (0, 3, 10 ** 6):
        _check_recover_idempotent(scheme, [0, 3, 5, 7, 11, 13], op, crash_at)


@pytest.mark.parametrize("op", OPS)
def test_serial_and_wave_traces_same_durable_state_fixed(op):
    _check_serial_wave_equivalence(list(range(14)), op)
    _check_serial_wave_equivalence([2, 9, 4, 30, 17], op)


if HAVE_HYPOTHESIS:
    key_ids = st.lists(st.integers(min_value=0, max_value=59), min_size=1,
                       max_size=16, unique=True)

    @pytest.mark.parametrize("scheme", list(matrix.SHAPES))
    @settings(max_examples=10, deadline=None)
    @given(ids=key_ids, op_pick=st.integers(min_value=0, max_value=2),
           crash_at=st.integers(min_value=0, max_value=10 ** 6))
    def test_recover_idempotent_property(scheme, ids, op_pick, crash_at):
        _check_recover_idempotent(scheme, ids, OPS[op_pick], crash_at)

    @settings(max_examples=15, deadline=None)
    @given(ids=key_ids, op_pick=st.integers(min_value=0, max_value=2))
    def test_serial_and_wave_traces_same_durable_state_property(ids, op_pick):
        _check_serial_wave_equivalence(ids, OPS[op_pick])


# ---------------------------------------------------------------------------
# level movement: crash-safe 5-store order + duplicate-scan recovery
# ---------------------------------------------------------------------------

def test_level_movement_crash_safe_and_dedup():
    """Drive a level insert onto the one-movement path, then crash it at
    every point: torn stores must be invisible (the freed slot is never
    written while its bit is set) and the transient duplicate of the
    moved item must be repaired by recovery's duplicate scan."""
    store = api.make_store("level", table_slots=48)
    cfg = store.cfg
    h = HANDLERS["level"]
    rng = np.random.RandomState(3)
    state = h.init_state(cfg, store.create())
    K = ycsb.make_key(np.array([123]))
    V = ycsb.make_value(rng, 1)
    cand = h.route(cfg, K)[0]                    # K's four candidate buckets
    # mover M: lives in K's first bucket (slot 0) with a DIFFERENT second
    # top hash whose bucket we leave empty (the movement destination)
    M = alt = None
    for i in range(5000):
        cM = ycsb.make_key(np.array([5000 + i]))
        from repro.core.hashfn import hash128, hash128_2
        a1 = int(np.asarray(hash128(jnp.asarray(cM)))[0]) % cfg.num_top
        a2 = int(np.asarray(hash128_2(jnp.asarray(cM)))[0]) % cfg.num_top
        if a1 == int(cand[0]) and a2 != a1 and a2 not in set(int(c) for c in cand):
            M, alt = cM, a2
            break
    assert M is not None
    # fill all four candidate buckets of K (mover in cand[0] slot 0)
    nxt = iter(range(9000, 9999))
    for j in range(4):
        top = j < 2
        kf = "tkeys" if top else "bkeys"
        tf = "ttok" if top else "btok"
        b = int(cand[j])
        for s in range(cfg.bucket_slots):
            state[kf][b, s] = ycsb.make_key(np.array([next(nxt)]))[0]
        state[tf][b] = np.uint8((1 << cfg.bucket_slots) - 1)
    state["tkeys"][int(cand[0]), 0] = M[0]
    base_trace = trace_batch(h, cfg, state, "insert", K, V)[1]
    assert base_trace.ops[0].path == "move", base_trace.ops[0].path
    r = run_case(store, state, "insert", K, V)
    assert r.consistent, r.violations[:5]
    assert "move" in r.paths
    assert r.log_records_in_trace == 0          # movement is log-free
    # the mid-move crash points leave a duplicate that recovery clears
    assert r.report.duplicates_cleared > 0


# ---------------------------------------------------------------------------
# serving page table + runtime restart drill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["continuity", "dense"])
def test_serving_page_table_crash_checkable(scheme):
    from repro.configs.registry import smoke_config
    from repro.models.config import ShapeConfig
    from repro.runtime.fault import page_table_recovery_drill
    from repro.serving import kvcache as KC

    cfg = smoke_config("yi-6b")
    shape = ShapeConfig("t", seq_len=128, global_batch=4, kind="decode")
    geom = KC.make_geometry(cfg, shape, shards=2, page_size=16,
                            scheme=scheme)
    cache = KC.create_cache(geom)
    need = (cache.seq_lens % geom.page_size) == 0
    ref = KC.open_new_pages(geom, cache, need)
    traced, traces = KC.open_new_pages_traced(geom, cache, need)
    h = HANDLERS[scheme]
    for s in range(geom.shards):
        t_ref = jax.tree.map(lambda x: x[s], ref.table)
        t_tr = jax.tree.map(lambda x: x[s], traced.table)
        assert (h.visible(geom.store.cfg, h.init_state(geom.store.cfg, t_ref))
                == h.visible(geom.store.cfg,
                             h.init_state(geom.store.cfg, t_tr)))
    np.testing.assert_array_equal(np.asarray(ref.next_free),
                                  np.asarray(traced.next_free))
    # crash shard 0's allocation batch at every point, then run the node
    # restart drill over the crashed images
    base = h.init_state(geom.store.cfg,
                        jax.tree.map(lambda x: x[0], cache.table))
    images = [cs.state for cs in crash_states(base, traces[0].trace)]
    prefix_sets = [h.visible(geom.store.cfg, h.init_state(
        geom.store.cfg, jax.tree.map(lambda x: x[0], cache.table)))]
    tables, rep = page_table_recovery_drill(geom.store, images)
    assert rep.log_records_used == 0            # log-free at serving scale
    for tbl in tables:
        vis = h.visible(geom.store.cfg, h.init_state(geom.store.cfg, tbl))
        # each mapping all-or-nothing: values must be exact page ids
        for k, v in vis.items():
            assert len(v) == 16


def test_store_recover_accepts_tables_and_reports():
    store, t, live, _ = _loaded_store("continuity")
    t2, rep = store.recover(t)
    assert rep.log_free()
    assert int(t2.count) == int(t.count)
    t3, _ = store.recover(t2)
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(t3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""The incremental-resize surface: begin/step/cutover on every scheme,
insert-during-split losslessness, the mid-split crash cell, the unified
plan-emitting trio, and the fingerprint/stash tier's API-visible effects.
"""

import dataclasses
import inspect
import sys
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _propcheck import given, settings, st  # noqa: E402

from repro import api  # noqa: E402
from repro.api.types import ResizeState  # noqa: E402
from repro.data import ycsb  # noqa: E402
from repro.rdma.verbs import VerbPlan  # noqa: E402

SCHEMES = ("continuity", "level", "pfarm", "dense")


def _seeded(store, n, seed=3):
    rng = np.random.RandomState(seed)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    table, res = store.insert(store.create(), K, V)
    okn = np.asarray(res.ok)
    return table, K[okn], V[okn], rng


# -- the begin/step/cutover triple ---------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
def test_incremental_triple_preserves_members(scheme):
    store = api.make_store(scheme, table_slots=160)
    table, K, V, _ = _seeded(store, 40)
    rs = store.begin_resize(table)
    assert isinstance(rs, ResizeState) and not rs.done
    assert rs.n_items == len(K)
    steps = 0
    while not rs.done:
        rs = store.resize_step(rs, budget=1)
        steps += 1
        assert steps <= 10_000
    new_store, new_table = store.resize_cutover(rs)
    assert new_store.total_slots() > store.total_slots()
    res = new_store.lookup(new_table, K)
    assert np.asarray(res.ok).all()
    assert (np.asarray(res.values) == V).all()


@pytest.mark.parametrize("scheme", SCHEMES)
def test_deprecated_resize_shim_warns_and_matches(scheme):
    store = api.make_store(scheme, table_slots=160)
    table, K, V, _ = _seeded(store, 40)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        new_store, new_table = store.resize(table)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    res = new_store.lookup(new_table, K)
    assert np.asarray(res.ok).all()
    assert (np.asarray(res.values) == V).all()


def test_continuity_split_is_actually_incremental():
    """budget=1 advances exactly one cohort: the old table drains pair by
    pair, and dual-read serves the full item set at EVERY intermediate."""
    store = api.make_store("continuity", table_slots=160)
    table, K, V, _ = _seeded(store, 40)
    cohorts = store.cfg.num_pairs
    rs = store.begin_resize(table)
    for step in range(cohorts):
        assert not rs.done
        rs = store.resize_step(rs, budget=1)
        res = store.resize_lookup(rs, K)
        assert np.asarray(res.ok).all(), f"lost keys after cohort {step}"
        assert (np.asarray(res.values) == V).all()
    assert rs.done and rs.moved == len(K)
    assert int(rs.table.count) == 0          # the source drained
    new_store, new_table = store.resize_cutover(rs)
    assert np.asarray(new_store.lookup(new_table, K).ok).all()


# -- writes during the split window --------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_write_during_split_never_loses_or_duplicates(seed):
    """Interleave foreground writes with cohort moves: after cutover the
    grown table holds EXACTLY the oracle — no lost ack, no phantom, no
    key present twice (the matrix-gated invariant, driven through the
    public maintenance API)."""
    rng = np.random.RandomState(seed)
    store = api.make_store("continuity", table_slots=240)
    n0 = 100
    K = ycsb.make_key(np.arange(n0))
    V = ycsb.make_value(rng, n0)
    table, res = store.insert(store.create(), K, V)
    okn = np.asarray(res.ok)
    oracle = {int(i): v for i, v, o in zip(np.arange(n0), V, okn) if o}
    rs = store.begin_resize(table)
    next_new = 1000
    while not rs.done:
        op = ("insert", "update", "delete")[rng.randint(3)]
        if op == "insert" or not oracle:
            op, kid = "insert", next_new
            next_new += 1
        else:
            kid = sorted(oracle)[rng.randint(len(oracle))]
        k = ycsb.make_key(np.array([kid]))
        v = ycsb.make_value(rng, 1)
        rs, r = store.resize_write(rs, op, k,
                                   None if op == "delete" else v)
        if bool(np.asarray(r.ok)[0]):
            if op == "delete":
                oracle.pop(kid, None)
            else:
                oracle[kid] = v[0]
        rs = store.resize_step(rs, budget=1)
        if oracle:       # dual-read spot check mid-split
            probe = sorted(oracle)[rng.randint(len(oracle))]
            lr = store.resize_lookup(rs, ycsb.make_key(np.array([probe])))
            assert bool(np.asarray(lr.ok)[0])
            assert (np.asarray(lr.values)[0] == oracle[probe]).all()
    new_store, new_table = store.resize_cutover(rs)
    if oracle:
        ids = np.array(sorted(oracle))
        lr = new_store.lookup(new_table, ycsb.make_key(ids))
        assert np.asarray(lr.ok).all(), "acked key lost across the split"
        want = np.stack([oracle[int(i)] for i in ids])
        assert (np.asarray(lr.values) == want).all()
    k2, _, live = new_store._extract(new_table)
    kl = np.asarray(k2, np.uint32)[np.asarray(live)]
    kb = [bytes(k.tobytes()) for k in kl]
    assert len(kb) == len(set(kb)), "duplicate key after cutover"
    assert len(kb) == len(oracle), "phantom keys after cutover"


def test_mid_split_crash_cell_green():
    from repro.consistency.matrix import run_resize_cell
    row = run_resize_cell("continuity")
    assert row["ok"]
    assert row["consistent"] and row["log_free"]
    assert row["violations"] == 0
    assert row["crash_points"] > 0 and row["torn_points"] > 0


# -- cluster maintenance loop --------------------------------------------

def test_cluster_maintenance_grows_shard_under_load():
    from repro.cluster.sim import run_cluster
    cell = run_cluster("continuity", "D", nodes=3, replicas=2,
                       num_records=400, num_ops=2400, batch=200,
                       node_slots=288, seed=3, resize_budget=4)
    assert cell["committed_lost"] == 0
    mnt = cell["maintenance"]
    assert mnt["resizes_begun"] >= 1, "no shard ever crossed the trigger"
    assert mnt["cutovers"] >= 1
    assert mnt["steps"] > mnt["cutovers"], \
        "splits completed in one step — not incremental"


# -- the unified plan-emitting trio --------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
def test_plan_trio_unified_signature(scheme):
    """Every scheme module emits its three verb plans through ONE shape:
    ``fn(cfg, table, keys, ...) -> VerbPlan`` with batch == B."""
    store = api.make_store(scheme, table_slots=160)
    table, K, _, _ = _seeded(store, 24)
    mod = store._mod
    B = K.shape[0]
    for name in ("lookup_plan", "version_read_plan", "scan_plan"):
        fn = getattr(mod, name)
        params = list(inspect.signature(fn).parameters)
        assert len(params) >= 3, (scheme, name)
    plans = [
        mod.lookup_plan(store.cfg, table, K, mod.lookup(store.cfg, table, K)),
        mod.version_read_plan(store.cfg, table, K),
        mod.scan_plan(store.cfg, table, K, np.ones((B,), np.int64)),
    ]
    for name, plan in zip(("lookup", "version_read", "scan"), plans):
        assert isinstance(plan, VerbPlan), (scheme, name)
        assert plan.batch == B, (scheme, name)
    # and the store adapters surface the same trio uniformly
    assert isinstance(store.version_read_plan(table, K), VerbPlan)
    assert isinstance(store.scan_plan(table, K, np.ones((B,), np.int64)),
                      VerbPlan)


# -- fingerprint/stash tier at the API boundary --------------------------

def test_stash_free_config_plan_bytes_unchanged():
    """stash_frac=0 (the core default) keeps the pre-stash wire contract:
    a (B, 2) plan — main segment + conditional ext lane — bit for bit."""
    import repro.core.continuity as ch
    cfg = ch.ContinuityConfig(num_buckets=16)
    assert cfg.stash_frac == 0.0 and cfg.stash_slots == 0
    rng = np.random.RandomState(0)
    K = ycsb.make_key(np.arange(32))
    out = ch.insert(cfg, ch.create(cfg), K, ycsb.make_value(rng, 32))
    table = out[0]
    res = ch.lookup(cfg, table, K)
    plan = ch.lookup_plan(cfg, table, K, res)
    assert plan.verb.shape == (32, 2)


def test_api_store_carries_stash_tier():
    store = api.make_store("continuity", table_slots=160)
    assert store.cfg.stash_slots > 0        # from_slots defaults 1/8
    table, K, V, _ = _seeded(store, 40)
    res = store.lookup(table, K)
    assert np.asarray(res.ok).all()
    # the stash lane rides in the SAME plan as a third conditional lane
    assert res.plan.verb.shape[1] == 3


def test_wave_serial_identical_with_stash_engaged():
    """Overfill a tiny table so inserts spill into the stash tier; the
    wave and serial engines must still produce bit-identical state."""
    tables = {}
    for engine in ("serial", "wave"):
        store = api.make_store(
            "continuity", table_slots=64,
            policy=api.ExecPolicy(engine=engine))
        rng = np.random.RandomState(9)
        K = ycsb.make_key(np.arange(90))
        V = ycsb.make_value(rng, 90)
        table, res = store.insert(store.create(), K, V)
        tables[engine] = (table, np.asarray(res.ok))
    t_s, ok_s = tables["serial"]
    t_w, ok_w = tables["wave"]
    assert (ok_s == ok_w).all()
    assert int((np.asarray(t_s.stash_meta) != 0).sum()) > 0, \
        "test did not actually engage the stash tier"
    for ls, lw in zip(jax.tree.leaves(t_s), jax.tree.leaves(t_w)):
        assert (np.asarray(ls) == np.asarray(lw)).all()


def test_load_factor_first_trigger_past_085():
    """The tentpole's capacity claim: with fingerprints + stash the first
    insert failure lands past 0.85 load factor (the paper's band), vs the
    ~0.70 floor of the plain layout."""
    store = api.make_store("continuity", table_slots=256)
    table = store.create()
    rng = np.random.RandomState(4)
    step = 16
    first_reject_lf = None
    for lo in range(0, 2048, step):
        K = ycsb.make_key(np.arange(lo, lo + step))
        V = ycsb.make_value(rng, step)
        table, res = store.insert(table, K, V)
        if not np.asarray(res.ok).all():
            first_reject_lf = float(store.load_factor(table))
            break
    assert first_reject_lf is not None, "table never filled"
    assert first_reject_lf >= 0.85, first_reject_lf

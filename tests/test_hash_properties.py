"""Property-based (hypothesis) tests: every scheme is a faithful map.

A random op sequence applied to EVERY registered `repro.api` scheme must
match a python-dict oracle (one generic test, parametrized over the
registry — new schemes get the oracle for free), and the continuity
invariant must hold after every op: an indicator bit is set IFF the slot
holds a live item that lookup can see.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Formerly importorskip("hypothesis"): the repro container has no network
# and hypothesis is dev-only, so that skipped this whole module in tier-1.
# _propcheck runs the same properties on seeded examples when hypothesis
# is absent (and uses the real thing when present).
from _propcheck import given, settings, st

import repro.core.continuity as ch
from repro import api
from repro.data import ycsb

CFG = ch.ContinuityConfig(num_buckets=32)
SLOTS = 320   # equal capacity across schemes (CFG's slot count)

ops_strategy = st.lists(
    st.tuples(st.sampled_from(["insert", "update", "delete", "lookup"]),
              st.integers(min_value=0, max_value=39),
              st.integers(min_value=0, max_value=2 ** 31 - 1)),
    min_size=1, max_size=60)


def key_of(i):
    return ycsb.make_key(np.array([i]))


def val_of(x):
    return np.full((1, 4), x, np.uint32)


@pytest.mark.parametrize("scheme", list(api.available_schemes()))
@settings(max_examples=25, deadline=None)
@given(ops_strategy)
def test_scheme_matches_dict_oracle(scheme, ops):
    """One oracle, every registered scheme, through `repro.api`."""
    store = api.make_store(scheme, table_slots=SLOTS)
    t = store.create()
    oracle = {}
    for op, i, x in ops:
        K, V = key_of(i), val_of(x)
        if op == "insert":
            if i in oracle:          # paper's insert assumes new keys
                continue
            t, r = store.insert(t, K, V)
            if bool(r.ok[0]):
                oracle[i] = x
        elif op == "update":
            t, r = store.update(t, K, V)
            # success implies presence (may fail only if the bucket is full)
            assert (not bool(r.ok[0])) or i in oracle
            if bool(r.ok[0]):
                oracle[i] = x
        elif op == "delete":
            t, r = store.delete(t, K)
            assert bool(r.ok[0]) == (i in oracle)
            oracle.pop(i, None)
        else:
            r = store.lookup(t, K)
            assert bool(r.ok[0]) == (i in oracle)
            if i in oracle:
                assert int(np.asarray(r.values)[0, 0]) == oracle[i]
    # final sweep
    for i, x in oracle.items():
        r = store.lookup(t, key_of(i))
        assert bool(r.ok[0])
        assert int(np.asarray(r.values)[0, 0]) == x
    assert int(t.count) == len(oracle)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 500), min_size=1, max_size=80, unique=True))
def test_indicator_bit_iff_live_item(ids):
    """Structural invariant behind the log-free consistency argument."""
    t = ch.create(CFG)
    K = ycsb.make_key(np.asarray(ids))
    V = ycsb.make_value(np.random.RandomState(0), len(ids))
    t, ok, _ = ch.insert(CFG, t, K, V)
    # popcount of indicators == live count == table.count
    bits = np.asarray(t.indicator)
    pop = sum(bin(int(b)).count("1") for b in bits)
    assert pop == int(t.count) == int(np.asarray(ok).sum())
    # every found slot's bit is set
    res = ch.lookup(CFG, t, K)
    for j in np.nonzero(np.asarray(ok))[0]:
        pair, slot = int(res.pair[j]), int(res.slot[j])
        assert (int(t.indicator[pair]) >> slot) & 1 == 1

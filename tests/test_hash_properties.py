"""Property-based (hypothesis) tests: every scheme is a faithful map.

A random op sequence applied to each scheme must match a python-dict oracle,
and the continuity invariant must hold after every op: an indicator bit is
set IFF the slot holds a live item that lookup can see.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core.continuity as ch
from repro.data import ycsb

CFG = ch.ContinuityConfig(num_buckets=32)

ops_strategy = st.lists(
    st.tuples(st.sampled_from(["insert", "update", "delete", "lookup"]),
              st.integers(min_value=0, max_value=39),
              st.integers(min_value=0, max_value=2 ** 31 - 1)),
    min_size=1, max_size=60)


def key_of(i):
    return ycsb.make_key(np.array([i]))


def val_of(x):
    return np.full((1, 4), x, np.uint32)


@settings(max_examples=40, deadline=None)
@given(ops_strategy)
def test_continuity_matches_dict_oracle(ops):
    t = ch.create(CFG)
    oracle = {}
    for op, i, x in ops:
        K, V = key_of(i), val_of(x)
        if op == "insert":
            if i in oracle:          # paper's insert assumes new keys
                continue
            t, ok, _ = ch.insert(CFG, t, K, V)
            if bool(ok[0]):
                oracle[i] = x
        elif op == "update":
            t, ok, _ = ch.update(CFG, t, K, V)
            assert bool(ok[0]) == (i in oracle and
                                   bool(ok[0]))  # may fail only if full
            if bool(ok[0]):
                oracle[i] = x
        elif op == "delete":
            t, ok, _ = ch.delete(CFG, t, K)
            assert bool(ok[0]) == (i in oracle)
            oracle.pop(i, None)
        else:
            res = ch.lookup(CFG, t, K)
            assert bool(res.found[0]) == (i in oracle)
            if i in oracle:
                assert int(np.asarray(res.values)[0, 0]) == oracle[i]
    # final sweep
    for i, x in oracle.items():
        res = ch.lookup(CFG, t, key_of(i))
        assert bool(res.found[0])
        assert int(np.asarray(res.values)[0, 0]) == x
    assert int(t.count) == len(oracle)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 500), min_size=1, max_size=80, unique=True))
def test_indicator_bit_iff_live_item(ids):
    """Structural invariant behind the log-free consistency argument."""
    t = ch.create(CFG)
    K = ycsb.make_key(np.asarray(ids))
    V = ycsb.make_value(np.random.RandomState(0), len(ids))
    t, ok, _ = ch.insert(CFG, t, K, V)
    # popcount of indicators == live count == table.count
    bits = np.asarray(t.indicator)
    pop = sum(bin(int(b)).count("1") for b in bits)
    assert pop == int(t.count) == int(np.asarray(ok).sum())
    # every found slot's bit is set
    res = ch.lookup(CFG, t, K)
    for j in np.nonzero(np.asarray(ok))[0]:
        pair, slot = int(res.pair[j]), int(res.slot[j])
        assert (int(t.indicator[pair]) >> slot) & 1 == 1


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 300), min_size=2, max_size=50, unique=True),
       st.data())
def test_level_and_pfarm_match_oracle(ids, data):
    import repro.core.level as lv
    import repro.core.pfarm as pf
    for mod, cfg in ((lv, lv.LevelConfig(num_top=32)),
                     (pf, pf.PFarmConfig(num_buckets=32))):
        t = mod.create(cfg)
        K = ycsb.make_key(np.asarray(ids))
        V = ycsb.make_value(np.random.RandomState(1), len(ids))
        t, ok, _ = mod.insert(cfg, t, K, V)
        okn = np.asarray(ok)
        res = mod.lookup(cfg, t, K)
        assert np.asarray(res.found)[okn].all()
        kill = data.draw(st.integers(0, len(ids) - 1))
        if okn[kill]:
            t, dok, _ = mod.delete(cfg, t, K[kill:kill + 1])
            assert bool(dok[0])
            assert not bool(mod.lookup(cfg, t, K[kill:kill + 1]).found[0])

"""Checkpoint manager: two-phase commit semantics + restart recovery."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = tree()
    mgr.save(7, t, extra={"loss": 1.5})
    out, step, extra = mgr.restore(t)
    assert step == 7 and extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_tmp_is_invisible(tmp_path):
    """Crash before the atomic rename = the paper's uncommitted indicator:
    restart must not see the partial checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = tree()
    mgr.save(1, t)
    # simulate a crash mid-save of step 2: payload written, NO commit
    tmp = tmp_path / "step_000000002.tmp"
    os.makedirs(tmp)
    np.save(tmp / "w.npy", np.zeros((8, 4)))
    assert mgr.latest_step() == 1
    out, step, _ = mgr.restore(t)
    assert step == 1


def test_digest_verification(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = tree()
    mgr.save(1, t)
    # corrupt a payload byte after commit
    d = tmp_path / "step_000000001"
    arr = np.load(d / "w.npy")
    arr[0, 0] += 1
    np.save(d / "w.npy", arr)
    with pytest.raises(IOError):
        mgr.restore(t)


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.committed_steps() == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = tree()
    mgr.save(5, t)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_into_train_state_and_resume(tmp_path):
    """End-to-end: train 3 steps, checkpoint, restart from scratch, resume —
    losses continue from the restored point."""
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.training import optimizer as O
    from repro.training.train_step import make_train_step
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = O.init(params)
    step_fn = jax.jit(make_train_step(cfg, O.OptConfig(lr=1e-3)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
    for _ in range(3):
        params, state, stats = step_fn(params, state, batch)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, {"params": params, "opt": state})
    l3 = float(stats["loss"])

    # "restart"
    params2 = T.init_params(cfg, jax.random.PRNGKey(0))
    state2 = O.init(params2)
    restored, step, _ = mgr.restore({"params": params2, "opt": state2})
    params2, state2 = restored["params"], restored["opt"]
    assert int(state2.step) == 3
    _, _, stats2 = step_fn(params2, state2, batch)
    # resumed loss must be BELOW the step-3 loss (continuing, not restarting)
    assert float(stats2["loss"]) <= l3 + 1e-3

"""Fault-tolerance runtime: detection, elastic remesh, stragglers, replay."""

import numpy as np
import pytest

from repro.runtime.fault import (DeterministicSchedule, HeartbeatMonitor,
                                 StragglerPolicy, plan_remesh)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_silence():
    clk = FakeClock()
    mon = HeartbeatMonitor(timeout_s=10, clock=clk)
    for h in ("host0", "host1", "host2"):
        mon.register(h)
    clk.t = 5
    mon.heartbeat("host0", 1)
    mon.heartbeat("host1", 1)
    clk.t = 12
    assert mon.failed_hosts() == ["host2"]
    clk.t = 25
    assert set(mon.failed_hosts()) == {"host0", "host1", "host2"}


def test_remesh_shrinks_data_axis():
    plan = plan_remesh(total_chips=256, failed_chips=16, model_axis=16,
                       checkpoint_step=900, current_step=942)
    assert plan.mesh_shape == (15, 16)
    assert plan.replay_steps == 42
    assert plan.dropped_chips == 0


def test_remesh_multi_pod_and_exhaustion():
    plan = plan_remesh(total_chips=512, failed_chips=20, model_axis=16,
                       checkpoint_step=0, current_step=5, pod_axis=2)
    assert plan.mesh_shape == (2, 15, 16)
    assert plan.dropped_chips == 492 - 480
    with pytest.raises(RuntimeError):
        plan_remesh(total_chips=16, failed_chips=15, model_axis=16,
                    checkpoint_step=0, current_step=0)


def test_deterministic_schedule_replay_exact():
    sched = DeterministicSchedule(seed=42, global_batch=256)
    a = sched.batch_indices(step=10, shard=3, num_shards=16)
    b = sched.batch_indices(step=10, shard=3, num_shards=16)
    np.testing.assert_array_equal(a, b)
    c = sched.batch_indices(step=11, shard=3, num_shards=16)
    assert (a != c).any()
    d = sched.batch_indices(step=10, shard=4, num_shards=16)
    assert (a != d).any()


def test_straggler_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(timeout_s=1e9, clock=clk)
    rng = np.random.RandomState(0)
    for h in range(8):
        mon.register(f"h{h}")
    for step in range(30):
        for h in range(8):
            lat = 100 + rng.rand() * 2 + (40 if h == 5 else 0)
            mon.heartbeat(f"h{h}", step, step_latency_ms=lat)
    reports = StragglerPolicy(threshold=1.15).analyze(mon)
    assert [r.host for r in reports] == ["h5"]
    assert reports[0].severity > 1.3

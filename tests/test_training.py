"""Training substrate: learning, microbatch equivalence, optimizer, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training.train_step import make_train_step, microbatch_grads


def setup(arch="yi-6b"):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
    return cfg, params, batch


def test_loss_decreases():
    cfg, params, batch = setup()
    state = O.init(params)
    step = jax.jit(make_train_step(cfg, O.OptConfig(lr=1e-3, warmup=2,
                                                    decay_steps=100)))
    losses = []
    for _ in range(10):
        params, state, stats = step(params, state, batch)
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equals_full_batch_grads():
    cfg, params, batch = setup()
    l1, g1 = microbatch_grads(cfg, params, batch, 1, jnp.float32)
    l2, g2 = microbatch_grads(cfg, params, batch, 4, jnp.float32)
    assert abs(float(l1) - float(l2)) < 2e-2  # means over different slices
    # grads agree closely (mean-of-means == mean for equal slices)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-2)


def test_grad_compression_bf16_close():
    cfg, params, batch = setup()
    _, g32 = microbatch_grads(cfg, params, batch, 2, jnp.float32)
    _, g16 = microbatch_grads(cfg, params, batch, 2, jnp.bfloat16)
    n32 = O.global_norm(g32)
    n16 = O.global_norm(g16)
    assert abs(float(n32) - float(n16)) / float(n32) < 0.05


def test_adamw_bias_correction_first_step():
    """After one step from zero moments, update ~= lr * sign-ish step."""
    cfg, params, batch = setup()
    ocfg = O.OptConfig(lr=1e-2, warmup=1, weight_decay=0.0, grad_clip=1e9)
    state = O.init(params)
    _, grads = microbatch_grads(cfg, params, batch, 1, jnp.float32)
    p2, state2, _ = O.apply_updates(ocfg, params, grads, state)
    g = np.asarray(jax.tree.leaves(grads)[3])
    dp = np.asarray(jax.tree.leaves(p2)[3]) - np.asarray(
        jax.tree.leaves(params)[3])
    mask = np.abs(g) > 1e-6
    # first-step Adam update = -lr * g/|g| (bias-corrected)
    np.testing.assert_allclose(dp[mask], -1e-2 * np.sign(g[mask]),
                               atol=2e-3)
    assert int(state2.step) == 1


def test_lr_schedule_shape():
    ocfg = O.OptConfig(lr=1.0, warmup=10, decay_steps=110)
    lrs = [float(O.schedule(ocfg, s)) for s in [0, 5, 10, 60, 110, 1000]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert lrs[3] < 1.0                      # decaying
    assert abs(lrs[4] - 0.1) < 1e-2          # floor = 0.1 * lr
    assert lrs[5] <= lrs[4] + 1e-6


def test_grad_clip():
    cfg, params, batch = setup()
    ocfg = O.OptConfig(lr=1e-3, grad_clip=1e-6)   # clip everything
    state = O.init(params)
    _, grads = microbatch_grads(cfg, params, batch, 1, jnp.float32)
    p2, _, stats = O.apply_updates(ocfg, params, grads, state)
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta < 1e-3                      # tiny because clipped


def test_zero1_axes_assignment():
    cfg, params, _ = setup()
    axes = T.param_logical_axes(cfg, params)
    oaxes = O.opt_logical_axes(axes, params, data_extent=2, zero1=True)
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(oaxes, is_leaf=lambda x: isinstance(x, tuple))
    n_zero = sum("zero" in (a or ()) for a in flat_a)
    assert n_zero > len(flat_p) // 2          # most leaves get ZeRO'd
    # and never on an already-sharded dim
    for a in flat_a:
        if a and "zero" in a:
            assert a.count("zero") == 1

"""Telemetry subsystem: sketch exactness, span determinism, attribution.

The contracts the obs layer sells to the rest of the repo:

  * the log-scale `Histogram` tracks sorted-list percentiles within its
    advertised relative bound |sketch - exact| <= exact * (GROWTH - 1)
    (fixed cases + a seeded property), and merging sketches is EXACTLY
    the sketch of the concatenation;
  * `Tracer` span nesting carries causal parent ids, the injectable
    `TickClock` makes a traced run's export a pure function of its call
    sequence — two same-seed runs export byte-identical JSON;
  * `MetricsRegistry.merge` rolls node registries up (counters add,
    histograms merge, gauges keep the max) — `ClusterStore.metrics_view`
    equals the sum of its per-node endpoints;
  * the transport buckets retries/timeouts PER TAG in ``by_tag`` and
    keeps its legacy `stats()` shape;
  * maintenance-step SLO accounting burns under a tiny SLO and stays
    clean at the defaults.
"""

import json

import numpy as np
import pytest

from _propcheck import given, settings, st
from repro import obs
from repro.cluster.store import ClusterStore
from repro.data import ycsb
from repro.obs.metrics import GROWTH, Histogram, MetricsRegistry
from repro.rdma import verbs as rv
from repro.rdma.transport import FaultInjector, RemoteMemory, RetryPolicy

pytestmark = pytest.mark.obs

PCTS = (50.0, 90.0, 99.0, 99.9)


def _assert_tracks(values, pcts=PCTS):
    """Sketch percentiles within the advertised relative bound."""
    h = Histogram()
    h.record_many(values)
    a = np.asarray(values, np.float64)
    for p in pcts:
        exact = float(np.percentile(a, p))
        got = h.percentile(p)
        assert abs(got - exact) <= abs(exact) * (GROWTH - 1) + 1e-12, \
            f"p{p}: sketch {got} vs exact {exact}"


# ---------------------------------------------------------------------------
# histogram sketch: exactness, merge, serialization
# ---------------------------------------------------------------------------

def test_histogram_tracks_exact_percentiles_fixed():
    _assert_tracks([1.0, 2.0, 3.0, 4.0, 5.0])
    _assert_tracks(np.linspace(0.5, 500.0, 997))
    _assert_tracks(np.random.RandomState(7).lognormal(2.0, 1.5, 2000))
    # bimodal read/write mix whose p50 IS the boundary interpolation
    _assert_tracks([2.8] * 2000 + [14.5] * 2000)


@settings(max_examples=40, deadline=None)
@given(st.tuples(st.integers(min_value=0, max_value=2 ** 31 - 1),
                 st.integers(min_value=2, max_value=400)))
def test_histogram_tracks_exact_percentiles_property(case):
    seed, n = case
    rng = np.random.RandomState(seed)
    values = np.exp(rng.uniform(np.log(1e-2), np.log(1e5), n))
    _assert_tracks(values)


def test_histogram_merge_equals_concatenation():
    rng = np.random.RandomState(3)
    a, b = rng.lognormal(1.0, 1.0, 500), rng.lognormal(3.0, 0.5, 700)
    ha, hb, hc = Histogram(), Histogram(), Histogram()
    ha.record_many(a)
    hb.record_many(b)
    hc.record_many(np.concatenate([a, b]))
    ha.merge(hb)
    assert ha.to_dict() == hc.to_dict()      # bucket-exact, not approximate


def test_histogram_roundtrip_and_edge_cases():
    h = Histogram()
    assert h.percentile(50) == 0.0           # empty sketch
    h.record(0.0)                            # underflow bucket
    h.record(1e12)                           # overflow: reported as max
    assert h.percentile(100) == pytest.approx(1e12)
    # fractional rank interpolates toward the overflow max, exactly as
    # np.percentile would over the two order stats
    assert h.percentile(99.9) == pytest.approx(
        float(np.percentile([0.0, 1e12], 99.9)))
    d = h.to_dict()
    assert set(d["percentiles"]) == {"p50", "p90", "p99", "p999"}
    h2 = Histogram.from_dict(json.loads(json.dumps(d)))
    for p in PCTS:
        assert h2.percentile(p) == h.percentile(p)


def test_record_many_matches_record_loop():
    values = np.random.RandomState(11).lognormal(0.0, 2.0, 300)
    h1, h2 = Histogram(), Histogram()
    h1.record_many(values)
    for v in values:
        h2.record(v)
    d1, d2 = h1.to_dict(), h2.to_dict()
    # np's pairwise summation vs the sequential loop: sum matches only
    # to float tolerance; every discrete field must match exactly
    assert d1.pop("sum") == pytest.approx(d2.pop("sum"))
    assert d1 == d2


# ---------------------------------------------------------------------------
# tracer: nesting, causal links, clock injection, scope isolation
# ---------------------------------------------------------------------------

def test_span_nesting_and_parent_ids():
    t = obs.Tracer(obs.TickClock())
    with t.span("outer", node="pm0") as s_out:
        with t.span("inner") as s_in:
            t.event("ring", n=3)
        assert s_in.parent_id == s_out.span_id
    assert s_out.parent_id is None
    assert [s.name for s in t.spans] == ["inner", "outer"]   # finish order
    assert s_in.events[0]["name"] == "ring"
    assert s_out.t1_us > s_out.t0_us >= 1.0   # TickClock: counted calls
    t.event("orphan")                         # no open span: counted, kept out
    assert t.dropped_events == 1


def test_scope_installs_and_restores():
    assert obs.get_tracer() is None
    outer_reg = obs.get_registry()
    with obs.scope() as (tracer, reg):
        assert obs.get_tracer() is tracer
        assert obs.get_registry() is reg
        with obs.span("x"):
            obs.event("e")
    assert obs.get_tracer() is None
    assert obs.get_registry() is outer_reg
    assert [s.name for s in tracer.spans] == ["x"]
    # the free functions are no-ops outside a scope (shared null span)
    with obs.span("ignored"):
        obs.event("ignored")
    assert [s.name for s in tracer.spans] == ["x"]


def _traced_mini_run(seed: int):
    from repro.rdma.sim import run_ycsb
    with obs.scope(obs.Tracer(obs.TickClock())) as (tracer, reg):
        with obs.span("e2e.cell", scheme="continuity", workload="A"):
            run_ycsb("continuity", "A", num_records=200, num_ops=200,
                     batch=100, seed=seed)
        return obs.export_strings(tracer, reg, meta={"seed": seed})


def test_same_seed_exports_are_byte_identical():
    t1, m1 = _traced_mini_run(5)
    t2, m2 = _traced_mini_run(5)
    assert t1 == t2 and m1 == m2
    t3, m3 = _traced_mini_run(6)
    assert m3 != m1                          # different seed, different data


# ---------------------------------------------------------------------------
# registry merge: the cross-node roll-up
# ---------------------------------------------------------------------------

def test_registry_merge_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c", node="pm0").inc(2)
    b.counter("c", node="pm0").inc(3)
    b.counter("c", node="pm1").inc(1)
    a.gauge("g").set(5.0)
    b.gauge("g").set(3.0)
    a.histogram("h").record(1.0)
    b.histogram("h").record(100.0)
    a.merge(b)
    assert a.counter("c", node="pm0").value == 5
    assert a.counter("c", node="pm1").value == 1
    assert a.gauge("g").max == 5.0           # merge keeps the worst observed
    assert a.histogram("h").count == 2


def test_cluster_metrics_view_sums_node_endpoints():
    cluster = ClusterStore("continuity", nodes=3, replicas=2,
                           node_slots=512)
    rng = np.random.RandomState(0)
    keys = ycsb.make_key(np.arange(96))
    cluster.insert(keys, ycsb.make_value(rng, 96))
    cluster.lookup(keys[:32])
    view = cluster.metrics_view()
    per_node = [n.mem.metrics for n in cluster._nodes.values()
                if n.mem is not None]
    want_posts = sum(r.counter("rdma.posts").value for r in per_node)
    assert want_posts > 0
    assert view.counter("rdma.posts").value == want_posts
    assert view.histogram("rdma.post_us").count \
        == sum(r.histogram("rdma.post_us").count for r in per_node)
    # the roll-up is a fresh registry: node endpoints stay intact
    assert per_node[0].counter("rdma.posts").value <= want_posts


# ---------------------------------------------------------------------------
# transport: per-tag attribution + legacy stats() shape
# ---------------------------------------------------------------------------

def test_by_tag_buckets_retries_and_timeouts():
    mem = RemoteMemory(faults=FaultInjector(drop_p=0.4, seed=3),
                       retry=RetryPolicy(max_attempts=8))
    plan = rv.single_read_plan(8, rv.REGION_TABLE, 0, 64)
    for _ in range(4):
        mem.post(plan, tag="probe")
    assert mem.retries > 0
    bt = mem.stats()["by_tag"]["probe"]
    assert bt["retries"] == mem.retries      # every drop hit tagged posts
    assert bt["timeouts"] == mem.timeouts
    assert bt["posts"] == 4 and bt["verbs"] > 0
    mem.post(plan)                           # untagged traffic: the global
    bt2 = mem.stats()["by_tag"]["probe"]     # counters may grow, the tag
    assert bt2["retries"] == bt["retries"]   # bucket must not


def test_stats_shape_is_unchanged():
    mem = RemoteMemory()
    mem.post(rv.single_read_plan(4, rv.REGION_TABLE, 0, 64), tag="read")
    s = mem.stats()
    assert {"simulated_us", "posts", "doorbells", "verbs", "bytes",
            "by_tag"} <= set(s)
    assert "retries" not in s                # fault block only when faulty
    assert set(s["by_tag"]) == {"read"}
    assert {"posts", "doorbells", "verbs", "bytes", "simulated_us",
            "retries", "timeouts"} <= set(s["by_tag"]["read"])


# ---------------------------------------------------------------------------
# maintenance SLO accounting
# ---------------------------------------------------------------------------

def _filled_single_shard():
    cluster = ClusterStore("continuity", nodes=1, replicas=1,
                           node_slots=256)
    rng = np.random.RandomState(0)
    node = next(iter(cluster._nodes.values()))
    next_id = 0
    while float(node.store.load_factor(node.table)) <= 0.86 \
            and next_id < 2048:
        ids = np.arange(next_id, next_id + 64)
        next_id += 64
        cluster.insert(ycsb.make_key(ids), ycsb.make_value(rng, len(ids)))
    return cluster


def test_slo_burn_counts_under_tiny_slo_and_not_at_defaults():
    with obs.scope() as (_, reg):
        cluster = _filled_single_shard()
        for _ in range(200):
            if not cluster.maintenance_step(budget=2,
                                            step_slo_us=1e-3):
                break
        assert cluster.maintenance["steps"] >= 1
        assert cluster.maintenance["slo_burns"] >= 1
        assert reg.counter("maintenance.slo_burn").value \
            == cluster.maintenance["slo_burns"]
        assert reg.gauge("maintenance.step_us", node="pm0").max > 1e-3
    with obs.scope() as (_, reg):
        cluster = _filled_single_shard()
        for _ in range(200):
            if not cluster.maintenance_step(budget=2):
                break
        assert cluster.maintenance["steps"] >= 1
        assert cluster.maintenance["slo_burns"] == 0
        assert reg.counter("maintenance.slo_burn").value == 0

"""Chaos engineering subsystem: fencing, retry/backoff, degradation.

Store-level drills of every invariant the seeded scenario matrix
(`python -m repro.chaos.matrix`) gates end-to-end:

  * epoch fencing — a partitioned ex-primary's stale-epoch acks are ALL
    detected at resync/failover and none stays visible;
  * transport robustness — per-round timeout, capped exponential backoff
    with jitter, duplicate/reorder absorption, retry-budget exhaustion
    surfacing as an UN-acked (never silently lost) round;
  * retry idempotence — replaying a fenced write round after any
    delivered prefix yields a bit-identical durable PM image, proved as
    a property over every registered scheme;
  * degradation — quorum loss flips the cluster read-only instead of
    acking writes it could lose;
  * two-phase failure detection — the HeartbeatMonitor grace window that
    distinguishes "partitioned but alive" from "dead".
"""

import numpy as np
import pytest

from _propcheck import given, settings, st
from repro import api
from repro.chaos.matrix import GRID
from repro.chaos.scenarios import SCENARIOS, run_scenario
from repro.cluster.store import ClusterStore
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import apply_trace
from repro.data import ycsb
from repro.rdma.transport import (DeliveryTimeout, FaultInjector,
                                  RemoteMemory, RetryPolicy)
from repro.runtime.fault import HeartbeatMonitor

pytestmark = pytest.mark.chaos


def _cluster(**kw):
    cfg = dict(scheme="continuity", nodes=4, replicas=2, node_slots=1024)
    cfg.update(kw)
    return ClusterStore(**cfg)


def _kv(n, seed=0, lo=0):
    rng = np.random.RandomState(seed)
    return ycsb.make_key(np.arange(lo, lo + n)), ycsb.make_value(rng, n)


# ---------------------------------------------------------------------------
# HeartbeatMonitor: two-phase suspect -> failed with a grace window
# ---------------------------------------------------------------------------

class _Clock:
    """Injectable monotonic clock (no sleeps in tier-1)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_grace_two_phase_declaration():
    clk = _Clock()
    mon = HeartbeatMonitor(timeout_s=5.0, clock=clk, grace_s=10.0)
    mon.register("pm0")
    assert mon.state("pm0") == "alive"
    clk.t = 5.0                      # boundary is strict
    assert mon.state("pm0") == "alive"
    clk.t = 5.1
    assert mon.state("pm0") == "suspect"
    assert mon.suspect_hosts() == ["pm0"] and mon.failed_hosts() == []
    clk.t = 15.0                     # timeout + grace, still strict
    assert mon.state("pm0") == "suspect"
    clk.t = 15.1
    assert mon.state("pm0") == "failed"
    assert mon.failed_hosts() == ["pm0"] and mon.suspect_hosts() == []


def test_heartbeat_heal_inside_grace_clears_suspicion():
    """The regression the window exists for: a partition that heals
    before timeout+grace must NOT be declared failed (no double-promote
    of a primary that is alive on the far side)."""
    clk = _Clock()
    mon = HeartbeatMonitor(timeout_s=5.0, clock=clk, grace_s=10.0)
    mon.register("pm0")
    clk.t = 8.0                      # partitioned: silent past timeout
    assert mon.state("pm0") == "suspect"
    mon.heartbeat("pm0", step=1)     # partition heals inside the grace
    assert mon.state("pm0") == "alive"
    assert mon.suspicions_cleared == 1
    clk.t = 13.5                     # 5.5 s silent since the heal
    assert mon.state("pm0") == "suspect"
    clk.t = 23.1                     # timeout + grace since the heal
    assert mon.state("pm0") == "failed"


def test_heartbeat_zero_grace_is_single_phase():
    clk = _Clock()
    mon = HeartbeatMonitor(timeout_s=5.0, clock=clk, grace_s=0.0)
    mon.register("pm0")
    clk.t = 5.1
    assert mon.state("pm0") == "failed"
    assert mon.suspect_hosts() == []


# ---------------------------------------------------------------------------
# transport: timeout, backoff, duplicate/reorder absorption, give-up
# ---------------------------------------------------------------------------

def test_backoff_capped_exponential_with_jitter():
    pol = RetryPolicy(base_us=4.0, cap_us=64.0, jitter=0.0)
    assert pol.backoff_us(0) == 4.0
    assert pol.backoff_us(3) == 32.0
    assert pol.backoff_us(10) == 64.0        # capped
    jit = RetryPolicy(base_us=4.0, cap_us=64.0, jitter=0.5)
    rng = np.random.RandomState(0)
    draws = [jit.backoff_us(2, rng) for _ in range(16)]
    assert len(set(draws)) > 1               # jitter decorrelates
    assert all(8.0 <= d <= 16.0 for d in draws)


def test_drop_storm_exhausts_budget_and_raises():
    mem = RemoteMemory(faults=FaultInjector(drop_p=1.0, seed=0),
                       retry=RetryPolicy(max_attempts=4))
    with pytest.raises(DeliveryTimeout):
        mem._deliver_round(1.0)
    assert mem.give_ups == 1
    assert mem.retries == 4 and mem.timeouts == 4
    assert mem.backoff_us > 0.0


def test_duplicate_and_reorder_absorbed_with_cost():
    dup = RemoteMemory(faults=FaultInjector(dup_p=1.0, seed=0))
    t = dup._deliver_round(1.0)
    assert dup.duplicates == 1
    assert t == pytest.approx(dup.link.rtt_us + 2.0)    # second copy drains
    ro = RemoteMemory(faults=FaultInjector(reorder_p=1.0, seed=0))
    t = ro._deliver_round(1.0)
    assert ro.reorders == 1
    assert t == pytest.approx(2 * ro.link.rtt_us + 1.0)  # one extra RTT


def test_retry_counters_survive_quiesce():
    """The audit phase removes injectors; stats must still report what
    the run survived."""
    mem = RemoteMemory(faults=FaultInjector(drop_p=1.0, seed=0),
                       retry=RetryPolicy(max_attempts=2))
    with pytest.raises(DeliveryTimeout):
        mem._deliver_round(1.0)
    mem.faults = None
    s = mem.stats()
    assert s["give_ups"] == 1 and s["retries"] == 2
    assert "injected" not in s


# ---------------------------------------------------------------------------
# epoch fencing: stale acks detected, lagging nodes routed around
# ---------------------------------------------------------------------------

def test_partition_fence_detects_every_stale_ack():
    c = _cluster()
    K, V = _kv(200)
    assert np.asarray(c.insert(K, V).ok).all()
    e0 = c.epoch
    c.partition("pm1")
    assert c.epoch == e0 + 1                 # partition bumps the epoch
    assert not c._name_serving("pm1")
    # the cut-off ex-primary keeps acking writes under its stale token
    SK, SV = K[:32], V[:32] ^ np.uint32(0xDEAD)
    assert c.stale_write("pm1", SK, SV) == 32
    c.heal("pm1")
    # healed but NOT resynced: visible, still fenced out of routing
    assert c._name_lagging("pm1") and not c._name_serving("pm1")
    rep = c.resync("pm1")
    assert rep.stale_acks_detected == 32
    assert (c.chaos["stale_acks_detected"]
            == c.chaos["stale_acks_injected"] == 32)
    assert c._name_serving("pm1")
    r = c.lookup(K)                          # no stale value visible anywhere
    assert np.asarray(r.found).all()
    assert (np.asarray(r.values) == V).all()


def test_failover_of_partitioned_node_detects_stale_acks():
    c = _cluster()
    K, V = _kv(200)
    assert np.asarray(c.insert(K, V).ok).all()
    c.partition("pm2")
    c.stale_write("pm2", K[:16], V[:16] ^ np.uint32(1))
    c.failover("pm2")                        # declared failed while cut off
    assert c.chaos["stale_acks_detected"] == 16
    r = c.lookup(K)
    assert np.asarray(r.found).all()
    assert (np.asarray(r.values) == V).all()


def test_healed_node_stays_fenced_through_unrelated_churn():
    c = _cluster()
    K, V = _kv(120)
    assert np.asarray(c.insert(K, V).ok).all()
    c.partition("pm3")
    c.heal("pm3")
    e = c.epoch
    c.join("pm9")                            # unrelated membership churn
    assert c.epoch > e
    # the join's epoch bump must NOT hand pm3 a current token
    assert c._name_lagging("pm3") and not c._name_serving("pm3")
    c.resync("pm3")
    assert c._name_serving("pm3")
    r = c.lookup(K)
    assert np.asarray(r.found).all()
    assert (np.asarray(r.values) == V).all()


# ---------------------------------------------------------------------------
# degradation: quorum-loss read-only, exhausted budget -> un-acked round
# ---------------------------------------------------------------------------

def test_quorum_loss_flips_read_only_but_keeps_reading():
    c = _cluster(nodes=3, replicas=2)
    K, V = _kv(150)
    assert np.asarray(c.insert(K, V).ok).all()
    c.kill("pm2")
    c.failover("pm2")
    assert not c.read_only                   # 2 serving == replicas
    c.kill("pm1")
    c.failover("pm1")
    assert c.read_only                       # 1 serving < replicas
    K2, V2 = _kv(10, seed=1, lo=1000)
    res = c.insert(K2, V2)
    assert not np.asarray(res.ok).any()      # never ack what it could lose
    assert c.chaos["writes_rejected_read_only"] == 10
    r = c.lookup(K)                          # reads keep flowing, exact
    assert np.asarray(r.found).all()
    assert (np.asarray(r.values) == V).all()


def test_exhausted_retry_budget_unacks_never_loses():
    c = _cluster()
    K, V = _kv(100)
    assert np.asarray(c.insert(K, V).ok).all()
    for name in c.node_names():
        node = c.node(name)
        node.mem.faults = FaultInjector(drop_p=1.0, seed=7)
        node.mem.retry = RetryPolicy(max_attempts=2)
    V2 = V ^ np.uint32(5)
    res = c.update(K[:32], V2[:32])
    assert not np.asarray(res.ok).any()      # budget exhausted -> un-acked
    assert c.chaos["write_timeouts"] > 0
    c.quiesce_faults()
    r = c.lookup(K)
    vals, found = np.asarray(r.values), np.asarray(r.found)
    assert found.all()
    # un-acked updates are INDETERMINATE (may have applied before the ack
    # round died): targeted keys hold the old or the new value, nothing
    # else; untargeted keys are exact
    old = (vals == V).all(axis=1)
    new = (vals == V2).all(axis=1)
    targeted = np.zeros(len(K), bool)
    targeted[:32] = True
    assert (old | (targeted & new)).all()
    assert old[~targeted].all()


# ---------------------------------------------------------------------------
# property: fenced write round retry is idempotent, every scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", list(HANDLERS))
@settings(max_examples=10, deadline=None)
@given(op=st.sampled_from(["insert", "update", "delete"]),
       seed=st.integers(min_value=0, max_value=2 ** 20),
       prefix_pct=st.integers(min_value=0, max_value=100))
def test_fenced_write_round_retry_idempotent(scheme, op, seed, prefix_pct):
    """The transport's timeout -> backoff -> replay loop assumes replaying
    a fenced write round is safe.  Property: for every registered scheme,
    any delivered PREFIX of a round followed by a full replay leaves the
    durable PM image bit-identical to one clean delivery."""
    h = HANDLERS[scheme]
    store = api.make_store(scheme, table_slots=240)
    rng = np.random.RandomState(seed)
    base_k = ycsb.make_key(np.arange(24))
    t = store.create()
    t, _ = store.insert(t, base_k, ycsb.make_value(rng, 24))
    base = h.init_state(store.cfg, t)

    if op == "insert":
        K = ycsb.make_key(np.arange(100, 108) + seed % 50)
    else:
        K = base_k[seed % 3::3][:8]
    V = None if op == "delete" else ycsb.make_value(rng, len(K))
    final, trace = trace_batch(h, store.cfg, base, op, K, V)

    clean = apply_trace(base, trace)         # one clean delivery
    p = prefix_pct * len(trace.records) // 100
    partial = apply_trace(base, trace, upto=p)   # round dies after p stores
    retried = apply_trace(partial, trace)        # full replay on top
    for field in clean:
        assert np.array_equal(clean[field], retried[field]), \
            (scheme, op, field, p)
        assert np.array_equal(clean[field], final[field]), (scheme, op, field)


# ---------------------------------------------------------------------------
# scenario cells (the fast drills + one YCSB partition cell)
# ---------------------------------------------------------------------------

def test_matrix_grid_covers_every_scenario_and_scan_rmw():
    assert {s for s, _ in GRID} == set(SCENARIOS)
    assert {"E", "F"} <= {w for _, w in GRID}   # short scans + RMW present


def test_scenario_read_only_degrade_cell():
    cell = run_scenario("read_only_degrade", seed=5)
    assert cell["ok"], cell["checks"]
    assert cell["committed_lost"] == 0
    assert cell["chaos"]["writes_rejected_read_only"] > 0


def test_scenario_timeout_giveup_cell():
    cell = run_scenario("timeout_giveup", seed=5)
    assert cell["ok"], cell["checks"]
    assert cell["wire"]["give_ups"] > 0


@pytest.mark.slow
def test_scenario_partition_fence_cell_scan_workload():
    cell = run_scenario("partition_fence", workload="E", seed=2)
    assert cell["ok"], cell["checks"]
    assert (cell["chaos"]["stale_acks_detected"]
            == cell["chaos"]["stale_acks_injected"] > 0)
    assert cell["committed_lost"] == 0


# ---------------------------------------------------------------------------
# seeded cluster sim payload (the replay contract)
# ---------------------------------------------------------------------------

def test_cluster_sim_payload_echoes_seed_and_chaos():
    from repro.cluster.sim import run_cluster
    p = run_cluster(num_records=200, num_ops=200, batch=100, nodes=3,
                    replicas=2, node_slots=1024, seed=11)
    assert p["seed"] == 11
    assert p["committed_lost"] == 0
    assert "chaos" in p and "stale_acks_injected" in p["chaos"]

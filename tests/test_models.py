"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward/train step asserting output shapes + finite values, plus
family-specific structure checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, smoke_config
from repro.models import transformer as T
from repro.models.config import SHAPES, input_specs, shape_applicable


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_loss(name):
    cfg = smoke_config(name)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 128
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "embed":
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    x, aux = T.forward(cfg, params, inputs)
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all())
    loss = T.loss_fn(cfg, params, {"inputs": inputs, "labels": labels})
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_one_train_step(name):
    from repro.training import optimizer as O
    from repro.training.train_step import make_train_step
    cfg = smoke_config(name)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = O.init(params)
    step = make_train_step(cfg, O.OptConfig(lr=1e-3), num_micro=1)
    B, S = 2, 64
    if cfg.frontend == "embed":
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    else:
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"inputs": inputs,
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab)}
    params2, state2, stats = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(stats["loss"]))
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


def test_full_configs_match_assignment():
    """Exact architecture numbers from the assignment block."""
    c = get_arch("starcoder2-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 6144, 48, 4, 24576, 49152)
    c = get_arch("minitron-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 16384, 256000)
    c = get_arch("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 5120, 40, 40, 27392, 152064)
    assert c.qkv_bias
    c = get_arch("yi-6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 4, 11008, 64000)
    c = get_arch("granite-moe-1b-a400m")
    assert (c.n_layers, c.d_model, c.moe.num_experts, c.moe.top_k,
            c.moe.expert_dff, c.vocab) == (24, 1024, 32, 8, 512, 49155)
    c = get_arch("granite-moe-3b-a800m")
    assert (c.n_layers, c.d_model, c.moe.num_experts, c.moe.top_k,
            c.vocab) == (32, 1536, 40, 8, 49155)
    c = get_arch("musicgen-large")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 2048, 32, 32, 8192, 2048)
    c = get_arch("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.ssm.d_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = get_arch("llava-next-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (60, 7168, 56, 8, 20480, 64000)
    c = get_arch("mamba2-370m")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab,
            c.ssm.d_state) == (48, 1024, 0, 50280, 128)


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md)."""
    runs = {a for a in ARCHS
            if shape_applicable(get_arch(a), SHAPES["long_500k"])[0]}
    assert runs == {"hymba-1.5b", "mamba2-370m"}


def test_param_count_analytic_vs_actual():
    for name in ("yi-6b", "granite-moe-1b-a400m", "mamba2-370m"):
        cfg = smoke_config(name)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        # analytic formula should be within 5% on smoke configs
        assert abs(actual - cfg.param_count) / actual < 0.05, \
            (name, actual, cfg.param_count)


def test_sliding_window_equals_full_for_short_seq():
    """window >= seq_len must reproduce full attention exactly."""
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    x1, _ = T.forward(cfg, params, toks)
    cfg_w = dataclasses.replace(cfg, window=64)     # window > S
    x2, _ = T.forward(cfg_w, params, toks)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=2e-4, atol=2e-4)


def test_window_masks_distant_tokens():
    """With a tiny window, distant tokens must not influence the output."""
    cfg = dataclasses.replace(smoke_config("yi-6b"), window=16, attn_chunk=16)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab)
    x1, _ = T.forward(cfg, params, toks)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab)
    x2, _ = T.forward(cfg, params, toks2)
    # position 0 changed; far-away outputs (>= 3 windows on) must be identical
    np.testing.assert_allclose(np.asarray(x1[0, 63]), np.asarray(x2[0, 63]),
                               atol=1e-5)


def test_moe_router_load_balance_aux():
    cfg = smoke_config("granite-moe-1b-a400m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    _, aux = T.forward(cfg, params, toks)
    # Switch aux loss ~1.0 at balanced routing, larger when skewed
    assert 0.5 < float(aux) / cfg.n_layers < 4.0


def test_mamba2_state_carries_information():
    """An input perturbation at t=0 must reach the last output (recurrence),
    even past the chunk boundary."""
    cfg = smoke_config("mamba2-370m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 80), 0, cfg.vocab)
    x1, _ = T.forward(cfg, params, toks)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab)
    x2, _ = T.forward(cfg, params, toks2)
    assert float(jnp.abs(x1[0, -1] - x2[0, -1]).max()) > 0


def test_causal_skip_equals_masked():
    """The cond-skipped blockwise attention is numerically identical."""
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 100), 0, cfg.vocab)
    x1, _ = T.forward(cfg, params, toks)
    x2, _ = T.forward(dataclasses.replace(cfg, attn_mode="causal_skip"),
                      params, toks)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               atol=2e-4, rtol=2e-3)


def test_dense_moe_equals_sorted():
    """Dense-MoE produces the same outputs as capacity-dispatch (with a
    capacity high enough that nothing drops)."""
    from repro.models.config import MoEConfig
    base = smoke_config("granite-moe-1b-a400m")
    cs = dataclasses.replace(base, moe=MoEConfig(8, 2, 64, 8.0, "sorted"))
    cd = dataclasses.replace(base, moe=MoEConfig(8, 2, 64, 8.0, "dense"))
    params = T.init_params(cs, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, base.vocab)
    y1, a1 = T.forward(cs, params, toks)
    y2, a2 = T.forward(cd, params, toks)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-3)
    assert abs(float(a1) - float(a2)) < 1e-5


def test_vocab_padding_masks_and_learns():
    cfg = dataclasses.replace(smoke_config("yi-6b"), vocab=500,
                              vocab_pad_to=16)
    assert cfg.padded_vocab == 512
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    assert params["embed"].shape[0] == 512
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    x, _ = T.forward(cfg, params, toks)
    lg = T.logits_fn(cfg, params, x)
    assert float(np.asarray(lg)[..., 500:].max()) < -1e29
    assert int(jnp.argmax(lg, -1).max()) < 500
    loss = T.loss_fn(cfg, params, {"inputs": toks,
                                   "labels": jnp.roll(toks, -1, 1)})
    assert np.isfinite(float(loss))

"""Distributed KV store + model sharding on 8 fake devices.

Device count is locked at first jax init, so these run in a SUBPROCESS with
XLA_FLAGS set — the main pytest process keeps 1 device (per the dry-run
isolation contract).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str):
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_store_roundtrip_and_counters():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
import repro.core.distributed as D
from repro.core import continuity as ch
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh((4, 2), ("data", "model"))
scfg = D.StoreConfig(table=ch.ContinuityConfig(num_buckets=256, ext_frac=0.0),
                     num_shards=4)
table = D.create_sharded(scfg)
lookup = D.make_lookup(scfg, mesh)
write = D.make_write(scfg, mesh)
rng = np.random.RandomState(0)
B = 64
K = rng.randint(0, 2**31, size=(B, 4)).astype(np.uint32)
V = rng.randint(0, 2**31, size=(B, 4)).astype(np.uint32)
with mesh:
    table, ok, routed = write(table, jnp.full((B,), D.OP_INSERT, jnp.int32),
                              jnp.asarray(K), jnp.asarray(V))
    assert int(ok.sum()) == B
    res = lookup(table, jnp.asarray(K))
    assert bool(np.asarray(res.found).all())
    assert (np.asarray(res.values) == V).all()
    assert int(D.sharded_count(table)) == B
    neg = lookup(table, jnp.asarray(rng.randint(0, 2**31, size=(B, 4)).astype(np.uint32)))
    assert int(neg.found.sum()) == 0
    table, dok, _ = write(table, jnp.full((B,), D.OP_DELETE, jnp.int32),
                          jnp.asarray(K), jnp.asarray(V))
    assert int(dok.sum()) == B and int(D.sharded_count(table)) == 0
print("STORE-OK")
""")
    assert "STORE-OK" in out


def test_store_matches_local_semantics():
    """Distributed ops produce the same member set as the local table."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
import repro.core.distributed as D
from repro.core import continuity as ch
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh((8,), ("data",))
tcfg = ch.ContinuityConfig(num_buckets=512, ext_frac=0.0)
scfg = D.StoreConfig(table=tcfg, num_shards=8)
dt = D.create_sharded(scfg)
write = D.make_write(scfg, mesh)
lookup = D.make_lookup(scfg, mesh)
lt = ch.create(tcfg)
rng = np.random.RandomState(1)
B = 128
K = rng.randint(0, 2**31, size=(B, 4)).astype(np.uint32)
V = rng.randint(0, 2**31, size=(B, 4)).astype(np.uint32)
with mesh:
    # clients retry routing-capacity overflows (the RDMA full-send-queue
    # analogue) until every insert lands
    pending = jnp.full((B,), D.OP_INSERT, jnp.int32)
    done = np.zeros((B,), bool)
    for _ in range(6):
        dt, dok, _ = write(dt, pending, jnp.asarray(K), jnp.asarray(V))
        done |= np.asarray(dok)
        pending = jnp.where(jnp.asarray(done), 0, D.OP_INSERT).astype(jnp.int32)
        if done.all():
            break
lt, lok, _ = ch.insert(tcfg, lt, K, V)
assert done.sum() == int(lok.sum()) == B
found = np.zeros((B,), bool)
resolved = np.zeros((B,), bool)
vals = np.zeros((B, 4), np.uint32)
with mesh:
    for _ in range(6):   # retry unrouted keys with an updated mask
        res = lookup(dt, jnp.asarray(K), jnp.asarray(~resolved))
        routed = np.asarray(res.routed)
        f = np.asarray(res.found)
        take = routed & ~resolved
        found[take] = f[take]
        vals[take & f] = np.asarray(res.values)[take & f]
        resolved |= routed
        if resolved.all():
            break
assert resolved.all()
lres = ch.lookup(tcfg, lt, K)
assert (found == np.asarray(lres.found)).all()
assert (vals[found] == np.asarray(lres.values)[found]).all()
print("SEMANTICS-OK")
""")
    assert "SEMANTICS-OK" in out


def test_sharded_train_step_matches_single_device():
    """A tiny model trained 2 steps under a (2,4) mesh == unsharded run."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.distribution.sharding import use_mesh
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training.train_step import make_train_step
cfg = smoke_config("yi-6b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
state = O.init(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
step = make_train_step(cfg, O.OptConfig(lr=1e-3))
# unsharded reference
p1, s1, st1 = jax.jit(step)(params, state, batch)
# sharded
mesh = make_debug_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    p2, s2, st2 = jax.jit(step)(params, state, batch)
assert abs(float(st1["loss"]) - float(st2["loss"])) < 1e-3
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-3)
print("TRAIN-SHARD-OK")
""")
    assert "TRAIN-SHARD-OK" in out


@pytest.mark.slow
def test_dryrun_cell_small():
    """The dry-run driver itself lowers a debug-scale cell end to end."""
    out = run_sub("""
from repro.launch.dryrun import collective_bytes
# parse a synthetic HLO line
line = ('  %all-gather.3 = bf16[16,4096,1024]{2,1,0} all-gather(%p), '
        'channel_id=4, replica_groups=[16,16]<=[256], dimensions={0}')
c = collective_bytes(line)
assert c["all-gather"]["count"] == 1
assert c["all-gather"]["bytes"] == 16*4096*1024*2 // 16
print("PARSE-OK")
""")
    assert "PARSE-OK" in out

"""The `repro.api` contract, registry-parametrized over EVERY scheme.

Three layers:
  * protocol conformance — each registered store satisfies `HashStore` and
    the uniform create/insert/update/delete/lookup/resize/load_factor/stats
    round-trip, including masked batches;
  * accounting — `CostLedger` PM-write averages reproduce paper Table I
    (continuity 2/2/1, level 2/~2/1, pfarm 5/5/5) and read amplification
    orders (continuity 1 <= level <= 4);
  * execution policy — `ExecPolicy(serial)` vs `ExecPolicy(wave)` produce
    byte-identical tables/counters through the API, and the Pallas probe
    strategies match the gather lookup exactly.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import api
from repro.data import ycsb

SLOTS = 1024
N = 300


def keys_vals(n=N, seed=0, start=0):
    rng = np.random.RandomState(seed)
    return ycsb.make_key(np.arange(start, start + n)), ycsb.make_value(rng, n)


@pytest.fixture(params=api.available_schemes())
def scheme(request):
    return request.param


@pytest.fixture
def store(scheme):
    return api.make_store(scheme, table_slots=SLOTS)


def test_registry_lists_builtin_schemes():
    names = api.available_schemes()
    for expected in ("continuity", "level", "pfarm", "dense"):
        assert expected in names


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown scheme"):
        api.make_store("cuckoo")
    with pytest.raises(ValueError, match="already registered"):
        api.register_scheme("dense", api.DenseStore.from_slots)


def test_store_satisfies_protocol(store):
    assert isinstance(store, api.HashStore)
    for method in ("create", "insert", "update", "delete", "lookup",
                   "resize", "load_factor", "stats"):
        assert callable(getattr(store, method)), method
    assert isinstance(store.policy, api.ExecPolicy)
    # hashable + frozen: usable as jit static / inside frozen configs
    assert hash(store) == hash(dataclasses.replace(store))


def test_crud_roundtrip(store):
    K, V = keys_vals()
    t = store.create()
    t, ins = store.insert(t, K, V)
    assert bool(ins.ok.all())
    assert int(t.count) == N

    hit = store.lookup(t, K)
    assert bool(hit.ok.all())
    np.testing.assert_array_equal(np.asarray(hit.values), V)
    assert int(hit.ledger.ops) == N
    assert bool((np.asarray(hit.reads) >= 1).all())

    neg = ycsb.negative_keys(np.random.RandomState(9), N, 64)
    assert not bool(store.lookup(t, neg).ok.any())

    V2 = keys_vals(seed=5)[1]
    t, upd = store.update(t, K, V2)
    assert bool(upd.ok.all())
    np.testing.assert_array_equal(np.asarray(store.lookup(t, K).values), V2)

    t, dele = store.delete(t, K[: N // 2])
    assert bool(dele.ok.all())
    assert int(t.count) == N - N // 2
    assert not bool(store.lookup(t, K[: N // 2]).ok.any())
    assert bool(store.lookup(t, K[N // 2:]).ok.all())

    lf = float(store.load_factor(t))
    assert 0.0 < lf < 1.0
    info = store.stats(t)
    assert info["scheme"] == store.name
    assert info["count"] == N - N // 2
    assert info["total_slots"] >= SLOTS - 20  # sized to ~table_slots


def test_masked_mutations(store):
    """Masked-off ops must neither write nor count, for every scheme —
    what lets ANY registered scheme back the serving page table."""
    K, V = keys_vals(n=64)
    mask = np.arange(64) % 2 == 0
    t = store.create()
    t, ins = store.insert(t, K, V, mask)
    assert bool((np.asarray(ins.ok) == mask).all())
    assert int(t.count) == mask.sum()
    # masked batch pays exactly what inserting only the survivors pays,
    # and the ops denominator counts only ACTIVE ops (per-op averages of a
    # masked batch match the unmasked equivalent)
    _, ref = store.insert(store.create(), K[mask], V[mask])
    assert int(ins.ledger.pm_writes) == int(ref.ledger.pm_writes)
    assert int(ins.ledger.ops) == int(mask.sum())
    assert ins.ledger.pm_per_op() == ref.ledger.pm_per_op()
    hit = store.lookup(t, K)
    assert bool((np.asarray(hit.ok) == mask).all())
    t, dele = store.delete(t, K, ~mask)
    assert not bool(dele.ok.any()) and int(t.count) == mask.sum()
    t, dele = store.delete(t, K, mask)
    assert int(t.count) == 0


def test_resize_preserves_members(store):
    K, V = keys_vals(n=128)
    t = store.create()
    t, _ = store.insert(t, K, V)
    t, _ = store.delete(t, K[:32])
    big, bt = store.resize(t, factor=2)
    assert big.total_slots(bt) >= 2 * (store.total_slots(t) - 40)
    assert int(bt.count) == 96
    assert not bool(big.lookup(bt, K[:32]).ok.any())
    hit = big.lookup(bt, K[32:])
    assert bool(hit.ok.all())
    np.testing.assert_array_equal(np.asarray(hit.values), V[32:])


# ---------------------------------------------------------------------------
# accounting: paper Table I through the unified ledger
# ---------------------------------------------------------------------------

TABLE_I = {  # scheme -> (insert, update, delete) PM writes per op
    "continuity": (2.0, 2.0, 1.0),
    "pfarm": (5.0, 5.0, 5.0),
}


def test_ledger_reproduces_paper_table1(scheme):
    K, V = keys_vals()
    store = api.make_store(scheme, table_slots=4096)
    t = store.create()
    t, ins = store.insert(t, K, V)
    t, upd = store.update(t, K, keys_vals(seed=3)[1])
    t, dele = store.delete(t, K[: N // 2])
    cells = (ins.ledger.pm_per_op(), upd.ledger.pm_per_op(),
             dele.ledger.pm_per_op())
    if scheme in TABLE_I:
        assert cells == pytest.approx(TABLE_I[scheme])
    elif scheme == "level":
        # paper reports insert 2–2.01, update 2–5 (logged fallback), delete 1
        assert cells[0] == pytest.approx(2.0, abs=0.05)
        assert 2.0 <= cells[1] <= 5.0
        assert cells[2] == pytest.approx(1.0)


def test_read_amplification_ordering():
    """Continuity: 1 fetch/lookup; level: up to 4 — the paper's §II claim,
    measured through one ledger."""
    K, V = keys_vals()
    reads = {}
    for scheme in ("continuity", "level", "pfarm"):
        store = api.make_store(scheme, table_slots=4096)
        t, _ = store.insert(store.create(), K, V)
        reads[scheme] = store.lookup(t, K).ledger.reads_per_op()
    assert reads["continuity"] == pytest.approx(1.0)
    assert 1.0 <= reads["level"] <= 4.0
    assert reads["continuity"] <= reads["level"]
    assert reads["pfarm"] >= 1.0


# ---------------------------------------------------------------------------
# execution policy: one boundary, interchangeable strategies
# ---------------------------------------------------------------------------

def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_policy_serial_vs_wave_byte_identical():
    K, V = keys_vals()
    V2 = keys_vals(seed=7)[1]
    wave = api.make_store("continuity", table_slots=SLOTS)
    serial = wave.with_policy(api.ExecPolicy(engine="serial"))
    tw, rw = wave.insert(wave.create(), K, V)
    ts, rs = serial.insert(serial.create(), K, V)
    _tree_equal(tw, ts)
    _tree_equal(rw, rs)
    tw2, uw = wave.update(tw, K[::3], V2[::3])
    ts2, us = serial.update(ts, K[::3], V2[::3])
    _tree_equal(tw2, ts2)
    _tree_equal(uw, us)
    tw3, dw = wave.delete(tw2, K[1::2])
    ts3, ds = serial.delete(ts2, K[1::2])
    _tree_equal(tw3, ts3)
    _tree_equal(dw, ds)


@pytest.mark.parametrize("probe", ["reference", "pallas"])
def test_policy_probe_strategies_match_gather(probe):
    n = 96
    K, V = keys_vals(n=n)
    gather = api.make_store("continuity", table_slots=512)
    t, _ = gather.insert(gather.create(), K, V)
    kern = gather.with_policy(api.ExecPolicy(probe=probe, qblock=8))
    for q in (K, ycsb.negative_keys(np.random.RandomState(2), n, 32)):
        a = kern.lookup(t, q)
        b = gather.lookup(t, q)
        _tree_equal(a, b)


def test_policy_validation():
    with pytest.raises(AssertionError):
        api.ExecPolicy(engine="quantum")
    with pytest.raises(AssertionError):
        api.ExecPolicy(probe="telepathy")


def test_custom_scheme_registration_roundtrip():
    """The registry is the extension seam: a new scheme registered at
    runtime is immediately usable through the same surface."""
    def tiny_dense(table_slots, policy, **kw):
        return api.DenseStore.from_slots(max(8, table_slots // 4), policy)

    api.register_scheme("dense_quarter", tiny_dense)
    try:
        st = api.make_store("dense_quarter", table_slots=64)
        assert st.cfg.capacity == 16
        K, V = keys_vals(n=8)
        t, res = st.insert(st.create(), K, V)
        assert bool(res.ok.all())
        assert bool(st.lookup(t, K).ok.all())
    finally:
        from repro.api import registry as _r
        _r._REGISTRY.pop("dense_quarter", None)

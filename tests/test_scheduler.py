"""Continuous batcher: admission, generation, release, slot reuse."""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.config import ShapeConfig
from repro.serving import kvcache as KC
from repro.serving.scheduler import ContinuousBatcher, Request


def test_continuous_batching_drains_queue():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("s", seq_len=128, global_batch=4, kind="decode")
    geom = KC.make_geometry(cfg, shape, shards=2, page_size=16)
    batcher = ContinuousBatcher(cfg, geom, params)

    rng = np.random.RandomState(0)
    n_req = 7                                   # more requests than slots
    for rid in range(n_req):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab, size=(rng.randint(3, 10),)
                               ).astype(np.int32),
            max_new_tokens=4 + rid % 3))
    finished = batcher.run(max_steps=300)

    assert sorted(finished) == list(range(n_req))
    for rid, out in finished.items():
        assert len(out) == 4 + rid % 3
        assert all(0 <= t < cfg.vocab for t in out)
    # all pages released at the end
    assert int(batcher.cache.table.count.sum()) == 0
    # slots were reused (7 requests through 4 slots)
    assert all(s is None for s in batcher.slots)

"""Examples smoke: every example at least compiles, and the two cheap ones
actually RUN end-to-end (so examples can't silently rot against API
changes — exactly what happened to ycsb_cluster before the transport
refactor)."""

import os
import pathlib
import py_compile
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_compiles(path):
    py_compile.compile(str(path), doraise=True)


def _run(script, *args, timeout):
    env = dict(os.environ, PYTHONPATH=f"{ROOT}/src")
    env.pop("XLA_FLAGS", None)     # ycsb_cluster sets its own device count
    return subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)


def test_quickstart_runs():
    proc = _run("quickstart.py", timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "Table I" in proc.stdout


def test_serve_kv_cache_demo_runs():
    # the client-cache tier in front of the page-table store: no model,
    # so it is cheap enough for the fast tier; the script itself asserts
    # no client ever served a remapped (stale) page
    proc = _run("serve_kv.py", "--cache", "--clients", "8", timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "stale_served=0" in proc.stdout
    assert "cache check passed" in proc.stdout


@pytest.mark.slow
def test_ycsb_cluster_smoke_runs():
    # 8 simulated host devices + the RDMA transport comparison + the
    # replicated cluster with a mid-run primary kill; the script asserts
    # routing consistency, the read-heavy ordering, and zero committed-op
    # loss across the failover itself
    proc = _run("ycsb_cluster.py", "--smoke", "--nodes", "3",
                "--kill-primary", timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "consistency check passed" in proc.stdout
    assert "ordering check passed" in proc.stdout
    assert "failover check passed" in proc.stdout

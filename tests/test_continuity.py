"""Core continuity-hashing behaviour: paper §III semantics + Table I."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.continuity as ch
from repro.data import ycsb

CFG = ch.ContinuityConfig(num_buckets=64)


def keys_vals(n, seed=0, base=0):
    rng = np.random.RandomState(seed)
    return (ycsb.make_key(np.arange(base, base + n)),
            ycsb.make_value(rng, n))


def test_geometry_matches_paper():
    """Defaults reproduce Fig 2/3: 20 main slot bits + 12 ext = 32-bit
    indicator; segment = 16 slots; one segment fetch = ~520 B."""
    assert CFG.slots_per_pair == 20
    assert CFG.seg_slots == 16
    assert CFG.ext_slots == 12
    assert CFG.total_bits == 32
    assert CFG.segment_bytes == 8 + 8 + 16 * 32   # indicator + fp word + slots


def test_insert_lookup_roundtrip():
    t = ch.create(CFG)
    K, V = keys_vals(100)
    t, ok, ctr = ch.insert(CFG, t, K, V)
    assert bool(ok.all())
    res = ch.lookup(CFG, t, K)
    assert bool(res.found.all())
    np.testing.assert_array_equal(np.asarray(res.values), V)
    # PM writes: exactly 2 per insert (payload + indicator)  [Table I]
    assert int(ctr.pm_writes) == 2 * 100


def test_negative_lookup_single_read():
    t = ch.create(CFG)
    K, V = keys_vals(100)
    t, _, _ = ch.insert(CFG, t, K, V)
    neg = ycsb.negative_keys(np.random.RandomState(1), 100, 200)
    res = ch.lookup(CFG, t, neg)
    assert not bool(res.found.any())
    # no extensions allocated -> exactly ONE contiguous fetch per lookup
    assert int(res.reads.max()) == 1


def test_delete_semantics_and_cost():
    t = ch.create(CFG)
    K, V = keys_vals(50)
    t, _, _ = ch.insert(CFG, t, K, V)
    t, ok, ctr = ch.delete(CFG, t, K[:25])
    assert bool(ok.all())
    assert int(ctr.pm_writes) == 25          # 1 PM write per delete [Table I]
    res = ch.lookup(CFG, t, K)
    assert not bool(res.found[:25].any())
    assert bool(res.found[25:].all())
    # delete of absent key is a no-op
    t2, ok2, ctr2 = ch.delete(CFG, t, K[:25])
    assert not bool(ok2.any()) and int(ctr2.pm_writes) == 0


def test_update_out_of_place_atomic():
    t = ch.create(CFG)
    K, V = keys_vals(50)
    t, _, _ = ch.insert(CFG, t, K, V)
    V2 = keys_vals(50, seed=9)[1]
    t, ok, ctr = ch.update(CFG, t, K, V2)
    assert bool(ok.all())
    assert int(ctr.pm_writes) == 2 * 50      # payload + ONE indicator commit
    res = ch.lookup(CFG, t, K)
    np.testing.assert_array_equal(np.asarray(res.values), V2)
    assert int(t.count) == 50                # no duplicates


def test_update_missing_key_fails():
    t = ch.create(CFG)
    K, V = keys_vals(10)
    t, ok, _ = ch.update(CFG, t, K, V)
    assert not bool(ok.any())


def test_crash_between_payload_and_commit_is_invisible():
    """Paper §III-C: a crash after the payload store but BEFORE the atomic
    indicator commit leaves the table consistent (partial write invisible)."""
    t = ch.create(CFG)
    K, V = keys_vals(8)
    t, _, _ = ch.insert(CFG, t, K[:4], V[:4])
    before = ch.items_host(CFG, t)

    k, v = jnp.asarray(K[5]), jnp.asarray(V[5])
    pair, slot, ok, need_alloc, ext_idx = ch._find_insert_slot(CFG, t, k)
    crashed = ch._scatter_payload(t, ok, pair, slot, ext_idx, k, v,
                                  CFG.slots_per_pair)
    # NO _commit_indicator: simulated crash here.
    after = ch.items_host(CFG, crashed)
    assert before == after                    # partial write invisible
    res = ch.lookup(CFG, crashed, K[5:6])
    assert not bool(res.found[0])
    # recovery = nothing to do; a fresh insert succeeds and commits
    t2, ok2, _ = ch._insert_one(CFG, crashed, k, v)
    assert bool(ok2)
    assert bool(ch.lookup(CFG, t2, K[5:6]).found[0])


def test_probe_direction_by_parity():
    """Even homes fill bucket-then-SBuckets left->right; odd homes fill
    right->left (paper's directional scans)."""
    t = ch.create(CFG)
    found_even = found_odd = False
    for i in range(2000):
        k = ycsb.make_key(np.array([i]))
        pair, parity = ch.locate(CFG, jnp.asarray(k))
        t2, ok, _ = ch._insert_one(CFG, t, jnp.asarray(k[0]),
                                   jnp.asarray(k[0]))
        slot = int(ch.lookup(CFG, t2, k).slot[0])
        if int(parity[0]) == 0 and not found_even:
            assert slot == 0                  # first even insert -> slot 0
            found_even = True
        if int(parity[0]) == 1 and not found_odd:
            assert slot == CFG.slots_per_pair - 1   # first odd -> last slot
            found_odd = True
        if found_even and found_odd:
            break
    assert found_even and found_odd


def test_extension_allocation_and_two_reads():
    """Overflowing a segment allocates one added SBucket group (<=1/10 of
    pairs) and lookups of extended pairs cost at most 2 fetches."""
    cfg = ch.ContinuityConfig(num_buckets=4, ext_frac=0.5)
    t = ch.create(cfg)
    # drive inserts until an extension appears
    n = 0
    for i in range(200):
        K = ycsb.make_key(np.array([i]))
        t, ok, _ = ch.insert(cfg, t, K, K)
        n += int(ok[0])
        if int(t.ext_count) > 0:
            break
    assert int(t.ext_count) >= 1
    K = ycsb.make_key(np.arange(i + 1))
    res = ch.lookup(cfg, t, K)
    assert int(res.reads.max()) <= 2
    assert bool(res.found[np.asarray(res.found)].all())


def test_resize_preserves_items():
    cfg = ch.ContinuityConfig(num_buckets=8)
    t = ch.create(cfg)
    K, V = keys_vals(40)
    t, ok, _ = ch.insert(cfg, t, K, V)
    okn = np.asarray(ok)
    before = ch.items_host(cfg, t)
    ncfg, nt = ch.resize(cfg, t)
    after = ch.items_host(ncfg, nt)
    assert before == after
    assert ncfg.num_buckets == 16


def test_resize_crash_recovery():
    """Interrupt a stepwise resize mid-way, run the paper's restart
    procedure, and verify not a single item is lost or duplicated."""
    cfg = ch.ContinuityConfig(num_buckets=8)
    t = ch.create(cfg)
    K, V = keys_vals(30)
    t, ok, _ = ch.insert(cfg, t, K, V)
    before = ch.items_host(cfg, t)
    ncfg = cfg.grow(2)
    nt = ch.create(ncfg)
    # move only 7 items, then "crash"
    t, nt, moved = ch.resize_stepwise(cfg, t, ncfg, nt, max_items=7)
    assert moved == 7
    # restart: recovery completes the resize
    t, nt = ch.recover(cfg, t, ncfg, nt)
    after = ch.items_host(ncfg, nt)
    assert before == after
    assert ch.items_host(cfg, t) == {}        # old table fully drained


def test_load_factor_reaches_paper_band():
    """With 1/10 added SBuckets the paper reports ~70% load factors; accept
    anything >= 55% on the small 20-bucket table of Fig 18."""
    cfg = ch.ContinuityConfig(num_buckets=20, ext_frac=0.1)
    t = ch.create(cfg)
    i = 0
    while True:
        K = ycsb.make_key(np.arange(i, i + 4))
        t, ok, _ = ch.insert(cfg, t, K, ycsb.make_value(
            np.random.RandomState(i), 4))
        i += int(np.asarray(ok).sum())
        if not bool(np.asarray(ok).all()):
            break
    lf = float(ch.load_factor(cfg, t))
    assert lf >= 0.55, lf


def test_insert_parallel_matches_scan_semantics():
    cfg = ch.ContinuityConfig(num_buckets=128)
    t1 = ch.create(cfg)
    t2 = ch.create(cfg)
    K, V = keys_vals(64)
    t1, ok1, _ = ch.insert(cfg, t1, K, V)
    t2, ok2, retry = ch.insert_parallel(cfg, t2, K, V)
    # retries are exactly the non-first same-pair duplicates
    done = np.asarray(ok2)
    r = np.asarray(retry)
    assert (done | r).all()
    # finishing the retries converges to the same member set
    while r.any():
        t2, ok2, retry = ch.insert_parallel(cfg, t2, K, V, mask=jnp.asarray(r))
        r = np.asarray(retry)
    assert ch.items_host(cfg, t1) == ch.items_host(cfg, t2)

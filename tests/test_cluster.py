"""Cluster subsystem tests: rendezvous routing stability, replicated
fenced-write durability (with its unfenced negative control), live
migration crash consistency, heartbeat failover, and the end-to-end
`ClusterStore` / N-node sim invariants the ISSUE gates:

  * replicated commit-fenced writes lose ZERO committed ops across every
    primary-crash prefix (every scheme the matrix covers);
  * a node join remaps <= 1/N + 5% of resident keys (fixed cases here,
    a hypothesis property over random memberships when available).
"""

import numpy as np
import pytest

from repro import api
from repro.cluster import (ClusterStore, Directory, FailoverController,
                           check_replicated_durability,
                           migration_crash_sweep, replication_plan)
from repro.cluster.store import RebalanceReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.data import ycsb
from repro.rdma import verbs as rv

NAMES4 = ("pm0", "pm1", "pm2", "pm3")


def keys_of(n, base=0):
    return ycsb.make_key(np.arange(base, base + n))


# ---------------------------------------------------------------------------
# directory / router
# ---------------------------------------------------------------------------

class TestDirectory:
    def test_deterministic_and_replicas_distinct(self):
        d = Directory(NAMES4, replicas=2)
        K = keys_of(512)
        s1, s2 = d.replica_names(K), d.replica_names(K)
        assert (s1 == s2).all()
        assert (s1[:, 0] != s1[:, 1]).all()
        # primary is the top-weighted member
        assert (np.asarray(d.nodes, object)[d.primaries(K)] == s1[:, 0]).all()

    def test_membership_order_irrelevant(self):
        K = keys_of(256)
        a = Directory(("b", "a", "c")).replica_names(K)
        b = Directory(("c", "a", "b")).replica_names(K)
        assert (a == b).all()

    def test_balance_roughly_even(self):
        d = Directory(NAMES4)
        prim = d.primaries(keys_of(4000))
        counts = np.bincount(prim, minlength=4)
        assert counts.min() > 4000 / 4 * 0.7, counts

    def test_join_moves_at_most_one_nth_plus_slack(self):
        K = keys_of(4000)
        d = Directory(NAMES4, replicas=2)
        d2 = d.with_node("pm4")
        p1 = np.asarray(d.nodes, object)[d.primaries(K)]
        p2 = np.asarray(d2.nodes, object)[d2.primaries(K)]
        moved = p1 != p2
        assert moved.mean() <= 1 / len(d2.nodes) + 0.05
        # minimality: every moved key moved TO the joiner, none elsewhere
        assert (p2[moved] == "pm4").all()

    def test_leave_moves_only_the_leavers_keys(self):
        K = keys_of(4000)
        d = Directory(NAMES4, replicas=2)
        d2 = d.without_node("pm2")
        p1 = np.asarray(d.nodes, object)[d.primaries(K)]
        p2 = np.asarray(d2.nodes, object)[d2.primaries(K)]
        assert ((p1 != p2) == (p1 == "pm2")).all()

    def test_owned_mask_roles(self):
        d = Directory(NAMES4, replicas=2)
        K = keys_of(300)
        sets = d.replica_names(K)
        for n in NAMES4:
            assert (d.owned_mask(K, n, "primary") == (sets[:, 0] == n)).all()
            assert (d.owned_mask(K, n, "any")
                    == (sets == n).any(axis=1)).all()


def test_join_stability_property():
    """Property: for random memberships and replica counts, a join remaps
    <= 1/N + 5% of keys and a leave remaps only the leaver's.  Formerly
    importorskip("hypothesis"); _propcheck's seeded fallback keeps it in
    tier-1 when hypothesis is absent (no network in the container)."""
    from _propcheck import given, settings, st

    @settings(max_examples=25, deadline=None)
    @given(n_nodes=st.integers(min_value=2, max_value=12),
           replicas=st.integers(min_value=1, max_value=3),
           seed=st.integers(min_value=0, max_value=2 ** 20))
    def prop(n_nodes, replicas, seed):
        names = tuple(f"host{seed}-{i}" for i in range(n_nodes))
        d = Directory(names, replicas=min(replicas, n_nodes))
        K = ycsb.make_key(np.arange(seed, seed + 1500))
        p1 = np.asarray(d.nodes, object)[d.primaries(K)]
        d2 = d.with_node(f"joiner{seed}")
        p2 = np.asarray(d2.nodes, object)[d2.primaries(K)]
        moved = p1 != p2
        assert moved.mean() <= 1 / (n_nodes + 1) + 0.05
        assert (p2[moved] == f"joiner{seed}").all()
        d3 = d2.without_node(f"joiner{seed}")
        p3 = np.asarray(d3.nodes, object)[d3.primaries(K)]
        assert (p3 == p1).all()      # leave is the exact inverse of join

    prop()


# ---------------------------------------------------------------------------
# replicated fenced writes
# ---------------------------------------------------------------------------

def _loaded_store(scheme, slots=240, n=24):
    store = api.make_store(scheme, table_slots=slots)
    rng = np.random.RandomState(0)
    K = keys_of(n)
    table, res = store.insert(store.create(), K, ycsb.make_value(rng, n))
    return store, table, K[np.asarray(res.ok)], rng


@pytest.mark.parametrize("scheme", ["continuity", "level", "pfarm"])
@pytest.mark.parametrize("op", ["insert", "update", "delete"])
def test_fenced_replication_zero_committed_loss(scheme, op):
    """The acceptance criterion: across EVERY primary-crash prefix of the
    replica delivery, recovery of the persisted image retains every
    acked op exactly (and per-op atomicity holds throughout)."""
    store, table, live, rng = _loaded_store(scheme)
    n = min(8, live.shape[0])
    keys = keys_of(n, base=1000) if op == "insert" else live[:n]
    vals = None if op == "delete" else ycsb.make_value(rng, n)
    chk = check_replicated_durability(store, table, op, keys, vals,
                                      fenced=True)
    assert chk.acked_total > 0
    assert chk.lost_committed == 0 and not chk.violations, chk.violations[:5]


@pytest.mark.parametrize("scheme", ["continuity", "pfarm"])
def test_unfenced_replication_detected_losing_acks(scheme):
    """Negative control: ACK on NIC visibility without fences MUST be
    caught losing committed ops — proving the checker can see real loss."""
    store, table, live, rng = _loaded_store(scheme)
    chk = check_replicated_durability(store, table, "update", live[:8],
                                      ycsb.make_value(rng, 8), fenced=False)
    assert chk.lost_committed > 0


def test_wave_order_fenced_replication_lossless():
    store, table, live, rng = _loaded_store("continuity")
    chk = check_replicated_durability(store, table, "update", live[:8],
                                      ycsb.make_value(rng, 8), fenced=True,
                                      order="wave")
    assert chk.zero_loss


def test_replication_plan_shape_and_fences():
    store, table, live, rng = _loaded_store("continuity")
    h = HANDLERS["continuity"]
    st = h.init_state(store.cfg, table)
    _, trace = trace_batch(h, store.cfg, st, "update", live[:6],
                           ycsb.make_value(rng, 6))
    plan = replication_plan(trace)
    assert plan.batch == 6
    verb = np.asarray(plan.verb)
    fence = np.asarray(plan.fence)
    # every op: payload + fingerprint WRITEs then commit WRITE in the SAME
    # QP-ordered round, closed by the ONE commit fence — continuity's
    # 1-round write (the fp word rides the round for free)
    assert (verb[:, :3] == rv.WRITE).all()
    assert not fence[:, :2].any() and fence[:, 2].all()
    assert int(np.asarray(rv.round_trips(plan))) == 1

    # the logged baseline pays extra dependent rounds: each mid-op fence
    # (log commit, log free) closes a round before the next store may
    # issue — the write-side round-trip asymmetry at replication time
    pstore, ptable, plive, prng = _loaded_store("pfarm")
    ph = HANDLERS["pfarm"]
    pst = ph.init_state(pstore.cfg, ptable)
    _, ptrace = trace_batch(ph, pstore.cfg, pst, "update", plive[:6],
                            ycsb.make_value(prng, 6))
    pplan = replication_plan(ptrace)
    assert int(np.asarray(rv.round_trips(pplan))) > 1


# ---------------------------------------------------------------------------
# live migration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["continuity", "level"])
def test_migration_crash_sweep_consistent(scheme):
    store, table, live, rng = _loaded_store(scheme, n=18)
    keys, vals, mask = store._extract(table)
    kn = np.asarray(keys, np.uint32)[np.asarray(mask)][:6]
    vn = np.asarray(vals, np.uint32)[np.asarray(mask)][:6]
    sweep = migration_crash_sweep(store, table, store.create(), kn, vn)
    assert sweep.consistent, sweep.violations[:5]
    assert sweep.torn_points > 0          # torn payload splits were swept
    if scheme == "continuity":
        assert sweep.log_free             # zero migration log


def test_migration_rejects_non_resident_items():
    store, table, live, rng = _loaded_store("continuity")
    with pytest.raises(AssertionError):
        migration_crash_sweep(store, table, store.create(), live[:2],
                              ycsb.make_value(rng, 2))   # wrong values


def test_matrix_migrate_cell_passes():
    from repro.consistency import matrix
    row = matrix.run_migration_cell("continuity")
    assert row["ok"] and row["consistent"] and row["log_free"]
    assert row["crash_points"] > row["torn_points"] > 0


# ---------------------------------------------------------------------------
# ClusterStore end to end
# ---------------------------------------------------------------------------

def _cluster(scheme="continuity", nodes=3, n=180, replicas=2):
    cluster = ClusterStore(scheme, nodes=nodes, replicas=replicas,
                           node_slots=640, policy=api.ExecPolicy())
    rng = np.random.RandomState(1)
    K = keys_of(n)
    V = ycsb.make_value(rng, n)
    res = cluster.insert(K, V)
    assert np.asarray(res.ok).all()
    return cluster, K, V, rng


@pytest.mark.parametrize("scheme", ["continuity", "level"])
def test_cluster_roundtrip_any_scheme(scheme):
    cluster, K, V, rng = _cluster(scheme)
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()
    assert (np.asarray(res.values) == V).all()
    # every key is resident on exactly R nodes
    assert cluster.total_resident() == K.shape[0]
    per_node = [cluster.stats()["nodes"][n]["resident"]
                for n in cluster.node_names()]
    assert sum(per_node) == 2 * K.shape[0]


def test_cluster_update_delete_roundtrip():
    cluster, K, V, rng = _cluster()
    V2 = ycsb.make_value(rng, 40)
    res = cluster.update(K[:40], V2)
    assert np.asarray(res.ok).all()
    res = cluster.delete(K[40:60])
    assert np.asarray(res.ok).all()
    out = cluster.lookup(K[:60])
    f = np.asarray(out.found)
    assert f[:40].all() and not f[40:].any()
    assert (np.asarray(out.values)[:40] == V2).all()


def test_cluster_join_rebalance_bound_and_dual_read():
    cluster, K, V, rng = _cluster(nodes=3)
    cluster.begin_join("pmX", 640)
    # dual-read window: everything still readable BEFORE cutover
    mid = cluster.lookup(K)
    assert np.asarray(mid.found).all()
    rb = cluster.complete_join()
    assert isinstance(rb, RebalanceReport)
    assert rb.within_bound, (rb.moved_frac, rb.bound)
    assert rb.moved_primary > 0 and rb.copied >= rb.moved_primary
    post = cluster.lookup(K)
    assert np.asarray(post.found).all()
    assert (np.asarray(post.values) == V).all()
    assert cluster.total_resident() == K.shape[0]


def test_cluster_leave_graceful():
    cluster, K, V, rng = _cluster(nodes=4)
    rb = cluster.leave("pm1")
    assert "pm1" not in cluster.node_names()
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()
    assert (np.asarray(res.values) == V).all()


def test_cluster_kill_primary_failover_zero_committed_loss():
    """The end-to-end ISSUE criterion: kill a primary, promote via the
    heartbeat controller, and every committed (replica-fenced) op must
    read back exactly — with log-free (indicator-only) recovery."""
    cluster, K, V, rng = _cluster(nodes=3)
    clock = [0.0]
    ctl = FailoverController(cluster, timeout_s=2.0, clock=lambda: clock[0])
    victim = str(cluster.directory.replica_names(K[:1])[0, 0])
    cluster.kill(victim)
    # degraded reads: dead primary serves from the surviving replica
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()
    reports = []
    for step in range(4):
        clock[0] += 1.0
        ctl.beat(step)
        reports += ctl.tick()
    assert [r.dead for r in reports] == [victim]
    assert reports[0].recovery_log_free()     # indicator-based promotion
    assert victim not in cluster.node_names()
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()
    assert (np.asarray(res.values) == V).all()
    # replica count restored: every key on R nodes again
    per_node = [cluster.stats()["nodes"][n]["resident"]
                for n in cluster.node_names()]
    assert sum(per_node) == 2 * K.shape[0]


def test_cluster_failover_inside_migration_window():
    """A primary dying mid-join must not let the later cutover resurrect
    it; the joiner dying mid-join must void the migration entirely."""
    cluster, K, V, rng = _cluster(nodes=3)
    cluster.begin_join("pmX", 640)
    victim = next(n for n in cluster.node_names() if n != "pmX")
    cluster.kill(victim)
    cluster.failover(victim)
    assert cluster.migrating
    rb = cluster.complete_join()
    assert victim not in cluster.directory.nodes
    assert "pmX" in cluster.directory.nodes and rb.node == "pmX"
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()
    assert (np.asarray(res.values) == V).all()

    cluster.begin_join("pmY", 640)
    cluster.kill("pmY")
    cluster.failover("pmY")
    assert not cluster.migrating          # the join is moot
    res = cluster.lookup(K)
    assert np.asarray(res.found).all()


def test_cluster_sim_smoke_invariants():
    from repro.cluster import sim
    cell = sim.run_cluster(
        "continuity", "A", nodes=3, replicas=2, num_records=240,
        num_ops=480, batch=120, node_slots=768,
        events=(("join", 160, "pmJ"), ("kill", 320, "primary")))
    assert cell["committed_lost"] == 0
    assert cell["rebalance_within_bound"] and cell["failover_detected"]
    assert cell["ops_per_s"] > 0
    kinds = [e["event"] for e in cell["events"]]
    assert "join" in kinds and "failover" in kinds


def test_cluster_hotspot_stream():
    h = ycsb.Hotspot(1000)
    ids = h.sample(np.random.RandomState(0), 20000)
    hot = (ids < h.hot).mean()
    assert 0.7 < hot < 0.9
    assert ids.min() >= 0 and ids.max() < 1000


def test_api_exports_cluster_store():
    assert api.ClusterStore is ClusterStore

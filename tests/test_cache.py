"""Client cache subsystem tests — the ISSUE's correctness property first:
after ANY committed update/delete, a validating cached read NEVER returns
the pre-mutation value.  Driven three ways:

  * per-scheme over `StoreBackend` (every registered scheme, fixed cases
    + a seeded/hypothesis property over random mutation waves);
  * over a `ClusterStore` through partition -> stale-epoch -> heal ->
    resync chaos via `ClusterBackend`;
  * the continuity ABA regression: two back-to-back updates RESTORING a
    value must still change the stamp (the per-pair op counter in the
    8-byte word), so value-coincidence can never revalidate an entry.

Plus the policy units (TinyLFU sketch, admission, backpressure), the
keep-on-unresolved semantics, the tagged wire accounting the fan-in sim
bills from, the request-stream self-check, and a tiny end-to-end fan-in
run with the full chaos schedule.
"""

import numpy as np
import pytest

from _propcheck import given, settings, st
from repro import api
from repro.cache import (Backpressure, CacheConfig, ClientCache,
                         ClusterBackend, FrequencySketch, StoreBackend,
                         key_hash)
from repro.cache import fanin
from repro.cluster import ClusterStore
from repro.data import ycsb
from repro.rdma import verbs as rv

U32 = np.uint32


def K(ids):
    return ycsb.make_key(np.asarray(ids))


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_key_hash_deterministic_and_spread(self):
        ks = [K([i])[0].tobytes() for i in range(256)]
        hs = [key_hash(k) for k in ks]
        assert hs == [key_hash(k) for k in ks]
        assert len(set(hs)) == len(hs)

    def test_sketch_counts_and_overestimates_only(self):
        sk = FrequencySketch(width=256, depth=4, seed=1)
        h, other = key_hash(b"a" * 16), key_hash(b"b" * 16)
        for _ in range(5):
            sk.add(h)
        assert sk.estimate(h) >= 5          # count-min never undercounts
        assert sk.estimate(other) <= sk.estimate(h)

    def test_sketch_halving_decay(self):
        sk = FrequencySketch(width=64, depth=2, sample=32, seed=0)
        h = key_hash(b"hot!" * 4)
        for _ in range(20):
            sk.add(h)
        before = sk.estimate(h)
        for i in range(40):                 # push past the sample boundary
            sk.add(key_hash(i.to_bytes(8, "little")))
        assert sk.ages >= 1
        assert sk.estimate(h) <= before // 2 + 1

    def test_backpressure_unlimited(self):
        bp = Backpressure(None)
        assert bp.grant(np.array([1, 2, 3])).all()
        assert bp.shed == 0

    def test_backpressure_keeps_hottest(self):
        bp = Backpressure(2)
        g = bp.grant(np.array([5, 1, 9, 1]))
        assert g.tolist() == [True, False, True, False]
        assert bp.shed == 2 and bp.granted == 2

    def test_backpressure_stable_ties(self):
        g = Backpressure(1).grant(np.array([3, 3, 3]))
        assert g.tolist() == [True, False, False]


# ---------------------------------------------------------------------------
# ClientCache semantics against a scriptable backend
# ---------------------------------------------------------------------------

class FakeBackend:
    """Dict-served backend with switches for source flips and
    unresolved (partition-style) validations."""

    def __init__(self):
        self.data = {}                      # kb -> (value, stamp int)
        self.source = "n0"
        self.resolved = True
        self.fetches = 0

    def put(self, i, val, stamp):
        self.data[K([i])[0].tobytes()] = (np.asarray(val, U32), stamp)

    def drop(self, i):
        self.data.pop(K([i])[0].tobytes(), None)

    def _iter(self, keys):
        keys = np.asarray(keys, U32).reshape(-1, 4)
        return keys.shape[0], [k.tobytes() for k in keys]

    def validate(self, keys):
        B, kbs = self._iter(keys)
        stamps = np.full((B, 1), -1, np.int64)
        for j, kb in enumerate(kbs):
            if kb in self.data:
                stamps[j, 0] = self.data[kb][1]
        return (stamps, np.full(B, self.source, object),
                np.full(B, self.resolved, bool), np.zeros(B))

    def fetch(self, keys):
        B, kbs = self._iter(keys)
        self.fetches += B
        vals = np.zeros((B, 4), U32)
        found = np.zeros(B, bool)
        stamps = np.full((B, 1), -1, np.int64)
        for j, kb in enumerate(kbs):
            if kb in self.data:
                vals[j], stamps[j, 0] = self.data[kb]
                found[j] = True
        if not self.resolved:               # nobody answers fetches either
            found[:] = False
            stamps[:] = -1
        return (vals, found, stamps, np.full(B, self.source, object),
                np.zeros(B))


class TestClientCache:
    def _cache(self, **kw):
        be = FakeBackend()
        for i in range(8):
            be.put(i, [i, i, i, i], stamp=100 + i)
        return ClientCache(CacheConfig(**kw), be), be

    def test_miss_fill_then_validated_hit(self):
        c, be = self._cache(capacity=16)
        r = c.read_round(K([0, 1]))
        assert r.found.all() and not r.hit.any()
        r = c.read_round(K([0, 1]))
        assert r.found.all() and r.hit.all()
        assert c.stats["validations"] == 2 and c.stats["hits"] == 2

    def test_same_round_dedup_and_serve(self):
        c, be = self._cache(capacity=16)
        r = c.read_round(K([3, 3, 3, 4]))
        assert r.found.all()
        assert be.fetches == 2              # unique keys only
        assert np.array_equal(r.values[0], r.values[1])

    def test_stamp_mismatch_evicts_and_serves_new_value(self):
        c, be = self._cache(capacity=16)
        c.read_round(K([5]))
        be.put(5, [9, 9, 9, 9], stamp=999)  # committed remote mutation
        r = c.read_round(K([5]))
        assert r.found[0] and not r.hit[0]
        assert r.values[0].tolist() == [9, 9, 9, 9]
        assert c.stats["stamp_invalidations"] == 1

    def test_source_flip_evicts(self):
        c, be = self._cache(capacity=16)
        c.read_round(K([5]))
        be.source = "n1"                    # answerer changed (failover)
        r = c.read_round(K([5]))
        assert r.found[0] and not r.hit[0]
        assert c.stats["source_invalidations"] == 1

    def test_unresolved_keeps_entry_but_never_serves_it(self):
        c, be = self._cache(capacity=16)
        c.read_round(K([5]))
        be.resolved = False                 # partition: nobody can answer
        r = c.read_round(K([5]))
        assert not r.hit[0] and not r.found[0]
        assert c.stats["unresolved_validations"] == 1
        kb = K([5])[0].tobytes()
        assert kb in c.entries              # kept, unservable
        be.resolved = True                  # heal: the entry revalidates
        misses_before = c.stats["misses"]
        r = c.read_round(K([5]))
        assert r.hit[0] and r.found[0]
        assert c.stats["misses"] == misses_before

    def test_delete_never_serves_ghost(self):
        c, be = self._cache(capacity=16)
        c.read_round(K([2]))
        be.drop(2)                          # committed delete
        r = c.read_round(K([2]))
        assert not r.found[0] and not r.hit[0]

    def test_shed_is_refused_not_served(self):
        c, be = self._cache(capacity=16, budget=0)
        r = c.read_round(K([0, 1, 2]))
        assert not r.served.any() and not r.found.any()
        assert c.stats["shed"] == 3 and be.fetches == 0

    def test_tinylfu_admission_protects_hot_resident(self):
        c, be = self._cache(capacity=1)
        for _ in range(4):                  # make key 0 sketch-hot
            c.read_round(K([0]))
        c.read_round(K([7]))                # one-hit wonder
        assert K([0])[0].tobytes() in c.entries
        assert c.stats["admit_rejects"] >= 1

    def test_own_write_invalidate(self):
        c, be = self._cache(capacity=16)
        c.read_round(K([1]))
        assert c.invalidate(K([1])) == 1
        assert K([1])[0].tobytes() not in c.entries


# ---------------------------------------------------------------------------
# THE property: committed mutations are never served, every scheme
# ---------------------------------------------------------------------------

def _fill_store(scheme, n, slots, seed):
    store = api.make_store(scheme, table_slots=slots)
    table = store.create()
    rng = np.random.RandomState(seed)
    ids = np.arange(n)
    vals = ycsb.make_value(rng, n)
    table, res = store.insert(table, K(ids), vals)
    okn = np.asarray(res.ok)
    truth = {int(i): v for i, v in zip(ids[okn], vals[okn])}
    return store, table, rng, ids, truth


def _audit(r, ids, truth):
    """Every served value must be the committed one; deleted keys must
    not resurface."""
    for j, i in enumerate(np.asarray(ids)):
        if int(i) in truth:
            if r.found[j]:
                assert np.array_equal(r.values[j], truth[int(i)]), \
                    f"id {int(i)}: served a non-committed value"
        else:
            assert not r.found[j], f"id {int(i)}: served after delete"


class TestNeverStaleStore:
    @pytest.mark.parametrize("scheme", api.available_schemes())
    def test_update_delete_never_served_stale(self, scheme):
        store, table, rng, ids, truth = _fill_store(scheme, 48, 512, 0)
        backend = StoreBackend(store, table)
        cache = ClientCache(CacheConfig(capacity=64), backend)
        _audit(cache.read_round(K(ids)), ids, truth)   # warm fill
        up, dl = ids[:16], ids[16:24]
        nv = ycsb.make_value(rng, len(up))
        backend.table, ur = store.update(backend.table, K(up), nv)
        for i, v in zip(up[np.asarray(ur.ok)], nv[np.asarray(ur.ok)]):
            truth[int(i)] = v
        backend.table, dr = store.delete(backend.table, K(dl))
        for i in dl[np.asarray(dr.ok)]:
            truth.pop(int(i), None)
        r = cache.read_round(K(ids))
        _audit(r, ids, truth)
        # the mutated-and-cached keys were actually revalidated, not lucky
        assert cache.stats["stamp_invalidations"] > 0

    @pytest.mark.parametrize("scheme", api.available_schemes())
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1))
    def test_property_random_mutation_waves(self, scheme, seed):
        store, table, rng, ids, truth = _fill_store(scheme, 32, 256, seed)
        backend = StoreBackend(store, table)
        cache = ClientCache(CacheConfig(capacity=32), backend)
        mrng = np.random.RandomState(seed ^ 0x5EED)
        for _ in range(3):
            _audit(cache.read_round(K(ids)), ids, truth)
            up = ids[mrng.permutation(len(ids))[:8]]
            nv = ycsb.make_value(mrng, len(up))
            backend.table, ur = store.update(backend.table, K(up), nv)
            for i, v in zip(up[np.asarray(ur.ok)], nv[np.asarray(ur.ok)]):
                truth[int(i)] = v
            dl = ids[mrng.permutation(len(ids))[:4]]
            backend.table, dr = store.delete(backend.table, K(dl))
            for i in dl[np.asarray(dr.ok)]:
                truth.pop(int(i), None)
        _audit(cache.read_round(K(ids)), ids, truth)


class TestContinuityStamp:
    def test_aba_value_restoring_update_still_changes_stamp(self):
        store = api.make_store("continuity", table_slots=256)
        table = store.create()
        k = K([7])
        v1 = np.array([[1, 2, 3, 4]], U32)
        v2 = np.array([[5, 6, 7, 8]], U32)
        table, _ = store.insert(table, k, v1)
        s0 = np.asarray(store.version_stamp(table, k))
        table, _ = store.update(table, k, v2)
        table, _ = store.update(table, k, v1)      # value restored (ABA)
        s2 = np.asarray(store.version_stamp(table, k))
        assert not np.array_equal(s0, s2), \
            "stamp must advance even when the value round-trips"
        # and a real lookup agrees the value is back
        r = store.lookup(table, k)
        assert np.asarray(r.ok)[0]
        assert np.array_equal(np.asarray(r.values)[0], v1[0])

    def test_untouched_pair_stamp_is_stable(self):
        store = api.make_store("continuity", table_slots=512)
        table = store.create()
        ids = np.arange(16)
        vals = ycsb.make_value(np.random.RandomState(0), 16)
        table, _ = store.insert(table, K(ids), vals)
        before = np.asarray(store.version_stamp(table, K(ids)))
        table, _ = store.update(table, K([0]),
                                np.array([[9, 9, 9, 9]], U32))
        after = np.asarray(store.version_stamp(table, K(ids)))
        assert not np.array_equal(before[0], after[0])
        # the stamp is per bucket PAIR: only keys sharing key 0's pair may
        # re-stamp (their rows are identical to key 0's, before and after);
        # keys on other pairs never see a spurious invalidation
        moved = [j for j in range(1, 16)
                 if not np.array_equal(before[j], after[j])]
        for j in moved:
            assert np.array_equal(before[j], before[0]) \
                and np.array_equal(after[j], after[0]), \
                f"key {j} re-stamped but is not on key 0's pair"

    def test_validation_plan_is_single_8byte_read(self):
        store = api.make_store("continuity", table_slots=256)
        table = store.create()
        ids = np.arange(8)
        table, _ = store.insert(table, K(ids),
                                ycsb.make_value(np.random.RandomState(0), 8))
        plan = store.version_read_plan(table, K(ids))
        verb = np.asarray(plan.verb)
        active = verb == rv.READ
        assert active.sum() == 8            # exactly one READ per key
        assert (np.asarray(plan.nbytes)[active] == 8).all()
        assert (np.asarray(plan.depth)[active] == 0).all()


# ---------------------------------------------------------------------------
# cluster: never stale across partition -> stale epoch -> heal -> resync
# ---------------------------------------------------------------------------

class TestClusterNeverStale:
    def test_partition_heal_cycle(self):
        cluster = ClusterStore("continuity", nodes=3, replicas=2,
                               node_slots=2048)
        rng = np.random.RandomState(3)
        ids = np.arange(120)
        vals = ycsb.make_value(rng, 120)
        res = cluster.insert(K(ids), vals)
        okn = np.asarray(res.ok)
        truth = {int(i): v for i, v in zip(ids[okn], vals[okn])}
        backend = ClusterBackend(cluster)
        cache = ClientCache(CacheConfig(capacity=128), backend)
        _audit(cache.read_round(K(ids)), ids, truth)   # warm

        victim = str(cluster.directory.replica_names(K(ids[:1]))[0, 0])
        cluster.partition(victim)
        # stale unfenced acks through the partitioned ex-primary: these
        # must NEVER become servable
        sids = ids[:8]
        cluster.stale_write(victim, K(sids), ycsb.make_value(rng, len(sids)))
        up = ids[rng.permutation(120)[:24]]
        nv = ycsb.make_value(rng, len(up))
        ur = cluster.update(K(up), nv)
        for i, v in zip(up[np.asarray(ur.ok)], nv[np.asarray(ur.ok)]):
            truth[int(i)] = v
        _audit(cache.read_round(K(ids)), ids, truth)

        cluster.heal(victim)
        hr = cluster.resync(victim)
        assert hr.stale_acks_detected == 8
        up = ids[rng.permutation(120)[:24]]
        nv = ycsb.make_value(rng, len(up))
        ur = cluster.update(K(up), nv)
        for i, v in zip(up[np.asarray(ur.ok)], nv[np.asarray(ur.ok)]):
            truth[int(i)] = v
        _audit(cache.read_round(K(ids)), ids, truth)
        assert cache.stats["stamp_invalidations"] > 0

    def test_backend_tags_wire_traffic(self):
        cluster = ClusterStore("continuity", nodes=3, replicas=2,
                               node_slots=1024)
        ids = np.arange(40)
        cluster.insert(K(ids), ycsb.make_value(np.random.RandomState(0), 40))
        backend = ClusterBackend(cluster)
        cache = ClientCache(CacheConfig(capacity=64), backend)
        cache.read_round(K(ids))            # fills
        cache.read_round(K(ids))            # validations
        tags = {}
        for st_ in cluster.stats()["nodes"].values():
            for tag, row in st_.get("wire", {}).get("by_tag", {}).items():
                agg = tags.setdefault(tag, {"verbs": 0, "bytes": 0})
                agg["verbs"] += row["verbs"]
                agg["bytes"] += row["bytes"]
        assert tags["fill"]["verbs"] > 0
        # every validate verb is the 8-byte indicator word, nothing more
        assert tags["validate"]["verbs"] == 40
        assert tags["validate"]["bytes"] == 8 * 40


# ---------------------------------------------------------------------------
# request-stream self-check + the tiny end-to-end fan-in cell
# ---------------------------------------------------------------------------

class TestFanIn:
    @pytest.mark.parametrize("dist", ["zipf", "hotspot"])
    def test_request_stream_self_check(self, dist):
        s = ycsb.request_stream(dist, 500, theta=0.99, hot_frac=0.05,
                                hot_op_frac=0.9)
        chk = ycsb.stream_self_check(s, np.random.RandomState(1))
        assert chk["ok"], chk

    def test_tiny_fanin_full_chaos_schedule(self):
        events = [(2, "partition", "primary"), (2, "stale", ""),
                  (3, "heal", ""), (4, "resync", ""),
                  (5, "kill", "primary"), (6, "failover", "")]
        res = fanin.run_fanin("continuity", clients=6, rounds=7,
                              ops_per_round=6, writes_per_round=1,
                              num_records=300, nodes=3, replicas=2,
                              budget=None, events=events)
        ca, un = res["cached"], res["uncached"]
        assert ca["stale_served"] == 0
        assert ca["wrong_reads"] == 0 and un["wrong_reads"] == 0
        assert res["stream_check"]["ok"]
        assert res["doorbell_reduction"] > 1.0
        fired = {e["event"] for e in ca["events"]}
        assert {"partition", "stale", "heal", "resync",
                "kill", "failover"} <= fired

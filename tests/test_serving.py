"""Serving engine: paged/ssm/hybrid decode == training forward; page-table
lifecycle; prefix sharing; int8 KV quantization."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.config import ShapeConfig
from repro.serving import engine as E
from repro.serving import kvcache as KC

KEY = jax.random.PRNGKey(0)


def run_decode(cfg, geom, params, cache, toks):
    step = jax.jit(lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
    lg = None
    for t in range(toks.shape[1]):
        lg, cache = step(params, toks[:, t], cache)
    return lg, cache


def forward_last_logits(cfg, params, toks):
    x, _ = T.forward(cfg, params, toks)
    return T.logits_fn(cfg, params, x)[:, -1]


class TestPagedDecode:
    def setup_method(self, _):
        self.cfg = smoke_config("yi-6b")
        self.params = T.init_params(self.cfg, KEY)
        self.shape = ShapeConfig("t", seq_len=128, global_batch=4,
                                 kind="decode")

    def test_decode_matches_forward(self):
        geom = KC.make_geometry(self.cfg, self.shape, shards=2, page_size=16)
        cache = KC.create_cache(geom)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 40), 0,
                                  self.cfg.vocab)
        lg, cache = run_decode(self.cfg, geom, self.params, cache, toks)
        ref = forward_last_logits(self.cfg, self.params, toks)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   atol=3e-3, rtol=1e-3)
        # pages opened: ceil(40/16)=3 per sequence
        assert int(cache.table.count.sum()) == 4 * 3

    def test_prefill_then_decode(self):
        geom = KC.make_geometry(self.cfg, self.shape, shards=2, page_size=16)
        cache = KC.create_cache(geom)
        toks = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                  self.cfg.vocab)
        lg, cache = E.prefill(self.cfg, geom, self.params, toks, cache)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(forward_last_logits(
                self.cfg, self.params, toks)), atol=3e-3, rtol=1e-3)
        nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, cache = jax.jit(lambda p, t, c: E.serve_step(
            self.cfg, geom, p, t, c))(self.params, nxt, cache)
        full = jnp.concatenate([toks, nxt[:, None]], 1)
        np.testing.assert_allclose(
            np.asarray(lg2), np.asarray(forward_last_logits(
                self.cfg, self.params, full)), atol=3e-3, rtol=1e-3)

    def test_int8_kv_quantization_close(self):
        geom = KC.make_geometry(self.cfg, self.shape, shards=2, page_size=16,
                                kv_dtype="int8")
        cache = KC.create_cache(geom)
        assert cache.kscale is not None
        toks = jax.random.randint(jax.random.PRNGKey(3), (4, 24), 0,
                                  self.cfg.vocab)
        lg, _ = run_decode(self.cfg, geom, self.params, cache, toks)
        ref = forward_last_logits(self.cfg, self.params, toks)
        # int8 KV: small degradation allowed, ranking should agree
        match = (np.argmax(np.asarray(lg), -1)
                 == np.argmax(np.asarray(ref), -1)).mean()
        assert match >= 0.75, match

    def test_release_sequence_recycles(self):
        geom = KC.make_geometry(self.cfg, self.shape, shards=2, page_size=16)
        cache = KC.create_cache(geom)
        toks = jax.random.randint(jax.random.PRNGKey(4), (4, 20), 0,
                                  self.cfg.vocab)
        _, cache = run_decode(self.cfg, geom, self.params, cache, toks)
        n0 = int(cache.table.count.sum())
        cache = E.release_sequence(geom, cache, shard_idx=0, slot=0)
        assert int(cache.table.count.sum()) < n0
        assert int(cache.seq_lens[0, 0]) == 0
        # released seq id replaced with a fresh (never-used) one
        assert int(cache.seq_ids[0, 0]) >= 4


class TestOversubscription:
    def test_pool_smaller_than_logical(self):
        """The hash index keeps working when the physical pool is half the
        worst-case logical page space (sequences stay short)."""
        cfg = smoke_config("yi-6b")
        params = T.init_params(cfg, KEY)
        shape = ShapeConfig("t", seq_len=128, global_batch=4, kind="decode")
        geom = KC.make_geometry(cfg, shape, shards=2, page_size=16,
                                oversub=0.5)
        assert geom.pool_pages == 8          # vs 16 worst-case
        cache = KC.create_cache(geom)
        toks = jax.random.randint(jax.random.PRNGKey(5), (4, 40), 0,
                                  cfg.vocab)
        lg, cache = run_decode(cfg, geom, params, cache, toks)
        ref = forward_last_logits(cfg, params, toks)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   atol=3e-3, rtol=1e-3)


class TestRecurrentDecode:
    @pytest.mark.parametrize("arch,steps", [("mamba2-370m", 40),
                                            ("hymba-1.5b", 100)])
    def test_decode_matches_forward(self, arch, steps):
        cfg = smoke_config(arch)
        params = T.init_params(cfg, KEY)
        cache = KC.create_state_cache(cfg, 2, 256, dtype=jnp.float32)
        step = jax.jit(lambda p, t, c: E.serve_step(cfg, None, p, t, c))
        toks = jax.random.randint(jax.random.PRNGKey(6), (2, steps), 0,
                                  cfg.vocab)
        lg = None
        for t in range(steps):
            lg, cache = step(params, toks[:, t], cache)
        ref = forward_last_logits(cfg, params, toks)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   atol=3e-3, rtol=1e-3)


class TestPrefixSharing:
    def test_content_keys_dedupe(self):
        from repro.serving.engine import content_page_keys
        toks = np.random.RandomState(0).randint(0, 99, size=(6, 64)
                                                ).astype(np.int32)
        toks[3:] = toks[:3]
        keys = np.asarray(content_page_keys(jnp.asarray(toks), 16))
        np.testing.assert_array_equal(keys[:3], keys[3:])
        # rolling hash: diverge after the first differing page
        toks2 = toks.copy()
        toks2[0, 20] += 1                     # page 1 differs for seq 0
        keys2 = np.asarray(content_page_keys(jnp.asarray(toks2), 16))
        np.testing.assert_array_equal(keys2[0, 0], keys[0, 0])
        assert (keys2[0, 1] != keys[0, 1]).any()
        assert (keys2[0, 2] != keys[0, 2]).any()   # chained

"""The fused mutation engine (ISSUE 9): update/delete as single-pass
rank-indexed commits, the Pallas mutation-plan kernel, the `ExecPolicy`
mutate/use_fp knobs, and the resize-step SLO controller.

The load-bearing contract: `ch.update`/`ch.delete` (every match backend)
stay BYTE-identical to the `update_serial`/`delete_serial` oracles on
every table field, across batch sizes, stash on/off, duplicate keys, and
masked batches — that is what lets the bench's `wave >= serial on every
op x batch cell` band replace the serial path without a semantic rider.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import ExecPolicy
from repro.core import continuity as ch
from repro.data import ycsb
from repro.kernels import ops as K


def keys_vals(ids, seed=0):
    rng = np.random.RandomState(seed)
    ids = np.asarray(ids)
    return (jnp.asarray(ycsb.make_key(ids)),
            jnp.asarray(ycsb.make_value(rng, len(ids))))


def table_diff(a, b):
    for f in a._fields:
        if not bool((getattr(a, f) == getattr(b, f)).all()):
            return f
    return None


def _cfg(num_buckets=1024, stash=True):
    return ch.ContinuityConfig(num_buckets=num_buckets,
                               stash_frac=(1 / 8 if stash else 0.0))


def _mutation_ids(batch, rng):
    """Mixed workload: live keys, absent keys, duplicates."""
    ids = np.arange(batch)
    ids[batch - batch // 8:] = rng.randint(0, batch // 2,
                                           size=batch // 8)  # duplicates
    return ids


# ---------------------------------------------------------------------------
# byte-identity sweep: {64, 512, 4096} x {stash on/off} x {update, delete}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stash", [True, False], ids=["stash", "nostash"])
@pytest.mark.parametrize("batch", [64, 512, 4096])
@pytest.mark.parametrize("op", ["update", "delete"])
def test_fused_matches_serial_sweep(op, batch, stash):
    cfg = _cfg(num_buckets=max(32, batch // 4), stash=stash)
    rng = np.random.RandomState(batch + stash)
    kb, vb = keys_vals(np.arange(3 * batch // 4))   # live prefix
    table = ch.create(cfg)
    table, okb, _ = ch.insert(cfg, table, kb, vb)
    assert bool(okb.all())

    ids = _mutation_ids(batch, rng)                 # live + absent + dups
    keys, vals = keys_vals(ids, seed=1)
    mask = jnp.asarray(rng.random_sample(batch) > 0.1)
    if op == "update":
        ts, oks, cs = ch.update_serial(cfg, table, keys, vals, mask)
        tf, okf, cf = ch.update(cfg, table, keys, vals, mask)
    else:
        ts, oks, cs = ch.delete_serial(cfg, table, keys, mask)
        tf, okf, cf = ch.delete(cfg, table, keys, mask)
    assert table_diff(ts, tf) is None
    assert bool((oks == okf).all())
    assert int(cs.pm_writes) == int(cf.pm_writes)
    assert int(oks.sum()) > 0


# ---------------------------------------------------------------------------
# kernel backends: plan identity + fused identity through every backend
# ---------------------------------------------------------------------------

def _loaded(n=200, stash=True):
    cfg = _cfg(num_buckets=64, stash=stash)
    keys, vals = keys_vals(np.arange(n))
    table = ch.create(cfg)
    table, ok, _ = ch.insert(cfg, table, keys, vals)
    return cfg, table, keys, vals, ok


def test_mutation_plan_kernel_matches_ref():
    cfg, table, keys, _, _ = _loaded()
    nkeys, _ = keys_vals(np.arange(500, 560))       # negatives too
    for qs in (keys, nkeys):
        mk, vk, fk = K.mutation_plan(cfg, table, qs, use_kernel=True)
        mr, vr, fr = K.mutation_plan(cfg, table, qs, use_kernel=False)
        assert bool((mk == mr).all())
        assert bool((vk == vr).all())
        assert bool((fk == fr).all())


def test_mutation_plan_matches_probe_and_lookup():
    """The plan's match side agrees with the probe kernel and the full
    lookup on main-segment hits; flip is exactly old-bit | victim-bit."""
    cfg, table, keys, _, _ = _loaded()
    m, v, f = K.mutation_plan(cfg, table, keys, use_kernel=False)
    pm, pe, _, _ = K.probe_table(cfg, table, keys, use_kernel=False,
                                 use_fp=True)
    assert bool((m == pm).all())
    assert bool((v == pe).all())
    exp = (jnp.where(m >= 0, jnp.uint32(1) << jnp.maximum(m, 0).astype(
        jnp.uint32), jnp.uint32(0))
        | jnp.where(v >= 0, jnp.uint32(1) << jnp.maximum(v, 0).astype(
            jnp.uint32), jnp.uint32(0)))
    assert bool((f == exp).all())


@pytest.mark.parametrize("probe", ["pallas", "reference"])
@pytest.mark.parametrize("op", ["update", "delete"])
def test_fused_kernel_backends_match_serial(op, probe):
    cfg, table, keys, vals, _ = _loaded()
    rng = np.random.RandomState(3)
    ids = _mutation_ids(160, rng)
    keys, vals = keys_vals(ids, seed=2)
    if op == "update":
        ts, oks, _ = ch.update_serial(cfg, table, keys, vals)
        tf, okf, _ = ch.update(cfg, table, keys, vals, probe=probe)
    else:
        ts, oks, _ = ch.delete_serial(cfg, table, keys)
        tf, okf, _ = ch.delete(cfg, table, keys, probe=probe)
    assert table_diff(ts, tf) is None
    assert bool((oks == okf).all())


def test_fused_with_stash_hits_matches_serial():
    """Overflow a tiny table so mutations actually hit stash entries
    (delete-from-stash and update's stash->main relocation)."""
    cfg = _cfg(num_buckets=4, stash=True)
    keys, vals = keys_vals(np.arange(90))
    table = ch.create(cfg)
    table, ok, _ = ch.insert(cfg, table, keys, vals)
    assert int(ch.stash_count(table, jnp.arange(cfg.num_pairs)).sum()) > 0
    _, vals2 = keys_vals(np.arange(90), seed=9)
    ts, oks, _ = ch.update_serial(cfg, table, keys, vals2)
    tf, okf, _ = ch.update(cfg, table, keys, vals2)
    assert table_diff(ts, tf) is None and bool((oks == okf).all())
    ts, oks, _ = ch.delete_serial(cfg, table, keys)
    tf, okf, _ = ch.delete(cfg, table, keys)
    assert table_diff(ts, tf) is None and bool((oks == okf).all())


# ---------------------------------------------------------------------------
# residual trip bound: ranks only count ACTIVE (unsafe) ops, so one hot
# pair no longer serializes every cohort (satellite: trip-count pessimism)
# ---------------------------------------------------------------------------

def test_residual_waves_bounded_by_contended_cohort():
    cfg = _cfg(num_buckets=32)
    ids = np.concatenate([np.zeros(5, np.int64), np.arange(1, 40)])
    keys, _ = keys_vals(ids)
    dup_only = jnp.asarray(np.concatenate(
        [np.ones(5, bool), np.zeros(39, bool)]))
    _, _, rank, num_waves = ch._plan_waves(cfg, keys, dup_only)
    assert int(num_waves) == 5                     # the dup cohort alone
    _, _, _, all_waves = ch._plan_waves(
        cfg, keys, jnp.ones(len(ids), bool))
    assert int(all_waves) >= int(num_waves)


# ---------------------------------------------------------------------------
# ExecPolicy: mutate/use_fp knobs through the store API
# ---------------------------------------------------------------------------

def test_policy_defaults_fp_on_and_validates():
    p = ExecPolicy()
    assert p.use_fp is True
    assert p.mutate == "gather"
    with pytest.raises(AssertionError):
        ExecPolicy(mutate="bogus")


@pytest.mark.parametrize("mutate", ["gather", "pallas", "reference"])
def test_store_mutate_backends_identical(mutate):
    serial = api.make_store("continuity", table_slots=512,
                            policy=ExecPolicy(engine="serial"))
    store = api.make_store("continuity", table_slots=512,
                           policy=ExecPolicy(mutate=mutate))
    keys, vals = keys_vals(np.arange(120))
    t0 = store.create()
    t0, _ = store.insert(t0, keys, vals)
    _, vals2 = keys_vals(np.arange(120), seed=5)
    tu_s, ru_s = serial.update(t0, keys, vals2)
    tu_w, ru_w = store.update(t0, keys, vals2)
    assert table_diff(tu_s, tu_w) is None
    assert bool((ru_s.ok == ru_w.ok).all())
    td_s, rd_s = serial.delete(t0, keys)
    td_w, rd_w = store.delete(t0, keys)
    assert table_diff(td_s, td_w) is None
    assert bool((rd_s.ok == rd_w.ok).all())


@pytest.mark.parametrize("probe", ["pallas", "reference"])
def test_fp_on_off_probe_identity(probe):
    """use_fp is a pure compare-reduction: lookups are result-identical
    with the filter on and off, for hits and misses."""
    on = api.make_store("continuity", table_slots=512,
                        policy=ExecPolicy(probe=probe, use_fp=True))
    off = dataclasses.replace(
        on, policy=ExecPolicy(probe=probe, use_fp=False))
    keys, vals = keys_vals(np.arange(150))
    t = on.create()
    t, _ = on.insert(t, keys, vals)
    miss, _ = keys_vals(np.arange(900, 980))
    for qs in (keys, miss):
        a = on.lookup(t, qs)
        b = off.lookup(t, qs)
        assert bool((a.ok == b.ok).all())
        assert bool((a.values == b.values).all())
        assert bool((a.reads == b.reads).all())


def test_fp_filter_reduces_negative_compares():
    cfg, table, keys, _, _ = _loaded()
    miss, _ = keys_vals(np.arange(2000, 2400))
    s = K.fp_filter_stats(cfg, table, miss)
    assert s["compares_with_fp"] < s["compares_no_fp"]
    assert 0.0 < s["reduction"] <= 1.0
    # 2-bit fields pass ~1/4 of occupied slots on true negatives
    assert s["reduction"] > 0.5


# ---------------------------------------------------------------------------
# crash consistency: fused update/delete through the wave-order matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["update", "delete"])
def test_fused_ops_pass_crash_matrix(op):
    from repro.consistency import matrix
    r = matrix.run_cell("continuity", op)
    assert r.consistent, r.violations[:5]
    assert r.log_used_points == 0


# ---------------------------------------------------------------------------
# resize-step SLO controller
# ---------------------------------------------------------------------------

def test_cohort_move_cost_model():
    from repro.rdma.transport import LinkModel
    lm = LinkModel()
    c = lm.cohort_move_us(320.0, 336.0)
    assert c > lm.rtt_us
    assert lm.cohort_move_us(640.0, 672.0) > c


def test_begin_resize_slo_budget():
    store = api.make_store("continuity", table_slots=512)
    keys, vals = keys_vals(np.arange(200))
    t = store.create()
    t, _ = store.insert(t, keys, vals)
    tight = store.begin_resize(t, step_slo_us=1.0)
    loose = store.begin_resize(t, step_slo_us=500.0)
    assert tight.step_budget == 1                 # floor: always progresses
    assert loose.step_budget > tight.step_budget
    none = store.begin_resize(t)
    assert none.step_budget is None

    # budget=None consumes the controller's choice; the split completes
    # and cuts over exactly as the fixed-budget path does
    rs, steps = loose, 0
    while not rs.done and steps < 10_000:
        rs = store.resize_step(rs)
        steps += 1
    assert rs.done
    new_store, new_table = store.resize_cutover(rs)
    assert int(new_table.count) == 200
    res = new_store.lookup(new_table, keys)
    assert bool(res.ok.all())


def test_cluster_maintenance_slo_mode():
    from repro.cluster import ClusterStore
    cs = ClusterStore("continuity", nodes=2, replicas=1, node_slots=256,
                      policy=api.ExecPolicy())
    keys, vals = keys_vals(np.arange(360))
    res = cs.insert(keys, vals)
    assert bool(np.asarray(res.ok).all())
    moved_any = False
    for _ in range(600):
        acts = cs.maintenance_step(budget=None, trigger_lf=0.6,
                                   step_slo_us=200.0)
        moved_any = moved_any or any(a["action"] in ("step", "cutover")
                                     for a in acts)
        if not acts and moved_any:
            break
    assert moved_any
    assert cs.maintenance["cohorts_moved"] > 0
    res = cs.lookup(keys)
    assert bool(np.asarray(res.found).all())

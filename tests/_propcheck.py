"""Property-test harness: hypothesis when available, seeded fallback else.

The repro container has no network access, so `hypothesis` (a dev-only
dependency) may be absent.  Property tests used to importorskip it; that
silently dropped the strongest invariant checks from tier-1.  This shim
keeps them running everywhere: with hypothesis installed you get real
shrinking search, without it the same `@settings/@given` decorators run
a fixed number of seeded-random examples (deterministic across runs, so
failures replay bit-exactly).

Usage (drop-in for the hypothesis triple):

    from _propcheck import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

import inspect

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function wrapped with the one method the shim needs."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(
            lambda rng: int(rng.randint(min_value, max_value + 1)))

    def _sampled_from(seq):
        pool = list(seq)
        return _Strategy(lambda rng: pool[int(rng.randint(len(pool)))])

    def _tuples(*strats):
        return _Strategy(
            lambda rng: tuple(s.example(rng) for s in strats))

    def _lists(strat, min_size=0, max_size=None, unique=False):
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            n = int(rng.randint(min_size, hi + 1))
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 20 * n + 100:
                tries += 1
                v = strat.example(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

        return _Strategy(draw)

    class _St:
        integers = staticmethod(_integers)
        sampled_from = staticmethod(_sampled_from)
        tuples = staticmethod(_tuples)
        lists = staticmethod(_lists)

    st = _St()

    def settings(max_examples=25, deadline=None, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    def given(*strats, **kwstrats):
        def deco(f):
            params = list(inspect.signature(f).parameters.values())
            if kwstrats:
                passthrough = [p for p in params if p.name not in kwstrats]
                strat_names = ()
            else:
                cut = len(params) - len(strats)
                passthrough = params[:cut]
                strat_names = tuple(p.name for p in params[cut:])

            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 25)
                for ex in range(n):
                    rng = np.random.RandomState(1_000_003 * ex + 17)
                    drawn = {k: s.example(rng) for k, s in kwstrats.items()}
                    drawn.update(
                        (name, s.example(rng))
                        for name, s in zip(strat_names, strats))
                    bound = dict(zip((p.name for p in passthrough), args))
                    f(**bound, **kwargs, **drawn)

            # pytest must see only the non-strategy params (fixtures /
            # parametrize ids), exactly like hypothesis' own wrapper.
            wrapper.__signature__ = inspect.Signature(passthrough)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco

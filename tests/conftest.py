"""Suite-level hygiene for the CPU-only container.

The full tier-1 run compiles several hundred distinct XLA CPU
executables (every scheme x op x batch-shape combination across ~20
modules). jaxlib 0.4.37's CPU backend can segfault inside
``backend_compile`` once that much JIT state has accumulated in one
process — deterministic at suite scale, unreproducible for any module
in isolation. Dropping the executable caches at module boundaries
bounds the accumulation; each module recompiles its own shapes, which
it overwhelmingly does anyway.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_accumulation():
    yield
    jax.clear_caches()

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import paged_attention
from repro.kernels.paged_attn_ref import paged_attention_ref
from repro.kernels.probe import probe_segments
from repro.kernels.probe_ref import probe_ref

BIG = 0x7FFFFFFF


def make_probe_case(rng, P, S, KL, B, planted_frac=0.5):
    rows = rng.randint(0, 2 ** 31, size=(P, S * KL)).astype(np.uint32)
    ind = rng.randint(0, 2 ** S if S < 31 else 2 ** 31,
                      size=(P, 1)).astype(np.uint32)
    seg = (S * 4) // 5
    prio = np.full((2, S), BIG, np.int32)
    prio[0, :seg] = np.arange(seg)
    odd = list(range(S - 1, S - 1 - seg, -1))
    prio[1, odd] = np.arange(seg)
    pairs = rng.randint(0, P, size=(B,)).astype(np.int32)
    parity = rng.randint(0, 2, size=(B,)).astype(np.int32)
    qkeys = rng.randint(0, 2 ** 31, size=(B, KL)).astype(np.uint32)
    for i in range(0, B, max(int(1 / max(planted_frac, 1e-9)), 1)):
        s = rng.randint(0, S)
        qkeys[i] = rows[pairs[i], s * KL:(s + 1) * KL]
    return rows, ind, prio, pairs, parity, qkeys


@pytest.mark.parametrize("P,S,B", [(8, 20, 16), (32, 20, 64), (16, 10, 33),
                                   (64, 30, 128), (4, 20, 7)])
def test_probe_kernel_matches_oracle(P, S, B):
    rng = np.random.RandomState(P * 1000 + B)
    args = [jnp.asarray(a) for a in make_probe_case(rng, P, S, 4, B)]
    m1, e1 = probe_segments(*args)
    m2, e2 = probe_ref(*args)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_probe_kernel_full_and_empty_tables():
    rng = np.random.RandomState(0)
    rows, ind, prio, pairs, parity, qkeys = make_probe_case(rng, 8, 20, 4, 32)
    for fill in (0, 0xFFFFF):   # empty / all-20-main-bits-set
        indc = np.full_like(ind, fill)
        m1, e1 = probe_segments(*[jnp.asarray(a) for a in
                                  (rows, indc, prio, pairs, parity, qkeys)])
        m2, e2 = probe_ref(*[jnp.asarray(a) for a in
                             (rows, indc, prio, pairs, parity, qkeys)])
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 6e-2)])
@pytest.mark.parametrize("B,H,KVH,D,PS,MAXP", [
    (2, 4, 1, 16, 8, 3),
    (3, 8, 2, 32, 16, 4),
    (1, 16, 4, 64, 32, 2),
    (4, 4, 4, 16, 8, 5),       # MHA (G=1, padded to 8 by ops wrapper)
])
def test_paged_attention_matches_oracle(dtype, tol, B, H, KVH, D, PS, MAXP):
    rng = np.random.RandomState(B * 100 + H)
    NP = B * MAXP + 2
    q = (rng.randn(B, H, D) * 0.5).astype(np.float32)
    kp = (rng.randn(NP, KVH, PS, D) * 0.3).astype(np.float32)
    vp = rng.randn(NP, KVH, PS, D).astype(np.float32)
    pt = np.full((B, MAXP), -1, np.int32)
    lens = rng.randint(1, MAXP * PS, size=(B,)).astype(np.int32)
    perm = rng.permutation(NP)
    c = 0
    for b in range(B):
        for p in range(int(np.ceil(lens[b] / PS))):
            pt[b, p] = perm[c]
            c += 1
    args = (jnp.asarray(q, dtype), jnp.asarray(kp, dtype),
            jnp.asarray(vp, dtype), jnp.asarray(pt), jnp.asarray(lens))
    from repro.kernels.ops import paged_attention as pa_padded
    o1 = pa_padded(*args)
    o2 = paged_attention_ref(*args)
    err = np.max(np.abs(np.asarray(o1, np.float32)
                        - np.asarray(o2, np.float32)))
    assert err < tol, err


def test_paged_attention_ignores_dead_pages():
    """Garbage in unmapped pool pages must not leak into the output."""
    rng = np.random.RandomState(7)
    B, H, KVH, D, PS, MAXP, NP = 2, 4, 2, 16, 8, 4, 16
    q = rng.randn(B, H, D).astype(np.float32)
    kp = rng.randn(NP, KVH, PS, D).astype(np.float32)
    vp = rng.randn(NP, KVH, PS, D).astype(np.float32)
    pt = np.full((B, MAXP), -1, np.int32)
    pt[:, 0] = [0, 1]
    lens = np.array([5, 3], np.int32)
    from repro.kernels.ops import paged_attention as pa
    base = np.asarray(pa(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                         jnp.asarray(pt), jnp.asarray(lens)))
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[2:] = 1e3
    vp2[2:] = -1e3                      # poison every unmapped page
    out = np.asarray(pa(jnp.asarray(q), jnp.asarray(kp2), jnp.asarray(vp2),
                        jnp.asarray(pt), jnp.asarray(lens)))
    np.testing.assert_allclose(out, base, rtol=1e-6)


def test_probe_table_consistent_with_lookup():
    import repro.core.continuity as ch
    from repro.data import ycsb
    from repro.kernels import probe_table
    cfg = ch.ContinuityConfig(num_buckets=64)
    t = ch.create(cfg)
    K = ycsb.make_key(np.arange(120))
    V = ycsb.make_value(np.random.RandomState(3), 120)
    t, ok, _ = ch.insert(cfg, t, K, V)
    match, empty, pair, parity = probe_table(cfg, t, K)
    res = ch.lookup(cfg, t, K)
    slot = np.asarray(res.slot)
    main = (slot >= 0) & (slot < cfg.slots_per_pair)
    np.testing.assert_array_equal(np.asarray(match)[main], slot[main])

"""Wave-vectorized mutation engine vs the serial lax.scan reference.

The engine must produce BYTE-IDENTICAL tables (every array of the pytree),
identical per-op success flags and identical PM-write counters for any
batch, including the adversarial shapes: all ops on one pair, all pairs
distinct, extension-allocating overflows, duplicate keys, mixed-parity
contention on tiny tables, and masked batches.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.continuity as ch
from repro.data import ycsb


def table_diff(a: ch.ContinuityTable, b: ch.ContinuityTable):
    return [name for name, x, y in zip(a._fields, a, b)
            if not np.array_equal(np.asarray(x), np.asarray(y))] or None


def keys_vals(ids, seed=0):
    rng = np.random.RandomState(seed)
    ids = np.asarray(ids)
    return ycsb.make_key(ids), ycsb.make_value(rng, len(ids))


def same_pair_ids(cfg, pair_want, parity_want=None, n=32, search=20000):
    """Record ids whose home lands on one specific pair (optionally parity)."""
    ids = []
    for i in range(search):
        k = ycsb.make_key(np.array([i]))
        pair, parity = ch.locate(cfg, jnp.asarray(k))
        if int(pair[0]) == pair_want and (
                parity_want is None or int(parity[0]) == parity_want):
            ids.append(i)
            if len(ids) == n:
                break
    return np.asarray(ids)


def assert_equivalent(cfg, K, V, mask=None):
    t_ref = ch.create(cfg)
    if mask is None:
        t_ref, ok_ref, c_ref = ch.insert_serial(cfg, t_ref, K, V)
    else:
        t_ref, ok_m, c_ref = ch.insert_serial(cfg, t_ref, K[mask], V[mask])
        ok_ref = np.zeros(len(K), bool)
        ok_ref[mask] = np.asarray(ok_m)
    t_wave, ok_wave, c_wave = ch.insert(
        cfg, ch.create(cfg), K, V,
        None if mask is None else jnp.asarray(mask))
    assert table_diff(t_ref, t_wave) is None
    np.testing.assert_array_equal(np.asarray(ok_ref), np.asarray(ok_wave))
    assert int(c_ref.pm_writes) == int(c_wave.pm_writes)
    return t_wave


def test_insert_all_distinct_pairs():
    cfg = ch.ContinuityConfig(num_buckets=256)
    K, V = keys_vals(np.arange(64))
    assert_equivalent(cfg, K, V)


def test_insert_all_same_pair_single_parity():
    cfg = ch.ContinuityConfig(num_buckets=16, ext_frac=0.5)
    ids = same_pair_ids(cfg, pair_want=3, parity_want=0, n=24)
    K, V = keys_vals(ids)
    t = assert_equivalent(cfg, K, V)
    assert int(t.count) > 0


def test_insert_all_same_pair_mixed_parity_contention():
    """Both parities fighting over one pair's SBuckets must fall back to the
    exact wave loop — still byte-identical to serial."""
    cfg = ch.ContinuityConfig(num_buckets=4, ext_frac=0.5)
    even = same_pair_ids(cfg, pair_want=1, parity_want=0, n=16)
    odd = same_pair_ids(cfg, pair_want=1, parity_want=1, n=16)
    inter = np.empty(32, dtype=even.dtype)
    inter[0::2], inter[1::2] = even, odd      # adversarial interleaving
    K, V = keys_vals(inter)
    assert_equivalent(cfg, K, V)


def test_insert_extension_allocating():
    """Overflowing batches must grant added-SBucket groups in the same pool
    order as the serial reference (ext_keys/ext_vals byte-identical)."""
    cfg = ch.ContinuityConfig(num_buckets=8, ext_frac=1.0)
    K, V = keys_vals(np.arange(180))
    t = assert_equivalent(cfg, K, V)
    assert int(t.ext_count) >= 1


def test_insert_duplicate_keys():
    cfg = ch.ContinuityConfig(num_buckets=64)
    ids = np.repeat(np.arange(16), 4)
    K, V = keys_vals(ids)
    assert_equivalent(cfg, K, V)


def test_insert_masked_batch():
    cfg = ch.ContinuityConfig(num_buckets=64)
    K, V = keys_vals(np.arange(80))
    mask = np.random.RandomState(3).rand(80) < 0.5
    assert_equivalent(cfg, K, V, mask=mask)


def test_insert_fuzz_matches_serial():
    for seed in range(12):
        rng = np.random.RandomState(seed)
        cfg = ch.ContinuityConfig(
            num_buckets=int(rng.choice([2, 4, 8, 32, 64])),
            ext_frac=float(rng.choice([0.0, 0.1, 0.5, 1.0])))
        n = int(rng.randint(1, 150))
        K = ycsb.make_key(rng.randint(0, 80, n))
        V = ycsb.make_value(rng, n)
        assert_equivalent(cfg, K, V)


def test_update_matches_serial_with_duplicates():
    cfg = ch.ContinuityConfig(num_buckets=32)
    K, V = keys_vals(np.arange(40))
    t0, _, _ = ch.insert(cfg, ch.create(cfg), K, V)
    # duplicate update targets force multi-wave execution
    ids = np.concatenate([np.arange(40), np.arange(10), np.arange(5)])
    KU, VU = keys_vals(ids, seed=9)
    t_ref, ok_r, c_r = ch.update_serial(cfg, t0, KU, VU)
    t_wav, ok_w, c_w = ch.update(cfg, t0, KU, VU)
    assert table_diff(t_ref, t_wav) is None
    np.testing.assert_array_equal(np.asarray(ok_r), np.asarray(ok_w))
    assert int(c_r.pm_writes) == int(c_w.pm_writes) == 2 * int(ok_r.sum())


def test_delete_matches_serial_with_duplicate_stored_keys():
    """A key stored twice (two duplicate inserts) then deleted twice in ONE
    batch: the second delete must clear the second slot, as in serial."""
    cfg = ch.ContinuityConfig(num_buckets=32)
    ids = np.concatenate([np.arange(20), np.arange(6)])  # 6 keys stored twice
    K, V = keys_vals(ids)
    t0, _, _ = ch.insert(cfg, ch.create(cfg), K, V)
    KD = ycsb.make_key(np.concatenate([np.arange(6), np.arange(12)]))
    t_ref, ok_r, c_r = ch.delete_serial(cfg, t0, KD)
    t_wav, ok_w, c_w = ch.delete(cfg, t0, KD)
    assert table_diff(t_ref, t_wav) is None
    np.testing.assert_array_equal(np.asarray(ok_r), np.asarray(ok_w))
    assert int(c_r.pm_writes) == int(c_w.pm_writes) == int(ok_r.sum())


def test_load_factor_parity_at_resize_trigger():
    """The engine must reach the serial path's load factor (within 1%) at
    the moment inserts first fail (the resize trigger)."""
    def drive(insert_fn):
        cfg = ch.ContinuityConfig(num_buckets=20, ext_frac=0.1)
        t = ch.create(cfg)
        i = 0
        while True:
            K = ycsb.make_key(np.arange(i, i + 4))
            V = ycsb.make_value(np.random.RandomState(i), 4)
            t, ok, _ = insert_fn(cfg, t, K, V)
            i += int(np.asarray(ok).sum())
            if not bool(np.asarray(ok).all()):
                return float(ch.load_factor(cfg, t))
    lf_serial = drive(ch.insert_serial)
    lf_wave = drive(ch.insert)
    assert abs(lf_wave - lf_serial) <= 0.01 * max(lf_serial, 1e-9), \
        (lf_wave, lf_serial)


def test_insert_parallel_single_wave_and_ext_grant():
    """insert_parallel = wave 0 of the engine: first active op per pair
    executes (batch order priority), the rest retry; extension groups CAN
    now be granted on the parallel path."""
    cfg = ch.ContinuityConfig(num_buckets=4, ext_frac=1.0)
    K, V = keys_vals(np.arange(90))
    t, ok, retry = ch.insert_parallel(cfg, ch.create(cfg), K, V)
    r = np.asarray(retry)
    assert (np.asarray(ok) | r).all()
    rounds = 0
    while r.any() and rounds < 95:
        t, ok, retry = ch.insert_parallel(cfg, t, K, V, mask=jnp.asarray(r))
        r2 = np.asarray(retry)
        if r2.sum() == r.sum():        # table full: survivors keep failing
            break
        r, rounds = r2, rounds + 1
    t_ref, _, _ = ch.insert_serial(cfg, ch.create(cfg), K, V)
    assert ch.items_host(cfg, t) == ch.items_host(cfg, t_ref)
    assert int(t.ext_count) == int(t_ref.ext_count) >= 1


def test_vmapped_insert_matches_serial_per_shard():
    """The serving page table vmaps the engine over data shards."""
    cfg = ch.ContinuityConfig(num_buckets=64)
    DS, B = 3, 40
    base = ch.create(cfg)
    tables = ch.ContinuityTable(*jax.tree.map(
        lambda x: jnp.broadcast_to(x, (DS,) + x.shape), base))
    K = np.stack([ycsb.make_key(np.arange(i * B, (i + 1) * B))
                  for i in range(DS)])
    V = np.stack([ycsb.make_value(np.random.RandomState(i), B)
                  for i in range(DS)])
    Kj = jnp.asarray(K.astype(np.uint32))
    Vj = jnp.asarray(V.astype(np.uint32))
    out, ok, _ = jax.vmap(
        lambda t, k, v: ch.insert(cfg, t, k, v))(tables, Kj, Vj)
    out = ch.ContinuityTable(*out)
    assert bool(np.asarray(ok).all())
    for s in range(DS):
        ref, _, _ = ch.insert_serial(cfg, base, K[s], V[s])
        shard = ch.ContinuityTable(*[np.asarray(x)[s] for x in out])
        assert table_diff(ref, shard) is None


def test_fused_phase_split_crash_invisible():
    """Phase 1 (payload scatter) without phase 2 (indicator commit) must be
    invisible — the engine preserves the paper's log-free atomicity split."""
    cfg = ch.ContinuityConfig(num_buckets=64)
    K, V = keys_vals(np.arange(12))
    t, _, _ = ch.insert(cfg, ch.create(cfg), K[:8], V[:8])
    before = ch.items_host(cfg, t)
    # phase 1 only: scatter the payload for a new key, no indicator commit
    pair, parity = ch.locate(cfg, jnp.asarray(K[8:9]))
    crashed = ch._scatter_payload(
        t, jnp.ones((1,), jnp.bool_), pair, jnp.zeros((1,), jnp.int32),
        jnp.zeros((1,), jnp.int32), jnp.asarray(K[8:9]), jnp.asarray(V[8:9]),
        cfg.slots_per_pair)
    assert ch.items_host(cfg, crashed) == before

"""RDMA transport layer: verb plans, doorbell batching, latency model,
plan-derived accounting (byte-identical to the removed hand-tallies),
per-scheme read counts through the plan, remote-persist fences, and the
end-to-end YCSB ordering."""

import numpy as np
import pytest

from repro import api, rdma
from repro.data import ycsb
from repro.rdma import sim
from repro.rdma import verbs as rv

SCHEMES = ("continuity", "level", "pfarm", "dense")


def _loaded_store(scheme, n=600, slots=900, seed=0):
    """Store at ~2/3 load (extension groups / chains / spreads form)."""
    rng = np.random.RandomState(seed)
    store = api.make_store(scheme, table_slots=slots)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    table, res = store.insert(store.create(), K, V)
    return store, table, K[np.asarray(res.ok)], rng


# ---------------------------------------------------------------------------
# plan-derived ledger == the pre-refactor hand-tallied accounting
# ---------------------------------------------------------------------------

def _hand_tally(scheme, cfg, reads):
    """The four removed per-scheme ``read_counters`` formulas, kept here as
    the byte-identity oracle for the verb-plan-derived ledger."""
    n = reads.shape[0]
    if scheme == "continuity":
        return reads.sum(), n * cfg.segment_bytes + (reads - 1).sum() * cfg.ext_bytes
    if scheme == "level":
        return reads.sum(), reads.sum() * cfg.bucket_bytes
    if scheme == "pfarm":
        return (reads.sum(),
                n * cfg.window_bytes + (reads - 1).sum() * cfg.block_bytes)
    return reads.sum(), n * cfg.table_bytes


@pytest.mark.parametrize("scheme", SCHEMES)
def test_plan_ledger_byte_identical_to_hand_tally(scheme):
    store, table, K, rng = _loaded_store(scheme)
    NK = ycsb.negative_keys(rng, len(K), 400)
    for keys in (K, NK):
        res = store.lookup(table, keys)
        reads = np.asarray(res.reads)
        r_old, b_old = _hand_tally(scheme, store.cfg, reads)
        assert int(res.ledger.rdma_reads) == int(r_old)
        assert int(res.ledger.bytes_fetched) == int(b_old)
        assert int(res.ledger.ops) == keys.shape[0]
        # the plan itself is on the result and agrees with the per-op trace
        assert res.plan is not None
        assert (np.asarray(rv.reads_per_op(res.plan)) == reads).all()


# ---------------------------------------------------------------------------
# per-scheme negative-lookup read counts, asserted through the verb plan
# (paper §II-C2 — not through scheme-internal counters)
# ---------------------------------------------------------------------------

def test_negative_lookup_continuity_always_one_contiguous_read():
    # misses included: the home segment fetch answers the lookup in ONE
    # contiguous READ whenever the pair has no added SBuckets
    store, table, K, rng = _loaded_store("continuity", n=400, slots=900)
    NK = ycsb.negative_keys(rng, 400, 500)
    plan = store.lookup(table, NK).plan
    per_op = np.asarray(rv.reads_per_op(plan))
    assert per_op.min() >= 1
    if int(table.ext_count) == 0:
        assert (per_op == 1).all()
    # ext-free config: ALWAYS exactly one, by construction
    free = api.make_store("continuity", table_slots=900, ext_frac=0.0)
    t = free.create()
    t, _ = free.insert(t, K, ycsb.make_value(rng, len(K)))
    plan = free.lookup(t, NK).plan
    assert (np.asarray(rv.reads_per_op(plan)) == 1).all()
    # and the one verb is the contiguous segment fetch
    assert (np.asarray(plan.verb)[:, 0] == rv.READ).all()
    assert (np.asarray(plan.nbytes)[:, 0] == free.cfg.segment_bytes).all()


def test_negative_lookup_level_scans_all_distinct_candidates():
    store, table, K, rng = _loaded_store("level")
    NK = ycsb.negative_keys(rng, len(K), 500)
    plan = store.lookup(table, NK).plan
    per_op = np.asarray(rv.reads_per_op(plan))
    assert per_op.max() <= 4
    # negative search never stops early: it reads every DISTINCT candidate
    from repro.core import level as lv
    import jax.numpy as jnp
    cand = np.asarray(lv._cand_buckets(
        store.cfg, jnp.asarray(NK).reshape(-1, 4)))
    distinct = (1 + (cand[:, 1] != cand[:, 0])
                + 1 + (cand[:, 3] != cand[:, 2]))
    assert (per_op == distinct).all()
    assert per_op.max() == 4          # hash collisions of all four are rare
    # sequential probing: depths of active lanes are 0..reads-1
    depth = np.asarray(plan.depth)
    active = np.asarray(plan.verb) == rv.READ
    for b in (0, 1, 2):
        assert sorted(depth[b][active[b]]) == list(range(per_op[b]))


def test_negative_lookup_pfarm_reads_window_plus_chain():
    store, table, K, rng = _loaded_store("pfarm", n=700, slots=900)
    NK = ycsb.negative_keys(rng, len(K), 500)
    res = store.lookup(table, NK)
    per_op = np.asarray(rv.reads_per_op(res.plan))
    assert (per_op == np.asarray(res.reads)).all()
    assert per_op.min() >= 1
    assert per_op.max() <= 1 + store.cfg.max_chain
    # chain hops are DEPENDENT verbs: depth == hop index
    depth = np.asarray(res.plan.depth)
    verb = np.asarray(res.plan.verb)
    assert (depth[:, 0] == 0).all()
    for k in range(1, res.plan.lanes):
        lane_active = verb[:, k] == rv.READ
        assert (depth[lane_active, k] == k).all()


# ---------------------------------------------------------------------------
# transport: doorbell batching + latency model
# ---------------------------------------------------------------------------

def test_doorbell_batching_coalesces_independent_verbs():
    link = rdma.LinkModel()
    mem = rdma.RemoteMemory(link)
    B = 64
    plan = rv.pack(B, [(rv.READ, rv.REGION_TABLE, 0, 520, 0, False)])
    comp = mem.post(plan)
    # 64 independent READs = ONE doorbell = one RTT for the whole batch
    assert comp.rounds == 1
    assert comp.verbs == B
    expected = link.rtt_us + B * (
        link.verb_us + 520 / link.nic_bytes_per_us
        + 520 / link.pm_read_bytes_per_us)
    assert comp.batch_us == pytest.approx(expected)
    # unloaded per-op latency: one RTT + the op's own verb cost
    assert comp.op_us[0] == pytest.approx(
        link.rtt_us + link.verb_us + 520 / link.nic_bytes_per_us
        + 520 / link.pm_read_bytes_per_us)


def test_dependent_depths_cost_extra_round_trips():
    mem = rdma.RemoteMemory()
    B = 8
    chained = rv.pack(B, [
        (rv.READ, rv.REGION_TABLE, 0, 100, 0, False),
        (rv.READ, rv.REGION_EXT, 0, 100, 1, False)])
    flat = rv.pack(B, [
        (rv.READ, rv.REGION_TABLE, 0, 100, 0, False),
        (rv.READ, rv.REGION_EXT, 0, 100, 0, False)])
    c1 = mem.post(chained)
    c2 = mem.post(flat)
    assert c1.rounds == 2 and c2.rounds == 1
    assert c1.batch_us == pytest.approx(c2.batch_us + mem.link.rtt_us)
    assert int(rv.round_trips(chained)) == 2
    assert mem.doorbells == 3 and mem.posts == 2


def test_fenced_writes_price_remote_persistence():
    link = rdma.LinkModel()
    mem = rdma.RemoteMemory(link)
    plan = sim.write_plan(4, pm_per_op=2)
    comp = mem.post(plan)
    assert comp.rounds == 2                       # payload round, commit round
    # each op: 2 RTTs + 2 fenced WRITEs + media/wire time
    per_op = 2 * link.rtt_us + 2 * (link.verb_us + link.fence_us) \
        + (32 + 8) / link.nic_bytes_per_us \
        + (32 + 8) / link.pm_write_bytes_per_us
    assert comp.op_us[0] == pytest.approx(per_op)


def test_transport_selection_through_exec_policy():
    assert rdma.RemoteMemory.from_policy(api.ExecPolicy()) is None
    mem = rdma.RemoteMemory.from_policy(api.ExecPolicy(transport="sim"))
    assert isinstance(mem, rdma.RemoteMemory)
    with pytest.raises(AssertionError):
        api.ExecPolicy(transport="infiniband")


# ---------------------------------------------------------------------------
# remote-persist fences: the WRITE-visible vs persisted cut
# ---------------------------------------------------------------------------

def test_remote_crash_commit_fences_leave_no_durability_gap():
    from repro import consistency as C
    store, table, K, rng = _loaded_store("continuity", n=32, slots=400)
    h = C.HANDLERS["continuity"]
    base = h.init_state(store.cfg, table)
    NK = ycsb.negative_keys(rng, 64, 8)
    _, tres = store.trace_insert(table, NK, ycsb.make_value(rng, 8))
    states = list(C.remote_crash_states(base, tres.trace))
    assert len(states) == len(tres.trace.records) + 1
    for cs in states:
        # under the commit-fence discipline nothing observable is lost...
        assert C.unpersisted_commits(tres.trace, cs) == 0
        # ...and the persisted image recovers to a consistent table whose
        # visible items are exactly the fenced commits' items
        recovered, _ = store.recover(cs.persisted)
        vis = h.visible(store.cfg, h.init_state(store.cfg, recovered))
        committed = sum(1 for i, r in enumerate(tres.trace.records)
                        if i < cs.fenced_done and r.kind in C.COMMIT_KINDS)
        assert len(vis) == len(h.visible(store.cfg, base)) + committed


def test_remote_crash_unfenced_delivery_detected():
    from repro import consistency as C
    store, table, K, rng = _loaded_store("continuity", n=16, slots=400)
    h = C.HANDLERS["continuity"]
    base = h.init_state(store.cfg, table)
    NK = ycsb.negative_keys(rng, 32, 4)
    _, tres = store.trace_insert(table, NK, ycsb.make_value(rng, 4))
    # write-combined delivery: NO fences until the end of the batch — a cut
    # after a visible commit loses it (the injector must expose the gap)
    gaps = [C.unpersisted_commits(tres.trace, cs)
            for cs in C.remote_crash_states(base, tres.trace, fences=())]
    assert max(gaps) >= 1
    # strict per-store fencing closes it again
    gaps = [C.unpersisted_commits(tres.trace, cs)
            for cs in C.remote_crash_states(
                base, tres.trace, fences=C.fence_every_store(tres.trace))]
    assert max(gaps) == 0


# ---------------------------------------------------------------------------
# end-to-end YCSB: the paper's headline ordering
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_end_to_end_ordering_read_heavy():
    cells = {s: {wl: sim.run_ycsb(s, wl, num_records=800, num_ops=1000,
                                  batch=250)
                 for wl in ("B", "C")}
             for s in ("continuity", "level", "pfarm")}
    for wl in ("B", "C"):
        c = cells["continuity"][wl]["ops_per_s"]
        l = cells["level"][wl]["ops_per_s"]
        p = cells["pfarm"][wl]["ops_per_s"]
        assert c >= l >= p, (wl, c, l, p)
    # latency: continuity's p99 beats both baselines on read-heavy mixes
    # (one contiguous fetch has no multi-probe/chain tail)
    assert (cells["continuity"]["C"]["p99_us"]
            <= cells["level"]["C"]["p99_us"])
    assert (cells["continuity"]["C"]["p99_us"]
            <= cells["pfarm"]["C"]["p99_us"])


def test_scheduler_step_is_the_doorbell_flush_boundary():
    import jax
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.models.config import ShapeConfig
    from repro.serving import kvcache as KC
    from repro.serving.scheduler import ContinuousBatcher, Request

    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("s", seq_len=64, global_batch=2, kind="decode")
    geom = KC.make_geometry(
        cfg, shape, shards=1, page_size=16,
        policy=api.ExecPolicy(transport="sim"))
    batcher = ContinuousBatcher(cfg, geom, params)
    assert batcher.transport is not None      # selected via ExecPolicy
    batcher.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32),
                           max_new_tokens=3))
    steps = 0
    while batcher.step():
        steps += 1
    # one post (>= one doorbell) per decode step — the flush boundary
    assert batcher.transport.posts == steps + 1
    assert batcher.transport.doorbells >= steps
    # every translation is one verb; batch x max_pages lanes per step
    assert batcher.transport.total_verbs > 0

"""Level hashing and P-FaRM-KV baselines: semantics + paper counters."""

import numpy as np

import repro.core.level as lv
import repro.core.pfarm as pf
from repro.data import ycsb


def kv(n, seed=0):
    return (ycsb.make_key(np.arange(n)),
            ycsb.make_value(np.random.RandomState(seed), n))


class TestLevel:
    CFG = lv.LevelConfig(num_top=64)

    def test_roundtrip(self):
        t = lv.create(self.CFG)
        K, V = kv(150)
        t, ok, ctr = lv.insert(self.CFG, t, K, V)
        okn = np.asarray(ok)
        assert okn.sum() > 140
        res = lv.lookup(self.CFG, t, K)
        assert np.asarray(res.found)[okn].all()
        np.testing.assert_array_equal(np.asarray(res.values)[okn], V[okn])

    def test_pm_writes_band(self):
        """Paper Table I: insert 2–2.01, update 2–5, delete 1."""
        t = lv.create(self.CFG)
        K, V = kv(180)
        t, ok, ci = lv.insert(self.CFG, t, K, V)
        per_ins = float(ci.pm_writes) / float(np.asarray(ok).sum())
        assert 2.0 <= per_ins <= 2.2
        t, uok, cu = lv.update(self.CFG, t, K, kv(180, 1)[1])
        per_upd = float(cu.pm_writes) / max(float(np.asarray(uok).sum()), 1)
        assert 2.0 <= per_upd <= 5.0
        t, dok, cd = lv.delete(self.CFG, t, K[:50])
        assert float(cd.pm_writes) == float(np.asarray(dok).sum())

    def test_negative_search_reads_four_buckets(self):
        t = lv.create(self.CFG)
        K, V = kv(100)
        t, _, _ = lv.insert(self.CFG, t, K, V)
        neg = ycsb.negative_keys(np.random.RandomState(2), 100, 300)
        res = lv.lookup(self.CFG, t, neg)
        assert not np.asarray(res.found).any()
        # paper: negative searches probe all (<=4) candidate buckets
        assert 3.5 <= float(np.mean(np.asarray(res.reads))) <= 4.0

    def test_update_moves_or_logs(self):
        t = lv.create(self.CFG)
        K, V = kv(100)
        t, _, _ = lv.insert(self.CFG, t, K, V)
        V2 = kv(100, 3)[1]
        t, ok, _ = lv.update(self.CFG, t, K, V2)
        res = lv.lookup(self.CFG, t, K)
        u = np.asarray(ok)
        np.testing.assert_array_equal(np.asarray(res.values)[u], V2[u])


class TestPFarm:
    CFG = pf.PFarmConfig(num_buckets=64)

    def test_roundtrip_with_chains(self):
        t = pf.create(self.CFG)
        K, V = kv(250)
        t, ok, ctr = pf.insert(self.CFG, t, K, V)
        okn = np.asarray(ok)
        assert okn.sum() > 230
        res = pf.lookup(self.CFG, t, K)
        assert np.asarray(res.found)[okn].all()
        np.testing.assert_array_equal(np.asarray(res.values)[okn], V[okn])

    def test_recipe_logging_cost(self):
        """Paper Table I: 5 PM writes for every op type."""
        t = pf.create(self.CFG)
        K, V = kv(100)
        t, ok, ci = pf.insert(self.CFG, t, K, V)
        n = float(np.asarray(ok).sum())
        assert float(ci.pm_writes) == 5 * n
        t, uok, cu = pf.update(self.CFG, t, K, kv(100, 1)[1])
        assert float(cu.pm_writes) == 5 * float(np.asarray(uok).sum())
        t, dok, cd = pf.delete(self.CFG, t, K[:30])
        assert float(cd.pm_writes) == 5 * float(np.asarray(dok).sum())

    def test_window_is_single_read_until_chained(self):
        t = pf.create(self.CFG)
        K, V = kv(100)
        t, ok, _ = pf.insert(self.CFG, t, K, V)
        res = pf.lookup(self.CFG, t, K)
        okn = np.asarray(ok)
        if int(t.ocount) == 0:
            assert int(np.asarray(res.reads)[okn].max()) == 1
        else:
            assert int(np.asarray(res.reads)[okn].max()) <= 1 + self.CFG.max_chain

    def test_delete_then_lookup_missing(self):
        t = pf.create(self.CFG)
        K, V = kv(60)
        t, _, _ = pf.insert(self.CFG, t, K, V)
        t, dok, _ = pf.delete(self.CFG, t, K[:30])
        res = pf.lookup(self.CFG, t, K[:30])
        assert not np.asarray(res.found)[np.asarray(dok)].any()

"""End-to-end behaviour tests for the whole system."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.continuity as ch
from repro.data import ycsb


def test_ycsb_generator_semantics():
    """Op mixes respect workload definitions; D inserts fresh ids."""
    for wl, checks in {
        "A": {ycsb.OP_READ: (0.4, 0.6), ycsb.OP_UPDATE: (0.4, 0.6)},
        "B": {ycsb.OP_READ: (0.9, 1.0), ycsb.OP_UPDATE: (0.0, 0.1)},
        "C": {ycsb.OP_READ: (1.0, 1.0)},
        "F": {ycsb.OP_READ: (0.4, 0.6), ycsb.OP_RMW: (0.4, 0.6)},
    }.items():
        ops = np.concatenate([b.ops for b in
                              ycsb.generate(wl, 1000, 4000, 500, seed=1)])
        assert len(ops) == 4000
        for code, (lo, hi) in checks.items():
            frac = (ops == code).mean()
            assert lo <= frac <= hi, (wl, code, frac)


def test_ycsb_full_workload_against_table():
    """Run a complete YCSB-A pass over a continuity table; every positive
    read of a loaded record must hit."""
    n = 400
    cfg = ch.ContinuityConfig(num_buckets=2 * int(n / 0.5 / 20))
    t = ch.create(cfg)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(np.random.RandomState(0), n)
    t, ok, _ = ch.insert(cfg, t, K, V)
    assert bool(np.asarray(ok).all())
    for ob in ycsb.generate("A", n, 1200, 300, seed=2):
        reads = ob.ops == ycsb.OP_READ
        res = ch.lookup(cfg, t, ob.keys[reads])
        assert bool(res.found.all())
        upd = ob.ops == ycsb.OP_UPDATE
        t, uok, _ = ch.update(cfg, t, ob.keys[upd], ob.vals[upd])
        assert bool(np.asarray(uok).all())


def test_zipf_is_skewed():
    z = ycsb.Zipf(10_000)
    s = z.sample(np.random.RandomState(0), 20_000)
    top = (s < 100).mean()
    assert top > 0.3                       # zipf(0.99): head-heavy
    assert s.max() < 10_000 and s.min() >= 0


def test_train_short_run_with_checkpoint_restart(tmp_path):
    """Mini end-to-end driver: train, crash, restart, converge further."""
    from repro.checkpoint import CheckpointManager
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.training import optimizer as O
    from repro.training.train_step import make_train_step

    cfg = smoke_config("granite-moe-1b-a400m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = O.init(params)
    step = jax.jit(make_train_step(cfg, O.OptConfig(lr=3e-3, warmup=2,
                                                    decay_steps=60)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    first = None
    for i in range(6):
        params, state, stats = step(params, state, batch)
        first = first if first is not None else float(stats["loss"])
        if i == 3:
            mgr.save(4, {"p": params, "o": state})
    # crash + restart
    p2 = T.init_params(cfg, jax.random.PRNGKey(0))
    s2 = O.init(p2)
    restored, at, _ = mgr.restore({"p": p2, "o": s2})
    assert at == 4 and int(restored["o"].step) == 4
    params, state = restored["p"], restored["o"]
    for _ in range(4):
        params, state, stats = step(params, state, batch)
    assert float(stats["loss"]) < first


def test_hash_function_quality():
    """Bucket placement is near-uniform (chi-square sanity)."""
    from repro.core.hashfn import hash128
    K = ycsb.make_key(np.arange(20_000))
    h = np.asarray(hash128(jnp.asarray(K))) % 64
    counts = np.bincount(h, minlength=64)
    expected = 20_000 / 64
    chi2 = ((counts - expected) ** 2 / expected).sum()
    assert chi2 < 150, chi2                 # df=63, p≈1e-9 threshold


def test_two_hash_functions_independent():
    from repro.core.hashfn import hash128, hash128_2
    K = ycsb.make_key(np.arange(5_000))
    h1 = np.asarray(hash128(jnp.asarray(K))) % 64
    h2 = np.asarray(hash128_2(jnp.asarray(K))) % 64
    agree = (h1 == h2).mean()
    assert agree < 0.05                     # ~1/64 expected

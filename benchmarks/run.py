"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  * bench_hash    — Table I (PM writes), Figs 4–18 (YCSB throughput/latency,
                    search micro, update micro, load factor), access-amp
  * bench_serving — technique-on-the-hot-path serving numbers
  * roofline      — per-(arch x shape x mesh) dry-run roofline rows
                    (requires experiments/dryrun/*.json from
                    ``python -m repro.launch.dryrun --all``)

The serial-vs-wave write-batch sweep always runs and is written to
``BENCH_hash.json`` (ops/s + PM-write counters at batch {64, 512, 4096}) so
successive PRs accumulate a perf trajectory — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--sections", default="hash,serving,roofline",
                   help="comma-separated subset of hash,serving,roofline "
                        "(the write-batch sweep always runs)")
    p.add_argument("--bench-json", default="BENCH_hash.json",
                   help="where to write the write-batch sweep artifact")
    args = p.parse_args(argv)
    sections = {s for s in args.sections.split(",") if s}
    unknown = sections - {"hash", "serving", "roofline"}
    if unknown:
        p.error(f"unknown sections {sorted(unknown)}; "
                f"valid: hash, serving, roofline (or empty for sweep only)")

    rows = []
    from benchmarks import bench_hash, bench_serving, roofline
    if "hash" in sections:
        bench_hash.run(rows)
    if "serving" in sections:
        bench_serving.run(rows)
    if "roofline" in sections:
        roofline.run(rows)
    payload = bench_hash.bench_write_batch_sweep(rows)
    with open(args.bench_json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

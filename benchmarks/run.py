"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  * hash          — everything below (Table I + Figs 4–18 + access-amp)
  * pm_writes     — Table I (PM writes per op, via repro.api CostLedger)
  * access_amp    — contiguous fetches + bytes per lookup
  * search        — positive/negative search micro (Figs 6/7 + 13/14)
  * update_micro  — 100% updates (Figs 10/17)
  * ycsb          — YCSB-A/B/C/D/F throughput + latency (Figs 4–10/11–17,
                    CPU wall clock of the jitted ops)
  * end_to_end    — per-scheme YCSB-A/B/C throughput + p50/p99 latency over
                    the RDMA transport simulation (repro.rdma: verb plans,
                    doorbell batching, analytical LinkModel) — the paper's
                    headline 1.45–2.43x ordering; --e2e-scale smoke shrinks
                    it for CI
  * load_factor   — load factor at each resize (Fig 18; emitted to the
                    BENCH json and banded against the paper's claim — the
                    fingerprint/stash tier lifts the first trigger past
                    ~0.85 — by validate_bench.py)
  * resize        — online-resize stalls: steps per cutover and worst
                    per-step pause of the incremental cohort split vs the
                    stop-the-world rehash (emitted to the BENCH json;
                    validate_bench.py gates the non-blocking claim)
  * cluster       — N-node replicated cluster YCSB with a mid-run join
                    (live migration) and primary kill (failover), plus
                    the replicated-durability and migration crash drills
                    (repro.cluster; --e2e-scale smoke shrinks it for CI)
  * cache         — 100-client fan-in: version-stamped client caches vs
                    the uncached request-per-post edge under membership
                    chaos (repro.cache; doorbell/p99 collapse + the
                    zero-stale gate; --e2e-scale smoke shrinks it)
  * obs           — telemetry-sketch headline numbers (repro.obs): the
                    e2e scheme trio's p50/p99 read back OUT of the
                    e2e.op_us registry histograms, plus the
                    maintenance-SLO drill (validate_bench gates the
                    YCSB-A ordering chain and zero SLO burns)
  * crash_consistency — recovery work per scheme from the crash/scheme
                    matrix (repro.consistency; EXPERIMENTS.md §Crash)
  * bench_serving — technique-on-the-hot-path serving numbers
  * roofline      — per-(arch x shape x mesh) dry-run roofline rows
                    (requires experiments/dryrun/*.json from
                    ``python -m repro.launch.dryrun --all``)

The serial-vs-wave write-batch sweep always runs and is written to
``--bench-json`` (default BENCH_hash.json; ops/s + PM-write counters at
``--sweep-batches``) so successive PRs accumulate a perf trajectory — see
EXPERIMENTS.md §Perf.  ``benchmarks/validate_bench.py`` checks the emitted
artifact against its schema (CI runs it on the smoke sweep).  ``--merge``
updates the existing artifact in place with just this run's sections; an
EMPTY ``--sweep-batches`` under ``--merge`` skips the sweep and keeps the
artifact's committed one.
"""

from __future__ import annotations

import argparse
import json

HASH_SECTIONS = ("pm_writes", "access_amp", "search", "update_micro",
                 "ycsb", "end_to_end", "load_factor", "resize")
SECTIONS = HASH_SECTIONS + ("cluster", "cache", "obs", "crash_consistency",
                            "hash", "serving", "roofline")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--sections", default="hash,serving,roofline",
                   help="comma-separated subset of "
                        f"{', '.join(SECTIONS)} "
                        "(the write-batch sweep always runs)")
    p.add_argument("--bench-json", default="BENCH_hash.json",
                   help="where to write the write-batch sweep artifact")
    p.add_argument("--sweep-batches", default="64,512,4096",
                   help="batch sizes for the serial-vs-wave sweep "
                        "(smoke CI uses a small subset)")
    p.add_argument("--e2e-scale", default="full", choices=("full", "smoke"),
                   help="workload sizes for the end_to_end section")
    p.add_argument("--merge", action="store_true",
                   help="load the existing --bench-json and update it "
                        "with this run's sections (instead of rewriting "
                        "the whole artifact) — lets a single section "
                        "refresh without regenerating the sweep")
    args = p.parse_args(argv)
    sections = {s for s in args.sections.split(",") if s}
    unknown = sections - set(SECTIONS)
    if unknown:
        p.error(f"unknown sections {sorted(unknown)}; valid: "
                f"{', '.join(SECTIONS)} (or empty for sweep only)")
    if "hash" in sections:
        sections |= set(HASH_SECTIONS)
    batches = tuple(int(b) for b in args.sweep_batches.split(",") if b)
    if not batches and not args.merge:
        p.error("an empty --sweep-batches (skip the sweep) requires "
                "--merge: the artifact must keep its existing sweep")

    rows = []
    table1 = crash = e2e = lf = rz = cluster = cache = obs_sec = None
    from benchmarks import (bench_cache, bench_cluster, bench_crash,
                            bench_hash, bench_obs, bench_serving, roofline)
    if "pm_writes" in sections:
        table1 = bench_hash.bench_pm_writes(rows)
    if "crash_consistency" in sections:
        crash = bench_crash.run(rows)
    if "end_to_end" in sections:
        e2e = bench_hash.bench_end_to_end(rows, scale=args.e2e_scale)
    if "cluster" in sections:
        cluster = bench_cluster.run(rows, scale=args.e2e_scale)
    if "cache" in sections:
        cache = bench_cache.run(rows, scale=args.e2e_scale)
    if "obs" in sections:
        obs_sec = bench_obs.run(rows, scale=args.e2e_scale)
    if "access_amp" in sections:
        bench_hash.bench_access_amp(rows)
    if "search" in sections:
        bench_hash.bench_search_micro(rows)
    if "update_micro" in sections:
        bench_hash.bench_update_micro(rows)
    if "ycsb" in sections:
        bench_hash.bench_ycsb(rows)
    if "load_factor" in sections:
        lf = bench_hash.bench_load_factor(rows)
    if "resize" in sections:
        rz = bench_hash.bench_resize(rows)
    if "serving" in sections:
        bench_serving.run(rows)
    if "roofline" in sections:
        roofline.run(rows)
    payload = (bench_hash.bench_write_batch_sweep(rows, batches=batches)
               if batches else {})
    if args.merge:
        with open(args.bench_json) as f:
            base = json.load(f)
        base.update(payload)
        payload = base
    if table1 is not None:
        payload["table1"] = table1
    if crash is not None:
        payload["crash_consistency"] = crash
    if e2e is not None:
        payload["end_to_end"] = e2e
    if lf is not None:
        payload["load_factor"] = lf
    if rz is not None:
        payload["resize"] = rz
    if cluster is not None:
        payload["cluster"] = cluster
    if cache is not None:
        payload["cache"] = cache
    if obs_sec is not None:
        payload["obs"] = obs_sec
    with open(args.bench_json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

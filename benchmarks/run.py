"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  * bench_hash    — Table I (PM writes), Figs 4–18 (YCSB throughput/latency,
                    search micro, update micro, load factor), access-amp
  * bench_serving — technique-on-the-hot-path serving numbers
  * roofline      — per-(arch x shape x mesh) dry-run roofline rows
                    (requires experiments/dryrun/*.json from
                    ``python -m repro.launch.dryrun --all``)
"""

from __future__ import annotations

import sys


def main() -> None:
    rows = []
    from benchmarks import bench_hash, bench_serving, roofline
    bench_hash.run(rows)
    bench_serving.run(rows)
    roofline.run(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

"""Cache benchmark section: the 100-client fan-in cell.

One `repro.cache.fanin.run_fanin` drill — O(100) clients behind
version-stamped `ClientCache` instances vs the uncached request-per-post
edge, same seeded stream and chaos schedule on both sides.  The cell's
p50/p99 come out of the shared `repro.obs` histogram sketch (fanin
records every served op into it), so this section and a traced export
derive their percentiles from the same buckets.  The payload
lands in the BENCH json under ``cache`` and `validate_bench.py` gates
the ISSUE's acceptance criteria on it: >= 2x per-node read-doorbell
reduction, cached p99 <= uncached p99, hit rate above the honesty floor,
and ``stale_served`` exactly zero across partition/heal/join/failover.
"""

from __future__ import annotations

from repro.cache import fanin

SMOKE_KW = dict(rounds=14, ops_per_round=16, writes_per_round=2,
                num_records=1200)
FULL_KW = dict(rounds=18, ops_per_round=16, writes_per_round=2,
               num_records=2000)


def run(rows, scale: str = "full") -> dict:
    kw = SMOKE_KW if scale == "smoke" else FULL_KW
    cell = fanin.run_fanin("continuity", clients=100, **kw)
    un, ca = cell["uncached"], cell["cached"]
    payload = {
        "clients": cell["clients"], "rounds": cell["rounds"],
        "seed": cell["seed"], "dist": cell["dist"],
        "trust_window": cell["trust_window"],
        "doorbell_reduction": cell["doorbell_reduction"],
        "bytes_reduction": cell["bytes_reduction"],
        "p99_ratio": cell["p99_ratio"],
        "hit_rate": ca["hit_rate"], "stale_served": ca["stale_served"],
        "uncached": {k: un[k] for k in
                     ("read_doorbells", "read_bytes", "p50_us", "p99_us",
                      "wrong_reads", "reads_served")},
        "cached": {k: ca[k] for k in
                   ("read_doorbells", "read_bytes", "p50_us", "p99_us",
                    "wrong_reads", "reads_served")},
        "gate_failures": fanin.check_gates(cell),
    }
    rows.append(("cache_fanin[continuity]", ca["p50_us"],
                 f"doorbells {un['read_doorbells']}->{ca['read_doorbells']} "
                 f"({cell['doorbell_reduction']:.2f}x) "
                 f"p99={ca['p99_us']:.2f}us hit={ca['hit_rate']:.3f} "
                 f"stale={ca['stale_served']}"))
    rows.append(("cache_fanin_uncached", un["p50_us"],
                 f"p99={un['p99_us']:.2f}us (request-per-post baseline)"))
    return payload

"""Paper-table benchmarks for the three hash schemes.

Artifacts reproduced (see EXPERIMENTS.md §Paper-validation):
  * Table I    — PM writes per insert / update / delete (exact counters);
  * Figs 4–10  — YCSB-A/B/C/D/F + positive/negative search + update-only
                 throughput (CPU wall-clock of the jitted batched ops;
                 orderings are the reproducible claim, Optane/IB absolutes
                 are not);
  * Figs 11–17 — per-op latency (us/op of the same runs);
  * Fig 18     — load factor at each resize for none / 1/20 / 1/10
                 added-SBucket policies;
  * access amplification — contiguous fetches per lookup (continuity 1 vs
                 level <=4 vs pfarm 1+chain) and bytes fetched per lookup;
  * write-batch sweep — serial lax.scan vs wave-vectorized mutation engine
                 at batch sizes {64, 512, 4096} (EXPERIMENTS.md §Perf;
                 emitted as BENCH_hash.json by benchmarks.run).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import SchemeDriver, timeit
from repro import api
from repro.data import ycsb

# registry-driven: every scheme registered with repro.api is benchmarked
# (continuity, level, pfarm, dense, + anything a later PR registers)
SCHEMES = tuple(api.available_schemes())


def bench_pm_writes(rows, n=512, table_slots=4096):
    """Table I — through ``repro.api`` (one `CostLedger` per scheme).

    Returns the ``table1`` payload for the BENCH json ({scheme: {op:
    pm/op}}), which ``validate_bench.py --assert-table1`` checks against
    the paper's values (CI's Table I gate)."""
    rng = np.random.RandomState(0)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    table1 = {}
    for s in SCHEMES:
        store = api.make_store(s, table_slots=table_slots)
        t = store.create()
        t, ri = store.insert(t, K, V)
        t, ru = store.update(t, K, ycsb.make_value(rng, n))
        t, rd = store.delete(t, K[: n // 2])
        table1[s] = {"insert": ri.ledger.pm_per_op(),
                     "update": ru.ledger.pm_per_op(),
                     "delete": rd.ledger.pm_per_op()}
        for op in ("insert", "update", "delete"):
            rows.append((f"pm_writes_{op}[{s}]", 0.0,
                         f"{table1[s][op]:.2f}"))
    return table1


def bench_access_amp(rows):
    """§II claim: contiguous fetches + bytes per lookup (pos and neg)."""
    rng = np.random.RandomState(1)
    n = 1500
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)
    for s in SCHEMES:
        d = SchemeDriver(s, table_slots=4096)
        d.insert(K, V)
        res, ctr = d.lookup(K)
        rows.append((f"reads_per_pos_lookup[{s}]", 0.0,
                     f"{float(np.mean(np.asarray(res.reads))):.2f}"))
        rows.append((f"bytes_per_pos_lookup[{s}]", 0.0,
                     f"{float(ctr.bytes_fetched)/n:.0f}"))
        neg = ycsb.negative_keys(rng, n, 1000)
        nres, nctr = d.lookup(neg)
        rows.append((f"reads_per_neg_lookup[{s}]", 0.0,
                     f"{float(np.mean(np.asarray(nres.reads))):.2f}"))


def bench_ycsb(rows, num_records=3000, num_ops=6000, batch=500):
    """Figs 4–10 (throughput) + Figs 11–17 (latency).

    Batches use FIXED op-type counts (expected mix ratios) so every jitted op
    shape compiles exactly once — the random-mix generator in
    repro.data.ycsb is exercised by the correctness tests instead."""
    import time
    rng = np.random.RandomState(3)
    for wl in ("A", "B", "C", "D", "F"):
        mix = dict(ycsb.WORKLOADS[wl])
        n_read = int(batch * (mix.get(ycsb.OP_READ, 0)
                              + mix.get(ycsb.OP_RMW, 0)))
        n_upd = int(batch * (mix.get(ycsb.OP_UPDATE, 0)
                             + mix.get(ycsb.OP_RMW, 0)))
        n_ins = int(batch * mix.get(ycsb.OP_INSERT, 0))
        zipf = ycsb.Zipf(num_records)
        for s in SCHEMES:
            d = SchemeDriver(s, table_slots=4 * num_records)
            K = ycsb.make_key(np.arange(num_records))
            V = ycsb.make_value(np.random.RandomState(2), num_records)
            d.insert(K, V)
            jax.block_until_ready(d.table)
            next_id = num_records
            # one warmup round to compile each op shape
            batches = []
            for _ in range(num_ops // batch):
                ids_r = zipf.sample(rng, max(n_read, 1))
                ids_u = zipf.sample(rng, max(n_upd, 1)) if n_upd else None
                ins_ids = (np.arange(next_id, next_id + n_ins)
                           if n_ins else None)
                next_id += n_ins
                batches.append((ycsb.make_key(ids_r),
                                ycsb.make_key(ids_u) if n_upd else None,
                                ycsb.make_value(rng, max(n_upd, 1)),
                                ycsb.make_key(ins_ids) if n_ins else None,
                                ycsb.make_value(rng, max(n_ins, 1))))
            def round_(b):
                kr, ku, vu, ki, vi = b
                d.lookup(kr)
                if ku is not None:
                    d.update(ku, vu)
                if ki is not None:
                    d.insert(ki, vi)
            round_(batches[0])            # compile
            jax.block_until_ready(d.table)
            t0 = time.perf_counter()
            for b in batches[1:]:
                round_(b)
            jax.block_until_ready(d.table)
            dt = time.perf_counter() - t0
            nops = (len(batches) - 1) * batch
            rows.append((f"ycsb_{wl}[{s}]", dt / nops * 1e6,
                         f"{nops/dt:.0f} ops/s"))


def bench_end_to_end(rows, scale="full"):
    """The paper's headline end-to-end claim: per-scheme YCSB-A/B/C
    throughput + p50/p99 latency over the RDMA transport simulation
    (`repro.rdma.sim`: exact verb plans, doorbell batching, analytical
    `LinkModel`).  Returns the ``end_to_end`` payload for the BENCH json;
    ``validate_bench.py`` bands the relative ordering (continuity >= level
    >= pfarm on read-heavy workloads)."""
    from repro.rdma import sim
    kw = (dict(num_records=1200, num_ops=1500, batch=300) if scale == "smoke"
          else dict(num_records=3000, num_ops=4000, batch=500))
    e2e = {}
    for s in SCHEMES:
        for wl in sim.SIM_WORKLOADS:
            r = sim.run_ycsb(s, wl, **kw)
            e2e.setdefault(s, {})[wl] = r
            rows.append((f"end_to_end_{wl}[{s}]", r["p50_us"],
                         f"{r['ops_per_s']:.0f} ops/s p99={r['p99_us']:.2f}us "
                         f"verbs/op={r['verbs_per_op']:.2f}"))
    return e2e


def bench_search_micro(rows, num_records=3000):
    """Figs 6/7 + 13/14: positive and negative search."""
    rng = np.random.RandomState(4)
    K = ycsb.make_key(np.arange(num_records))
    V = ycsb.make_value(rng, num_records)
    NK = ycsb.negative_keys(rng, num_records, num_records)
    for s in SCHEMES:
        d = SchemeDriver(s, table_slots=4 * num_records)
        d.insert(K, V)
        fn = jax.jit(d.lookup_fn())
        tpos, _ = timeit(fn, d.table, K)
        tneg, _ = timeit(fn, d.table, NK)
        rows.append((f"search_pos[{s}]", tpos / num_records * 1e6,
                     f"{num_records/tpos:.0f} ops/s"))
        rows.append((f"search_neg[{s}]", tneg / num_records * 1e6,
                     f"{num_records/tneg:.0f} ops/s"))


def bench_update_micro(rows, num_records=2000):
    """Figs 10/17: 100% updates."""
    rng = np.random.RandomState(5)
    K = ycsb.make_key(np.arange(num_records))
    V = ycsb.make_value(rng, num_records)
    for s in SCHEMES:
        d = SchemeDriver(s, table_slots=4 * num_records)
        d.insert(K, V)
        V2 = ycsb.make_value(rng, num_records)
        t, _ = timeit(lambda: d.update(K, V2)[0], warmup=1, iters=2)
        rows.append((f"update_only[{s}]", t / num_records * 1e6,
                     f"{num_records/t:.0f} ops/s"))


def bench_load_factor(rows):
    """Fig 18: load factor at each resize trigger; 3 extension policies.

    Returns the ``load_factor`` payload for the BENCH json ({policy
    label: [lf at each resize trigger]}), which ``validate_bench.py``
    bands against the paper's ~70% continuity load-factor claim."""
    rng = np.random.RandomState(6)
    payload = {}
    for frac, label in ((0.0, "none"), (1 / 20, "1/20"), (1 / 10, "1/10")):
        store = api.make_store("continuity", table_slots=200, ext_frac=frac)
        table = store.create()
        lfs = []
        next_id = 0
        for resize_round in range(6):
            while True:
                K = ycsb.make_key(np.arange(next_id, next_id + 8))
                V = ycsb.make_value(rng, 8)
                table, res = store.insert(table, K, V)
                okn = np.asarray(res.ok)
                next_id += int(okn.sum())
                if not okn.all():
                    break
            lfs.append(float(store.load_factor(table)))
            store, table = store.resize_cutover(store.begin_resize(table))
        payload[label] = lfs
        rows.append((f"load_factor[{label}]", 0.0,
                     " ".join(f"{x:.2f}" for x in lfs)))
    return payload


def bench_resize(rows, table_slots=2048, batch=256):
    """Online-resize section: steps-per-cutover and the foreground stall
    while a shard splits, incremental vs stop-the-world.

    Every scheme grows a ~0.8-full table through the begin/step/cutover
    triple.  Continuity advances ONE cohort per step with a foreground
    YCSB round (lookup + insert routed by the split tokens) between
    steps; the baselines rehash everything inside their first step — the
    stop-the-world pause.  Also times continuity's own one-shot shim as
    the like-for-like pause.  Returns the ``resize`` payload for the
    BENCH json, which ``validate_bench.py`` gates: the split must be
    genuinely incremental (steps == cohorts > 1) and its worst per-step
    stall must undercut the scheme's own stop-the-world pause."""
    import time
    payload = {}
    for s in SCHEMES:
        rng = np.random.RandomState(8)
        store = api.make_store(s, table_slots=table_slots)
        table = store.create()
        next_id = 0
        while float(store.load_factor(table)) < 0.8:
            K = ycsb.make_key(np.arange(next_id, next_id + batch))
            table, res = store.insert(table, K,
                                      ycsb.make_value(rng, batch))
            next_id += batch
            if not np.asarray(res.ok).all():
                break
        n_items = int(np.asarray(store.stats(table)["count"]))
        incremental = hasattr(store, "resize_write")

        # stop-the-world reference: the whole rehash as ONE pause.  Run
        # twice and keep the second — the first pays jit compilation for
        # the grown shapes, which would flatter the incremental column
        store.resize_cutover(store.begin_resize(table))
        t0 = time.perf_counter()
        _, stw_table = store.resize_cutover(store.begin_resize(table))
        stw_ms = (time.perf_counter() - t0) * 1e3

        # incremental path (one cohort per step, foreground between)
        rs = store.begin_resize(table)
        step_ms, fg_us = [], []
        steps = 0
        probe = ycsb.make_key(rng.randint(0, max(next_id, 1), batch))
        while not rs.done:
            t0 = time.perf_counter()
            rs = store.resize_step(rs, budget=1)
            jax.block_until_ready(rs.new_table)
            step_ms.append((time.perf_counter() - t0) * 1e3)
            steps += 1
            if incremental:     # the stream keeps flowing mid-split
                kin = ycsb.make_key(
                    np.arange(10_000 + steps * 8, 10_008 + steps * 8))
                vin = ycsb.make_value(rng, 8)
                t0 = time.perf_counter()
                lr = store.resize_lookup(rs, probe)
                rs, _ = store.resize_write(rs, "insert", kin, vin)
                jax.block_until_ready((lr.values, rs.new_table))
                fg_us.append((time.perf_counter() - t0) * 1e6 / (batch + 8))
        new_store, new_table = store.resize_cutover(rs)
        got = int(np.asarray(new_store.stats(new_table)["count"]))
        # first step pays jit compilation for the grown shapes; the
        # steady-state stall is what a serving shard would see
        steady = step_ms[1:] or step_ms
        payload[s] = {
            "n_items": n_items,
            "cohorts": steps,
            "steps_per_cutover": steps,
            "incremental_routing": incremental,
            "stw_pause_ms": stw_ms,
            "first_step_ms": step_ms[0],
            "max_step_ms": float(max(steady)),
            "mean_step_ms": float(np.mean(steady)),
            "max_stall_over_stw": float(max(steady)) / max(stw_ms, 1e-9),
            "foreground_p99_us": (float(np.percentile(fg_us, 99))
                                  if fg_us else None),
            "lossless": got >= n_items,
        }
        rows.append((f"resize[{s}]", payload[s]["mean_step_ms"] * 1e3,
                     f"{steps} steps, max stall "
                     f"{payload[s]['max_step_ms']:.1f}ms vs stw "
                     f"{stw_ms:.1f}ms"))
    return payload


def bench_write_batch_sweep(rows, batches=(64, 512, 4096), iters=3):
    """Serial-scan vs wave-vectorized write paths across batch sizes.

    Both paths run through ``repro.api`` — the execution strategy is the
    `ExecPolicy` the store was built with, which is the whole point of the
    policy boundary.  Returns the BENCH_hash.json payload: per (op, path,
    batch) ops/s and the exact PM-write counters. The counters MATCH
    between paths whenever the extension pool is not exhausted mid-batch —
    true for every config in this sweep (the engine is an execution-
    strategy change, not a protocol change; see ``continuity.insert`` for
    the exhaustion caveat).
    """
    from benchmarks.common import timeit
    rng = np.random.RandomState(7)
    sweep = {}
    for B in batches:
        slots = max(4096, 4 * B)
        stores = {
            "serial": api.make_store("continuity", table_slots=slots,
                                     policy=api.ExecPolicy(engine="serial")),
            "wave": api.make_store("continuity", table_slots=slots),
        }
        K = ycsb.make_key(np.arange(B))
        V = ycsb.make_value(rng, B)
        V2 = ycsb.make_value(rng, B)
        base = stores["wave"].create()
        loaded, _ = stores["wave"].insert(base, K, V)  # for update/delete
        for path, st in stores.items():
            cases = {
                "insert": lambda st=st: st.insert(base, K, V),
                "update": lambda st=st: st.update(loaded, K, V2),
                "delete": lambda st=st: st.delete(loaded, K),
            }
            # small batches are dispatch-noise-dominated: take the median
            # over more repeats so the wave>=serial ordering band gates on
            # signal, not scheduler jitter
            it = iters if B > 64 else max(iters, 9)
            for op, fn in cases.items():
                med, (_, res) = timeit(fn, warmup=1, iters=it)
                cell = {"ops_per_s": B / med, "us_per_op": med / B * 1e6,
                        "pm_writes": int(res.ledger.pm_writes),
                        "succeeded": int(np.asarray(res.ok).sum())}
                sweep.setdefault(op, {}).setdefault(path, {})[str(B)] = cell
                rows.append((f"{op}_{path}_b{B}[continuity]", med / B * 1e6,
                             f"{B/med:.0f} ops/s "
                             f"pm={int(res.ledger.pm_writes)}"))
    speedups = {
        f"{op}_b{B}": (sweep[op]["wave"][str(B)]["ops_per_s"]
                       / sweep[op]["serial"][str(B)]["ops_per_s"])
        for op in sweep for B in batches}
    return {"write_batch_sweep": sweep, "wave_over_serial_speedup": speedups}


def run(rows):
    bench_pm_writes(rows)
    bench_access_amp(rows)
    bench_search_micro(rows)
    bench_update_micro(rows)
    bench_ycsb(rows)
    bench_load_factor(rows)

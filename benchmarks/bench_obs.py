"""Obs benchmark section: headline numbers FROM the telemetry sketches.

Two drills inside one `repro.obs.scope`, emitted to the BENCH json under
``obs`` and gated by `validate_bench._check_obs`:

  * **e2e trio** — `repro.rdma.sim.run_ycsb` at its full default sizes
    for continuity/level/pfarm x YCSB-A/C.  The reported p50/p99 are
    read back OUT of the ``e2e.op_us`` registry histograms (the
    op=read/write lanes merged), not from a side list — so the bench
    artifact and a traced `cluster/sim.py --trace` export derive their
    percentiles from the same buckets and cannot disagree.  The gate:
    p50 ranks continuity <= level <= pfarm on the write-mixed YCSB-A
    (the paper's ~1.7x latency ordering) and continuity <= pfarm on
    the read-only C.
  * **SLO drill** — a single-shard continuity `ClusterStore` is filled
    past the resize trigger and drained by budget-2 maintenance steps.
    Every advancing step is priced against `DEFAULT_STEP_SLO_US`; at
    the default budget the incremental split must finish with ZERO
    ``maintenance.slo_burn`` counts — the non-blocking-resize claim
    restated as an SLO.
"""

from __future__ import annotations

import numpy as np

from repro import obs
from repro.cluster.store import ClusterStore, DEFAULT_STEP_SLO_US
from repro.data import ycsb

E2E_SCHEMES = ("continuity", "level", "pfarm")
E2E_WORKLOADS = ("A", "C")
MAX_DRILL_ROUNDS = 400


def _slo_drill(reg: obs.MetricsRegistry, seed: int = 0) -> dict:
    """Fill one shard past the trigger, drain it with budget-2 steps."""
    cluster = ClusterStore("continuity", nodes=1, replicas=1,
                           node_slots=512)
    rng = np.random.RandomState(seed)
    node = next(iter(cluster._nodes.values()))
    next_id = 0
    # fill by the shard's OWN load factor (the stash tier counts toward
    # capacity, so a fixed record count undershoots the 0.85 trigger)
    while float(node.store.load_factor(node.table)) <= 0.86 \
            and next_id < 4096:
        ids = np.arange(next_id, next_id + 64)
        next_id += 64
        cluster.insert(ycsb.make_key(ids), ycsb.make_value(rng, len(ids)))
    rounds = 0
    while rounds < MAX_DRILL_ROUNDS:
        rounds += 1
        if not cluster.maintenance_step(budget=2):
            break
    m = cluster.maintenance
    worst = reg.gauge("maintenance.step_us", node="pm0").max
    return {
        "steps": m["steps"], "cohorts_moved": m["cohorts_moved"],
        "resizes_begun": m["resizes_begun"], "cutovers": m["cutovers"],
        "slo_burns": m["slo_burns"], "slo_us": DEFAULT_STEP_SLO_US,
        "worst_step_us": worst if worst > float("-inf") else 0.0,
        "drill_rounds": rounds,
    }


def run(rows, scale: str = "full") -> dict:
    """The ``obs`` BENCH section.  The trio always runs at run_ycsb's
    full default sizes — small tables let the probe baselines hit on
    their first probe, which inverts the ordering the section exists to
    report (``scale`` is accepted for harness symmetry)."""
    with obs.scope() as (_, reg):
        from repro.rdma.sim import run_ycsb
        for sch in E2E_SCHEMES:
            for wl in E2E_WORKLOADS:
                run_ycsb(sch, wl, seed=0)
        e2e: dict = {}
        for wl in E2E_WORKLOADS:
            for sch in E2E_SCHEMES:
                merged = obs.Histogram()
                for op in ("read", "write"):
                    merged.merge(reg.histogram("e2e.op_us", op=op,
                                               scheme=sch, workload=wl))
                e2e.setdefault(wl, {})[sch] = {
                    "p50_us": merged.percentile(50),
                    "p99_us": merged.percentile(99),
                }
        slo = _slo_drill(reg)
    for wl in E2E_WORKLOADS:
        base = e2e[wl]["continuity"]["p50_us"]
        rows.append((f"obs_e2e[{wl}]", base,
                     " ".join(f"{s}={e2e[wl][s]['p50_us']:.2f}us"
                              f"({e2e[wl][s]['p50_us'] / base:.2f}x)"
                              for s in E2E_SCHEMES[1:])))
    rows.append(("obs_slo_drill", slo["worst_step_us"],
                 f"steps={slo['steps']} burns={slo['slo_burns']} "
                 f"slo={slo['slo_us']:.0f}us"))
    return {"e2e": e2e, "slo": slo}

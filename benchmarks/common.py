"""Shared benchmark utilities: timing + scheme-uniform op drivers."""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time of ``fn(*args)`` (jitted fns block on result)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


class SchemeDriver:
    """Uniform (insert/delete/update/lookup) driver over the three schemes.

    ``continuity`` runs the wave-vectorized mutation engine;
    ``continuity_serial`` pins the reference ``lax.scan`` write paths (the
    before/after pair for the EXPERIMENTS.md §Perf write-batch sweep).
    """

    def __init__(self, name: str, table_slots: int = 4096):
        import repro.core.continuity as ch
        import repro.core.level as lv
        import repro.core.pfarm as pf
        self.name = name
        self.serial = name.endswith("_serial")
        if name in ("continuity", "continuity_serial"):
            # slots = pairs * 20
            pairs = table_slots // 20
            self.cfg = ch.ContinuityConfig(num_buckets=2 * pairs)
            self.mod = ch
        elif name == "level":
            # slots = 1.5 * num_top * bucket_slots
            top = int(table_slots / 1.5 / 4)
            self.cfg = lv.LevelConfig(num_top=top + top % 2)
            self.mod = lv
        elif name == "pfarm":
            nb = int(table_slots / 1.25 / 4)
            self.cfg = pf.PFarmConfig(num_buckets=nb)
            self.mod = pf
        else:
            raise ValueError(name)
        self.table = self.mod.create(self.cfg)

    def _op(self, op: str):
        if self.serial:
            return getattr(self.mod, op + "_serial")
        return getattr(self.mod, op)

    def insert(self, keys, vals):
        self.table, ok, ctr = self._op("insert")(self.cfg, self.table, keys, vals)
        return ok, ctr

    def update(self, keys, vals):
        self.table, ok, ctr = self._op("update")(self.cfg, self.table, keys, vals)
        return ok, ctr

    def delete(self, keys):
        self.table, ok, ctr = self._op("delete")(self.cfg, self.table, keys)
        return ok, ctr

    def lookup(self, keys):
        res = self.mod.lookup(self.cfg, self.table, keys)
        ctr = self.mod.read_counters(self.cfg, res) \
            if hasattr(self.mod, "read_counters") else None
        return res, ctr

    def lookup_fn(self):
        """Jit-stable lookup callable for timing."""
        mod, cfg = self.mod, self.cfg
        return lambda table, keys: mod.lookup(cfg, table, keys)

"""Shared benchmark utilities: timing + the legacy scheme-driver shim.

``SchemeDriver`` predates ``repro.api`` and is now a thin shim over it —
kept so existing bench scripts and notebooks keep running.  New code
should use the registry directly:

    from repro import api
    store = api.make_store("continuity", table_slots=4096)

(see README.md "Migrating to repro.api" for the full old->new mapping).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import api


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time of ``fn(*args)`` (jitted fns block on result)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


class SchemeDriver:
    """DEPRECATED shim: uniform op driver over the registered schemes.

    ``<name>_serial`` pins ``ExecPolicy(engine="serial")`` (the before/
    after pair for the EXPERIMENTS.md §Perf write-batch sweep).  All
    behaviour lives in `repro.api`; this class only carries mutable table
    state between calls the way the old driver did.
    """

    def __init__(self, name: str, table_slots: int = 4096):
        self.name = name
        scheme = name[:-len("_serial")] if name.endswith("_serial") else name
        policy = (api.ExecPolicy(engine="serial")
                  if name.endswith("_serial") else api.ExecPolicy())
        self.store = api.make_store(scheme, table_slots=table_slots,
                                    policy=policy)
        self.cfg = self.store.cfg
        self.table = self.store.create()

    def insert(self, keys, vals):
        self.table, res = self.store.insert(self.table, keys, vals)
        return res.ok, res.ledger

    def update(self, keys, vals):
        self.table, res = self.store.update(self.table, keys, vals)
        return res.ok, res.ledger

    def delete(self, keys):
        self.table, res = self.store.delete(self.table, keys)
        return res.ok, res.ledger

    def lookup(self, keys):
        res = self.store.lookup(self.table, keys)
        return res, res.ledger

    def lookup_fn(self):
        """Jit-stable lookup callable for timing."""
        store = self.store
        return lambda table, keys: store.lookup(table, keys)

"""Crash-consistency benchmark: recovery work per scheme.

Runs the `repro.consistency` crash/scheme matrix (every scheme x
insert/update/delete, every trace prefix + torn split injected) and
reports what each scheme's RESTART costs — the paper's consistency
contrast as numbers instead of prose:

  * continuity — indicator words scanned, zero log records, zero repairs;
  * level      — token words + undo-log rollbacks + duplicate-scan slots;
  * pfarm      — token words + RECIPE redo-log replays (every op logged);
  * dense      — live bits only; its in-place update is the documented
    torn-write hazard (violations are EXPECTED there and only there).

Rows land in the CSV; the structured per-cell summaries go into the
BENCH json under ``crash_consistency`` (schema-checked by
``validate_bench.py``, which requires every cell's ``ok`` flag — the
same gate the crash-matrix CI job enforces).
"""

from __future__ import annotations

from repro.consistency import matrix as cmatrix


def run(rows):
    payload = {}
    for r in cmatrix.run_matrix():
        s = cmatrix.summarize(r)
        rec = s["recovery"]
        rows.append((
            f"crash_recovery[{r.scheme}-{r.op}]", 0.0,
            f"crash={s['crash_points']} torn={s['torn_points']} "
            f"viol={s['violations']} log_used={s['log_used_points']} "
            f"words={rec['commit_words_scanned']} "
            f"repairs={rec['repairs']} dup={rec['duplicates_cleared']} "
            f"{'OK' if s['ok'] else 'UNEXPECTED'}"))
        payload[f"{r.scheme}.{r.op}"] = s
    return payload

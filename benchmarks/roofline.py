"""Roofline table builder: reads experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (and prints CSV rows for benchmarks.run)."""

from __future__ import annotations

import glob
import json
import os

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def load_records(dirpath="experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def table(dirpath="experiments/dryrun", mesh="16x16"):
    """Markdown §Roofline table for one mesh."""
    lines = [
        "| arch | shape | dominant | compute s | memory s | collective s | "
        "peak GB/dev | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(dirpath):
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        ro = r["roofline"]
        peak = r.get("memory", {}).get("peak_estimate_per_device", 0) / 1e9
        ratio = r.get("useful_flops_ratio", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['dominant'][:-2]} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} "
            f"| {ro['collective_s']:.2e} | {peak:.1f} | {ratio:.2f} |")
    return "\n".join(lines)


def run(rows, dirpath="experiments/dryrun"):
    for r in load_records(dirpath):
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        step_s = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append((f"roofline[{r['arch']}|{r['shape']}|{r['mesh']}]",
                     step_s * 1e6,
                     f"dom={ro['dominant'][:-2]} "
                     f"useful={r.get('useful_flops_ratio', 0):.2f}"))

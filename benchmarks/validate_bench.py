"""Schema + value checks for the BENCH json perf artifacts.

The artifact is the cross-PR perf trajectory (EXPERIMENTS.md §Perf), so CI
guards its shape: a structural schema (hand-rolled — no jsonschema dep in
the container) over the payload ``benchmarks/run.py`` emits:

    {
      "write_batch_sweep": {<op>: {<path>: {<batch>: CELL}}},
      "wave_over_serial_speedup": {"<op>_b<batch>": float},
      "table1": {<scheme>: {"insert"|"update"|"delete": float}},   # optional
      "crash_consistency": {"<scheme>.<op>": {..., "ok": bool}},    # optional
      "end_to_end": {<scheme>: {<workload>: E2E_CELL}},             # optional
      "load_factor": {<policy>: [float, ...]},                      # optional
      "resize": {<scheme>: {"steps_per_cutover": int, ...}},        # optional
      "cluster": {"cells": ..., "durability": ..., "migration": ...}, # optional
      "cache": {"doorbell_reduction": ..., "hit_rate": ...,
                "stale_served": 0, "uncached": ..., "cached": ...},   # optional
      "obs": {"e2e": {<wl>: {<scheme>: {"p50_us", "p99_us"}}},
              "slo": {"steps", "slo_burns", "worst_step_us", ...}}    # optional
    }

    CELL = {"ops_per_s": float > 0, "us_per_op": float > 0,
            "pm_writes": int >= 0, "succeeded": int >= 0}
    E2E_CELL = {"ops_per_s": float > 0, "p50_us": float > 0,
                "p99_us": float >= p50_us, ...}

``--assert-table1`` additionally checks the ``table1`` VALUES against the
paper (continuity 2/2/1, pfarm 5/5/5, level and dense bands) — the CI
Table I gate, reading structured JSON instead of grepping CSV rows.
``crash_consistency`` cells, when present, must all report ``ok``.
``end_to_end``, when present, must satisfy the paper's relative-ordering
band on the read-heavy mixes: continuity throughput >= level >= pfarm on
BOTH YCSB-C and YCSB-B — the transport model is deterministic, so the
ordering is a hard gate, not a tolerance check.
``load_factor``, when present, is banded against the continuity
load-factor claim: with the fingerprint/stash tier every policy triggers
its FIRST resize at >= 85% occupancy (the plain layout's floor was the
paper's ~70%), and the 1/10-extension policy keeps min >= 85% / mean
>= 90% across all resize rounds.
``resize``, when present, gates the online-resize claim: at least one
scheme routes traffic mid-split, every scheme's rehash is lossless, and
an incremental scheme must split in > 1 steps with its worst per-step
stall under RESIZE_MAX_STALL_FRAC of its own stop-the-world pause and
its mid-split foreground p99 bounded.
``cluster``, when present, gates the cluster acceptance criteria: zero
committed-op loss per cell, rebalance within 1/N + 5%, failover
detected, the fenced durability drill lossless AND its unfenced negative
control caught losing acked ops, the migration crash sweep clean.
``cache``, when present, gates the client-cache fan-in criteria: >= 2x
read-doorbell reduction, cached p99 <= uncached p99, hit rate >= the
honesty floor, ``stale_served`` exactly 0, and zero wrong reads on
both passes.
``obs``, when present, gates the telemetry section: the e2e p50s read
back out of the metric sketches must rank continuity <= level <= pfarm
on YCSB-A (continuity <= pfarm on the read-only C), and the
maintenance-SLO drill must report >= 1 resize step with exactly zero
SLO burns at the default budget.

The script also recognises a ``repro.chaos.matrix --json`` artifact
(top-level ``cells``/``totals``/``gates``) and gates it on the chaos
invariants: every cell ok, ``committed_lost == 0`` grid-wide, fencing
completeness (``stale_acks_detected == stale_acks_injected``), every
transport retry path exercised, and both degradation paths observed.

Usage: python benchmarks/validate_bench.py [BENCH.json] [--assert-table1]
Exit 0 on a valid artifact; exits 1 with the offending path else.
"""

from __future__ import annotations

import argparse
import json
import sys

OPS = ("insert", "update", "delete")
PATHS = ("serial", "wave")

# hard ordering band on the sweep: the wave engine must be at least as
# fast as the serial oracle on EVERY op x batch cell (the fused
# update/delete passes killed the last losing cells — keep them dead)
WAVE_MIN_SPEEDUP = 1.0

# scheme -> {op: (lo, hi)} inclusive acceptance band (paper Table I; level
# insert/update have path-dependent ranges, dense is the repo's reference)
TABLE1_BANDS = {
    "continuity": {"insert": (2.0, 2.0), "update": (2.0, 2.0),
                   "delete": (1.0, 1.0)},
    "pfarm": {"insert": (5.0, 5.0), "update": (5.0, 5.0),
              "delete": (5.0, 5.0)},
    "level": {"insert": (2.0, 2.2), "update": (2.0, 5.0),
              "delete": (1.0, 1.0)},
    "dense": {"insert": (2.0, 2.0), "update": (1.0, 1.0),
              "delete": (1.0, 1.0)},
}
TABLE1_REQUIRED = ("continuity", "pfarm")    # the paper's headline contrast
CELL_FIELDS = {
    "ops_per_s": (float, int),
    "us_per_op": (float, int),
    "pm_writes": (int,),
    "succeeded": (int,),
}


class SchemaError(ValueError):
    pass


def _fail(path: str, msg: str):
    raise SchemaError(f"{path}: {msg}")


def _check_cell(cell, path: str) -> None:
    if not isinstance(cell, dict):
        _fail(path, f"expected object, got {type(cell).__name__}")
    for field, types in CELL_FIELDS.items():
        if field not in cell:
            _fail(path, f"missing field {field!r}")
        v = cell[field]
        if not isinstance(v, types) or isinstance(v, bool):
            _fail(f"{path}.{field}", f"expected {types}, got {v!r}")
        if v < 0:
            _fail(f"{path}.{field}", f"negative value {v!r}")
    for field in ("ops_per_s", "us_per_op"):
        if not cell[field] > 0:
            _fail(f"{path}.{field}", f"must be > 0, got {cell[field]!r}")
    extra = set(cell) - set(CELL_FIELDS)
    if extra:
        _fail(path, f"unexpected fields {sorted(extra)}")


def _check_table1(t1) -> None:
    if not isinstance(t1, dict) or not t1:
        _fail("table1", "must be a non-empty object")
    for scheme, cells in t1.items():
        if not isinstance(cells, dict):
            _fail(f"table1.{scheme}",
                  f"expected object, got {type(cells).__name__}")
        if set(cells) != set(OPS):
            _fail(f"table1.{scheme}", f"ops must be exactly {OPS}, "
                                      f"got {sorted(cells)}")
        for op, v in cells.items():
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                _fail(f"table1.{scheme}.{op}",
                      f"expected non-negative number, got {v!r}")


E2E_SCHEMES = ("continuity", "level", "pfarm")   # the ordering-band trio
E2E_FIELDS = ("ops_per_s", "p50_us", "p99_us")


def _check_end_to_end(e2e) -> None:
    if not isinstance(e2e, dict) or not e2e:
        _fail("end_to_end", "must be a non-empty object")
    for scheme, cells in e2e.items():
        if not isinstance(cells, dict) or not cells:
            _fail(f"end_to_end.{scheme}", "must be a non-empty object")
        for wl, cell in cells.items():
            here = f"end_to_end.{scheme}.{wl}"
            if not isinstance(cell, dict):
                _fail(here, f"expected object, got {type(cell).__name__}")
            for field in E2E_FIELDS:
                v = cell.get(field)
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or v <= 0:
                    _fail(f"{here}.{field}",
                          f"expected positive number, got {v!r}")
            if cell["p99_us"] < cell["p50_us"]:
                _fail(here, f"p99 {cell['p99_us']!r} < p50 "
                            f"{cell['p50_us']!r}")
    # relative-ordering band (paper Figs 4-10): read-heavy mixes must rank
    # continuity >= level >= pfarm in simulated throughput
    missing = set(E2E_SCHEMES) - set(e2e)
    if missing:
        _fail("end_to_end", f"ordering-band schemes missing: "
                            f"{sorted(missing)}")
    for wl, chain in (("C", E2E_SCHEMES), ("B", E2E_SCHEMES)):
        tputs = []
        for s in chain:
            if wl not in e2e[s]:
                _fail(f"end_to_end.{s}", f"workload {wl!r} missing")
            tputs.append(e2e[s][wl]["ops_per_s"])
        for a, b, sa, sb in zip(tputs, tputs[1:], chain, chain[1:]):
            if a < b:
                _fail(f"end_to_end.{sa}.{wl}",
                      f"ordering band violated: {sa} {a:.0f} ops/s < "
                      f"{sb} {b:.0f} ops/s")


# paper Fig 18 / §V, lifted by the fingerprint/stash tier: the plain
# layout first-triggered around the paper's ~70%; with 2-bit slot
# fingerprints pre-filtering probes and a 1/8 stash absorbing overflow,
# continuity sustains ~94% occupancy before resizing (EXPERIMENTS.md)
LF_FIRST_TRIGGER_MIN = 0.85
LF_BEST_POLICY = "1/10"
LF_BEST_MIN, LF_BEST_MEAN = 0.85, 0.90


def _check_load_factor(lf) -> None:
    if not isinstance(lf, dict) or not lf:
        _fail("load_factor", "must be a non-empty object")
    for policy, lfs in lf.items():
        here = f"load_factor.{policy}"
        if not isinstance(lfs, list) or not lfs:
            _fail(here, "must be a non-empty list")
        for i, v in enumerate(lfs):
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or not 0.0 < v <= 1.0:
                _fail(f"{here}[{i}]", f"expected load factor in (0, 1], "
                                      f"got {v!r}")
        if lfs[0] < LF_FIRST_TRIGGER_MIN:
            _fail(here, f"first resize triggered at {lfs[0]:.2f} < "
                        f"{LF_FIRST_TRIGGER_MIN} — the paper's ~70% "
                        f"load-factor claim")
    if LF_BEST_POLICY in lf:
        lfs = lf[LF_BEST_POLICY]
        if min(lfs) < LF_BEST_MIN or sum(lfs) / len(lfs) < LF_BEST_MEAN:
            _fail(f"load_factor.{LF_BEST_POLICY}",
                  f"min {min(lfs):.2f} / mean {sum(lfs)/len(lfs):.2f} "
                  f"below the [{LF_BEST_MIN}, {LF_BEST_MEAN}] band")


# online-resize gates: the incremental split must be genuinely
# incremental (many steps, each a small bounded stall) while the
# baselines' one-shot rehash IS the stop-the-world pause it undercuts
RESIZE_MAX_STALL_FRAC = 0.5      # worst step vs own stop-the-world pause
RESIZE_FG_P99_MAX_US = 20_000.0  # mid-split foreground p99 ceiling


def _check_resize(rz) -> None:
    if not isinstance(rz, dict) or not rz:
        _fail("resize", "must be a non-empty object")
    any_incremental = False
    for scheme, cell in rz.items():
        here = f"resize.{scheme}"
        if not isinstance(cell, dict):
            _fail(here, f"expected object, got {type(cell).__name__}")
        for field in ("steps_per_cutover", "max_step_ms", "stw_pause_ms",
                      "max_stall_over_stw", "n_items", "lossless",
                      "incremental_routing"):
            if field not in cell:
                _fail(here, f"missing {field!r}")
        if cell["lossless"] is not True:
            _fail(here, "rehash lost items")
        if not cell["incremental_routing"]:
            continue
        any_incremental = True
        if cell["steps_per_cutover"] <= 1:
            _fail(here, "claimed incremental but cut over in one step")
        if cell["max_stall_over_stw"] > RESIZE_MAX_STALL_FRAC:
            _fail(here, f"worst per-step stall is "
                        f"{cell['max_stall_over_stw']:.2f}x the stop-the-"
                        f"world pause (> {RESIZE_MAX_STALL_FRAC}) — the "
                        f"split is not meaningfully online")
        p99 = cell.get("foreground_p99_us")
        if not isinstance(p99, (int, float)) or isinstance(p99, bool) \
                or not 0 < p99 <= RESIZE_FG_P99_MAX_US:
            _fail(here, f"mid-split foreground p99 {p99!r} outside "
                        f"(0, {RESIZE_FG_P99_MAX_US}]us")
    if not any_incremental:
        _fail("resize", "no scheme routes traffic mid-split")


def _check_cluster(cl) -> None:
    if not isinstance(cl, dict):
        _fail("cluster", f"expected object, got {type(cl).__name__}")
    for part in ("cells", "durability", "migration"):
        if not isinstance(cl.get(part), dict):
            _fail("cluster", f"missing or non-object {part!r}")
    for scheme, by_wl in cl["cells"].items():
        if not isinstance(by_wl, dict):
            _fail(f"cluster.cells.{scheme}",
                  f"expected object, got {type(by_wl).__name__}")
        for wl, cell in by_wl.items():
            here = f"cluster.cells.{scheme}.{wl}"
            if not isinstance(cell, dict):
                _fail(here, f"expected object, got {type(cell).__name__}")
            for field in ("ops_per_s", "p50_us", "p99_us"):
                v = cell.get(field)
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or v <= 0:
                    _fail(f"{here}.{field}",
                          f"expected positive number, got {v!r}")
            if cell.get("committed_lost") != 0:
                _fail(here, f"lost {cell.get('committed_lost')!r} committed "
                            f"ops across failover (must be 0)")
            if cell.get("rebalance_within_bound") is not True:
                _fail(here, "join rebalance moved more than 1/N + 5% "
                            "of resident keys")
            if cell.get("failover_detected") is not True:
                _fail(here, "primary kill was never detected/promoted")
    d = cl["durability"]
    if d.get("fenced", {}).get("lost_committed") != 0:
        _fail("cluster.durability.fenced",
              "commit-fenced replication lost acked ops")
    if not d.get("unfenced", {}).get("lost_committed"):
        _fail("cluster.durability.unfenced",
              "negative control lost nothing — the checker cannot see loss")
    if d.get("ok") is not True:
        _fail("cluster.durability", "drill reported not ok")
    if cl["migration"].get("ok") is not True:
        _fail("cluster.migration", "migration crash sweep reported "
                                   "violations")


# the chaos gates that must hold on EVERY matrix run (repro.chaos.matrix):
# zero committed loss anywhere, and fencing completeness — every stale
# ack a partitioned ex-primary took was detected and discarded
CHAOS_GATES = ("all_cells_ok", "zero_committed_loss",
               "stale_acks_all_detected", "retry_path_drop",
               "retry_path_backoff", "retry_path_duplicate",
               "retry_path_reorder", "retry_path_give_up",
               "degradation_read_only", "degradation_lag_redirect")
CHAOS_CELL_FIELDS = ("scenario", "scheme", "workload", "seed", "checks",
                     "ok", "committed_lost", "chaos", "wire")


def is_chaos_artifact(payload) -> bool:
    """A `repro.chaos.matrix --json` artifact (vs a BENCH sweep)."""
    return isinstance(payload, dict) and "gates" in payload \
        and "cells" in payload and "write_batch_sweep" not in payload


def _check_chaos(payload) -> None:
    """Schema + gate check of the seeded chaos-matrix artifact."""
    for field in ("seed", "scheme", "profile", "grid_cells", "cells",
                  "totals", "gates", "ok"):
        if field not in payload:
            _fail("$", f"chaos artifact missing {field!r}")
    cells = payload["cells"]
    if not isinstance(cells, list) or not cells:
        _fail("cells", "must be a non-empty list")
    if payload["grid_cells"] != len(cells):
        _fail("grid_cells", f"{payload['grid_cells']!r} != {len(cells)} "
                            f"cells present")
    for i, cell in enumerate(cells):
        here = f"cells[{i}]"
        if not isinstance(cell, dict):
            _fail(here, f"expected object, got {type(cell).__name__}")
        for field in CHAOS_CELL_FIELDS:
            if field not in cell:
                _fail(here, f"missing field {field!r}")
        if not isinstance(cell["checks"], dict) or not cell["checks"]:
            _fail(f"{here}.checks", "must be a non-empty object")
        for name, v in cell["checks"].items():
            if not isinstance(v, bool):
                _fail(f"{here}.checks.{name}", f"expected bool, got {v!r}")
        if cell["ok"] is not all(cell["checks"].values()):
            _fail(f"{here}.ok", "inconsistent with the cell's checks")
        if not cell["ok"]:
            bad = [k for k, v in cell["checks"].items() if not v]
            _fail(here, f"{cell['scenario']} x {cell['workload']} "
                        f"(seed {cell['seed']}) failed {bad}")
        if cell["committed_lost"] != 0:
            _fail(f"{here}.committed_lost",
                  f"lost {cell['committed_lost']!r} acked ops (must be 0)")
    totals, gates = payload["totals"], payload["gates"]
    missing = set(CHAOS_GATES) - set(gates)
    if missing:
        _fail("gates", f"missing gates {sorted(missing)}")
    if totals.get("committed_lost") != 0:
        _fail("totals.committed_lost",
              f"{totals.get('committed_lost')!r} acked ops lost across the "
              f"grid (must be 0)")
    inj = totals.get("stale_acks_injected")
    det = totals.get("stale_acks_detected")
    if not (isinstance(inj, int) and inj > 0 and det == inj):
        _fail("totals", f"fencing incomplete: detected {det!r} of "
                        f"{inj!r} injected stale acks")
    for gate in CHAOS_GATES:
        if gates[gate] is not True:
            _fail(f"gates.{gate}", "gate did not hold")
    if payload["ok"] is not True:
        _fail("ok", "artifact reports not ok")


# the cache fan-in gates (shared floors with repro.cache.fanin.GATES —
# kept literal here so the validator has no runtime imports)
CACHE_DOORBELL_FLOOR = 2.0
CACHE_HIT_FLOOR = 0.45
CACHE_PASS_FIELDS = ("read_doorbells", "read_bytes", "p50_us", "p99_us",
                     "wrong_reads", "reads_served")


def _check_cache(ca) -> None:
    if not isinstance(ca, dict):
        _fail("cache", f"expected object, got {type(ca).__name__}")
    for part in ("uncached", "cached"):
        cell = ca.get(part)
        if not isinstance(cell, dict):
            _fail(f"cache.{part}", "missing or non-object")
        for field in CACHE_PASS_FIELDS:
            v = cell.get(field)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v < 0:
                _fail(f"cache.{part}.{field}",
                      f"expected non-negative number, got {v!r}")
        if cell["wrong_reads"] != 0:
            _fail(f"cache.{part}.wrong_reads",
                  f"{cell['wrong_reads']!r} reads served a value that was "
                  f"never the committed one (must be 0)")
        if cell["p99_us"] < cell["p50_us"]:
            _fail(f"cache.{part}", f"p99 {cell['p99_us']!r} < p50 "
                                   f"{cell['p50_us']!r}")
    if ca.get("stale_served") != 0:
        _fail("cache.stale_served",
              f"{ca.get('stale_served')!r} cached reads served a "
              f"pre-mutation value (must be exactly 0)")
    db = ca.get("doorbell_reduction")
    if not isinstance(db, (int, float)) or db < CACHE_DOORBELL_FLOOR:
        _fail("cache.doorbell_reduction",
              f"{db!r} below the {CACHE_DOORBELL_FLOOR}x floor")
    hr = ca.get("hit_rate")
    if not isinstance(hr, (int, float)) or not CACHE_HIT_FLOOR <= hr <= 1.0:
        _fail("cache.hit_rate",
              f"{hr!r} outside [{CACHE_HIT_FLOOR}, 1.0]")
    if ca["cached"]["p99_us"] > ca["uncached"]["p99_us"]:
        _fail("cache.cached.p99_us",
              f"cached tail {ca['cached']['p99_us']!r} > uncached "
              f"{ca['uncached']['p99_us']!r} — the fan-in collapse "
              f"did not happen")
    gf = ca.get("gate_failures")
    if gf:
        _fail("cache.gate_failures", f"fan-in run reported {gf!r}")


# the obs-section gates: the telemetry sketches must reproduce the same
# relative ordering the raw end_to_end section bands — full p50 chain
# continuity <= level <= pfarm on the write-mixed YCSB-A (the paper's
# ~1.7x latency ordering), headline contrast continuity <= pfarm on the
# read-only C (level's shorter probe chains legitimately undercut
# continuity's read p50 there, as in the committed end_to_end artifact)
# — and the maintenance-SLO drill must finish with ZERO burned steps
OBS_SCHEMES = ("continuity", "level", "pfarm")
OBS_SLO_FIELDS = ("steps", "cohorts_moved", "resizes_begun", "cutovers",
                  "slo_burns", "slo_us", "worst_step_us")


def _check_obs(ob) -> None:
    if not isinstance(ob, dict):
        _fail("obs", f"expected object, got {type(ob).__name__}")
    e2e = ob.get("e2e")
    if not isinstance(e2e, dict) or not e2e:
        _fail("obs.e2e", "missing or empty")
    for wl, by_s in e2e.items():
        missing = set(OBS_SCHEMES) - set(by_s)
        if missing:
            _fail(f"obs.e2e.{wl}", f"schemes missing: {sorted(missing)}")
        for s, cell in by_s.items():
            here = f"obs.e2e.{wl}.{s}"
            for field in ("p50_us", "p99_us"):
                v = cell.get(field)
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or v <= 0:
                    _fail(f"{here}.{field}",
                          f"expected positive number, got {v!r}")
            if cell["p99_us"] < cell["p50_us"]:
                _fail(here, f"p99 {cell['p99_us']!r} < p50 "
                            f"{cell['p50_us']!r}")
        names = OBS_SCHEMES if wl == "A" else ("continuity", "pfarm")
        chain = [(s, by_s[s]["p50_us"]) for s in names]
        for (sa, a), (sb, b) in zip(chain, chain[1:]):
            if a > b * (1 + 1e-9):
                _fail(f"obs.e2e.{wl}",
                      f"p50 ordering violated: {sa} {a:.2f}us > "
                      f"{sb} {b:.2f}us")
    slo = ob.get("slo")
    if not isinstance(slo, dict):
        _fail("obs.slo", "missing or non-object")
    for field in OBS_SLO_FIELDS:
        v = slo.get(field)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            _fail(f"obs.slo.{field}",
                  f"expected non-negative number, got {v!r}")
    if slo["steps"] < 1:
        _fail("obs.slo.steps", "the drill never advanced a resize step")
    if slo["slo_burns"] != 0:
        _fail("obs.slo.slo_burns",
              f"{slo['slo_burns']!r} maintenance steps burned the "
              f"{slo['slo_us']!r}us SLO at default budget (must be 0)")


def _check_crash(cc) -> None:
    if not isinstance(cc, dict) or not cc:
        _fail("crash_consistency", "must be a non-empty object")
    for cell, s in cc.items():
        if not isinstance(s, dict) or "ok" not in s:
            _fail(f"crash_consistency.{cell}", "missing 'ok' flag")
        if s["ok"] is not True:
            _fail(f"crash_consistency.{cell}",
                  "cell did not match its crash-matrix expectation")


def assert_table1(payload: dict) -> None:
    """Check the paper's Table I values from the structured payload."""
    if "table1" not in payload:
        _fail("table1", "missing (run with --sections pm_writes)")
    _check_table1(payload["table1"])
    t1 = payload["table1"]
    missing = set(TABLE1_REQUIRED) - set(t1)
    if missing:
        _fail("table1", f"required schemes missing: {sorted(missing)}")
    for scheme, cells in t1.items():
        bands = TABLE1_BANDS.get(scheme)
        if bands is None:
            continue
        for op, (lo, hi) in bands.items():
            v = cells[op]
            if not lo - 1e-9 <= v <= hi + 1e-9:
                _fail(f"table1.{scheme}.{op}",
                      f"{v!r} outside the paper band [{lo}, {hi}]")


def validate(payload: dict) -> None:
    """Raise `SchemaError` unless ``payload`` is a valid sweep artifact."""
    if not isinstance(payload, dict):
        _fail("$", "top level must be an object")
    missing = {"write_batch_sweep", "wave_over_serial_speedup"} - set(payload)
    if missing:
        _fail("$", f"missing keys {sorted(missing)}")
    if "table1" in payload:
        _check_table1(payload["table1"])
    if "crash_consistency" in payload:
        _check_crash(payload["crash_consistency"])
    if "end_to_end" in payload:
        _check_end_to_end(payload["end_to_end"])
    if "load_factor" in payload:
        _check_load_factor(payload["load_factor"])
    if "resize" in payload:
        _check_resize(payload["resize"])
    if "cluster" in payload:
        _check_cluster(payload["cluster"])
    if "cache" in payload:
        _check_cache(payload["cache"])
    if "obs" in payload:
        _check_obs(payload["obs"])

    sweep = payload["write_batch_sweep"]
    if set(sweep) - set(OPS) or not sweep:
        _fail("write_batch_sweep", f"ops must be a subset of {OPS}, "
                                   f"got {sorted(sweep)}")
    batches = None
    for op, by_path in sweep.items():
        if set(by_path) != set(PATHS):
            _fail(f"write_batch_sweep.{op}",
                  f"paths must be exactly {PATHS}, got {sorted(by_path)}")
        for path, by_batch in by_path.items():
            here = f"write_batch_sweep.{op}.{path}"
            if not by_batch:
                _fail(here, "no batch cells")
            for b, cell in by_batch.items():
                if not b.isdigit() or int(b) <= 0:
                    _fail(here, f"batch key {b!r} is not a positive int")
                _check_cell(cell, f"{here}.{b}")
            keys = set(by_batch)
            if batches is None:
                batches = keys
            elif keys != batches:
                _fail(here, f"inconsistent batch set {sorted(keys)} "
                            f"vs {sorted(batches)}")

    speed = payload["wave_over_serial_speedup"]
    want = {f"{op}_b{b}" for op in sweep for b in batches}
    if set(speed) != want:
        _fail("wave_over_serial_speedup",
              f"keys {sorted(set(speed) ^ want)} mismatch the sweep grid")
    for k, v in speed.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            _fail(f"wave_over_serial_speedup.{k}",
                  f"expected positive number, got {v!r}")
        # the ordering band (ISSUE 9): with the fused single-pass
        # update/delete there is no op x batch cell left where the wave
        # engine loses to the serial scan — wave >= serial EVERYWHERE
        if v < WAVE_MIN_SPEEDUP:
            _fail(f"wave_over_serial_speedup.{k}",
                  f"wave engine slower than serial ({v:.3f}x < "
                  f"{WAVE_MIN_SPEEDUP}) — the fused mutation band requires "
                  f"wave >= serial on every op x batch cell")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("file", nargs="?", default="BENCH_hash.json")
    p.add_argument("--assert-table1", action="store_true",
                   help="also check table1 VALUES against the paper bands")
    args = p.parse_args(argv)
    with open(args.file) as f:
        payload = json.load(f)
    try:
        if is_chaos_artifact(payload):
            _check_chaos(payload)
            print(f"OK {args.file}: valid chaos-matrix artifact "
                  f"({payload['grid_cells']} cells, seed {payload['seed']}, "
                  f"all {len(CHAOS_GATES)} gates hold)")
            return 0
        validate(payload)
        if args.assert_table1:
            assert_table1(payload)
    except SchemaError as e:
        print(f"INVALID {args.file}: {e}", file=sys.stderr)
        return 1
    extras = [k for k in ("table1", "crash_consistency", "end_to_end",
                          "load_factor", "resize", "cluster", "cache",
                          "obs")
              if k in payload]
    print(f"OK {args.file}: valid write-batch sweep artifact "
          f"({len(payload['write_batch_sweep'])} ops"
          + (f"; + {', '.join(extras)}" if extras else "")
          + ("; table1 values in paper bands" if args.assert_table1 else "")
          + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())

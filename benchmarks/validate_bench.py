"""Schema check for the BENCH_hash.json perf artifact.

The artifact is the cross-PR perf trajectory (EXPERIMENTS.md §Perf), so CI
guards its shape: a structural schema (hand-rolled — no jsonschema dep in
the container) over the payload ``benchmarks/run.py`` emits:

    {
      "write_batch_sweep": {<op>: {<path>: {<batch>: CELL}}},
      "wave_over_serial_speedup": {"<op>_b<batch>": float}
    }

    CELL = {"ops_per_s": float > 0, "us_per_op": float > 0,
            "pm_writes": int >= 0, "succeeded": int >= 0}

Usage: python benchmarks/validate_bench.py [BENCH_hash.json]
Exit 0 on a valid artifact; raises/exits 1 with the offending path else.
"""

from __future__ import annotations

import json
import sys

OPS = ("insert", "update", "delete")
PATHS = ("serial", "wave")
CELL_FIELDS = {
    "ops_per_s": (float, int),
    "us_per_op": (float, int),
    "pm_writes": (int,),
    "succeeded": (int,),
}


class SchemaError(ValueError):
    pass


def _fail(path: str, msg: str):
    raise SchemaError(f"{path}: {msg}")


def _check_cell(cell, path: str) -> None:
    if not isinstance(cell, dict):
        _fail(path, f"expected object, got {type(cell).__name__}")
    for field, types in CELL_FIELDS.items():
        if field not in cell:
            _fail(path, f"missing field {field!r}")
        v = cell[field]
        if not isinstance(v, types) or isinstance(v, bool):
            _fail(f"{path}.{field}", f"expected {types}, got {v!r}")
        if v < 0:
            _fail(f"{path}.{field}", f"negative value {v!r}")
    for field in ("ops_per_s", "us_per_op"):
        if not cell[field] > 0:
            _fail(f"{path}.{field}", f"must be > 0, got {cell[field]!r}")
    extra = set(cell) - set(CELL_FIELDS)
    if extra:
        _fail(path, f"unexpected fields {sorted(extra)}")


def validate(payload: dict) -> None:
    """Raise `SchemaError` unless ``payload`` is a valid sweep artifact."""
    if not isinstance(payload, dict):
        _fail("$", "top level must be an object")
    missing = {"write_batch_sweep", "wave_over_serial_speedup"} - set(payload)
    if missing:
        _fail("$", f"missing keys {sorted(missing)}")

    sweep = payload["write_batch_sweep"]
    if set(sweep) - set(OPS) or not sweep:
        _fail("write_batch_sweep", f"ops must be a subset of {OPS}, "
                                   f"got {sorted(sweep)}")
    batches = None
    for op, by_path in sweep.items():
        if set(by_path) != set(PATHS):
            _fail(f"write_batch_sweep.{op}",
                  f"paths must be exactly {PATHS}, got {sorted(by_path)}")
        for path, by_batch in by_path.items():
            here = f"write_batch_sweep.{op}.{path}"
            if not by_batch:
                _fail(here, "no batch cells")
            for b, cell in by_batch.items():
                if not b.isdigit() or int(b) <= 0:
                    _fail(here, f"batch key {b!r} is not a positive int")
                _check_cell(cell, f"{here}.{b}")
            keys = set(by_batch)
            if batches is None:
                batches = keys
            elif keys != batches:
                _fail(here, f"inconsistent batch set {sorted(keys)} "
                            f"vs {sorted(batches)}")

    speed = payload["wave_over_serial_speedup"]
    want = {f"{op}_b{b}" for op in sweep for b in batches}
    if set(speed) != want:
        _fail("wave_over_serial_speedup",
              f"keys {sorted(set(speed) ^ want)} mismatch the sweep grid")
    for k, v in speed.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            _fail(f"wave_over_serial_speedup.{k}",
                  f"expected positive number, got {v!r}")


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    fname = args[0] if args else "BENCH_hash.json"
    with open(fname) as f:
        payload = json.load(f)
    try:
        validate(payload)
    except SchemaError as e:
        print(f"INVALID {fname}: {e}", file=sys.stderr)
        return 1
    print(f"OK {fname}: valid write-batch sweep artifact "
          f"({len(payload['write_batch_sweep'])} ops)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

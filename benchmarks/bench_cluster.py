"""Cluster benchmark section: N-node YCSB with elastic membership.

Each cell is one `repro.cluster.sim.run_cluster` drill — an N-node
replicated cluster under a skewed YCSB mix with a mid-run node JOIN
(live migration, dual-read window) and a mid-run primary KILL
(heartbeat detection -> replica promotion) — plus the two trace-level
drills (fenced replicated durability with its unfenced negative
control, and the migration crash sweep).  The payload lands in the
BENCH json under ``cluster`` and `validate_bench.py` gates the ISSUE's
acceptance criteria on it: zero committed-op loss, rebalance within
1/N + 5%, failover detected, fenced lossless + unfenced caught.
"""

from __future__ import annotations

from repro.cluster import sim as csim

SMOKE_SCHEMES = ("continuity",)
FULL_SCHEMES = ("continuity", "level", "pfarm")
WORKLOADS = ("A",)          # the update-heavy mix exercises replication most


def run(rows, scale: str = "full") -> dict:
    schemes = SMOKE_SCHEMES if scale == "smoke" else FULL_SCHEMES
    kw = (dict(num_records=600, num_ops=1200, batch=240) if scale == "smoke"
          else dict(num_records=1500, num_ops=3000, batch=300))
    cells = {}
    for s in schemes:
        for wl in WORKLOADS:
            events = (("join", kw["num_ops"] // 3, "pmJ"),
                      ("kill", 2 * kw["num_ops"] // 3, "primary"))
            cell = csim.run_cluster(s, wl, nodes=4, replicas=2,
                                    events=events, **kw)
            cells.setdefault(s, {})[wl] = {
                k: cell[k] for k in
                ("ops_per_s", "p50_us", "p99_us", "committed",
                 "committed_lost", "rebalance_within_bound",
                 "failover_detected", "nodes_initial", "nodes_final")}
            rows.append((f"cluster_{wl}[{s}]", cell["p50_us"],
                         f"{cell['ops_per_s']:.0f} ops/s "
                         f"p99={cell['p99_us']:.2f}us "
                         f"lost={cell['committed_lost']}"))
    payload = {
        "cells": cells,
        "durability": csim.durability_drill(schemes[0]),
        "migration": csim.migration_drill(schemes[0]),
    }
    d = payload["durability"]
    rows.append(("cluster_durability_fenced_lost", 0.0,
                 f"{d['fenced']['lost_committed']} over "
                 f"{d['fenced']['cuts']} cuts"))
    rows.append(("cluster_durability_unfenced_lost", 0.0,
                 f"{d['unfenced']['lost_committed']} (negative control)"))
    return payload

"""Technique-integrated serving benchmarks (beyond the paper's own tables):

  * paged decode step time (hash page table on the hot path) vs a dense
    block-table oracle — measures the index overhead the continuity layout
    keeps at one gather per translation;
  * prefix-sharing hit rate with content-addressed page keys;
  * page-table op costs at serving scale (lookups/inserts per decode step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit


def bench_paged_decode(rows):
    from repro.configs import smoke_config
    from repro.models import transformer as T
    from repro.models.config import ShapeConfig
    from repro.serving import engine as E
    from repro.serving import kvcache as KC

    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("b", seq_len=256, global_batch=8, kind="decode")
    geom = KC.make_geometry(cfg, shape, shards=2, page_size=32)
    cache = KC.create_cache(geom)
    step = jax.jit(lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab)
    # warm the cache to half depth
    for _ in range(16):
        lg, cache = step(params, toks, cache)
    t, _ = timeit(lambda: step(params, toks, cache), iters=5)
    rows.append(("paged_decode_step[smoke-yi]", t * 1e6,
                 f"{8/t:.0f} tok/s"))

    tl, _ = timeit(jax.jit(
        lambda c: KC.lookup_pages(geom, c.table, c.seq_ids)), cache, iters=5)
    npages = geom.max_pages * geom.batch
    rows.append(("page_table_lookup", tl / npages * 1e6,
                 f"{npages} translations/step"))


def bench_prefix_sharing(rows):
    """Content-addressed page keys: identical prompt prefixes dedupe."""
    from repro import api
    from repro.serving.engine import content_page_keys

    rng = np.random.RandomState(0)
    B, S, PS = 16, 256, 32
    prompts = rng.randint(0, 1000, size=(B, S)).astype(np.int32)
    prompts[8:] = prompts[:8]              # half the batch shares prompts
    keys = content_page_keys(jnp.asarray(prompts), PS)     # (B, NP, 4)
    flat = np.asarray(keys).reshape(-1, 4)
    uniq = len({tuple(r) for r in map(tuple, flat)})
    total = flat.shape[0]
    rows.append(("prefix_share_unique_pages", 0.0,
                 f"{uniq}/{total} ({1-uniq/total:.0%} shared)"))

    store = api.make_store("continuity", table_slots=640)
    t = store.create()
    vals = jnp.tile(jnp.arange(total, dtype=jnp.uint32)[:, None], (1, 4))
    t, _ = store.insert(t, jnp.asarray(flat), vals)
    # duplicate keys simply insert twice in this path; a dedup insert would
    # first lookup — count how many lookups hit after the first copy
    hit = store.lookup(t, jnp.asarray(flat))
    rows.append(("prefix_share_lookup_hits", 0.0,
                 f"{int(hit.ok.sum())}/{total}"))


def run(rows):
    bench_paged_decode(rows)
    bench_prefix_sharing(rows)

"""Quickstart: continuity hashing through `repro.api` in 60 lines.

Builds a store, runs the paper's op mix, and prints the metrics the paper
reports: PM writes per op (Table I), contiguous fetches per lookup (the
RDMA-amplification claim), and the load factor — all read off the one
`CostLedger` every scheme shares. Swap the scheme name for "level",
"pfarm" or "dense" and the same script benchmarks the baselines.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api
from repro.data import ycsb


def main():
    store = api.make_store("continuity", table_slots=2560)  # 128 pairs
    cfg = store.cfg
    print(f"table: {cfg.num_buckets} buckets, {cfg.num_pairs} segment pairs, "
          f"{cfg.slots_per_pair} slots/pair (+{cfg.ext_slots} ext), "
          f"indicator {cfg.total_bits} bits, segment fetch "
          f"{cfg.segment_bytes} B")
    table = store.create()

    rng = np.random.RandomState(0)
    n = 1500
    keys = ycsb.make_key(np.arange(n))
    vals = ycsb.make_value(rng, n)

    # server-side inserts: payload write + ONE atomic indicator commit each
    table, ins = store.insert(table, keys, vals)
    print(f"\ninsert: {int(ins.ok.sum())}/{n} ok, "
          f"{ins.ledger.pm_per_op():.2f} PM writes/op (paper Table I: 2)")

    # client-side reads: ONE contiguous segment fetch per lookup
    hit = store.lookup(table, keys)
    print(f"lookup: {int(hit.ok.sum())}/{n} hits, "
          f"{hit.ledger.reads_per_op():.2f} contiguous fetches/op "
          f"(level hashing needs up to 4), "
          f"{hit.ledger.bytes_per_op():.0f} B/op")

    neg = store.lookup(table, ycsb.negative_keys(rng, n, 500))
    print(f"negative search: {int(neg.ok.sum())} false hits, "
          f"{neg.ledger.reads_per_op():.2f} fetches/op")

    # out-of-place updates: two indicator bits flip in ONE atomic store
    table, upd = store.update(table, keys[:500], ycsb.make_value(rng, 500))
    print(f"update: {int(upd.ok.sum())}/500 ok, "
          f"{upd.ledger.pm_per_op():.2f} PM writes/op (paper: 2)")

    table, dele = store.delete(table, keys[:250])
    print(f"delete: {int(dele.ok.sum())}/250 ok, "
          f"{dele.ledger.pm_per_op():.2f} PM writes/op (paper: 1)")

    print(f"\nstats: {store.stats(table)}")

    # log-free ONLINE resizing: one bucket-pair cohort per step, an atomic
    # 8-byte token cutover each, foreground reads served throughout
    rs = store.begin_resize(table)
    steps = 0
    while not rs.done:
        rs = store.resize_step(rs, budget=16)
        steps += 1
        store.resize_lookup(rs, keys[250:300])   # dual-read mid-split
    store2, table2 = store.resize_cutover(rs)
    hit2 = store2.lookup(table2, keys[250:])
    print(f"resize 2x in {steps} incremental steps: "
          f"{int(hit2.ok.sum())}/{n-250} items survive, "
          f"new load factor {float(store2.load_factor(table2)):.2f}")


if __name__ == "__main__":
    main()

"""Quickstart: continuity hashing in 60 lines.

Builds a table, runs the paper's op mix, and prints the metrics the paper
reports: PM writes per op (Table I), contiguous fetches per lookup (the
RDMA-amplification claim), and the load factor.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.core.continuity as ch
from repro.data import ycsb


def main():
    cfg = ch.ContinuityConfig(num_buckets=256)   # 128 segment pairs
    print(f"table: {cfg.num_buckets} buckets, {cfg.num_pairs} segment pairs, "
          f"{cfg.slots_per_pair} slots/pair (+{cfg.ext_slots} ext), "
          f"indicator {cfg.total_bits} bits, segment fetch "
          f"{cfg.segment_bytes} B")
    table = ch.create(cfg)

    rng = np.random.RandomState(0)
    n = 1500
    keys = ycsb.make_key(np.arange(n))
    vals = ycsb.make_value(rng, n)

    # server-side inserts: payload write + ONE atomic indicator commit each
    table, ok, ctr = ch.insert(cfg, table, keys, vals)
    print(f"\ninsert: {int(ok.sum())}/{n} ok, "
          f"{float(ctr.pm_writes)/n:.2f} PM writes/op (paper Table I: 2)")

    # client-side reads: ONE contiguous segment fetch per lookup
    res = ch.lookup(cfg, table, keys)
    rc = ch.read_counters(cfg, res)
    print(f"lookup: {int(res.found.sum())}/{n} hits, "
          f"{float(rc.rdma_reads)/n:.2f} contiguous fetches/op "
          f"(level hashing needs up to 4), "
          f"{float(rc.bytes_fetched)/n:.0f} B/op")

    neg = ycsb.negative_keys(rng, n, 500)
    nres = ch.lookup(cfg, table, neg)
    print(f"negative search: {int(nres.found.sum())} false hits, "
          f"{float(np.mean(np.asarray(nres.reads))):.2f} fetches/op")

    # out-of-place updates: two indicator bits flip in ONE atomic store
    table, uok, uc = ch.update(cfg, table, keys[:500], ycsb.make_value(rng, 500))
    print(f"update: {int(uok.sum())}/500 ok, "
          f"{float(uc.pm_writes)/500:.2f} PM writes/op (paper: 2)")

    table, dok, dc = ch.delete(cfg, table, keys[:250])
    print(f"delete: {int(dok.sum())}/250 ok, "
          f"{float(dc.pm_writes)/250:.2f} PM writes/op (paper: 1)")

    print(f"\nload factor: {float(ch.load_factor(cfg, table)):.2f} "
          f"({int(table.count)} items, {int(table.ext_count)} extension "
          f"groups in use)")

    # log-free resizing (insert-to-new then delete-from-old per item)
    cfg2, table2 = ch.resize(cfg, table)
    res2 = ch.lookup(cfg2, table2, keys[250:])
    print(f"resize 2x: {int(res2.found.sum())}/{n-250} items survive, "
          f"new load factor {float(ch.load_factor(cfg2, table2)):.2f}")


if __name__ == "__main__":
    main()

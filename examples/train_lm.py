"""Train a small LM end to end with checkpoint/restart (framework driver).

Uses the yi-6b family at smoke scale by default; pass --big for a ~100M-param
variant (slower on CPU). Demonstrates: deterministic data, microbatched
train step, two-phase checkpoints, and crash/restart replay.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 120] [--big]
"""

import argparse
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (much slower on CPU)")
    args = ap.parse_args()

    cfg = smoke_config("yi-6b")
    if args.big:
        cfg = dataclasses.replace(cfg, n_layers=8, d_model=768, n_heads=12,
                                  n_kv_heads=4, head_dim=64, d_ff=2048,
                                  vocab=32000, attn_chunk=128)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name}-family model: {n/1e6:.1f}M params")

    state = O.init(params)
    step_fn = jax.jit(make_train_step(
        cfg, O.OptConfig(lr=3e-3, warmup=10, decay_steps=args.steps),
        num_micro=2))

    def batch_for(s):
        rng = np.random.Generator(np.random.Philox(key=0, counter=[0, 0, s, 0]))
        toks = rng.integers(0, cfg.vocab, size=(4, 128), dtype=np.int32)
        return {"inputs": jnp.asarray(toks),
                "labels": jnp.asarray(np.roll(toks, -1, 1))}

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    mgr = CheckpointManager(ckpt_dir, async_save=True)
    half = args.steps // 2
    for s in range(half):
        params, state, stats = step_fn(params, state, batch_for(s))
        if s % 20 == 0:
            print(f"step {s:4d} loss {float(stats['loss']):.4f}")
    mgr.save(half, {"p": params, "o": state})
    mgr.wait()
    print(f"--- checkpoint at step {half}; simulating crash + restart ---")

    params = T.init_params(cfg, jax.random.PRNGKey(0))   # fresh process
    state = O.init(params)
    restored, at, _ = mgr.restore({"p": params, "o": state})
    params, state = restored["p"], restored["o"]
    print(f"restored step {at}; replaying deterministic data from there")
    for s in range(at, args.steps):
        params, state, stats = step_fn(params, state, batch_for(s))
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(stats['loss']):.4f}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("done.")


if __name__ == "__main__":
    main()

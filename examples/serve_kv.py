"""End-to-end serving driver (the paper-kind example): serve a small LM with
batched requests on the continuity-hash paged KV cache.

Flow: batch of prompts -> prefill (bulk page registration through the hash
table) -> batched decode (every step translates (seq, page) keys through the
table: the paper's one-contiguous-fetch client reads) -> a request finishes
and its pages are released (atomic indicator-bit deletes) -> a new request
takes the slot.

Run: PYTHONPATH=src python examples/serve_kv.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.config import ShapeConfig
from repro.serving import engine as E
from repro.serving import kvcache as KC


def main():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, PROMPT, GEN, PS = 4, 32, 48, 16
    shape = ShapeConfig("serve", seq_len=256, global_batch=B, kind="decode")
    geom = KC.make_geometry(cfg, shape, shards=2, page_size=PS)
    cache = KC.create_cache(geom)
    print(f"model: {cfg.name} smoke ({sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params)")
    print(f"paged cache: {geom.shards} shards x {geom.pool_pages} pages x "
          f"{PS} tokens; page table = continuity hash "
          f"({geom.table_cfg.num_buckets} buckets/shard)")

    rng = np.random.RandomState(1)
    prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT)).astype(np.int32)

    t0 = time.time()
    logits, cache = E.prefill(cfg, geom, params, jnp.asarray(prompts), cache)
    print(f"\nprefill {B}x{PROMPT} tokens: {time.time()-t0:.2f}s; "
          f"{int(cache.table.count.sum())} page mappings registered")

    step = jax.jit(lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for i in range(GEN):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decode {GEN} steps: {dt:.2f}s ({B*GEN/dt:.1f} tok/s); "
          f"seq_lens={np.asarray(cache.seq_lens).ravel().tolist()}")

    # request lifecycle: finish seq (0,0), release its pages, admit a new one
    n_before = int(cache.table.count.sum())
    cache = E.release_sequence(geom, cache, shard_idx=0, slot=0)
    print(f"\nreleased one sequence: {n_before} -> "
          f"{int(cache.table.count.sum())} page mappings "
          f"(deletes = 1 atomic indicator-bit clear each)")

    # the freed slot serves a new request immediately
    new_prompt = rng.randint(0, cfg.vocab, size=(1, PS)).astype(np.int32)
    for t in range(PS):
        onetok = jnp.where(jnp.arange(B) == 0, new_prompt[0, t], tok)
        logits, cache = step(params, onetok.astype(jnp.int32), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"admitted a new request in the freed slot; seq_lens="
          f"{np.asarray(cache.seq_lens).ravel().tolist()}")

    # content-addressed prefix sharing stats
    shared = prompts.copy()
    shared[2:] = shared[:2]
    keys = np.asarray(E.content_page_keys(jnp.asarray(shared), PS))
    uniq = len({tuple(r) for r in keys.reshape(-1, 4)})
    print(f"\nprefix sharing: {uniq}/{keys.shape[0]*keys.shape[1]} unique "
          f"page keys when half the prompts repeat "
          f"({1-uniq/(keys.shape[0]*keys.shape[1]):.0%} dedup)")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper-kind example): serve a small LM with
batched requests on the continuity-hash paged KV cache.

Flow: batch of prompts -> prefill (bulk page registration through the hash
table) -> batched decode (every step translates (seq, page) keys through the
table: the paper's one-contiguous-fetch client reads) -> a request finishes
and its pages are released (atomic indicator-bit deletes) -> a new request
takes the slot.

Run: PYTHONPATH=src python examples/serve_kv.py

``--cache --clients N`` runs the client-cache tier instead (no model):
N clients translate a hot page set through per-client `ClientCache`
instances in front of one continuity store, while a writer remaps hot
pages mid-run.  The only invalidation signal is the pair's 8-byte
version word — each cross-round hit revalidates with one 8-byte READ —
and the demo asserts no client ever serves a remapped (stale) page.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.config import ShapeConfig
from repro.serving import engine as E
from repro.serving import kvcache as KC


def cache_demo(clients: int = 16, rounds: int = 8) -> None:
    """The client-cache tier on the page-table store: hot-page translation
    through per-client caches, revalidated by the 8-byte version word."""
    from repro.api import make_store
    from repro.cache import CacheConfig, ClientCache, StoreBackend
    from repro.data import ycsb

    PAGES, HOT, PER_ROUND = 384, 24, 8
    store = make_store("continuity", table_slots=2048)
    table = store.create()
    rng = np.random.RandomState(0)
    ids = np.arange(PAGES)
    vals = ycsb.make_value(rng, PAGES)
    table, res = store.insert(table, ycsb.make_key(ids), vals)
    okn = np.asarray(res.ok)
    truth = {int(i): v for i, v in zip(ids[okn], vals[okn])}
    print(f"page table: continuity store, {int(okn.sum())}/{PAGES} page "
          f"mappings registered; {clients} clients x {rounds} rounds over "
          f"a {HOT}-page hot set")

    backend = StoreBackend(store, table)
    caches = [ClientCache(CacheConfig(capacity=64, seed=c), backend)
              for c in range(clients)]
    hot = ids[:HOT]
    served = stale = 0
    for _ in range(rounds):
        # a writer remaps two hot pages each round; clients learn of it
        # ONLY through the pair's bumped 8-byte version word
        wids = hot[rng.randint(0, HOT, size=2)]
        wv = ycsb.make_value(rng, len(wids))
        backend.table, wres = store.update(backend.table,
                                           ycsb.make_key(wids), wv)
        for i, v in zip(wids[np.asarray(wres.ok)], wv[np.asarray(wres.ok)]):
            truth[int(i)] = v
        for c in caches:
            rids = hot[rng.randint(0, HOT, size=PER_ROUND)]
            r = c.read_round(ycsb.make_key(rids))
            for j in range(len(rids)):
                if r.found[j]:
                    served += 1
                    stale += not np.array_equal(r.values[j],
                                                truth[int(rids[j])])

    hits = sum(c.stats["hits"] for c in caches)
    misses = sum(c.stats["misses"] for c in caches)
    checks = sum(c.stats["validations"] for c in caches)
    inval = sum(c.stats["stamp_invalidations"] for c in caches)
    led = backend.ledger
    print(f"cache tier: hit_rate={hits / max(1, hits + misses):.3f} "
          f"({hits} hits / {misses} misses), {checks} validations "
          f"({inval} caught a remap), stale_served={stale}")
    print(f"wire ledger: {int(led.rdma_reads)} one-sided READs, "
          f"{int(led.bytes_fetched)} bytes "
          f"({int(led.bytes_fetched) / max(1, int(led.rdma_reads)):.1f} "
          f"B/read — validations are 8-byte indicator reads)")
    assert stale == 0, f"{stale} reads served a remapped page"
    print("cache check passed: no client served a remapped page")


def main():
    cfg = smoke_config("yi-6b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, PROMPT, GEN, PS = 4, 32, 48, 16
    shape = ShapeConfig("serve", seq_len=256, global_batch=B, kind="decode")
    geom = KC.make_geometry(cfg, shape, shards=2, page_size=PS)
    cache = KC.create_cache(geom)
    print(f"model: {cfg.name} smoke ({sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params)")
    print(f"paged cache: {geom.shards} shards x {geom.pool_pages} pages x "
          f"{PS} tokens; page table = continuity hash "
          f"({geom.table_cfg.num_buckets} buckets/shard)")

    rng = np.random.RandomState(1)
    prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT)).astype(np.int32)

    t0 = time.time()
    logits, cache = E.prefill(cfg, geom, params, jnp.asarray(prompts), cache)
    print(f"\nprefill {B}x{PROMPT} tokens: {time.time()-t0:.2f}s; "
          f"{int(cache.table.count.sum())} page mappings registered")

    step = jax.jit(lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for i in range(GEN):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decode {GEN} steps: {dt:.2f}s ({B*GEN/dt:.1f} tok/s); "
          f"seq_lens={np.asarray(cache.seq_lens).ravel().tolist()}")

    # request lifecycle: finish seq (0,0), release its pages, admit a new one
    n_before = int(cache.table.count.sum())
    cache = E.release_sequence(geom, cache, shard_idx=0, slot=0)
    print(f"\nreleased one sequence: {n_before} -> "
          f"{int(cache.table.count.sum())} page mappings "
          f"(deletes = 1 atomic indicator-bit clear each)")

    # the freed slot serves a new request immediately
    new_prompt = rng.randint(0, cfg.vocab, size=(1, PS)).astype(np.int32)
    for t in range(PS):
        onetok = jnp.where(jnp.arange(B) == 0, new_prompt[0, t], tok)
        logits, cache = step(params, onetok.astype(jnp.int32), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"admitted a new request in the freed slot; seq_lens="
          f"{np.asarray(cache.seq_lens).ravel().tolist()}")

    # content-addressed prefix sharing stats
    shared = prompts.copy()
    shared[2:] = shared[:2]
    keys = np.asarray(E.content_page_keys(jnp.asarray(shared), PS))
    uniq = len({tuple(r) for r in keys.reshape(-1, 4)})
    print(f"\nprefix sharing: {uniq}/{keys.shape[0]*keys.shape[1]} unique "
          f"page keys when half the prompts repeat "
          f"({1-uniq/(keys.shape[0]*keys.shape[1]):.0%} dedup)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache", action="store_true",
                    help="run the client-cache tier demo (no model)")
    ap.add_argument("--clients", type=int, default=16,
                    help="cache-demo client count (only with --cache)")
    args = ap.parse_args()
    if args.cache:
        cache_demo(clients=args.clients)
    else:
        main()

"""Distributed continuity KV store under YCSB-A on a simulated 8-device mesh.

The paper's deployment: each data shard is a 'server' owning a pair range;
clients batch reads (one contiguous segment fetch each, via all_to_all
routing) and route writes to owners. Prints throughput + the consistency
check that every committed write is visible.

NOTE: sets XLA_FLAGS for 8 host devices — run as its own process.

Run: PYTHONPATH=src python examples/ycsb_cluster.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main():
    import repro.core.distributed as D
    from repro.core import continuity as ch
    from repro.data import ycsb
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh((8,), ("data",))
    scfg = D.StoreConfig(
        table=ch.ContinuityConfig(num_buckets=1 << 14, ext_frac=0.0),
        num_shards=8)
    print(f"store: {scfg.table.num_buckets} buckets over {scfg.num_shards} "
          f"servers ({scfg.pairs_per_shard} pairs each)")
    table = D.create_sharded(scfg)
    lookup = D.make_lookup(scfg, mesh)
    write = D.make_write(scfg, mesh)

    n = 20_000
    rng = np.random.RandomState(0)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)

    with mesh:
        t0 = time.time()
        done = np.zeros(n, bool)
        for lo in range(0, n, 4096):
            hi = min(lo + 4096, n)
            table, ok, _ = write(table, jnp.full((hi - lo,), D.OP_INSERT,
                                                 jnp.int32),
                                 jnp.asarray(K[lo:hi]), jnp.asarray(V[lo:hi]))
            done[lo:hi] = np.asarray(ok)
        print(f"load: {done.sum()}/{n} inserted in {time.time()-t0:.1f}s; "
              f"count={int(D.sharded_count(table))}")

        # YCSB-A: 50% reads / 50% updates, zipfian
        zipf = ycsb.Zipf(n)
        B = 4096
        rounds = 8
        t0 = time.time()
        for r in range(rounds):
            rk = ycsb.make_key(zipf.sample(rng, B))
            res = lookup(table, jnp.asarray(rk))
            uk = ycsb.make_key(zipf.sample(rng, B))
            table, uok, _ = write(table, jnp.full((B,), D.OP_UPDATE, jnp.int32),
                                  jnp.asarray(uk), jnp.asarray(
                                      ycsb.make_value(rng, B)))
        jax.block_until_ready(table)
        dt = time.time() - t0
        nops = rounds * B * 2
        print(f"YCSB-A: {nops} ops in {dt:.1f}s = {nops/dt:.0f} ops/s "
              f"(8 simulated devices on one CPU)")

        # consistency: all loaded keys still resolve with correct liveness
        res = lookup(table, jnp.asarray(K[:4096]))
        assert bool(np.asarray(res.found)[done[:4096]].all())
        print("consistency check passed: every committed insert is visible")


if __name__ == "__main__":
    main()

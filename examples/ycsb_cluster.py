"""Distributed continuity KV store under YCSB-A on a simulated 8-device mesh,
the end-to-end RDMA transport comparison (`repro.rdma`), and the N-node
replicated cluster with live failover (`repro.cluster`).

The paper's deployment: each data shard is a 'server' owning a pair range;
clients batch reads (one contiguous segment fetch each, via all_to_all
routing) and route writes to owners.  Wire accounting is verb-plan-derived
(`DLookupResult.ledger`); the second section drives the same YCSB mixes
through the analytical transport (`repro.rdma.sim`) and prints the
per-scheme throughput/latency ordering the paper reports; the third runs
an elastic `ClusterStore` — rendezvous-sharded, replica-fenced writes —
and (with ``--kill-primary``) crashes a primary mid-run to exercise
heartbeat detection, replica promotion with indicator-based recovery,
and the zero-committed-loss audit.

NOTE: sets XLA_FLAGS for 8 host devices — run as its own process.

Run: PYTHONPATH=src python examples/ycsb_cluster.py \
        [--smoke] [--nodes N] [--kill-primary]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def run_mesh(smoke: bool) -> None:
    import repro.core.distributed as D
    from repro.core import continuity as ch
    from repro.data import ycsb
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh((8,), ("data",))
    scfg = D.StoreConfig(
        table=ch.ContinuityConfig(num_buckets=1 << (10 if smoke else 14),
                                  ext_frac=0.0),
        num_shards=8)
    print(f"store: {scfg.table.num_buckets} buckets over {scfg.num_shards} "
          f"servers ({scfg.pairs_per_shard} pairs each)")
    table = D.create_sharded(scfg)
    lookup = D.make_lookup(scfg, mesh)
    write = D.make_write(scfg, mesh)

    n = 1536 if smoke else 20_000      # batches must divide the 8-way mesh
    B = 512 if smoke else 4096
    rounds = 2 if smoke else 8
    rng = np.random.RandomState(0)
    K = ycsb.make_key(np.arange(n))
    V = ycsb.make_value(rng, n)

    with mesh:
        t0 = time.time()
        done = np.zeros(n, bool)
        for lo in range(0, n, B):
            hi = min(lo + B, n)
            table, ok, _ = write(table, jnp.full((hi - lo,), D.OP_INSERT,
                                                 jnp.int32),
                                 jnp.asarray(K[lo:hi]), jnp.asarray(V[lo:hi]))
            done[lo:hi] = np.asarray(ok)
        print(f"load: {done.sum()}/{n} inserted in {time.time()-t0:.1f}s; "
              f"count={int(D.sharded_count(table))}")

        # YCSB-A: 50% reads / 50% updates, zipfian
        zipf = ycsb.Zipf(n)
        t0 = time.time()
        reads = bytes_fetched = 0
        for r in range(rounds):
            rk = ycsb.make_key(zipf.sample(rng, B))
            res = lookup(table, jnp.asarray(rk))
            reads += int(res.ledger.rdma_reads)
            bytes_fetched += int(res.ledger.bytes_fetched)
            uk = ycsb.make_key(zipf.sample(rng, B))
            table, uok, _ = write(table, jnp.full((B,), D.OP_UPDATE, jnp.int32),
                                  jnp.asarray(uk), jnp.asarray(
                                      ycsb.make_value(rng, B)))
        jax.block_until_ready(table)
        dt = time.time() - t0
        nops = rounds * B * 2
        print(f"YCSB-A: {nops} ops in {dt:.1f}s = {nops/dt:.0f} ops/s "
              f"(8 simulated devices on one CPU); global wire ledger: "
              f"{reads} one-sided reads, {bytes_fetched} B fetched "
              f"(verb-plan-derived)")

        # consistency: all loaded keys still resolve with correct liveness
        res = lookup(table, jnp.asarray(K[:B]))
        assert bool(np.asarray(res.found)[done[:B]].all())
        print("consistency check passed: every committed insert is visible")


def run_transport(smoke: bool) -> None:
    """End-to-end per-scheme YCSB over the one-sided transport simulation:
    the paper's headline throughput/latency ordering."""
    from repro.rdma import sim

    kw = (dict(num_records=800, num_ops=1000, batch=250) if smoke
          else dict(num_records=3000, num_ops=4000, batch=500))
    print("\nRDMA transport end-to-end (doorbell batching + analytical "
          "latency model):")
    print(f"{'scheme':12s} {'wl':2s} {'ops/s':>10s} {'p50 us':>8s} "
          f"{'p99 us':>8s} {'verbs/op':>9s}")
    order = {}
    for s in ("continuity", "level", "pfarm"):
        for wl in sim.SIM_WORKLOADS:
            r = sim.run_ycsb(s, wl, **kw)
            order.setdefault(wl, []).append(r["ops_per_s"])
            print(f"{s:12s} {wl:2s} {r['ops_per_s']:10.0f} "
                  f"{r['p50_us']:8.2f} {r['p99_us']:8.2f} "
                  f"{r['verbs_per_op']:9.2f}")
    for wl in ("B", "C"):
        c, l, p = order[wl]
        assert c >= l >= p, (wl, order[wl])
    print("ordering check passed: continuity >= level >= pfarm on "
          "read-heavy workloads")


def run_failover(smoke: bool, nodes: int, kill_primary: bool) -> None:
    """The N-node replicated cluster: rendezvous routing, fenced replica
    writes, heartbeat-driven failover with indicator-based recovery."""
    from repro.cluster import ClusterStore, FailoverController
    from repro.data import ycsb

    n = 400 if smoke else 2000
    B = 100 if smoke else 400
    rounds = 4 if smoke else 10
    cluster = ClusterStore("continuity", nodes=nodes, replicas=2,
                           node_slots=max(512, 3 * 2 * n // nodes))
    clock = [0.0]
    ctl = FailoverController(cluster, timeout_s=3.0,
                             clock=lambda: clock[0])

    print(f"\nN-node cluster ({nodes} PM nodes, R=2, rendezvous "
          f"directory, fenced replica writes):")
    rng = np.random.RandomState(0)
    acked = {}
    for lo in range(0, n, B):
        ids = np.arange(lo, min(lo + B, n))
        vals = ycsb.make_value(rng, len(ids))
        res = cluster.insert(ycsb.make_key(ids), vals)
        for i, v in zip(ids[np.asarray(res.ok)], vals[np.asarray(res.ok)]):
            acked[int(i)] = v
    print(f"load: {len(acked)}/{n} committed (primary + replica fenced)")

    zipf = ycsb.Zipf(n)
    victim = None
    for r in range(rounds):
        clock[0] += 1.0
        ctl.beat(r)
        for rep in ctl.tick():
            print(f"failover: {rep.dead} promoted away "
                  f"({rep.promoted_keys} keys re-primaried, "
                  f"{rep.recopied} copies restored, recovery log-free="
                  f"{rep.recovery_log_free()})")
        if kill_primary and r == rounds // 2:
            hot = ycsb.make_key(np.array([0]))
            victim = str(cluster.directory.replica_names(hot)[0, 0])
            cluster.kill(victim)
            print(f"killed {victim} (primary of the hottest key) mid-run")
        ids = zipf.sample(rng, B)
        vals = ycsb.make_value(rng, B)
        res = cluster.update(ycsb.make_key(ids), vals)
        okn = np.asarray(res.ok)
        for i, v in zip(ids[okn], vals[okn]):
            acked[int(i)] = v
    for extra in range(5):          # let detection + promotion drain
        clock[0] += 1.0
        ctl.beat(rounds + extra)
        for rep in ctl.tick():
            print(f"failover: {rep.dead} promoted away "
                  f"({rep.promoted_keys} keys re-primaried, "
                  f"{rep.recopied} copies restored, recovery log-free="
                  f"{rep.recovery_log_free()})")

    ids = np.array(sorted(acked))
    lost = 0
    for lo in range(0, len(ids), B):
        sub = ids[lo:lo + B]
        res = cluster.lookup(ycsb.make_key(sub))
        want = np.stack([acked[int(i)] for i in sub])
        good = np.asarray(res.found) & (res.values == want).all(axis=1)
        lost += int((~good).sum())
    assert lost == 0, f"{lost} committed ops lost"
    if kill_primary:
        assert victim is not None and victim not in cluster.node_names()
    print(f"failover check passed: {len(acked)} committed ops, 0 lost "
          f"(nodes: {', '.join(cluster.node_names())})")


def main(smoke: bool = False, nodes: int = 4, kill_primary: bool = False):
    run_mesh(smoke)
    run_transport(smoke)
    run_failover(smoke, nodes, kill_primary)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the examples smoke test")
    ap.add_argument("--nodes", type=int, default=4,
                    help="PM nodes in the replicated cluster section")
    ap.add_argument("--kill-primary", action="store_true",
                    help="crash a primary mid-run and exercise failover")
    args = ap.parse_args()
    main(args.smoke, args.nodes, args.kill_primary)

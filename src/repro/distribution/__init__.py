"""Distribution layer: logical-axis sharding rules, meshes, collectives."""

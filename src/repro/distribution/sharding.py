"""Logical-axis sharding: MaxText-style rules mapping logical dims to mesh axes.

Models annotate arrays with LOGICAL names ("batch", "heads", "mlp", ...).
A rules table (per launch config) maps logical names to physical mesh axes.
``shard()`` applies a with_sharding_constraint when a mesh is active, and is
the identity on single-device runs (smoke tests see no mesh, per the
dry-run isolation contract).

Changing a rules entry re-lowers the whole model on a different sharding —
this is also the elastic-rescale path: a new mesh + the same rules table
re-compiles every step function without touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default rules for the production meshes: DP over (pod, data); TP over model.
# kv_heads / experts map to model only when divisible (checked at use site).
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": None,
    "vocab": ("model",),
    "layers": None,
    "ssm_inner": None,
    "ssm_heads": ("model",),
    "kv_pairs": ("data",),        # the continuity table's pair dim
    "zero": ("data",),            # ZeRO-1 moment sharding
    # decode-time KV layout: pools shard over (pod, data); page tokens split
    # over model ("split-KV" — works for any kv-head count); kv heads at
    # decode stay replicated (the split-KV axis carries the parallelism)
    "kv_shard": ("pod", "data"),
    "page_tokens": ("model",),
    "kv_heads_dec": None,
}


def set_mesh_and_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def get_rules() -> dict:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    old = get_mesh(), getattr(_state, "rules", None)
    set_mesh_and_rules(mesh, rules)
    try:
        with mesh:
            yield
    finally:
        _state.mesh, _state.rules = old


def logical_spec(*names: Optional[str], size_of=None) -> P:
    """PartitionSpec from logical dim names under the active rules.

    ``size_of``: optional tuple of dim sizes; a logical axis whose dim size is
    not divisible by its mesh-axes extent degrades to replicated (the GQA
    kv_heads < TP case, or 40-expert MoE on 16-way model axis).
    """
    mesh = get_mesh()
    rules = get_rules()
    out = []
    for i, n in enumerate(names):
        axes = rules.get(n) if n else None
        if axes and mesh is not None:
            extent = 1
            for a in axes:
                extent *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
            if size_of is not None and size_of[i] % max(extent, 1) != 0:
                out.append(None)
                continue
            axes = tuple(a for a in axes if a in mesh.axis_names)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        else:
            out.append(None)
    return P(*out)


def shard(x, *names: Optional[str]):
    """Constrain ``x``'s sharding by logical dim names (identity w/o mesh)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_spec(*names, size_of=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str], size_of=None) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(*names, size_of=size_of))

"""Pallas TPU kernel: batched continuity mutation plan (update/delete).

Peer of ``probe.py`` for the WRITE path.  A mutation against a continuity
pair needs exactly two facts about the cohort's contiguous segment row:

  * the MATCH slot — the key's current home (the bit an update/delete
    clears), resolved by the same directional fp-filtered scan the probe
    kernel runs; and
  * the VICTIM slot — the first empty probe candidate in direction order
    (the bit an update sets for its out-of-place copy; insert's target).

Both live in the one region a single HBM->VMEM row DMA fetches (the RDMA
single-READ analogue), so the kernel resolves them in-register per grid
step and emits a dense commit plan: ``(match_slot, victim_slot, flip)``
rows, where ``flip`` is the one-word XOR mask an uncontended op would
commit (old-bit | new-bit for update, old-bit alone for delete).  The
host-side fused pass consumes the match side directly and replays victim
allocation only for pairs that receive multiple ops in one batch (the
plan's victim is pre-state-exact for the single-op-per-pair common case).

DMA/grid structure is identical to ``probe.py``: ``qblock`` queries per
grid step, all row copies started before any wait, all plan math one
vectorized (Q, S) VPU pass.  The fingerprint filter is ALWAYS on here —
mutations must never act on a wrong slot, and visible slots always carry
the correct field, so the filter is a pure compare-reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
I32 = jnp.int32
BIG = 0x7FFFFFFF  # python int: stays a kernel-embedded literal


def _mutate_kernel(pairs_ref, rows_ref, ind_ref, fps_ref, prio_ref,
                   parity_ref, qk_ref, qfp_ref, match_ref, victim_ref,
                   flip_ref, seg_vmem, ind_vmem, fp_vmem, sem, *,
                   slots: int, key_lanes: int, qblock: int):
    i = pl.program_id(0)

    # ONE contiguous DMA per query: segment row + the indicator and fp
    # words that physically head the same region.  All copies start before
    # any wait (doorbell batching) — see probe.py for the layout notes.
    def start(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).start()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).start()
        pltpu.make_async_copy(fps_ref.at[p], fp_vmem.at[q], sem).start()
        return carry

    def wait(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).wait()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).wait()
        pltpu.make_async_copy(fps_ref.at[p], fp_vmem.at[q], sem).wait()
        return carry

    jax.lax.fori_loop(0, qblock, start, 0)
    jax.lax.fori_loop(0, qblock, wait, 0)

    seg = seg_vmem[...].reshape(qblock, slots, key_lanes)
    qk = qk_ref[...]                                          # (Q, KL)
    eq = jnp.all(seg == qk[:, None, :], axis=-1)              # (Q, S)
    iota = jax.lax.broadcasted_iota(U32, (qblock, slots), 1)
    bits = (ind_vmem[...] >> iota) & U32(1)                   # (Q,1)>>(Q,S)
    lane = jnp.where(iota < U32(16), fp_vmem[:, 0:1], fp_vmem[:, 1:2])
    field = (lane >> (U32(2) * (iota % U32(16)))) & U32(3)    # (Q, S)
    eq = eq & (field == qfp_ref[...])                         # fp pre-filter
    pr = jnp.where(parity_ref[...] == 0,
                   prio_ref[0][None, :], prio_ref[1][None, :])  # (Q, S)
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == U32(1)) & cand, pr, BIG)
    vrank = jnp.where((bits == U32(0)) & cand, pr, BIG)
    mslot = jnp.argmin(mrank, axis=-1).astype(I32)
    vslot = jnp.argmin(vrank, axis=-1).astype(I32)
    mfound = jnp.min(mrank, -1) < BIG
    vfound = jnp.min(vrank, -1) < BIG
    match_ref[...] = jnp.where(mfound, mslot, -1)[:, None]
    victim_ref[...] = jnp.where(vfound, vslot, -1)[:, None]
    flip_ref[...] = (jnp.where(mfound, U32(1) << mslot.astype(U32), U32(0))
                     | jnp.where(vfound, U32(1) << vslot.astype(U32),
                                 U32(0)))[:, None]


@functools.partial(jax.jit, static_argnames=("interpret", "qblock"))
def mutate_segments(rows, indicators, fps, prio, pairs, parity, qkeys, qfp,
                    *, interpret: bool = True, qblock: int = 8):
    """Resolve the mutation plan for one contiguous segment row per query.

    Args mirror ``probe.probe_segments`` with the fp word mandatory.
    Returns ``(match_slot, victim_slot, flip)``: (B,) int32/int32/uint32
    with -1 for miss/full and ``flip`` the one-word commit XOR mask.
    """
    P, RL = rows.shape
    B, KL = qkeys.shape
    S = RL // KL
    nb = max(1, -(-B // qblock))
    pad = nb * qblock - B
    pairs = jnp.pad(pairs.astype(I32), (0, pad))
    parity = jnp.pad(parity.astype(I32), (0, pad))[:, None]
    qkeys = jnp.pad(qkeys, ((0, pad), (0, 0)))
    qfp = jnp.pad(qfp.astype(U32), (0, pad))[:, None]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # pairs drive the row DMAs
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # rows stay in HBM
            pl.BlockSpec(memory_space=pl.ANY),     # indicators stay in HBM
            pl.BlockSpec(memory_space=pl.ANY),     # fp words stay in HBM
            pl.BlockSpec((2, S), lambda i, pairs: (0, 0)),
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
            pl.BlockSpec((qblock, KL), lambda i, pairs: (i, 0)),
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qblock, RL), U32),         # per-block segment tile
            pltpu.VMEM((qblock, 1), U32),          # per-block indicators
            pltpu.VMEM((qblock, 2), U32),          # per-block fp words
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    match, victim, flip = pl.pallas_call(
        functools.partial(_mutate_kernel, slots=S, key_lanes=KL,
                          qblock=qblock),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb * qblock, 1), I32),
            jax.ShapeDtypeStruct((nb * qblock, 1), I32),
            jax.ShapeDtypeStruct((nb * qblock, 1), U32),
        ],
        interpret=interpret,
    )(pairs, rows, indicators, fps, prio, parity, qkeys, qfp)
    return match[:B, 0], victim[:B, 0], flip[:B, 0]

"""Pallas TPU kernel: paged decode attention over a hash-indexed page pool.

This is where the paper's technique meets the model hot path. The serving
engine stores the KV cache in fixed-size physical pages; the logical->physical
mapping comes from the continuity-hash page table. Each (sequence, kv-head,
logical-page) grid step scalar-prefetches the PHYSICAL page id and the
``BlockSpec`` index map turns it into ONE contiguous (page_size, head_dim)
HBM->VMEM DMA — the TPU rendering of "all positions of an item are in one
contiguous region, fetched with a single one-sided read" (paper §III-A), with
Pallas double-buffering playing the role of RDMA doorbell pipelining.

Online-softmax accumulation across pages (flash-attention style) keeps VMEM
residency at one page per buffer: VMEM working set =
``2 * page_size * head_dim * bytes + G * head_dim * 4`` (~132 KB for
page_size=128, D=128, bf16 double-buffered) — far under the ~16 MB v5e VMEM,
leaving room to raise page_size or pipeline depth.

Validated in interpret mode against ``paged_attn_ref.paged_attention_ref``;
dimensions are MXU/VPU aligned for real TPUs (D=128 lanes, page_size a
multiple of 8 sublanes; q-head group dim padded to >= 8 by ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page_size: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    maxp = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (PS, D)
    v = v_ref[0, 0].astype(jnp.float32)             # (PS, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale                                   # (G, PS)

    seq_len = len_ref[b]
    page_ok = pt_ref[b, p] >= 0
    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    live = (pos < seq_len) & page_ok                # (1, PS)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]                             # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)                       # (G, PS)
    pexp = jnp.where(live, pexp, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == maxp - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q, kpool, vpool, page_table, seq_lens, *,
                    scale: float | None = None, interpret: bool = True):
    """Paged GQA decode attention.

    Args:
      q:          (B, H, D)
      kpool:      (NP, KVH, PS, D) — physical pages, contiguous per (page, head)
      vpool:      (NP, KVH, PS, D)
      page_table: (B, MAXP) int32 physical page ids (-1 = absent)
      seq_lens:   (B,) int32
    Returns: (B, H, D)
    """
    B, H, D = q.shape
    NP, KVH, PS, _ = kpool.shape
    MAXP = page_table.shape[1]
    G = H // KVH
    if scale is None:
        scale = float(1.0 / (D ** 0.5))
    qg = q.reshape(B, KVH, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # page_table, seq_lens
        grid=(B, KVH, MAXP),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, sl: (b, h, 0, 0)),
            # ONE contiguous physical page per step, selected via the
            # hash-page-table (the single one-sided read of a segment):
            pl.BlockSpec((1, 1, PS, D),
                         lambda b, h, p, pt, sl: (jnp.maximum(pt[b, p], 0), h, 0, 0)),
            pl.BlockSpec((1, 1, PS, D),
                         lambda b, h, p, pt, sl: (jnp.maximum(pt[b, p], 0), h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),        # running max m
            pltpu.VMEM((G, 1), jnp.float32),        # running denom l
            pltpu.VMEM((G, D), jnp.float32),        # output accumulator
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, page_size=PS, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      qg, kpool, vpool)
    return out.reshape(B, H, D)

"""Pure-jnp oracle for paged decode attention (GQA) over a physical page pool."""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, kpool, vpool, page_table, seq_lens, scale=None):
    """Reference paged decode attention.

    Args:
      q:          (B, H, D) — one new query token per sequence
      kpool:      (NP, KVH, PS, D) physical key pages (page-major contiguous)
      vpool:      (NP, KVH, PS, D)
      page_table: (B, MAXP) int32 — physical page per logical page (-1 = absent)
      seq_lens:   (B,) int32 — tokens currently in each sequence's cache
    Returns:
      (B, H, D) attention output, same dtype as q.
    """
    B, H, D = q.shape
    NP, KVH, PS, _ = kpool.shape
    MAXP = page_table.shape[1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(D))

    pt = jnp.maximum(page_table, 0)
    k = kpool[pt]                                  # (B, MAXP, KVH, PS, D)
    v = vpool[pt]
    k = jnp.moveaxis(k, 2, 1).reshape(B, KVH, MAXP * PS, D)
    v = jnp.moveaxis(v, 2, 1).reshape(B, KVH, MAXP * PS, D)
    qg = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32)) * scale

    pos = jnp.arange(MAXP * PS)[None]                        # (1, T)
    live = (pos < seq_lens[:, None]) & jnp.repeat(page_table >= 0, PS, axis=1)
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)

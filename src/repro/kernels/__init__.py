"""Pallas TPU kernels for the continuity-hashing framework.

Kernels (each: <name>.py kernel + <name>_ref.py pure-jnp oracle, wrapped in ops.py):
  * probe      — batched continuity-segment probe (one contiguous DMA per query)
  * paged_attn — paged GQA decode attention over the hash-indexed page pool
"""

from repro.kernels.ops import paged_attention, probe_table, priority_table  # noqa: F401

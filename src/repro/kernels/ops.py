"""Jit'd public wrappers around the Pallas kernels.

``probe_table`` adapts a ``ContinuityTable`` into the probe kernel's layout
(flat contiguous rows + parity priority table) and returns results identical
to ``repro.core.continuity.lookup``'s probe stage. ``probe_lookup`` extends
it to a FULL lookup (values + extension slots + fetch accounting) — it is
the continuity backend's kernel probe strategy, selected through
``repro.api.ExecPolicy(probe="pallas")`` instead of per-call kwargs.
``paged_attention`` is re-exported with TPU-alignment padding for the
q-head-group dimension.

Set ``interpret=False`` on real TPU hardware; this container is CPU-only so
every caller (tests, benches) uses the interpreter, which executes the same
kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.continuity import ContinuityConfig, ContinuityTable, KEY_LANES
from repro.kernels import mutate as _mutate
from repro.kernels import mutate_ref as _mutate_ref
from repro.kernels import paged_attn as _pa
from repro.kernels import probe as _probe
from repro.kernels import probe_ref as _probe_ref

BIG = 0x7FFFFFFF


@functools.lru_cache(maxsize=None)
def priority_table(cfg: ContinuityConfig) -> np.ndarray:
    """(2, SLOTS) probe rank per parity over MAIN slots (ext handled outside).

    Even homes: bucket then SBuckets, left->right. Odd homes: bucket then
    SBuckets, right->left (paper §III-C's directional scans).
    """
    S, bs, seg = cfg.slots_per_pair, cfg.bucket_slots, cfg.seg_slots
    prio = np.full((2, S), BIG, np.int32)
    prio[0, :seg] = np.arange(seg)
    odd_order = list(range(S - 1, bs - 1, -1))
    prio[1, odd_order] = np.arange(seg)
    return prio


def table_rows(table: ContinuityTable) -> jnp.ndarray:
    """Flatten main key storage into contiguous per-pair rows (P, SLOTS*KL)."""
    P, S, KL = table.keys.shape
    return table.keys.reshape(P, S * KL)


def probe_table(cfg: ContinuityConfig, table: ContinuityTable, keys,
                *, interpret: bool = True, use_kernel: bool = True,
                qblock: int = 8, use_fp: bool = False):
    """Probe the main segments of ``table`` for a batch of keys.

    ``qblock`` queries share one grid step (one VPU pass over their
    DMA-gathered segment rows). ``use_fp`` enables the fingerprint-word
    pre-filter (same results — visible slots always carry the correct
    field — but models the paper-style compare-reduction). Returns
    (match_slot, empty_slot, pair, parity); slots are -1 on miss/full.
    """
    from repro.core import continuity as ch  # local import to avoid cycle
    keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
    pair, parity = ch.locate(cfg, keys)
    rows = table_rows(table)
    ind = table.indicator[:, None]
    prio = jnp.asarray(priority_table(cfg))
    fps = table.fp if use_fp else None
    qfp = ch.fingerprint(keys) if use_fp else None
    if use_kernel:
        match, empty = _probe.probe_segments(
            rows, ind, prio, pair, parity, keys, fps, qfp,
            interpret=interpret, qblock=qblock)
    else:
        match, empty = _probe_ref.probe_ref(rows, ind, prio, pair, parity,
                                            keys, fps, qfp)
    return match, empty, pair, parity


def mutation_plan(cfg: ContinuityConfig, table: ContinuityTable, keys,
                  *, interpret: bool = True, use_kernel: bool = True,
                  qblock: int = 8):
    """Resolve the main-segment mutation plan for a batch of keys.

    The write-path peer of ``probe_table``: one contiguous row DMA per
    query resolves both the MATCH slot (the key's current home — the bit
    update/delete clears) and the VICTIM slot (first empty probe candidate
    — the bit update sets), plus ``flip``, the one-word XOR commit mask an
    uncontended update would store.  The fingerprint filter is always on
    (pure compare-reduction; visible slots carry correct fields).  The
    fused mutation engine (``continuity.update``/``delete`` with
    ``probe="pallas"``) consumes the match side and replays victim
    allocation only for multi-op pairs.  Returns (match, victim, flip),
    each (B,), slots -1 on miss/full.
    """
    from repro.core import continuity as ch  # local import to avoid cycle
    keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
    pair, parity = ch.locate(cfg, keys)
    rows = table_rows(table)
    ind = table.indicator[:, None]
    prio = jnp.asarray(priority_table(cfg))
    qfp = ch.fingerprint(keys)
    if use_kernel:
        return _mutate.mutate_segments(rows, ind, table.fp, prio, pair,
                                       parity, keys, qfp,
                                       interpret=interpret, qblock=qblock)
    return _mutate_ref.mutate_ref(rows, ind, table.fp, prio, pair, parity,
                                  keys, qfp)


def fp_filter_stats(cfg: ContinuityConfig, table: ContinuityTable, keys):
    """Main-segment key compares a probe batch performs with vs without the
    fingerprint pre-filter (the paper's Figs 7/14 quantity).

    Without the filter every OCCUPIED probe-candidate slot costs a 16-byte
    key compare; with it only slots whose 2-bit field equals the query's
    fingerprint do.  Returns a host-side dict with both totals and the
    reduction ratio — run it on a negative-search batch to reproduce the
    paper's claim (positive searches stop at the match either way).
    """
    from repro.core import continuity as ch
    keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
    pair, parity = ch.locate(cfg, keys)
    S = cfg.slots_per_pair
    iota = jnp.arange(S, dtype=jnp.uint32)[None, :]
    bits = (table.indicator[pair][:, None] >> iota) & jnp.uint32(1)
    prio = jnp.asarray(priority_table(cfg))
    pr = jnp.where(parity[:, None] == 0, prio[0][None, :], prio[1][None, :])
    occ = (bits == jnp.uint32(1)) & (pr < BIG)
    lane = jnp.where(iota < jnp.uint32(16),
                     table.fp[pair, 0:1], table.fp[pair, 1:2])
    field = (lane >> (jnp.uint32(2) * (iota % jnp.uint32(16)))) & jnp.uint32(3)
    qfp = ch.fingerprint(keys)
    pass_fp = occ & (field == qfp[:, None])
    no_fp = int(jnp.sum(occ))
    with_fp = int(jnp.sum(pass_fp))
    return {
        "queries": int(keys.shape[0]),
        "compares_no_fp": no_fp,
        "compares_with_fp": with_fp,
        "reduction": 1.0 - (with_fp / no_fp if no_fp else 0.0),
    }


def probe_lookup(cfg: ContinuityConfig, table: ContinuityTable, keys,
                 *, interpret: bool = True, use_kernel: bool = True,
                 qblock: int = 8, use_fp: bool = True):
    """Full continuity lookup with the Pallas kernel as the main-segment
    probe stage; byte-identical to ``repro.core.continuity.lookup``.

    The kernel resolves the directional main-segment scan (one contiguous
    row DMA per query, fingerprint pre-filter folded into the match rank);
    the rare extension-slot tail (the paper's "+1 fetch iff the pair has
    added SBuckets and the main segment missed") is a tiny jnp gather over
    the 12 ext candidates, and stash-enabled geometries get the same
    one-contiguous-fetch stash tail as the reference."""
    from repro.core import continuity as ch
    keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
    match, _, pair, parity = probe_table(
        cfg, table, keys, interpret=interpret, use_kernel=use_kernel,
        qblock=qblock, use_fp=use_fp)
    found_main = match >= 0
    safe_m = jnp.maximum(match, 0)
    vals_main = table.vals[pair, safe_m]

    # extension tail: slots S..S+E-1, ascending for BOTH parities (probe
    # order puts them last), only addressable when the pair is extended
    S, E = cfg.slots_per_pair, cfg.ext_slots
    eidx = table.ext_map[pair]                         # (B,)
    has_ext = eidx >= 0
    if E:
        ebits = (table.indicator[pair][:, None]
                 >> (S + jnp.arange(E, dtype=jnp.uint32))[None]) & jnp.uint32(1)
        ekeys = table.ext_keys[jnp.maximum(eidx, 0)]   # (B, E, KL)
        ematch = has_ext[:, None] & (ebits == 1) & \
            jnp.all(ekeys == keys[:, None, :], axis=-1)
        efound = jnp.any(ematch, axis=-1)
        efirst = jnp.argmax(ematch, axis=-1)
        evals = jnp.take_along_axis(
            table.ext_vals[jnp.maximum(eidx, 0)], efirst[:, None, None], 1)[:, 0]
    else:
        efound = jnp.zeros_like(found_main)
        efirst = jnp.zeros(keys.shape[0], jnp.int32)
        evals = jnp.zeros_like(vals_main)

    found = found_main | efound
    slot = jnp.where(found_main, match,
                     jnp.where(efound, S + efirst, -1))
    values = jnp.where(found_main[:, None], vals_main,
                       jnp.where(efound[:, None], evals, 0))
    reads = 1 + (has_ext & ~found_main).astype(jnp.int32)
    if cfg.stash_slots:
        # stash tail: one contiguous region fetch iff the pair's count byte
        # is non-zero and both main and extension missed (mirrors ch.lookup)
        found_me = found
        home = pair.astype(jnp.uint32) + jnp.uint32(1)
        smatch = (table.stash_meta[None, :] == home[:, None]) & jnp.all(
            table.stash_keys[None, :, :] == keys[:, None, :], axis=-1)
        sfound = jnp.any(smatch, axis=-1) & ~found
        sfirst = jnp.argmax(smatch, axis=-1).astype(jnp.int32)
        values = jnp.where(sfound[:, None], table.stash_vals[sfirst], values)
        slot = jnp.where(sfound, cfg.total_bits + sfirst, slot)
        found = found | sfound
        reads = reads + ((ch.stash_count(table, pair) > 0)
                         & ~found_me).astype(jnp.int32)
    return ch.LookupResult(found, values, slot, pair, reads)


def paged_attention(q, kpool, vpool, page_table, seq_lens, *,
                    scale: float | None = None, interpret: bool = True,
                    use_kernel: bool = True):
    """Paged GQA decode attention; pads the q-head group dim to >=8 sublanes
    so the kernel block shapes are TPU-tileable, then unpads."""
    if not use_kernel:
        from repro.kernels.paged_attn_ref import paged_attention_ref
        return paged_attention_ref(q, kpool, vpool, page_table, seq_lens,
                                   scale=scale)
    B, H, D = q.shape
    KVH = kpool.shape[1]
    G = H // KVH
    pad = 0
    if G < 8:
        pad = 8 - G
        qg = q.reshape(B, KVH, G, D)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q = qg.reshape(B, KVH * (G + pad), D)
    out = _pa.paged_attention(q, kpool, vpool, page_table, seq_lens,
                              scale=scale, interpret=interpret)
    if pad:
        out = out.reshape(B, KVH, G + pad, D)[:, :, :G].reshape(B, H, D)
    return out

"""Jit'd public wrappers around the Pallas kernels.

``probe_table`` adapts a ``ContinuityTable`` into the probe kernel's layout
(flat contiguous rows + parity priority table) and returns results identical
to ``repro.core.continuity.lookup``'s probe stage. ``paged_attention`` is
re-exported with TPU-alignment padding for the q-head-group dimension.

Set ``interpret=False`` on real TPU hardware; this container is CPU-only so
every caller (tests, benches) uses the interpreter, which executes the same
kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.continuity import ContinuityConfig, ContinuityTable, KEY_LANES
from repro.kernels import paged_attn as _pa
from repro.kernels import probe as _probe
from repro.kernels import probe_ref as _probe_ref

BIG = 0x7FFFFFFF


@functools.lru_cache(maxsize=None)
def priority_table(cfg: ContinuityConfig) -> np.ndarray:
    """(2, SLOTS) probe rank per parity over MAIN slots (ext handled outside).

    Even homes: bucket then SBuckets, left->right. Odd homes: bucket then
    SBuckets, right->left (paper §III-C's directional scans).
    """
    S, bs, seg = cfg.slots_per_pair, cfg.bucket_slots, cfg.seg_slots
    prio = np.full((2, S), BIG, np.int32)
    prio[0, :seg] = np.arange(seg)
    odd_order = list(range(S - 1, bs - 1, -1))
    prio[1, odd_order] = np.arange(seg)
    return prio


def table_rows(table: ContinuityTable) -> jnp.ndarray:
    """Flatten main key storage into contiguous per-pair rows (P, SLOTS*KL)."""
    P, S, KL = table.keys.shape
    return table.keys.reshape(P, S * KL)


def probe_table(cfg: ContinuityConfig, table: ContinuityTable, keys,
                *, interpret: bool = True, use_kernel: bool = True,
                qblock: int = 8):
    """Probe the main segments of ``table`` for a batch of keys.

    ``qblock`` queries share one grid step (one VPU pass over their
    DMA-gathered segment rows). Returns (match_slot, empty_slot, pair,
    parity); slots are -1 on miss/full.
    """
    from repro.core.continuity import locate  # local import to avoid cycle
    keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
    pair, parity = locate(cfg, keys)
    rows = table_rows(table)
    ind = table.indicator[:, None]
    prio = jnp.asarray(priority_table(cfg))
    if use_kernel:
        match, empty = _probe.probe_segments(
            rows, ind, prio, pair, parity, keys, interpret=interpret,
            qblock=qblock)
    else:
        match, empty = _probe_ref.probe_ref(rows, ind, prio, pair, parity,
                                            keys)
    return match, empty, pair, parity


def paged_attention(q, kpool, vpool, page_table, seq_lens, *,
                    scale: float | None = None, interpret: bool = True,
                    use_kernel: bool = True):
    """Paged GQA decode attention; pads the q-head group dim to >=8 sublanes
    so the kernel block shapes are TPU-tileable, then unpads."""
    if not use_kernel:
        from repro.kernels.paged_attn_ref import paged_attention_ref
        return paged_attention_ref(q, kpool, vpool, page_table, seq_lens,
                                   scale=scale)
    B, H, D = q.shape
    KVH = kpool.shape[1]
    G = H // KVH
    pad = 0
    if G < 8:
        pad = 8 - G
        qg = q.reshape(B, KVH, G, D)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q = qg.reshape(B, KVH * (G + pad), D)
    out = _pa.paged_attention(q, kpool, vpool, page_table, seq_lens,
                              scale=scale, interpret=interpret)
    if pad:
        out = out.reshape(B, KVH, G + pad, D)[:, :, :G].reshape(B, H, D)
    return out

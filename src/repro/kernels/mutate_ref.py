"""Pure-jnp reference for the Pallas mutation-plan kernel.

Same contract as ``mutate.mutate_segments`` — the interpret-mode oracle
the identity tests diff the kernel against, and the backend ``ops``
selects when the kernel is disabled (``use_kernel=False``).  Mirrors
``probe_ref.probe_ref`` structurally: gather the per-query segment row,
run the directional fp-filtered rank math as one (B, S) pass.
"""

from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
I32 = jnp.int32
BIG = 0x7FFFFFFF


def mutate_ref(rows, indicators, fps, prio, pairs, parity, qkeys, qfp):
    """Returns (match_slot, victim_slot, flip); see mutate.mutate_segments."""
    P, RL = rows.shape
    B, KL = qkeys.shape
    S = RL // KL
    seg = rows[pairs].reshape(B, S, KL)
    eq = jnp.all(seg == qkeys[:, None, :], axis=-1)           # (B, S)
    iota = jnp.arange(S, dtype=U32)[None, :]
    bits = (indicators[pairs] >> iota) & U32(1)               # (B, S)
    lane = jnp.where(iota < U32(16), fps[pairs, 0:1], fps[pairs, 1:2])
    field = (lane >> (U32(2) * (iota % U32(16)))) & U32(3)
    eq = eq & (field == qfp.astype(U32)[:, None])             # fp pre-filter
    pr = jnp.where(parity[:, None] == 0, prio[0][None, :], prio[1][None, :])
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == U32(1)) & cand, pr, BIG)
    vrank = jnp.where((bits == U32(0)) & cand, pr, BIG)
    mslot = jnp.argmin(mrank, axis=-1).astype(I32)
    vslot = jnp.argmin(vrank, axis=-1).astype(I32)
    mfound = jnp.min(mrank, -1) < BIG
    vfound = jnp.min(vrank, -1) < BIG
    match = jnp.where(mfound, mslot, -1)
    victim = jnp.where(vfound, vslot, -1)
    flip = (jnp.where(mfound, U32(1) << mslot.astype(U32), U32(0))
            | jnp.where(vfound, U32(1) << vslot.astype(U32), U32(0)))
    return match, victim, flip

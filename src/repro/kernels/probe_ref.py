"""Pure-jnp oracle for the continuity segment-probe kernel."""

from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
BIG = 0x7FFFFFFF  # python int: safe to create at import time inside a trace


def probe_ref(rows: jnp.ndarray, indicators: jnp.ndarray, prio: jnp.ndarray,
              pairs: jnp.ndarray, parity: jnp.ndarray, qkeys: jnp.ndarray,
              fps: jnp.ndarray | None = None,
              qfp: jnp.ndarray | None = None):
    """Reference segment probe.

    Args:
      rows:       (P, SLOTS*KL) uint32 — flattened contiguous segment-pair rows
      indicators: (P, 1) uint32
      prio:       (2, SLOTS) int32 probe rank per parity (BIG = not a candidate)
      pairs:      (B,) int32 — home pair per query
      parity:     (B,) int32
      qkeys:      (B, KL) uint32
      fps:        optional (P, 2) uint32 fingerprint-word lanes (2-bit field
                  per main slot); with ``qfp`` (B,) the probe pre-filters on
                  the field before the full key compare — never drops a true
                  match because visible slots always carry the correct field
      qfp:        optional (B,) uint32 query fingerprints
    Returns:
      match_slot (B,) int32 (-1 = miss), empty_slot (B,) int32 (-1 = full)
    """
    P, RL = rows.shape
    B, KL = qkeys.shape
    S = RL // KL
    seg = rows[pairs].reshape(B, S, KL)
    eq = jnp.all(seg == qkeys[:, None, :], axis=-1)
    ind = indicators[pairs, 0]
    bits = (ind[:, None] >> jnp.arange(S, dtype=U32)[None]) & U32(1)
    if fps is not None:
        s = jnp.arange(S)
        lane = jnp.where(s[None] < 16, fps[pairs, 0:1], fps[pairs, 1:2])
        field = (lane >> U32(2 * (s % 16))[None]) & U32(3)   # (B, S)
        eq = eq & (field == qfp.astype(U32)[:, None])
    pr = prio[parity]                                    # (B, S)
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == 1) & cand, pr, BIG)
    erank = jnp.where((bits == 0) & cand, pr, BIG)
    mbest = jnp.min(mrank, -1)
    ebest = jnp.min(erank, -1)
    match_slot = jnp.where(mbest < BIG, jnp.argmin(mrank, -1), -1)
    empty_slot = jnp.where(ebest < BIG, jnp.argmin(erank, -1), -1)
    return match_slot.astype(jnp.int32), empty_slot.astype(jnp.int32)

"""Pallas TPU kernel: batched continuity-segment probe.

The defining property of continuity hashing — every candidate position of a
key lives in ONE contiguous memory region (the segment) — maps onto the TPU
as follows: the segment-pair row index is scalar-prefetched and used in the
``BlockSpec`` index map, so the Pallas pipeline issues exactly ONE contiguous
HBM->VMEM DMA per query (the analogue of the paper's single one-sided RDMA
read), double-buffered across the grid so the DMA of query ``i+1`` overlaps
the probe of query ``i`` (the analogue of RDMA doorbell pipelining).

Layout notes for real TPUs (validated here in interpret mode):
  * the row stride should be padded to a multiple of 128 lanes
    (SLOTS*KEY_LANES = 80 -> 128 for the default geometry; ops.py pads);
  * all probe math is 2-D ``(1, S)`` so iota/argmin lower on TPU;
  * compute per step is a few hundred VPU ops — the kernel is DMA-bound by
    design (it is a memory-streaming index probe, like the RDMA original).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
I32 = jnp.int32
BIG = 0x7FFFFFFF  # python int: stays a kernel-embedded literal


def _probe_kernel(pairs_ref, parity_ref, rows_ref, ind_ref, prio_ref, qk_ref,
                  match_ref, empty_ref, *, slots: int, key_lanes: int):
    del pairs_ref, parity_ref  # consumed by the index maps
    row = rows_ref[0]                               # (SLOTS*KL,) one segment row
    seg = row.reshape(slots, key_lanes)             # (S, KL)
    qk = qk_ref[0]                                  # (KL,)
    eq = jnp.all(seg == qk[None, :], axis=-1)[None]           # (1, S)
    ind = ind_ref[0, 0]
    iota = jax.lax.broadcasted_iota(U32, (1, slots), 1)
    bits = (ind >> iota) & U32(1)                             # (1, S)
    pr = prio_ref[0][None]                                    # (1, S)
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == U32(1)) & cand, pr, BIG)
    erank = jnp.where((bits == U32(0)) & cand, pr, BIG)
    mslot = jnp.argmin(mrank, axis=-1).astype(I32)
    eslot = jnp.argmin(erank, axis=-1).astype(I32)
    match_ref[0, 0] = jnp.where(jnp.min(mrank) < BIG, mslot[0], I32(-1))
    empty_ref[0, 0] = jnp.where(jnp.min(erank) < BIG, eslot[0], I32(-1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_segments(rows, indicators, prio, pairs, parity, qkeys, *,
                   interpret: bool = True):
    """Probe one contiguous segment row per query.

    Args mirror ``probe_ref.probe_ref``. Returns (match_slot, empty_slot),
    each (B,) int32 with -1 for miss/full.
    """
    P, RL = rows.shape
    B, KL = qkeys.shape
    S = RL // KL
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # pairs, parity
        grid=(B,),
        in_specs=[
            # ONE contiguous segment-pair row per grid step (the RDMA read)
            pl.BlockSpec((1, RL), lambda i, pairs, par: (pairs[i], 0)),
            pl.BlockSpec((1, 1), lambda i, pairs, par: (pairs[i], 0)),
            pl.BlockSpec((1, S), lambda i, pairs, par: (par[i], 0)),
            pl.BlockSpec((1, KL), lambda i, pairs, par: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, pairs, par: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, pairs, par: (i, 0)),
        ],
    )
    kernel = functools.partial(_probe_kernel, slots=S, key_lanes=KL)
    match, empty = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), I32),
            jax.ShapeDtypeStruct((B, 1), I32),
        ],
        interpret=interpret,
    )(pairs.astype(I32), parity.astype(I32), rows, indicators, prio, qkeys)
    return match[:, 0], empty[:, 0]

"""Pallas TPU kernel: batched continuity-segment probe.

The defining property of continuity hashing — every candidate position of a
key lives in ONE contiguous memory region (the segment) — maps onto the TPU
as follows: the table stays in HBM (``pl.ANY``) and each query issues exactly
ONE contiguous HBM->VMEM row DMA for its segment-pair row (the analogue of
the paper's single one-sided RDMA read), plus the tiny indicator word that
physically heads the same region.

Each grid step processes a BLOCK of ``qblock`` queries: the per-query row
DMAs are issued back-to-back into a VMEM scratch tile (the analogue of RDMA
doorbell batching) and the probe math for the whole block then runs as one
vectorized (Q, S) VPU pass — amortizing grid/dispatch overhead over the
block while preserving the one-contiguous-DMA-per-segment property. The
query-side inputs (query keys, parity) are streamed through the normal
Pallas pipeline, double-buffered across grid steps.

Layout notes for real TPUs (validated here in interpret mode):
  * the row stride should be padded to a multiple of 128 lanes
    (SLOTS*KEY_LANES = 80 -> 128 for the default geometry; ops.py pads);
  * all probe math is 2-D ``(Q, S)`` so iota/argmin lower on TPU;
  * compute per step is a few hundred VPU ops — the kernel is DMA-bound by
    design (it is a memory-streaming index probe, like the RDMA original).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
I32 = jnp.int32
BIG = 0x7FFFFFFF  # python int: stays a kernel-embedded literal


def _probe_kernel(pairs_ref, rows_ref, ind_ref, prio_ref, parity_ref, qk_ref,
                  match_ref, empty_ref, seg_vmem, ind_vmem, sem, *,
                  slots: int, key_lanes: int, qblock: int):
    i = pl.program_id(0)

    # ONE contiguous DMA per query: the segment-pair row, plus its indicator
    # word (physically the head of the same contiguous region; a separate
    # copy only because the reference layout stores indicators in their own
    # array). All 2*qblock copies are STARTED before any wait — the block's
    # DMAs are in flight concurrently (the doorbell-batching analogue) and
    # single-query latency is not serialized across the block.
    def start(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).start()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).start()
        return carry

    def wait(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).wait()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).wait()
        return carry

    jax.lax.fori_loop(0, qblock, start, 0)
    jax.lax.fori_loop(0, qblock, wait, 0)

    seg = seg_vmem[...].reshape(qblock, slots, key_lanes)
    qk = qk_ref[...]                                          # (Q, KL)
    eq = jnp.all(seg == qk[:, None, :], axis=-1)              # (Q, S)
    iota = jax.lax.broadcasted_iota(U32, (qblock, slots), 1)
    bits = (ind_vmem[...] >> iota) & U32(1)                   # (Q,1)>>(Q,S)
    pr = jnp.where(parity_ref[...] == 0,
                   prio_ref[0][None, :], prio_ref[1][None, :])  # (Q, S)
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == U32(1)) & cand, pr, BIG)
    erank = jnp.where((bits == U32(0)) & cand, pr, BIG)
    mslot = jnp.argmin(mrank, axis=-1).astype(I32)
    eslot = jnp.argmin(erank, axis=-1).astype(I32)
    match_ref[...] = jnp.where(jnp.min(mrank, -1) < BIG, mslot, -1)[:, None]
    empty_ref[...] = jnp.where(jnp.min(erank, -1) < BIG, eslot, -1)[:, None]


def _probe_kernel_fp(pairs_ref, rows_ref, ind_ref, fps_ref, prio_ref,
                     parity_ref, qk_ref, qfp_ref, match_ref, empty_ref,
                     seg_vmem, ind_vmem, fp_vmem, sem, *,
                     slots: int, key_lanes: int, qblock: int):
    """Fingerprint-filtering variant: the 8-byte fp word is indicator-
    adjacent in the physical row, so its copy rides the SAME contiguous
    region fetch — the match rank just gains a 2-bit field pre-filter.
    Never drops a true match: visible slots always carry the correct field
    (inserts/updates set the NEW slot's field before the commit)."""
    i = pl.program_id(0)

    def start(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).start()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).start()
        pltpu.make_async_copy(fps_ref.at[p], fp_vmem.at[q], sem).start()
        return carry

    def wait(q, carry):
        p = pairs_ref[i * qblock + q]
        pltpu.make_async_copy(rows_ref.at[p], seg_vmem.at[q], sem).wait()
        pltpu.make_async_copy(ind_ref.at[p], ind_vmem.at[q], sem).wait()
        pltpu.make_async_copy(fps_ref.at[p], fp_vmem.at[q], sem).wait()
        return carry

    jax.lax.fori_loop(0, qblock, start, 0)
    jax.lax.fori_loop(0, qblock, wait, 0)

    seg = seg_vmem[...].reshape(qblock, slots, key_lanes)
    qk = qk_ref[...]                                          # (Q, KL)
    eq = jnp.all(seg == qk[:, None, :], axis=-1)              # (Q, S)
    iota = jax.lax.broadcasted_iota(U32, (qblock, slots), 1)
    bits = (ind_vmem[...] >> iota) & U32(1)                   # (Q,1)>>(Q,S)
    lane = jnp.where(iota < U32(16), fp_vmem[:, 0:1], fp_vmem[:, 1:2])
    field = (lane >> (U32(2) * (iota % U32(16)))) & U32(3)    # (Q, S)
    eq = eq & (field == qfp_ref[...])                         # fp pre-filter
    pr = jnp.where(parity_ref[...] == 0,
                   prio_ref[0][None, :], prio_ref[1][None, :])  # (Q, S)
    cand = pr < BIG
    mrank = jnp.where(eq & (bits == U32(1)) & cand, pr, BIG)
    erank = jnp.where((bits == U32(0)) & cand, pr, BIG)
    mslot = jnp.argmin(mrank, axis=-1).astype(I32)
    eslot = jnp.argmin(erank, axis=-1).astype(I32)
    match_ref[...] = jnp.where(jnp.min(mrank, -1) < BIG, mslot, -1)[:, None]
    empty_ref[...] = jnp.where(jnp.min(erank, -1) < BIG, eslot, -1)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret", "qblock"))
def probe_segments(rows, indicators, prio, pairs, parity, qkeys,
                   fps=None, qfp=None, *,
                   interpret: bool = True, qblock: int = 8):
    """Probe one contiguous segment row per query, ``qblock`` queries per
    grid step.

    Args mirror ``probe_ref.probe_ref``; ``fps``/``qfp`` (both or neither)
    enable the fingerprint pre-filter.  Returns (match_slot, empty_slot),
    each (B,) int32 with -1 for miss/full.
    """
    P, RL = rows.shape
    B, KL = qkeys.shape
    S = RL // KL
    use_fp = fps is not None
    nb = max(1, -(-B // qblock))
    pad = nb * qblock - B
    pairs = jnp.pad(pairs.astype(I32), (0, pad))
    parity = jnp.pad(parity.astype(I32), (0, pad))[:, None]
    qkeys = jnp.pad(qkeys, ((0, pad), (0, 0)))
    in_specs = [
        pl.BlockSpec(memory_space=pl.ANY),         # rows stay in HBM
        pl.BlockSpec(memory_space=pl.ANY),         # indicators stay in HBM
    ]
    scratch = [
        pltpu.VMEM((qblock, RL), U32),             # per-block segment tile
        pltpu.VMEM((qblock, 1), U32),              # per-block indicators
    ]
    operands = [rows, indicators]
    if use_fp:
        qfp = jnp.pad(qfp.astype(U32), (0, pad))[:, None]
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))   # fp words in HBM
        scratch.append(None)                       # placeholder, reordered below
        operands.append(fps)
    in_specs += [
        pl.BlockSpec((2, S), lambda i, pairs: (0, 0)),
        pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
        pl.BlockSpec((qblock, KL), lambda i, pairs: (i, 0)),
    ]
    operands += [prio, parity, qkeys]
    if use_fp:
        in_specs.append(pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)))
        operands.append(qfp)
        scratch[2] = pltpu.VMEM((qblock, 2), U32)  # per-block fp words
        kernel = functools.partial(_probe_kernel_fp, slots=S, key_lanes=KL,
                                   qblock=qblock)
    else:
        scratch = scratch[:2]
        kernel = functools.partial(_probe_kernel, slots=S, key_lanes=KL,
                                   qblock=qblock)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # pairs drive the row DMAs
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
            pl.BlockSpec((qblock, 1), lambda i, pairs: (i, 0)),
        ],
        scratch_shapes=scratch + [pltpu.SemaphoreType.DMA(())],
    )
    match, empty = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb * qblock, 1), I32),
            jax.ShapeDtypeStruct((nb * qblock, 1), I32),
        ],
        interpret=interpret,
    )(pairs, *operands)
    return match[:B, 0], empty[:B, 0]

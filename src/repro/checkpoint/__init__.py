"""Checkpointing: two-phase atomic commit, async save, restart recovery."""

from repro.checkpoint.manager import CheckpointManager  # noqa: F401

"""Checkpoint manager with the paper's indicator discipline lifted to storage.

Continuity hashing's crash consistency rule — write the payload first, then
flip the indicator with ONE atomic store — becomes, at checkpoint scale:

  1. write every shard payload file under ``step_N.tmp/`` and fsync each;
  2. write a manifest (the "indicator") listing payload digests;
  3. atomically ``rename(step_N.tmp, step_N)`` — the single atomic commit.

A crash before (3) leaves only a .tmp directory that restart ignores
(= the partial write is invisible, paper §III-C); after (3) the checkpoint is
complete by construction. Saves run on a background thread (async checkpoint:
the train loop only blocks on device->host transfer, not on disk). Restore
picks the newest COMMITTED step; ``keep`` bounds disk usage.

Restart recovery of an interrupted hash-table resize is in
``repro.core.continuity.recover`` — the manager just persists both tables
plus the resize cursor so recovery can run.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _flatten(tree):
    """Canonical (jax.tree-ordered) {dotted-path: leaf} mapping."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {".".join(_key_str(p) for p in path) or "_root": leaf
            for path, leaf in leaves}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot to host, then commit (optionally) in the background."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}   # D2H barrier
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._commit, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._commit(step, host, extra or {})

    def _commit(self, step: int, host: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        for k, v in host.items():
            path = os.path.join(tmp, k.replace("/", "_") + ".npy")
            with open(path, "wb") as f:                 # phase 1: payloads
                np.save(f, v)
                f.flush()
                os.fsync(f.fileno())
            manifest["arrays"][k] = {
                "file": os.path.basename(path), "shape": list(v.shape),
                "dtype": str(v.dtype),
                "digest": hashlib.sha256(v.tobytes()).hexdigest()[:16]}
        mpath = os.path.join(tmp, "MANIFEST.json")
        with open(mpath, "w") as f:                     # phase 2: indicator
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                           # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def committed_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):                   # uncommitted: invisible
                continue
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Restore into the structure of ``template``; verifies digests.
        Returns (tree, step, extra) or (None, None, None) if no checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        d = os.path.join(self.dir, f"step_{step:09d}")
        manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
        arrays = {}
        for k, meta in manifest["arrays"].items():
            v = np.load(os.path.join(d, meta["file"]))
            dig = hashlib.sha256(v.tobytes()).hexdigest()[:16]
            if dig != meta["digest"]:
                raise IOError(f"digest mismatch for {k} in step {step}")
            arrays[k] = v
        flat_t = _flatten(template)
        missing = set(flat_t) - set(arrays)
        if missing:
            raise KeyError(f"checkpoint step {step} missing {sorted(missing)[:5]}")
        rebuilt = jax.tree.unflatten(
            jax.tree.structure(template),
            [arrays[k] for k in _flatten(template)])
        return rebuilt, step, manifest["extra"]

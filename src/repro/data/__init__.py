"""Data layer: YCSB op-stream generators + deterministic token pipeline."""

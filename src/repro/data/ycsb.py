"""YCSB workload generators (Cooper et al., SoCC'10) matching the paper §V-A.

Workloads over 16-byte keys / 16-byte values (paper: 16 B keys, <=15 B
values):
  A: 50% update / 50% read          (update-heavy)
  B: 95% read / 5% update           (read-mostly)
  C: 100% read                      (read-only; positive search)
  D: 95% read / 5% insert, reads target LATEST inserts (read-latest)
  E: 95% scan / 5% insert           (short range scans — the workload
                                     continuity's contiguous SBuckets
                                     are built for: a scan is ONE
                                     contiguous segment-range READ)
  F: 50% read / 50% read-modify-write
plus the paper's microbenchmarks: insert-only, update-only, delete-only,
positive/negative search.

Request distributions: zipfian (theta=0.99, YCSB default) for A/B/C/F,
"latest" for D, uniform for microbenchmarks.  E's scan lengths are
uniform on [1, MAX_SCAN_LEN] (YCSB's uniform default, shortened to keep
sim cells small).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

OP_READ, OP_UPDATE, OP_INSERT, OP_RMW, OP_DELETE, OP_SCAN = 0, 1, 2, 3, 4, 5

MAX_SCAN_LEN = 16       # YCSB-E max scan length (uniform in [1, max])

WORKLOADS = {
    "A": [(OP_READ, 0.5), (OP_UPDATE, 0.5)],
    "B": [(OP_READ, 0.95), (OP_UPDATE, 0.05)],
    "C": [(OP_READ, 1.0)],
    "D": [(OP_READ, 0.95), (OP_INSERT, 0.05)],
    "E": [(OP_SCAN, 0.95), (OP_INSERT, 0.05)],
    "F": [(OP_READ, 0.5), (OP_RMW, 0.5)],
}


def scan_lengths(rng: np.random.RandomState, n: int,
                 max_len: int = MAX_SCAN_LEN) -> np.ndarray:
    """YCSB-E scan lengths: uniform integers in [1, max_len]."""
    return rng.randint(1, max_len + 1, size=n)


def make_key(ids: np.ndarray) -> np.ndarray:
    """64-bit record ids -> (N, 4) uint32 16-byte keys (YCSB 'user###' style:
    deterministic, well-spread)."""
    ids = ids.astype(np.uint64)
    lo = (ids & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (ids >> np.uint64(32)).astype(np.uint32)
    salt = (lo * np.uint32(2654435761)) ^ np.uint32(0xDEADBEEF)
    return np.stack([lo, hi, salt, np.uint32(0x59435342)
                     * np.ones_like(lo)], -1)


def make_value(rng: np.random.RandomState, n: int) -> np.ndarray:
    return rng.randint(0, 2 ** 31, size=(n, 4)).astype(np.uint32)


class Zipf:
    """Gray et al. zipfian generator over [0, n) with theta=0.99 (YCSB)."""

    def __init__(self, n: int, theta: float = 0.99):
        self.n = n
        self.theta = theta
        zetan = np.sum(1.0 / np.arange(1, n + 1) ** theta)
        self.zetan = zetan
        self.alpha = 1.0 / (1.0 - theta)
        zeta2 = np.sum(1.0 / np.arange(1, 3) ** theta)
        self.eta = (1 - (2.0 / n) ** (1 - theta)) / (1 - zeta2 / zetan)

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        u = rng.random_sample(size)
        uz = u * self.zetan
        out = np.where(uz < 1.0, 0,
                       np.where(uz < 1.0 + 0.5 ** self.theta, 1,
                                (self.n * (self.eta * u - self.eta + 1)
                                 ** self.alpha).astype(np.int64)))
        return np.clip(out, 0, self.n - 1)


class Hotspot:
    """YCSB hotspot distribution: ``hot_op_frac`` of requests hit the
    first ``hot_frac`` of the keyspace uniformly, the rest hit the cold
    remainder uniformly (the cluster sim's shard-imbalance stressor)."""

    def __init__(self, n: int, hot_frac: float = 0.2,
                 hot_op_frac: float = 0.8):
        assert 0.0 < hot_frac < 1.0 and 0.0 < hot_op_frac < 1.0
        self.n = n
        self.hot = max(1, int(n * hot_frac))
        self.hot_op_frac = hot_op_frac

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        is_hot = rng.random_sample(size) < self.hot_op_frac
        hot_ids = rng.randint(0, self.hot, size=size)
        cold_ids = (rng.randint(0, max(1, self.n - self.hot), size=size)
                    + self.hot) % self.n
        return np.where(is_hot, hot_ids, cold_ids)


def request_stream(dist: str, n: int, *, theta: float = 0.99,
                   hot_frac: float = 0.2, hot_op_frac: float = 0.8):
    """The ONE factory for skewed request streams: every sim (rdma,
    cluster, cache fan-in) builds its stream here so the skew knobs —
    zipf ``theta``, hotspot ``hot_frac``/``hot_op_frac`` — are sweepable
    end to end instead of baked into each caller."""
    if dist == "zipf":
        return Zipf(n, theta=theta)
    assert dist == "hotspot", dist
    return Hotspot(n, hot_frac=hot_frac, hot_op_frac=hot_op_frac)


def stream_self_check(stream, rng: np.random.RandomState,
                      samples: int = 20_000, tol: float = 0.05) -> dict:
    """Tiny distribution audit (the cache tests gate on it): draw
    ``samples`` ranks and compare the measured hot mass to the stream's
    analytic expectation.  Hotspot: the fraction of draws landing inside
    the hot set must match ``hot_op_frac`` (the cold branch never wraps
    into the hot range by construction).  Zipf: the mass on the top 1% of
    ranks must match the partial zeta sum.  A sim whose 'hotspot' is not
    actually hot would silently void every cache claim downstream."""
    ranks = stream.sample(rng, samples)
    if isinstance(stream, Hotspot):
        measured = float((ranks < stream.hot).mean())
        expected = float(stream.hot_op_frac)
    else:
        k = max(1, stream.n // 100)
        measured = float((ranks < k).mean())
        expected = float(np.sum(1.0 / np.arange(1, k + 1) ** stream.theta)
                         / stream.zetan)
    return {"ok": bool(abs(measured - expected) <= tol),
            "measured": measured, "expected": expected, "tol": tol,
            "samples": samples}


@dataclasses.dataclass
class OpBatch:
    ops: np.ndarray     # (B,) int32 op codes
    keys: np.ndarray    # (B, 4) uint32
    vals: np.ndarray    # (B, 4) uint32


def generate(workload: str, num_records: int, num_ops: int,
             batch: int, seed: int = 0,
             theta: float = 0.99) -> Iterator[OpBatch]:
    """Yield op batches for a YCSB workload over a preloaded keyspace of
    ``num_records`` records (load phase is the caller's insert of ids
    [0, num_records)).  ``theta`` sweeps the request-skew exponent."""
    rng = np.random.RandomState(seed)
    mix = WORKLOADS[workload]
    codes = np.array([c for c, _ in mix])
    probs = np.array([p for _, p in mix])
    zipf = Zipf(num_records, theta=theta)
    next_insert = num_records
    done = 0
    while done < num_ops:
        b = min(batch, num_ops - done)
        ops = rng.choice(codes, size=b, p=probs).astype(np.int32)
        if workload == "D":     # read-latest: skew toward newest ids
            lat = next_insert - 1 - zipf.sample(rng, b)
            ids = np.clip(lat, 0, None)
        else:
            ids = zipf.sample(rng, b)
        ins = ops == OP_INSERT
        n_ins = int(ins.sum())
        if n_ins:
            ids = ids.copy()
            ids[ins] = np.arange(next_insert, next_insert + n_ins)
            next_insert += n_ins
        yield OpBatch(ops=ops, keys=make_key(ids),
                      vals=make_value(rng, b))
        done += b


def negative_keys(rng: np.random.RandomState, num_records: int,
                  n: int) -> np.ndarray:
    """Keys guaranteed absent (ids beyond the loaded range)."""
    ids = num_records + 10_000_000 + rng.randint(0, 2 ** 30, size=n)
    return make_key(ids.astype(np.int64))

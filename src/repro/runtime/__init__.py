"""Cluster runtime: failure handling, elastic rescale, straggler mitigation."""

"""Fault tolerance and elasticity for 1000+-node deployments.

TPU pods run SPMD: a single chip failure kills the step on every peer, so
fault tolerance is structured as detect -> replace/shrink -> restore ->
replay, not per-node recovery. This module provides the control-plane logic;
it is exercised in simulation (tests/test_fault.py) since the container has
one real device, and every piece composes from primitives that are real here:
deterministic data order, two-phase checkpoints, mesh-shape-agnostic
sharding rules.

Components:
  * HeartbeatMonitor — failure detection with configurable timeout;
  * plan_remesh — elastic rescale: given the surviving chip count, pick the
    largest valid mesh (data axis shrinks first — TP degree is fixed by
    memory, DP is the elastic axis) and return the new mesh shape + the
    steps/batches to replay;
  * DeterministicSchedule — data order is a pure function of (step, shard),
    so replay after restore is exact (no persisted dataloader state needed);
  * page_table_recovery_drill — the PM side of restore: replay the hash-
    store recovery procedure over every shard's crashed page-table image
    (composes with repro.consistency's crash injector);
  * StragglerPolicy — synchronous-collective straggler mitigation: track
    per-host step latencies (TPU steps are globally synchronized, so the
    slowest host IS the step time), flag persistent outliers for replacement
    with hot spares; optional microbatch rebalancing hook.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    last_seen: float
    step: int = 0
    latencies_ms: Optional[List[float]] = None


class HeartbeatMonitor:
    """Failure detection. Hosts report (host_id, step) heartbeats; a host
    silent for ``timeout_s`` becomes SUSPECT, and only after a further
    ``grace_s`` of silence is it declared failed.

    The two-phase declaration distinguishes "node dead" from "node
    partitioned but alive": a partition that heals inside the grace
    window resumes heartbeating, the suspicion clears, and no failover
    fires — without the window, a transient partition and a crash are
    indistinguishable and the controller double-promotes a primary that
    is still alive on the far side.  ``grace_s=0`` keeps the original
    single-timeout behaviour."""

    def __init__(self, timeout_s: float = 30.0, clock=time.monotonic,
                 grace_s: float = 0.0):
        self.timeout = timeout_s
        self.grace = grace_s
        self.clock = clock
        self.hosts: Dict[str, HostState] = {}
        self.suspicions_cleared = 0     # suspect hosts that came back

    def register(self, host_id: str):
        self.hosts[host_id] = HostState(last_seen=self.clock(),
                                        latencies_ms=[])

    def heartbeat(self, host_id: str, step: int,
                  step_latency_ms: Optional[float] = None):
        st = self.hosts[host_id]
        if self.state(host_id) == "suspect":
            self.suspicions_cleared += 1    # partitioned-but-alive came back
        st.last_seen = self.clock()
        st.step = step
        if step_latency_ms is not None:
            st.latencies_ms.append(step_latency_ms)
            del st.latencies_ms[:-100]

    def state(self, host_id: str) -> str:
        """``alive`` | ``suspect`` (silent past timeout, inside the grace
        window) | ``failed`` (silent past timeout + grace)."""
        silent = self.clock() - self.hosts[host_id].last_seen
        if silent > self.timeout + self.grace:
            return "failed"
        return "suspect" if silent > self.timeout else "alive"

    def suspect_hosts(self) -> List[str]:
        return [h for h in self.hosts if self.state(h) == "suspect"]

    def failed_hosts(self) -> List[str]:
        return [h for h in self.hosts if self.state(h) == "failed"]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    restore_step: int
    replay_steps: int
    dropped_chips: int


def plan_remesh(total_chips: int, failed_chips: int, model_axis: int,
                checkpoint_step: int, current_step: int,
                pod_axis: int = 1) -> RemeshPlan:
    """Elastic rescale after losing ``failed_chips``.

    TP (model axis) is fixed — it is set by per-chip memory. The DATA axis is
    elastic: shrink it to the largest value that fits the survivors. Global
    batch stays constant (microbatch count rises), so training dynamics are
    unchanged; throughput degrades proportionally instead of stopping.
    """
    survivors = total_chips - failed_chips
    per_replica = model_axis * pod_axis
    new_data = survivors // per_replica
    if new_data < 1:
        raise RuntimeError("not enough survivors for one model replica")
    shape = ((pod_axis, new_data, model_axis) if pod_axis > 1
             else (new_data, model_axis))
    axes = (("pod", "data", "model") if pod_axis > 1 else ("data", "model"))
    return RemeshPlan(
        mesh_shape=shape, mesh_axes=axes,
        restore_step=checkpoint_step,
        replay_steps=current_step - checkpoint_step,
        dropped_chips=survivors - new_data * per_replica)


class DeterministicSchedule:
    """Data order as a pure function of (step, shard): replay-exact."""

    def __init__(self, seed: int, global_batch: int):
        self.seed = seed
        self.global_batch = global_batch

    def batch_indices(self, step: int, shard: int, num_shards: int):
        import numpy as np
        per = self.global_batch // num_shards
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, step, shard]))
        return rng.integers(0, 2 ** 31, size=(per,), dtype=np.int64)


@dataclasses.dataclass
class StragglerReport:
    host: str
    p50_ms: float
    host_p50_ms: float
    severity: float


def page_table_recovery_drill(store, shard_states):
    """Restart drill for a failed serving node: run the page-table store's
    recovery procedure (`repro.api` ``store.recover``) on every shard's
    crashed PM image and aggregate the per-shard recovery work.

    ``shard_states`` — one crashed state (or table pytree) per data shard,
    e.g. `repro.consistency.CrashState.state` images of an interrupted
    `serving.kvcache.open_new_pages_traced` batch.  Returns ``(tables,
    merged RecoveryReport)``; the merged report is the restart cost of the
    node (for continuity page tables: indicator words scanned, ZERO log
    records — the paper's log-free recovery claim at serving scale).
    """
    from repro.consistency import RecoveryReport
    tables, merged = [], RecoveryReport(store.name)
    for st in shard_states:
        table, report = store.recover(st)
        tables.append(table)
        merged = merged.merge(report)
    return tables, merged


class StragglerPolicy:
    """Synchronous-SPMD straggler detection: a host whose median step latency
    exceeds the fleet median by ``threshold``x is flagged (for hot-spare
    swap at the next checkpoint boundary)."""

    def __init__(self, threshold: float = 1.15, min_samples: int = 20):
        self.threshold = threshold
        self.min_samples = min_samples

    def analyze(self, monitor: HeartbeatMonitor) -> List[StragglerReport]:
        import numpy as np
        meds = {h: float(np.median(st.latencies_ms))
                for h, st in monitor.hosts.items()
                if st.latencies_ms and len(st.latencies_ms) >= self.min_samples}
        if len(meds) < 2:
            return []
        fleet = float(np.median(list(meds.values())))
        return [StragglerReport(h, fleet, m, m / fleet)
                for h, m in sorted(meds.items())
                if m > fleet * self.threshold]

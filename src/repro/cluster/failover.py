"""Failure detection and replica promotion for the cluster.

Detection reuses `runtime.fault.HeartbeatMonitor` unchanged — cluster
nodes heartbeat (node, step) and a node silent past the timeout is
declared dead.  Promotion is where the paper's recovery story pays off
at cluster scale: the surviving replica's table IS the shard (it mirrors
every committed write, fenced — see `cluster.replication`), so failover
is

    remove the dead node from the directory (rendezvous re-ranks the
    surviving replica to primary for exactly the dead node's keys),
    run the scheme's restart procedure on the promoted image
    (indicator-based for continuity: scan the commit words, ZERO log),
    re-replicate the shard to restore the replica count.

`FailoverController` packages detect -> promote as a host-side control
loop with an injectable clock, so the N-node sim (and CI) can drive
kill -> detect -> promote deterministically without real sleeps.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.consistency.recovery import RecoveryReport
from repro.runtime.fault import HeartbeatMonitor


@dataclasses.dataclass
class FailoverReport:
    """One completed promotion."""

    dead: str
    promoted_keys: int              # keys whose primary moved off the dead node
    recopied: int                   # replica copies restored post-promotion
    recovery: Dict[str, RecoveryReport]   # per-survivor restart reports

    def recovery_log_free(self) -> bool:
        return all(r.log_free() for r in self.recovery.values())


class FailoverController:
    """detect -> promote loop over a `ClusterStore`.

    ``clock`` is injectable (tests/sim pass a fake) so the detection
    timeout is deterministic.  ``tick`` is safe to call every round: it
    returns the reports of any promotions it performed (usually none).
    """

    def __init__(self, cluster, timeout_s: float = 5.0,
                 clock: Optional[Callable[[], float]] = None,
                 grace_s: float = 0.0):
        self.cluster = cluster
        kw = {"clock": clock} if clock is not None else {}
        self.monitor = HeartbeatMonitor(timeout_s=timeout_s,
                                        grace_s=grace_s, **kw)
        for name in cluster.node_names():
            self.monitor.register(name)

    def beat(self, step: int) -> None:
        """Heartbeat every node that is actually alive AND reachable (a
        killed node goes silent — that is the failure signal; a
        partitioned node is alive but its beats don't get through, which
        is exactly what the monitor's suspect/grace window exists to
        tell apart from death)."""
        for name in self.cluster.node_names():
            if (self.cluster.is_alive(name)
                    and getattr(self.cluster, "is_reachable",
                                lambda n: True)(name)):
                self.monitor.heartbeat(name, step)

    def tick(self) -> List[FailoverReport]:
        """Detect silent nodes and promote their replicas."""
        reports = []
        for dead in self.monitor.failed_hosts():
            if dead not in self.cluster.node_names():
                continue            # already promoted away
            reports.append(self.cluster.failover(dead))
            self.monitor.hosts.pop(dead, None)
        return reports

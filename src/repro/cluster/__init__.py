"""`repro.cluster` — sharded multi-node KV serving over the RDMA transport.

The fifth subsystem (DESIGN.md §9), composing the other four: the
rendezvous `Directory` routes one keyspace over N PM nodes, each node
runs any registered `repro.api` scheme as its shard image behind its own
`rdma.RemoteMemory` endpoint, writes replicate primary -> replica under
the remote-persist fence discipline (`replication` proves zero
committed-op loss across every primary-crash prefix), rebalance is
crash-consistent live migration with a one-word token cutover
(`migration`), and `failover` promotes replicas with the schemes' own
(indicator-based) restart.  `sim` scales the YCSB end-to-end simulation
to an elastic N-node cluster (`python -m repro.cluster.sim --smoke` is
the CI drill).
"""

from repro.cluster.directory import Directory, key_hash64
from repro.cluster.failover import FailoverController, FailoverReport
from repro.cluster.migration import (MigrationSweep, build_migration_trace,
                                     migration_crash_sweep, token_record)
from repro.cluster.replication import (ReplicaCheck,
                                       check_replicated_durability,
                                       op_ack_indices, replication_plan)
from repro.cluster.store import (ClusterReadResult, ClusterStore,
                                 ClusterWriteResult, RebalanceReport)

__all__ = [
    "Directory", "key_hash64",
    "FailoverController", "FailoverReport",
    "MigrationSweep", "build_migration_trace", "migration_crash_sweep",
    "token_record",
    "ReplicaCheck", "check_replicated_durability", "op_ack_indices",
    "replication_plan",
    "ClusterReadResult", "ClusterStore", "ClusterWriteResult",
    "RebalanceReport",
]

"""Crash-consistent live shard migration: COPY -> TOKEN CUTOVER -> CLEANUP.

Rebalance (node join/leave) moves resident keys between PM nodes while
both keep serving.  The protocol is the paper's one-word-commit
discipline lifted one level up:

  COPYING   the destination receives the moving items as ordinary traced
            inserts (each individually crash-atomic under its scheme's
            own discipline).  Reads run DUAL: the source stays
            authoritative; a destination copy is only ever a byte-equal
            duplicate, so reading the union is always correct.
  CUTOVER   ONE atomic 8-byte migration-token store flips ownership.
            Before the token persists the migration never happened
            (destination copies are harmless duplicates, re-copy is
            idempotent); after it the destination owns the keys.
  CLEANUP   the source deletes the moved items (each delete crash-atomic;
            leftovers are byte-equal duplicates under dual-read until
            the window closes).

`migration_crash_sweep` proves the invariant the matrix CLI gates: at
EVERY crash prefix of the composite trace (dest inserts + token + source
deletes, including torn splits of non-atomic stores), recovering both
tables and resolving reads by token yields EXACTLY the original item
set — zero loss, zero corruption, no phantom — recoverable from any
crash prefix with no migration log.

The composite PM image prefixes the two tables' leaves (``src/``,
``dst/``) plus the token word, so the EXISTING injector
(`consistency.trace.crash_states`) sweeps it unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import (PMStore, PMTrace, State, SubWrite,
                                     crash_states)

MIG_TOKEN = "__mig_token__"      # composite-state key of the cutover word
TOKEN_ADDR = 1 << 31             # symbolic PM address of the token


def _prefix_records(records, tag: str):
    return [dataclasses.replace(
        r, writes=tuple(SubWrite(tag + w.field, w.index, w.value)
                        for w in r.writes))
        for r in records]


def _split(state: State, tag: str) -> State:
    n = len(tag)
    return {f[n:]: v for f, v in state.items() if f.startswith(tag)}


def token_record(op_id: int, committed: bool = True) -> PMStore:
    """The cutover commit: one atomic 8-byte store (not Table-I-counted —
    it is per MIGRATION, not per op)."""
    return PMStore(op_id, "token", True, TOKEN_ADDR, 8, False,
                   (SubWrite(MIG_TOKEN, (), np.uint64(1 if committed else 0)),))


def build_migration_trace(store, src_table, dst_table, keys, vals
                          ) -> Tuple[State, PMTrace]:
    """Compose the full migration PM trace over the prefixed joint image.

    ``keys``/``vals`` are the moving items (resident on src).  Records:
    dst-side traced inserts, the token store, src-side traced deletes —
    exactly the order the live path issues them.
    """
    handler = HANDLERS[store.name]
    cfg = store.cfg
    src_state = handler.init_state(cfg, src_table)
    dst_state = handler.init_state(cfg, dst_table)

    # a migration COPIES: every moving item must be src-resident with
    # exactly this value, else dual-read resolution would be wrong
    src_items = handler.visible(cfg, src_state)
    kn = np.asarray(keys, np.uint32).reshape(-1, 4)
    vn = np.asarray(vals, np.uint32).reshape(-1, 4)
    for k, v in zip(kn, vn):
        assert src_items.get(k.tobytes()) == v.tobytes(), \
            "migrating item is not src-resident with this exact value"

    _, ins_trace = trace_batch(handler, cfg, dst_state, "insert",
                               keys, vals)
    assert all(o.ok for o in ins_trace.ops), \
        "destination too full to receive the moving items"
    _, del_trace = trace_batch(handler, cfg, src_state, "delete", keys)

    base: State = {MIG_TOKEN: np.zeros((), np.uint64)}
    for f, v in src_state.items():
        base["src/" + f] = v
    for f, v in dst_state.items():
        base["dst/" + f] = v
    records = (_prefix_records(ins_trace.records, "dst/")
               + [token_record(len(ins_trace.ops))]
               + _prefix_records(del_trace.records, "src/"))
    ops = list(ins_trace.ops) + list(del_trace.ops)
    return base, PMTrace(store.name, "migrate", records, ops)


@dataclasses.dataclass
class MigrationSweep:
    """Exhaustive crash sweep of one shard migration."""

    scheme: str
    moved: int
    crash_points: int
    torn_points: int
    token_cut_index: int            # record index of the cutover store
    violations: List[str]
    log_records_in_trace: int
    report: RecoveryReport          # merged recovery work over all points

    @property
    def consistent(self) -> bool:
        return not self.violations

    @property
    def log_free(self) -> bool:
        return self.log_records_in_trace == 0 \
            and self.report.log_records_used == 0


def resolve_dual_read(handler, cfg, state: State) -> Dict[bytes, bytes]:
    """What a dual-reading client durably sees in a (recovered) composite
    image: the union of both tables, source-authoritative before the
    token, destination-authoritative after.  Copies are byte-equal, so
    precedence only matters for torn edges — which each side's own
    recovery already ruled out."""
    src = handler.visible(cfg, _split(state, "src/"))
    dst = handler.visible(cfg, _split(state, "dst/"))
    if int(state[MIG_TOKEN]) == 0:
        return {**dst, **src}       # src wins key collisions
    return {**src, **dst}           # dst wins


def migration_crash_sweep(store, src_table, dst_table, keys, vals,
                          include_torn: bool = True) -> MigrationSweep:
    """Inject a crash at every PM-store boundary of the migration (and
    every torn split), recover BOTH tables, resolve by token, and require
    the resolved set to equal the pre-migration item set at every point.
    """
    handler = HANDLERS[store.name]
    cfg = store.cfg
    base, trace = build_migration_trace(store, src_table, dst_table,
                                        keys, vals)
    want = resolve_dual_read(handler, cfg, base)
    token_idx = next(i for i, r in enumerate(trace.records)
                     if r.writes[0].field == MIG_TOKEN)

    violations: List[str] = []
    merged = RecoveryReport(store.name)
    n_crash = n_torn = 0
    for cs in crash_states(base, trace, include_torn=include_torn):
        n_crash += 1
        n_torn += int(cs.torn)
        src_rec, r1 = handler.recover(cfg, _split(cs.state, "src/"))
        dst_rec, r2 = handler.recover(cfg, _split(cs.state, "dst/"))
        merged = merged.merge(r1).merge(r2)
        joined: State = {MIG_TOKEN: cs.state[MIG_TOKEN]}
        for f, v in src_rec.items():
            joined["src/" + f] = v
        for f, v in dst_rec.items():
            joined["dst/" + f] = v
        got = resolve_dual_read(handler, cfg, joined)
        if got != want:
            lost = sum(1 for k in want if got.get(k) != want[k])
            phantom = sum(1 for k in got if k not in want)
            violations.append(f"{cs.label}: resolved set diverged "
                              f"({lost} lost/torn, {phantom} phantom)")
    return MigrationSweep(
        scheme=store.name, moved=len(trace.ops) // 2,
        crash_points=n_crash, torn_points=n_torn,
        token_cut_index=token_idx, violations=violations,
        log_records_in_trace=trace.log_records(), report=merged)

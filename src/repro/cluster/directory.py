"""Shard directory: rendezvous (highest-random-weight) key -> node routing.

The cluster serves ONE keyspace from N PM nodes.  The directory is the
pure routing function every client and every server agrees on: for a
16-byte key and a node name, a deterministic 64-bit weight; the key's
replica set is the R highest-weighted nodes, its primary the highest.

Rendezvous hashing gives the minimal-movement property the elastic
cluster needs without a ring or a central table: when a node JOINS, the
only keys that move are those whose new weight ranks it into their
replica set (~R/N of the keyspace for the primary role, ~1/N per role);
when a node LEAVES, only the keys it owned move, and they scatter evenly
over the survivors.  `tests/test_cluster.py` asserts the bound the
ISSUE/CI gate uses: a join moves <= 1/N + 5% of resident keys.

Weights mix the key's 128-bit lanes with a per-node salt derived ONLY
from the node name — membership changes never perturb other nodes'
weights (that is where minimal movement comes from).  All routing is
vectorized numpy over (B, 4) uint32 key batches; the directory is a
frozen value object, so replacing it (join/leave/failover) is an atomic
host-side swap, mirroring the one-word cutover discipline the PM side
uses.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Tuple

import numpy as np

U64 = np.uint64


def _node_salt(name: str) -> np.uint64:
    """Stable 64-bit salt of a node name (membership-independent)."""
    return U64(int.from_bytes(
        hashlib.blake2b(name.encode(), digest_size=8).digest(), "little"))


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: full-avalanche 64-bit mixer (numpy wraps)."""
    x = x.astype(U64)
    x = (x ^ (x >> U64(30))) * U64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> U64(27))) * U64(0x94D049BB133111EB)
    return x ^ (x >> U64(31))


def key_hash64(keys: np.ndarray) -> np.ndarray:
    """(B, 4) uint32 key lanes -> (B,) uint64 full-width key hash."""
    k = np.asarray(keys, np.uint32).reshape(-1, 4).astype(U64)
    h = (k[:, 0] | (k[:, 1] << U64(32)))
    h = _mix64(h ^ _mix64(k[:, 2] | (k[:, 3] << U64(32))))
    return h


@dataclasses.dataclass(frozen=True)
class Directory:
    """Frozen rendezvous routing table over the current membership.

    ``nodes`` is kept sorted so equal memberships compare equal regardless
    of join order; ``replicas`` is the replica-set size R (primary
    included).  R > live node count is clamped at routing time, so a
    cluster can lose nodes below R without the router failing.
    """

    nodes: Tuple[str, ...]
    replicas: int = 2

    def __post_init__(self):
        assert self.nodes, "directory needs at least one node"
        assert len(set(self.nodes)) == len(self.nodes), "duplicate node"
        assert self.replicas >= 1
        object.__setattr__(self, "nodes", tuple(sorted(self.nodes)))

    # -- membership (returns a NEW directory: host-side atomic swap) --------
    def with_node(self, name: str) -> "Directory":
        assert name not in self.nodes, name
        return dataclasses.replace(self, nodes=self.nodes + (name,))

    def without_node(self, name: str) -> "Directory":
        assert name in self.nodes, name
        assert len(self.nodes) > 1, "cannot remove the last node"
        return dataclasses.replace(
            self, nodes=tuple(n for n in self.nodes if n != name))

    # -- routing ------------------------------------------------------------
    def weights(self, keys: np.ndarray) -> np.ndarray:
        """(B, N) rendezvous weight of every key on every node."""
        h = key_hash64(keys)[:, None]                       # (B, 1)
        salts = np.array([_node_salt(n) for n in self.nodes])[None]  # (1, N)
        return _mix64(h ^ salts)

    def replica_sets(self, keys: np.ndarray) -> np.ndarray:
        """(B, R) node indices, weight-descending: column 0 is the primary.

        Indices point into ``self.nodes``; use `replica_names` when the
        caller holds nodes by name (indices shift across membership
        changes, names do not)."""
        w = self.weights(keys)
        r = min(self.replicas, len(self.nodes))
        top = np.argpartition(-w, r - 1, axis=1)[:, :r] if r < w.shape[1] \
            else np.broadcast_to(np.arange(w.shape[1]), w.shape).copy()
        order = np.argsort(-np.take_along_axis(w, top, axis=1), axis=1,
                           kind="stable")
        return np.take_along_axis(top, order, axis=1)

    def primaries(self, keys: np.ndarray) -> np.ndarray:
        """(B,) primary node index per key (= replica_sets column 0)."""
        return np.argmax(self.weights(keys), axis=1)

    def replica_names(self, keys: np.ndarray) -> np.ndarray:
        """(B, R) node NAMES (object array) — the stable form of
        `replica_sets`."""
        return np.asarray(self.nodes, object)[self.replica_sets(keys)]

    def owned_mask(self, keys: np.ndarray, name: str,
                   role: str = "any") -> np.ndarray:
        """(B,) bool — keys this node serves as ``primary`` / ``replica`` /
        ``any`` member of the replica set."""
        sets = self.replica_names(keys)
        if role == "primary":
            return sets[:, 0] == name
        hit = (sets == name).any(axis=1)
        if role == "replica":
            return hit & (sets[:, 0] != name)
        assert role == "any", role
        return hit

    def placement(self, keys: np.ndarray) -> Dict[str, np.ndarray]:
        """{node name: (B,) primary-ownership mask} over the whole batch."""
        prim = self.primaries(keys)
        return {n: prim == i for i, n in enumerate(self.nodes)}

"""N-node YCSB cluster simulation: skewed streams, elastic membership,
mid-run failures — `rdma.sim` scaled from one server to a cluster.

Drives a `ClusterStore` (any registered scheme) with YCSB mixes under a
zipfian or hotspot request stream, firing membership EVENTS at op
thresholds mid-run:

    ("join",  at_op, name)   live migration in (begin -> dual-read
                             window -> cutover at the next round)
    ("leave", at_op, name)   graceful decommission
    ("kill",  at_op, name)   crash (name or "primary" = the node owning
                             the hottest key); heartbeats stop, the
                             `FailoverController` detects and promotes
    ("partition", at, name)  network partition: the node stays alive but
                             unreachable — the epoch bump fences it; the
                             monitor's suspect/grace window decides
                             whether it is promoted away or survives
    ("stale", at, name)      clients that missed the partition write
                             THROUGH the stale ex-primary (unfenced
                             acks, all of which MUST be detected)
    ("heal",  at, name)      the partition heals: reachable again but
                             fenced (replica-lag reads) until resync
    ("resync", at, name)     detect the stale acks, rebuild the shard
                             from the current primaries, re-admit

and checks the cluster invariants the ISSUE gates:

  * zero committed-op loss: every op acked before the crash is readable
    with its exact value after failover;
  * rebalance minimality: a join moves <= 1/N + 5% of resident keys;
  * fencing completeness: every injected stale ack is detected at
    resync/failover and none becomes visible in the keyspace.

``python -m repro.cluster.sim --smoke --json OUT.json`` runs the CI
drill: the N-node mixed-workload run with one join and one
primary-kill, PLUS the store-trace-level durability sweep
(`replication.check_replicated_durability` — fenced must be lossless,
UNFENCED must be caught losing acked ops) and the migration crash sweep.
Exit status 0 iff every invariant holds.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.cluster.failover import FailoverController
from repro.cluster.store import ClusterStore
from repro.data import ycsb

Event = Tuple[str, int, str]


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _stream(dist: str, n: int, theta: float = 0.99,
            hot_frac: float = 0.2, hot_op_frac: float = 0.8):
    return ycsb.request_stream(dist, n, theta=theta, hot_frac=hot_frac,
                               hot_op_frac=hot_op_frac)


def run_cluster(scheme: str = "continuity", workload: str = "A", *,
                nodes: int = 4, replicas: int = 2,
                num_records: int = 1200, num_ops: int = 2400,
                batch: int = 240, dist: str = "zipf",
                theta: float = 0.99, hot_frac: float = 0.2,
                hot_op_frac: float = 0.8,
                events: Sequence[Event] = (), node_slots: Optional[int] = None,
                seed: int = 0, heartbeat_timeout: float = 5.0,
                grace_s: float = 0.0, faults=None, retry=None,
                maintenance: bool = True, resize_trigger_lf: float = 0.85,
                resize_budget: int = 2) -> Dict:
    """One cluster cell; deterministic given the seed (ONE explicit seed
    feeds the value stream, the request stream, the scramble, and the
    chaos injections — the returned payload echoes it so any cell can be
    replayed bit-exactly).  ``faults``/``retry`` optionally wrap every
    node's endpoint in the transport's delivery-fault injector and retry
    policy; ``grace_s`` is the monitor's partition-suspicion window.
    Returns the aggregate payload the bench/CI artifact stores."""
    assert workload in ycsb.WORKLOADS, workload
    from repro.rdma.sim import _mix_counts
    n_read, n_upd, n_ins, n_scan, n_rmw = _mix_counts(workload, batch)
    n_logical = n_read + n_upd + n_ins + n_scan - n_rmw

    # size each node for its replicated share plus rebalance headroom
    if node_slots is None:
        per = ((num_records + n_ins * (num_ops // max(1, n_logical)))
               * replicas / nodes)
        node_slots = int(per * 3) + 256
    cluster = ClusterStore(scheme, nodes=nodes, replicas=replicas,
                           node_slots=node_slots, faults=faults, retry=retry)
    clock = _FakeClock()
    ctl = FailoverController(cluster, timeout_s=heartbeat_timeout,
                             clock=clock, grace_s=grace_s)

    rng = np.random.RandomState(seed)
    acked: Dict[int, np.ndarray] = {}       # record id -> committed value
    order: List[int] = []                   # insertion order (for D reads)

    def load(ids: np.ndarray, vals: np.ndarray,
             record: bool = False) -> np.ndarray:
        nonlocal wall_us
        res = cluster.insert(ycsb.make_key(ids), vals)
        okn = np.asarray(res.ok)
        if record:              # mid-run inserts count toward the metrics
            wall_us += res.round_us
            h_write.record_many(res.op_us[okn])
        for i, v in zip(ids[okn], vals[okn]):
            acked[int(i)] = v
            order.append(int(i))
        return okn

    # per-op-type latency sketches: the ONE percentile path for this
    # cell (the payload's p50/p99 AND the obs export read these buckets)
    h_read, h_write = obs.Histogram(), obs.Histogram()
    wall_us = 0.0
    for lo in range(0, num_records, batch):
        ids = np.arange(lo, min(lo + batch, num_records))
        load(ids, ycsb.make_value(rng, len(ids)))
    stream = _stream(dist, len(order), theta, hot_frac, hot_op_frac)
    scramble = rng.permutation(len(order))

    pending = sorted(events, key=lambda e: e[1])
    pending_complete_join = False
    reports: List[dict] = []
    rebalance_ok = failover_seen = True
    ops_done = step = 0
    killed: List[str] = []
    partitioned: List[str] = []

    def hottest_primary() -> str:
        hot = ycsb.make_key(np.array([order[scramble[0] % len(order)]]))
        names = cluster.directory.replica_names(hot)
        return str(names[0, 0])

    while ops_done < num_ops:
        step += 1
        with obs.span("cluster.round", round=step):
            clock.t += 1.0
            ctl.beat(step)
            for rep in ctl.tick():
                reports.append({"event": "failover", "dead": rep.dead,
                                "promoted_keys": rep.promoted_keys,
                                "recopied": rep.recopied,
                                "recovery_log_free": rep.recovery_log_free()})
            if pending_complete_join and not cluster.migrating:
                pending_complete_join = False   # the joiner died mid-window
            if pending_complete_join:       # cutover one full round after COPY:
                rb = cluster.complete_join()    # the dual-read window was live
                pending_complete_join = False
                rebalance_ok &= rb.within_bound
                reports.append({"event": "join", "node": rb.node,
                                "resident": rb.resident,
                                "moved_primary": rb.moved_primary,
                                "moved_frac": rb.moved_frac, "bound": rb.bound,
                                "copied": rb.copied, "cleaned": rb.cleaned,
                                "within_bound": rb.within_bound})
            while pending and pending[0][1] <= ops_done:
                kind, _, name = pending.pop(0)
                if kind == "join":
                    cluster.begin_join(name, node_slots)
                    ctl.monitor.register(name)
                    pending_complete_join = True
                elif kind == "leave":
                    rb = cluster.leave(name)
                    reports.append({"event": "leave", "node": rb.node,
                                    "moved_frac": rb.moved_frac,
                                    "copied": rb.copied})
                    ctl.monitor.hosts.pop(name, None)
                elif kind == "partition":
                    name = hottest_primary() if name == "primary" else name
                    cluster.partition(name)
                    partitioned.append(name)
                    reports.append({"event": "partition", "node": name,
                                    "epoch": cluster.epoch})
                elif kind == "stale":
                    # clients that missed the partition keep writing through
                    # the stale ex-primary: divergent values on HOT keys (the
                    # worst case — if fencing leaked, the audit would read
                    # them).  None of these acks is legitimate, so none
                    # enters `acked`.
                    ranks = stream.sample(rng, 16) % len(scramble)
                    sids = np.array(order)[scramble[ranks] % len(order)]
                    n = cluster.stale_write(name, ycsb.make_key(sids),
                                            ycsb.make_value(rng, len(sids)))
                    reports.append({"event": "stale", "node": name,
                                    "acks_injected": n})
                elif kind == "heal":
                    cluster.heal(name)
                    reports.append({"event": "heal", "node": name})
                elif kind == "resync":
                    hr = cluster.resync(name)
                    reports.append({"event": "resync", "node": hr.node,
                                    "stale_acks_detected":
                                        hr.stale_acks_detected,
                                    "resynced": hr.resynced})
                else:
                    assert kind == "kill", kind
                    name = hottest_primary() if name == "primary" else name
                    cluster.kill(name)
                    killed.append(name)

            if n_read:
                ranks = stream.sample(rng, n_read) % len(order)
                ids = np.array(order)[scramble[ranks % len(scramble)]
                                      % len(order)] \
                    if workload != "D" else \
                    np.array(order)[len(order) - 1 - ranks]
                res = cluster.lookup(ycsb.make_key(ids))
                h_read.record_many(res.op_us[np.asarray(res.found)])
                wall_us += res.round_us
            if n_scan:
                # YCSB-E short scans: zipf-ranked start keys, uniform spans
                ranks = stream.sample(rng, n_scan) % len(scramble)
                sids = np.array(order)[scramble[ranks] % len(order)]
                spans = ycsb.scan_lengths(rng, n_scan)
                res = cluster.scan(ycsb.make_key(sids), spans)
                h_read.record_many(res.op_us[np.asarray(res.found)])
                wall_us += res.round_us
            if n_upd:
                # F's updates are the write half of read-modify-write: they
                # hit the keys the SAME round just read, not a fresh draw
                if n_rmw:
                    ids = ids[-n_upd:]
                else:
                    ranks = stream.sample(rng, n_upd) % len(scramble)
                    ids = np.array(order)[scramble[ranks] % len(order)]
                vals = ycsb.make_value(rng, n_upd)
                res = cluster.update(ycsb.make_key(ids), vals)
                okn = np.asarray(res.ok)
                for i, v in zip(ids[okn], vals[okn]):
                    acked[int(i)] = v
                h_write.record_many(res.op_us[okn])
                wall_us += res.round_us
            if n_ins:
                base = max(order) + 1
                ids = np.arange(base, base + n_ins)
                load(ids, ycsb.make_value(rng, n_ins), record=True)
                stream = _stream(dist, len(order), theta, hot_frac, hot_op_frac)
            if maintenance:
                # between-rounds shard growth: any shard past the trigger
                # load factor splits `resize_budget` cohorts per round while
                # the YCSB stream above keeps flowing (writes/reads route by
                # the split's cutover tokens)
                for act in cluster.maintenance_step(budget=resize_budget,
                                                    trigger_lf=resize_trigger_lf):
                    if act["action"] != "step":
                        reports.append({"event": "resize", "round": step, **act})
            ops_done += n_logical

    # let a terminal kill drain through detection before the audit (the
    # horizon includes the suspicion grace window: a node is only
    # declared failed past timeout + grace)
    for _ in range(int(heartbeat_timeout + grace_s) + 2):
        step += 1
        clock.t += 1.0
        ctl.beat(step)
        for rep in ctl.tick():
            reports.append({"event": "failover", "dead": rep.dead,
                            "promoted_keys": rep.promoted_keys,
                            "recopied": rep.recopied,
                            "recovery_log_free": rep.recovery_log_free()})
    failover_seen = (not killed
                     or any(r["event"] == "failover" for r in reports))

    # the zero-committed-loss audit: EVERY acked (id, value) must read
    # back exactly after all failures and rebalances.  Fault injection is
    # quiesced first — the audit measures durability, not delivery luck
    cluster.quiesce_faults()
    audit_ids = np.array(sorted(acked))
    lost = 0
    with obs.span("cluster.audit", n=len(audit_ids)):
        for lo in range(0, len(audit_ids), batch):
            ids = audit_ids[lo:lo + batch]
            res = cluster.lookup(ycsb.make_key(ids))
            vals = np.stack([acked[int(i)] for i in ids])
            good = np.asarray(res.found) & (res.values == vals).all(axis=1)
            lost += int((~good).sum())

    merged = obs.Histogram()
    merged.merge(h_read)
    merged.merge(h_write)
    reg = obs.get_registry()
    reg.histogram("cluster.op_us", scheme=scheme, workload=workload,
                  op="read", seed=seed).merge(h_read)
    reg.histogram("cluster.op_us", scheme=scheme, workload=workload,
                  op="write", seed=seed).merge(h_write)
    # fold every node endpoint's wire registry into the installed one so
    # a traced run exports per-tag transport counters cluster-wide
    reg.merge(cluster.metrics_view())
    return {
        "scheme": scheme, "workload": workload, "dist": dist, "seed": seed,
        "theta": theta, "hot_frac": hot_frac, "hot_op_frac": hot_op_frac,
        "nodes_initial": nodes, "nodes_final": len(cluster.node_names()),
        "replicas": replicas, "ops": ops_done,
        "chaos": dict(cluster.chaos), "partitioned": partitioned,
        "ops_per_s": ops_done / max(wall_us, 1e-9) * 1e6,
        "p50_us": merged.percentile(50),
        "p99_us": merged.percentile(99),
        "committed": len(acked), "committed_lost": lost,
        "rebalance_within_bound": bool(rebalance_ok),
        "failover_detected": bool(failover_seen),
        "maintenance": dict(cluster.maintenance),
        "events": reports, "killed": killed,
        "stats": cluster.stats(),
    }


def durability_drill(scheme: str = "continuity", n_base: int = 24,
                     n_ops: int = 8) -> Dict:
    """Store-trace-level replicated-durability sweep for the CI artifact:
    the fenced discipline must lose ZERO acked ops over every primary-
    crash prefix; the unfenced delivery MUST be caught losing some (the
    negative control proving the checker sees real loss)."""
    from repro import api
    from repro.cluster.replication import check_replicated_durability
    store = api.make_store(scheme, table_slots=max(240, n_base * 10))
    rng = np.random.RandomState(11)
    K = ycsb.make_key(np.arange(n_base))
    table, res = store.insert(store.create(), K,
                              ycsb.make_value(rng, n_base))
    live = K[np.asarray(res.ok)][:n_ops]
    fenced = check_replicated_durability(
        store, table, "update", live, ycsb.make_value(rng, len(live)),
        fenced=True)
    unfenced = check_replicated_durability(
        store, table, "update", live, ycsb.make_value(rng, len(live)),
        fenced=False)
    return {
        "scheme": scheme,
        "fenced": {"cuts": fenced.cuts, "acked": fenced.acked_total,
                   "lost_committed": fenced.lost_committed,
                   "zero_loss": fenced.zero_loss},
        "unfenced": {"cuts": unfenced.cuts, "acked": unfenced.acked_total,
                     "lost_committed": unfenced.lost_committed,
                     "loss_detected": unfenced.lost_committed > 0},
        "ok": fenced.zero_loss and unfenced.lost_committed > 0,
    }


def migration_drill(scheme: str = "continuity", n_base: int = 18,
                    n_move: int = 6) -> Dict:
    """Migration crash sweep for the CI artifact (the matrix cell's twin)."""
    from repro import api
    from repro.cluster.migration import migration_crash_sweep
    store = api.make_store(scheme, table_slots=max(240, n_base * 10))
    rng = np.random.RandomState(13)
    K = ycsb.make_key(np.arange(n_base))
    V = ycsb.make_value(rng, n_base)
    src, res = store.insert(store.create(), K, V)
    okn = np.asarray(res.ok)
    sweep = migration_crash_sweep(store, src, store.create(),
                                  K[okn][:n_move], V[okn][:n_move])
    return {
        "scheme": scheme, "moved": sweep.moved,
        "crash_points": sweep.crash_points,
        "torn_points": sweep.torn_points,
        "violations": len(sweep.violations),
        "log_free": sweep.log_free, "ok": sweep.consistent,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scheme", default="continuity")
    p.add_argument("--workload", default="A")
    p.add_argument("--nodes", type=int, default=4)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--dist", default=None, choices=("zipf", "hotspot"),
                   help="request distribution (default: zipf here, the "
                        "fan-in drill's own hotspot default under --cache)")
    p.add_argument("--seed", type=int, default=0,
                   help="the ONE seed every stream derives from (echoed "
                        "in the JSON payload for bit-exact replay)")
    p.add_argument("--smoke", action="store_true",
                   help="CI sizes: small run + join + primary kill + the "
                        "durability and migration drills")
    p.add_argument("--json", default=None, help="write the payload here")
    p.add_argument("--trace", default=None, metavar="BASE",
                   help="trace the run under a deterministic TickClock and "
                        "write BASE.trace.json (Perfetto-loadable) + "
                        "BASE.metrics.json, including the single-server "
                        "YCSB scheme trio so `python -m repro.obs.report "
                        "BASE` prints the continuity-vs-pfarm p50 ratio")
    p.add_argument("--cache", action="store_true",
                   help="run the client-cache fan-in drill instead "
                        "(`repro.cache.fanin`): O(100) clients behind "
                        "version-stamped caches vs the uncached baseline")
    p.add_argument("--clients", type=int, default=100,
                   help="fan-in client count (only with --cache)")
    args = p.parse_args(argv)

    if args.cache:
        from repro.cache import fanin
        fwd = ["--scheme", args.scheme,
               "--clients", str(args.clients), "--seed", str(args.seed)]
        if args.dist is not None:
            fwd += ["--dist", args.dist]
        if args.smoke:
            fwd.append("--smoke")
        if args.json:
            fwd += ["--json", args.json]
        return fanin.main(fwd)

    kw = (dict(num_records=600, num_ops=1200, batch=240) if args.smoke
          else dict(num_records=2000, num_ops=4000, batch=400))
    events: Tuple[Event, ...] = (
        ("join", kw["num_ops"] // 3, "pmJ"),
        ("kill", 2 * kw["num_ops"] // 3, "primary"),
    )
    def _drive():
        cell = run_cluster(args.scheme, args.workload, nodes=args.nodes,
                           replicas=args.replicas, dist=args.dist or "zipf",
                           events=events, seed=args.seed, **kw)
        return cell, {
            "cluster": cell,
            "durability": durability_drill(args.scheme),
            "migration": migration_drill(args.scheme),
        }

    if args.trace:
        from repro.rdma.sim import run_ycsb
        with obs.scope(obs.Tracer(obs.TickClock())) as (tracer, reg):
            cell, payload = _drive()
            # the report's headline latency ratio wants the single-server
            # YCSB scheme trio in the SAME export (e2e.op_us histograms).
            # The trio runs at run_ycsb's FULL default sizes even under
            # --smoke: small tables let the probe baselines hit on their
            # first probe, which inverts the p50 ordering the report gates
            for sch in ("continuity", "level", "pfarm"):
                for wl in ("A", "C"):
                    with obs.span("e2e.cell", scheme=sch, workload=wl):
                        run_ycsb(sch, wl, seed=args.seed)
            tpath, mpath = obs.write_export(
                args.trace, tracer, reg,
                meta={"scheme": args.scheme, "workload": args.workload,
                      "seed": args.seed,
                      "profile": "smoke" if args.smoke else "full"})
        payload["obs_export"] = {"trace": tpath, "metrics": mpath}
        print(f"obs export: {tpath} + {mpath}")
    else:
        cell, payload = _drive()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=str)

    print(f"cluster {args.scheme}/{args.workload} x{args.nodes} "
          f"(R={args.replicas}, {args.dist or 'zipf'}, seed={args.seed}): "
          f"{cell['ops_per_s']:.0f} ops/s p50={cell['p50_us']:.2f}us "
          f"p99={cell['p99_us']:.2f}us nodes {cell['nodes_initial']}->"
          f"{cell['nodes_final']}")
    for r in cell["events"]:
        print(f"  event: {r}")
    print(f"committed={cell['committed']} lost={cell['committed_lost']} "
          f"rebalance_within_bound={cell['rebalance_within_bound']} "
          f"failover_detected={cell['failover_detected']}")
    d, m = payload["durability"], payload["migration"]
    print(f"durability drill: fenced lost={d['fenced']['lost_committed']} "
          f"over {d['fenced']['cuts']} cuts; unfenced lost="
          f"{d['unfenced']['lost_committed']} (must be >0) -> "
          f"{'PASS' if d['ok'] else 'FAIL'}")
    print(f"migration drill: {m['crash_points']} crash points "
          f"({m['torn_points']} torn), {m['violations']} violations, "
          f"log_free={m['log_free']} -> {'PASS' if m['ok'] else 'FAIL'}")

    bad = []
    if cell["committed_lost"]:
        bad.append("committed ops lost across failover")
    if not cell["rebalance_within_bound"]:
        bad.append("join moved more than 1/N + 5% of resident keys")
    if not cell["failover_detected"]:
        bad.append("kill was never detected/promoted")
    if not d["ok"]:
        bad.append("replicated-durability drill failed")
    if not m["ok"]:
        bad.append("migration crash sweep failed")
    for b in bad:
        print(f"FAIL: {b}", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

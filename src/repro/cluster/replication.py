"""Primary -> replica replicated writes with the remote-persist fence
discipline, and the checker that PROVES the discipline loses nothing.

Replication model (DESIGN.md §9): the primary executes a write batch and
ships the op's ordered PM store sequence — exactly the `PMTrace` the
consistency subsystem already records — to each replica as one-sided
RDMA WRITEs.  A store is *visible* at the replica once the NIC ACKs it,
*persisted* only after a remote-persist fence (Kashyap et al.) drains it
to the PM media.  The protocol ACKs an op to the client only when the
fence covering the op's LAST store has completed at the replica; under
the schemes' commit-fence discipline (`fence_after_commits`) that last
store IS a commit-kind store for every committed path, so

    acked  ==>  the op's commit word is on the replica's PM media,

and a primary crash at ANY point can lose no committed op: promotion
recovers the replica's PERSISTED image and every acked commit is in it.
`check_replicated_durability` proves this exhaustively — every remote
cut of the replica delivery, recovery on the persisted image, per-op
atomic-visibility check — and keeps the UNFENCED delivery (ACK on NIC
visibility, the write-combined shortcut) as the detected negative
control: there it finds acked-but-lost ops, which is precisely the bug
class the fence discipline exists to rule out.

Wire pricing reuses the verb layer: `replication_plan` turns a trace
into the (B_ops, M) fenced WRITE `VerbPlan` a replica endpoint posts, so
replica traffic shows up in the same doorbell/latency model as reads.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.consistency.checker import all_or_nothing_violations
from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import (PMTrace, fence_after_commits,
                                     remote_crash_states)
from repro.rdma import verbs as rv


def op_ack_indices(trace: PMTrace) -> Dict[int, int]:
    """{op_id: index of the op's LAST store record} for successful ops —
    the record whose fence completion triggers the client ACK."""
    last: Dict[int, int] = {}
    for i, rec in enumerate(trace.records):
        last[rec.op_id] = i
    return {o.op_id: last[o.op_id] for o in trace.ops
            if o.ok and o.op_id in last}


def replication_plan(trace: PMTrace,
                     fences: Optional[Tuple[int, ...]] = None) -> rv.VerbPlan:
    """The fenced one-sided WRITE plan a replica delivery posts.

    One WRITE verb per PM store record, one row per op.  Stores between
    fences share a dependency depth (they may write-combine into one
    round); each fenced store closes its round, so the next store is a
    new dependent round trip — the ordering rule that makes remote
    persistence correct (DESIGN.md §8).
    """
    fset = set(fence_after_commits(trace) if fences is None else fences)
    # per op: (region, addr, nbytes, fenced, depth) — depth is the count
    # of this op's fences BEFORE the store (each fence closes a round)
    rows: Dict[int, List[Tuple[int, int, int, bool, int]]] = {}
    fences_seen: Dict[int, int] = {}
    for i, rec in enumerate(trace.records):
        d = fences_seen.get(rec.op_id, 0)
        region = rv.REGION_LOG if rec.kind.startswith("log") else rv.REGION_TABLE
        rows.setdefault(rec.op_id, []).append(
            (region, rec.addr, rec.nbytes, i in fset, d))
        if i in fset:
            fences_seen[rec.op_id] = d + 1
    if not rows:
        return rv.pack(1, [(rv.NOOP, rv.REGION_TABLE, 0, 0, 0, False)])
    order = sorted(rows)
    lanes = []
    for m in range(max(len(v) for v in rows.values())):
        cols = [rows[o][m] if m < len(rows[o]) else (0, 0, 0, False, 0)
                for o in order]
        active = [m < len(rows[o]) for o in order]
        lanes.append((np.where(active, rv.WRITE, rv.NOOP),
                      np.array([c[0] for c in cols]),
                      np.array([c[1] for c in cols]) & 0x7FFFFFFF,
                      np.array([c[2] for c in cols]),
                      np.array([c[4] for c in cols]),
                      np.array([c[3] for c in cols])))
    return rv.pack(len(order), lanes)


@dataclasses.dataclass
class ReplicaCheck:
    """Exhaustive primary-crash sweep result for one replicated batch.

    ``cuts``            remote crash points swept (one per store boundary);
    ``acked_total``     op-acks outstanding summed over all cuts;
    ``lost_committed``  acked ops MISSING from the recovered persisted
                        image, summed over cuts (0 iff the discipline is
                        sound — the CI gate);
    ``violations``      per-op atomic-visibility failures on recovered
                        images (labels name the cut);
    ``fenced``          which delivery discipline was swept;
    ``report``          merged recovery work over every cut.
    """

    scheme: str
    op: str
    fenced: bool
    cuts: int
    acked_total: int
    lost_committed: int
    violations: List[str]
    report: RecoveryReport

    @property
    def zero_loss(self) -> bool:
        return self.lost_committed == 0 and not self.violations


def check_replicated_durability(store, table, op: str, keys, vals=None,
                                mask=None, fenced: bool = True,
                                order: str = "serial") -> ReplicaCheck:
    """Sweep EVERY primary-crash point of one replicated write batch.

    The replica starts from the same durable image as the primary (it
    mirrors the shard), receives the batch's PM store sequence as RDMA
    WRITEs, and the primary's power is cut after each store's NIC ACK.
    At every cut: recover the replica's PERSISTED image (never the
    visible one — that is the whole point), then require

      * every op acked at that cut is exactly-new in the recovered image
        (insert/update) or exactly-absent (delete);
      * every op, acked or not, is atomically visible or invisible
        (`all_or_nothing_violations`).

    ``fenced=True`` swept under `fence_after_commits` must return
    ``zero_loss``; ``fenced=False`` (ACK on NIC visibility, no fences) is
    the negative control and must NOT — callers assert both directions.
    """
    handler = HANDLERS[store.name]
    cfg = store.cfg
    base_state = handler.init_state(cfg, table)
    base_items = handler.visible(cfg, base_state)
    _, trace = trace_batch(handler, cfg, base_state, op, keys, vals, mask,
                           order=order)
    fences = fence_after_commits(trace) if fenced else ()
    ack_at = op_ack_indices(trace)
    by_id = {o.op_id: o for o in trace.ops}

    acked_total = lost = cuts = 0
    violations: List[str] = []
    merged: Optional[RecoveryReport] = None
    for cs in remote_crash_states(base_state, trace, fences=fences):
        cuts += 1
        horizon = cs.fenced_done if fenced else cs.records_done
        rec_state, report = handler.recover(cfg, cs.persisted)
        merged = report if merged is None else merged.merge(report)
        vis = handler.visible(cfg, rec_state)
        for op_id, last_idx in ack_at.items():
            if last_idx >= horizon:
                continue                    # not yet acked at this cut
            acked_total += 1
            o = by_id[op_id]
            if o.op == "delete":
                good = o.key not in vis
            else:
                good = vis.get(o.key) == o.val
            if not good:
                lost += 1
                violations.append(
                    f"{cs.label}: acked {o.op} op {op_id} lost or torn "
                    f"after recovery")
        for v in all_or_nothing_violations(base_items, trace, vis):
            violations.append(f"{cs.label}: {v}")
    return ReplicaCheck(
        scheme=store.name, op=op, fenced=fenced, cuts=cuts,
        acked_total=acked_total, lost_committed=lost,
        violations=violations,
        report=merged if merged is not None else RecoveryReport(store.name))

"""`ClusterStore`: one keyspace served by N PM nodes, any registered scheme.

The cluster composes the existing subsystems into the deployment the
ROADMAP's north star describes: every node runs ONE `repro.api` store
(any registered scheme — the cluster is scheme-agnostic by construction)
as its PM shard image, the rendezvous `Directory` routes every key to an
R-node replica set, and each node owns a simulated RNIC endpoint
(`rdma.RemoteMemory`) that prices what the cluster puts on its wire.

Semantics:

  * **writes** apply to every live replica-set member and post the
    fenced replication `VerbPlan` (synthesized from the member's own
    `CostLedger`, exactly like `rdma.sim`) to that member's endpoint.
    An op is acked iff every live member committed it; per-op latency
    is the chain sum (primary applies, forwards, acks after the last
    replica's commit fence — the discipline
    `cluster.replication.check_replicated_durability` proves lossless).
  * **reads** route to the key's primary (first ALIVE member — a dead,
    not-yet-promoted primary degrades to replica reads instead of
    failing) and post the scheme's exact lookup verb plan.  During a
    migration window reads run DUAL: misses retry against the other
    directory's owner (`cluster.migration` proves the union is always
    correct).
  * **join/leave** are live migrations: copy (from old primaries only —
    one source per key), ONE host-atomic directory cutover (the PM
    token twin is swept in `migration.py`), then cleanup.  The
    `RebalanceReport` carries the moved-key fraction the CI gate bounds
    at 1/N + 5%.
  * **kill/failover**: a killed node goes silent (its image frozen);
    `failover` removes it from the directory — rendezvous re-ranks the
    surviving replicas to primary for exactly its keys — runs every
    survivor's restart procedure (indicator-based for continuity), and
    re-replicates to restore R.

Batch sub-routing pads per-node sub-batches to a fixed quantum so the
jitted scheme ops compile once per node instead of once per arrival
pattern; padded rows are masked writes / ignored reads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro import api
from repro.cluster.directory import Directory
from repro.cluster.failover import FailoverReport
from repro.rdma.sim import post_ledger_writes
from repro.rdma.transport import LinkModel, RemoteMemory

U32 = np.uint32
PAD_QUANTUM = 64


@dataclasses.dataclass
class _Node:
    name: str
    store: Any
    table: Any
    mem: Optional[RemoteMemory]
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class _Migration:
    new_dir: Directory
    resident: int
    copied: int
    moved_primary: int


class ClusterWriteResult(NamedTuple):
    ok: np.ndarray          # (B,) acked per op (all live members committed)
    op_us: np.ndarray       # (B,) simulated chain latency per acked op
    round_us: float         # wall time of the round (busiest node)


class ClusterReadResult(NamedTuple):
    values: np.ndarray      # (B, 4) uint32
    found: np.ndarray       # (B,) bool
    op_us: np.ndarray       # (B,) unloaded per-op latency
    round_us: float


@dataclasses.dataclass(frozen=True)
class RebalanceReport:
    """One join/leave rebalance; ``moved_frac <= bound`` is the CI gate."""

    kind: str               # join | leave
    node: str
    resident: int           # distinct keys resident before the change
    moved_primary: int      # keys whose PRIMARY changed
    copied: int             # replica copies shipped
    cleaned: int            # stale copies deleted at cleanup
    bound: float            # 1/N + 5% for the new membership

    @property
    def moved_frac(self) -> float:
        return self.moved_primary / max(1, self.resident)

    @property
    def within_bound(self) -> bool:
        return self.moved_frac <= self.bound


def _pad(n: int) -> int:
    return -(-max(n, 1) // PAD_QUANTUM) * PAD_QUANTUM


class ClusterStore:
    """Sharded, replicated KV store over N simulated PM nodes."""

    def __init__(self, scheme: str = "continuity", nodes: int = 4,
                 replicas: int = 2, node_slots: int = 2048,
                 policy: Optional[api.ExecPolicy] = None,
                 link: Optional[LinkModel] = None):
        names = tuple(f"pm{i}" for i in range(nodes))
        self.scheme = scheme
        self._node_slots = node_slots
        self._policy = policy or api.ExecPolicy(transport="sim")
        self._link = link
        self.directory = Directory(names, replicas=replicas)
        self._nodes: Dict[str, _Node] = {n: self._make_node(n)
                                         for n in names}
        self._mig: Optional[_Migration] = None

    # -- membership plumbing ------------------------------------------------
    def _make_node(self, name: str, slots: Optional[int] = None) -> _Node:
        store = api.make_store(self.scheme,
                               table_slots=slots or self._node_slots,
                               policy=self._policy)
        return _Node(name, store, store.create(),
                     RemoteMemory.from_policy(store.policy, self._link))

    def node_names(self) -> Tuple[str, ...]:
        return tuple(self._nodes)

    def is_alive(self, name: str) -> bool:
        return name in self._nodes and self._nodes[name].alive

    @property
    def migrating(self) -> bool:
        """True while a begin_join window is open (a mid-window failover
        of the joiner itself closes it — see `failover`)."""
        return self._mig is not None

    def node(self, name: str) -> _Node:
        return self._nodes[name]

    def _resident(self, node: _Node) -> Tuple[np.ndarray, np.ndarray]:
        keys, vals, live = node.store._extract(node.table)
        liven = np.asarray(live)
        return (np.asarray(keys, U32)[liven], np.asarray(vals, U32)[liven])

    def _distinct_resident(self) -> Tuple[np.ndarray, np.ndarray]:
        """(K, V) of every distinct key on any live node (replica dedup)."""
        seen: Dict[bytes, np.ndarray] = {}
        order: List[np.ndarray] = []
        for node in self._nodes.values():
            if not node.alive:
                continue
            K, V = self._resident(node)
            for k, v in zip(K, V):
                kb = k.tobytes()
                if kb not in seen:
                    seen[kb] = v
                    order.append(k)
        if not order:
            return np.zeros((0, 4), U32), np.zeros((0, 4), U32)
        return np.stack(order), np.stack([seen[k.tobytes()] for k in order])

    # -- padded per-node sub-batches ---------------------------------------
    def _padded_write(self, op: str, node: _Node, keys: np.ndarray,
                      vals: Optional[np.ndarray]):
        n = keys.shape[0]
        P = _pad(n)
        pk = np.zeros((P, 4), U32)
        pk[:n] = keys
        mask = np.zeros((P,), bool)
        mask[:n] = True
        if vals is None:
            node.table, res = getattr(node.store, op)(node.table, pk, mask)
        else:
            pv = np.zeros((P, 4), U32)
            pv[:n] = vals
            node.table, res = getattr(node.store, op)(node.table, pk, pv,
                                                      mask)
        return np.asarray(res.ok)[:n], res

    def _padded_lookup(self, node: _Node, keys: np.ndarray):
        n = keys.shape[0]
        pk = np.zeros((_pad(n), 4), U32)
        pk[:n] = keys
        res = node.store.lookup(node.table, pk)
        return (np.asarray(res.values)[:n], np.asarray(res.ok)[:n], res)

    # -- writes -------------------------------------------------------------
    def insert(self, keys, vals) -> ClusterWriteResult:
        return self._write("insert", keys, vals)

    def update(self, keys, vals) -> ClusterWriteResult:
        return self._write("update", keys, vals)

    def delete(self, keys) -> ClusterWriteResult:
        return self._write("delete", keys, None)

    def _write(self, op: str, keys, vals) -> ClusterWriteResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        vals = None if vals is None else np.asarray(vals, U32).reshape(-1, 4)
        ok = np.ones((B,), bool)
        touched = np.zeros((B,), bool)
        lat = np.zeros((B,))
        round_us = 0.0
        dirs = [self.directory] + ([self._mig.new_dir] if self._mig else [])
        # one routing pass per directory (not per node): the weight
        # matrix is the cluster's hottest computation
        sets_by_dir = [d.replica_names(keys) for d in dirs]
        for node in list(self._nodes.values()):
            if not node.alive:
                continue
            m = np.zeros((B,), bool)
            for d, sets in zip(dirs, sets_by_dir):
                if node.name in d.nodes:
                    m |= (sets == node.name).any(axis=1)
            if not m.any():
                continue
            okn, res = self._padded_write(
                op, node, keys[m], None if vals is None else vals[m])
            ok[m] &= okn
            touched |= m
            if node.mem is not None:
                comp = post_ledger_writes(node.mem, int(okn.sum()),
                                          int(res.ledger.pm_writes))
                if comp is not None:
                    lat[np.flatnonzero(m)[okn]] += comp.op_us   # chain sum
                    round_us = max(round_us, comp.batch_us)
        ok &= touched           # no live member -> not acked
        return ClusterWriteResult(ok, lat, round_us)

    # -- reads --------------------------------------------------------------
    def lookup(self, keys) -> ClusterReadResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        values = np.zeros((B, 4), U32)
        found = np.zeros((B,), bool)
        lat = np.zeros((B,))
        round_us = 0.0
        round_us = max(round_us, self._lookup_via(
            self.directory, keys, np.ones((B,), bool), values, found, lat))
        if self._mig is not None and not found.all():
            # dual-read window: misses retry on the new directory's owner
            round_us = max(round_us, self._lookup_via(
                self._mig.new_dir, keys, ~found, values, found, lat))
        return ClusterReadResult(values, found, lat, round_us)

    def _lookup_via(self, d: Directory, keys, mask, values, found,
                    lat) -> float:
        sets = d.replica_names(keys)                       # (B, R) names
        # serve from the first ALIVE member: a dead primary degrades to
        # replica reads until failover promotes
        alive = np.vectorize(self.is_alive)(sets)
        has = alive.any(axis=1)
        first = np.argmax(alive, axis=1)
        target = np.where(has, sets[np.arange(len(first)), first], "")
        round_us = 0.0
        for name in np.unique(target[mask & has]):
            node = self._nodes[name]
            m = mask & has & (target == name)
            vs, fs, res = self._padded_lookup(node, keys[m])
            values[m] = np.where(fs[:, None], vs, values[m])
            found[m] |= fs
            if node.mem is not None and res.plan is not None:
                comp = node.mem.post(res.plan)
                lat[m] = np.maximum(lat[m],
                                    comp.op_us[: int(m.sum())])
                round_us = max(round_us, comp.batch_us)
        return round_us

    # -- rebalance: live join / leave ---------------------------------------
    def begin_join(self, name: str,
                   node_slots: Optional[int] = None) -> _Migration:
        """COPY phase: add the node, ship it every key it will own.  Reads
        keep routing through the OLD directory (dual-read covers the
        window); `complete_join` is the cutover."""
        assert self._mig is None, "a migration is already in flight"
        new_dir = self.directory.with_node(name)
        self._nodes[name] = self._make_node(name, node_slots)
        K, V = self._distinct_resident()
        if len(K):
            new_sets = new_dir.replica_names(K)
            to_new = (new_sets == name).any(axis=1)
            moved_primary = int((new_sets[:, 0] == name).sum())
            copied = int(to_new.sum())
            if copied:
                okn, _ = self._padded_write("insert", self._nodes[name],
                                            K[to_new], V[to_new])
                assert okn.all(), "join target too small for its shard"
        else:
            moved_primary = copied = 0
        self._mig = _Migration(new_dir, len(K), copied, moved_primary)
        return self._mig

    def complete_join(self) -> RebalanceReport:
        """CUTOVER (one host-atomic directory swap — the PM token twin is
        `migration.token_record`) + CLEANUP (drop un-owned copies)."""
        assert self._mig is not None, "no migration in flight"
        mig = self._mig
        joined = set(mig.new_dir.nodes) - set(self.directory.nodes)
        self.directory = mig.new_dir
        self._mig = None
        cleaned = self._cleanup()
        return RebalanceReport(
            kind="join", node=next(iter(joined)), resident=mig.resident,
            moved_primary=mig.moved_primary, copied=mig.copied,
            cleaned=cleaned, bound=1.0 / len(self.directory.nodes) + 0.05)

    def join(self, name: str,
             node_slots: Optional[int] = None) -> RebalanceReport:
        self.begin_join(name, node_slots)
        return self.complete_join()

    def leave(self, name: str) -> RebalanceReport:
        """Graceful decommission: re-home the leaving node's keys, cut
        over, drop the node."""
        assert self._mig is None, "complete the in-flight migration first"
        assert self.is_alive(name), name
        new_dir = self.directory.without_node(name)
        K, V = self._distinct_resident()
        copied = 0
        if len(K):
            old_sets = self.directory.replica_names(K)
            new_sets = new_dir.replica_names(K)
            moved_primary = int(
                (old_sets[:, 0] != new_sets[:, 0]).sum())
            for node in self._nodes.values():
                if node.name == name or not node.alive:
                    continue
                gains = ((new_sets == node.name).any(axis=1)
                         & ~(old_sets == node.name).any(axis=1))
                if gains.any():
                    okn, _ = self._padded_write("insert", node, K[gains],
                                                V[gains])
                    copied += int(okn.sum())
        else:
            moved_primary = 0
        self.directory = new_dir
        del self._nodes[name]
        return RebalanceReport(
            kind="leave", node=name, resident=len(K),
            moved_primary=moved_primary, copied=copied, cleaned=0,
            bound=1.0 / (len(new_dir.nodes) + 1) + 0.05)

    def _cleanup(self) -> int:
        cleaned = 0
        for node in self._nodes.values():
            if not node.alive:
                continue
            K, _ = self._resident(node)
            if not len(K):
                continue
            drop = ~self.directory.owned_mask(K, node.name)
            if drop.any():
                okn, _ = self._padded_write("delete", node, K[drop], None)
                cleaned += int(okn.sum())
        return cleaned

    # -- failure ------------------------------------------------------------
    def kill(self, name: str) -> None:
        """Crash a node: it goes silent, its PM image frozen as-is.
        Detection (heartbeat timeout) and promotion are the
        `FailoverController`'s job."""
        self._nodes[name].alive = False

    def failover(self, dead: str) -> FailoverReport:
        """Promote the dead node's replicas: directory removal re-ranks
        them to primary, every survivor runs its scheme's restart
        procedure on its (possibly mid-write) image, and the lost
        replica count is restored from the new primaries."""
        assert dead in self._nodes and not self._nodes[dead].alive, dead
        old_dir = self.directory
        if dead not in old_dir.nodes:
            # a joiner died inside its own migration window: it owned
            # nothing yet (the source is still authoritative), so the
            # join is void — drop the node and its copies, promote nobody
            assert self._mig is not None and dead in self._mig.new_dir.nodes
            self._mig = None
            del self._nodes[dead]
            return FailoverReport(dead=dead, promoted_keys=0, recopied=0,
                                  recovery={})
        new_dir = old_dir.without_node(dead)
        if self._mig is not None:
            # a primary died inside a migration window: the PENDING
            # cutover must target the post-failover membership, or
            # complete_join would resurrect the dead node (and is moot
            # when the dead node IS the joiner)
            nd = (self._mig.new_dir.without_node(dead)
                  if dead in self._mig.new_dir.nodes else self._mig.new_dir)
            if set(nd.nodes) == set(new_dir.nodes):
                self._mig = None
            else:
                self._mig = dataclasses.replace(self._mig, new_dir=nd)
        recovery = {}
        for node in self._nodes.values():
            if not node.alive:
                continue
            node.table, report = node.store.recover(node.table)
            recovery[node.name] = report
        del self._nodes[dead]
        self.directory = new_dir
        K, V = self._distinct_resident()
        promoted = recopied = 0
        if len(K):
            promoted = int((old_dir.replica_names(K)[:, 0] == dead).sum())
            new_sets = new_dir.replica_names(K)
            for node in self._nodes.values():
                need = (new_sets == node.name).any(axis=1)
                if not need.any():
                    continue
                _, have, _ = self._padded_lookup(node, K[need])
                miss = np.flatnonzero(need)[~have]
                if len(miss):
                    okn, _ = self._padded_write("insert", node, K[miss],
                                                V[miss])
                    recopied += int(okn.sum())
        return FailoverReport(dead=dead, promoted_keys=promoted,
                              recopied=recopied, recovery=recovery)

    # -- diagnostics --------------------------------------------------------
    def total_resident(self) -> int:
        return len(self._distinct_resident()[0])

    def stats(self) -> dict:
        out = {"scheme": self.scheme, "nodes": {}, "replicas":
               self.directory.replicas, "migrating": self._mig is not None}
        for node in self._nodes.values():
            st = {"alive": node.alive,
                  "resident": int(len(self._resident(node)[0]))}
            if node.mem is not None:
                st["wire"] = node.mem.stats()
            out["nodes"][node.name] = st
        return out

"""`ClusterStore`: one keyspace served by N PM nodes, any registered scheme.

The cluster composes the existing subsystems into the deployment the
ROADMAP's north star describes: every node runs ONE `repro.api` store
(any registered scheme — the cluster is scheme-agnostic by construction)
as its PM shard image, the rendezvous `Directory` routes every key to an
R-node replica set, and each node owns a simulated RNIC endpoint
(`rdma.RemoteMemory`) that prices what the cluster puts on its wire.

Semantics:

  * **writes** apply to every live replica-set member and post the
    fenced replication `VerbPlan` (synthesized from the member's own
    `CostLedger`, exactly like `rdma.sim`) to that member's endpoint.
    An op is acked iff every live member committed it; per-op latency
    is the chain sum (primary applies, forwards, acks after the last
    replica's commit fence — the discipline
    `cluster.replication.check_replicated_durability` proves lossless).
  * **reads** route to the key's primary (first ALIVE member — a dead,
    not-yet-promoted primary degrades to replica reads instead of
    failing) and post the scheme's exact lookup verb plan.  During a
    migration window reads run DUAL: misses retry against the other
    directory's owner (`cluster.migration` proves the union is always
    correct).
  * **join/leave** are live migrations: copy (from old primaries only —
    one source per key), ONE host-atomic directory cutover (the PM
    token twin is swept in `migration.py`), then cleanup.  The
    `RebalanceReport` carries the moved-key fraction the CI gate bounds
    at 1/N + 5%.
  * **kill/failover**: a killed node goes silent (its image frozen);
    `failover` removes it from the directory — rendezvous re-ranks the
    surviving replicas to primary for exactly its keys — runs every
    survivor's restart procedure (indicator-based for continuity), and
    re-replicates to restore R.

Batch sub-routing pads per-node sub-batches to a fixed quantum so the
jitted scheme ops compile once per node instead of once per arrival
pattern; padded rows are masked writes / ignored reads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro import api, obs
from repro.cluster.directory import Directory
from repro.cluster.failover import FailoverReport
from repro.rdma.sim import post_ledger_writes
from repro.rdma.transport import (DeliveryTimeout, FaultInjector, LinkModel,
                                  RemoteMemory, RetryPolicy)

U32 = np.uint32
PAD_QUANTUM = 64

# per-maintenance-step stall SLO (us) when the caller does not pass one:
# a step is priced at cohorts_moved x LinkModel.cohort_move_us(row), and a
# step whose priced stall exceeds the SLO counts as one burn
# (maintenance["slo_burns"] / the maintenance.slo_burn counter — the
# obs-smoke CI job and the bench obs section gate this at ZERO under
# default budgets)
DEFAULT_STEP_SLO_US = 500.0


@dataclasses.dataclass
class _Node:
    name: str
    store: Any
    table: Any
    mem: Optional[RemoteMemory]
    alive: bool = True
    reachable: bool = True      # False while partitioned (alive, but cut off)
    epoch: int = 0              # directory epoch the node last joined/synced
    # in-flight incremental resize (an api.ResizeState): while set, every
    # read/write/stamp on this node routes through the split's per-cohort
    # cutover tokens; `maintenance_step` advances and eventually clears it
    resize: Optional[Any] = None
    # (key, val, epoch) writes a stale ex-primary acked while partitioned —
    # the fencing machinery must detect and discard EVERY one of these
    stale_log: List[Tuple[np.ndarray, np.ndarray, int]] = \
        dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class HealReport:
    """One partition heal + resync: the fencing-epoch bookkeeping."""

    node: str
    stale_acks_detected: int    # logged stale-epoch acks fenced out
    resynced: int               # keys re-copied from the current primaries


@dataclasses.dataclass(frozen=True)
class _Migration:
    new_dir: Directory
    resident: int
    copied: int
    moved_primary: int


class ClusterWriteResult(NamedTuple):
    ok: np.ndarray          # (B,) acked per op (all live members committed)
    op_us: np.ndarray       # (B,) simulated chain latency per acked op
    round_us: float         # wall time of the round (busiest node)


class ClusterReadResult(NamedTuple):
    values: np.ndarray      # (B, 4) uint32
    found: np.ndarray       # (B,) bool
    op_us: np.ndarray       # (B,) unloaded per-op latency
    round_us: float


class ClusterStampResult(NamedTuple):
    """One stamp-validation round (`ClusterStore.version_read`)."""

    stamps: np.ndarray      # (B, S) int64 — scheme stamp rows; -1 = unresolved
    source: np.ndarray      # (B,) object — answering node name ("" = none)
    resolved: np.ndarray    # (B,) bool — a serving member answered
    op_us: np.ndarray       # (B,) unloaded per-op latency
    round_us: float


class ClusterStampedRead(NamedTuple):
    """A cache-fill read (`ClusterStore.lookup_stamped`): lookup answers
    plus the answering node's version stamps from the same routing."""

    values: np.ndarray      # (B, 4) uint32
    found: np.ndarray       # (B,) bool
    stamps: np.ndarray      # (B, S) int64 — -1 rows carry no stamp
    source: np.ndarray      # (B,) object — answering node name ("" = none)
    op_us: np.ndarray       # (B,) unloaded per-op latency
    round_us: float


@dataclasses.dataclass(frozen=True)
class RebalanceReport:
    """One join/leave rebalance; ``moved_frac <= bound`` is the CI gate."""

    kind: str               # join | leave
    node: str
    resident: int           # distinct keys resident before the change
    moved_primary: int      # keys whose PRIMARY changed
    copied: int             # replica copies shipped
    cleaned: int            # stale copies deleted at cleanup
    bound: float            # 1/N + 5% for the new membership

    @property
    def moved_frac(self) -> float:
        return self.moved_primary / max(1, self.resident)

    @property
    def within_bound(self) -> bool:
        return self.moved_frac <= self.bound


def _pad(n: int) -> int:
    return -(-max(n, 1) // PAD_QUANTUM) * PAD_QUANTUM


def _slice_plan(plan, n: int):
    """First ``n`` rows of a padded `VerbPlan` as host arrays: plan rows
    are per-op and independent, so the slice is a legal plan on its own."""
    from repro.rdma import verbs as rv
    return rv.VerbPlan(*(np.asarray(leaf)[:n] for leaf in plan))


class ClusterStore:
    """Sharded, replicated KV store over N simulated PM nodes."""

    def __init__(self, scheme: str = "continuity", nodes: int = 4,
                 replicas: int = 2, node_slots: int = 2048,
                 policy: Optional[api.ExecPolicy] = None,
                 link: Optional[LinkModel] = None,
                 faults: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None):
        names = tuple(f"pm{i}" for i in range(nodes))
        self.scheme = scheme
        self._node_slots = node_slots
        self._policy = policy or api.ExecPolicy(transport="sim")
        self._link = link
        self._faults = faults       # shared injector: one seeded draw stream
        self._retry = retry
        self.epoch = 0              # the directory/fencing epoch: bumped on
        #                             every membership change and partition
        self.directory = Directory(names, replicas=replicas)
        self._nodes: Dict[str, _Node] = {n: self._make_node(n)
                                         for n in names}
        self._mig: Optional[_Migration] = None
        self.chaos = {"stale_acks_injected": 0, "stale_acks_detected": 0,
                      "writes_rejected_read_only": 0, "lag_read_redirects": 0,
                      "write_timeouts": 0, "read_timeouts": 0}
        self.maintenance = {"resizes_begun": 0, "steps": 0,
                            "cohorts_moved": 0, "cutovers": 0,
                            "blocking_resizes": 0, "slo_burns": 0}

    # -- membership plumbing ------------------------------------------------
    def _make_node(self, name: str, slots: Optional[int] = None) -> _Node:
        store = api.make_store(self.scheme,
                               table_slots=slots or self._node_slots,
                               policy=self._policy)
        return _Node(name, store, store.create(),
                     RemoteMemory.from_policy(store.policy, self._link,
                                              faults=self._faults,
                                              retry=self._retry),
                     epoch=self.epoch)

    def node_names(self) -> Tuple[str, ...]:
        return tuple(self._nodes)

    def is_alive(self, name: str) -> bool:
        return name in self._nodes and self._nodes[name].alive

    def is_reachable(self, name: str) -> bool:
        return name in self._nodes and self._nodes[name].reachable

    def _serving(self, node: _Node) -> bool:
        """A node serves cluster traffic iff it is alive, reachable, and
        CURRENT-EPOCH: a healed-but-not-yet-resynced node holds an old
        epoch token, so routing fences it out until `resync` (its image
        may carry stale-ack divergence)."""
        return node.alive and node.reachable and node.epoch == self.epoch

    def _name_serving(self, name: str) -> bool:
        return name in self._nodes and self._serving(self._nodes[name])

    def _name_lagging(self, name: str) -> bool:
        """Healed but not yet resynced: reachable, holding an old epoch
        token.  Readable-looking but fenced — reads redirect past it."""
        n = self._nodes.get(name)
        return (n is not None and n.alive and n.reachable
                and n.epoch < self.epoch)

    def serving_names(self) -> Tuple[str, ...]:
        return tuple(n.name for n in self._nodes.values()
                     if self._serving(n))

    @property
    def read_only(self) -> bool:
        """Quorum-loss degradation: with fewer serving nodes than the
        replication factor the cluster cannot place a full replica set,
        so it stops acking writes (reads keep flowing) instead of
        acking under-replicated data it could later lose."""
        return len(self.serving_names()) < self.directory.replicas

    @property
    def migrating(self) -> bool:
        """True while a begin_join window is open (a mid-window failover
        of the joiner itself closes it — see `failover`)."""
        return self._mig is not None

    def node(self, name: str) -> _Node:
        return self._nodes[name]

    def _bump_epoch(self) -> None:
        """Advance the fencing epoch and hand the new token to every node
        the coordinator can still reach.  A partitioned node keeps its
        old epoch — the fence: when it heals, routing refuses it and its
        stale-epoch acks are detected and discarded at `resync`."""
        cur = self.epoch
        self.epoch += 1
        for node in self._nodes.values():
            # only CURRENT nodes get the new token: a healed-but-unsynced
            # node (epoch already behind) must stay fenced through
            # unrelated membership churn until its `resync` runs
            if node.alive and node.reachable and node.epoch == cur:
                node.epoch = self.epoch
        obs.event("cluster.epoch_bump", epoch=self.epoch)

    def _resident(self, node: _Node) -> Tuple[np.ndarray, np.ndarray]:
        keys, vals, live = node.store._extract(node.table)
        liven = np.asarray(live)
        K = np.asarray(keys, U32)[liven]
        V = np.asarray(vals, U32)[liven]
        if node.resize is not None:
            # mid-split the shard's items are PARTITIONED across the two
            # tables (each cohort's source copies are deleted as its token
            # flips, and between maintenance steps no cohort is half-moved),
            # so residency is the plain union of both images
            rs = node.resize
            k2, v2, l2 = rs.new_store._extract(rs.new_table)
            l2n = np.asarray(l2)
            K = np.concatenate([K, np.asarray(k2, U32)[l2n]])
            V = np.concatenate([V, np.asarray(v2, U32)[l2n]])
        return K, V

    def _distinct_resident(self) -> Tuple[np.ndarray, np.ndarray]:
        """(K, V) of every distinct key on any SERVING node, taking each
        key's value from its highest-ranked replica-set member.
        Partitioned or stale-epoch images are excluded (their divergence
        must never become authoritative), and a leftover copy on a node
        that lost ownership (un-cleaned after churn — it stops receiving
        updates the moment it leaves the set) must never outrank the
        current owners' copy."""
        seen: Dict[bytes, Tuple[int, np.ndarray]] = {}
        order: List[np.ndarray] = []
        for node in self._nodes.values():
            if not self._serving(node):
                continue
            K, V = self._resident(node)
            if not len(K):
                continue
            sets = self.directory.replica_names(K)          # (n, R)
            member = sets == node.name
            rank = np.where(member.any(axis=1),
                            np.argmax(member, axis=1), sets.shape[1] + 1)
            for k, v, r in zip(K, V, rank):
                kb = k.tobytes()
                cur = seen.get(kb)
                if cur is None:
                    order.append(k)
                    seen[kb] = (int(r), v)
                elif int(r) < cur[0]:
                    seen[kb] = (int(r), v)
        if not order:
            return np.zeros((0, 4), U32), np.zeros((0, 4), U32)
        return (np.stack(order),
                np.stack([seen[k.tobytes()][1] for k in order]))

    # -- padded per-node sub-batches ---------------------------------------
    def _padded_write(self, op: str, node: _Node, keys: np.ndarray,
                      vals: Optional[np.ndarray]):
        n = keys.shape[0]
        P = _pad(n)
        pk = np.zeros((P, 4), U32)
        pk[:n] = keys
        mask = np.zeros((P,), bool)
        mask[:n] = True
        pv = None
        if vals is not None:
            pv = np.zeros((P, 4), U32)
            pv[:n] = vals
        if node.resize is not None:
            # in-flight split: the store routes each key to the table its
            # cohort's cutover token owns (insert-during-split stays
            # lossless and duplicate-free — the matrix property gates it)
            node.resize, res = node.store.resize_write(
                node.resize, op, pk, pv, mask)
            node.table = node.resize.table
        elif vals is None:
            node.table, res = getattr(node.store, op)(node.table, pk, mask)
        else:
            node.table, res = getattr(node.store, op)(node.table, pk, pv,
                                                      mask)
        return np.asarray(res.ok)[:n], res

    def _padded_lookup(self, node: _Node, keys: np.ndarray):
        n = keys.shape[0]
        pk = np.zeros((_pad(n), 4), U32)
        pk[:n] = keys
        if node.resize is not None:
            # dual-read during the node's split window, resolved per-pair
            # by cutover token
            res = node.store.resize_lookup(node.resize, pk)
        else:
            res = node.store.lookup(node.table, pk)
        return (np.asarray(res.values)[:n], np.asarray(res.ok)[:n], res)

    # -- writes -------------------------------------------------------------
    def insert(self, keys, vals) -> ClusterWriteResult:
        return self._write("insert", keys, vals)

    def update(self, keys, vals) -> ClusterWriteResult:
        return self._write("update", keys, vals)

    def delete(self, keys) -> ClusterWriteResult:
        return self._write("delete", keys, None)

    def _write(self, op: str, keys, vals) -> ClusterWriteResult:
        with obs.span("cluster.write", op=op):
            return self._write_impl(op, keys, vals)

    def _write_impl(self, op: str, keys, vals) -> ClusterWriteResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        if self.read_only:
            # quorum loss: refuse the whole batch rather than ack data the
            # cluster cannot place on a full replica set
            self.chaos["writes_rejected_read_only"] += B
            obs.event("cluster.write_rejected_read_only", n=B)
            return ClusterWriteResult(np.zeros((B,), bool),
                                      np.zeros((B,)), 0.0)
        vals = None if vals is None else np.asarray(vals, U32).reshape(-1, 4)
        ok = np.ones((B,), bool)
        touched = np.zeros((B,), bool)
        lat = np.zeros((B,))
        round_us = 0.0
        dirs = [self.directory] + ([self._mig.new_dir] if self._mig else [])
        # one routing pass per directory (not per node): the weight
        # matrix is the cluster's hottest computation
        sets_by_dir = [d.replica_names(keys) for d in dirs]
        for node in list(self._nodes.values()):
            if not self._serving(node):
                continue
            m = np.zeros((B,), bool)
            for d, sets in zip(dirs, sets_by_dir):
                if node.name in d.nodes:
                    m |= (sets == node.name).any(axis=1)
            if not m.any():
                continue
            okn, res = self._padded_write(
                op, node, keys[m], None if vals is None else vals[m])
            ok[m] &= okn
            touched |= m
            if node.mem is not None:
                try:
                    comp = post_ledger_writes(node.mem, int(okn.sum()),
                                              int(res.ledger.pm_writes))
                except DeliveryTimeout:
                    # the retry budget drained before this member's fenced
                    # round completed: the member's ops are NOT acked (the
                    # client never saw the commit), which keeps the
                    # zero-committed-loss invariant trivially true for them
                    self.chaos["write_timeouts"] += 1
                    obs.event("cluster.write_timeout", node=node.name)
                    ok[m] = False
                    continue
                if comp is not None:
                    lat[np.flatnonzero(m)[okn]] += comp.op_us   # chain sum
                    round_us = max(round_us, comp.batch_us)
        ok &= touched           # no serving member -> not acked
        return ClusterWriteResult(ok, lat, round_us)

    # -- reads --------------------------------------------------------------
    def lookup(self, keys) -> ClusterReadResult:
        with obs.span("cluster.read"):
            return self._lookup_impl(keys)

    def _lookup_impl(self, keys) -> ClusterReadResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        values = np.zeros((B, 4), U32)
        found = np.zeros((B,), bool)
        lat = np.zeros((B,))
        round_us = 0.0
        round_us = max(round_us, self._lookup_via(
            self.directory, keys, np.ones((B,), bool), values, found, lat))
        if self._mig is not None and not found.all():
            # dual-read window: misses retry on the new directory's owner
            round_us = max(round_us, self._lookup_via(
                self._mig.new_dir, keys, ~found, values, found, lat))
        return ClusterReadResult(values, found, lat, round_us)

    def _lookup_via(self, d: Directory, keys, mask, values, found,
                    lat) -> float:
        sets = d.replica_names(keys)                       # (B, R) names
        # serve from the first SERVING member: a dead, partitioned, or
        # fenced (lagging) primary degrades to replica reads until
        # failover promotes / resync re-admits it
        serving = np.vectorize(self._name_serving)(sets)
        has = serving.any(axis=1)
        first = np.argmax(serving, axis=1)
        # a healed-but-lagging replica ranked ahead of the member chosen
        # forces a redirect — the replica-lag read path the chaos matrix
        # measures (stale images must never serve)
        lagging = np.vectorize(self._name_lagging)(sets)
        rank = np.arange(sets.shape[1])[None, :]
        self.chaos["lag_read_redirects"] += int(
            (mask[:, None] & has[:, None] & lagging
             & (rank < first[:, None])).any(axis=1).sum())
        target = np.where(has, sets[np.arange(len(first)), first], "")
        round_us = 0.0
        for name in np.unique(target[mask & has]):
            node = self._nodes[name]
            m = mask & has & (target == name)
            vs, fs, res = self._padded_lookup(node, keys[m])
            if node.mem is not None and res.plan is not None:
                try:
                    comp = node.mem.post(res.plan)
                except DeliveryTimeout:
                    # delivery gave up: the client saw nothing — these ops
                    # stay unresolved (a dual-read window may still retry
                    # them on the other directory's owner)
                    self.chaos["read_timeouts"] += 1
                    obs.event("cluster.read_timeout", node=name)
                    continue
                lat[m] = np.maximum(lat[m],
                                    comp.op_us[: int(m.sum())])
                round_us = max(round_us, comp.batch_us)
            values[m] = np.where(fs[:, None], vs, values[m])
            found[m] |= fs
        return round_us

    # -- cache-validation reads (repro.cache) -------------------------------
    # Version stamps are ENDPOINT-LOCAL: replica op histories legitimately
    # diverge after a resync (reconciliation replays different ops than the
    # originals), so a stamp is only comparable against the node that
    # produced it.  The answering node's name travels with every stamp;
    # the cache treats a different answerer — or an unresolved row — as a
    # failed validation and falls back to a full read.  That rule is what
    # keeps cached reads safe across partition/heal/resync and failover:
    # any node whose image could have moved past a client's stamp either
    # bumped the pair's version (same-node mutation, stale-ack repair,
    # resync overwrite) or stopped being the answerer.

    def _route_serving(self, keys) -> Tuple[np.ndarray, np.ndarray]:
        """(target, has): `_lookup_via`'s first-serving-member rule over
        the current directory, without the migration dual-read retry."""
        sets = self.directory.replica_names(keys)
        serving = np.vectorize(self._name_serving)(sets)
        has = serving.any(axis=1)
        first = np.argmax(serving, axis=1)
        target = np.where(has, sets[np.arange(keys.shape[0]), first], "")
        return target, has

    def _padded_stamp(self, node: _Node, keys: np.ndarray):
        """(stamps, plan, fresh).  ``fresh=False`` while the node is mid-
        split: a moved cohort's mutations bump the GROWN table's pair
        word, so a stamp against the draining source word would validate
        stale cache rows forever.  Unresolved stamps cost the cache a
        full read per hot key for the window and nothing in safety —
        callers already treat unresolved as a failed validation."""
        n = keys.shape[0]
        if node.resize is not None:
            return np.full((n, 2), -1, np.int64), None, False
        pk = np.zeros((_pad(n), 4), U32)
        pk[:n] = keys
        st = np.asarray(node.store.version_stamp(node.table, pk), np.int64)
        plan = node.store.version_read_plan(node.table, pk)
        # post only the REAL rows: validation is priced per key actually
        # checked, never per pad lane (the 8-byte-per-key claim is a gate)
        return st[:n], _slice_plan(plan, n), True

    def lookup_stamped(self, keys) -> ClusterStampedRead:
        """Cache-fill read: one routed lookup whose answers also carry the
        answering node's version stamps.  For continuity the stamp word
        lies INSIDE the segment the lookup already fetched, so the fill
        stamp is free on the wire; the post is tagged ``"fill"``.

        Live-migration windows need no special case: a join's COPY phase
        only ADDS copies, so the OLD directory's serving members (the
        routing below) hold every key, and `_write` commits bump BOTH
        directories' member sets — a stamp taken here stays honest for
        its node through the window.  The cutover's ownership changes
        surface as source mismatches at the cache, never as stale hits."""
        with obs.span("cache.fill"):
            return self._lookup_stamped_impl(keys)

    def _lookup_stamped_impl(self, keys) -> ClusterStampedRead:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        src = np.full((B,), "", object)
        values = np.zeros((B, 4), U32)
        found = np.zeros((B,), bool)
        lat = np.zeros((B,))
        stamps = None
        round_us = 0.0
        target, has = self._route_serving(keys)
        for name in np.unique(target[has]):
            node = self._nodes[name]
            m = has & (target == name)
            vs, fs, res = self._padded_lookup(node, keys[m])
            st, _, fresh = self._padded_stamp(node, keys[m])
            if stamps is None:
                stamps = np.full((B, st.shape[1]), -1, np.int64)
            if node.mem is not None and res.plan is not None:
                try:
                    comp = node.mem.post(_slice_plan(res.plan, int(m.sum())),
                                         tag="fill")
                except DeliveryTimeout:
                    self.chaos["read_timeouts"] += 1
                    continue
                lat[m] = np.maximum(lat[m], comp.op_us[: int(m.sum())])
                round_us = max(round_us, comp.batch_us)
            values[m] = np.where(fs[:, None], vs, values[m])
            found[m] |= fs
            stamps[m] = st
            if fresh:               # a mid-split answer is uncacheable
                src[m] = name
        if stamps is None:
            stamps = np.full((B, 1), -1, np.int64)
        return ClusterStampedRead(values, found, stamps, src, lat, round_us)

    def version_read(self, keys) -> ClusterStampResult:
        """Stamp-validation round: the scheme's `version_read_plan` —
        continuity: ONE depth-0 8-byte indicator-word READ per key —
        posted to each key's serving member (the OLD directory during a
        migration window, whose members stay write-current — see
        `lookup_stamped`), tagged ``"validate"``.  Keys with no serving
        member and delivery-timed-out sub-batches report unresolved;
        callers MUST treat unresolved as a failed validation (miss),
        never a hit."""
        with obs.span("cache.validate"):
            return self._version_read_impl(keys)

    def _version_read_impl(self, keys) -> ClusterStampResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        lat = np.zeros((B,))
        src = np.full((B,), "", object)
        resolved = np.zeros((B,), bool)
        stamps = None
        round_us = 0.0
        target, has = self._route_serving(keys)
        for name in np.unique(target[has]):
            node = self._nodes[name]
            m = has & (target == name)
            st, plan, fresh = self._padded_stamp(node, keys[m])
            if stamps is None:
                stamps = np.full((B, st.shape[1]), -1, np.int64)
            if node.mem is not None and plan is not None:
                try:
                    comp = node.mem.post(plan, tag="validate")
                except DeliveryTimeout:
                    self.chaos["read_timeouts"] += 1
                    continue
                lat[m] = comp.op_us[: int(m.sum())]
                round_us = max(round_us, comp.batch_us)
            stamps[m] = st
            src[m] = name
            resolved[m] = fresh
        if stamps is None:
            stamps = np.full((B, 1), -1, np.int64)
        return ClusterStampResult(stamps, src, resolved, lat, round_us)

    def scan(self, keys, spans) -> ClusterReadResult:
        """YCSB-E short scans: route each scan's START key to its serving
        primary and post the scheme's multi-record scan plan (continuity:
        ONE contiguous multi-row READ; the probe baselines: one scattered
        READ per record).  Rendezvous hashing randomizes placement, so a
        scan is the contiguous PM range around the start record on its
        owner — it never spans shards.  ``found`` reports the start
        record resolving; the fetched range rides in the plan's bytes."""
        with obs.span("cluster.scan"):
            return self._scan_impl(keys, spans)

    def _scan_impl(self, keys, spans) -> ClusterReadResult:
        keys = np.asarray(keys, U32).reshape(-1, 4)
        spans = np.maximum(np.asarray(spans, np.int64).reshape(-1), 1)
        B = keys.shape[0]
        values = np.zeros((B, 4), U32)
        found = np.zeros((B,), bool)
        lat = np.zeros((B,))
        sets = self.directory.replica_names(keys)
        serving = np.vectorize(self._name_serving)(sets)
        has = serving.any(axis=1)
        first = np.argmax(serving, axis=1)
        target = np.where(has, sets[np.arange(len(first)), first], "")
        round_us = 0.0
        for name in np.unique(target[has]):
            node = self._nodes[name]
            m = has & (target == name)
            vs, fs, _ = self._padded_lookup(node, keys[m])
            if node.mem is not None:
                plan = node.store.scan_plan(node.table, keys[m], spans[m])
                try:
                    comp = node.mem.post(plan)
                except DeliveryTimeout:
                    self.chaos["read_timeouts"] += 1
                    continue
                lat[m] = comp.op_us[: int(m.sum())]
                round_us = max(round_us, comp.batch_us)
            values[m] = np.where(fs[:, None], vs, values[m])
            found[m] |= fs
        return ClusterReadResult(values, found, lat, round_us)

    # -- background maintenance: incremental per-shard resize ---------------
    def maintenance_step(self, budget: Optional[int] = 1,
                         trigger_lf: float = 0.85, factor: int = 2,
                         step_slo_us: Optional[float] = None) -> List[dict]:
        """One maintenance round, called between foreground batches: any
        serving shard past ``trigger_lf`` begins an incremental resize;
        shards mid-split advance ``budget`` cohorts and cut over when
        drained.  Foreground traffic keeps flowing the whole time — the
        split's per-pair tokens route it (`_padded_write`/`_padded_lookup`)
        — so growth never stops the world.  Schemes without mid-split
        routing (the baselines' one-shot ``resize_step``) are driven to
        cutover inside the round: the stop-the-world stall the resize
        bench prices.  ``step_slo_us`` hands sizing to the per-step stall
        SLO controller instead of a fixed cohort count: ``begin_resize``
        derives the budget from the `LinkModel` and ``budget=None`` lets
        each step consume it.  Returns one action dict per shard touched.

        Every advancing step is priced (cohorts moved x the `LinkModel`
        cohort-move stall) against the step SLO — `DEFAULT_STEP_SLO_US`
        unless ``step_slo_us`` overrides it — feeding the
        ``maintenance.step_us`` gauge and, on overrun, the
        ``maintenance.slo_burn`` counter; a BLOCKING baseline resize is
        priced over its whole item count (the stop-the-world stall)."""
        with obs.span("cluster.maintenance"):
            return self._maintenance_impl(budget, trigger_lf, factor,
                                          step_slo_us)

    def _price_step(self, node: _Node, moved: int,
                    step_slo_us: Optional[float]) -> None:
        row = float(getattr(node.store.cfg, "row_bytes", 256))
        per = (self._link or LinkModel()).cohort_move_us(
            read_bytes=row, write_bytes=row + 16)
        step_us = moved * per
        slo = step_slo_us if step_slo_us is not None else DEFAULT_STEP_SLO_US
        reg = obs.get_registry()
        reg.gauge("maintenance.step_us", node=node.name).set(step_us)
        reg.gauge("maintenance.step_slo_us").set(slo)
        if step_us > slo:
            reg.counter("maintenance.slo_burn").inc()
            self.maintenance["slo_burns"] += 1
        obs.event("resize.step_priced", node=node.name, moved=moved,
                  step_us=round(step_us, 3), slo_us=slo)

    def _maintenance_impl(self, budget, trigger_lf, factor,
                          step_slo_us) -> List[dict]:
        actions: List[dict] = []
        for node in self._nodes.values():
            if not self._serving(node):
                continue
            if node.resize is None:
                lf = float(node.store.load_factor(node.table))
                if lf <= trigger_lf:
                    continue
                try:
                    rs = node.store.begin_resize(node.table, factor,
                                                 step_slo_us=step_slo_us)
                except TypeError:   # external store without the SLO kwarg
                    rs = node.store.begin_resize(node.table, factor)
                self.maintenance["resizes_begun"] += 1
                if not hasattr(node.store, "resize_write"):
                    node.store, node.table = node.store.resize_cutover(rs)
                    self.maintenance["blocking_resizes"] += 1
                    self._price_step(node, rs.n_items, step_slo_us)
                    obs.event("resize.blocking", node=node.name,
                              moved=rs.n_items)
                    actions.append({"node": node.name, "action": "blocking",
                                    "lf": lf, "moved": rs.n_items})
                    continue
                node.resize = rs
                node.table = rs.table
                obs.event("resize.begin", node=node.name,
                          cohorts=rs.store.cfg.num_pairs)
                actions.append({"node": node.name, "action": "begin",
                                "lf": lf, "cohorts": rs.store.cfg.num_pairs})
            else:
                moved = (budget if budget is not None
                         else (node.resize.step_budget or 1))
                rs = node.store.resize_step(node.resize, budget)
                node.table = rs.table
                self.maintenance["steps"] += 1
                self.maintenance["cohorts_moved"] += moved
                self._price_step(node, moved, step_slo_us)
                if rs.done:
                    node.store, node.table = node.store.resize_cutover(rs)
                    node.resize = None
                    self.maintenance["cutovers"] += 1
                    obs.event("resize.cutover", node=node.name,
                              moved=rs.moved)
                    actions.append({"node": node.name, "action": "cutover",
                                    "moved": rs.moved,
                                    "n_items": rs.n_items})
                else:
                    node.resize = rs
                    actions.append({"node": node.name, "action": "step",
                                    "moved": rs.moved})
        return actions

    # -- rebalance: live join / leave ---------------------------------------
    def begin_join(self, name: str,
                   node_slots: Optional[int] = None) -> _Migration:
        """COPY phase: add the node, ship it every key it will own.  Reads
        keep routing through the OLD directory (dual-read covers the
        window); `complete_join` is the cutover."""
        with obs.span("cluster.join.copy", node=name):
            return self._begin_join_impl(name, node_slots)

    def _begin_join_impl(self, name: str,
                         node_slots: Optional[int] = None) -> _Migration:
        assert self._mig is None, "a migration is already in flight"
        new_dir = self.directory.with_node(name)
        self._nodes[name] = self._make_node(name, node_slots)
        K, V = self._distinct_resident()
        if len(K):
            new_sets = new_dir.replica_names(K)
            to_new = (new_sets == name).any(axis=1)
            moved_primary = int((new_sets[:, 0] == name).sum())
            copied = int(to_new.sum())
            if copied:
                okn, _ = self._padded_write("insert", self._nodes[name],
                                            K[to_new], V[to_new])
                assert okn.all(), "join target too small for its shard"
        else:
            moved_primary = copied = 0
        self._mig = _Migration(new_dir, len(K), copied, moved_primary)
        return self._mig

    def complete_join(self) -> RebalanceReport:
        """CUTOVER (one host-atomic directory swap — the PM token twin is
        `migration.token_record`) + CLEANUP (drop un-owned copies)."""
        assert self._mig is not None, "no migration in flight"
        mig = self._mig
        joined = set(mig.new_dir.nodes) - set(self.directory.nodes)
        obs.event("cluster.join.cutover", node=next(iter(joined)),
                  copied=mig.copied)
        self.directory = mig.new_dir
        self._mig = None
        self._bump_epoch()
        cleaned = self._cleanup()
        return RebalanceReport(
            kind="join", node=next(iter(joined)), resident=mig.resident,
            moved_primary=mig.moved_primary, copied=mig.copied,
            cleaned=cleaned, bound=1.0 / len(self.directory.nodes) + 0.05)

    def join(self, name: str,
             node_slots: Optional[int] = None) -> RebalanceReport:
        self.begin_join(name, node_slots)
        return self.complete_join()

    def leave(self, name: str) -> RebalanceReport:
        """Graceful decommission: re-home the leaving node's keys, cut
        over, drop the node."""
        assert self._mig is None, "complete the in-flight migration first"
        assert self.is_alive(name), name
        new_dir = self.directory.without_node(name)
        K, V = self._distinct_resident()
        copied = 0
        if len(K):
            old_sets = self.directory.replica_names(K)
            new_sets = new_dir.replica_names(K)
            moved_primary = int(
                (old_sets[:, 0] != new_sets[:, 0]).sum())
            for node in self._nodes.values():
                if node.name == name or not node.alive:
                    continue
                gains = ((new_sets == node.name).any(axis=1)
                         & ~(old_sets == node.name).any(axis=1))
                if gains.any():
                    okn, _ = self._padded_write("insert", node, K[gains],
                                                V[gains])
                    copied += int(okn.sum())
        else:
            moved_primary = 0
        self.directory = new_dir
        del self._nodes[name]
        self._bump_epoch()
        return RebalanceReport(
            kind="leave", node=name, resident=len(K),
            moved_primary=moved_primary, copied=copied, cleaned=0,
            bound=1.0 / (len(new_dir.nodes) + 1) + 0.05)

    def _cleanup(self) -> int:
        cleaned = 0
        for node in self._nodes.values():
            if not self._serving(node):
                continue
            K, _ = self._resident(node)
            if not len(K):
                continue
            drop = ~self.directory.owned_mask(K, node.name)
            if drop.any():
                okn, _ = self._padded_write("delete", node, K[drop], None)
                cleaned += int(okn.sum())
        return cleaned

    # -- failure ------------------------------------------------------------
    def kill(self, name: str) -> None:
        """Crash a node: it goes silent, its PM image frozen as-is.
        Detection (heartbeat timeout) and promotion are the
        `FailoverController`'s job."""
        self._nodes[name].alive = False
        obs.event("cluster.kill", node=name)

    # -- partitions & fencing ----------------------------------------------
    def partition(self, name: str) -> None:
        """Cut a node off the cluster network: it stays ALIVE (its image
        keeps accepting whatever `stale_write` injects) but the
        coordinator cannot reach it.  The epoch bump is the fence —
        every reachable node gets the new token, the partitioned node
        keeps the old one, and `_serving` refuses it from then on."""
        node = self._nodes[name]
        assert node.alive and node.reachable, name
        node.reachable = False
        obs.event("cluster.partition", node=name)
        self._bump_epoch()

    def heal(self, name: str) -> None:
        """The partition heals: the node is reachable again but still
        holds its OLD epoch token, so routing keeps it fenced (the
        replica-lag window) until `resync` reconciles its image."""
        node = self._nodes[name]
        assert node.alive and not node.reachable, name
        node.reachable = True
        obs.event("cluster.heal", node=name)

    def stale_write(self, name: str, keys, vals) -> int:
        """A client that has not heard about the partition writes THROUGH
        the stale ex-primary, which acks alone — the unfenced-ack hazard
        `replication.check_replicated_durability`'s negative control
        demonstrates.  Every such ack is logged with the node's (stale)
        epoch; `resync` or `failover` must detect ALL of them
        (``chaos['stale_acks_detected'] == chaos['stale_acks_injected']``
        is the matrix gate) and none may survive into the keyspace."""
        node = self._nodes[name]
        assert node.alive and not node.reachable, name
        keys = np.asarray(keys, U32).reshape(-1, 4)
        vals = np.asarray(vals, U32).reshape(-1, 4)
        _, fnd, _ = self._padded_lookup(node, keys)
        if fnd.any():
            self._padded_write("update", node, keys[fnd], vals[fnd])
        if (~fnd).any():
            self._padded_write("insert", node, keys[~fnd], vals[~fnd])
        node.stale_log.append((keys, vals, node.epoch))
        self.chaos["stale_acks_injected"] += int(keys.shape[0])
        return int(keys.shape[0])

    def _detect_stale(self, node: _Node) -> int:
        """Fence check: every logged ack carrying an epoch older than the
        directory's is detected (and its divergence discarded with the
        image).  Returns the count and clears the log."""
        detected = sum(len(k) for k, _, e in node.stale_log
                       if e < self.epoch)
        node.stale_log.clear()
        self.chaos["stale_acks_detected"] += detected
        return detected

    def resync(self, name: str) -> HealReport:
        """Re-admit a healed node by RECONCILING its image against the
        serving replicas — never by wiping it, because the node may hold
        the sole surviving copy of committed keys whose co-replica died
        while it was partitioned.  Three passes:

          1. stale-ack repair: every key the node acked while fenced is
             overwritten from the current primaries where they hold it
             and DELETED where they do not (a stale insert must not
             resurface as a legitimate sole copy);
          2. catch-up: every authoritative key the node owns is inserted
             if missing and overwritten if divergent (writes it missed
             while out of the set);
          3. garbage: copies of keys it no longer owns are dropped (they
             stop receiving updates and would silently go stale).

        Then the node gets the current epoch token and `_serving`
        accepts it again."""
        with obs.span("cluster.resync", node=name):
            return self._resync_impl(name)

    def _resync_impl(self, name: str) -> HealReport:
        node = self._nodes[name]
        assert node.alive and node.reachable, name
        assert node.epoch < self.epoch, f"{name} is already current"
        stale_keys = (np.concatenate(
            [k for k, _, e in node.stale_log if e < self.epoch])
            if node.stale_log else np.zeros((0, 4), U32))
        detected = self._detect_stale(node)
        K, V = self._distinct_resident()    # authoritative (excludes node)
        auth = {k.tobytes() for k in K}
        if len(stale_keys):
            held = np.array([k.tobytes() in auth for k in stale_keys],
                            bool)
            if (~held).any():
                self._padded_write("delete", node, stale_keys[~held], None)
            # held ones are refreshed by the catch-up pass below
        resynced = 0
        if len(K):
            own = self.directory.owned_mask(K, name)
            if own.any():
                Ko, Vo = K[own], V[own]
                vs, have, _ = self._padded_lookup(node, Ko)
                div = have & (vs != Vo).any(axis=1)
                if (~have).any():
                    okn, _ = self._padded_write("insert", node, Ko[~have],
                                                Vo[~have])
                    resynced += int(okn.sum())
                if div.any():
                    okn, _ = self._padded_write("update", node, Ko[div],
                                                Vo[div])
                    resynced += int(okn.sum())
        Kn, Vn = self._resident(node)
        if len(Kn):
            unowned = ~self.directory.owned_mask(Kn, name)
            in_auth = np.array([k.tobytes() in auth for k in Kn], bool)
            # an un-owned key with NO authoritative holder is a sole
            # surviving copy (its owners died while this node was out):
            # re-home it to its serving owners before dropping it here
            orphan = unowned & ~in_auth
            if orphan.any():
                osets = self.directory.replica_names(Kn[orphan])
                for other in self._nodes.values():
                    if other is node or not self._serving(other):
                        continue
                    g = (osets == other.name).any(axis=1)
                    if g.any():
                        self._padded_write("insert", other,
                                           Kn[orphan][g], Vn[orphan][g])
            if unowned.any():
                self._padded_write("delete", node, Kn[unowned], None)
        node.epoch = self.epoch
        obs.event("cluster.resynced", node=name, stale_detected=detected,
                  resynced=resynced)
        return HealReport(node=name, stale_acks_detected=detected,
                          resynced=resynced)

    def quiesce_faults(self) -> None:
        """Disable delivery-fault injection on every endpoint (and for
        nodes made later).  The audit phase calls this: it measures
        durability, not delivery luck — a dropped audit READ must not
        masquerade as lost data."""
        self._faults = None
        for node in self._nodes.values():
            if node.mem is not None:
                node.mem.faults = None

    def failover(self, dead: str) -> FailoverReport:
        """Promote the failed node's replicas: directory removal re-ranks
        them to primary, every survivor runs its scheme's restart
        procedure on its (possibly mid-write) image, and the lost
        replica count is restored from the new primaries.  ``dead`` may
        be crashed OR partitioned past the suspicion grace window — a
        partitioned ex-primary is fenced out the same way, and every
        stale ack it took is detected here."""
        with obs.span("cluster.failover", node=dead):
            return self._failover_impl(dead)

    def _failover_impl(self, dead: str) -> FailoverReport:
        node = self._nodes[dead]
        assert not (node.alive and node.reachable), dead
        self._detect_stale(node)
        old_dir = self.directory
        if dead not in old_dir.nodes:
            # a joiner died inside its own migration window: it owned
            # nothing yet (the source is still authoritative), so the
            # join is void — drop the node and its copies, promote nobody
            assert self._mig is not None and dead in self._mig.new_dir.nodes
            self._mig = None
            del self._nodes[dead]
            return FailoverReport(dead=dead, promoted_keys=0, recopied=0,
                                  recovery={})
        new_dir = old_dir.without_node(dead)
        if self._mig is not None:
            # a primary died inside a migration window: the PENDING
            # cutover must target the post-failover membership, or
            # complete_join would resurrect the dead node (and is moot
            # when the dead node IS the joiner)
            nd = (self._mig.new_dir.without_node(dead)
                  if dead in self._mig.new_dir.nodes else self._mig.new_dir)
            if set(nd.nodes) == set(new_dir.nodes):
                self._mig = None
            else:
                self._mig = dataclasses.replace(self._mig, new_dir=nd)
        recovery = {}
        obs.event("failover.fenced", node=dead, epoch=self.epoch)
        for node in self._nodes.values():
            if not self._serving(node):
                continue
            node.table, report = node.store.recover(node.table)
            obs.event("failover.recovered", node=node.name)
            if node.resize is not None:
                # a survivor mid-split restarts BOTH images; the handle
                # resumes from the recovered tables (tokens are host
                # state here — PM-token recovery is the matrix cell's job)
                rs = node.resize
                new_table, _ = rs.new_store.recover(rs.new_table)
                node.resize = dataclasses.replace(
                    rs, table=node.table, new_table=new_table)
            recovery[node.name] = report
        del self._nodes[dead]
        self.directory = new_dir
        self._bump_epoch()
        K, V = self._distinct_resident()
        promoted = recopied = 0
        if len(K):
            promoted = int((old_dir.replica_names(K)[:, 0] == dead).sum())
            new_sets = new_dir.replica_names(K)
            for node in self._nodes.values():
                if not self._serving(node):
                    continue
                need = (new_sets == node.name).any(axis=1)
                if not need.any():
                    continue
                vs, have, _ = self._padded_lookup(node, K[need])
                # backfill missing copies AND refresh stale ones: a node
                # re-entering a key's replica set after churn may hold a
                # leftover copy that stopped receiving updates while it
                # was out of the set — re-ranked to primary, that stale
                # copy would serve unless re-replication overwrites it
                stale = have & (vs != V[need]).any(axis=1)
                miss = np.flatnonzero(need)[~have]
                fix = np.flatnonzero(need)[stale]
                if len(miss):
                    okn, _ = self._padded_write("insert", node, K[miss],
                                                V[miss])
                    recopied += int(okn.sum())
                if len(fix):
                    okn, _ = self._padded_write("update", node, K[fix],
                                                V[fix])
                    recopied += int(okn.sum())
        obs.event("failover.promoted", node=dead, promoted=promoted,
                  recopied=recopied)
        return FailoverReport(dead=dead, promoted_keys=promoted,
                              recopied=recopied, recovery=recovery)

    # -- diagnostics --------------------------------------------------------
    def total_resident(self) -> int:
        return len(self._distinct_resident()[0])

    def metrics_view(self) -> obs.MetricsRegistry:
        """ONE registry merged across every node endpoint (counters add,
        histograms merge buckets, gauges keep the worst observed) — the
        cross-node roll-up a traced run exports.  Per-node registries
        stay intact on each `RemoteMemory`."""
        reg = obs.MetricsRegistry()
        for node in self._nodes.values():
            if node.mem is not None:
                reg.merge(node.mem.metrics)
        return reg

    def stats(self) -> dict:
        out = {"scheme": self.scheme, "nodes": {}, "replicas":
               self.directory.replicas, "migrating": self._mig is not None,
               "epoch": self.epoch, "read_only": self.read_only,
               "chaos": dict(self.chaos),
               "maintenance": dict(self.maintenance)}
        for node in self._nodes.values():
            st = {"alive": node.alive, "reachable": node.reachable,
                  "epoch": node.epoch, "resizing": node.resize is not None,
                  "resident": int(len(self._resident(node)[0]))}
            if node.mem is not None:
                st["wire"] = node.mem.stats()
            out["nodes"][node.name] = st
        return out

"""LM substrate: pure-JAX model zoo (params = pytrees, scan-over-layers)."""

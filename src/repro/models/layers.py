"""Transformer building blocks: norms, RoPE, blockwise attention, MLP, MoE.

All functions are pure; parameters are plain dicts of arrays. Attention is
blockwise (scan over query chunks, online accumulation is unnecessary because
each chunk sees the full key range with masking), which bounds activation
memory at O(chunk * S) per layer instead of O(S^2) — required for the 32k
prefill shapes. Sliding-window attention uses a *banded* static slice of
width (window + chunk) so its FLOPs are O(S * window), not O(S^2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def _dense_moe_group(num_experts: int) -> int:
    """Expert-group size for the dense MoE scan (bounds transients)."""
    for g in (8, 5, 4, 2, 1):
        if num_experts % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), -1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps) * scale.astype(F32)
    return y.astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(F32) \
        + bias.astype(F32)
    return y.astype(x.dtype)


def apply_norm(cfg, p, prefix, x):
    if cfg.norm == "rms":
        return rmsnorm(x, p[f"{prefix}_scale"])
    return layernorm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"])


# ---------------------------------------------------------------------------
# rotary position embedding (GPT-NeoX half-rotation convention)
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (..., S, H, D) or (..., H, D) with positions (..., S) / (...,)."""
    D = x.shape[-1]
    half = D // 2
    freq = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = positions[..., None].astype(F32) * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise causal attention (GQA)
# ---------------------------------------------------------------------------

def _attn_scores(q, k, scale):
    """q (B,C,KVH,G,D) x k (B,T,KVH,D) -> (B,KVH,G,C,T) f32."""
    return jnp.einsum("bckgd,btkd->bkgct", q.astype(F32), k.astype(F32),
                      preferred_element_type=F32) * scale


def _attn_out(p, v):
    """p (B,KVH,G,C,T) x v (B,T,KVH,D) -> (B,C,KVH,G,D)."""
    return jnp.einsum("bkgct,btkd->bckgd", p, v.astype(F32),
                      preferred_element_type=F32)


def blockwise_attention(q, k, v, *, chunk: int, window: int = 0,
                        q_offset=0, causal_skip: bool = False):
    """Causal (optionally sliding-window) attention, scanned over q chunks.

    q: (B, S, H, D); k, v: (B, T, KVH, D); returns (B, S, H, D).
    ``q_offset``: absolute position of q[0] (for prefill continuation).
    window > 0 restricts attention to the last ``window`` positions and uses a
    banded static slice (FLOPs O(S·window)).
    ``causal_skip``: inner-scan over KV chunks with a ``lax.cond`` skip of
    strictly-above-diagonal chunk pairs + online softmax — runtime FLOPs drop
    to the causal half (nC+1)/(2·nC) at the cost of a serialized inner loop
    (hillclimb lever; see EXPERIMENTS.md §Perf).
    """
    B, S_in, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / (D ** 0.5)
    C = min(chunk, S_in)
    if S_in % C:                       # pad q chunks; outputs sliced below
        q = jnp.pad(q, ((0, 0), (0, C - S_in % C), (0, 0), (0, 0)))
    S = q.shape[1]
    nC = S // C
    qg = q.reshape(B, nC, C, KVH, G, D)

    if window > 0:
        band = window + C                               # static banded width

        def step(c):
            qc = qg[:, c]
            start = jnp.maximum(c * C + q_offset - window, 0)
            start = jnp.minimum(start, jnp.maximum(T - band, 0))
            kb = jax.lax.dynamic_slice_in_dim(k, start, min(band, T), 1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, min(band, T), 1)
            s = _attn_scores(qc, kb, scale)             # (B,KVH,G,C,band)
            qpos = c * C + q_offset + jnp.arange(C)
            kpos = start + jnp.arange(min(band, T))
            m = (kpos[None, :] <= qpos[:, None]) & \
                (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(m[None, None, None], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            return _attn_out(p, vb)
    elif causal_skip:
        if T % C:                      # pad kv to a chunk multiple (masked)
            k = jnp.pad(k, ((0, 0), (0, C - T % C), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, C - T % C), (0, 0), (0, 0)))
            T = k.shape[1]
        nK = T // C
        KVH2, G2 = k.shape[2], H // k.shape[2]

        def step(c):
            qc = qg[:, c]
            qpos = c * C + q_offset + jnp.arange(C)

            def inner(carry, j):
                m_r, l_r, acc = carry

                def compute(carry):
                    m_r, l_r, acc = carry
                    kj = jax.lax.dynamic_slice_in_dim(k, j * C, C, 1)
                    vj = jax.lax.dynamic_slice_in_dim(v, j * C, C, 1)
                    s = _attn_scores(qc, kj, scale)     # (B,KVH,G,C,C)
                    kpos = j * C + jnp.arange(C)
                    mask = kpos[None, :] <= qpos[:, None]
                    s = jnp.where(mask[None, None, None], s, -1e30)
                    m_new = jnp.maximum(m_r, jnp.max(s, -1))
                    p = jnp.exp(s - m_new[..., None])
                    alpha = jnp.exp(m_r - m_new)
                    l_new = l_r * alpha + jnp.sum(p, -1)
                    acc = acc * alpha[..., None] + jnp.einsum(
                        "bkgct,btkd->bkgcd", p, vj.astype(F32),
                        preferred_element_type=F32)
                    return m_new, l_new, acc

                carry = jax.lax.cond(j <= c, compute, lambda x: x,
                                     (m_r, l_r, acc))
                return carry, None

            B2 = qc.shape[0]
            init = (jnp.full((B2, KVH2, G2, C), -1e30, F32),
                    jnp.zeros((B2, KVH2, G2, C), F32),
                    jnp.zeros((B2, KVH2, G2, C, D), F32))
            (m_r, l_r, acc), _ = jax.lax.scan(inner, init, jnp.arange(nK))
            out = acc / jnp.maximum(l_r, 1e-30)[..., None]
            return jnp.moveaxis(out, 3, 1)              # (B,C,KVH,G,D)
    else:
        kpos = jnp.arange(T)

        def step(c):
            qc = qg[:, c]
            s = _attn_scores(qc, k, scale)              # (B,KVH,G,C,T)
            qpos = c * C + q_offset + jnp.arange(C)
            m = kpos[None, :] <= qpos[:, None]
            s = jnp.where(m[None, None, None], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            return _attn_out(p, v)

    out = jax.lax.map(step, jnp.arange(nC))             # (nC,B,C,KVH,G,D)
    out = jnp.moveaxis(out, 0, 1)                       # (B,nC,C,KVH,G,D)
    return out.reshape(B, S, H, D)[:, :S_in].astype(q.dtype)


def decode_attention(q, k, v, seq_len, *, window: int = 0):
    """Single-token attention against a (B, T, KVH, D) cache (T = ring or
    linear buffer). q: (B, H, D). ``seq_len`` (B,) live lengths. For ring
    buffers (window>0) the cache is position-mod-window; masking is by
    liveness only since all live entries are within the window."""
    B, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, KVH, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(F32), k.astype(F32),
                   preferred_element_type=F32) * scale
    idx = jnp.arange(T)[None]
    live = idx < jnp.minimum(seq_len, T if window == 0 else window)[:, None]
    s = jnp.where(live[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(F32),
                     preferred_element_type=F32)
    return out.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention(q, kg, vg, page_table, seq_lens, page_size: int):
    """Decode attention over gathered pages with (MAXP, PS) kept separate.

    q: (DS, Bl, H, D); kg/vg: (DS, Bl, MAXP, KVH, PS, D);
    page_table: (DS, Bl, MAXP) (-1 = unmapped); seq_lens: (DS, Bl) live
    lengths INCLUDING the just-written token. Returns (DS, Bl, H, D).

    The PS axis can stay sharded over the model axis (split-KV): the softmax
    reductions and the value contraction produce small cross-shard
    all-reduces instead of a cache-sized reshard.
    """
    DS, Bl, H, D = q.shape
    KVH, PS = kg.shape[3], kg.shape[4]
    G = H // KVH
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(DS, Bl, KVH, G, D).astype(F32)
    s = jnp.einsum("sbkgd,sbmkpd->sbkgmp", qg, kg.astype(F32),
                   preferred_element_type=F32) * scale
    tok = (jnp.arange(kg.shape[2])[:, None] * page_size
           + jnp.arange(PS)[None, :])                   # (MAXP, PS)
    live = (tok[None, None] < seq_lens[..., None, None]) \
        & (page_table[..., None] >= 0)                  # (DS,Bl,MAXP,PS)
    s = jnp.where(live[:, :, None, None], s, -1e30)
    m = jnp.max(s, axis=(-2, -1), keepdims=True)
    pr = jnp.exp(s - m)
    denom = jnp.maximum(jnp.sum(pr, axis=(-2, -1), keepdims=True), 1e-30)
    pr = pr / denom
    out = jnp.einsum("sbkgmp,sbmkpd->sbkgd", pr, vg.astype(F32),
                     preferred_element_type=F32)
    return out.reshape(DS, Bl, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(cfg, p, x):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        g = jnp.einsum("...e,ef->...f", x, p["w_gate"].astype(dt))
        u = jnp.einsum("...e,ef->...f", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g.astype(F32)).astype(dt) * u
    else:
        h = jnp.einsum("...e,ef->...f", x, p["w_up"].astype(dt))
        h = jax.nn.gelu(h.astype(F32)).astype(dt)
    return jnp.einsum("...f,fe->...e", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, sort-based dispatch, capacity-bounded)
# ---------------------------------------------------------------------------

def moe(cfg, p, x):
    """x: (B, S, E) -> (B, S, E). Two implementations with identical outputs:

    "sorted": capacity-bucket dispatch (argsort + scatter). Standard for big
    experts, but the data-dependent scatter/gather makes GSPMD reshard the
    dispatch buffers across the mesh (collective-heavy; see §Perf).

    "dense": compute EVERY expert for every token and weight by the (masked,
    renormalized top-k) gates. E/top_k x the active FLOPs, zero dispatch
    communication, no token dropping. For small experts (granite: dff=512)
    this converts a collective-bound layer into a compute-bound one.
    Expert groups are scanned to bound the (T, E_g, dff) transient.
    """
    m = cfg.moe
    B, S, E = x.shape
    T = B * S
    xt = x.reshape(T, E)
    logits = jnp.einsum("te,en->tn", xt.astype(F32), p["router"].astype(F32))
    topv, topi = jax.lax.top_k(logits, m.top_k)          # (T, k)
    gates = jax.nn.softmax(topv, axis=-1)                # renormalized top-k

    if m.impl == "dense":
        gate_full = jnp.zeros((T, m.num_experts), F32)
        gate_full = gate_full.at[jnp.arange(T)[:, None], topi].set(gates)
        GE = _dense_moe_group(m.num_experts)

        def group(carry, idx):
            acc = carry
            wg = jax.lax.dynamic_slice_in_dim(p["we_gate"], idx * GE, GE, 0)
            wu = jax.lax.dynamic_slice_in_dim(p["we_up"], idx * GE, GE, 0)
            wd = jax.lax.dynamic_slice_in_dim(p["we_down"], idx * GE, GE, 0)
            gf = jax.lax.dynamic_slice_in_dim(gate_full, idx * GE, GE, 1)
            g = jnp.einsum("td,xdf->txf", xt, wg.astype(x.dtype))
            u = jnp.einsum("td,xdf->txf", xt, wu.astype(x.dtype))
            h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
            y = jnp.einsum("txf,xfd->txd", h, wd.astype(x.dtype))
            acc = acc + jnp.einsum("txd,tx->td", y.astype(F32), gf)
            return acc, None

        n_groups = m.num_experts // GE
        out, _ = jax.lax.scan(group, jnp.zeros((T, E), F32),
                              jnp.arange(n_groups))
        me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
        ce = jnp.bincount(topi.reshape(-1), length=m.num_experts) \
            / (T * m.top_k)
        aux = m.num_experts * jnp.sum(me * ce)
        return out.reshape(B, S, E).astype(x.dtype), aux

    K = m.top_k
    eid = topi.reshape(T * K)
    tid = jnp.repeat(jnp.arange(T), K)
    gk = gates.reshape(T * K)
    order = jnp.argsort(eid)
    se, st, sg = eid[order], tid[order], gk[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * K) - first                      # rank within expert
    cap = int(np.ceil(T * K / m.num_experts * m.capacity_factor))
    keep = pos < cap

    drop = m.num_experts                                  # OOB bucket
    be = jnp.where(keep, se, drop)
    buf = jnp.zeros((m.num_experts, cap, E), x.dtype)
    buf = buf.at[be, jnp.minimum(pos, cap - 1)].set(xt[st], mode="drop")

    g = jnp.einsum("xcd,xdf->xcf", buf, p["we_gate"].astype(x.dtype))
    u = jnp.einsum("xcd,xdf->xcf", buf, p["we_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    y = jnp.einsum("xcf,xfd->xcd", h, p["we_down"].astype(x.dtype))

    out = jnp.zeros((T, E), F32)
    contrib = y[jnp.minimum(se, m.num_experts - 1), jnp.minimum(pos, cap - 1)]
    contrib = contrib.astype(F32) * (sg * keep)[:, None]
    out = out.at[st].add(contrib)
    # auxiliary load-balance loss (Switch-style), returned for training
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    ce = jnp.bincount(eid, length=m.num_experts) / (T * K)
    aux = m.num_experts * jnp.sum(me * ce)
    return out.reshape(B, S, E).astype(x.dtype), aux

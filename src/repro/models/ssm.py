"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Forward pass uses the chunked SSD algorithm: within a chunk the recurrence is
materialized as a masked (attention-like) matrix — the "duality" — and chunks
are linked by a ``lax.scan`` over the running state, so cost is
O(S·chunk·(d_state + head_dim)) — sub-quadratic in S, which is what makes the
``long_500k`` shape runnable for the SSM/hybrid archs.

Decode is the O(1)-per-token recurrence with a rolling conv window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.d_state
    return d_inner, nheads, conv_ch


def init_ssm_params(key, cfg, scale=0.02):
    s = cfg.ssm
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    E = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.d_state + nheads
    # Mamba-2 dt init: dt ~ LogUniform(1e-3, 1e-1) via softplus^-1 bias —
    # slow decay gives the state usefully long memory from step 0
    dt0 = jnp.exp(jax.random.uniform(k4, (nheads,), F32,
                                     jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_proj": jax.random.normal(k1, (E, proj_out), F32) * scale,
        "conv_w": jax.random.normal(k2, (s.conv_width, conv_ch), F32) * scale,
        "conv_b": jnp.zeros((conv_ch,), F32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)),
        "D": jnp.ones((nheads,), F32),
        "dt_bias": jnp.log(jnp.expm1(dt0)),
        "ssm_norm": jnp.ones((d_inner,), F32),
        "out_proj": jax.random.normal(k3, (d_inner, E), F32) * scale,
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_inner, nheads, _ = ssm_dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
               2 * d_inner + 2 * s.d_state], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(xcbc, w, b):
    """Depthwise causal conv over (B, S, CH) with kernel (W, CH)."""
    W = w.shape[0]
    pad = jnp.pad(xcbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xcbc.shape[1]] * w[i][None, None] for i in range(W))
    return out + b


def ssd_forward(cfg, p, x, apply_out: bool = True):
    """x: (B, S, E) -> (B, S, E) (or (B, S, d_inner) when ``apply_out`` is
    False — hybrid archs fuse heads before a shared projection). Chunked SSD
    with a state scan across chunks."""
    s = cfg.ssm
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    B_, S_in, E = x.shape
    P, N, Q = s.head_dim, s.d_state, min(s.chunk, S_in)
    if S_in % Q:                       # zero-pad tail to a chunk multiple
        x = jnp.pad(x, ((0, 0), (0, Q - S_in % Q), (0, 0)))
    S = x.shape[1]
    nQ = S // Q

    proj = jnp.einsum("bse,ef->bsf", x, p["in_proj"].astype(x.dtype))
    z, xc, Bm, Cm, dtr = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], -1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                    p["conv_b"].astype(x.dtype)).astype(F32))
    xc, Bm, Cm = (conv[..., :d_inner],
                  conv[..., d_inner:d_inner + N],
                  conv[..., d_inner + N:])
    xh = xc.reshape(B_, S, nheads, P)                       # (B,S,H,P)
    dt = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                # (H,) negative
    la = dt * A[None, None]                                 # log decay (B,S,H)

    # chunked views
    lac = la.reshape(B_, nQ, Q, nheads)
    cum = jnp.cumsum(lac, axis=2)                           # (B,nQ,Q,H)
    xq = xh.reshape(B_, nQ, Q, nheads, P)
    dtq = dt.reshape(B_, nQ, Q, nheads)
    Bq = Bm.reshape(B_, nQ, Q, N).astype(F32)
    Cq = Cm.reshape(B_, nQ, Q, N).astype(F32)

    # intra-chunk (duality: masked attention-like term). Mask BEFORE exp:
    # masked (t < s) entries have POSITIVE log-decay whose exp overflows, and
    # where(mask, exp(seg), 0)'s VJP would produce 0 * inf = NaN.
    CB = jnp.einsum("bqtn,bqsn->bqts", Cq, Bq)              # (B,nQ,Q,Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # l_t - l_s (B,nQ,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    G = jnp.exp(seg) * CB[..., None] * dtq[:, :, None, :, :]  # weight by dt_s
    y_intra = jnp.einsum("bqtsh,bqshp->bqthp", G, xq.astype(F32))

    # inter-chunk state scan
    decay_out = jnp.exp(cum)                                 # exp(l_t)
    decay_in = jnp.exp(cum[:, :, -1:, :] - cum)              # exp(l_Q - l_s)
    dBx = jnp.einsum("bqsh,bqsn,bqshp->bqhnp",
                     dtq * decay_in, Bq, xq.astype(F32))     # chunk state delta
    chunk_decay = jnp.exp(cum[:, :, -1])                     # (B,nQ,H)

    def scan_fn(state, inp):
        dS, cd = inp                                         # (B,H,N,P),(B,H)
        new = state * cd[..., None, None] + dS
        return new, state                                    # emit PRE-state

    init = jnp.zeros((B_, nheads, N, P), F32)
    _, pre_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    pre = jnp.moveaxis(pre_states, 0, 1)                     # (B,nQ,H,N,P)
    y_inter = jnp.einsum("bqtn,bqth,bqhnp->bqthp",
                         Cq, decay_out, pre)

    y = (y_intra + y_inter).reshape(B_, S, nheads, P)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = y * jax.nn.silu(z.astype(F32))
    # RMSNorm before out-projection (Mamba-2 block layout)
    var = jnp.mean(jnp.square(y), -1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["ssm_norm"]
    y = y[:, :S_in]                    # drop chunk padding
    if not apply_out:
        return y.astype(x.dtype)
    return jnp.einsum("bsf,fe->bse", y.astype(x.dtype),
                      p["out_proj"].astype(x.dtype))


def init_ssm_state(cfg, batch, dtype=F32):
    s = cfg.ssm
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    return {
        "S": jnp.zeros((batch, nheads, s.d_state, s.head_dim), F32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssd_decode(cfg, p, x, state, apply_out: bool = True):
    """One-token recurrent step. x: (B, E); returns (y (B, E), new_state)."""
    s = cfg.ssm
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    B_ = x.shape[0]
    N, P = s.d_state, s.head_dim

    proj = jnp.einsum("be,ef->bf", x, p["in_proj"].astype(x.dtype))
    z, xc, Bm, Cm, dtr = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], -1)              # (B, CH)
    hist = jnp.concatenate([state["conv"], conv_in[:, None]], 1)  # (B, W, CH)
    w = p["conv_w"].astype(x.dtype)
    conv = jax.nn.silu((jnp.einsum("bwc,wc->bc", hist, w)
                        + p["conv_b"].astype(x.dtype)).astype(F32))
    xc, Bv, Cv = (conv[:, :d_inner], conv[:, d_inner:d_inner + N],
                  conv[:, d_inner + N:])
    xhp = xc.reshape(B_, nheads, P)
    dt = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"])     # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                   # (B,H)
    S_new = state["S"] * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bv.astype(F32), xhp.astype(F32))
    y = jnp.einsum("bn,bhnp->bhp", Cv.astype(F32), S_new)
    y = y + xhp.astype(F32) * p["D"][None, :, None]
    y = y.reshape(B_, d_inner) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(y), -1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["ssm_norm"]
    new_state = {"S": S_new, "conv": hist[:, 1:]}
    if not apply_out:
        return y.astype(x.dtype), new_state
    out = jnp.einsum("bf,fe->be", y.astype(x.dtype), p["out_proj"].astype(x.dtype))
    return out, new_state

"""Decoder-LM assembly for every assigned architecture family.

Design notes:
  * params are plain dicts; per-layer params are STACKED along a leading L
    dim and the layer stack runs under ``lax.scan`` — HLO size (and so CPU
    dry-run compile time) is depth-independent;
  * every family shares this file: dense / moe / audio / vlm are one block
    shape; hybrid adds parallel SSM heads; ssm drops attention entirely;
  * hybrid global-attention layers (hymba places them at first/middle/last)
    are lifted OUT of the scan as static segments, so each layer's attention
    window is compile-time static — no dual-branch waste, exact FLOP
    accounting in ``cost_analysis`` for the roofline;
  * activation shardings are expressed in LOGICAL axes (distribution/sharding)
    so the same model code lowers on 1 CPU device, a 16x16 pod, or 2x16x16;
  * decode for full-attention archs runs against the hash-indexed paged KV
    pool (the paper's technique on the serving hot path); window/SSM archs
    carry ring buffers / recurrent state.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.sharding import shard
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig

F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    E, Lh, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    keys = jax.random.split(key, 16)
    sc = 0.02

    def norm_params(prefix):
        p = {f"{prefix}_scale": jnp.ones((Lh, E), F32)}
        if cfg.norm == "ln":
            p[f"{prefix}_bias"] = jnp.zeros((Lh, E), F32)
        return p

    blocks = {}
    blocks.update(norm_params("ln1"))
    blocks.update(norm_params("ln2"))

    if cfg.has_attention:
        blocks["wq"] = jax.random.normal(keys[0], (Lh, E, H * D), F32) * sc
        blocks["wk"] = jax.random.normal(keys[1], (Lh, E, KVH * D), F32) * sc
        blocks["wv"] = jax.random.normal(keys[2], (Lh, E, KVH * D), F32) * sc
        if cfg.family != "hybrid":
            blocks["wo"] = jax.random.normal(keys[3], (Lh, H * D, E), F32) * sc
        if cfg.qkv_bias:
            blocks["bq"] = jnp.zeros((Lh, H * D), F32)
            blocks["bk"] = jnp.zeros((Lh, KVH * D), F32)
            blocks["bv"] = jnp.zeros((Lh, KVH * D), F32)

    if cfg.moe is not None:
        m = cfg.moe
        blocks["router"] = jax.random.normal(keys[4], (Lh, E, m.num_experts), F32) * sc
        blocks["we_gate"] = jax.random.normal(
            keys[5], (Lh, m.num_experts, E, m.expert_dff), F32) * sc
        blocks["we_up"] = jax.random.normal(
            keys[6], (Lh, m.num_experts, E, m.expert_dff), F32) * sc
        blocks["we_down"] = jax.random.normal(
            keys[7], (Lh, m.num_experts, m.expert_dff, E), F32) * sc
    elif cfg.d_ff:
        if cfg.mlp == "swiglu":
            blocks["w_gate"] = jax.random.normal(keys[4], (Lh, E, cfg.d_ff), F32) * sc
        blocks["w_up"] = jax.random.normal(keys[5], (Lh, E, cfg.d_ff), F32) * sc
        blocks["w_down"] = jax.random.normal(keys[6], (Lh, cfg.d_ff, E), F32) * sc

    if cfg.ssm is not None:
        sp = jax.vmap(lambda k: S.init_ssm_params(k, cfg))(
            jax.random.split(keys[8], Lh))
        if cfg.family == "hybrid":
            sp.pop("out_proj")         # fused projection replaces it
        blocks.update({f"ssm_{k}": v for k, v in sp.items()})
        if cfg.family == "hybrid":
            d_inner = S.ssm_dims(cfg)[0]
            assert d_inner == H * D, (d_inner, H * D)
            blocks["fuse_attn_scale"] = jnp.ones((Lh, H * D), F32)
            blocks["fuse_ssm_scale"] = jnp.ones((Lh, d_inner), F32)
            blocks["w_fuse"] = jax.random.normal(keys[9], (Lh, H * D, E), F32) * sc

    # tied embeddings double as the LM head: init small to keep initial
    # logits O(1) (the first block norm makes the input side scale-free)
    emb_scale = sc if cfg.tie_embeddings else 1.0
    params = {
        "embed": jax.random.normal(keys[10], (V, E), F32) * emb_scale,
        "blocks": blocks,
        "final_scale": jnp.ones((E,), F32),
    }
    if cfg.norm == "ln":
        params["final_bias"] = jnp.zeros((E,), F32)
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[11], (E, V), F32) * sc
    return params


def param_logical_axes(cfg: ModelConfig, params: dict) -> dict:
    """Mirror of ``params`` with logical-axis tuples per leaf."""
    ax = {
        "embed": ("vocab", "embed"),
        "final_scale": ("embed",),
        "final_bias": ("embed",),
        "lm_head": ("embed", "vocab"),
    }
    bl = {
        "ln1_scale": ("layers", "embed"), "ln1_bias": ("layers", "embed"),
        "ln2_scale": ("layers", "embed"), "ln2_bias": ("layers", "embed"),
        "wq": ("layers", "embed", "heads"),
        "wk": ("layers", "embed", "kv_heads"),
        "wv": ("layers", "embed", "kv_heads"),
        "wo": ("layers", "heads", "embed"),
        "bq": ("layers", "heads"), "bk": ("layers", "kv_heads"),
        "bv": ("layers", "kv_heads"),
        "router": ("layers", "embed", None),
        "we_gate": ("layers", "experts", "embed", "expert_mlp"),
        "we_up": ("layers", "experts", "embed", "expert_mlp"),
        "we_down": ("layers", "experts", "expert_mlp", "embed"),
        "w_gate": ("layers", "embed", "mlp"),
        "w_up": ("layers", "embed", "mlp"),
        "w_down": ("layers", "mlp", "embed"),
        "ssm_in_proj": ("layers", "embed", "ssm_inner"),
        "ssm_conv_w": ("layers", None, None),
        "ssm_conv_b": ("layers", None),
        "ssm_A_log": ("layers", None), "ssm_D": ("layers", None),
        "ssm_dt_bias": ("layers", None),
        "ssm_ssm_norm": ("layers", "ssm_inner"),
        "ssm_out_proj": ("layers", "ssm_inner", "embed"),
        "fuse_attn_scale": ("layers", "heads"),
        "fuse_ssm_scale": ("layers", "ssm_inner"),
        "w_fuse": ("layers", "heads", "embed"),
    }
    out = {k: ax[k] for k in params if k != "blocks"}
    out["blocks"] = {k: bl[k] for k in params["blocks"]}
    return out


# ---------------------------------------------------------------------------
# layer segmentation (static per-layer attention windows for hybrids)
# ---------------------------------------------------------------------------

def layer_segments(cfg: ModelConfig) -> List[Tuple[int, int, int]]:
    """[(start, stop, window)] covering 0..L; window=0 means full attention.

    Hybrids (hymba) use full attention at layers {0, L//2, L-1} and a sliding
    window elsewhere; all other families are one segment.
    """
    Lh = cfg.n_layers
    if cfg.family != "hybrid":
        return [(0, Lh, cfg.window)]
    glob = sorted({0, Lh // 2, Lh - 1})
    segs, prev = [], 0
    for g in glob:
        if g > prev:
            segs.append((prev, g, cfg.window))
        segs.append((g, g + 1, 0))
        prev = g + 1
    if prev < Lh:
        segs.append((prev, Lh, cfg.window))
    return segs


def tree_slice(tree, a, b):
    return jax.tree.map(lambda x: x[a:b], tree)


# ---------------------------------------------------------------------------
# block forward (training / prefill)
# ---------------------------------------------------------------------------

def _attn_heads(cfg, p, x, positions, window):
    """Projection + rope + blockwise attention; returns concat head outputs
    (B, S, H*D) WITHOUT the output projection, plus (k, v) for cache fills."""
    B, Sq, E = x.shape
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bse,eh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bse,eh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bse,eh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, Sq, H, D)
    k = k.reshape(B, Sq, KVH, D)
    v = v.reshape(B, Sq, KVH, D)
    if cfg.constrain_qkv:
        # seq is NOT bound here: under sequence parallelism the residual
        # stream is seq-sharded but attention runs on the gathered sequence
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
    if cfg.rope:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    out = L.blockwise_attention(q, k, v, chunk=cfg.attn_chunk, window=window,
                                causal_skip=cfg.attn_mode == "causal_skip")
    return out.reshape(B, Sq, H * D), (k, v)


def _ssm_part(cfg, p, h, apply_out: bool):
    sp = {k[4:]: v for k, v in p.items() if k.startswith("ssm_")}
    return S.ssd_forward(cfg, sp, h, apply_out=apply_out)


def _block_fwd(cfg: ModelConfig, x, p, window: int):
    """One decoder block with a STATIC attention window (0 = full)."""
    B, Sq, E = x.shape
    positions = jnp.arange(Sq)[None]
    aux = jnp.zeros((), F32)

    if cfg.family == "hybrid":
        h = L.apply_norm(cfg, p, "ln1", x)
        attn, _ = _attn_heads(cfg, p, h, positions, window)
        y_ssm = _ssm_part(cfg, p, h, apply_out=False)
        a = L.rmsnorm(attn, p["fuse_attn_scale"])
        s_ = L.rmsnorm(y_ssm, p["fuse_ssm_scale"])
        fused = jnp.einsum("bsh,he->bse", ((a + s_) * 0.5).astype(x.dtype),
                           p["w_fuse"].astype(x.dtype))
        x = x + shard(fused, "batch", "seq", "embed")
        x = x + L.mlp(cfg, p, L.apply_norm(cfg, p, "ln2", x))
        return x, aux

    if cfg.family == "ssm":
        h = L.apply_norm(cfg, p, "ln1", x)
        x = x + _ssm_part(cfg, p, h, apply_out=True)
        if cfg.d_ff:
            x = x + L.mlp(cfg, p, L.apply_norm(cfg, p, "ln2", x))
        return x, aux

    # dense / moe / audio / vlm
    h = L.apply_norm(cfg, p, "ln1", x)
    attn, _ = _attn_heads(cfg, p, h, positions, window)
    x = x + shard(jnp.einsum("bsh,he->bse", attn, p["wo"].astype(x.dtype)),
                  "batch", "seq", "embed")
    h2 = L.apply_norm(cfg, p, "ln2", x)
    if cfg.moe is not None:
        mo, aux = L.moe(cfg, p, h2)
        x = x + mo
    else:
        x = x + L.mlp(cfg, p, h2)
    return x, aux


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def forward(cfg: ModelConfig, params: dict, inputs):
    """Token/embedding inputs -> (hidden (B,S,E), moe-aux scalar)."""
    dt = _dtype(cfg)
    if inputs.ndim == 2:                                   # token ids
        x = params["embed"].astype(dt)[inputs]
    else:                                                  # precomputed embeds
        x = inputs.astype(dt)
    x = shard(x, "batch", "seq", "embed")
    aux = jnp.zeros((), F32)

    for (a, b, window) in layer_segments(cfg):
        blk = tree_slice(params["blocks"], a, b)

        def body(carry, p, _w=window):
            x, aux = carry
            x, da = _block_fwd(cfg, x, p, _w)
            return (x, aux + da), None

        (x, aux), _ = jax.lax.scan(_remat(cfg, body), (x, aux), blk)

    if cfg.norm == "rms":
        x = L.rmsnorm(x, params["final_scale"])
    else:
        x = L.layernorm(x, params["final_scale"], params["final_bias"])
    return x, aux


def logits_fn(cfg: ModelConfig, params: dict, x) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...e,ev->...v", x.astype(F32), head.astype(F32))
    if cfg.padded_vocab != cfg.vocab:    # mask padding ids everywhere
        live = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(live, logits, -1e30)
    if logits.ndim == 3:
        return shard(logits, "batch", None, "vocab")
    return shard(logits, "batch", "vocab")


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jnp.ndarray:
    """Causal-LM cross entropy (labels pre-shifted by the data pipeline)."""
    x, aux = forward(cfg, params, batch["inputs"])
    logits = logits_fn(cfg, params, x)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ce = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(logz))
    moe_w = 1e-2 if cfg.moe is not None else 0.0
    return ce + zloss + moe_w * aux


# ---------------------------------------------------------------------------
# decode (serving hot path)
# ---------------------------------------------------------------------------
# Full-attention families decode against the hash-indexed paged KV pool
# (serving/kvcache.py): every step translates (seq, logical_page) through the
# continuity hash table — the paper's one-contiguous-fetch lookups — then
# attends over gathered pages. Hybrid uses a sliding ring buffer (+ linear
# caches for its three global layers); SSM is the O(1) recurrence.

def _rope_step(cfg, q, k, positions):
    if not cfg.rope:
        return q, k
    q = L.rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    k = L.rope(k[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    return q, k


def _qkv_step(cfg, p, h, positions):
    """h (B, E) -> q (B,H,D), k,v (B,KVH,D) with rope applied."""
    B = h.shape[0]
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("be,eh->bh", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("be,eh->bh", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("be,eh->bh", h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q, k, v = (q + p["bq"].astype(h.dtype), k + p["bk"].astype(h.dtype),
                   v + p["bv"].astype(h.dtype))
    q = q.reshape(B, H, D)
    k = k.reshape(B, KVH, D)
    v = v.reshape(B, KVH, D)
    return (*_rope_step(cfg, q, k, positions), v)


def _ffn_step(cfg, p, x):
    h2 = L.apply_norm(cfg, p, "ln2", x[:, None])[:, 0]
    if cfg.moe is not None:
        mo, _ = L.moe(cfg, p, h2[:, None])
        return x + mo[:, 0]
    return x + L.mlp(cfg, p, h2)


def _paged_layer_step(cfg, geom, p, x, lcache, page_table, cache):
    """One decoder layer of paged decode. lcache: this layer's pool slices
    (DS, NPl, KVH, PS, D) [+ scales]."""
    from repro.serving import kvcache as KC
    DS, Bl = geom.shards, geom.batch_per_shard
    B = DS * Bl
    positions = cache.seq_lens.reshape(B)
    h = L.apply_norm(cfg, p, "ln1", x[:, None])[:, 0]
    q, k, v = _qkv_step(cfg, p, h, positions)

    kw, vw, ks, vs = k, v, None, None
    if geom.kv_dtype == "int8":
        kw, ks = KC.quant_store(k)
        vw, vs = KC.quant_store(v)

    def write(pool, val):
        def per_shard(pool_s, page_s, off_s, val_s):
            return pool_s.at[page_s, :, off_s].set(val_s)
        return jax.vmap(per_shard)(pool, cache.cur_page, cache.cur_off,
                                   val.reshape(DS, Bl, *val.shape[1:]))

    lcache = dict(lcache)
    lcache["k"] = write(lcache["k"], kw.astype(lcache["k"].dtype))
    lcache["v"] = write(lcache["v"], vw.astype(lcache["v"].dtype))
    if geom.kv_dtype == "int8":
        lcache["ks"] = write(lcache["ks"], ks)
        lcache["vs"] = write(lcache["vs"], vs)

    def gather(pool):
        return jax.vmap(lambda pool_s, pt_s: pool_s[jnp.maximum(pt_s, 0)])(
            pool, page_table)                    # (DS,Bl,MAXP,KVH,PS,D)

    kg, vg = gather(lcache["k"]), gather(lcache["v"])
    if geom.kv_dtype == "int8":
        kg = KC.dequant(kg, gather(lcache["ks"]), x.dtype)
        vg = KC.dequant(vg, gather(lcache["vs"]), x.dtype)
    if geom.merged_attn:
        # legacy path (§Perf before/after): merging (MAXP, PS) -> T forces
        # GSPMD to fully rematerialize the gathered cache across the mesh
        T_ = geom.max_pages * geom.page_size
        kf = shard(jnp.moveaxis(kg, 4, 3), "kv_shard", None, None,
                   "page_tokens", None, None).reshape(
                       B, T_, geom.kv_heads, geom.head_dim)
        vf = shard(jnp.moveaxis(vg, 4, 3), "kv_shard", None, None,
                   "page_tokens", None, None).reshape(
                       B, T_, geom.kv_heads, geom.head_dim)
        attn = L.decode_attention(q, kf, vf, positions + 1)
    else:
        # keep (MAXP, PS) UNMERGED: the page-token dim stays sharded over
        # the model axis (split-KV decode) — softmax/value reductions turn
        # into small all-reduces instead of a cache-sized reshard
        kg = shard(kg, "kv_shard", None, None, "kv_heads_dec",
                   "page_tokens", None)
        vg = shard(vg, "kv_shard", None, None, "kv_heads_dec",
                   "page_tokens", None)
        attn = L.paged_decode_attention(
            q.reshape(geom.shards, geom.batch_per_shard, *q.shape[1:]),
            kg, vg, page_table, cache.seq_lens + 1, geom.page_size)
    attn = attn.reshape(B, cfg.n_heads * cfg.hd)
    x = x + jnp.einsum("bh,he->be", attn, p["wo"].astype(x.dtype))
    return _ffn_step(cfg, p, x), lcache


def paged_decode_step(cfg: ModelConfig, params: dict, tokens, cache, geom):
    """tokens (B,) int32 -> (logits (B, V), updated cache). The page table is
    re-translated through the continuity hash table every step (client
    reads); page opening/commit bookkeeping is in serving/engine.py."""
    from repro.serving import kvcache as KC
    dt = _dtype(cfg)
    B = geom.batch
    x = shard(params["embed"].astype(dt)[tokens], "batch", "embed")
    page_table = KC.lookup_pages(geom, cache.table, cache.seq_ids)

    lpools = {"k": cache.kpool, "v": cache.vpool}
    if geom.kv_dtype == "int8":
        lpools.update(ks=cache.kscale, vs=cache.vscale)

    def body(x, xs):
        p, lcache = xs
        x, lcache = _paged_layer_step(cfg, geom, p, x, lcache, page_table,
                                      cache)
        return x, lcache

    x, pools = jax.lax.scan(body, x, (params["blocks"], lpools))
    if cfg.norm == "rms":
        x = L.rmsnorm(x[:, None], params["final_scale"])[:, 0]
    else:
        x = L.layernorm(x[:, None], params["final_scale"],
                        params["final_bias"])[:, 0]
    logits = logits_fn(cfg, params, x)
    cache = cache._replace(kpool=pools["k"], vpool=pools["v"],
                           kscale=pools.get("ks"), vscale=pools.get("vs"))
    return logits, cache


def ssm_decode_step(cfg: ModelConfig, params: dict, tokens, cache):
    """SSM decode: O(1) recurrent state per layer. cache: {"S", "conv",
    "seq_lens"} with leading layer dims on S/conv."""
    dt = _dtype(cfg)
    x = shard(params["embed"].astype(dt)[tokens], "batch", "embed")

    def body(x, xs):
        p, st = xs
        sp = {k[4:]: v for k, v in p.items() if k.startswith("ssm_")}
        h = L.apply_norm(cfg, p, "ln1", x[:, None])[:, 0]
        y, st = S.ssd_decode(cfg, sp, h, st, apply_out=True)
        x = x + y
        if cfg.d_ff:
            x = _ffn_step(cfg, p, x)
        return x, st

    x, state = jax.lax.scan(
        body, x, (params["blocks"], {"S": cache["S"], "conv": cache["conv"]}))
    x = L.rmsnorm(x[:, None], params["final_scale"])[:, 0]
    logits = logits_fn(cfg, params, x)
    new_cache = dict(cache, S=state["S"], conv=state["conv"],
                     seq_lens=cache["seq_lens"] + 1)
    return logits, new_cache


def hybrid_decode_step(cfg: ModelConfig, params: dict, tokens, cache):
    """Hybrid decode: ring-buffer window attention + linear caches for the
    global layers + SSM state, all in parallel heads. Layers are unrolled
    (static windows per layer)."""
    dt = _dtype(cfg)
    x = shard(params["embed"].astype(dt)[tokens], "batch", "embed")
    seq_lens = cache["seq_lens"]                            # (B,)
    B = x.shape[0]
    W = cfg.window
    ring_k, ring_v = cache["ring_k"], cache["ring_v"]       # (Lw,B,W,KVH,D)
    glob_k, glob_v = cache["glob_k"], cache["glob_v"]       # (Lg,B,Smax,KVH,D)
    ssm_S, ssm_conv = cache["S"], cache["conv"]

    wi = gi = 0
    new_rk, new_rv, new_gk, new_gv = list(ring_k), list(ring_v), \
        list(glob_k), list(glob_v)
    new_S, new_conv = list(ssm_S), list(ssm_conv)
    segs = layer_segments(cfg)
    li = 0
    for (a, b, window) in segs:
        for layer in range(a, b):
            p = jax.tree.map(lambda t: t[layer], params["blocks"])
            h = L.apply_norm(cfg, p, "ln1", x[:, None])[:, 0]
            q, k, v = _qkv_step(cfg, p, h, seq_lens)
            if window:                                       # ring buffer
                slot = seq_lens % W
                kc = ring_k[wi].at[jnp.arange(B), slot].set(k)
                vc = ring_v[wi].at[jnp.arange(B), slot].set(v)
                new_rk[wi], new_rv[wi] = kc, vc
                attn = L.decode_attention(q, kc, vc, seq_lens + 1, window=W)
                wi += 1
            else:                                            # global linear
                kc = glob_k[gi].at[jnp.arange(B), seq_lens].set(k)
                vc = glob_v[gi].at[jnp.arange(B), seq_lens].set(v)
                new_gk[gi], new_gv[gi] = kc, vc
                attn = L.decode_attention(q, kc, vc, seq_lens + 1)
                gi += 1
            sp = {k2[4:]: v2 for k2, v2 in p.items() if k2.startswith("ssm_")}
            st = {"S": ssm_S[li], "conv": ssm_conv[li]}
            y_ssm, st = S.ssd_decode(cfg, sp, h, st, apply_out=False)
            new_S[li], new_conv[li] = st["S"], st["conv"]
            a_n = L.rmsnorm(attn.reshape(B, -1), p["fuse_attn_scale"])
            s_n = L.rmsnorm(y_ssm, p["fuse_ssm_scale"])
            fused = jnp.einsum("bh,he->be", ((a_n + s_n) * 0.5).astype(x.dtype),
                               p["w_fuse"].astype(x.dtype))
            x = x + fused
            x = _ffn_step(cfg, p, x)
            li += 1

    x = L.rmsnorm(x[:, None], params["final_scale"])[:, 0]
    logits = logits_fn(cfg, params, x)
    new_cache = dict(cache,
                     ring_k=jnp.stack(new_rk) if new_rk else cache["ring_k"],
                     ring_v=jnp.stack(new_rv) if new_rv else cache["ring_v"],
                     glob_k=jnp.stack(new_gk) if new_gk else cache["glob_k"],
                     glob_v=jnp.stack(new_gv) if new_gv else cache["glob_v"],
                     S=jnp.stack(new_S), conv=jnp.stack(new_conv),
                     seq_lens=seq_lens + 1)
    return logits, new_cache

"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` describes the transformer backbone; modality frontends
(EnCodec for musicgen, vision tower for llava) are STUBS per the assignment:
``input_specs()`` provides precomputed frame/patch embeddings for those archs
(``frontend="embed"``), token ids otherwise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_dff: int
    capacity_factor: float = 1.25
    # "sorted": capacity-bucket dispatch (standard, collective-heavy under
    # GSPMD); "dense": compute ALL experts and mask by gates — identical
    # outputs, zero dispatch communication, E/top_k x active FLOPs; wins for
    # small experts (EXPERIMENTS.md §Perf granite hillclimb)
    impl: str = "sorted"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | audio | hybrid | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int              # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    norm: str = "rms"         # rms | ln
    mlp: str = "swiglu"       # swiglu | gelu
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    frontend: str = "tokens"  # tokens | embed (modality stub supplies embeds)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba-style) attention controls
    window: int = 0           # sliding-window size; 0 = full attention
    global_every: int = 0     # every k-th layer uses full attention (hybrid)
    # pad the embedding/LM-head vocab rows up to a multiple (extra ids are
    # masked in the loss and at decode): vocabs that don't divide the TP
    # degree otherwise REPLICATE the logits across the model axis
    vocab_pad_to: int = 1
    # numerics / perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    dtype: str = "bfloat16"
    remat: str = "full"       # none | full | dots
    attn_chunk: int = 512     # q-chunk for blockwise attention
    attn_mode: str = "masked"  # masked (full SxT) | causal_skip (~half FLOPs)
    # explicit q/k/v activation sharding constraints; False lets GSPMD
    # propagate from the (sharded) weights — kills the resharding
    # all-reduces that the kv_heads degrade-to-replicated constraint forces
    constrain_qkv: bool = True
    kv_quant: str = "none"    # none | int8 — serving KV-pool quantization

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        p = max(self.vocab_pad_to, 1)
        return (self.vocab + p - 1) // p * p

    @property
    def sub_quadratic(self) -> bool:
        """True if serve-time cost per token is o(seq_len) in memory (SSM /
        hybrid sliding-window) — gate for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        E, L, V = self.d_model, self.n_layers, self.vocab
        n = V * E                       # token embedding
        if not self.tie_embeddings:
            n += E * V                  # lm head
        H, KVH, D = self.n_heads, self.n_kv_heads, self.hd
        per_layer = 0
        if self.has_attention:
            per_layer += E * H * D + 2 * E * KVH * D + H * D * E
            if self.qkv_bias:
                per_layer += (H + 2 * KVH) * D
        if self.moe is not None:
            m = self.moe
            per_layer += E * m.num_experts                     # router
            per_layer += m.num_experts * (3 * E * m.expert_dff)
        elif self.d_ff:
            mults = 3 if self.mlp == "swiglu" else 2
            per_layer += mults * E * self.d_ff
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * E
            nh = d_in // s.head_dim
            # in_proj (z, x, B, C, dt) + out_proj + conv + A,D
            per_layer += E * (2 * d_in + 2 * s.d_state + nh) + d_in * E
            per_layer += s.conv_width * (d_in + 2 * s.d_state)
            per_layer += 2 * nh
        per_layer += 2 * E              # two norms (scales)
        return n + L * per_layer

    @property
    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D convention)."""
        if self.moe is None:
            return self.param_count
        m = self.moe
        L, E = self.n_layers, self.d_model
        inactive = L * (m.num_experts - m.top_k) * 3 * E * m.expert_dff
        return self.param_count - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a step (no
    allocation) — the dry-run contract. Modality frontends are stubs: for
    ``frontend="embed"`` archs the spec carries precomputed embeddings."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend == "embed":
            x = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        else:
            x = jax.ShapeDtypeStruct((B, S), i32)
        return {"inputs": x, "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.frontend == "embed":
            x = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        else:
            x = jax.ShapeDtypeStruct((B, S), i32)
        return {"inputs": x}
    # decode: one new token id per sequence against a cache of seq_len
    # (modality frontends only affect prefill/train inputs; generated tokens
    # are always ids embedded through the shared token embedding)
    return {"inputs": jax.ShapeDtypeStruct((B,), i32)}

"""Continuous-batching scheduler: the host-side request lifecycle around the
hash-paged decode engine.

A fixed device batch of B slots runs lock-step decode; the scheduler admits
queued requests into free slots (prefill via stepwise decode for short
prompts, bulk prefill for page-aligned ones), detects finished sequences
(EOS or max tokens), releases their pages (atomic indicator-bit deletes),
and immediately reuses the slots — the standard continuous-batching loop
(Orca/vLLM), with the continuity hash table as the page index.

Device work stays jitted and fixed-shape; the scheduler only flips host-side
masks between steps.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serving import engine as E
from repro.serving import kvcache as KC


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, geom: KC.PageGeometry, params,
                 pad_id: int = 0, transport=None):
        self.cfg = cfg
        self.geom = geom
        self.params = params
        self.pad_id = pad_id
        self.cache = KC.create_cache(geom)
        self.B = geom.batch
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.B
        self.prompt_pos = np.zeros(self.B, np.int64)  # next prompt token idx
        self._step = jax.jit(
            lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
        self._logits = None
        # one-sided transport the page-table traffic is accounted against
        # (None, or a repro.rdma.RemoteMemory — see RemoteMemory.from_policy
        # with the store's ExecPolicy).  The scheduler step is the doorbell
        # FLUSH BOUNDARY: every page translation of one decode step posts
        # as one doorbell-batched round.
        if transport is None:
            from repro.rdma import RemoteMemory
            transport = RemoteMemory.from_policy(geom.store.policy)
        self.transport = transport

    # -- request API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _slot_coords(self, b: int):
        return b // self.geom.batch_per_shard, b % self.geom.batch_per_shard

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                self._scrub(b)          # drop any idle-slot pad pages
                self.slots[b] = self.queue.popleft()
                self.prompt_pos[b] = 0

    def _scrub(self, b: int):
        """Idle slots still ride the fixed-shape decode batch (pad tokens),
        accumulating junk pages; release them before reuse/shutdown."""
        ds, sl = self._slot_coords(b)
        if int(self.cache.seq_lens[ds, sl]) > 0:
            self.cache = E.release_sequence(self.geom, self.cache, ds, sl)

    def _release(self, b: int):
        ds, sl = self._slot_coords(b)
        self.cache = E.release_sequence(self.geom, self.cache, ds, sl)
        self.slots[b] = None

    # -- the lock-step loop --------------------------------------------------

    def step(self) -> int:
        """One global decode step; returns number of live requests."""
        self._admit()
        toks = np.full((self.B,), self.pad_id, np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            if self.prompt_pos[b] < len(req.prompt):      # feeding the prompt
                toks[b] = req.prompt[self.prompt_pos[b]]
                self.prompt_pos[b] += 1
            elif self._logits is not None:                # generating
                toks[b] = int(np.argmax(self._logits[b]))
                req.out.append(int(toks[b]))
                if (len(req.out) >= req.max_new_tokens
                        or (req.eos_id is not None
                            and toks[b] == req.eos_id)):
                    req.done = True
        logits, self.cache = self._step(self.params, jnp.asarray(toks),
                                        self.cache)
        self._logits = np.asarray(logits)
        if self.transport is not None:
            # flush boundary: the step's page translations, ONE doorbell
            self.transport.post(KC.step_read_plan(self.geom, self.cache))
        live = 0
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            if req.done:
                self._release(b)
            else:
                live += 1
        return live

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until queue + slots drain; returns {rid: generated tokens}."""
        finished: Dict[int, List[int]] = {}
        done_reqs: List[Request] = []
        for _ in range(max_steps):
            before = [r for r in self.slots if r is not None]
            live = self.step()
            for r in before:
                if r.done and r.rid not in finished:
                    finished[r.rid] = r.out
                    done_reqs.append(r)
            if live == 0 and not self.queue:
                break
        for b in range(self.B):        # shutdown: scrub idle pad pages
            if self.slots[b] is None:
                self._scrub(b)
        return finished

"""Batched decode engine: the paper's read/write protocol on the serving path.

``serve_step`` is the unit the dry-run lowers for decode shapes:
  1. advance(): sequences crossing a page boundary get a physical page
     allocated and the (seq, page)->phys mapping INSERTED into the continuity
     hash table (server-side write: payload, then one atomic indicator
     commit);
  2. lookup_pages(): every (seq, logical page) is translated through the hash
     table (client read: ONE contiguous segment fetch each);
  3. the model decodes one token against the gathered pages;
  4. commit_token().

``release_sequence`` returns a finished sequence's pages (hash-table deletes:
one indicator-bit clear each — the paper's 1-PM-write deletion) so the pool
can be oversubscribed relative to worst-case logical space.

Prefix sharing (beyond-paper feature made natural by the hash index): page
keys may be CONTENT hashes of the token prefix, letting identical prompt
prefixes across requests map to the same physical page (refcounted).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import kvcache as KC

I32 = jnp.int32
U32 = jnp.uint32


def serve_step(cfg: ModelConfig, geom: Optional[KC.PageGeometry],
               params: dict, tokens: jnp.ndarray, cache):
    """One decode step for any family. tokens (B,) int32."""
    if cfg.family in ("ssm",):
        return T.ssm_decode_step(cfg, params, tokens, cache)
    if cfg.family == "hybrid":
        return T.hybrid_decode_step(cfg, params, tokens, cache)
    cache = KC.advance(geom, cache)
    logits, cache = T.paged_decode_step(cfg, params, tokens, cache, geom)
    return logits, KC.commit_token(cache)


def make_serve_step(cfg: ModelConfig, geom):
    return functools.partial(serve_step, cfg, geom)


# ---------------------------------------------------------------------------
# prefill — fills pools page-contiguously and registers mappings
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, geom: KC.PageGeometry, params: dict,
            inputs: jnp.ndarray, cache: KC.PagedCache,
            prompt_len: Optional[int] = None):
    """Run the full-attention forward over prompts and populate the paged
    cache. ``inputs``: (B, S) tokens or (B, S, E) embeds; S must be a
    multiple of page_size for the bulk page fill (pad upstream).

    Returns (last-position logits (B, V), cache)."""
    from repro.distribution.sharding import shard
    DS, Bl, PS = geom.shards, geom.batch_per_shard, geom.page_size
    B = DS * Bl
    S = inputs.shape[1]
    npages = S // PS
    dt = T._dtype(cfg)

    x = params["embed"].astype(dt)[inputs] if inputs.ndim == 2 \
        else inputs.astype(dt)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(S)[None]

    # deterministic physical layout for prompt pages: seq-major
    phys = (jnp.arange(Bl * npages, dtype=I32).reshape(Bl, npages)
            [None].repeat(DS, 0)) % geom.pool_pages          # (DS,Bl,NP)

    kpool, vpool = cache.kpool, cache.vpool

    def body(carry, xs):
        x, = carry
        p, kp, vp = xs                                       # layer slice
        h = T.L.apply_norm(cfg, p, "ln1", x)
        attn, (k, v) = T._attn_heads(cfg, p, h, positions, cfg.window)
        x = x + shard(jnp.einsum("bsh,he->bse", attn, p["wo"].astype(x.dtype)),
                      "batch", "seq", "embed")
        h2 = T.L.apply_norm(cfg, p, "ln2", x)
        if cfg.moe is not None:
            mo, _ = T.L.moe(cfg, p, h2)
            x = x + mo
        else:
            x = x + T.L.mlp(cfg, p, h2)
        # bulk page fill: (B,S,KVH,D) -> (DS,Bl,NP,PS,KVH,D) -> pool scatter
        KVH, D = geom.kv_heads, geom.head_dim
        kw = k.reshape(DS, Bl, npages, PS, KVH, D)
        vw = v.reshape(DS, Bl, npages, PS, KVH, D)
        kw = jnp.moveaxis(kw, 3, 4).reshape(DS, Bl * npages, KVH, PS, D)
        vw = jnp.moveaxis(vw, 3, 4).reshape(DS, Bl * npages, KVH, PS, D)
        pf = phys.reshape(DS, Bl * npages)
        kp = jax.vmap(lambda pool, idx, val: pool.at[idx].set(
            val.astype(pool.dtype)))(kp, pf, kw)
        vp = jax.vmap(lambda pool, idx, val: pool.at[idx].set(
            val.astype(pool.dtype)))(vp, pf, vw)
        return (x,), (kp, vp)

    (x,), (kpool, vpool) = jax.lax.scan(body, (x,),
                                        (params["blocks"], kpool, vpool))
    if cfg.norm == "rms":
        x = T.L.rmsnorm(x, params["final_scale"])
    else:
        x = T.L.layernorm(x, params["final_scale"], params["final_bias"])
    logits = T.logits_fn(cfg, params, x[:, -1])

    # register page mappings (server-side batched inserts via the store)
    pages = jnp.broadcast_to(jnp.arange(npages, dtype=U32), (Bl, npages))
    keys = jax.vmap(lambda s: KC.page_keys(
        jnp.repeat(s, npages).reshape(Bl, npages), pages))(cache.seq_ids)
    vals = KC.page_values(phys)
    table, _ = jax.vmap(
        lambda t, k, v: geom.store.insert(t, k.reshape(-1, 4),
                                          v.reshape(-1, 4)))(
        cache.table, keys, vals)

    plen = prompt_len if prompt_len is not None else S
    cache = cache._replace(
        kpool=kpool, vpool=vpool, table=table,
        next_free=jnp.full((DS,), Bl * npages % geom.pool_pages, I32),
        seq_lens=jnp.full((DS, Bl), plen, I32),
        cur_page=phys[:, :, -1],
        cur_off=jnp.full((DS, Bl), plen % PS, I32))
    return logits, cache


# ---------------------------------------------------------------------------
# sequence lifecycle (host-orchestrated, device-executed)
# ---------------------------------------------------------------------------

def release_sequence(geom: KC.PageGeometry, cache: KC.PagedCache,
                     shard_idx: int, slot: int) -> KC.PagedCache:
    """Finish a sequence: delete its page mappings (1 PM write each — the
    paper's atomic deletion) and recycle the slot for a new request."""
    seq = cache.seq_ids[shard_idx, slot]
    npages = (cache.seq_lens[shard_idx, slot] + geom.page_size - 1) \
        // geom.page_size
    pages = jnp.arange(geom.max_pages, dtype=U32)
    keys = KC.page_keys(jnp.broadcast_to(seq, pages.shape), pages)
    table_s = jax.tree.map(lambda x: x[shard_idx], cache.table)
    mask = pages < npages.astype(U32)
    # delete only the mapped pages (masked batch keeps PM-write accounting)
    table_s, _ = geom.store.delete(table_s, keys, mask)
    table = jax.tree.map(lambda full, s: full.at[shard_idx].set(s),
                         cache.table, table_s)
    new_id = jnp.max(cache.seq_ids) + 1
    return cache._replace(
        table=table,
        seq_ids=cache.seq_ids.at[shard_idx, slot].set(new_id),
        seq_lens=cache.seq_lens.at[shard_idx, slot].set(0),
        cur_page=cache.cur_page.at[shard_idx, slot].set(0),
        cur_off=cache.cur_off.at[shard_idx, slot].set(0))


# ---------------------------------------------------------------------------
# content-addressed prefix sharing (hash-index-native feature)
# ---------------------------------------------------------------------------

def content_page_keys(tokens: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """Rolling content hashes per page: key_p = H(key_{p-1}, tokens of page p)
    — identical prompt prefixes yield identical page keys across requests,
    so the hash table maps them to ONE shared physical page."""
    from repro.core.hashfn import fold_u32, mix_pair
    B, S = tokens.shape
    npages = S // page_size
    tp = tokens.reshape(B, npages, page_size).astype(U32)
    ph = fold_u32(tp)                                        # (B, npages)

    def roll(carry, h):
        nh = mix_pair(carry, h)
        return nh, nh

    _, chained = jax.lax.scan(roll, jnp.zeros((B,), U32),
                              jnp.moveaxis(ph, 1, 0))
    chained = jnp.moveaxis(chained, 0, 1)                    # (B, npages)
    pages = jnp.broadcast_to(jnp.arange(npages, dtype=U32), (B, npages))
    return jnp.stack([chained, pages,
                      chained ^ pages,
                      jnp.full_like(chained, U32(0x9E3779B9))], -1)

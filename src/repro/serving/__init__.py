"""Serving runtime: hash-indexed paged KV cache + batched decode engine."""

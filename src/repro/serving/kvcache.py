"""Paged KV cache whose page table is a pluggable `repro.api` hash store.

The physical KV pool is a fixed set of pages per data shard (the "server's
PM region"); the logical->physical mapping (sequence_id, logical_page) ->
physical_page lives in a per-shard hash-store table behind the
`repro.api.HashStore` protocol — continuity hashing by default, but any
registered scheme (``level``, ``pfarm``, ``dense``) plugs in via
``make_geometry(..., scheme=...)``.  Lookups on the decode hot path are the
paper's client reads (for continuity: ONE contiguous segment fetch per page
translation); insertions (page allocation) are the server-side writes with
indicator-commit atomicity.

Why a hash table instead of a dense block table (the vLLM baseline, now a
registered ``dense`` scheme): content-addressed keys enable cross-request
prefix sharing, and the index survives pool oversubscription (physical pool
smaller than worst-case logical space) — which is what makes the
qwen1.5-32b decode_32k cell fit on a v5e pod at all (EXPERIMENTS.md §Perf).

Sharding layout (see DESIGN.md §5):
  * pools: (L, DS, NPl, KVH, PS, D) — DS = data shards (pod x data axes);
    page-token dim PS is sharded over the MODEL axis ("split-KV" decoding:
    works for any kv-head count, bounds per-device cache bytes at
    total / (DS * model));
  * page tables: one store table per data shard (leading DS dim, vmapped
    ops) — the paper's one-server-per-node deployment.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecPolicy, make_store, store_shard_axes
from repro.models.config import ModelConfig, ShapeConfig

U32 = jnp.uint32
I32 = jnp.int32

PAGE_SALT = np.uint32(0xC0FFEE01)


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    layers: int
    kv_heads: int
    head_dim: int
    page_size: int
    max_pages: int            # logical pages per sequence
    shards: int               # DS (pod x data)
    batch_per_shard: int
    pool_pages: int           # NPl physical pages per shard
    kv_dtype: str             # bfloat16 | int8
    store: Any                # repro.api.HashStore — the page-table backend
    # legacy decode path that merges (MAXP, PS) -> T before attention;
    # forces a GSPMD involuntary remat — kept for the §Perf before/after
    merged_attn: bool = False

    @property
    def batch(self) -> int:
        return self.shards * self.batch_per_shard

    @property
    def table_cfg(self):
        """Deprecated: the page-table backend's raw config. Kept for old
        call sites; new code reads ``geom.store`` (the `HashStore`)."""
        return self.store.cfg


def page_table_slots(geom_entries: int, load: float = 0.5) -> int:
    """Storage units a page-table store needs for ``geom_entries``
    mappings/shard at target ``load``."""
    return int(np.ceil(geom_entries / load))


def make_geometry(cfg: ModelConfig, shape: ShapeConfig, shards: int,
                  page_size: int = 512, oversub: float = 1.0,
                  kv_dtype: Optional[str] = None,
                  merged_attn: bool = False,
                  scheme: str = "continuity",
                  policy: Optional[ExecPolicy] = None) -> PageGeometry:
    assert shape.global_batch % shards == 0, (shape.global_batch, shards)
    bl = shape.global_batch // shards
    maxp = (shape.seq_len + page_size - 1) // page_size
    pool = max(1, int(np.ceil(bl * maxp * oversub)))
    store = make_store(scheme, table_slots=page_table_slots(bl * maxp),
                       policy=policy or ExecPolicy())
    return PageGeometry(
        layers=cfg.n_layers, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        page_size=page_size, max_pages=maxp, shards=shards,
        batch_per_shard=bl, pool_pages=pool,
        kv_dtype=kv_dtype or cfg.kv_quant.replace("none", cfg.dtype),
        store=store, merged_attn=merged_attn)


class PagedCache(NamedTuple):
    kpool: jnp.ndarray          # (L, DS, NPl, KVH, PS, D) kv_dtype
    vpool: jnp.ndarray
    kscale: Optional[jnp.ndarray]  # (L, DS, NPl, KVH, PS, 1) f32 when int8
    vscale: Optional[jnp.ndarray]
    table: Any                  # store state; leading DS dim on every leaf
    next_free: jnp.ndarray      # (DS,) int32 — physical page bump allocator
    seq_ids: jnp.ndarray        # (DS, Bl) uint32 global sequence ids
    seq_lens: jnp.ndarray       # (DS, Bl) int32 tokens already cached
    cur_page: jnp.ndarray       # (DS, Bl) int32 physical id of open page
    cur_off: jnp.ndarray        # (DS, Bl) int32 write offset in open page


def _pool_shape(g: PageGeometry):
    return (g.layers, g.shards, g.pool_pages, g.kv_heads, g.page_size,
            g.head_dim)


def create_cache(g: PageGeometry) -> PagedCache:
    dt = jnp.int8 if g.kv_dtype == "int8" else jnp.dtype(g.kv_dtype)
    quant = g.kv_dtype == "int8"
    t0 = g.store.create()
    table = jax.tree.map(lambda x: jnp.broadcast_to(x, (g.shards,) + x.shape),
                         t0)
    DS, Bl = g.shards, g.batch_per_shard
    return PagedCache(
        kpool=jnp.zeros(_pool_shape(g), dt),
        vpool=jnp.zeros(_pool_shape(g), dt),
        kscale=jnp.zeros(_pool_shape(g)[:-1] + (1,), jnp.float32) if quant else None,
        vscale=jnp.zeros(_pool_shape(g)[:-1] + (1,), jnp.float32) if quant else None,
        table=table,
        next_free=jnp.zeros((DS,), I32),
        seq_ids=(jnp.arange(DS * Bl, dtype=U32)).reshape(DS, Bl),
        seq_lens=jnp.zeros((DS, Bl), I32),
        cur_page=jnp.zeros((DS, Bl), I32),
        cur_off=jnp.zeros((DS, Bl), I32),
    )


def cache_logical_axes(g: PageGeometry, cache: PagedCache):
    """Logical-axis tree matching ``cache`` (see distribution.sharding)."""
    pool_ax = ("layers", "kv_shard", None, "kv_heads_dec", "page_tokens", None)
    # scheme-generic: every store-state leaf shards its leading DS dim
    table_ax = store_shard_axes(cache.table, "kv_shard")
    return PagedCache(
        kpool=pool_ax, vpool=pool_ax,
        kscale=None if cache.kscale is None else pool_ax[:-1] + (None,),
        vscale=None if cache.vscale is None else pool_ax[:-1] + (None,),
        table=table_ax,
        next_free=("kv_shard",),
        seq_ids=("kv_shard", None), seq_lens=("kv_shard", None),
        cur_page=("kv_shard", None), cur_off=("kv_shard", None),
    )


# -- page-key construction ---------------------------------------------------

def page_keys(seq_ids: jnp.ndarray, logical_pages: jnp.ndarray) -> jnp.ndarray:
    """(..., ) ids + pages -> (..., 4) uint32 hash keys."""
    s = seq_ids.astype(U32)
    p = logical_pages.astype(U32)
    salt = jnp.broadcast_to(jnp.asarray(PAGE_SALT), s.shape)
    return jnp.stack([s, p, s ^ p, salt], axis=-1)


def page_values(phys: jnp.ndarray) -> jnp.ndarray:
    z = jnp.zeros_like(phys, dtype=U32)
    return jnp.stack([phys.astype(U32), z, z, z], axis=-1)


# -- the paper's ops on the decode path --------------------------------------

def _translation_keys(g: PageGeometry, seq_ids: jnp.ndarray) -> jnp.ndarray:
    """(DS, Bl*MAXP, 4) page-table keys for every (sequence, logical page)
    candidate translation of one decode step."""
    DS, Bl = seq_ids.shape
    pages = jnp.broadcast_to(jnp.arange(g.max_pages, dtype=U32),
                             (Bl, g.max_pages))
    keys = jax.vmap(lambda s: page_keys(
        jnp.repeat(s, g.max_pages).reshape(Bl, g.max_pages), pages))(seq_ids)
    return keys.reshape(DS, Bl * g.max_pages, 4)


def lookup_pages(g: PageGeometry, table, seq_ids: jnp.ndarray) -> jnp.ndarray:
    """Translate every (sequence, logical page) via a store lookup — the
    paper's client read (for continuity: one contiguous segment fetch per
    translation). Returns (DS, Bl, MAXP) physical ids, -1 where unmapped."""
    DS, Bl = seq_ids.shape
    res = jax.vmap(g.store.lookup)(table, _translation_keys(g, seq_ids))
    phys = jnp.where(res.ok, res.values[..., 0].astype(I32), -1)
    return phys.reshape(DS, Bl, g.max_pages)


@functools.partial(jax.jit, static_argnums=0)
def _step_read_plan(g: PageGeometry, table, seq_ids):
    from repro.rdma import verbs as rv
    res = jax.vmap(g.store.lookup)(table, _translation_keys(g, seq_ids))
    return rv.flatten(res.plan)


def step_read_plan(g: PageGeometry, cache: PagedCache):
    """One decode step's page-translation verb plan, all shards flattened:
    one one-sided READ per (sequence, logical page) candidate translation —
    the same keys `lookup_pages` resolves inside the jitted step.  This is
    the accounting twin the serving scheduler posts to its transport with
    ONE doorbell per step (the flush boundary): the whole step's
    translations coalesce into a single round trip for continuity, and the
    per-scheme amplification shows up as extra verbs/rounds.  The
    post-step cache is the right input: its table is exactly the
    post-``advance`` table the step's reads resolved against
    (``commit_token`` only bumps ``seq_lens``).  Jitted per geometry;
    still one extra (plan-only) lookup per step, so it is opt-in via the
    transport, not part of the decode dependency chain."""
    return _step_read_plan(g, cache.table, cache.seq_ids)


def _plan_page_allocation(g: PageGeometry, cache: PagedCache,
                          need: jnp.ndarray):
    """Shared allocation prologue: physical ids (bump allocator, alloc
    order, +wrap) and the (seq, page) -> phys mapping batch."""
    rank = jnp.cumsum(need.astype(I32), axis=1) - 1          # alloc order
    phys = (cache.next_free[:, None] + rank) % g.pool_pages  # bump (+wrap)
    logical = cache.seq_lens // g.page_size                  # page being opened
    keys = page_keys(cache.seq_ids, logical)                 # (DS, Bl, 4)
    vals = page_values(phys)
    return phys, keys, vals


def _open_pages_epilogue(cache: PagedCache, table, need, phys) -> PagedCache:
    """Shared epilogue: install the new table and open the pages."""
    return cache._replace(
        table=table,
        next_free=cache.next_free + jnp.sum(need, axis=1).astype(I32),
        cur_page=jnp.where(need, phys, cache.cur_page),
        cur_off=jnp.where(need, 0, cache.cur_off),
    )


def open_new_pages(g: PageGeometry, cache: PagedCache,
                   need: jnp.ndarray) -> PagedCache:
    """Allocate a physical page for each sequence with ``need`` set, insert
    the (seq, page) -> phys mapping into the hash table (server-side write:
    payload slots first, ONE atomic indicator commit), and open the page."""
    DS, Bl = need.shape
    phys, keys, vals = _plan_page_allocation(g, cache, need)
    # the store's batch engine resolves same-pair cohorts internally
    # (batch-order priority == the paper's lock order; for continuity this
    # is the wave engine, which can also grant extension groups).
    table, _ = jax.vmap(g.store.insert)(
        cache.table, keys.reshape(DS, Bl, 4), vals.reshape(DS, Bl, 4), need)
    return _open_pages_epilogue(cache, table, need, phys)


def open_new_pages_traced(g: PageGeometry, cache: PagedCache,
                          need: jnp.ndarray):
    """Crash-checkable twin of `open_new_pages`: the same page-table insert
    per data shard, but through ``store.trace_insert`` — returns the updated
    cache plus one `repro.consistency.TraceResult` per shard, whose PM store
    trace the crash injector can replay (every prefix of a page-allocation
    batch must recover to atomically-visible-or-invisible mappings; see
    tests/test_crash_consistency.py).  Host-level (python loop over shards):
    a drill/verification path, not the jitted decode hot path."""
    DS, Bl = need.shape
    phys, keys, vals = _plan_page_allocation(g, cache, need)
    tables, traces = [], []
    for s in range(DS):
        tbl = jax.tree.map(lambda x: x[s], cache.table)
        tbl, tres = g.store.trace_insert(
            tbl, keys[s].reshape(Bl, 4), vals[s].reshape(Bl, 4), need[s])
        tables.append(tbl)
        traces.append(tres)
    table = jax.tree.map(lambda *xs: jnp.stack(xs), *tables)
    return _open_pages_epilogue(cache, table, need, phys), traces


def advance(g: PageGeometry, cache: PagedCache) -> PagedCache:
    """Pre-step bookkeeping: open a fresh page for sequences whose next token
    starts a new logical page."""
    need = (cache.seq_lens % g.page_size) == 0
    cache = open_new_pages(g, cache, need)
    return cache._replace(cur_off=cache.seq_lens % g.page_size)


def commit_token(cache: PagedCache) -> PagedCache:
    """Post-step: the new token is now cached."""
    return cache._replace(seq_lens=cache.seq_lens + 1)


# -- int8 quantization (beyond-paper serving optimization) -------------------

def quant_store(x: jnp.ndarray):
    """Symmetric per-(token, head) int8 quant. x: (..., D) -> (int8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequant(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# -- recurrent/window caches (ssm & hybrid families) --------------------------

def create_state_cache(cfg: ModelConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16) -> dict:
    """Cache for SSM (recurrent state) and hybrid (ring window + linear
    global caches + recurrent state) architectures."""
    from repro.models import ssm as S
    from repro.models import transformer as T
    d_inner, nheads, conv_ch = S.ssm_dims(cfg)
    s = cfg.ssm
    cache = {
        "S": jnp.zeros((cfg.n_layers, batch, nheads, s.d_state, s.head_dim),
                       jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, conv_ch),
                          dtype),
        "seq_lens": jnp.zeros((batch,), I32),
    }
    if cfg.family == "hybrid":
        segs = T.layer_segments(cfg)
        n_win = sum(b - a for a, b, w in segs if w)
        n_glob = sum(b - a for a, b, w in segs if not w)
        KVH, D = cfg.n_kv_heads, cfg.hd
        cache.update(
            ring_k=jnp.zeros((n_win, batch, cfg.window, KVH, D), dtype),
            ring_v=jnp.zeros((n_win, batch, cfg.window, KVH, D), dtype),
            glob_k=jnp.zeros((n_glob, batch, max_seq, KVH, D), dtype),
            glob_v=jnp.zeros((n_glob, batch, max_seq, KVH, D), dtype),
        )
    return cache


def state_cache_logical_axes(cfg: ModelConfig, cache: dict) -> dict:
    ax = {
        "S": ("layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "batch", None, None),
        "seq_lens": ("batch",),
    }
    if "ring_k" in cache:
        win = ("layers", "batch", "page_tokens", "kv_heads_dec", None)
        ax.update(ring_k=win, ring_v=win, glob_k=win, glob_v=win)
    return ax

"""Persistent-memory cost accounting (the paper's evaluation metrics).

The paper measures:
  * "number of PM writes"  = number of cache-line flush instructions per op
    (Table I) — here each 64-byte-granule store that a scheme would flush is
    counted as one PM write;
  * RDMA access amplification = number of one-sided contiguous-region fetches
    a *client read* needs (continuity: 1 [+1 for extended pairs], level: <=4,
    P-FaRM-KV: 1 + overflow-chain hops);
  * bytes fetched per read (the RDMA payload) — on TPU this is exactly the
    collective payload of the sharded lookup, so the same counter feeds the
    roofline collective term.

Counters are a small pytree so they can thread through jitted scans.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class PMCounters(NamedTuple):
    """Accumulated device-side counters (all int32 scalars)."""

    pm_writes: jnp.ndarray      # cache-line flushes issued
    rdma_reads: jnp.ndarray     # one-sided contiguous fetches issued
    bytes_fetched: jnp.ndarray  # total fetched payload (bytes)
    ops: jnp.ndarray            # operations accounted

    @staticmethod
    def zero() -> "PMCounters":
        z = jnp.zeros((), jnp.int32)
        return PMCounters(z, z, z, z)

    def add(self, pm_writes=0, rdma_reads=0, bytes_fetched=0, ops=0) -> "PMCounters":
        return PMCounters(
            self.pm_writes + jnp.asarray(pm_writes, jnp.int32),
            self.rdma_reads + jnp.asarray(rdma_reads, jnp.int32),
            self.bytes_fetched + jnp.asarray(bytes_fetched, jnp.int32),
            self.ops + jnp.asarray(ops, jnp.int32),
        )

    def merge(self, other: "PMCounters") -> "PMCounters":
        return PMCounters(*(a + b for a, b in zip(self, other)))


CACHE_LINE = 64


def lines_touched(nbytes: int) -> int:
    """Number of cache lines covered by an aligned store of ``nbytes``."""
    return max(1, (nbytes + CACHE_LINE - 1) // CACHE_LINE)

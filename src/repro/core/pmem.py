"""Persistent-memory cost accounting (the paper's evaluation metrics).

The paper measures:
  * "number of PM writes"  = number of cache-line flush instructions per op
    (Table I) — here each 64-byte-granule store that a scheme would flush is
    counted as one PM write;
  * RDMA access amplification = number of one-sided contiguous-region fetches
    a *client read* needs (continuity: 1 [+1 for extended pairs], level: <=4,
    P-FaRM-KV: 1 + overflow-chain hops);
  * bytes fetched per read (the RDMA payload) — on TPU this is exactly the
    collective payload of the sharded lookup, so the same counter feeds the
    roofline collective term.

``CostLedger`` is the canonical name of the ONE counter pytree threaded
through every op of every scheme (`repro.api` returns it on each
`OpResult`); the per-op apples-to-apples comparison the paper's Table I
makes is just ``ledger.pm_per_op()`` across schemes.  ``PMCounters`` is a
DEPRECATED alias kept only for old external call sites (see README.md
"Migrating to repro.api") — nothing in this repo should use it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class CostLedger(NamedTuple):
    """Accumulated device-side counters (all int32 scalars).

    ``rdma_reads`` counts one-sided CONTIGUOUS fetches — in this codebase a
    "read" and a "contiguous fetch" are the same unit (the paper's access-
    amplification denominator); per-op fetch traces live on
    ``OpResult.reads``.
    """

    pm_writes: jnp.ndarray      # cache-line flushes issued
    rdma_reads: jnp.ndarray     # one-sided contiguous fetches issued
    bytes_fetched: jnp.ndarray  # total fetched payload (bytes)
    ops: jnp.ndarray            # ACTIVE operations accounted (masked-off
                                # batch slots count neither writes nor ops)

    @staticmethod
    def zero() -> "CostLedger":
        z = jnp.zeros((), jnp.int32)
        return CostLedger(z, z, z, z)

    def add(self, pm_writes=0, rdma_reads=0, bytes_fetched=0, ops=0) -> "CostLedger":
        return CostLedger(
            self.pm_writes + jnp.asarray(pm_writes, jnp.int32),
            self.rdma_reads + jnp.asarray(rdma_reads, jnp.int32),
            self.bytes_fetched + jnp.asarray(bytes_fetched, jnp.int32),
            self.ops + jnp.asarray(ops, jnp.int32),
        )

    def merge(self, other: "CostLedger") -> "CostLedger":
        return CostLedger(*(a + b for a, b in zip(self, other)))

    # -- per-op averages (host-side floats; the paper's table cells) --------
    def _per_op(self, x) -> float:
        n = float(self.ops)
        return float(x) / n if n else 0.0

    def pm_per_op(self) -> float:
        """Average PM writes per op (Table I cell)."""
        return self._per_op(self.pm_writes)

    def reads_per_op(self) -> float:
        """Average contiguous fetches per op (access amplification)."""
        return self._per_op(self.rdma_reads)

    def bytes_per_op(self) -> float:
        return self._per_op(self.bytes_fetched)


# DEPRECATED alias (pre-`repro.api` name); kept for external back-compat
# only — new code and the scheme modules use ``CostLedger``.
PMCounters = CostLedger


CACHE_LINE = 64


def lines_touched(nbytes: int) -> int:
    """Number of cache lines covered by an aligned store of ``nbytes``."""
    return max(1, (nbytes + CACHE_LINE - 1) // CACHE_LINE)

"""Level hashing baseline (Zuo, Hua, Wu — OSDI'18), as used by the paper's
evaluation (its PM-friendly competitor), with RDMA read accounting.

Structure: a top level of N buckets and a bottom level of N/2 buckets; two
hash functions; a key's four candidate buckets are top[h1], top[h2],
bottom[h1/2], bottom[h2/2]. Each bucket has ``bucket_slots`` slots and a
per-bucket token byte (one valid bit per slot, 8-byte-atomic commit).

RDMA behaviour (paper §II-C2): the four candidate buckets are NON-contiguous,
so a remote search costs up to four one-sided reads (negative searches always
scan all distinct candidates) — this is the access amplification the paper's
continuity layout removes.

PM-write behaviour (paper Table I): insert 2 (+3 on the rare one-movement
path, reordered crash-safe => 2–2.01 avg), delete 1, update 2 when an empty
slot exists in the same bucket (log-free out-of-place) else 4 with undo
logging (paper reports 2–5).  Crash semantics of every path are reproduced
and checked by `repro.consistency` (tests/test_crash_consistency.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pmem
from repro.core.continuity import KEY_LANES, VAL_LANES, SLOT_BYTES
from repro.core.hashfn import hash128, hash128_2

U32 = jnp.uint32
I32 = jnp.int32
U8 = jnp.uint8


@dataclasses.dataclass(frozen=True)
class LevelConfig:
    num_top: int                 # top-level buckets (bottom = num_top // 2)
    bucket_slots: int = 4

    def __post_init__(self):
        assert self.num_top % 2 == 0 and self.bucket_slots <= 8

    @property
    def num_bottom(self) -> int:
        return self.num_top // 2

    @property
    def total_slots(self) -> int:
        return (self.num_top + self.num_bottom) * self.bucket_slots

    @property
    def bucket_bytes(self) -> int:
        return self.bucket_slots * SLOT_BYTES + 8  # slots + token word

    def grow(self, factor: int = 2) -> "LevelConfig":
        return dataclasses.replace(self, num_top=self.num_top * factor)


class LevelTable(NamedTuple):
    tkeys: jnp.ndarray  # (NT, bs, KL) uint32
    tvals: jnp.ndarray  # (NT, bs, VL)
    ttok: jnp.ndarray   # (NT,) uint8 — valid bits
    bkeys: jnp.ndarray  # (NB, bs, KL)
    bvals: jnp.ndarray  # (NB, bs, VL)
    btok: jnp.ndarray   # (NB,) uint8
    count: jnp.ndarray  # () int32


def create(cfg: LevelConfig) -> LevelTable:
    NT, NB, bs = cfg.num_top, cfg.num_bottom, cfg.bucket_slots
    return LevelTable(
        tkeys=jnp.zeros((NT, bs, KEY_LANES), U32),
        tvals=jnp.zeros((NT, bs, VAL_LANES), U32),
        ttok=jnp.zeros((NT,), U8),
        bkeys=jnp.zeros((NB, bs, KEY_LANES), U32),
        bvals=jnp.zeros((NB, bs, VAL_LANES), U32),
        btok=jnp.zeros((NB,), U8),
        count=jnp.zeros((), I32),
    )


def load_factor(cfg: LevelConfig, t: LevelTable) -> jnp.ndarray:
    return t.count.astype(jnp.float32) / cfg.total_slots


def _cand_buckets(cfg: LevelConfig, keys: jnp.ndarray):
    """(B, 4) candidate bucket ids: [top h1, top h2, bottom h1/2, bottom h2/2]
    plus which level each lives in (True = top)."""
    h1 = hash128(keys) % U32(cfg.num_top)
    h2 = hash128_2(keys) % U32(cfg.num_top)
    t1, t2 = h1.astype(I32), h2.astype(I32)
    b1, b2 = t1 // 2, t2 // 2
    return jnp.stack([t1, t2, b1, b2], -1)


def _gather4(cfg, t: LevelTable, cand):
    """Fetch the four candidate buckets: (B,4,bs,·) keys/vals + (B,4,bs) valid."""
    tk = t.tkeys[cand[:, :2]]            # (B,2,bs,KL)
    tv = t.tvals[cand[:, :2]]
    tt = t.ttok[cand[:, :2]]             # (B,2)
    bk = t.bkeys[cand[:, 2:]]
    bv = t.bvals[cand[:, 2:]]
    bt = t.btok[cand[:, 2:]]
    keys4 = jnp.concatenate([tk, bk], 1)
    vals4 = jnp.concatenate([tv, bv], 1)
    tok4 = jnp.concatenate([tt, bt], 1)  # (B,4)
    bits = (tok4[..., None] >> jnp.arange(cfg.bucket_slots, dtype=U8)) & U8(1)
    return keys4, vals4, bits == 1


class LookupResult(NamedTuple):
    found: jnp.ndarray
    values: jnp.ndarray
    where: jnp.ndarray   # (B, 2) int32 (bucket#0-3, slot) or -1
    reads: jnp.ndarray   # contiguous fetches needed (distinct buckets probed)


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: LevelConfig, t: LevelTable, keys) -> LookupResult:
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    cand = _cand_buckets(cfg, keys)
    k4, v4, valid = _gather4(cfg, t, cand)
    match = valid & jnp.all(k4 == keys[:, None, None, :], -1)    # (B,4,bs)
    mflat = match.reshape(match.shape[0], -1)
    found = jnp.any(mflat, -1)
    first = jnp.argmax(mflat, -1)
    bs = cfg.bucket_slots
    bidx, slot = first // bs, first % bs
    values = jnp.take_along_axis(
        v4.reshape(v4.shape[0], -1, VAL_LANES), first[:, None, None], 1)[:, 0]
    values = jnp.where(found[:, None], values, 0)
    # distinct-bucket fetch count: probes proceed t1, t2, b1, b2 skipping dups
    distinct = jnp.stack([
        jnp.ones_like(found),
        cand[:, 1] != cand[:, 0],
        jnp.ones_like(found),
        cand[:, 3] != cand[:, 2]], -1).astype(I32)               # (B,4)
    upto = jnp.where(found, bidx, 3)
    mask = jnp.arange(4)[None, :] <= upto[:, None]
    reads = jnp.sum(distinct * mask, -1)
    where = jnp.where(found[:, None], jnp.stack([bidx, slot], -1), -1)
    return LookupResult(found, values, where, reads)


def lookup_plan(cfg: LevelConfig, t: LevelTable, keys, res: LookupResult):
    """Verb plan of a lookup batch (paper §II-C2): up to FOUR scattered
    one-sided bucket READs per key — the candidates are non-contiguous, so
    each distinct bucket is its own verb, probed sequentially (depth =
    probe rank: the client stops at the bucket that holds the key, so a
    negative search walks all four rounds).  This is the access
    amplification continuity's contiguous layout removes."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    cand = _cand_buckets(cfg, keys)                        # (B, 4)
    distinct = jnp.stack([
        jnp.ones(keys.shape[0], jnp.bool_),
        cand[:, 1] != cand[:, 0],
        jnp.ones(keys.shape[0], jnp.bool_),
        cand[:, 3] != cand[:, 2]], -1)
    upto = jnp.where(res.found, res.where[:, 0], 3)
    act = distinct & (jnp.arange(4)[None, :] <= upto[:, None])
    rank = jnp.cumsum(act.astype(I32), axis=1) - act.astype(I32)
    off = jnp.where(jnp.arange(4)[None, :] < 2, cand,
                    cfg.num_top + cand) * cfg.bucket_bytes
    return rv.pack(keys.shape[0], [
        (jnp.where(act[:, j], rv.READ, rv.NOOP), rv.REGION_TABLE,
         off[:, j], cfg.bucket_bytes, rank[:, j], False)
        for j in range(4)])

def version_read_plan(cfg: LevelConfig, t: LevelTable, keys):
    """Verb plan pricing one stamp-validation batch.  Level hashing has no
    per-key 8-byte commit word a client could poll — a stamp is the looked-
    up VALUE — so validation costs the full scattered-bucket lookup plan
    (same unified ``(cfg, table, keys)`` trio shape as every scheme)."""
    return lookup_plan(cfg, t, keys, lookup(cfg, t, keys))


def scan_plan(cfg: LevelConfig, t: LevelTable, keys, spans):
    """Verb plan of a YCSB-E short-scan batch: level hashing has NO
    contiguous range — the two hash functions scatter adjacent records
    over the whole top/bottom array — so a span-record scan degenerates
    to one scattered bucket READ per record (the per-record walk a
    hash-scattered layout forces, each record hashed independently).
    All reads are independent (depth 0): the client knows every record's
    bucket up front, but pays ``span`` verbs where continuity pays one."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    spans = np.maximum(np.asarray(spans, np.int32).reshape(-1), 1)
    M = int(spans.max())
    home = (hash128(keys) % U32(cfg.num_top)).astype(jnp.int32)
    lanes = []
    for j in range(M):
        act = j < spans
        # j-th record of the scan: an unrelated bucket (scattered layout)
        off = ((home + j * 7 + 1) % cfg.num_top) * cfg.bucket_bytes
        lanes.append((jnp.where(act, rv.READ, rv.NOOP), rv.REGION_TABLE,
                      off, cfg.bucket_bytes, 0, False))
    return rv.pack(keys.shape[0], lanes)


# -- server-side ops (scan-serialized like the other schemes) ----------------

def _bucket_arrays(t, level_top):
    return jax.lax.cond(
        level_top,
        lambda: (t.tkeys, t.tvals),
        lambda: (t.bkeys, t.bvals))


def _write_slot(t: LevelTable, is_top, bucket, slot, key, val, ok):
    drop = jnp.iinfo(I32).max
    tb = jnp.where(ok & is_top, bucket, drop)
    bb = jnp.where(ok & ~is_top, bucket, drop)
    return t._replace(
        tkeys=t.tkeys.at[tb, slot].set(key, mode="drop"),
        tvals=t.tvals.at[tb, slot].set(val, mode="drop"),
        bkeys=t.bkeys.at[bb, slot].set(key, mode="drop"),
        bvals=t.bvals.at[bb, slot].set(val, mode="drop"))


def _commit_tok(t: LevelTable, is_top, bucket, new_tok, ok):
    drop = jnp.iinfo(I32).max
    tb = jnp.where(ok & is_top, bucket, drop)
    bb = jnp.where(ok & ~is_top, bucket, drop)
    return t._replace(ttok=t.ttok.at[tb].set(new_tok, mode="drop"),
                      btok=t.btok.at[bb].set(new_tok, mode="drop"))


def _insert_one(cfg, t: LevelTable, key, val, active):
    bs = cfg.bucket_slots
    cand = _cand_buckets(cfg, key[None])[0]              # (4,)
    toks = jnp.stack([t.ttok[cand[0]], t.ttok[cand[1]],
                      t.btok[cand[2]], t.btok[cand[3]]])
    bits = (toks[:, None] >> jnp.arange(bs, dtype=U8)) & U8(1)
    empty = bits == 0                                     # (4,bs)
    has = jnp.any(empty, -1)
    bsel = jnp.argmax(has)                                # first bucket w/ empty
    ok_plain = jnp.any(has) & active
    slot = jnp.argmax(empty[bsel])
    is_top = bsel < 2
    bucket = cand[bsel]

    # one-movement path: top[h1]'s slot-0 item moves to ITS alternate top
    # bucket if that one has space.  Crash-safe 5-store order (+3 PM writes;
    # rare in practice): copy, commit copy, CLEAR the source bit, write the
    # new item into the freed slot, commit — the freed slot is never
    # payload-written while its valid bit is set, so a torn store is
    # invisible; the only crash artifact is a transient duplicate of the
    # moved item, repaired by recovery's duplicate scan
    # (repro.consistency.schemes.LevelHandler.recover).
    def try_move(t):
        mkey = t.tkeys[cand[0], 0]
        mval = t.tvals[cand[0], 0]
        a1 = (hash128(mkey) % U32(cfg.num_top)).astype(I32)
        a2 = (hash128_2(mkey) % U32(cfg.num_top)).astype(I32)
        alt = jnp.where(a1 == cand[0], a2, a1)
        atok = t.ttok[alt]
        abits = (atok >> jnp.arange(bs, dtype=U8)) & U8(1)   # (bs,)
        can = jnp.any(abits == 0) & (alt != cand[0]) & active
        aslot = jnp.argmax(abits == 0)
        tt = jnp.ones((), jnp.bool_)
        t2 = _write_slot(t, tt, alt, aslot, mkey, mval, can)
        t2 = _commit_tok(t2, tt, alt, atok | (U8(1) << aslot.astype(U8)), can)
        # clear the source bit BEFORE reusing the slot, then commit the new item
        src_tok = t2.ttok[cand[0]] & ~U8(1)
        t2 = _commit_tok(t2, tt, cand[0], src_tok, can)
        t2 = _write_slot(t2, tt, cand[0], jnp.zeros((), I32), key, val, can)
        t2 = _commit_tok(t2, tt, cand[0], src_tok | U8(1), can)
        return t2, can

    def plain(t):
        tok = jnp.where(is_top, t.ttok[bucket], t.btok[bucket]).astype(U8)
        t2 = _write_slot(t, is_top, bucket, slot, key, val, ok_plain)
        t2 = _commit_tok(t2, is_top, bucket,
                         tok | (U8(1) << slot.astype(U8)), ok_plain)
        return t2, ok_plain

    t2, ok = jax.lax.cond(ok_plain, plain, try_move, t)
    moved = ~ok_plain & ok
    pm = jnp.where(ok, jnp.where(moved, 5, 2), 0).astype(I32)
    return t2._replace(count=t2.count + ok.astype(I32)), ok, pm


def _delete_one(cfg, t: LevelTable, key, active):
    res = lookup(cfg, t, key[None])
    ok = res.found[0] & active
    bidx, slot = res.where[0, 0], res.where[0, 1]
    cand = _cand_buckets(cfg, key[None])[0]
    bucket = cand[jnp.maximum(bidx, 0)]
    is_top = bidx < 2
    tok = jnp.where(is_top, t.ttok[bucket], t.btok[bucket]).astype(U8)
    new_tok = tok & ~(U8(1) << jnp.maximum(slot, 0).astype(U8))
    t2 = _commit_tok(t, is_top, bucket, new_tok, ok)
    return t2._replace(count=t2.count - ok.astype(I32)), ok, jnp.where(ok, 1, 0).astype(I32)


def _update_one(cfg, t: LevelTable, key, val, active):
    bs = cfg.bucket_slots
    res = lookup(cfg, t, key[None])
    found = res.found[0] & active
    bidx, slot = res.where[0, 0], res.where[0, 1]
    cand = _cand_buckets(cfg, key[None])[0]
    bucket = cand[jnp.maximum(bidx, 0)]
    is_top = bidx < 2
    tok = jnp.where(is_top, t.ttok[bucket], t.btok[bucket]).astype(U8)
    bits = (tok >> jnp.arange(bs, dtype=U8)) & U8(1)         # (bs,)
    has_empty = jnp.any(bits == 0)
    eslot = jnp.argmax(bits == 0)
    # log-free out-of-place within the same bucket (2 PM writes)
    ok_free = found & has_empty
    t2 = _write_slot(t, is_top, bucket, eslot, key, val, ok_free)
    flip = (U8(1) << eslot.astype(U8)) | (U8(1) << jnp.maximum(slot, 0).astype(U8))
    t2 = _commit_tok(t2, is_top, bucket, tok ^ jnp.where(ok_free, flip, U8(0)), ok_free)
    # logged in-place fallback (4 PM writes: log entry, item, commit, invalidate)
    ok_log = found & ~has_empty
    t2 = _write_slot(t2, is_top, bucket, slot, key, val, ok_log)
    ok = ok_free | ok_log
    pm = jnp.where(ok_free, 2, jnp.where(ok_log, 4, 0)).astype(I32)
    return t2, ok, pm


def _scan(cfg, fn):
    def step(carry, kv):
        t, ctr = carry
        *args, active = kv
        t, ok, pm = fn(cfg, t, *args, active)
        # masked-off ops count neither writes nor the ops denominator
        return (t, ctr.add(pm_writes=pm, ops=jnp.where(active, 1, 0))), ok
    return step


def _active(keys, mask):
    B = keys.shape[0]
    return (jnp.ones((B,), jnp.bool_) if mask is None
            else jnp.asarray(mask).reshape(B).astype(jnp.bool_))


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg, t, keys, vals, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _insert_one), (t, pmem.CostLedger.zero()),
        (keys, vals, _active(keys, mask)))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def delete(cfg, t, keys, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _delete_one), (t, pmem.CostLedger.zero()),
        (keys, _active(keys, mask)))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def update(cfg, t, keys, vals, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _update_one), (t, pmem.CostLedger.zero()),
        (keys, vals, _active(keys, mask)))
    return t, ok, ctr

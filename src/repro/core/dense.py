"""Dense block-table reference store (the vLLM-style baseline index).

One flat, fully-associative region of ``capacity`` slots: a lookup fetches
the WHOLE table (it is dense and local — one contiguous region, so exactly
one "fetch" whose payload is the entire table) and compares against every
slot; an insert takes the first free slot in index order.  No hashing, no
buckets, no extension machinery — this is the correctness reference the
hash schemes are measured against, and the drop-in "dense page table"
backend for the serving path (`repro.api` registers it as ``dense``).

Cost model (for the shared `CostLedger` accounting):
  * lookup  — 1 contiguous fetch of ``table_bytes`` (dense tables are only
    viable when local; remote they are the worst case the paper's schemes
    exist to avoid);
  * insert  — 2 PM writes (slot payload, then the live-bit commit word —
    same split-commit discipline as continuity so crash tests can reuse it);
  * update  — 1 PM write (in-place value store; a dense entry is one line);
  * delete  — 1 PM write (live-bit clear).

All ops are batched and fully vectorized (O(B*C) compares); same-batch
duplicate KEYS on the write paths are resolved in batch order for insert
(prefix-sum slot grants) — update/delete of the same key twice in one batch
is a single-slot scatter and keeps one of the writes (unspecified which),
matching what a real block table does under racing writers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pmem
from repro.core.continuity import KEY_LANES, VAL_LANES, SLOT_BYTES

U32 = jnp.uint32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class DenseConfig:
    capacity: int                 # total slots

    def __post_init__(self):
        assert self.capacity >= 1

    @property
    def total_slots(self) -> int:
        return self.capacity

    @property
    def table_bytes(self) -> int:
        return self.capacity * (SLOT_BYTES + 1)   # slots + live bytes

    def grow(self, factor: int = 2) -> "DenseConfig":
        return dataclasses.replace(self, capacity=self.capacity * factor)


class DenseTable(NamedTuple):
    keys: jnp.ndarray    # (C, KL) uint32
    vals: jnp.ndarray    # (C, VL) uint32
    live: jnp.ndarray    # (C,) bool
    count: jnp.ndarray   # () int32


def create(cfg: DenseConfig) -> DenseTable:
    C = cfg.capacity
    return DenseTable(
        keys=jnp.zeros((C, KEY_LANES), U32),
        vals=jnp.zeros((C, VAL_LANES), U32),
        live=jnp.zeros((C,), jnp.bool_),
        count=jnp.zeros((), I32),
    )


def load_factor(cfg: DenseConfig, t: DenseTable) -> jnp.ndarray:
    return t.count.astype(jnp.float32) / cfg.capacity


class LookupResult(NamedTuple):
    found: jnp.ndarray   # (B,) bool
    values: jnp.ndarray  # (B, VAL_LANES)
    slot: jnp.ndarray    # (B,) int32 (-1 on miss)
    reads: jnp.ndarray   # (B,) int32 — always 1 (whole-table fetch)


def _match(t: DenseTable, keys: jnp.ndarray) -> jnp.ndarray:
    """(B, C) bool: live slot holds exactly this key."""
    return t.live[None, :] & jnp.all(
        t.keys[None, :, :] == keys[:, None, :], axis=-1)


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: DenseConfig, t: DenseTable, keys) -> LookupResult:
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    m = _match(t, keys)
    found = jnp.any(m, -1)
    slot = jnp.where(found, jnp.argmax(m, -1), -1)
    values = jnp.where(found[:, None], t.vals[jnp.maximum(slot, 0)], 0)
    return LookupResult(found, values, slot,
                        jnp.ones((keys.shape[0],), I32))


def lookup_plan(cfg: DenseConfig, t: DenseTable, keys, res: LookupResult):
    """Verb plan of a lookup batch: the degenerate worst case — one READ of
    the ENTIRE table region per key (dense tables are only viable local;
    remote they are what the paper's schemes exist to avoid)."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    return rv.pack(keys.shape[0], [
        (rv.READ, rv.REGION_TABLE, 0, cfg.table_bytes, 0, False)])

def version_read_plan(cfg: DenseConfig, t: DenseTable, keys):
    """Verb plan pricing one stamp-validation batch: value-based stamps, so
    a validation is a full (whole-table) lookup plan (unified
    ``(cfg, table, keys)`` shape)."""
    return lookup_plan(cfg, t, keys, lookup(cfg, t, keys))


def scan_plan(cfg: DenseConfig, t: DenseTable, keys, spans):
    """Verb plan of a YCSB-E scan batch: dense storage is contiguous, so
    like lookup this degenerates to one whole-table READ per scan (a
    local-only layout priced at its remote worst case)."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    return rv.pack(keys.shape[0], [
        (rv.READ, rv.REGION_TABLE, 0, cfg.table_bytes, 0, False)])


def _batch(keys, vals, mask):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    B = keys.shape[0]
    if vals is not None:
        vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    active = (jnp.ones((B,), jnp.bool_) if mask is None
              else jnp.asarray(mask).reshape(B).astype(jnp.bool_))
    return keys, vals, active


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg: DenseConfig, t: DenseTable, keys, vals, mask=None):
    """Batched insert: active op of batch rank r takes the (r+1)-th free
    slot in index order. 2 PM writes/op (payload, live commit)."""
    keys, vals, active = _batch(keys, vals, mask)
    free = ~t.live                                     # (C,)
    rank = jnp.cumsum(active.astype(I32)) - 1          # (B,) batch order
    freerank = jnp.cumsum(free.astype(I32)) - 1        # (C,) free order
    eq = free[None, :] & (freerank[None, :] == rank[:, None])
    ok = active & (rank < jnp.sum(free.astype(I32)))
    slot = jnp.argmax(eq, -1)
    drop = jnp.iinfo(I32).max
    w = jnp.where(ok, slot, drop)
    t = t._replace(
        keys=t.keys.at[w].set(keys, mode="drop"),      # phase 1: payload
        vals=t.vals.at[w].set(vals, mode="drop"))
    t = t._replace(live=t.live.at[w].set(True, mode="drop"),  # phase 2
                   count=t.count + jnp.sum(ok).astype(I32))
    ctr = pmem.CostLedger.zero().add(pm_writes=2 * jnp.sum(ok),
                                     ops=jnp.sum(active))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def update(cfg: DenseConfig, t: DenseTable, keys, vals, mask=None):
    """Batched in-place update. 1 PM write/op."""
    keys, vals, active = _batch(keys, vals, mask)
    m = _match(t, keys)
    ok = active & jnp.any(m, -1)
    slot = jnp.argmax(m, -1)
    drop = jnp.iinfo(I32).max
    w = jnp.where(ok, slot, drop)
    t = t._replace(vals=t.vals.at[w].set(vals, mode="drop"))
    ctr = pmem.CostLedger.zero().add(pm_writes=jnp.sum(ok),
                                     ops=jnp.sum(active))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def delete(cfg: DenseConfig, t: DenseTable, keys, mask=None):
    """Batched delete: live-bit clear. 1 PM write/op."""
    keys, _, active = _batch(keys, None, mask)
    m = _match(t, keys)
    ok = active & jnp.any(m, -1)
    slot = jnp.argmax(m, -1)
    drop = jnp.iinfo(I32).max
    w = jnp.where(ok, slot, drop)
    t = t._replace(live=t.live.at[w].set(False, mode="drop"),
                   count=t.count - jnp.sum(ok).astype(I32))
    ctr = pmem.CostLedger.zero().add(pm_writes=jnp.sum(ok),
                                     ops=jnp.sum(active))
    return t, ok, ctr


def extract_items(cfg: DenseConfig, t: DenseTable):
    """Live (key, value) slots + validity mask (for generic resize)."""
    return t.keys, t.vals, t.live

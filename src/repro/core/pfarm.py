"""P-FaRM-KV baseline: FaRM-KV's chained associative hopscotch hashing
(Dragojević et al., NSDI'14) converted to persistent memory via RECIPE
(Lee et al., SOSP'19), as constructed by the paper's evaluation (§V-A).

Structure: N buckets of ``bucket_slots`` slots; a key with home bucket ``h``
may live in the CONTIGUOUS neighbourhood ``h .. h+H-1`` (hopscotch window) —
one one-sided read fetches the whole window. When the window is full, an
overflow block is chained to the home bucket (each chain hop = one extra
one-sided read). Insertion uses at most ONE displacement (the paper's own
optimization of P-FaRM-KV: "replacing the iteratively displacing key-value
pairs in the original scheme with at most one movement").

RECIPE conversion: clflush + mfence after each store, undo-logging around
every multi-store write => every write op costs 5 PM writes (log entry,
log header/commit, item store, token store, log invalidate) — paper Table I
reports 5 / 5 / 5 for insert / update / delete.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pmem
from repro.core.continuity import KEY_LANES, VAL_LANES, SLOT_BYTES
from repro.core.hashfn import hash128

U32 = jnp.uint32
I32 = jnp.int32
U8 = jnp.uint8

PM_WRITES_PER_OP = 5  # RECIPE logging discipline (paper Table I)


@dataclasses.dataclass(frozen=True)
class PFarmConfig:
    num_buckets: int
    bucket_slots: int = 4
    window: int = 6                   # hopscotch neighbourhood H
    overflow_frac: float = 0.25       # overflow pool size as frac of buckets
    max_chain: int = 4                # chain hops followed per lookup

    @property
    def pool_blocks(self) -> int:
        return max(2, int(self.num_buckets * self.overflow_frac))

    @property
    def total_slots(self) -> int:
        return (self.num_buckets + self.pool_blocks) * self.bucket_slots

    @property
    def window_bytes(self) -> int:
        return self.window * (self.bucket_slots * SLOT_BYTES + 8)

    @property
    def block_bytes(self) -> int:
        return self.bucket_slots * SLOT_BYTES + 16  # slots + tok + next ptr

    def grow(self, factor: int = 2) -> "PFarmConfig":
        return dataclasses.replace(self, num_buckets=self.num_buckets * factor)


class PFarmTable(NamedTuple):
    keys: jnp.ndarray    # (N, bs, KL)
    vals: jnp.ndarray    # (N, bs, VL)
    tok: jnp.ndarray     # (N,) uint8
    head: jnp.ndarray    # (N,) int32 — overflow chain head block (-1 none)
    okeys: jnp.ndarray   # (PO, bs, KL) overflow pool
    ovals: jnp.ndarray   # (PO, bs, VL)
    otok: jnp.ndarray    # (PO,) uint8
    onext: jnp.ndarray   # (PO,) int32
    ocount: jnp.ndarray  # () int32 — allocated blocks
    count: jnp.ndarray   # () int32


def create(cfg: PFarmConfig) -> PFarmTable:
    N, bs, PO = cfg.num_buckets, cfg.bucket_slots, cfg.pool_blocks
    return PFarmTable(
        keys=jnp.zeros((N, bs, KEY_LANES), U32),
        vals=jnp.zeros((N, bs, VAL_LANES), U32),
        tok=jnp.zeros((N,), U8),
        head=jnp.full((N,), -1, I32),
        okeys=jnp.zeros((PO, bs, KEY_LANES), U32),
        ovals=jnp.zeros((PO, bs, VAL_LANES), U32),
        otok=jnp.zeros((PO,), U8),
        onext=jnp.full((PO,), -1, I32),
        ocount=jnp.zeros((), I32),
        count=jnp.zeros((), I32),
    )


def load_factor(cfg: PFarmConfig, t: PFarmTable) -> jnp.ndarray:
    return t.count.astype(jnp.float32) / cfg.total_slots


def _home(cfg, keys):
    return (hash128(keys) % U32(cfg.num_buckets)).astype(I32)


def _window_ids(cfg, home):
    return (home[:, None] + jnp.arange(cfg.window, dtype=I32)[None]) % cfg.num_buckets


class LookupResult(NamedTuple):
    found: jnp.ndarray
    values: jnp.ndarray
    where: jnp.ndarray   # (B,3): [in_chain, bucket_or_block, slot]
    reads: jnp.ndarray   # one-sided fetches (1 window + chain hops followed)


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: PFarmConfig, t: PFarmTable, keys) -> LookupResult:
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    B = keys.shape[0]
    home = _home(cfg, keys)
    win = _window_ids(cfg, home)                       # (B,H)
    k = t.keys[win]                                    # (B,H,bs,KL)
    v = t.vals[win]
    bits = (t.tok[win][..., None] >> jnp.arange(cfg.bucket_slots, dtype=U8)) & U8(1)
    match = (bits == 1) & jnp.all(k == keys[:, None, None, :], -1)
    mflat = match.reshape(B, -1)
    found_w = jnp.any(mflat, -1)
    first = jnp.argmax(mflat, -1)
    bs = cfg.bucket_slots
    values = jnp.take_along_axis(v.reshape(B, -1, VAL_LANES),
                                 first[:, None, None], 1)[:, 0]
    wbucket = jnp.take_along_axis(win, (first // bs)[:, None], 1)[:, 0]
    wslot = first % bs

    # chain walk (unrolled to max_chain): each hop is one more one-sided read
    cur = t.head[home]
    found = found_w
    vals_out = jnp.where(found_w[:, None], values, 0)
    where = jnp.where(found_w[:, None],
                      jnp.stack([jnp.zeros_like(wbucket), wbucket, wslot], -1), -1)
    hops = jnp.zeros((B,), I32)
    for _ in range(cfg.max_chain):
        live = (cur >= 0) & ~found
        blk = jnp.maximum(cur, 0)
        hops = hops + live.astype(I32)
        bk = t.okeys[blk]                               # (B,bs,KL)
        bv = t.ovals[blk]
        bbits = (t.otok[blk][:, None] >> jnp.arange(bs, dtype=U8)) & U8(1)
        bmatch = (bbits == 1) & jnp.all(bk == keys[:, None, :], -1) & live[:, None]
        bfound = jnp.any(bmatch, -1)
        bslot = jnp.argmax(bmatch, -1)
        bvals = jnp.take_along_axis(bv, bslot[:, None, None], 1)[:, 0]
        vals_out = jnp.where(bfound[:, None], bvals, vals_out)
        where = jnp.where(bfound[:, None],
                          jnp.stack([jnp.ones_like(blk), blk, bslot], -1), where)
        found = found | bfound
        cur = jnp.where(live & ~bfound, t.onext[blk], -1)
    return LookupResult(found, vals_out, where, 1 + hops)


def lookup_plan(cfg: PFarmConfig, t: PFarmTable, keys, res: LookupResult):
    """Verb plan of a lookup batch: one hopscotch-window READ (the whole
    contiguous H-bucket neighbourhood) plus CHAINED dependent block READs —
    each overflow hop needs the previous block's next-pointer, so hop k is
    a depth-k verb: an extra full round trip per hop, the chain-walk cost
    the paper charges P-FaRM-KV."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    home = _home(cfg, keys)
    bucket_stride = cfg.bucket_slots * SLOT_BYTES + 8      # slots + token
    lanes = [(rv.READ, rv.REGION_TABLE, home * bucket_stride,
              cfg.window_bytes, 0, False)]
    cur = t.head[home]
    for k in range(1, cfg.max_chain + 1):
        blk = jnp.maximum(cur, 0)
        act = k < res.reads
        lanes.append((jnp.where(act, rv.READ, rv.NOOP), rv.REGION_EXT,
                      blk * cfg.block_bytes, cfg.block_bytes, k, False))
        cur = t.onext[blk]
    return rv.pack(keys.shape[0], lanes)

def version_read_plan(cfg: PFarmConfig, t: PFarmTable, keys):
    """Verb plan pricing one stamp-validation batch.  P-FaRM-KV stamps are
    value-based (no cheap version word), so validation costs the full
    window-plus-chain lookup plan (unified ``(cfg, table, keys)`` shape)."""
    return lookup_plan(cfg, t, keys, lookup(cfg, t, keys))


def scan_plan(cfg: PFarmConfig, t: PFarmTable, keys, spans):
    """Verb plan of a YCSB-E short-scan batch: FaRM-KV's hopscotch layout
    scatters adjacent records over unrelated windows, so a span-record
    scan is one whole-window READ per record — and every record whose
    window overflowed adds a chained dependent block READ (depth 1),
    modelled here for the records past the first window's capacity.
    The most expensive scan of the three remote schemes: span wide
    window fetches where continuity posts one contiguous verb."""
    import numpy as np
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    spans = np.maximum(np.asarray(spans, np.int32).reshape(-1), 1)
    M = int(spans.max())
    home = _home(cfg, keys).astype(jnp.int32)
    bucket_stride = cfg.bucket_slots * SLOT_BYTES + 8      # slots + token
    lanes = []
    for j in range(M):
        act = j < spans
        off = ((home + j * 5 + 1) % cfg.num_buckets) * bucket_stride
        lanes.append((jnp.where(act, rv.READ, rv.NOOP), rv.REGION_TABLE,
                      off, cfg.window_bytes, 0, False))
        # records past the window's neighbourhood walk a chain hop
        if j + 1 > cfg.window:
            lanes.append((jnp.where(act, rv.READ, rv.NOOP), rv.REGION_EXT,
                          (off // bucket_stride % max(1, cfg.pool_blocks))
                          * cfg.block_bytes, cfg.block_bytes, 1, False))
    return rv.pack(keys.shape[0], lanes)


# -- server-side ops ---------------------------------------------------------

def _insert_one(cfg, t: PFarmTable, key, val, active):
    bs, H = cfg.bucket_slots, cfg.window
    home = _home(cfg, key[None])[0]
    win = _window_ids(cfg, home[None])[0]              # (H,)
    toks = t.tok[win]
    bits = (toks[:, None] >> jnp.arange(bs, dtype=U8)) & U8(1)
    empty = bits == 0                                  # (H,bs)
    has = jnp.any(empty, -1)
    bsel = jnp.argmax(has)
    ok_plain = jnp.any(has) & active
    bucket = win[bsel]
    slot = jnp.argmax(empty[bsel])

    def plain(t):
        tok = t.tok[bucket]
        t2 = t._replace(
            keys=t.keys.at[bucket, slot].set(key),
            vals=t.vals.at[bucket, slot].set(val),
            tok=t.tok.at[bucket].set(tok | (U8(1) << slot.astype(U8))))
        return t2, jnp.ones((), jnp.bool_)

    def displace_or_chain(t):
        # ONE displacement attempt: window slot whose item can legally move
        # to a free slot in ITS OWN window frees space for the new key.
        wkeys = t.keys[win].reshape(H * bs, KEY_LANES)
        whome = _home(cfg, wkeys)                      # (H*bs,)
        wwin = _window_ids(cfg, whome)                 # (H*bs, H)
        wbits = (t.tok[wwin][..., None] >> jnp.arange(bs, dtype=U8)) & U8(1)
        wempty = (wbits == 0).reshape(H * bs, H * bs)
        can_move = jnp.any(wempty, -1)
        msel = jnp.argmax(can_move)
        movable = jnp.any(can_move) & active
        src_b, src_s = win[msel // bs], msel % bs
        dflat = jnp.argmax(wempty[msel])
        dst_b = wwin[msel, dflat // bs]
        dst_s = dflat % bs

        def do_move(t):
            mk, mv = t.keys[src_b, src_s], t.vals[src_b, src_s]
            t2 = t._replace(
                keys=t.keys.at[dst_b, dst_s].set(mk),
                vals=t.vals.at[dst_b, dst_s].set(mv))
            t2 = t2._replace(tok=t2.tok.at[dst_b].set(
                t2.tok[dst_b] | (U8(1) << dst_s.astype(U8))))
            t2 = t2._replace(tok=t2.tok.at[src_b].set(
                t2.tok[src_b] & ~(U8(1) << src_s.astype(U8))))
            t2 = t2._replace(
                keys=t2.keys.at[src_b, src_s].set(key),
                vals=t2.vals.at[src_b, src_s].set(val))
            t2 = t2._replace(tok=t2.tok.at[src_b].set(
                t2.tok[src_b] | (U8(1) << src_s.astype(U8))))
            return t2, jnp.ones((), jnp.bool_)

        def do_chain(t):
            # append to head block if it has space, else allocate a new block
            head = t.head[home]
            hblk = jnp.maximum(head, 0)
            hbits = (t.otok[hblk] >> jnp.arange(bs, dtype=U8)) & U8(1)  # (bs,)
            head_has = (head >= 0) & jnp.any(hbits == 0)
            hslot = jnp.argmax(hbits == 0)
            can_alloc = t.ocount < cfg.pool_blocks
            blk = jnp.where(head_has, hblk, t.ocount)
            slot2 = jnp.where(head_has, hslot, 0)
            ok = (head_has | can_alloc) & active
            drop = jnp.iinfo(I32).max
            wblk = jnp.where(ok, blk, drop)
            t2 = t._replace(
                okeys=t.okeys.at[wblk, slot2].set(key, mode="drop"),
                ovals=t.ovals.at[wblk, slot2].set(val, mode="drop"),
                otok=t.otok.at[wblk].set(
                    t.otok[blk] | (U8(1) << slot2.astype(U8)), mode="drop"))
            fresh = ok & ~head_has
            t2 = t2._replace(
                onext=t2.onext.at[jnp.where(fresh, blk, drop)].set(head, mode="drop"),
                head=t2.head.at[jnp.where(fresh, home, drop)].set(blk, mode="drop"),
                ocount=t2.ocount + fresh.astype(I32))
            return t2, ok

        return jax.lax.cond(movable, do_move, do_chain, t)

    t2, ok = jax.lax.cond(ok_plain, plain, displace_or_chain, t)
    pm = jnp.where(ok, PM_WRITES_PER_OP, 0).astype(I32)
    return t2._replace(count=t2.count + ok.astype(I32)), ok, pm


def _delete_one(cfg, t: PFarmTable, key, active):
    res = lookup(cfg, t, key[None])
    ok = res.found[0] & active
    in_chain, where, slot = res.where[0, 0], res.where[0, 1], res.where[0, 2]
    drop = jnp.iinfo(I32).max
    mb = jnp.where(ok & (in_chain == 0), where, drop)
    ob = jnp.where(ok & (in_chain == 1), where, drop)
    bit = U8(1) << jnp.maximum(slot, 0).astype(U8)
    t2 = t._replace(
        tok=t.tok.at[mb].set(t.tok[jnp.maximum(where, 0)] & ~bit, mode="drop"),
        otok=t.otok.at[ob].set(t.otok[jnp.maximum(where, 0)] & ~bit, mode="drop"))
    pm = jnp.where(ok, PM_WRITES_PER_OP, 0).astype(I32)
    return t2._replace(count=t2.count - ok.astype(I32)), ok, pm


def _update_one(cfg, t: PFarmTable, key, val, active):
    res = lookup(cfg, t, key[None])
    ok = res.found[0] & active
    in_chain, where, slot = res.where[0, 0], res.where[0, 1], res.where[0, 2]
    drop = jnp.iinfo(I32).max
    mb = jnp.where(ok & (in_chain == 0), where, drop)
    ob = jnp.where(ok & (in_chain == 1), where, drop)
    slot0 = jnp.maximum(slot, 0)
    # logged in-place update (undo log makes the multi-store atomic)
    t2 = t._replace(
        vals=t.vals.at[mb, slot0].set(val, mode="drop"),
        ovals=t.ovals.at[ob, slot0].set(val, mode="drop"))
    pm = jnp.where(ok, PM_WRITES_PER_OP, 0).astype(I32)
    return t2, ok, pm


def _scan(cfg, fn):
    def step(carry, kv):
        t, ctr = carry
        *args, active = kv
        t, ok, pm = fn(cfg, t, *args, active)
        # masked-off ops count neither writes nor the ops denominator
        return (t, ctr.add(pm_writes=pm, ops=jnp.where(active, 1, 0))), ok
    return step


def _active(keys, mask):
    B = keys.shape[0]
    return (jnp.ones((B,), jnp.bool_) if mask is None
            else jnp.asarray(mask).reshape(B).astype(jnp.bool_))


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg, t, keys, vals, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _insert_one), (t, pmem.CostLedger.zero()),
        (keys, vals, _active(keys, mask)))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def delete(cfg, t, keys, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _delete_one), (t, pmem.CostLedger.zero()),
        (keys, _active(keys, mask)))
    return t, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def update(cfg, t, keys, vals, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (t, ctr), ok = jax.lax.scan(
        _scan(cfg, _update_one), (t, pmem.CostLedger.zero()),
        (keys, vals, _active(keys, mask)))
    return t, ok, ctr

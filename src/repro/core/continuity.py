"""Continuity hashing (Liu, Hua, Bai — CS.DC 2021) as a functional JAX data structure.

Structure (paper §III-A), defaults ``bucket_slots=4, sbuckets=3``::

      slot ids within one segment-pair row (SLOTS = 20):
      [ B_even: 0..3 | shared SBuckets: 4..15 | B_odd: 16..19 ]   + ext: 20..31

  * segment(even) = slots [0, 16)   — home bucket + shared region
  * segment(odd)  = slots [4, 20)   — shared region + home bucket
  * the two segments of a pair overlap on the SBuckets — exactly the paper's
    layout, flattened so that one row = one contiguous memory region and a
    segment fetch is ONE contiguous read (the RDMA-friendliness property).
  * a 32-bit ``indicator`` word per pair holds one valid-bit per slot
    (20 main + 12 extension bits — the paper's Fig. 3), committed with a
    single atomic store AFTER the slot payload: log-free failure atomicity.

Probe order (paper §III-C): even homes scan left->right (bucket, then
SBuckets); odd homes scan right->left (bucket, then SBuckets in reverse);
extension slots come last for both parities.

All operations are pure functions ``(table, ...) -> (table, result, counters)``
and jit-compile with the config static. Server-side mutation batches are
applied with ``lax.scan`` in batch order — the deterministic TPU analogue of
the paper's per-slot spin-locks (lock-acquisition order == batch order).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pmem
from repro.core.hashfn import hash128

U32 = jnp.uint32
I32 = jnp.int32

KEY_LANES = 4   # 16-byte keys (paper: 16 B)
VAL_LANES = 4   # 16-byte value slots (paper: values <= 15 B + metadata byte)
SLOT_BYTES = (KEY_LANES + VAL_LANES) * 4
INDICATOR_BYTES = 8  # stored/committed as one 8-byte atomic unit


@dataclasses.dataclass(frozen=True)
class ContinuityConfig:
    """Static geometry of a continuity hash table."""

    num_buckets: int                 # N numbered buckets (must be even)
    bucket_slots: int = 4            # slots per bucket (paper: 4)
    sbuckets: int = 3                # shared SBuckets per pair (paper: 3)
    ext_frac: float = 1.0 / 10.0     # max fraction of pairs with added SBuckets
    ext_groups: int = 1              # added SBucket groups per extended pair

    def __post_init__(self):
        assert self.num_buckets >= 2 and self.num_buckets % 2 == 0
        assert self.total_bits <= 32, (
            f"indicator must fit one atomic word: {self.total_bits} bits")

    # -- derived geometry ---------------------------------------------------
    @property
    def num_pairs(self) -> int:
        return self.num_buckets // 2

    @property
    def slots_per_pair(self) -> int:          # main row width
        return (2 + self.sbuckets) * self.bucket_slots

    @property
    def seg_slots(self) -> int:               # slots per segment
        return (1 + self.sbuckets) * self.bucket_slots

    @property
    def ext_slots(self) -> int:               # slots per extension group
        return self.sbuckets * self.bucket_slots * self.ext_groups

    @property
    def total_bits(self) -> int:
        return self.slots_per_pair + self.ext_slots

    @property
    def ext_pool_pairs(self) -> int:
        return max(1, int(np.ceil(self.num_pairs * self.ext_frac)))

    @property
    def n_cand(self) -> int:
        return self.seg_slots + self.ext_slots

    @property
    def segment_bytes(self) -> int:
        """Payload of one one-sided segment fetch (indicator + segment slots)."""
        return INDICATOR_BYTES + self.seg_slots * SLOT_BYTES

    @property
    def ext_bytes(self) -> int:
        return self.ext_slots * SLOT_BYTES

    def grow(self, factor: int = 2) -> "ContinuityConfig":
        return dataclasses.replace(self, num_buckets=self.num_buckets * factor)


@functools.lru_cache(maxsize=None)
def _probe_order(cfg: ContinuityConfig) -> np.ndarray:
    """(2, n_cand) int32: slot ids in probe-priority order per home parity."""
    bs, sp, seg = cfg.bucket_slots, cfg.slots_per_pair, cfg.seg_slots
    even = list(range(0, seg))                       # B_even then SBuckets, L->R
    odd = list(range(sp - 1, bs - 1, -1))            # B_odd then SBuckets, R->L
    ext = list(range(sp, sp + cfg.ext_slots))        # extension last, both
    return np.asarray([even + ext, odd + ext], dtype=np.int32)


class ContinuityTable(NamedTuple):
    """Functional table state. All arrays; geometry travels separately."""

    keys: jnp.ndarray        # (P, SLOTS, KEY_LANES) uint32
    vals: jnp.ndarray        # (P, SLOTS, VAL_LANES) uint32
    indicator: jnp.ndarray   # (P,) uint32 — one valid bit per slot (+ext bits)
    ext_keys: jnp.ndarray    # (PE, EXT_SLOTS, KEY_LANES) uint32
    ext_vals: jnp.ndarray    # (PE, EXT_SLOTS, VAL_LANES) uint32
    ext_map: jnp.ndarray     # (P,) int32 — pair -> ext group index, -1 = none
    ext_count: jnp.ndarray   # () int32 — allocated extension groups
    count: jnp.ndarray       # () int32 — live items


def create(cfg: ContinuityConfig) -> ContinuityTable:
    P, S, E, PE = cfg.num_pairs, cfg.slots_per_pair, cfg.ext_slots, cfg.ext_pool_pairs
    return ContinuityTable(
        keys=jnp.zeros((P, S, KEY_LANES), U32),
        vals=jnp.zeros((P, S, VAL_LANES), U32),
        indicator=jnp.zeros((P,), U32),
        ext_keys=jnp.zeros((PE, E, KEY_LANES), U32),
        ext_vals=jnp.zeros((PE, E, VAL_LANES), U32),
        ext_map=jnp.full((P,), -1, I32),
        ext_count=jnp.zeros((), I32),
        count=jnp.zeros((), I32),
    )


def capacity(cfg: ContinuityConfig, table: ContinuityTable) -> jnp.ndarray:
    """Total allocated storage units (paper's load-factor denominator)."""
    return (cfg.num_pairs * cfg.slots_per_pair
            + table.ext_count * cfg.ext_slots).astype(jnp.float32)


def load_factor(cfg: ContinuityConfig, table: ContinuityTable) -> jnp.ndarray:
    return table.count.astype(jnp.float32) / capacity(cfg, table)


def locate(cfg: ContinuityConfig, keys: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. (1): home bucket number -> (pair index, parity)."""
    h = hash128(keys)
    bno = h % U32(cfg.num_buckets)
    return (bno >> U32(1)).astype(I32), (bno & U32(1)).astype(I32)


# ---------------------------------------------------------------------------
# candidate gathering — the "one contiguous segment fetch" primitive
# ---------------------------------------------------------------------------

def _gather_candidates(cfg: ContinuityConfig, table: ContinuityTable,
                       pair: jnp.ndarray, parity: jnp.ndarray,
                       ext_allowed: jnp.ndarray):
    """Fetch each key's candidate slots in probe order.

    Returns (cand_ids, cand_keys, cand_vals, valid, empty_ok, is_ext, has_ext):
      cand_ids  (B, C) int32   slot ids (>= SLOTS means extension slot)
      cand_keys (B, C, KL)     key lanes per candidate
      cand_vals (B, C, VL)
      valid     (B, C) bool    indicator bit set AND slot addressable
      slot_ok   (B, C) bool    slot addressable (main always; ext iff allowed)
    """
    probe = jnp.asarray(_probe_order(cfg))           # (2, C)
    cand = probe[parity]                             # (B, C)
    S = cfg.slots_per_pair
    is_ext = cand >= S

    ind = table.indicator[pair]                      # (B,)
    bits = (ind[:, None] >> cand.astype(U32)) & U32(1)

    main_ids = jnp.minimum(cand, S - 1)
    mkeys = table.keys[pair[:, None], main_ids]      # (B, C, KL)
    mvals = table.vals[pair[:, None], main_ids]

    eidx = table.ext_map[pair]                       # (B,)
    has_ext = eidx >= 0
    safe_e = jnp.maximum(eidx, 0)
    ext_ids = jnp.maximum(cand - S, 0)
    ekeys = table.ext_keys[safe_e[:, None], ext_ids]
    evals = table.ext_vals[safe_e[:, None], ext_ids]

    cand_keys = jnp.where(is_ext[..., None], ekeys, mkeys)
    cand_vals = jnp.where(is_ext[..., None], evals, mvals)

    slot_ok = jnp.where(is_ext, (has_ext | ext_allowed)[:, None], True)
    valid = (bits == 1) & slot_ok & jnp.where(is_ext, has_ext[:, None], True)
    return cand, cand_keys, cand_vals, valid, slot_ok, is_ext, has_ext


# ---------------------------------------------------------------------------
# client read path — single one-sided fetch (paper §III-B)
# ---------------------------------------------------------------------------

class LookupResult(NamedTuple):
    found: jnp.ndarray   # (B,) bool
    values: jnp.ndarray  # (B, VAL_LANES) uint32
    slot: jnp.ndarray    # (B,) int32 — matched slot id (or -1)
    pair: jnp.ndarray    # (B,) int32
    reads: jnp.ndarray   # (B,) int32 — contiguous fetches this lookup needed


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: ContinuityConfig, table: ContinuityTable,
           keys: jnp.ndarray) -> LookupResult:
    """Batched client read: ONE contiguous segment fetch per key (+1 iff the
    pair has added SBuckets and the main segment missed)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, parity = locate(cfg, keys)
    f = jnp.zeros((keys.shape[0],), jnp.bool_)
    cand, ckeys, cvals, valid, _, is_ext, has_ext = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=f)
    match = valid & jnp.all(ckeys == keys[:, None, :], axis=-1)
    found = jnp.any(match, axis=-1)
    first = jnp.argmax(match, axis=-1)                       # probe-priority
    slot = jnp.where(found, jnp.take_along_axis(cand, first[:, None], 1)[:, 0], -1)
    values = jnp.take_along_axis(cvals, first[:, None, None], 1)[:, 0]
    values = jnp.where(found[:, None], values, 0)
    found_main = jnp.any(match & ~is_ext, axis=-1)
    reads = 1 + (has_ext & ~found_main).astype(I32)
    return LookupResult(found, values, slot, pair, reads)


def read_counters(cfg: ContinuityConfig, res: LookupResult) -> pmem.PMCounters:
    """Client-side RDMA accounting for a lookup batch."""
    extra = jnp.sum(res.reads - 1)
    n = res.reads.shape[0]
    return pmem.PMCounters.zero().add(
        rdma_reads=jnp.sum(res.reads),
        bytes_fetched=n * cfg.segment_bytes + extra * cfg.ext_bytes,
        ops=n)


# ---------------------------------------------------------------------------
# server write path — log-free failure atomicity (paper §III-C)
# ---------------------------------------------------------------------------
# Each op is split into explicit phases so tests can crash between them:
#   phase 1: write slot payload (key+value)        — PM write #1
#   phase 2: commit indicator with ONE atomic store — PM write #2
# A crash after phase 1 leaves the bit clear -> the partial write is invisible.

def _scatter_payload(table: ContinuityTable, ok, pair, slot_id, ext_idx,
                     key, val, slots_per_pair) -> ContinuityTable:
    """Phase 1: payload store (dropped when not ok via OOB index)."""
    S = slots_per_pair
    is_ext = slot_id >= S
    m_pair = jnp.where(ok & ~is_ext, pair, jnp.iinfo(I32).max)
    m_slot = jnp.minimum(slot_id, S - 1)
    keys = table.keys.at[m_pair, m_slot].set(key, mode="drop")
    vals = table.vals.at[m_pair, m_slot].set(val, mode="drop")
    e_idx = jnp.where(ok & is_ext, ext_idx, jnp.iinfo(I32).max)
    e_slot = jnp.maximum(slot_id - S, 0)
    ekeys = table.ext_keys.at[e_idx, e_slot].set(key, mode="drop")
    evals = table.ext_vals.at[e_idx, e_slot].set(val, mode="drop")
    return table._replace(keys=keys, vals=vals, ext_keys=ekeys, ext_vals=evals)


def _commit_indicator(table: ContinuityTable, ok, pair, new_word) -> ContinuityTable:
    """Phase 2: ONE atomic word store commits the operation."""
    m_pair = jnp.where(ok, pair, jnp.iinfo(I32).max)
    return table._replace(indicator=table.indicator.at[m_pair].set(new_word, mode="drop"))


def _find_insert_slot(cfg, table, key):
    """Probe for the first empty candidate slot of ``key`` (paper's directional
    scan), allowing extension slots if allocated or allocatable."""
    key = key[None]
    pair, parity = locate(cfg, key)
    if cfg.ext_frac > 0:
        can_alloc = (table.ext_count < cfg.ext_pool_pairs)[None]
    else:
        can_alloc = jnp.zeros((1,), jnp.bool_)
    cand, _, _, valid, slot_ok, is_ext, has_ext = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=can_alloc)
    empty = (~valid) & slot_ok
    ok = jnp.any(empty, axis=-1)[0]
    first = jnp.argmax(empty, axis=-1)
    slot = jnp.take_along_axis(cand, first[:, None], 1)[0, 0]
    need_alloc = ok & (slot >= cfg.slots_per_pair) & ~has_ext[0]
    ext_idx = jnp.where(need_alloc, table.ext_count, jnp.maximum(table.ext_map[pair[0]], 0))
    return pair[0], slot, ok, need_alloc, ext_idx


def _insert_one(cfg, table: ContinuityTable, key, val):
    pair, slot, ok, need_alloc, ext_idx = _find_insert_slot(cfg, table, key)
    # extension allocation is metadata (rebuilt on recovery from ext_map scan)
    ext_map = table.ext_map.at[jnp.where(need_alloc, pair, jnp.iinfo(I32).max)].set(
        ext_idx, mode="drop")
    table = table._replace(ext_map=ext_map,
                           ext_count=table.ext_count + need_alloc.astype(I32))
    table = _scatter_payload(table, ok, pair, slot, ext_idx, key, val,
                             cfg.slots_per_pair)
    new_word = table.indicator[pair] | jnp.where(ok, U32(1) << slot.astype(U32), U32(0))
    table = _commit_indicator(table, ok, pair, new_word)
    return table._replace(count=table.count + ok.astype(I32)), ok


def _delete_one(cfg, table: ContinuityTable, key):
    res = lookup(cfg, table, key[None])
    ok, pair, slot = res.found[0], res.pair[0], res.slot[0]
    safe = jnp.maximum(slot, 0).astype(U32)
    new_word = table.indicator[pair] & ~jnp.where(ok, U32(1) << safe, U32(0))
    table = _commit_indicator(table, ok, pair, new_word)
    return table._replace(count=table.count - ok.astype(I32)), ok


def _update_one(cfg, table: ContinuityTable, key, val):
    """Out-of-place update: both bit-flips land in ONE atomic indicator store."""
    res = lookup(cfg, table, key[None])
    found, pair, old_slot = res.found[0], res.pair[0], res.slot[0]
    _, parity = locate(cfg, key[None])
    no = jnp.zeros((1,), jnp.bool_)
    cand, _, _, valid, slot_ok, _, _ = _gather_candidates(
        cfg, table, pair[None], parity, ext_allowed=no)
    empty = (~valid) & slot_ok
    has_empty = jnp.any(empty, axis=-1)[0]
    first = jnp.argmax(empty, axis=-1)
    new_slot = jnp.take_along_axis(cand, first[:, None], 1)[0, 0]
    ok = found & has_empty
    ext_idx = jnp.maximum(table.ext_map[pair], 0)
    table = _scatter_payload(table, ok, pair, new_slot, ext_idx, key, val,
                             cfg.slots_per_pair)
    flip = (U32(1) << jnp.maximum(old_slot, 0).astype(U32)) | (U32(1) << new_slot.astype(U32))
    new_word = table.indicator[pair] ^ jnp.where(ok, flip, U32(0))
    table = _commit_indicator(table, ok, pair, new_word)
    return table, ok


def _scan_op(cfg, one_fn, pm_per_op):
    def step(carry, kv):
        table, ctr = carry
        table, ok = one_fn(cfg, table, *kv)
        ctr = ctr.add(pm_writes=jnp.where(ok, pm_per_op, 0), ops=1)
        return (table, ctr), ok
    return step


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg: ContinuityConfig, table: ContinuityTable, keys, vals):
    """Server-side batched insert (batch-order deterministic). 2 PM writes/op."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _insert_one, 2), (table, pmem.PMCounters.zero()), (keys, vals))
    return table, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def delete(cfg: ContinuityConfig, table: ContinuityTable, keys):
    """Server-side batched delete. 1 PM write/op (indicator bit clear)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _delete_one, 1), (table, pmem.PMCounters.zero()), (keys,))
    return table, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def update(cfg: ContinuityConfig, table: ContinuityTable, keys, vals):
    """Server-side batched out-of-place update. 2 PM writes/op."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _update_one, 2), (table, pmem.PMCounters.zero()), (keys, vals))
    return table, ok, ctr


# ---------------------------------------------------------------------------
# parallel (conflict-resolved) insert — used by the serving page table, where
# a batch touches mostly-distinct pairs; duplicates past the first per pair
# are reported for retry (batch-order priority == lock order).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=0)
def insert_parallel(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                    mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    B = keys.shape[0]
    active = jnp.ones((B,), jnp.bool_) if mask is None else jnp.asarray(mask)
    pair, parity = locate(cfg, keys)
    # first active occurrence per pair wins; later ones retry next batch
    same = (pair[:, None] == pair[None, :]) & active[None, :]
    earlier = jnp.tril(jnp.ones((B, B), jnp.bool_), k=-1)
    dup = jnp.any(same & earlier, axis=-1)
    go = active & ~dup

    no = jnp.zeros((B,), jnp.bool_)
    cand, _, _, valid, slot_ok, _, _ = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=no)
    empty = (~valid) & slot_ok
    ok = go & jnp.any(empty, axis=-1)
    first = jnp.argmax(empty, axis=-1)
    slot = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
    ext_idx = jnp.maximum(table.ext_map[pair], 0)
    table = _scatter_payload(table, ok, pair, slot, ext_idx, keys, vals,
                             cfg.slots_per_pair)
    okbit = jnp.where(ok, U32(1) << slot.astype(U32), U32(0))
    word = table.indicator.at[jnp.where(ok, pair, jnp.iinfo(I32).max)].set(
        table.indicator[pair] | okbit, mode="drop")
    table = table._replace(indicator=word,
                           count=table.count + jnp.sum(ok).astype(I32))
    retry = active & ~ok
    return table, ok, retry


# ---------------------------------------------------------------------------
# resizing (paper §III-C "Log-free Resizing") + recovery
# ---------------------------------------------------------------------------

def extract_items(cfg: ContinuityConfig, table: ContinuityTable):
    """All live (key, value) slots as flat arrays + validity mask (jittable)."""
    P, S, E = cfg.num_pairs, cfg.slots_per_pair, cfg.ext_slots
    bits = (table.indicator[:, None] >> jnp.arange(S, dtype=U32)[None]) & U32(1)
    mkeys = table.keys.reshape(P * S, KEY_LANES)
    mvals = table.vals.reshape(P * S, VAL_LANES)
    mmask = (bits == 1).reshape(P * S)
    ebits = (table.indicator[:, None] >> (S + jnp.arange(E, dtype=U32))[None]) & U32(1)
    has = table.ext_map >= 0
    PE = cfg.ext_pool_pairs
    # scatter pair-order ext validity into pool order
    pool_mask = jnp.zeros((PE, E), jnp.bool_).at[
        jnp.where(has, table.ext_map, PE), :].set(
        (ebits == 1) & has[:, None], mode="drop")
    ekeys = table.ext_keys.reshape(PE * E, KEY_LANES)
    evals = table.ext_vals.reshape(PE * E, VAL_LANES)
    keys = jnp.concatenate([mkeys, ekeys], 0)
    vals = jnp.concatenate([mvals, evals], 0)
    mask = jnp.concatenate([mmask, pool_mask.reshape(PE * E)], 0)
    return keys, vals, mask


def resize(cfg: ContinuityConfig, table: ContinuityTable, factor: int = 2):
    """Rehash into a table with ``factor``x buckets (fast batched path).

    The crash-faithful per-item path (insert-to-new THEN delete-from-old, two
    indicator commits in that order) is ``resize_stepwise``; this batched path
    produces the same final state and is what production resizing uses.
    """
    new_cfg = cfg.grow(factor)
    new = create(new_cfg)
    keys, vals, mask = extract_items(cfg, table)

    def step(carry, kv):
        t, = carry
        k, v, m = kv
        def do(t):
            t2, _ = _insert_one(new_cfg, t, k, v)
            return t2
        t = jax.lax.cond(m, do, lambda t: t, t)
        return (t,), None

    (new,), _ = jax.lax.scan(step, (new,), (keys, vals, mask))
    return new_cfg, new


def resize_stepwise(cfg, table, new_cfg, new_table, max_items: int):
    """Move up to ``max_items`` live items old->new, one at a time, with the
    paper's ordering: insert into new, commit, then delete from old. Returns
    (old, new, moved). Used by crash-recovery tests (host loop)."""
    moved = 0
    for _ in range(max_items):
        keys, vals, mask = extract_items(cfg, table)
        idx = int(jnp.argmax(mask))
        if not bool(mask[idx]):
            break
        k, v = keys[idx], vals[idx]
        new_table, ok = _insert_one(new_cfg, new_table, k, v)
        table, _ = _delete_one(cfg, table, k)
        moved += int(ok)
    return table, new_table, moved


def recover(cfg, old_table, new_cfg, new_table):
    """Paper §III-C recovery: after restart mid-resize, for each item still in
    the old table, delete it if it already reached the new table, otherwise
    move it (insert-to-new then delete-from-old); finishes the resize."""
    keys, vals, mask = extract_items(cfg, old_table)
    kn, vn, mn = np.asarray(keys), np.asarray(vals), np.asarray(mask)
    for i in np.nonzero(mn)[0]:
        k = jnp.asarray(kn[i])
        v = jnp.asarray(vn[i])
        res = lookup(new_cfg, new_table, k[None])
        if not bool(res.found[0]):
            new_table, _ = _insert_one(new_cfg, new_table, k, v)
        old_table, _ = _delete_one(cfg, old_table, k)
    return old_table, new_table


def items_host(cfg, table):
    """Live items as a python dict {key_bytes: value_bytes} (tests only)."""
    keys, vals, mask = extract_items(cfg, table)
    kn, vn, mn = np.asarray(keys), np.asarray(vals), np.asarray(mask)
    out = {}
    for i in np.nonzero(mn)[0]:
        out[kn[i].tobytes()] = vn[i].tobytes()
    return out

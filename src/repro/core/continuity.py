"""Continuity hashing (Liu, Hua, Bai — CS.DC 2021) as a functional JAX data structure.

Structure (paper §III-A), defaults ``bucket_slots=4, sbuckets=3``::

      slot ids within one segment-pair row (SLOTS = 20):
      [ B_even: 0..3 | shared SBuckets: 4..15 | B_odd: 16..19 ]   + ext: 20..31

  * segment(even) = slots [0, 16)   — home bucket + shared region
  * segment(odd)  = slots [4, 20)   — shared region + home bucket
  * the two segments of a pair overlap on the SBuckets — exactly the paper's
    layout, flattened so that one row = one contiguous memory region and a
    segment fetch is ONE contiguous read (the RDMA-friendliness property).
  * a 32-bit ``indicator`` word per pair holds one valid-bit per slot
    (20 main + 12 extension bits — the paper's Fig. 3), committed with a
    single atomic store AFTER the slot payload: log-free failure atomicity.

Probe order (paper §III-C): even homes scan left->right (bucket, then
SBuckets); odd homes scan right->left (bucket, then SBuckets in reverse);
extension slots come last for both parities.

All operations are pure functions ``(table, ...) -> (table, result, counters)``
and jit-compile with the config static.

Server-side mutation batches run on the **wave-vectorized mutation engine**
(``insert`` / ``update`` / ``delete``): one stable packed sort by pair index
groups the batch into per-pair cohorts, a segment scan assigns each op its
intra-cohort rank, and ops of equal rank ("waves") touch pairwise-distinct
pairs — so a wave is one batched probe, one batched payload scatter
(phase 1) and one batched round of independent one-word indicator commits
(phase 2): the deterministic TPU analogue of the paper's per-slot
spin-locks, preserving lock-acquisition order == batch order and the
log-free crash-atomicity split.  Because insert-only occupancy grows
monotonically, ``insert`` executes ALL of its waves in one fused
rank-indexed bit-select pass over the indicator words (a residual wave
``while_loop`` exactly resolves the rare parity-contended cohorts);
``update``/``delete`` run their waves in a ``while_loop`` whose trip count
is max_collisions_per_pair.  Extension groups are granted by prefix sum in
batch order and the pool relabelled to serial allocation order, so the
engine produces tables byte-identical to the ``lax.scan`` reference paths
(``insert_serial`` / ``update_serial`` / ``delete_serial``, kept for
crash-recovery tests and as the equivalence oracle).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pmem
from repro.core.hashfn import hash128, hash128_2

U32 = jnp.uint32
I32 = jnp.int32

KEY_LANES = 4   # 16-byte keys (paper: 16 B)
VAL_LANES = 4   # 16-byte value slots (paper: values <= 15 B + metadata byte)
SLOT_BYTES = (KEY_LANES + VAL_LANES) * 4
INDICATOR_BYTES = 8  # stored/committed as one 8-byte atomic unit
FP_BYTES = 8         # fingerprint word, adjacent to the indicator (Dash-style)
FP_SLOT_BITS = 2     # fingerprint bits per main slot
FP_MASK = (1 << FP_SLOT_BITS) - 1
_FPW = 32 // FP_SLOT_BITS            # fp fields per 32-bit lane
STASH_CNT_SHIFT = 24                 # per-pair stash count byte (fp lane 1)
STASH_META_BYTES = 8                 # per-stash-entry meta word (atomic commit)


@dataclasses.dataclass(frozen=True)
class ContinuityConfig:
    """Static geometry of a continuity hash table."""

    num_buckets: int                 # N numbered buckets (must be even)
    bucket_slots: int = 4            # slots per bucket (paper: 4)
    sbuckets: int = 3                # shared SBuckets per pair (paper: 3)
    ext_frac: float = 1.0 / 10.0     # max fraction of pairs with added SBuckets
    ext_groups: int = 1              # added SBucket groups per extended pair
    stash_frac: float = 0.0          # stash slots as a fraction of main slots

    def __post_init__(self):
        assert self.num_buckets >= 2 and self.num_buckets % 2 == 0
        assert self.total_bits <= 32, (
            f"indicator must fit one atomic word: {self.total_bits} bits")
        # fp lane 1 keeps its top byte for the per-pair stash count, so main
        # slot fields must fit the remaining 56 bits of the fingerprint word
        assert self.slots_per_pair * FP_SLOT_BITS <= 64 - 8, (
            f"fingerprint fields overflow the fp word: {self.slots_per_pair}")

    # -- derived geometry ---------------------------------------------------
    @property
    def num_pairs(self) -> int:
        return self.num_buckets // 2

    @property
    def slots_per_pair(self) -> int:          # main row width
        return (2 + self.sbuckets) * self.bucket_slots

    @property
    def seg_slots(self) -> int:               # slots per segment
        return (1 + self.sbuckets) * self.bucket_slots

    @property
    def ext_slots(self) -> int:               # slots per extension group
        return self.sbuckets * self.bucket_slots * self.ext_groups

    @property
    def total_bits(self) -> int:
        return self.slots_per_pair + self.ext_slots

    @property
    def ext_pool_pairs(self) -> int:
        return max(1, int(np.ceil(self.num_pairs * self.ext_frac)))

    @property
    def n_cand(self) -> int:
        return self.seg_slots + self.ext_slots

    @property
    def segment_bytes(self) -> int:
        """Payload of one one-sided segment fetch (indicator + fingerprint
        word + segment slots — the fp word rides in the segments' overlap)."""
        return INDICATOR_BYTES + FP_BYTES + self.seg_slots * SLOT_BYTES

    @property
    def row_bytes(self) -> int:
        """One full pair row: [B_even | indicator | fp | SBuckets | B_odd]."""
        return INDICATOR_BYTES + FP_BYTES + self.slots_per_pair * SLOT_BYTES

    @property
    def ext_bytes(self) -> int:
        return self.ext_slots * SLOT_BYTES

    @property
    def stash_slots(self) -> int:
        if self.stash_frac <= 0:
            return 0
        return max(1, int(np.ceil(
            self.num_pairs * self.slots_per_pair * self.stash_frac)))

    @property
    def stash_bytes(self) -> int:
        """The whole stash region (fetched as ONE contiguous READ)."""
        return self.stash_slots * (STASH_META_BYTES + SLOT_BYTES)

    def grow(self, factor: int = 2) -> "ContinuityConfig":
        return dataclasses.replace(self, num_buckets=self.num_buckets * factor)


@functools.lru_cache(maxsize=None)
def _probe_order(cfg: ContinuityConfig) -> np.ndarray:
    """(2, n_cand) int32: slot ids in probe-priority order per home parity."""
    bs, sp, seg = cfg.bucket_slots, cfg.slots_per_pair, cfg.seg_slots
    even = list(range(0, seg))                       # B_even then SBuckets, L->R
    odd = list(range(sp - 1, bs - 1, -1))            # B_odd then SBuckets, R->L
    ext = list(range(sp, sp + cfg.ext_slots))        # extension last, both
    return np.asarray([even + ext, odd + ext], dtype=np.int32)


class ContinuityTable(NamedTuple):
    """Functional table state. All arrays; geometry travels separately."""

    keys: jnp.ndarray        # (P, SLOTS, KEY_LANES) uint32
    vals: jnp.ndarray        # (P, SLOTS, VAL_LANES) uint32
    indicator: jnp.ndarray   # (P,) uint32 — one valid bit per slot (+ext bits)
    version: jnp.ndarray     # (P,) uint32 — per-pair committed-op counter; the
    #   upper half of the 8B atomic indicator word (total_bits <= 32 leaves it
    #   free), bumped by the SAME store that flips the bits.  A bare indicator
    #   word is ABA-prone (two updates can walk a key back to its slot); the
    #   counter makes (version << 32 | indicator) a safe client version stamp.
    ext_keys: jnp.ndarray    # (PE, EXT_SLOTS, KEY_LANES) uint32
    ext_vals: jnp.ndarray    # (PE, EXT_SLOTS, VAL_LANES) uint32
    ext_map: jnp.ndarray     # (P,) int32 — pair -> ext group index, -1 = none
    ext_count: jnp.ndarray   # () int32 — allocated extension groups
    count: jnp.ndarray       # () int32 — live items
    fp: jnp.ndarray          # (P, 2) uint32 — the 8B fingerprint word next to
    #   the indicator: FP_SLOT_BITS per main slot (lane s//16, field s%16) and
    #   the per-pair stash count in lane 1's top byte.  Pure probe metadata:
    #   uncommitted stores never make an item visible (the indicator bit does),
    #   so fp writes are not PM-write-counted and Table I is unchanged.
    stash_keys: jnp.ndarray  # (T, KEY_LANES) uint32 — shared overflow stash
    stash_vals: jnp.ndarray  # (T, VAL_LANES) uint32
    stash_meta: jnp.ndarray  # (T,) uint32 — home pair + 1; 0 = free.  The 8B
    #   atomic commit word of a stash entry (payload first, meta second).


def create(cfg: ContinuityConfig) -> ContinuityTable:
    P, S, E, PE = cfg.num_pairs, cfg.slots_per_pair, cfg.ext_slots, cfg.ext_pool_pairs
    T = max(cfg.stash_slots, 1)
    return ContinuityTable(
        keys=jnp.zeros((P, S, KEY_LANES), U32),
        vals=jnp.zeros((P, S, VAL_LANES), U32),
        indicator=jnp.zeros((P,), U32),
        version=jnp.zeros((P,), U32),
        ext_keys=jnp.zeros((PE, E, KEY_LANES), U32),
        ext_vals=jnp.zeros((PE, E, VAL_LANES), U32),
        ext_map=jnp.full((P,), -1, I32),
        ext_count=jnp.zeros((), I32),
        count=jnp.zeros((), I32),
        fp=jnp.zeros((P, 2), U32),
        stash_keys=jnp.zeros((T, KEY_LANES), U32),
        stash_vals=jnp.zeros((T, VAL_LANES), U32),
        stash_meta=jnp.zeros((T,), U32),
    )


def capacity(cfg: ContinuityConfig, table: ContinuityTable) -> jnp.ndarray:
    """Total allocated storage units (paper's load-factor denominator)."""
    return (cfg.num_pairs * cfg.slots_per_pair + cfg.stash_slots
            + table.ext_count * cfg.ext_slots).astype(jnp.float32)


def load_factor(cfg: ContinuityConfig, table: ContinuityTable) -> jnp.ndarray:
    return table.count.astype(jnp.float32) / capacity(cfg, table)


def locate(cfg: ContinuityConfig, keys: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. (1): home bucket number -> (pair index, parity)."""
    h = hash128(keys)
    bno = h % U32(cfg.num_buckets)
    return (bno >> U32(1)).astype(I32), (bno & U32(1)).astype(I32)


def fingerprint(keys: jnp.ndarray) -> jnp.ndarray:
    """(B,) uint32 slot fingerprint from the second hash function (so it is
    independent of the bucket number, which the first hash determines)."""
    return hash128_2(jnp.asarray(keys, U32).reshape(-1, KEY_LANES)) & U32(FP_MASK)


def stash_count(table: ContinuityTable, pair: jnp.ndarray) -> jnp.ndarray:
    """Per-pair stash occupancy byte (fp lane 1, top byte).  May briefly read
    HIGH of the true count (insert bumps it before the meta commit, delete
    decrements after) — a conservative overcount only ever costs an extra
    stash READ, never a missed item."""
    return (table.fp[pair, 1] >> U32(STASH_CNT_SHIFT)) & U32(0xFF)


def _fp_store(table: ContinuityTable, ok, pair, slot, fpv) -> ContinuityTable:
    """Set the fp field of (pair, slot) — main slots only; callers mask.
    Active lanes must touch distinct (pair, slot); the read-modify-write
    models the server's 4-byte fp-lane store (uncounted metadata)."""
    w = jnp.where(ok, slot // _FPW, 0)
    sh = (U32(FP_SLOT_BITS) * (slot % _FPW).astype(U32))
    old = table.fp[pair, w]
    new = (old & ~(U32(FP_MASK) << sh)) | ((fpv & U32(FP_MASK)) << sh)
    drop = jnp.iinfo(I32).max
    return table._replace(
        fp=table.fp.at[jnp.where(ok, pair, drop), w].set(new, mode="drop"))


# ---------------------------------------------------------------------------
# candidate gathering — the "one contiguous segment fetch" primitive
# ---------------------------------------------------------------------------

def _gather_candidates(cfg: ContinuityConfig, table: ContinuityTable,
                       pair: jnp.ndarray, parity: jnp.ndarray,
                       ext_allowed: jnp.ndarray):
    """Fetch each key's candidate slots in probe order.

    Returns (cand_ids, cand_keys, cand_vals, valid, empty_ok, is_ext, has_ext):
      cand_ids  (B, C) int32   slot ids (>= SLOTS means extension slot)
      cand_keys (B, C, KL)     key lanes per candidate
      cand_vals (B, C, VL)
      valid     (B, C) bool    indicator bit set AND slot addressable
      slot_ok   (B, C) bool    slot addressable (main always; ext iff allowed)
    """
    probe = jnp.asarray(_probe_order(cfg))           # (2, C)
    cand = probe[parity]                             # (B, C)
    S = cfg.slots_per_pair
    is_ext = cand >= S

    ind = table.indicator[pair]                      # (B,)
    bits = (ind[:, None] >> cand.astype(U32)) & U32(1)

    main_ids = jnp.minimum(cand, S - 1)
    mkeys = table.keys[pair[:, None], main_ids]      # (B, C, KL)
    mvals = table.vals[pair[:, None], main_ids]

    eidx = table.ext_map[pair]                       # (B,)
    has_ext = eidx >= 0
    safe_e = jnp.maximum(eidx, 0)
    ext_ids = jnp.maximum(cand - S, 0)
    ekeys = table.ext_keys[safe_e[:, None], ext_ids]
    evals = table.ext_vals[safe_e[:, None], ext_ids]

    cand_keys = jnp.where(is_ext[..., None], ekeys, mkeys)
    cand_vals = jnp.where(is_ext[..., None], evals, mvals)

    slot_ok = jnp.where(is_ext, (has_ext | ext_allowed)[:, None], True)
    valid = (bits == 1) & slot_ok & jnp.where(is_ext, has_ext[:, None], True)
    return cand, cand_keys, cand_vals, valid, slot_ok, is_ext, has_ext


# ---------------------------------------------------------------------------
# client read path — single one-sided fetch (paper §III-B)
# ---------------------------------------------------------------------------

class LookupResult(NamedTuple):
    found: jnp.ndarray   # (B,) bool
    values: jnp.ndarray  # (B, VAL_LANES) uint32
    slot: jnp.ndarray    # (B,) int32 — matched slot id (or -1); stash hits
    #   report cfg.total_bits + stash_index
    pair: jnp.ndarray    # (B,) int32
    reads: jnp.ndarray   # (B,) int32 — contiguous fetches this lookup needed


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: ContinuityConfig, table: ContinuityTable,
           keys: jnp.ndarray) -> LookupResult:
    """Batched client read: ONE contiguous segment fetch per key (+1 iff the
    pair has added SBuckets and the main segment missed, +1 iff the pair's
    stash count byte is non-zero and both main and extension missed)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, parity = locate(cfg, keys)
    f = jnp.zeros((keys.shape[0],), jnp.bool_)
    cand, ckeys, cvals, valid, _, is_ext, has_ext = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=f)
    match = valid & jnp.all(ckeys == keys[:, None, :], axis=-1)
    found = jnp.any(match, axis=-1)
    first = jnp.argmax(match, axis=-1)                       # probe-priority
    slot = jnp.where(found, jnp.take_along_axis(cand, first[:, None], 1)[:, 0], -1)
    values = jnp.take_along_axis(cvals, first[:, None, None], 1)[:, 0]
    values = jnp.where(found[:, None], values, 0)
    found_main = jnp.any(match & ~is_ext, axis=-1)
    found_me = found                          # matched in main or extension
    reads = 1 + (has_ext & ~found_main).astype(I32)
    if cfg.stash_slots:
        # stash probe: the whole region arrives in one contiguous READ, so
        # the scan is free once the fetch is paid; probe priority stays
        # main > extension > stash (commits clear the stash entry LAST)
        home = pair.astype(U32) + U32(1)
        smatch = (table.stash_meta[None, :] == home[:, None]) & jnp.all(
            table.stash_keys[None, :, :] == keys[:, None, :], axis=-1)
        sfound = jnp.any(smatch, axis=-1) & ~found
        sfirst = jnp.argmax(smatch, axis=-1).astype(I32)
        values = jnp.where(sfound[:, None], table.stash_vals[sfirst], values)
        slot = jnp.where(sfound, cfg.total_bits + sfirst, slot)
        found = found | sfound
        reads = reads + ((stash_count(table, pair) > 0) & ~found_me).astype(I32)
    return LookupResult(found, values, slot, pair, reads)


def lookup_plan(cfg: ContinuityConfig, table: ContinuityTable, keys,
                res: LookupResult):
    """Verb plan of a lookup batch (paper §III-B): ONE contiguous segment
    READ per key — home bucket + neighbouring SBuckets in a single
    one-sided fetch, misses included — plus one DEPENDENT extension-group
    READ iff the pair has added SBuckets and the main segment missed, and
    one dependent stash-region READ iff the pair's stash count byte (read
    for free inside the fp word of the first fetch) is non-zero and both
    prior fetches missed.  The `CostLedger` every caller sees is derived
    from this plan (`repro.rdma.verbs.ledger_from_plan`)."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, parity = locate(cfg, keys)
    # modeled row layout: [B_even | indicator | fp | SBuckets | B_odd] — the
    # indicator and fingerprint words sit in the two segments' OVERLAP, so
    # BOTH parities' fetches are genuinely contiguous ranges that include
    # them: even = [row, row + segment_bytes), odd = [row +
    # bucket_slots*SLOT_BYTES, row_end); a replay against a linear memory
    # image stays valid
    row_bytes = cfg.row_bytes
    seg_off = pair * row_bytes + parity * (cfg.bucket_slots * SLOT_BYTES)
    found_main = res.found & (res.slot >= 0) & (res.slot < cfg.slots_per_pair)
    ext = (table.ext_map[pair] >= 0) & ~found_main
    eidx = jnp.maximum(table.ext_map[pair], 0)
    lanes = [
        (rv.READ, rv.REGION_TABLE, seg_off, cfg.segment_bytes, 0, False),
        (jnp.where(ext, rv.READ, rv.NOOP), rv.REGION_EXT,
         eidx * cfg.ext_bytes, cfg.ext_bytes, 1, False),
    ]
    if cfg.stash_slots:
        found_me = res.found & (res.slot >= 0) & (res.slot < cfg.total_bits)
        srd = (stash_count(table, pair) > 0) & ~found_me
        lanes.append((jnp.where(srd, rv.READ, rv.NOOP), rv.REGION_STASH,
                      0, cfg.stash_bytes,
                      jnp.where(ext, 2, 1).astype(I32), False))
    return rv.pack(keys.shape[0], lanes)


def scan_plan(cfg: ContinuityConfig, table: ContinuityTable, keys, spans):
    """Verb plan of a YCSB-E short-scan batch: ONE contiguous multi-segment
    READ per scan, whatever the span.

    Continuity's SBuckets are CONTIGUOUS in PM — bucket pairs and their
    shared SBuckets lie in one linear row, rows adjacent — so scanning
    ``span`` records from the start key's row is a single one-sided READ
    of ``ceil(span / slots_per_pair)`` consecutive rows (indicator words
    ride along in the same range).  This is the access-pattern advantage
    YCSB-E exists to show: the multi-probe baselines pay one scattered
    READ per record, continuity pays one verb per scan."""
    from repro.rdma import verbs as rv
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    spans = jnp.maximum(jnp.asarray(spans, I32).reshape(-1), 1)
    pair, _ = locate(cfg, keys)
    row_bytes = cfg.row_bytes
    rows = -(-spans // cfg.slots_per_pair)          # ceil: rows crossed
    # clamp to the table's tail so the range stays a valid remote region
    start = jnp.minimum(pair, jnp.maximum(cfg.num_pairs - rows, 0))
    return rv.pack(keys.shape[0], [
        (rv.READ, rv.REGION_TABLE, start * row_bytes, rows * row_bytes,
         0, False)])


def version_stamp(cfg: ContinuityConfig, table: ContinuityTable, keys):
    """(B, 2) uint32 version stamp per key: ``[version, indicator]`` of the
    key's home pair — the two halves of the ONE 8-byte word every committed
    mutation atomically stores.  A client that caches a value together with
    this stamp can later validate the entry with a single 8-byte READ
    (`version_read_plan`): any committed insert/update/delete on the pair
    bumped ``version``, so stamp equality proves the cached value is the
    value a fresh lookup would return.  The counter half is what makes the
    check ABA-proof — indicator bits alone can walk back to a prior pattern
    (update a key twice and it returns to its original slot)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, _ = locate(cfg, keys)
    return jnp.stack([table.version[pair], table.indicator[pair]], axis=-1)


def version_read_plan(cfg: ContinuityConfig, table: ContinuityTable, keys):
    """Verb plan of a stamp validation batch: ONE depth-0 8-byte READ per key
    at the home pair's indicator-word offset.  This is the whole point of
    indicator-word validation: it costs `INDICATOR_BYTES` on the wire versus
    `segment_bytes` for a full lookup, with no server-side invalidation
    protocol at all.  (``table`` is unused — the plan depends only on the
    geometry — but rides along for the unified ``(cfg, table, keys)`` plan
    signature shared by every scheme module.)"""
    from repro.rdma import verbs as rv
    del table
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, _ = locate(cfg, keys)
    return rv.single_read_plan(keys.shape[0], rv.REGION_TABLE,
                               pair * cfg.row_bytes, INDICATOR_BYTES)


# ---------------------------------------------------------------------------
# server write path — log-free failure atomicity (paper §III-C)
# ---------------------------------------------------------------------------
# Each op is split into explicit phases so tests can crash between them:
#   phase 1: write slot payload (key+value)        — PM write #1
#   phase 2: commit indicator with ONE atomic store — PM write #2
# A crash after phase 1 leaves the bit clear -> the partial write is invisible.

def _scatter_payload(table: ContinuityTable, ok, pair, slot_id, ext_idx,
                     key, val, slots_per_pair) -> ContinuityTable:
    """Phase 1: payload store (dropped when not ok via OOB index)."""
    S = slots_per_pair
    is_ext = slot_id >= S
    m_pair = jnp.where(ok & ~is_ext, pair, jnp.iinfo(I32).max)
    m_slot = jnp.minimum(slot_id, S - 1)
    keys = table.keys.at[m_pair, m_slot].set(key, mode="drop")
    vals = table.vals.at[m_pair, m_slot].set(val, mode="drop")
    e_idx = jnp.where(ok & is_ext, ext_idx, jnp.iinfo(I32).max)
    e_slot = jnp.maximum(slot_id - S, 0)
    ekeys = table.ext_keys.at[e_idx, e_slot].set(key, mode="drop")
    evals = table.ext_vals.at[e_idx, e_slot].set(val, mode="drop")
    return table._replace(keys=keys, vals=vals, ext_keys=ekeys, ext_vals=evals)


def _commit_indicator(table: ContinuityTable, ok, pair, new_word) -> ContinuityTable:
    """Phase 2: ONE atomic word store commits the operation.

    The same 8-byte store carries the per-pair version counter in its upper
    half, so the bump costs zero extra PM writes (Table I unchanged)."""
    m_pair = jnp.where(ok, pair, jnp.iinfo(I32).max)
    return table._replace(
        indicator=table.indicator.at[m_pair].set(new_word, mode="drop"),
        version=table.version.at[m_pair].add(U32(1), mode="drop"))


def _find_insert_slot(cfg, table, key):
    """Probe for the first empty candidate slot of ``key`` (paper's directional
    scan), allowing extension slots if allocated or allocatable."""
    key = key[None]
    pair, parity = locate(cfg, key)
    if cfg.ext_frac > 0:
        can_alloc = (table.ext_count < cfg.ext_pool_pairs)[None]
    else:
        can_alloc = jnp.zeros((1,), jnp.bool_)
    cand, _, _, valid, slot_ok, is_ext, has_ext = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=can_alloc)
    empty = (~valid) & slot_ok
    ok = jnp.any(empty, axis=-1)[0]
    first = jnp.argmax(empty, axis=-1)
    slot = jnp.take_along_axis(cand, first[:, None], 1)[0, 0]
    need_alloc = ok & (slot >= cfg.slots_per_pair) & ~has_ext[0]
    ext_idx = jnp.where(need_alloc, table.ext_count, jnp.maximum(table.ext_map[pair[0]], 0))
    return pair[0], slot, ok, need_alloc, ext_idx


def _stash_insert_one(cfg, table: ContinuityTable, key, val, want):
    """Stash fallback of one insert (``want`` = probe failed, op active).

    Record order for crash atomicity: fp count bump (uncounted metadata,
    may overcount) -> payload store -> version bump -> meta word commit.
    The 8B meta word is the atomic commit point; a crash before it leaves
    the entry invisible.  3 counted PM writes."""
    pair, _ = locate(cfg, key[None])
    free = table.stash_meta == U32(0)
    sok = want & jnp.any(free)
    sidx = jnp.argmax(free).astype(I32)
    drop = jnp.iinfo(I32).max
    w = jnp.where(sok, sidx, drop)
    pw = jnp.where(sok, pair[0], drop)
    table = table._replace(
        fp=table.fp.at[pw, 1].add(U32(1) << U32(STASH_CNT_SHIFT), mode="drop"),
        stash_keys=table.stash_keys.at[w].set(key, mode="drop"),
        stash_vals=table.stash_vals.at[w].set(val, mode="drop"),
        version=table.version.at[pw].add(U32(1), mode="drop"),
        stash_meta=table.stash_meta.at[w].set(
            pair[0].astype(U32) + U32(1), mode="drop"),
        count=table.count + sok.astype(I32))
    return table, sok


def _insert_one(cfg, table: ContinuityTable, key, val, active=None):
    pair, slot, ok, need_alloc, ext_idx = _find_insert_slot(cfg, table, key)
    act = jnp.ones((), jnp.bool_) if active is None else active
    ok = ok & act
    need_alloc = need_alloc & act
    # extension allocation is metadata (rebuilt on recovery from ext_map scan)
    ext_map = table.ext_map.at[jnp.where(need_alloc, pair, jnp.iinfo(I32).max)].set(
        ext_idx, mode="drop")
    table = table._replace(ext_map=ext_map,
                           ext_count=table.ext_count + need_alloc.astype(I32))
    table = _scatter_payload(table, ok, pair, slot, ext_idx, key, val,
                             cfg.slots_per_pair)
    # fingerprint field of the NEW slot lands before the commit (main only)
    table = _fp_store(table, ok & (slot < cfg.slots_per_pair), pair, slot,
                      fingerprint(key[None])[0])
    new_word = table.indicator[pair] | jnp.where(ok, U32(1) << slot.astype(U32), U32(0))
    table = _commit_indicator(table, ok, pair, new_word)
    table = table._replace(count=table.count + ok.astype(I32))
    pm = jnp.where(ok, 2, 0).astype(I32)
    if cfg.stash_slots:
        table, sok = _stash_insert_one(cfg, table, key, val, act & ~ok)
        ok = ok | sok
        pm = pm + jnp.where(sok, 3, 0).astype(I32)
    return table, ok, pm


def _delete_one(cfg, table: ContinuityTable, key, active=None):
    res = lookup(cfg, table, key[None])
    ok, pair, slot = res.found[0], res.pair[0], res.slot[0]
    if active is not None:
        ok = ok & active
    in_stash = ok & (slot >= cfg.total_bits)
    okm = ok & ~in_stash
    safe = jnp.minimum(jnp.maximum(slot, 0), cfg.total_bits - 1).astype(U32)
    new_word = table.indicator[pair] & ~jnp.where(okm, U32(1) << safe, U32(0))
    table = _commit_indicator(table, okm, pair, new_word)
    pm = jnp.where(okm, 1, 0).astype(I32)
    if cfg.stash_slots:
        # stash delete: version bump -> meta clear (the atomic commit) ->
        # fp count decrement (uncounted, AFTER the commit so the count byte
        # never reads LOW of the true occupancy at any crash prefix)
        drop = jnp.iinfo(I32).max
        sidx = jnp.where(in_stash, slot - cfg.total_bits, drop)
        pw = jnp.where(in_stash, pair, drop)
        table = table._replace(
            version=table.version.at[pw].add(U32(1), mode="drop"),
            stash_meta=table.stash_meta.at[sidx].set(U32(0), mode="drop"))
        table = table._replace(
            fp=table.fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                      mode="drop"))
        pm = pm + jnp.where(in_stash, 2, 0).astype(I32)
    return table._replace(count=table.count - ok.astype(I32)), ok, pm


def _update_one(cfg, table: ContinuityTable, key, val, active=None):
    """Out-of-place update: both bit-flips land in ONE atomic indicator store.

    A key living in the stash relocates into an empty main/SBucket slot
    (payload -> fp -> indicator commit makes the new copy win by probe
    priority -> stash meta clear); with no empty candidate the update
    fails rather than tearing the stash entry in place."""
    res = lookup(cfg, table, key[None])
    found, pair, old_slot = res.found[0], res.pair[0], res.slot[0]
    if active is not None:
        found = found & active
    _, parity = locate(cfg, key[None])
    no = jnp.zeros((1,), jnp.bool_)
    cand, _, _, valid, slot_ok, _, _ = _gather_candidates(
        cfg, table, pair[None], parity, ext_allowed=no)
    empty = (~valid) & slot_ok
    has_empty = jnp.any(empty, axis=-1)[0]
    first = jnp.argmax(empty, axis=-1)
    new_slot = jnp.take_along_axis(cand, first[:, None], 1)[0, 0]
    in_stash = found & (old_slot >= cfg.total_bits)
    ok = found & has_empty
    okm = ok & ~in_stash
    oks = ok & in_stash
    ext_idx = jnp.maximum(table.ext_map[pair], 0)
    table = _scatter_payload(table, ok, pair, new_slot, ext_idx, key, val,
                             cfg.slots_per_pair)
    table = _fp_store(table, ok & (new_slot < cfg.slots_per_pair), pair,
                      new_slot, fingerprint(key[None])[0])
    safe_old = jnp.minimum(jnp.maximum(old_slot, 0), cfg.total_bits - 1)
    flip = jnp.where(okm, U32(1) << safe_old.astype(U32), U32(0)) | \
        (U32(1) << new_slot.astype(U32))
    new_word = table.indicator[pair] ^ jnp.where(ok, flip, U32(0))
    table = _commit_indicator(table, ok, pair, new_word)
    pm = jnp.where(okm, 2, 0).astype(I32)
    if cfg.stash_slots:
        drop = jnp.iinfo(I32).max
        sidx = jnp.where(oks, old_slot - cfg.total_bits, drop)
        pw = jnp.where(oks, pair, drop)
        table = table._replace(
            stash_meta=table.stash_meta.at[sidx].set(U32(0), mode="drop"),
            fp=table.fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                      mode="drop"))
        pm = pm + jnp.where(oks, 3, 0).astype(I32)
    return table, ok, pm


def _scan_op(cfg, one_fn):
    def step(carry, kv):
        table, ctr = carry
        *args, active = kv
        table, ok, pm = one_fn(cfg, table, *args, active)
        # masked-off ops count neither writes nor the ops denominator, so
        # per-op ledger averages stay meaningful for masked batches
        ctr = ctr.add(pm_writes=pm, ops=jnp.where(active, 1, 0))
        return (table, ctr), ok
    return step


def _active_mask(keys, mask):
    B = keys.shape[0]
    return (jnp.ones((B,), jnp.bool_) if mask is None
            else jnp.asarray(mask).reshape(B).astype(jnp.bool_))


@functools.partial(jax.jit, static_argnums=0)
def insert_serial(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                  mask=None):
    """Reference ``lax.scan`` insert (batch-order deterministic). 2 PM
    writes/op (3 on the stash-fallback path). Kept as the crash-recovery
    path and equivalence oracle for the wave engine; production batches
    use ``insert``."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _insert_one), (table, pmem.CostLedger.zero()),
        (keys, vals, _active_mask(keys, mask)))
    return table, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def delete_serial(cfg: ContinuityConfig, table: ContinuityTable, keys,
                  mask=None):
    """Reference ``lax.scan`` delete. 1 PM write/op (indicator bit clear;
    2 for stash entries: version bump + meta clear)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _delete_one), (table, pmem.CostLedger.zero()),
        (keys, _active_mask(keys, mask)))
    return table, ok, ctr


@functools.partial(jax.jit, static_argnums=0)
def update_serial(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                  mask=None):
    """Reference ``lax.scan`` out-of-place update. 2 PM writes/op (3 when
    the op relocates a stash entry into the main row)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    (table, ctr), ok = jax.lax.scan(
        _scan_op(cfg, _update_one), (table, pmem.CostLedger.zero()),
        (keys, vals, _active_mask(keys, mask)))
    return table, ok, ctr


# ---------------------------------------------------------------------------
# wave-vectorized mutation engine
# ---------------------------------------------------------------------------
# A batch of B mutations is scheduled into "waves": one stable sort by pair
# index clusters same-pair ops (keeping batch order inside a cluster), a
# segment scan assigns each op its intra-pair rank, and wave w holds every
# op of rank w.  All ops in a wave touch pairwise-distinct pairs, so a wave
# is one batched probe, one batched payload scatter (phase 1) and one
# batched set of independent one-word indicator stores (phase 2) — exactly
# B_w conflict-free applications of the paper's write protocol; same-pair
# ops serialize across waves in batch order (lock order == batch order).
#
# Execution strategy per op kind:
#   * ``insert``: occupancy per pair only GROWS, so every wave is
#     determined by the pre-batch indicator word — the op of intra-cohort
#     rank r takes the (r+1)-th empty candidate in its own probe order.
#     All waves therefore run FUSED in a single rank-indexed bit-select
#     pass over the 32-bit indicator words.  The one case where waves
#     genuinely interact — both parities of one pair contending for the
#     same middle SBucket slots — is detected exactly (see
#     ``_insert_fused``) and resolved by a residual wave ``while_loop``.
#   * ``update`` / ``delete``: occupancy mutates non-monotonically (bits
#     clear, items relocate), but with distinct keys every op's MATCH slot
#     is fixed by the pre-batch table — a slot's bit is only cleared by its
#     own unique matcher — so both ops also run FUSED from one pre-state
#     match pass.  ``delete`` needs no sequencing at all (clear masks of
#     distinct slots compose by OR); ``update``'s new-slot choices evolve
#     with the pair word, so a tiny rank loop over a (P,) word COPY
#     replays the allocation order — O(B) vector work per trip, none of
#     the table-wide gathers/scatters the old per-wave loop paid.  The one
#     genuine serialization point is a duplicate target (two ops resolving
#     to the SAME slot/stash row, i.e. the same key twice in a batch):
#     those run the exact residual wave ``while_loop``, whose trip count
#     is bounded by the contended cohorts alone — a hot pair no longer
#     serializes the full batch width (the old loop ran every cohort
#     ``max_collisions_per_pair`` heavy waves).

def _stable_order(cls: jnp.ndarray, num_class: int):
    """Stable ascending order of small int class ids.

    Packs (class, position) into ONE uint32 sort key when the product fits
    (single-array sort is ~2-3x faster on CPU/TPU than a key+payload sort),
    falling back to a stable argsort otherwise.  Returns ``(cls_s, idx_s)``.
    """
    B = cls.shape[0]
    width = 1 << max(1, (B - 1).bit_length())
    if (num_class + 1) * width < 2 ** 31:
        sk = jax.lax.sort(cls.astype(U32) * U32(width)
                          + jnp.arange(B, dtype=U32))
        return (sk // U32(width)).astype(I32), (sk & U32(width - 1)).astype(I32)
    idx = jnp.argsort(cls, stable=True).astype(I32)
    return cls[idx].astype(I32), idx


def _cohort_ranks(cls_s: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its (sorted, contiguous) class run."""
    B = cls_s.shape[0]
    ii = jnp.arange(B, dtype=I32)
    head = jnp.concatenate([jnp.ones((1,), jnp.bool_), cls_s[1:] != cls_s[:-1]])
    return ii - jax.lax.cummax(jnp.where(head, ii, 0))


def _plan_waves(cfg: ContinuityConfig, keys: jnp.ndarray, active: jnp.ndarray):
    """Group a batch into per-pair cohorts with ONE stable packed sort.

    Returns ``(pair, parity, rank, num_waves)``: ``rank[i]`` is op i's
    position among active same-pair ops in batch order (-1 if inactive);
    ops of equal rank touch pairwise-distinct pairs.
    """
    B = keys.shape[0]
    pair, parity = locate(cfg, keys)
    cls = jnp.where(active, pair, cfg.num_pairs)
    cls_s, order = _stable_order(cls, cfg.num_pairs)
    rank = jnp.zeros((B,), I32).at[order].set(_cohort_ranks(cls_s))
    rank = jnp.where(active, rank, -1)
    return pair, parity, rank, jnp.max(rank) + 1


@jax.custom_batching.custom_vmap
def _pin(xs):
    """Identity that pins its operands as materialized values.

    XLA CPU loop fusion re-computes a producer chain inside every consumer
    fusion; without this the sort/probe chain above a commit phase runs once
    PER SCATTER (~2x wall time at batch 512).  ``optimization_barrier`` has
    no batching rule in this jax version, so supply one (the barrier applies
    unchanged to the batched arrays)."""
    return jax.lax.optimization_barrier(xs)


@_pin.def_vmap
def _pin_vmap(axis_size, in_batched, xs):
    return jax.lax.optimization_barrier(xs), in_batched[0]


def _bitreverse32(v: jnp.ndarray) -> jnp.ndarray:
    c = U32
    v = ((v >> c(1)) & c(0x55555555)) | ((v & c(0x55555555)) << c(1))
    v = ((v >> c(2)) & c(0x33333333)) | ((v & c(0x33333333)) << c(2))
    v = ((v >> c(4)) & c(0x0F0F0F0F)) | ((v & c(0x0F0F0F0F)) << c(4))
    v = ((v >> c(8)) & c(0x00FF00FF)) | ((v & c(0x00FF00FF)) << c(8))
    return (v >> c(16)) | (v << c(16))


def _canonical_occupancy(cfg: ContinuityConfig, ind: jnp.ndarray,
                         parity: jnp.ndarray) -> jnp.ndarray:
    """Rearrange indicator words so bit p = the op's p-th probe candidate.

    Even homes probe slots 0..seg-1 ascending (bits pass through); odd homes
    probe slots S-1..S-seg descending (one vectorized bit-reversal); the
    extension bits follow at positions seg.. for both parities.
    """
    S, seg, E = cfg.slots_per_pair, cfg.seg_slots, cfg.ext_slots
    main = jnp.where(parity == 0, ind, _bitreverse32(ind) >> U32(32 - S))
    canon = main & U32((1 << seg) - 1)
    if E:
        canon = canon | (((ind >> U32(S)) & U32((1 << E) - 1)) << U32(seg))
    return canon


def _select_bit(word: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Position of the (n+1)-th set bit of each uint32 word (branch-free
    5-step binary descend on popcounts; valid iff n < popcount(word))."""
    pos = jnp.zeros_like(word)
    rem = n.astype(U32)
    for width in (16, 8, 4, 2, 1):
        low = (word >> pos) & U32((1 << width) - 1)
        cnt = jax.lax.population_count(low)
        go = rem >= cnt
        rem = jnp.where(go, rem - cnt, rem)
        pos = jnp.where(go, pos + U32(width), pos)
    return pos.astype(I32)


def _insert_wave_plan(cfg: ContinuityConfig, table: ContinuityTable,
                      pair, parity, m):
    """Probe phase of one insert wave: pick each active op's slot and grant
    extension groups by prefix sum over batch order (== serial grant order).

    Returns ``(slot, ok, grant, ext_idx)``.
    """
    B = pair.shape[0]
    if cfg.ext_frac > 0:
        pool_left = cfg.ext_pool_pairs - table.ext_count
    else:
        pool_left = jnp.zeros((), I32)
    opt = jnp.broadcast_to(pool_left > 0, (B,))      # optimistic ext candidacy
    cand, _, _, valid, slot_ok, is_ext, has_ext = _gather_candidates(
        cfg, table, pair, parity, ext_allowed=opt)
    empty = (~valid) & slot_ok
    first = jnp.argmax(empty, axis=-1)
    slot = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
    want = m & jnp.any(empty, -1) & (slot >= cfg.slots_per_pair) & ~has_ext
    grant = want & (jnp.cumsum(want.astype(I32)) - 1 < pool_left)
    # pool-denied allocators fall back to main-segment candidates only
    denied = want & ~grant
    empty = jnp.where(denied[:, None], empty & ~is_ext, empty)
    ok = m & jnp.any(empty, -1)
    first = jnp.argmax(empty, axis=-1)
    slot = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
    new_idx = table.ext_count + jnp.cumsum(grant.astype(I32)) - 1
    ext_idx = jnp.where(grant, new_idx, jnp.maximum(table.ext_map[pair], 0))
    return slot, ok, grant, ext_idx


def _insert_wave(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                 pair, parity, m):
    """Execute one insert wave (active ops have distinct pairs)."""
    slot, ok, grant, ext_idx = _insert_wave_plan(cfg, table, pair, parity, m)
    ext_map = table.ext_map.at[jnp.where(grant, pair, jnp.iinfo(I32).max)].set(
        ext_idx, mode="drop")
    table = table._replace(
        ext_map=ext_map, ext_count=table.ext_count + jnp.sum(grant).astype(I32))
    table = _scatter_payload(table, ok, pair, slot, ext_idx, keys, vals,
                             cfg.slots_per_pair)                    # phase 1
    table = _fp_store(table, ok & (slot < cfg.slots_per_pair), pair, slot,
                      fingerprint(keys))
    word = table.indicator[pair] | jnp.where(
        ok, U32(1) << slot.astype(U32), U32(0))
    table = _commit_indicator(table, ok, pair, word)                # phase 2
    return table._replace(count=table.count + jnp.sum(ok).astype(I32)), \
        ok, grant, ext_idx


def _reorder_ext_pool(cfg: ContinuityConfig, table: ContinuityTable,
                      alloc_pos, alloc_idx):
    """Relabel extension groups granted this batch into batch-position order.

    Waves grant pool rows in (wave, batch) order while the serial reference
    grants in pure batch order; both grant the SAME pair set, so a pure
    metadata permutation of the pool rows + ``ext_map`` makes the wave
    result byte-identical to the serial one.
    """
    B = alloc_pos.shape[0]
    PE = cfg.ext_pool_pairs
    did = alloc_pos >= 0
    order = jnp.argsort(jnp.where(did, alloc_pos, jnp.iinfo(I32).max),
                        stable=True)                 # granters first
    did_s = did[order]
    old_s = alloc_idx[order]
    new_s = (table.ext_count - jnp.sum(did).astype(I32)
             + jnp.arange(B, dtype=I32))
    fwd = jnp.arange(PE, dtype=I32).at[
        jnp.where(did_s, old_s, PE)].set(new_s, mode="drop")
    inv = jnp.arange(PE, dtype=I32).at[
        jnp.where(did_s, new_s, PE)].set(old_s, mode="drop")
    ext_map = jnp.where(table.ext_map >= 0,
                        fwd[jnp.maximum(table.ext_map, 0)], -1)
    return table._replace(ext_keys=table.ext_keys[inv],
                          ext_vals=table.ext_vals[inv], ext_map=ext_map)


def _batch_arrays(keys, vals=None, mask=None):
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    B = keys.shape[0]
    if vals is not None:
        vals = jnp.asarray(vals, U32).reshape(-1, VAL_LANES)
    active = (jnp.ones((B,), jnp.bool_) if mask is None
              else jnp.asarray(mask).reshape(B).astype(jnp.bool_))
    return keys, vals, active


def _insert_fused(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                  active):
    """All insert waves fused into one rank-indexed bit-select pass.

    For an insert-only batch, a pair's occupancy only grows, so the op of
    intra-cohort rank r takes the (r+1)-th empty candidate of the PRE-batch
    indicator word — every wave is computable up front.  The single genuine
    inter-wave interaction is a pair whose two home parities contend for the
    same middle SBucket slots; a cohort is contention-free (closed form ==
    serial for every interleaving) iff it is single-parity, or no op leaves
    its main segment AND the two directional claims fit disjointly:
    ``n_even + n_odd <= popcount(empty main slots)`` (claims from opposite
    ends of one ordered slot list can only collide if they outnumber it).
    Contended cohorts are flagged and returned for the residual wave loop.

    Returns ``(table, ok, unsafe_sorted, idx_s, grant_pos, grant_idx)`` —
    ``unsafe_sorted``/``idx_s`` flag contended cohorts (in sorted op order),
    and the grant records (batch position / pool row) feed the final
    serial-order pool relabel.
    """
    B = keys.shape[0]
    P = cfg.num_pairs
    S, seg, E = cfg.slots_per_pair, cfg.seg_slots, cfg.ext_slots
    pair, parity = locate(cfg, keys)
    drop = jnp.iinfo(I32).max

    # plan: one stable packed sort by (pair, parity); batch order within
    cls = jnp.where(active, pair * 2 + parity, 2 * P)
    cls_s, idx_s = _stable_order(cls, 2 * P)
    act = cls_s < 2 * P
    pair_s = jnp.minimum(cls_s >> 1, P - 1)
    par_s = cls_s & 1
    r2 = _cohort_ranks(cls_s)                 # rank within (pair, parity)
    # barriers pin each stage's results: XLA CPU otherwise re-fuses the
    # producer chain into every downstream scatter/gather (see EXPERIMENTS)
    act, pair_s, par_s, r2, idx_s = _pin((act, pair_s, par_s, r2, idx_s))

    ind = table.indicator[pair_s]
    has_ext = table.ext_map[pair_s] >= 0
    main_mask = U32((1 << seg) - 1)
    canon = _canonical_occupancy(cfg, ind, par_s)
    own_empty = jax.lax.population_count(~canon & main_mask).astype(I32)
    spill = act & (r2 >= own_empty)           # would leave its main segment
    canon, own_empty, spill = _pin((canon, own_empty, spill))

    # cohort safety: per-(pair, parity) op count + spill flag, ONE scatter
    rec = jnp.where(act, 1 + (spill.astype(I32) << 16), 0)
    cnt = jnp.zeros((P, 2), I32).at[pair_s, par_s].add(rec)
    own = cnt[pair_s, par_s]
    oth = cnt[pair_s, 1 - par_s]
    pair_empty = jax.lax.population_count(
        ~ind & U32((1 << S) - 1)).astype(I32)
    unsafe = act & (oth > 0) & (
        ((own >> 16) + (oth >> 16) > 0)
        | ((own & 0xFFFF) + (oth & 0xFFFF) > pair_empty))
    go = act & ~unsafe

    # extension grants, in batch order (== serial grant order); a spilling
    # op in a safe cohort is necessarily single-parity, and the trigger is
    # the first such op (rank == #empty main candidates).  The grant branch
    # also produces the (batch position, pool row) records for the final
    # pool relabel; batches without ext pressure skip all of it.
    no_grant = (jnp.zeros((B,), jnp.bool_), jnp.zeros((B,), I32),
                jnp.full((B,), -1, I32), jnp.full((B,), -1, I32))
    if cfg.ext_frac > 0 and E:
        pool_left = cfg.ext_pool_pairs - table.ext_count
        want = go & (r2 == own_empty) & ~has_ext
        def grants(_):
            wb = jnp.zeros((B,), jnp.bool_).at[idx_s].set(want)
            grank = jnp.cumsum(wb.astype(I32)) - 1
            gb = wb & (grank < pool_left)
            gi = jnp.where(gb, table.ext_count + grank, -1)
            return gb[idx_s], (table.ext_count + grank)[idx_s], \
                jnp.where(gb, jnp.arange(B, dtype=I32), -1), gi
        grant, new_eidx, gpos, gidx = jax.lax.cond(
            jnp.any(want) & (pool_left > 0), grants, lambda _: no_grant, 0)
        ext_map = table.ext_map.at[
            jnp.where(grant, pair_s, drop)].set(new_eidx, mode="drop")
        table = table._replace(
            ext_map=ext_map,
            ext_count=table.ext_count + jnp.sum(grant).astype(I32))
    else:
        grant, new_eidx, gpos, gidx = no_grant
    eidx = table.ext_map[pair_s]

    # rank-indexed slot selection on the canonical empty word
    ext_bits = U32(((1 << E) - 1) << seg) if E else U32(0)
    empty = ~canon & (main_mask | jnp.where(eidx >= 0, ext_bits, U32(0)))
    ok = go & (r2 < jax.lax.population_count(empty).astype(I32))
    pos = _select_bit(empty, r2)
    slot = jnp.where(pos < seg,
                     jnp.where(par_s == 0, pos, S - 1 - pos),
                     S + (pos - seg))

    # materialize the plan once: without this barrier XLA re-fuses the whole
    # sort/probe chain into EVERY commit scatter below (~2x the work)
    ok, slot, eidx, pair_s, idx_s, unsafe, k_s, v_s = _pin(
        (ok, slot, eidx, pair_s, idx_s, unsafe, keys[idx_s], vals[idx_s]))

    # phase 1: payload rows (flat 1-D scatters; ext rows cond-skipped)
    is_ext = slot >= S
    midx = jnp.where(ok & ~is_ext, pair_s * S + jnp.minimum(slot, S - 1), drop)
    tkeys = table.keys.reshape(P * S, KEY_LANES).at[midx].set(
        k_s, mode="drop").reshape(P, S, KEY_LANES)
    tvals = table.vals.reshape(P * S, VAL_LANES).at[midx].set(
        v_s, mode="drop").reshape(P, S, VAL_LANES)

    def ext_rows(kv):
        ek, ev = kv
        PE, EX = ek.shape[0], ek.shape[1]
        eix = jnp.where(ok & is_ext,
                        jnp.maximum(eidx, 0) * EX + jnp.maximum(slot - S, 0),
                        drop)
        return (ek.reshape(PE * EX, KEY_LANES).at[eix].set(
                    k_s, mode="drop").reshape(ek.shape),
                ev.reshape(PE * EX, VAL_LANES).at[eix].set(
                    v_s, mode="drop").reshape(ev.shape))
    tek, tev = jax.lax.cond(jnp.any(ok & is_ext), ext_rows,
                            lambda kv: kv, (table.ext_keys, table.ext_vals))

    # fingerprint fields of the committed main slots: committed ops claim
    # pairwise-distinct (pair, slot), so their 2-bit fields are disjoint
    # and two scatter-adds (clear mask, then new bits) compose exactly like
    # the serial path's per-op read-modify-writes
    okm = ok & ~is_ext
    fpv = fingerprint(k_s)
    fw = jnp.where(okm, jnp.minimum(slot, S - 1) // _FPW, 0)
    fsh = (U32(FP_SLOT_BITS) * (slot % _FPW).astype(U32))
    fpair = jnp.where(okm, pair_s, drop)
    fclear = jnp.zeros((P, 2), U32).at[fpair, fw].add(
        jnp.where(okm, U32(FP_MASK) << fsh, U32(0)), mode="drop")
    fnew = jnp.zeros((P, 2), U32).at[fpair, fw].add(
        jnp.where(okm, (fpv & U32(FP_MASK)) << fsh, U32(0)), mode="drop")

    # phase 2: one-word indicator commits (bits of one pair are disjoint,
    # so a scatter-add is the batch of independent atomic ORs)
    add = jnp.zeros((P,), U32).at[jnp.where(ok, pair_s, drop)].add(
        U32(1) << slot.astype(U32), mode="drop")
    # version bumps ride the same per-pair commit scatter: one bump per
    # committed op, and per-pair counts are order-independent sums, so the
    # fused path stays byte-identical to the serial oracle
    vadd = jnp.zeros((P,), U32).at[jnp.where(ok, pair_s, drop)].add(
        U32(1), mode="drop")
    table = table._replace(
        keys=tkeys, vals=tvals, ext_keys=tek, ext_vals=tev,
        indicator=table.indicator | add,
        version=table.version + vadd,
        fp=(table.fp & ~fclear) | fnew,
        count=table.count + jnp.sum(ok).astype(I32))

    okb = jnp.zeros((B,), jnp.bool_).at[idx_s].set(ok)
    return table, okb, unsafe, idx_s, gpos, gidx


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
           mask=None):
    """Server-side batched insert on the wave engine. 2 PM writes/op.

    Byte-identical tables and counters to ``insert_serial`` (masked ops are
    skipped); same-pair ops execute in batch order. The one permitted
    divergence is extension-pool exhaustion mid-batch: grants are a true
    serialization point, so when the pool runs dry in a batch that also has
    parity-contended cohorts, a different set of pairs may win the last
    groups than under the serial order — and with them the admitted ops,
    ``ok`` flags and PM-write totals. Batches that do not exhaust the pool
    (every sweep/test config here) are exactly serial.
    """
    keys, vals, active = _batch_arrays(keys, vals, mask)
    B = keys.shape[0]
    table, ok, unsafe_s, idx_s, gpos, gidx = _insert_fused(
        cfg, table, keys, vals, active)

    def contended(args):
        # residual wave loop: only parity-contended cohorts (rare) run here
        table, ok, gpos, gidx = args
        unsafe = jnp.zeros((B,), jnp.bool_).at[idx_s].set(unsafe_s)
        pair, parity, rank, num_waves = _plan_waves(cfg, keys, unsafe)

        def body(c):
            w, t, okw, ap, ai = c
            t, wok, wgrant, weidx = _insert_wave(cfg, t, keys, vals, pair,
                                                 parity, rank == w)
            ap = jnp.where(wgrant, jnp.arange(B, dtype=I32), ap)
            ai = jnp.where(wgrant, weidx, ai)
            return w + 1, t, okw | wok, ap, ai

        _, table, ok, gpos, gidx = jax.lax.while_loop(
            lambda c: c[0] < num_waves, body,
            (jnp.zeros((), I32), table, ok, gpos, gidx))
        return table, ok, gpos, gidx

    table, ok, gpos, gidx = jax.lax.cond(
        jnp.any(unsafe_s), contended, lambda a: a, (table, ok, gpos, gidx))

    n_stash = jnp.zeros((), I32)
    if cfg.stash_slots:
        # stash fallback AFTER all main waves: probe outcomes never depend
        # on stash state, so deferring the failed ops preserves serial
        # byte-identity — op i's stash slot is the (rank_i+1)-th free slot
        # in ascending order, exactly what the serial first-free scan picks
        def stash_pass(args):
            t, okb = args
            T = cfg.stash_slots
            fail = active & ~okb
            free = t.stash_meta == U32(0)
            nth = jnp.cumsum(fail.astype(I32)) - 1       # batch-order rank
            sok = fail & (nth < jnp.sum(free.astype(I32)))
            fs = jnp.sort(jnp.where(free, jnp.arange(T, dtype=I32), T))
            sidx = fs[jnp.clip(nth, 0, T - 1)]
            drop = jnp.iinfo(I32).max
            w = jnp.where(sok, sidx, drop)
            pair, _ = locate(cfg, keys)
            pw = jnp.where(sok, pair, drop)
            t = t._replace(
                fp=t.fp.at[pw, 1].add(U32(1) << U32(STASH_CNT_SHIFT),
                                      mode="drop"),
                stash_keys=t.stash_keys.at[w].set(keys, mode="drop"),
                stash_vals=t.stash_vals.at[w].set(vals, mode="drop"),
                version=t.version.at[pw].add(U32(1), mode="drop"),
                stash_meta=t.stash_meta.at[w].set(
                    pair.astype(U32) + U32(1), mode="drop"),
                count=t.count + jnp.sum(sok).astype(I32))
            return t, okb | sok, jnp.sum(sok).astype(I32)

        table, ok, n_stash = jax.lax.cond(
            jnp.any(active & ~ok), stash_pass,
            lambda a: (a[0], a[1], jnp.zeros((), I32)), (table, ok))

    if cfg.ext_frac > 0:
        # relabel pool rows into batch-grant order (== serial pool layout)
        table = jax.lax.cond(
            jnp.any(gpos >= 0),
            lambda t: _reorder_ext_pool(cfg, t, gpos, gidx),
            lambda t: t, table)
    ctr = pmem.CostLedger.zero().add(pm_writes=2 * jnp.sum(ok) + n_stash,
                                     ops=jnp.sum(active))
    return table, ok, ctr


def _gather_candidate_keys(cfg: ContinuityConfig, table: ContinuityTable,
                           pair, parity, ext_allowed):
    """``_gather_candidates`` minus the value gathers — the write-path waves
    only match/probe on keys (values are scattered, never read)."""
    probe = jnp.asarray(_probe_order(cfg))           # (2, C)
    cand = probe[parity]                             # (B, C)
    S = cfg.slots_per_pair
    is_ext = cand >= S
    ind = table.indicator[pair]
    bits = (ind[:, None] >> cand.astype(U32)) & U32(1)
    main_ids = jnp.minimum(cand, S - 1)
    mkeys = table.keys[pair[:, None], main_ids]
    eidx = table.ext_map[pair]
    has_ext = eidx >= 0
    ekeys = table.ext_keys[jnp.maximum(eidx, 0)[:, None], jnp.maximum(cand - S, 0)]
    cand_keys = jnp.where(is_ext[..., None], ekeys, mkeys)
    slot_ok = jnp.where(is_ext, (has_ext | ext_allowed)[:, None], True)
    valid = (bits == 1) & slot_ok & jnp.where(is_ext, has_ext[:, None], True)
    return cand, cand_keys, valid, slot_ok


def _stash_match(cfg, table: ContinuityTable, keys, pair):
    """(B, T) bool: stash entries holding ``keys`` homed at ``pair``."""
    home = pair.astype(U32) + U32(1)
    return (table.stash_meta[None, :] == home[:, None]) & jnp.all(
        table.stash_keys[None, :, :] == keys[:, None, :], axis=-1)


def _stash_match_gated(cfg, table: ContinuityTable, keys, pair):
    """`_stash_match`, skipped entirely (all-False) while no pair has a
    live stash entry — one count-byte reduction gates the (B, T) full-key
    compare the common stash-empty batch would otherwise pay."""
    B = keys.shape[0]
    return jax.lax.cond(
        jnp.any((table.fp[:, 1] >> U32(STASH_CNT_SHIFT)) != U32(0)),
        lambda _: _stash_match(cfg, table, keys, pair),
        lambda _: jnp.zeros((B, cfg.stash_slots), jnp.bool_), 0)


def _delete_wave(cfg: ContinuityConfig, table: ContinuityTable, keys,
                 pair, parity, m):
    B = keys.shape[0]
    no = jnp.zeros((B,), jnp.bool_)
    cand, ckeys, valid, _ = _gather_candidate_keys(
        cfg, table, pair, parity, ext_allowed=no)
    match = valid & jnp.all(ckeys == keys[:, None, :], axis=-1)
    ok = m & jnp.any(match, -1)
    slot = jnp.take_along_axis(cand, jnp.argmax(match, -1)[:, None], 1)[:, 0]
    ok, slot = _pin((ok, slot))
    word = table.indicator[pair] & ~jnp.where(
        ok, U32(1) << jnp.maximum(slot, 0).astype(U32), U32(0))
    table = _commit_indicator(table, ok, pair, word)    # the ONE PM write
    pm = jnp.sum(ok).astype(I32)
    if cfg.stash_slots:
        # stash delete (probe priority: only when the main row missed);
        # active ops have distinct pairs, and a stash row belongs to one
        # pair, so the scatters below are conflict-free
        smatch = _stash_match(cfg, table, keys, pair)
        sok = m & ~ok & jnp.any(smatch, -1)
        sidx = jnp.argmax(smatch, -1).astype(I32)
        drop = jnp.iinfo(I32).max
        w = jnp.where(sok, sidx, drop)
        pw = jnp.where(sok, pair, drop)
        table = table._replace(
            version=table.version.at[pw].add(U32(1), mode="drop"),
            stash_meta=table.stash_meta.at[w].set(U32(0), mode="drop"))
        table = table._replace(
            fp=table.fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                      mode="drop"))
        ok = ok | sok
        pm = pm + 2 * jnp.sum(sok).astype(I32)
    return table._replace(count=table.count - jnp.sum(ok).astype(I32)), ok, pm


def _mutation_match(cfg: ContinuityConfig, table: ContinuityTable, keys,
                    pair, parity, *, probe="gather", qblock=8,
                    interpret=True):
    """Pre-batch match resolution shared by the fused update/delete passes.

    Returns ``(found, mslot)``: the first main/extension slot (pair
    coordinates, probe order) holding each key, -1 on miss.  ``probe``
    selects the backend: ``"gather"`` is the pure-jnp candidate gather;
    ``"pallas"``/``"reference"`` run the mutation-plan kernel
    (`repro.kernels.mutate`) / its jnp oracle over the main segment (with
    the fingerprint pre-filter) plus the same jnp extension tail the
    kernel lookup path uses.  All backends are result-identical — visible
    slots always carry correct fingerprint fields."""
    B = keys.shape[0]
    if probe == "gather":
        no = jnp.zeros((B,), jnp.bool_)
        cand, ckeys, valid, _ = _gather_candidate_keys(
            cfg, table, pair, parity, ext_allowed=no)
        match = valid & jnp.all(ckeys == keys[:, None, :], axis=-1)
        found = jnp.any(match, -1)
        mslot = jnp.where(found, jnp.take_along_axis(
            cand, jnp.argmax(match, -1)[:, None], 1)[:, 0], -1)
        return found, mslot
    from repro.kernels import ops as K        # deferred: pallas import
    mmain, _, _ = K.mutation_plan(cfg, table, keys,
                                  use_kernel=probe == "pallas",
                                  interpret=interpret, qblock=qblock)
    found_m = mmain >= 0
    S, E = cfg.slots_per_pair, cfg.ext_slots
    if E:
        eidx = table.ext_map[pair]
        has_ext = eidx >= 0
        ebits = (table.indicator[pair][:, None]
                 >> (S + jnp.arange(E, dtype=U32))[None]) & U32(1)
        ekeys = table.ext_keys[jnp.maximum(eidx, 0)]
        ematch = has_ext[:, None] & (ebits == 1) & jnp.all(
            ekeys == keys[:, None, :], axis=-1)
        efound = jnp.any(ematch, -1)
        eslot = S + jnp.argmax(ematch, -1).astype(I32)
    else:
        efound = jnp.zeros((B,), jnp.bool_)
        eslot = jnp.zeros((B,), I32)
    found = found_m | efound
    return found, jnp.where(found_m, mmain, jnp.where(efound, eslot, -1))


def _dup_targets(cfg: ContinuityConfig, pair, cm, mslot, cs, sidx):
    """Per-op flag: does another active op resolve to the SAME target (main
    or extension slot, or stash row)?

    A slot holds one key and pre-state probes of equal keys are identical,
    so duplicate targets <=> duplicate keys in the batch — the one case
    where update/delete waves genuinely interact.  One scatter-count over
    a flat (P * total_bits + stash) location space."""
    P, TB, T = cfg.num_pairs, cfg.total_bits, cfg.stash_slots
    drop = jnp.iinfo(I32).max
    loc = jnp.where(cm, pair * TB + jnp.maximum(mslot, 0),
                    jnp.where(cs, P * TB + sidx, drop))
    hit = cm | cs
    cnt = jnp.zeros((P * TB + max(T, 1),), I32).at[loc].add(1, mode="drop")
    return hit & (cnt[jnp.where(hit, loc, 0)] > 1)


def _delete_fused(cfg: ContinuityConfig, table: ContinuityTable, keys,
                  active, *, probe, qblock, interpret):
    """All delete waves fused into one pass.

    With distinct keys, each op's match slot comes from the PRE-batch table
    (a slot's bit is only ever cleared by its own unique matcher), cleared
    bits of one pair are disjoint (they OR-compose in any order), and
    version bumps are order-independent per-pair sums — so the whole batch
    commits in one scatter round.  Ops with duplicate targets (same key
    twice) are flagged ``unsafe`` and left untouched for the residual wave
    loop.  Returns ``(table, ok, pm, unsafe)``."""
    B = keys.shape[0]
    P = cfg.num_pairs
    drop = jnp.iinfo(I32).max
    pair, parity = locate(cfg, keys)
    found, mslot = _mutation_match(cfg, table, keys, pair, parity,
                                   probe=probe, qblock=qblock,
                                   interpret=interpret)
    cm = active & found
    if cfg.stash_slots:
        smatch = _stash_match_gated(cfg, table, keys, pair)
        cs = active & ~found & jnp.any(smatch, -1)
        sidx = jnp.argmax(smatch, -1).astype(I32)
    else:
        cs = jnp.zeros((B,), jnp.bool_)
        sidx = jnp.zeros((B,), I32)
    unsafe = _dup_targets(cfg, pair, cm, mslot, cs, sidx)
    okm = cm & ~unsafe
    oks = cs & ~unsafe
    okm, oks, mslot, sidx, pair = _pin((okm, oks, mslot, sidx, pair))

    # phase 2 only — a delete's ONE counted PM write is the indicator
    # commit; committed ops clear pairwise-distinct bits, so a scatter-add
    # composes them exactly like the serial per-op stores.  ONE flat
    # scatter carries both halves of the 8-byte word (bit clears in [0,P),
    # version bumps in [P,2P)) — scatter dispatch is most of this pass's
    # cost on CPU, so the fewer the better
    idx = jnp.concatenate([jnp.where(okm, pair, drop),
                           jnp.where(okm | oks, pair + P, drop)])
    upd = jnp.concatenate([U32(1) << jnp.maximum(mslot, 0).astype(U32),
                           jnp.ones((B,), U32)])
    buf = jnp.zeros((2 * P,), U32).at[idx].add(upd, mode="drop")
    table = table._replace(indicator=table.indicator & ~buf[:P],
                           version=table.version + buf[P:])
    pm = jnp.sum(okm).astype(I32)
    if cfg.stash_slots:
        # stash tail gated on an actual stash hit: the common all-main
        # batch skips both scatters
        def stash_tail(sm_fp):
            sm, fp = sm_fp
            w = jnp.where(oks, sidx, drop)
            pw = jnp.where(oks, pair, drop)
            return (sm.at[w].set(U32(0), mode="drop"),
                    fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                     mode="drop"))
        sm, fp = jax.lax.cond(jnp.any(oks), stash_tail, lambda x: x,
                              (table.stash_meta, table.fp))
        table = table._replace(stash_meta=sm, fp=fp)
        pm = pm + 2 * jnp.sum(oks).astype(I32)
    ok = okm | oks
    table = table._replace(count=table.count - jnp.sum(ok).astype(I32))
    return table, ok, pm, unsafe


@functools.partial(jax.jit, static_argnums=0,
                   static_argnames=("probe", "qblock", "interpret"))
def delete(cfg: ContinuityConfig, table: ContinuityTable, keys, mask=None,
           *, probe: str = "gather", qblock: int = 8,
           interpret: bool = True):
    """Server-side batched delete on the wave engine. 1 PM write/op
    (2 for stash entries).

    One fused pass commits the whole batch; only duplicate-target cohorts
    (the same key deleted twice in one batch) fall back to the exact
    residual wave loop, whose trip count is bounded by those cohorts alone.
    ``probe`` selects the match backend (see `_mutation_match`)."""
    keys, _, active = _batch_arrays(keys, mask=mask)
    table, ok, pm, unsafe = _delete_fused(cfg, table, keys, active,
                                          probe=probe, qblock=qblock,
                                          interpret=interpret)

    # residual wave loop: ranks are planned over the UNSAFE ops alone, so
    # the trip count is bounded by the contended cohorts (zero trips — the
    # loop body never executes — for the common duplicate-free batch)
    pair, parity, rank, num_waves = _plan_waves(cfg, keys, unsafe)

    def body(c):
        w, t, okw, pmw = c
        t, wok, wpm = _delete_wave(cfg, t, keys, pair, parity, rank == w)
        return w + 1, t, okw | wok, pmw + wpm

    _, table, ok, pm = jax.lax.while_loop(
        lambda c: c[0] < num_waves, body,
        (jnp.zeros((), I32), table, ok, pm))
    ctr = pmem.CostLedger.zero().add(pm_writes=pm, ops=jnp.sum(active))
    return table, ok, ctr


def _update_wave(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                 pair, parity, m):
    B = keys.shape[0]
    no = jnp.zeros((B,), jnp.bool_)
    cand, ckeys, valid, slot_ok = _gather_candidate_keys(
        cfg, table, pair, parity, ext_allowed=no)
    match = valid & jnp.all(ckeys == keys[:, None, :], axis=-1)
    found = jnp.any(match, -1)
    old = jnp.take_along_axis(cand, jnp.argmax(match, -1)[:, None], 1)[:, 0]
    empty = (~valid) & slot_ok
    new = jnp.take_along_axis(cand, jnp.argmax(empty, -1)[:, None], 1)[:, 0]
    has_empty = jnp.any(empty, -1)
    if cfg.stash_slots:
        smatch = _stash_match(cfg, table, keys, pair)
        in_stash = ~found & jnp.any(smatch, -1)
        sidx = jnp.argmax(smatch, -1).astype(I32)
        found = found | in_stash
    else:
        in_stash = jnp.zeros((B,), jnp.bool_)
        sidx = jnp.zeros((B,), I32)
    ok = m & found & has_empty
    okm = ok & ~in_stash
    oks = ok & in_stash
    ext_idx = jnp.maximum(table.ext_map[pair], 0)
    ok, okm, oks, old, new, ext_idx = _pin((ok, okm, oks, old, new, ext_idx))
    table = _scatter_payload(table, ok, pair, new, ext_idx, keys, vals,
                             cfg.slots_per_pair)                    # phase 1
    table = _fp_store(table, ok & (new < cfg.slots_per_pair), pair, new,
                      fingerprint(keys))
    flip = jnp.where(okm, U32(1) << jnp.maximum(old, 0).astype(U32), U32(0)) \
        | (U32(1) << new.astype(U32))
    word = table.indicator[pair] ^ jnp.where(ok, flip, U32(0))
    table = _commit_indicator(table, ok, pair, word)                # phase 2
    pm = 2 * jnp.sum(okm).astype(I32)
    if cfg.stash_slots:
        # stash relocation tail: the commit above made the main copy win by
        # probe priority, so the meta clear only removes a shadowed entry
        drop = jnp.iinfo(I32).max
        w = jnp.where(oks, sidx, drop)
        pw = jnp.where(oks, pair, drop)
        table = table._replace(
            stash_meta=table.stash_meta.at[w].set(U32(0), mode="drop"),
            fp=table.fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                      mode="drop"))
        pm = pm + 3 * jnp.sum(oks).astype(I32)
    return table, ok, pm


def _update_fused(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                  active, *, probe, qblock, interpret):
    """All update waves fused into one rank-indexed pass.

    With distinct keys, each op's OLD slot is fixed by the pre-batch table
    (only an op's own matcher frees its slot), so the one state that
    genuinely evolves mid-batch is the pair's occupancy word: op r's new
    slot is the first empty probe candidate of the word AFTER ranks < r
    applied.  That allocation order is replayed on a (P,) COPY of the
    indicator words — O(B) gathers + one (P,) scatter per trip, none of
    the table-wide key/value traffic the old per-wave loop paid — and the
    batch then commits in one scatter round: payload stores to
    pairwise-distinct slots (each slot is freed at most once, by its
    unique matcher, and claimed at most once), fingerprint fields as two
    disjoint scatter-adds, indicator words from the evolved copy, version
    bumps as per-pair sums.  Duplicate-target cohorts poison their whole
    pair (allocation order entangles every op of the pair) and fall back
    to the residual wave loop.  Returns ``(table, ok, pm, unsafe)``."""
    B = keys.shape[0]
    P = cfg.num_pairs
    S, seg, E = cfg.slots_per_pair, cfg.seg_slots, cfg.ext_slots
    drop = jnp.iinfo(I32).max
    pair, parity = locate(cfg, keys)
    found, mslot = _mutation_match(cfg, table, keys, pair, parity,
                                   probe=probe, qblock=qblock,
                                   interpret=interpret)
    if cfg.stash_slots:
        smatch = _stash_match_gated(cfg, table, keys, pair)
        in_stash = ~found & jnp.any(smatch, -1)
        sidx = jnp.argmax(smatch, -1).astype(I32)
    else:
        in_stash = jnp.zeros((B,), jnp.bool_)
        sidx = jnp.zeros((B,), I32)
    cm = active & found
    cs = active & in_stash
    dup = _dup_targets(cfg, pair, cm, mslot, cs, sidx)
    # unlike delete, a duplicate target serializes its WHOLE pair: new-slot
    # allocation threads through every op of the cohort in batch order
    pdup = jnp.zeros((P,), jnp.bool_).at[
        jnp.where(dup, pair, drop)].set(True, mode="drop")
    unsafe = active & pdup[pair]
    cand_op = (cm | cs) & ~unsafe
    cand_op, found, mslot, in_stash, sidx = _pin(
        (cand_op, found, mslot, in_stash, sidx))

    # rank-sequential new-slot allocation on the word copy
    _, _, rank, num_waves = _plan_waves(cfg, keys, cand_op)
    main_mask = U32((1 << seg) - 1)
    ext_bits = U32(((1 << E) - 1) << seg) if E else U32(0)
    has_ext = table.ext_map[pair] >= 0
    is_m = cand_op & found                   # main/ext match frees its bit

    def body(c):
        w, evo, new_slot, okv = c
        sel = cand_op & (rank == w)
        word = evo[pair]
        canon = _canonical_occupancy(cfg, word, parity)
        empty = ~canon & (main_mask | jnp.where(has_ext, ext_bits, U32(0)))
        okw = sel & (empty != U32(0))
        pos = _select_bit(empty, jnp.zeros((B,), I32))
        ns = jnp.where(pos < seg,
                       jnp.where(parity == 0, pos, S - 1 - pos),
                       S + (pos - seg))
        flip = (U32(1) << ns.astype(U32)) | jnp.where(
            is_m, U32(1) << jnp.maximum(mslot, 0).astype(U32), U32(0))
        evo = evo.at[jnp.where(okw, pair, drop)].set(
            word ^ flip, mode="drop")
        return w + 1, evo, jnp.where(okw, ns, new_slot), okv | okw

    _, evo, new_slot, ok = jax.lax.while_loop(
        lambda c: c[0] < num_waves, body,
        (jnp.zeros((), I32), table.indicator, jnp.zeros((B,), I32),
         jnp.zeros((B,), jnp.bool_)))
    okm = ok & ~in_stash
    oks = ok & in_stash
    eidx = jnp.maximum(table.ext_map[pair], 0)
    ok, okm, oks, new_slot, eidx, evo = _pin(
        (ok, okm, oks, new_slot, eidx, evo))

    # phase 1: payload rows (ONE flat scatter covers keys and values —
    # key rows in [0, P*S), value rows in [P*S, 2*P*S); ext rows
    # cond-skipped)
    is_ext = new_slot >= S
    okp = ok & ~is_ext
    slotf = pair * S + jnp.minimum(new_slot, S - 1)
    pay = jnp.concatenate([table.keys.reshape(P * S, KEY_LANES),
                           table.vals.reshape(P * S, VAL_LANES)]).at[
        jnp.concatenate([jnp.where(okp, slotf, drop),
                         jnp.where(okp, slotf + P * S, drop)])].set(
        jnp.concatenate([keys, vals]), mode="drop")
    tkeys = pay[:P * S].reshape(P, S, KEY_LANES)
    tvals = pay[P * S:].reshape(P, S, VAL_LANES)

    def ext_rows(kv):
        ek, ev = kv
        PE, EX = ek.shape[0], ek.shape[1]
        eix = jnp.where(ok & is_ext,
                        eidx * EX + jnp.maximum(new_slot - S, 0), drop)
        return (ek.reshape(PE * EX, KEY_LANES).at[eix].set(
                    keys, mode="drop").reshape(ek.shape),
                ev.reshape(PE * EX, VAL_LANES).at[eix].set(
                    vals, mode="drop").reshape(ev.shape))
    tek, tev = jax.lax.cond(jnp.any(ok & is_ext), ext_rows,
                            lambda kv: kv, (table.ext_keys, table.ext_vals))

    # fingerprint fields of the claimed slots (disjoint 2-bit fields) and
    # the per-pair version bumps: ONE flat scatter-add carries all three
    # side words (version bumps in [0,P), fp clear masks in [P,3P), fp new
    # fields in [3P,5P)) — scatter dispatch dominates this pass on CPU
    okf = ok & ~is_ext
    fpv = fingerprint(keys)
    fw = jnp.minimum(new_slot, S - 1) // _FPW
    fsh = U32(FP_SLOT_BITS) * (new_slot % _FPW).astype(U32)
    fflat = pair * 2 + fw
    sidxs = jnp.concatenate([jnp.where(ok, pair, drop),
                             jnp.where(okf, P + fflat, drop),
                             jnp.where(okf, 3 * P + fflat, drop)])
    supd = jnp.concatenate([jnp.ones((B,), U32),
                            U32(FP_MASK) << fsh,
                            (fpv & U32(FP_MASK)) << fsh])
    buf = jnp.zeros((5 * P,), U32).at[sidxs].add(supd, mode="drop")
    vadd, fclear, fnew = (buf[:P], buf[P:3 * P].reshape(P, 2),
                          buf[3 * P:].reshape(P, 2))

    # phase 2: indicator words straight from the evolved copy (equal to the
    # serial per-op XOR chain), version bumps as per-pair sums
    table = table._replace(
        keys=tkeys, vals=tvals, ext_keys=tek, ext_vals=tev,
        indicator=evo, version=table.version + vadd,
        fp=(table.fp & ~fclear) | fnew)
    pm = 2 * jnp.sum(okm).astype(I32)
    if cfg.stash_slots:
        # stash relocation tail (commit first: the main copy wins by probe
        # priority, so the meta clear only removes a shadowed entry),
        # gated on an actual relocation so all-main batches skip it
        def stash_tail(sm_fp):
            sm, fp = sm_fp
            w = jnp.where(oks, sidx, drop)
            pw = jnp.where(oks, pair, drop)
            return (sm.at[w].set(U32(0), mode="drop"),
                    fp.at[pw, 1].add(-(U32(1) << U32(STASH_CNT_SHIFT)),
                                     mode="drop"))
        sm, fp = jax.lax.cond(jnp.any(oks), stash_tail, lambda x: x,
                              (table.stash_meta, table.fp))
        table = table._replace(stash_meta=sm, fp=fp)
        pm = pm + 3 * jnp.sum(oks).astype(I32)
    return table, ok, pm, unsafe


@functools.partial(jax.jit, static_argnums=0,
                   static_argnames=("probe", "qblock", "interpret"))
def update(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
           mask=None, *, probe: str = "gather", qblock: int = 8,
           interpret: bool = True):
    """Server-side batched out-of-place update on the wave engine.
    2 PM writes/op; both bit-flips land in ONE atomic indicator store
    (3 writes when the op relocates a stash entry into the main row).

    One fused pass commits the whole batch (new-slot allocation replayed
    on a (P,) word copy); only pairs with duplicate targets fall back to
    the exact residual wave loop, whose trip count is bounded by those
    cohorts alone.  ``probe`` selects the match backend
    (see `_mutation_match`)."""
    keys, vals, active = _batch_arrays(keys, vals, mask)
    table, ok, pm, unsafe = _update_fused(cfg, table, keys, vals, active,
                                          probe=probe, qblock=qblock,
                                          interpret=interpret)

    # residual wave loop: ranks are planned over the UNSAFE (duplicate-
    # target-pair) ops alone, so the trip count is bounded by the
    # contended cohorts — zero trips for the common duplicate-free batch
    pair, parity, rank, num_waves = _plan_waves(cfg, keys, unsafe)

    def body(c):
        w, t, okw, pmw = c
        t, wok, wpm = _update_wave(cfg, t, keys, vals, pair, parity,
                                   rank == w)
        return w + 1, t, okw | wok, pmw + wpm

    _, table, ok, pm = jax.lax.while_loop(
        lambda c: c[0] < num_waves, body,
        (jnp.zeros((), I32), table, ok, pm))
    ctr = pmem.CostLedger.zero().add(pm_writes=pm, ops=jnp.sum(active))
    return table, ok, ctr


# ---------------------------------------------------------------------------
# parallel (conflict-resolved) insert — one wave of the engine; used by the
# serving page table, where a batch touches mostly-distinct pairs.  Same-pair
# duplicates past the first are reported for retry (batch-order priority ==
# lock order).  Unlike the old O(B^2) all-pairs conflict matrix this costs
# one argsort, and extension groups CAN be granted (prefix-sum allocation).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=0)
def insert_parallel(cfg: ContinuityConfig, table: ContinuityTable, keys, vals,
                    mask=None):
    keys, vals, active = _batch_arrays(keys, vals, mask)
    pair, parity, rank, _ = _plan_waves(cfg, keys, active)
    table, ok, _, _ = _insert_wave(cfg, table, keys, vals, pair, parity,
                                   rank == 0)
    retry = active & ~ok
    return table, ok, retry


# ---------------------------------------------------------------------------
# resizing (paper §III-C "Log-free Resizing") + recovery
# ---------------------------------------------------------------------------

def extract_items(cfg: ContinuityConfig, table: ContinuityTable):
    """All live (key, value) slots as flat arrays + validity mask (jittable)."""
    P, S, E = cfg.num_pairs, cfg.slots_per_pair, cfg.ext_slots
    bits = (table.indicator[:, None] >> jnp.arange(S, dtype=U32)[None]) & U32(1)
    mkeys = table.keys.reshape(P * S, KEY_LANES)
    mvals = table.vals.reshape(P * S, VAL_LANES)
    mmask = (bits == 1).reshape(P * S)
    ebits = (table.indicator[:, None] >> (S + jnp.arange(E, dtype=U32))[None]) & U32(1)
    has = table.ext_map >= 0
    PE = cfg.ext_pool_pairs
    # scatter pair-order ext validity into pool order
    pool_mask = jnp.zeros((PE, E), jnp.bool_).at[
        jnp.where(has, table.ext_map, PE), :].set(
        (ebits == 1) & has[:, None], mode="drop")
    ekeys = table.ext_keys.reshape(PE * E, KEY_LANES)
    evals = table.ext_vals.reshape(PE * E, VAL_LANES)
    keys = jnp.concatenate([mkeys, ekeys], 0)
    vals = jnp.concatenate([mvals, evals], 0)
    mask = jnp.concatenate([mmask, pool_mask.reshape(PE * E)], 0)
    if cfg.stash_slots:
        keys = jnp.concatenate([keys, table.stash_keys], 0)
        vals = jnp.concatenate([vals, table.stash_vals], 0)
        mask = jnp.concatenate([mask, table.stash_meta != U32(0)], 0)
    return keys, vals, mask


def resize(cfg: ContinuityConfig, table: ContinuityTable, factor: int = 2):
    """Rehash into a table with ``factor``x buckets (fast batched path).

    The crash-faithful per-item path (insert-to-new THEN delete-from-old, two
    indicator commits in that order) is ``resize_stepwise``; this batched path
    produces the same final state and is what production resizing uses.
    """
    new_cfg = cfg.grow(factor)
    new = create(new_cfg)
    # seed versions strictly above the old table's max: stamps cached against
    # the old geometry can then never compare equal to a post-resize stamp
    new = new._replace(version=jnp.full(
        (new_cfg.num_pairs,), jnp.max(table.version) + U32(1), U32))
    keys, vals, mask = extract_items(cfg, table)
    new, _, _ = insert(new_cfg, new, keys, vals, mask)
    return new_cfg, new


def resize_stepwise(cfg, table, new_cfg, new_table, max_items: int):
    """Move up to ``max_items`` live items old->new, one at a time, with the
    paper's ordering: insert into new, commit, then delete from old. Returns
    (old, new, moved). Used by crash-recovery tests (host loop)."""
    moved = 0
    for _ in range(max_items):
        keys, vals, mask = extract_items(cfg, table)
        idx = int(jnp.argmax(mask))
        if not bool(mask[idx]):
            break
        k, v = keys[idx], vals[idx]
        new_table, ok, _ = _insert_one(new_cfg, new_table, k, v)
        table, _, _ = _delete_one(cfg, table, k)
        moved += int(ok)
    return table, new_table, moved


def recover(cfg, old_table, new_cfg, new_table):
    """Paper §III-C recovery: after restart mid-resize, for each item still in
    the old table, delete it if it already reached the new table, otherwise
    move it (insert-to-new then delete-from-old); finishes the resize."""
    keys, vals, mask = extract_items(cfg, old_table)
    kn, vn, mn = np.asarray(keys), np.asarray(vals), np.asarray(mask)
    for i in np.nonzero(mn)[0]:
        k = jnp.asarray(kn[i])
        v = jnp.asarray(vn[i])
        res = lookup(new_cfg, new_table, k[None])
        if not bool(res.found[0]):
            new_table, _, _ = _insert_one(new_cfg, new_table, k, v)
        old_table, _, _ = _delete_one(cfg, old_table, k)
    return old_table, new_table


def items_host(cfg, table):
    """Live items as a python dict {key_bytes: value_bytes} (tests only)."""
    keys, vals, mask = extract_items(cfg, table)
    kn, vn, mn = np.asarray(keys), np.asarray(vals), np.asarray(mask)
    out = {}
    for i in np.nonzero(mn)[0]:
        out[kn[i].tobytes()] = vn[i].tobytes()
    return out


# ---------------------------------------------------------------------------
# incremental split — online resize, one bucket-group cohort per step
# ---------------------------------------------------------------------------
# The intra-node port of cluster/migration.py's copy -> token-cutover ->
# cleanup protocol.  Growing ``num_buckets`` by an even factor preserves a
# key's bucket parity and maps every item homed at old pair p into a new
# pair of the form p + k*P (k < factor), so ONE old pair is a closed
# rehash cohort: copy its items into the new table (insert-if-absent, so a
# replayed step is idempotent), flip the pair's 8-byte split token with ONE
# atomic store — the commit point that switches routing — then delete the
# moved items from the old table as cleanup.  Live traffic routes purely
# by token: lookups and writes for a key go to the new table iff
# ``token[old_pair] != 0``, so at every crash prefix the union of
# {old items, token==0} and {new items, token==1} is exactly the original
# item set, with zero log records (see repro.consistency.split).

class SplitState(NamedTuple):
    """In-flight incremental resize (functional, host-stepped)."""

    token: jnp.ndarray      # (P_old,) uint32 — 1 = cohort cut over
    next_pair: jnp.ndarray  # () int32 — first pair not yet moved


def split_begin(cfg: ContinuityConfig, table: ContinuityTable,
                factor: int = 2):
    """Open an incremental split to a ``factor``x table.  Returns
    ``(new_cfg, new_table, state)``; the old table is untouched."""
    assert factor >= 2 and factor % 2 == 0, "parity-preserving factors only"
    new_cfg = cfg.grow(factor)
    new = create(new_cfg)
    # seed versions strictly above the old table's max: stamps cached against
    # the old geometry can then never compare equal to a post-split stamp
    new = new._replace(version=jnp.full(
        (new_cfg.num_pairs,), jnp.max(table.version) + U32(1), U32))
    state = SplitState(token=jnp.zeros((cfg.num_pairs,), U32),
                       next_pair=jnp.zeros((), I32))
    return new_cfg, new, state


@functools.partial(jax.jit, static_argnums=0)
def cohort_items(cfg: ContinuityConfig, table: ContinuityTable, pair):
    """Fixed-shape candidate rows of ONE pair: (keys, vals, live) where the
    row count S+E+T is static — so every split step jits to one program."""
    S, E, T = cfg.slots_per_pair, cfg.ext_slots, cfg.stash_slots
    pair = jnp.asarray(pair, I32)
    ind = table.indicator[pair]
    mmask = ((ind >> jnp.arange(S, dtype=U32)) & U32(1)) == 1
    keys = table.keys[pair]
    vals = table.vals[pair]
    eidx = table.ext_map[pair]
    ebits = ((ind >> (U32(S) + jnp.arange(E, dtype=U32))) & U32(1)) == 1
    emask = ebits & (eidx >= 0)
    safe_e = jnp.maximum(eidx, 0)
    keys = jnp.concatenate([keys, table.ext_keys[safe_e]], 0)
    vals = jnp.concatenate([vals, table.ext_vals[safe_e]], 0)
    mask = jnp.concatenate([mmask, emask], 0)
    if T:
        smask = table.stash_meta == pair.astype(U32) + U32(1)
        keys = jnp.concatenate([keys, table.stash_keys], 0)
        vals = jnp.concatenate([vals, table.stash_vals], 0)
        mask = jnp.concatenate([mask, smask], 0)
    return keys, vals, mask


def split_step(cfg: ContinuityConfig, table: ContinuityTable,
               new_cfg: ContinuityConfig, new_table: ContinuityTable,
               state: SplitState, budget: int = 1):
    """Move up to ``budget`` cohorts (host loop; each cohort is the paper's
    insert-to-new -> commit -> delete-from-old ordering, with the token
    flip as the single routing commit point).  Returns
    ``(table, new_table, state, moved)``."""
    P = cfg.num_pairs
    start = int(state.next_pair)
    token = state.token
    moved = 0
    for p in range(start, min(start + int(budget), P)):
        kc, vc, mc = cohort_items(cfg, table, p)
        already = lookup(new_cfg, new_table, kc).found
        new_table, okn, _ = insert(new_cfg, new_table, kc, vc,
                                   mc & ~already)       # idempotent copy
        token = token.at[p].set(U32(1))                 # atomic cutover
        table, _, _ = delete(cfg, table, kc, mc)        # cleanup
        moved += int(jnp.sum(mc))
    state = SplitState(token=token,
                       next_pair=jnp.asarray(min(start + int(budget), P), I32))
    return table, new_table, state, moved


def split_done(cfg: ContinuityConfig, state: SplitState) -> bool:
    return int(state.next_pair) >= cfg.num_pairs


def split_route(cfg: ContinuityConfig, state: SplitState, keys):
    """(B,) bool — True where the key's cohort has cut over (route to new)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    pair, _ = locate(cfg, keys)
    return state.token[pair] != U32(0)


def split_lookup(cfg: ContinuityConfig, table: ContinuityTable,
                 new_cfg: ContinuityConfig, new_table: ContinuityTable,
                 state: SplitState, keys) -> LookupResult:
    """Token-routed dual read during a split: each key consults exactly the
    table its token names (the copy phase holds items in BOTH tables, but
    the un-flipped token keeps the old copy authoritative until cutover)."""
    keys = jnp.asarray(keys, U32).reshape(-1, KEY_LANES)
    cut = split_route(cfg, state, keys)
    r_old = lookup(cfg, table, keys)
    r_new = lookup(new_cfg, new_table, keys)
    pick = lambda a, b: jnp.where(
        cut.reshape(cut.shape + (1,) * (a.ndim - 1)), b, a)
    return LookupResult(*(pick(a, b) for a, b in zip(r_old, r_new)))

"""Distributed continuity KV store over a device mesh (shard_map).

Maps the paper's deployment onto a TPU pod:
  * the table's segment pairs are block-partitioned over the DATA axis —
    each data shard is one "server" owning a contiguous pair range
    (its "PM region");
  * CLIENT READS (paper §III-B): each device batches its lookups, routes the
    16-byte keys to owners with ONE all_to_all, owners respond with the RAW
    SEGMENT PAYLOAD (keys row + vals row + indicator) with a second
    all_to_all, and the CLIENT probes locally — the one-sided RDMA semantics:
    the owner CPU does no probing, bytes-on-wire = one segment per lookup.
    Compare level hashing: up to FOUR non-contiguous bucket fetches per
    lookup = 4x response payload (bench_access_amp / the collective roofline
    term make this visible);
  * SERVER WRITES: insert/update/delete requests are routed to owners
    (write-with-immediate), applied scan-serialized per owner (lock order =
    batch order), acknowledged in the return all_to_all.

Routing uses fixed per-destination capacity buckets (all_to_all needs static
shapes); overflowing keys are reported for retry — the RDMA analogue of a
full send queue.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import continuity as ch
from repro.core import pmem
from repro.core.continuity import (FP_BYTES, INDICATOR_BYTES, KEY_LANES, SLOT_BYTES,
                                   VAL_LANES, ContinuityConfig,
                                   ContinuityTable, _commit_indicator,
                                   _gather_candidates, _scatter_payload,
                                   locate)
from repro.rdma import verbs as rv

U32 = jnp.uint32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    table: ContinuityConfig       # GLOBAL table geometry
    num_shards: int               # servers (= product of sharded axes)
    capacity_factor: float = 2.0  # routing bucket headroom
    axis_names: tuple = ("data",)  # mesh axes the store shards over

    def __post_init__(self):
        assert self.table.num_pairs % self.num_shards == 0
        assert self.table.ext_frac == 0.0, \
            "distributed store uses ext-free tables (DESIGN.md §5)"

    @property
    def pairs_per_shard(self) -> int:
        return self.table.num_pairs // self.num_shards

    @property
    def local_cfg(self) -> ContinuityConfig:
        return dataclasses.replace(self.table,
                                   num_buckets=2 * self.pairs_per_shard)

    def cap(self, batch_per_shard: int) -> int:
        c = int(batch_per_shard / self.num_shards * self.capacity_factor) + 1
        return min(c, batch_per_shard)


def create_sharded(cfg: StoreConfig) -> ContinuityTable:
    """Global table as one pytree; shard dim 0 (pairs) over 'data'."""
    return ch.create(cfg.table)


def table_pspec(axes=("data",)) -> ContinuityTable:
    """Pair-indexed leaves shard over the store axes; the (unused, ext-free)
    extension pool and the scalar counters stay replicated. Live-item counting
    in distributed mode is ``sharded_count`` (indicator popcount)."""
    d = P(axes)
    return ContinuityTable(keys=d, vals=d, indicator=d, version=d,
                           ext_keys=P(), ext_vals=P(), ext_map=d,
                           ext_count=P(), count=P(), fp=d,
                           stash_keys=P(), stash_vals=P(), stash_meta=P())


def sharded_count(table: ContinuityTable) -> jnp.ndarray:
    """Live items from indicator popcounts (count scalar is not maintained
    across shards)."""
    bits = (table.indicator[:, None] >>
            jnp.arange(32, dtype=U32)[None]) & U32(1)
    return jnp.sum(bits).astype(I32)


def _route(cfg: StoreConfig, payload, owner, mask):
    """Scatter ``payload`` (B, F) into per-destination capacity buckets and
    all_to_all them. Returns (recv (S, CAP, F), recv_slot bookkeeping)."""
    axis = cfg.axis_names
    B = owner.shape[0]
    S = cfg.num_shards
    CAP = cfg.cap(B)
    # rank of each key within its destination bucket
    onehot = (owner[:, None] == jnp.arange(S)[None]) & mask[:, None]
    rank = jnp.cumsum(onehot, axis=0) - 1
    rank = jnp.sum(rank * onehot, axis=1)                    # (B,)
    ok = mask & (rank < CAP)
    drop = jnp.iinfo(I32).max
    o = jnp.where(ok, owner, drop)
    r = jnp.where(ok, rank, drop)
    send = jnp.zeros((S, CAP) + payload.shape[1:], payload.dtype)
    send = send.at[o, r].set(payload, mode="drop")
    live = jnp.zeros((S, CAP), jnp.bool_).at[o, r].set(ok, mode="drop")
    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
    rlive = jax.lax.all_to_all(live, axis, 0, 0, tiled=False)
    return recv, rlive, (o, r, ok)


def _route_back(cfg: StoreConfig, reply, route_meta):
    """Inverse all_to_all + gather each key's reply back to its batch slot."""
    axis = cfg.axis_names
    o, r, ok = route_meta
    back = jax.lax.all_to_all(reply, axis, 0, 0, tiled=False)  # (S, CAP, F)
    safe_o = jnp.minimum(o, cfg.num_shards - 1)
    safe_r = jnp.minimum(r, back.shape[1] - 1)
    out = back[safe_o, safe_r]
    return out, ok


class DLookupResult(NamedTuple):
    found: jnp.ndarray     # (B,) bool
    values: jnp.ndarray    # (B, VAL_LANES)
    routed: jnp.ndarray    # (B,) bool — False = routing overflow, retry
    ledger: pmem.CostLedger  # GLOBAL client-batch wire ledger (verb-plan-
    #                          derived, psum-replicated over the mesh)


def _client_probe(cfg: ContinuityConfig, seg_keys, seg_vals, indicator,
                  parity, qkeys, live):
    """Client-side probe of fetched segments (one per query)."""
    import numpy as np
    from repro.core.continuity import _probe_order
    probe = jnp.asarray(_probe_order(cfg))[:, :cfg.seg_slots]  # main slots
    cand = probe[parity]                                       # (B, C)
    bits = (indicator[:, None] >> cand.astype(U32)) & U32(1)
    ck = jnp.take_along_axis(seg_keys, cand[..., None], 1)
    cv = jnp.take_along_axis(seg_vals, cand[..., None], 1)
    match = (bits == 1) & jnp.all(ck == qkeys[:, None, :], -1) & live[:, None]
    found = jnp.any(match, -1)
    first = jnp.argmax(match, -1)
    vals = jnp.take_along_axis(cv, first[:, None, None], 1)[:, 0]
    return found, jnp.where(found[:, None], vals, 0)


def make_lookup(cfg: StoreConfig, mesh):
    """Build the jitted distributed lookup:
    (table, keys (B,4), mask (B,)) -> DLookupResult. ``keys`` sharded over
    the store axes on dim 0 (each device = one client batch). Routing uses
    fixed capacity buckets; retry unrouted keys with an updated ``mask``
    (deterministic ranks mean identical batches overflow identically)."""
    S = cfg.num_shards
    Ppairs = cfg.pairs_per_shard
    lcfg = cfg.local_cfg
    SL = cfg.table.slots_per_pair

    def impl(table: ContinuityTable, keys, mask):
        keys = keys.reshape(-1, KEY_LANES)
        pair, parity = locate(cfg.table, keys)          # GLOBAL pair ids
        owner = pair // Ppairs
        req = jnp.concatenate([pair[:, None].astype(U32),
                               parity[:, None].astype(U32)], 1)
        recv, rlive, meta = _route(cfg, req, owner, mask)

        # owner side: fetch raw segment payload (NO probing — one-sided read)
        lp = jnp.maximum(recv[..., 0].astype(I32) % Ppairs, 0)
        seg_k = table.keys[lp]                          # (S, CAP, SL, KL)
        seg_v = table.vals[lp]
        ind = table.indicator[lp]                       # (S, CAP)
        reply = jnp.concatenate([
            seg_k.reshape(*lp.shape, SL * KEY_LANES).astype(U32),
            seg_v.reshape(*lp.shape, SL * VAL_LANES).astype(U32),
            ind[..., None].astype(U32)], -1)
        out, ok = _route_back(cfg, reply, meta)

        # client side: local probe of the fetched segment
        B = keys.shape[0]
        rkeys = out[:, :SL * KEY_LANES].reshape(B, SL, KEY_LANES)
        rvals = out[:, SL * KEY_LANES:SL * (KEY_LANES + VAL_LANES)] \
            .reshape(B, SL, VAL_LANES)
        rind = out[:, -1]
        found, vals = _client_probe(cfg.table, rkeys, rvals, rind, parity,
                                    keys, ok)
        # wire accounting via the verb plan (one whole-row READ per routed
        # key, addressed by GLOBAL pair), same helper as the local stores;
        # unrouted/masked rows count neither reads nor ops (the CostLedger
        # contract), and psum makes the ledger genuinely replicated (its
        # out-spec is P())
        row_bytes = INDICATOR_BYTES + FP_BYTES + SL * SLOT_BYTES
        plan = rv.pack(B, [(jnp.where(ok, rv.READ, rv.NOOP), rv.REGION_TABLE,
                            pair * row_bytes, row_bytes, 0, False)])
        ledger = rv.ledger_from_plan(plan)._replace(
            ops=jnp.sum(ok.astype(jnp.int32)))
        ledger = jax.tree.map(
            lambda x: jax.lax.psum(x, cfg.axis_names), ledger)
        return DLookupResult(found, vals, ok, ledger)

    ax = P(cfg.axis_names)
    sm = shard_map(impl, mesh=mesh,
                   in_specs=(table_pspec(cfg.axis_names), ax, ax),
                   out_specs=DLookupResult(
                       ax, ax, ax,
                       pmem.CostLedger(P(), P(), P(), P())),
                   check_rep=False)
    jitted = jax.jit(sm)

    def lookup(table, keys, mask=None):
        if mask is None:
            mask = jnp.ones((keys.shape[0],), jnp.bool_)
        return jitted(table, keys, mask)
    return lookup


OP_INSERT, OP_UPDATE, OP_DELETE = 1, 2, 3


def _apply_routed_writes(lcfg: ContinuityConfig, table: ContinuityTable,
                         pair_l, parity, op, keys, vals, live):
    """Owner-side scan-serialized write application with indicator commits.

    Works on LOCAL pair ids with the GLOBAL parity (segment geometry is
    per-pair, so locality only changes the pair index)."""
    def one(table, x):
        pr, pa, o, k, v, lv = x
        can_alloc = jnp.zeros((1,), jnp.bool_)          # ext-free tables
        cand, ckeys, cvals, valid, slot_ok, is_ext, _ = _gather_candidates(
            lcfg, table, pr[None], pa[None], ext_allowed=can_alloc)
        match = valid & jnp.all(ckeys == k[None, None, :], -1)
        mfound = jnp.any(match, -1)[0]
        mfirst = jnp.argmax(match, -1)
        mslot = jnp.take_along_axis(cand, mfirst[:, None], 1)[0, 0]
        empty = (~valid) & slot_ok
        has_empty = jnp.any(empty, -1)[0]
        efirst = jnp.argmax(empty, -1)
        eslot = jnp.take_along_axis(cand, efirst[:, None], 1)[0, 0]
        word = table.indicator[pr]

        ins = lv & (o == OP_INSERT) & has_empty & ~mfound
        upd = lv & (o == OP_UPDATE) & mfound & has_empty
        dele = lv & (o == OP_DELETE) & mfound

        wslot = jnp.where(dele, 0, eslot)
        do_payload = ins | upd
        table = _scatter_payload(table, do_payload, pr, wslot,
                                 jnp.zeros((), I32), k, v, lcfg.slots_per_pair)
        bit_new = U32(1) << eslot.astype(U32)
        bit_old = U32(1) << jnp.maximum(mslot, 0).astype(U32)
        word = jnp.where(ins, word | bit_new, word)
        word = jnp.where(upd, (word | bit_new) ^ bit_old, word)
        word = jnp.where(dele, word & ~bit_old, word)
        table = _commit_indicator(table, ins | upd | dele, pr, word)
        status = jnp.where(ins | upd | dele, 1, 0).astype(U32)
        return table, status

    table, status = jax.lax.scan(
        one, table, (pair_l, parity, op, keys, vals, live))
    return table, status


def make_write(cfg: StoreConfig, mesh):
    """Jitted distributed write: (table, op (B,), keys, vals) ->
    (table, ok (B,), routed (B,))."""
    Ppairs = cfg.pairs_per_shard
    lcfg = cfg.local_cfg

    def impl(table, op, keys, vals):
        keys = keys.reshape(-1, KEY_LANES)
        vals = vals.reshape(-1, VAL_LANES)
        pair, parity = locate(cfg.table, keys)
        owner = pair // Ppairs
        mask = op > 0
        req = jnp.concatenate([
            pair[:, None].astype(U32), parity[:, None].astype(U32),
            op[:, None].astype(U32), keys, vals], 1)
        recv, rlive, meta = _route(cfg, req, owner, mask)
        S, CAP, F = recv.shape
        flat = recv.reshape(S * CAP, F)
        table, status = _apply_routed_writes(
            lcfg, table,
            (flat[:, 0].astype(I32) % Ppairs),
            flat[:, 1].astype(I32),
            flat[:, 2].astype(I32),
            flat[:, 3:3 + KEY_LANES],
            flat[:, 3 + KEY_LANES:3 + KEY_LANES + VAL_LANES],
            rlive.reshape(S * CAP))
        reply = status.reshape(S, CAP, 1)
        out, ok = _route_back(cfg, reply, meta)
        return table, (out[:, 0] == 1) & ok, ok

    ax = P(cfg.axis_names)
    sm = shard_map(impl, mesh=mesh,
                   in_specs=(table_pspec(cfg.axis_names), ax, ax, ax),
                   out_specs=(table_pspec(cfg.axis_names), ax, ax),
                   check_rep=False)
    return jax.jit(sm, donate_argnums=0)


# ---------------------------------------------------------------------------
# level-hashing-style distributed lookup (for the access-amplification
# comparison at pod scale — EXPERIMENTS.md §Paper-validation)
# ---------------------------------------------------------------------------

def make_lookup_multifetch(cfg: StoreConfig, mesh, fetches: int = 4):
    """A lookup that must fetch ``fetches`` NON-CONTIGUOUS candidate rows per
    key (level hashing's four buckets / CCEH's directory+bucket), issued in
    parallel like independent one-sided reads. Wire cost per key =
    ``fetches`` x (request + bucket-row payload) and ``fetches`` x the
    message count, vs continuity's single segment. Rows are derived with
    independent hashes; the reply payload is one BUCKET row (a quarter
    segment) per fetch. This function exists purely to measure the
    collective-term difference — it is not a functional store."""
    from repro.core.hashfn import hash128
    Ppairs = cfg.pairs_per_shard
    SL = cfg.table.slots_per_pair
    bucket_lanes = SL // 4 * (KEY_LANES + VAL_LANES)   # quarter row

    def impl(table: ContinuityTable, keys, mask):
        keys = keys.reshape(-1, KEY_LANES)
        B = keys.shape[0]
        reps = []
        for f in range(fetches):
            h = hash128(keys, seed=(0x9E3779B9 * (f + 1)) & 0xFFFFFFFF)
            pair = (h % jnp.uint32(cfg.table.num_pairs)).astype(I32)
            owner = pair // Ppairs
            req = pair[:, None].astype(U32)
            recv, rlive, meta = _route(cfg, req, owner, mask)
            lp = jnp.maximum(recv[..., 0].astype(I32) % Ppairs, 0)
            rowk = table.keys[lp][..., :SL // 4, :]
            rowv = table.vals[lp][..., :SL // 4, :]
            reply = jnp.concatenate(
                [rowk.reshape(*lp.shape, -1), rowv.reshape(*lp.shape, -1),
                 table.indicator[lp][..., None]], -1).astype(U32)
            out, ok = _route_back(cfg, reply, meta)
            reps.append((out, ok))
        found = jnp.zeros((B,), jnp.bool_)
        for out, ok in reps:     # client-side probe of each fetched bucket
            rk = out[:, :SL // 4 * KEY_LANES].reshape(B, SL // 4, KEY_LANES)
            hit = jnp.any(jnp.all(rk == keys[:, None, :], -1), -1) & ok
            found = found | hit
        return found

    ax = P(cfg.axis_names)
    sm = shard_map(impl, mesh=mesh,
                   in_specs=(table_pspec(cfg.axis_names), ax, ax),
                   out_specs=ax, check_rep=False)
    jitted = jax.jit(sm)

    def lookup(table, keys, mask=None):
        if mask is None:
            mask = jnp.ones((keys.shape[0],), jnp.bool_)
        return jitted(table, keys, mask)
    return lookup

"""Vectorized hash functions over 128-bit keys represented as (..., 4) uint32 lanes.

JAX's default (no-x64) mode has no uint64, so all mixing is done in uint32
arithmetic (murmur3-style fmix32 + boost-style lane combining). These are the
hash functions used by every scheme in ``repro.core`` so that bucket placement
is identical across continuity / level / P-FaRM-KV comparisons.
"""

from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32

_C1 = U32(0xCC9E2D51)
_C2 = U32(0x1B873593)
_FMIX1 = U32(0x85EBCA6B)
_FMIX2 = U32(0xC2B2AE35)
_GOLDEN = U32(0x9E3779B9)


def _rotl32(x: jnp.ndarray, r: int) -> jnp.ndarray:
    return (x << U32(r)) | (x >> U32(32 - r))


def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer: full-avalanche 32-bit mixer."""
    h = h.astype(U32)
    h ^= h >> U32(16)
    h *= _FMIX1
    h ^= h >> U32(13)
    h *= _FMIX2
    h ^= h >> U32(16)
    return h


def hash128(key: jnp.ndarray, seed: int = 0) -> jnp.ndarray:
    """Hash (..., 4) uint32 key lanes -> (...,) uint32, murmur3-32 style.

    Used for home-bucket placement (Eq. (1) of the paper: ``hash(k) % N``).
    """
    assert key.shape[-1] == 4, key.shape
    k = key.astype(U32)
    h = U32(seed) ^ U32(16)  # len = 16 bytes
    for i in range(4):
        lane = k[..., i]
        lane = lane * _C1
        lane = _rotl32(lane, 15)
        lane = lane * _C2
        h = h ^ lane
        h = _rotl32(h, 13)
        h = h * U32(5) + U32(0xE6546B64)
    return fmix32(h)


def hash128_2(key: jnp.ndarray) -> jnp.ndarray:
    """Independent second hash (for two-hash-function schemes, e.g. level hashing)."""
    return hash128(key, seed=0x5BD1E995)


def mix_pair(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Combine two uint32 words into one well-mixed uint32 (content hashing)."""
    a = a.astype(U32)
    b = b.astype(U32)
    return fmix32(a ^ (b + _GOLDEN + (a << U32(6)) + (a >> U32(2))))


def fold_u32(words: jnp.ndarray) -> jnp.ndarray:
    """Fold (..., L) uint32 words into (...,) uint32 (e.g. token-prefix hashing
    for content-addressed KV-cache pages)."""
    h = jnp.full(words.shape[:-1], U32(0x811C9DC5), dtype=U32)
    for i in range(words.shape[-1]):
        h = mix_pair(h, words[..., i])
    return h

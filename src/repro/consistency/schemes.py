"""Per-scheme consistency handlers: traced write paths + recovery.

One handler per registered scheme, each providing:

  * ``trace_one``  — emit the ordered `PMStore` sequence of ONE op (the
    instrumented twin of the scheme's write path; final states are
    semantically identical to the scheme's own serial op, which the crash
    tests assert);
  * ``visible``    — the durable item set of a (possibly crashed) PM
    image, derived exactly the way a reader would: commit words first,
    payload only where the commit bit is set;
  * ``recover``    — the scheme's restart procedure on a crashed image,
    returning the repaired state plus a `RecoveryReport` of what it had
    to read and fix.

Consistency disciplines reproduced (the paper's Table I contrast):

  scheme      discipline                                recovery input
  ---------   ---------------------------------------   -----------------
  continuity  payload -> ONE atomic indicator commit    indicator words ONLY
  level       out-of-place + token commit; undo log     token words + undo log
              on the in-place update fallback;            + duplicate scan
              5-store crash-safe slot movement
  pfarm       RECIPE redo logging around every op       token words + FULL
              (log entry, commit, stores, invalidate)     redo-log replay
  dense       split commit on insert/delete; update     live bits only — torn
              is an UNPROTECTED in-place store            updates survive (the
                                                          matrix's neg. control)

States are numpy dicts (see `repro.consistency.trace`); routing decisions
(hash -> pair/bucket) call the scheme modules' own jitted hash functions
once per batch so traced placement can never drift from the real one.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

import repro.core.continuity as ch
import repro.core.dense as dn
import repro.core.level as lv
import repro.core.pfarm as pf
from repro.consistency.recovery import RecoveryReport, popcount
from repro.consistency.trace import (LOG, PMStore, PMTrace, State, SubWrite,
                                     TraceOp, apply_store, copy_state)

U32 = np.uint32
KL = ch.KEY_LANES
VL = ch.VAL_LANES
SLOT_BYTES = ch.SLOT_BYTES

LOG_ROWS = 64        # PM log region: entries (reused round-robin per op id)
LOG_LANES = 32       # uint32 lanes per entry (status word + images)

# log entry status (lane 0)
L_FREE, L_COMMITTED = 0, 1


def _key_bytes(k: np.ndarray) -> bytes:
    return np.asarray(k, U32).tobytes()


class _Handler:
    """Shared plumbing; subclasses fill in the scheme specifics."""

    name = "?"
    table_cls = None
    uses_log = False

    def init_state(self, cfg, table) -> State:
        if isinstance(table, dict):
            state = copy_state(table)
        else:
            state = {f: np.array(np.asarray(v))
                     for f, v in zip(table._fields, table)}
        if self.uses_log and LOG not in state:
            state[LOG] = np.zeros((LOG_ROWS, LOG_LANES), U32)
        return state

    def state_to_table(self, cfg, state: State):
        return self.table_cls(**{f: jnp.asarray(state[f])
                                 for f in self.table_cls._fields})

    def route(self, cfg, keys: np.ndarray):
        """Per-batch hash routing (ONE jitted call; numpy out)."""
        raise NotImplementedError

    def trace_one(self, cfg, state: State, op: str, op_id: int,
                  key: np.ndarray, val: Optional[np.ndarray],
                  route) -> Tuple[List[PMStore], bool, str]:
        fn = getattr(self, f"_trace_{op}")
        return fn(cfg, state, op_id, key, val, route)

    def visible(self, cfg, state: State) -> Dict[bytes, bytes]:
        raise NotImplementedError

    def recover(self, cfg, state: State) -> Tuple[State, RecoveryReport]:
        raise NotImplementedError

    def rebuild_counts(self, cfg, state: State) -> State:
        """Recompute the derived (non-traced) counters IN PLACE semantics:
        returns a copy with count/alloc counters rebuilt, but performs NO
        repairs (no log replay, no duplicate scan) — for reconciling a
        fully-applied trace, where repairs must not run (e.g. level
        legitimately holds duplicate keys after a duplicate insert)."""
        raise NotImplementedError

    # -- log helpers (logging schemes) --------------------------------------
    def _log_addr(self, row: int, lane: int = 0) -> int:
        return 1 << 30 | row * LOG_LANES * 4 + lane * 4

    def _log_entry(self, op_id: int, row: int, lanes: np.ndarray,
                   nlanes: int) -> PMStore:
        """Write the entry body: lanes ``1..nlanes`` (the status lane is
        untouched — still FREE).  The store covers exactly the bytes it
        writes, so address ranges and torn-split counts agree."""
        return PMStore(op_id, "log", False, self._log_addr(row, 1),
                       4 * (nlanes - 1), True,
                       (SubWrite(LOG, (row, slice(1, nlanes)),
                                 lanes[1:nlanes]),))

    def _log_status(self, op_id: int, row: int, status: int,
                    kind: str) -> PMStore:
        return PMStore(op_id, kind, True, self._log_addr(row), 8, True,
                       (SubWrite(LOG, (row, 0), np.uint32(status)),))


# ---------------------------------------------------------------------------
# continuity — payload then ONE atomic indicator commit; zero log
# ---------------------------------------------------------------------------

class ContinuityHandler(_Handler):
    name = "continuity"
    table_cls = ch.ContinuityTable
    uses_log = False

    # symbolic PM layout: [pair rows: indicator | fp | slots] [ext pool]
    # [ext_map] [stash: (meta | slot) entries]
    def _row_bytes(self, cfg) -> int:
        return ch.INDICATOR_BYTES + ch.FP_BYTES + cfg.slots_per_pair * SLOT_BYTES

    def _addr_indicator(self, cfg, pair) -> int:
        return pair * self._row_bytes(cfg)

    def _addr_fp(self, cfg, pair, lane) -> int:
        return pair * self._row_bytes(cfg) + ch.INDICATOR_BYTES + lane * 4

    def _addr_ext(self, cfg, eidx, eslot) -> int:
        ext_base = cfg.num_pairs * self._row_bytes(cfg)
        return ext_base + (eidx * cfg.ext_slots + eslot) * SLOT_BYTES

    def _addr_map(self, cfg, pair) -> int:
        return (cfg.num_pairs * self._row_bytes(cfg)
                + cfg.ext_pool_pairs * cfg.ext_slots * SLOT_BYTES + pair * 4)

    def _addr_stash(self, cfg, sidx) -> int:
        base = (cfg.num_pairs * self._row_bytes(cfg)
                + cfg.ext_pool_pairs * cfg.ext_slots * SLOT_BYTES
                + cfg.num_pairs * 4)
        return base + sidx * (ch.STASH_META_BYTES + SLOT_BYTES)

    def route(self, cfg, keys):
        pair, parity = ch.locate(cfg, jnp.asarray(keys, jnp.uint32))
        return np.asarray(pair), np.asarray(parity)

    def wave_ranks(self, cfg, keys, active):
        """Intra-pair cohort ranks — the engine's wave schedule."""
        _, _, rank, _ = ch._plan_waves(cfg, jnp.asarray(keys, jnp.uint32),
                                       jnp.asarray(active))
        return np.asarray(rank)

    # -- numpy probe (twin of ch._gather_candidates, one key) ---------------
    def _probe(self, cfg, st, pair, parity, ext_allowed):
        cand = np.asarray(ch._probe_order(cfg))[parity]        # (C,)
        S = cfg.slots_per_pair
        is_ext = cand >= S
        ind = int(st["indicator"][pair])
        bits = (ind >> cand.astype(np.int64)) & 1
        eidx = int(st["ext_map"][pair])
        has_ext = eidx >= 0
        slot_ok = np.where(is_ext, has_ext or ext_allowed, True).astype(bool)
        valid = ((bits == 1) & slot_ok
                 & np.where(is_ext, has_ext, True).astype(bool))
        return cand, valid, slot_ok, has_ext, eidx

    def _cand_keys(self, cfg, st, pair, cand, eidx):
        S = cfg.slots_per_pair
        out = np.zeros((len(cand), KL), U32)
        for j, c in enumerate(cand):
            if c >= S:
                if eidx >= 0:
                    out[j] = st["ext_keys"][eidx, c - S]
            else:
                out[j] = st["keys"][pair, c]
        return out

    def _payload(self, cfg, op_id, pair, slot, eidx, key, val) -> PMStore:
        S = cfg.slots_per_pair
        if slot < S:
            writes = (SubWrite("keys", (pair, slot), key),
                      SubWrite("vals", (pair, slot), val))
            addr = (pair * self._row_bytes(cfg) + ch.INDICATOR_BYTES
                    + ch.FP_BYTES + slot * SLOT_BYTES)
        else:
            writes = (SubWrite("ext_keys", (eidx, slot - S), key),
                      SubWrite("ext_vals", (eidx, slot - S), val))
            addr = self._addr_ext(cfg, eidx, slot - S)
        return PMStore(op_id, "payload", False, addr, SLOT_BYTES, True, writes)

    def _commit(self, cfg, op_id, st, pair, word) -> PMStore:
        # the version bump shares the ONE atomic 8-byte store: the word's
        # upper half is the per-pair committed-op counter (see
        # ch.ContinuityTable.version) — same record, same nbytes, still
        # untearable, zero extra PM writes
        return PMStore(op_id, "indicator", True, self._addr_indicator(cfg, pair),
                       ch.INDICATOR_BYTES, True,
                       (SubWrite("indicator", (pair,), np.uint32(word)),
                        SubWrite("version", (pair,),
                                 U32(int(st["version"][pair]) + 1))))

    def _vbump(self, cfg, op_id, st, pair) -> PMStore:
        """Version-only store of the 8-byte commit word: stash commits live
        OUTSIDE the indicator bits, but cached stamps must still be
        invalidated, so the pair's counter half is bumped on its own."""
        return PMStore(op_id, "vbump", True, self._addr_indicator(cfg, pair),
                       ch.INDICATOR_BYTES, True,
                       (SubWrite("version", (pair,),
                                 U32(int(st["version"][pair]) + 1)),))

    def _fp_rec(self, cfg, op_id, pair, lane, word, kind="fp") -> PMStore:
        """Fingerprint-word lane store: probe metadata only — never the
        visibility commit point, never Table-I-counted."""
        return PMStore(op_id, kind, True, self._addr_fp(cfg, pair, lane), 4,
                       False, (SubWrite("fp", (pair, lane), np.uint32(word)),))

    def _fp_field_word(self, st, pair, slot, key) -> Tuple[int, U32]:
        """(lane, new-lane-word) setting ``slot``'s fingerprint field."""
        lane = slot // ch._FPW
        sh = ch.FP_SLOT_BITS * (slot % ch._FPW)
        fpv = int(np.asarray(ch.fingerprint(key))[0])
        old = int(st["fp"][pair, lane])
        return lane, U32((old & ~(ch.FP_MASK << sh)) | (fpv << sh))

    def _smeta(self, cfg, op_id, sidx, value) -> PMStore:
        return PMStore(op_id, "smeta", True, self._addr_stash(cfg, sidx), 8,
                       True, (SubWrite("stash_meta", (sidx,),
                                       np.uint32(value)),))

    def _stash_payload(self, cfg, op_id, sidx, key, val) -> PMStore:
        return PMStore(op_id, "payload", False,
                       self._addr_stash(cfg, sidx) + ch.STASH_META_BYTES,
                       SLOT_BYTES, True,
                       (SubWrite("stash_keys", (sidx,), key),
                        SubWrite("stash_vals", (sidx,), val)))

    def _stash_match(self, cfg, st, pair, key):
        """First live stash entry holding ``key`` homed at ``pair`` (-1)."""
        if not cfg.stash_slots:
            return -1
        m = ((st["stash_meta"] == U32(pair + 1))
             & np.all(st["stash_keys"] == key[None], axis=-1))
        return int(np.argmax(m)) if m.any() else -1

    def _trace_insert(self, cfg, st, op_id, key, val, route):
        pair, parity = int(route[0][op_id]), int(route[1][op_id])
        can_alloc = (cfg.ext_frac > 0
                     and int(st["ext_count"]) < cfg.ext_pool_pairs)
        cand, valid, slot_ok, has_ext, eidx = self._probe(
            cfg, st, pair, parity, can_alloc)
        empty = ~valid & slot_ok
        if not empty.any():
            # stash fallback: count-byte bump (conservative overcount is
            # harmless: an extra read, never a missed item) -> payload ->
            # version bump -> atomic meta-word commit.  3 counted writes.
            if not cfg.stash_slots:
                return [], False, "full"
            free = st["stash_meta"][:cfg.stash_slots] == 0
            if not free.any():
                return [], False, "full"
            sidx = int(np.argmax(free))
            cnt = U32(int(st["fp"][pair, 1]) + (1 << ch.STASH_CNT_SHIFT))
            recs = [self._fp_rec(cfg, op_id, pair, 1, cnt),
                    self._stash_payload(cfg, op_id, sidx, key, val),
                    self._vbump(cfg, op_id, st, pair),
                    self._smeta(cfg, op_id, sidx, pair + 1)]
            return recs, True, "stash"
        slot = int(cand[int(np.argmax(empty))])
        S = cfg.slots_per_pair
        recs = []
        if slot >= S and not has_ext:
            eidx = int(st["ext_count"])
            # extension-group grant: allocator metadata (pool-row ownership),
            # persisted but not Table-I-counted (amortized in the paper)
            recs.append(PMStore(
                op_id, "meta", True, self._addr_map(cfg, pair), 8, False,
                (SubWrite("ext_map", (pair,), np.int32(eidx)),
                 SubWrite("ext_count", (), np.int32(eidx + 1)))))
        recs.append(self._payload(cfg, op_id, pair, slot, eidx, key, val))
        if slot < S:
            # the NEW slot's fingerprint field lands before the commit, so
            # the fp pre-filter is always correct for visible slots
            lane, word = self._fp_field_word(st, pair, slot, key)
            recs.append(self._fp_rec(cfg, op_id, pair, lane, word))
        word = U32(int(st["indicator"][pair]) | (1 << slot))
        recs.append(self._commit(cfg, op_id, st, pair, word))
        return recs, True, ("ext" if slot >= S else "main")

    def _trace_update(self, cfg, st, op_id, key, val, route):
        pair, parity = int(route[0][op_id]), int(route[1][op_id])
        cand, valid, slot_ok, has_ext, eidx = self._probe(
            cfg, st, pair, parity, False)
        match = valid & np.all(self._cand_keys(cfg, st, pair, cand, eidx)
                               == key[None], axis=-1)
        empty = ~valid & slot_ok
        sidx = -1 if match.any() else self._stash_match(cfg, st, pair, key)
        if not ((match.any() or sidx >= 0) and empty.any()):
            return [], False, "miss"
        new = int(cand[int(np.argmax(empty))])
        S = cfg.slots_per_pair
        recs = [self._payload(cfg, op_id, pair, new, eidx, key, val)]
        fp1 = int(st["fp"][pair, 1])
        if new < S:
            lane, word = self._fp_field_word(st, pair, new, key)
            recs.append(self._fp_rec(cfg, op_id, pair, lane, word))
            if lane == 1:
                fp1 = int(word)
        if sidx >= 0:
            # stash relocation: the ONE indicator store makes the main copy
            # win by probe priority; meta clear + count decrement follow as
            # shadowed-entry cleanup (count stays >= live at every prefix)
            word = U32(int(st["indicator"][pair]) ^ (1 << new))
            recs.append(self._commit(cfg, op_id, st, pair, word))
            recs.append(self._smeta(cfg, op_id, sidx, 0))
            recs.append(self._fp_rec(
                cfg, op_id, pair, 1,
                U32(fp1 - (1 << ch.STASH_CNT_SHIFT)), kind="fpcnt"))
            return recs, True, "stash-move"
        old = int(cand[int(np.argmax(match))])
        # out-of-place: BOTH bit flips land in the one atomic word store
        word = U32(int(st["indicator"][pair]) ^ ((1 << old) | (1 << new)))
        recs.append(self._commit(cfg, op_id, st, pair, word))
        return recs, True, "oop"

    def _trace_delete(self, cfg, st, op_id, key, val, route):
        pair, parity = int(route[0][op_id]), int(route[1][op_id])
        cand, valid, _, _, eidx = self._probe(cfg, st, pair, parity, False)
        match = valid & np.all(self._cand_keys(cfg, st, pair, cand, eidx)
                               == key[None], axis=-1)
        if not match.any():
            sidx = self._stash_match(cfg, st, pair, key)
            if sidx < 0:
                return [], False, "miss"
            # stash delete: version bump -> atomic meta clear (the commit)
            # -> count-byte decrement AFTER the commit, so the count never
            # reads LOW of the live occupancy at any crash prefix
            recs = [self._vbump(cfg, op_id, st, pair),
                    self._smeta(cfg, op_id, sidx, 0),
                    self._fp_rec(
                        cfg, op_id, pair, 1,
                        U32(int(st["fp"][pair, 1])
                            - (1 << ch.STASH_CNT_SHIFT)), kind="fpcnt")]
            return recs, True, "stash"
        slot = int(cand[int(np.argmax(match))])
        word = U32(int(st["indicator"][pair]) & ~(1 << slot))
        return [self._commit(cfg, op_id, st, pair, word)], True, "main"

    def visible(self, cfg, st):
        out = {}
        S, E = cfg.slots_per_pair, cfg.ext_slots
        for p in range(cfg.num_pairs):
            ind = int(st["indicator"][p])
            for s in range(S):
                if ind >> s & 1:
                    out[_key_bytes(st["keys"][p, s])] = \
                        _key_bytes(st["vals"][p, s])
            e = int(st["ext_map"][p])
            if e >= 0:
                for s in range(E):
                    if ind >> (S + s) & 1:
                        out[_key_bytes(st["ext_keys"][e, s])] = \
                            _key_bytes(st["ext_vals"][e, s])
        for i in range(cfg.stash_slots):
            # probe priority main > ext > stash: a stash copy never shadows
            # a committed row copy (mid-relocation crash states rely on it)
            if int(st["stash_meta"][i]) != 0:
                out.setdefault(_key_bytes(st["stash_keys"][i]),
                               _key_bytes(st["stash_vals"][i]))
        return out

    def rebuild_counts(self, cfg, st):
        st = copy_state(st)
        S, E = cfg.slots_per_pair, cfg.ext_slots
        ind = st["indicator"].astype(U32)
        main = int(popcount(ind & U32((1 << S) - 1)).sum())
        mapped = st["ext_map"] >= 0
        ext = 0
        if E:
            ext = int((popcount((ind >> U32(S)) & U32((1 << E) - 1))
                       * mapped).sum())
        stash = 0
        if cfg.stash_slots:
            stash = int((st["stash_meta"][:cfg.stash_slots] != 0).sum())
        st["count"] = np.asarray(main + ext + stash, st["count"].dtype)
        st["ext_count"] = np.asarray(int(mapped.sum()),
                                     st["ext_count"].dtype)
        return st

    def _row_has_key(self, cfg, st, pair, kb) -> bool:
        S, E = cfg.slots_per_pair, cfg.ext_slots
        ind = int(st["indicator"][pair])
        for s in range(S):
            if ind >> s & 1 and _key_bytes(st["keys"][pair, s]) == kb:
                return True
        e = int(st["ext_map"][pair])
        if e >= 0:
            for s in range(E):
                if (ind >> (S + s) & 1
                        and _key_bytes(st["ext_keys"][e, s]) == kb):
                    return True
        return False

    def recover(self, cfg, st):
        """Paper §III-C restart: a PURE function of the commit words — the
        indicator words plus (stash-enabled geometries only) the stash meta
        words.  A crashed stash relocation can leave a live meta word whose
        entry is shadowed by the committed row copy; recovery clears those
        (bounded by the stash size, the only payload reads it ever does)
        and re-derives the per-pair count bytes.  No log, ever."""
        st = copy_state(st)
        T = cfg.stash_slots
        dups = scanned = 0
        if T:
            seen = set()
            for i in np.nonzero(st["stash_meta"][:T] != 0)[0]:
                scanned += 1
                pair = int(st["stash_meta"][i]) - 1
                kb = _key_bytes(st["stash_keys"][i])
                if self._row_has_key(cfg, st, pair, kb) or (pair, kb) in seen:
                    st["stash_meta"][i] = U32(0)
                    dups += 1
                else:
                    seen.add((pair, kb))
            for p in range(cfg.num_pairs):
                cnt = int((st["stash_meta"][:T] == U32(p + 1)).sum())
                st["fp"][p, 1] = U32(
                    (int(st["fp"][p, 1]) & ((1 << ch.STASH_CNT_SHIFT) - 1))
                    | (cnt << ch.STASH_CNT_SHIFT))
        return self.rebuild_counts(cfg, st), RecoveryReport(
            self.name, commit_words_scanned=cfg.num_pairs + T,
            payload_slots_scanned=scanned, duplicates_cleared=dups)


# ---------------------------------------------------------------------------
# dense — split commit on insert/delete; UNPROTECTED in-place update
# ---------------------------------------------------------------------------

class DenseHandler(_Handler):
    name = "dense"
    table_cls = dn.DenseTable
    uses_log = False

    def route(self, cfg, keys):
        return None

    def _match(self, st, key):
        m = st["live"] & np.all(st["keys"] == key[None], axis=-1)
        return (int(np.argmax(m)) if m.any() else -1)

    def _trace_insert(self, cfg, st, op_id, key, val, route):
        free = ~st["live"]
        if not free.any():
            return [], False, "full"
        slot = int(np.argmax(free))
        recs = [
            PMStore(op_id, "payload", False, slot * SLOT_BYTES, SLOT_BYTES,
                    True, (SubWrite("keys", (slot,), key),
                           SubWrite("vals", (slot,), val))),
            PMStore(op_id, "token", True,
                    cfg.capacity * SLOT_BYTES + slot, 1, True,
                    (SubWrite("live", (slot,), np.bool_(True)),)),
        ]
        return recs, True, "plain"

    def _trace_update(self, cfg, st, op_id, key, val, route):
        slot = self._match(st, key)
        if slot < 0:
            return [], False, "miss"
        # in-place value store on a LIVE slot: 1 PM write, no out-of-place
        # commit, no log — a crash mid-store leaves a torn VISIBLE value
        # (the matrix's negative control).
        rec = PMStore(op_id, "payload", False,
                      slot * SLOT_BYTES + KL * 4, VL * 4, True,
                      (SubWrite("vals", (slot,), val),))
        return [rec], True, "inplace"

    def _trace_delete(self, cfg, st, op_id, key, val, route):
        slot = self._match(st, key)
        if slot < 0:
            return [], False, "miss"
        rec = PMStore(op_id, "token", True, cfg.capacity * SLOT_BYTES + slot,
                      1, True, (SubWrite("live", (slot,), np.bool_(False)),))
        return [rec], True, "plain"

    def visible(self, cfg, st):
        return {_key_bytes(st["keys"][i]): _key_bytes(st["vals"][i])
                for i in range(cfg.capacity) if st["live"][i]}

    def rebuild_counts(self, cfg, st):
        st = copy_state(st)
        st["count"] = np.asarray(int(st["live"].sum()), st["count"].dtype)
        return st

    def recover(self, cfg, st):
        return self.rebuild_counts(cfg, st), RecoveryReport(
            self.name, commit_words_scanned=cfg.capacity)


# ---------------------------------------------------------------------------
# level — token commits; undo log on the in-place update fallback;
#         crash-safe 5-store slot movement + recovery duplicate scan
# ---------------------------------------------------------------------------

# log entry lanes: [status, region, bucket, slot, old_val*4, ...]
LV_REGION, LV_BUCKET, LV_SLOT, LV_OLD = 1, 2, 3, 4


class LevelHandler(_Handler):
    name = "level"
    table_cls = lv.LevelTable
    uses_log = True

    _REGIONS = (("tkeys", "tvals", "ttok"), ("bkeys", "bvals", "btok"))

    def route(self, cfg, keys):
        return np.asarray(lv._cand_buckets(cfg, jnp.asarray(keys, jnp.uint32)))

    def _addr_bucket(self, cfg, top, bucket, slot=0) -> int:
        base = 0 if top else cfg.num_top * cfg.bucket_bytes
        return base + bucket * cfg.bucket_bytes + slot * SLOT_BYTES

    def _addr_tok(self, cfg, top, bucket) -> int:
        return (self._addr_bucket(cfg, top, bucket)
                + cfg.bucket_slots * SLOT_BYTES)

    def _tok(self, st, top, bucket) -> int:
        return int(st[self._REGIONS[0 if top else 1][2]][bucket])

    def _payload(self, cfg, op_id, top, bucket, slot, key, val) -> PMStore:
        kf, vf, _ = self._REGIONS[0 if top else 1]
        return PMStore(op_id, "payload", False,
                       self._addr_bucket(cfg, top, bucket, slot), SLOT_BYTES,
                       True, (SubWrite(kf, (bucket, slot), key),
                              SubWrite(vf, (bucket, slot), val)))

    def _commit(self, cfg, op_id, top, bucket, tok) -> PMStore:
        tf = self._REGIONS[0 if top else 1][2]
        return PMStore(op_id, "token", True, self._addr_tok(cfg, top, bucket),
                       8, True, (SubWrite(tf, (bucket,), np.uint8(tok)),))

    def _lookup(self, cfg, st, key, cand):
        """(found, cand_pos, bucket, slot) in the scheme's probe order."""
        bs = cfg.bucket_slots
        for j in range(4):
            top = j < 2
            b = int(cand[j])
            kf = self._REGIONS[0 if top else 1][0]
            tok = self._tok(st, top, b)
            for s in range(bs):
                if tok >> s & 1 and (st[kf][b, s] == key).all():
                    return True, j, b, s
        return False, -1, -1, -1

    def _trace_insert(self, cfg, st, op_id, key, val, route):
        cand = route[op_id]
        bs = cfg.bucket_slots
        for j in range(4):
            top = j < 2
            b = int(cand[j])
            tok = self._tok(st, top, b)
            for s in range(bs):
                if not tok >> s & 1:
                    recs = [self._payload(cfg, op_id, top, b, s, key, val),
                            self._commit(cfg, op_id, top, b, tok | 1 << s)]
                    return recs, True, "plain"
        # one-movement path: top[h1] slot 0 moves to ITS alternate top bucket.
        # Crash-safe 5-store order (copy, commit copy, clear source bit,
        # write new item, commit) — matches lv._insert_one.
        b0 = int(cand[0])
        mkey = st["tkeys"][b0, 0].copy()
        mval = st["tvals"][b0, 0].copy()
        from repro.core.hashfn import hash128, hash128_2
        a1 = int(np.asarray(hash128(jnp.asarray(mkey[None])))[0]) % cfg.num_top
        a2 = int(np.asarray(hash128_2(jnp.asarray(mkey[None])))[0]) % cfg.num_top
        alt = a2 if a1 == b0 else a1
        atok = self._tok(st, True, alt)
        free = [s for s in range(bs) if not atok >> s & 1]
        if alt == b0 or not free:
            return [], False, "full"
        aslot = free[0]
        tok0 = self._tok(st, True, b0)
        recs = [
            self._payload(cfg, op_id, True, alt, aslot, mkey, mval),
            self._commit(cfg, op_id, True, alt, atok | 1 << aslot),
            self._commit(cfg, op_id, True, b0, tok0 & ~1),
            self._payload(cfg, op_id, True, b0, 0, key, val),
            self._commit(cfg, op_id, True, b0, (tok0 & ~1) | 1),
        ]
        return recs, True, "move"

    def _trace_update(self, cfg, st, op_id, key, val, route):
        cand = route[op_id]
        found, j, b, slot = self._lookup(cfg, st, key, cand)
        if not found:
            return [], False, "miss"
        top = j < 2
        bs = cfg.bucket_slots
        tok = self._tok(st, top, b)
        free = [s for s in range(bs) if not tok >> s & 1]
        if free:
            # log-free out-of-place within the same bucket (2 PM writes)
            es = free[0]
            recs = [self._payload(cfg, op_id, top, b, es, key, val),
                    self._commit(cfg, op_id, top, b,
                                 tok ^ ((1 << es) | (1 << slot)))]
            return recs, True, "oop"
        # bucket full -> logged in-place update (4 PM writes):
        # undo entry, atomic commit, in-place item store, invalidate
        vf = self._REGIONS[0 if top else 1][1]
        row = op_id % LOG_ROWS
        lanes = np.zeros((LOG_LANES,), U32)
        lanes[LV_REGION] = 0 if top else 1
        lanes[LV_BUCKET] = b
        lanes[LV_SLOT] = slot
        lanes[LV_OLD:LV_OLD + VL] = st[vf][b, slot]
        recs = [
            self._log_entry(op_id, row, lanes, LV_OLD + VL),
            self._log_status(op_id, row, L_COMMITTED, "log_commit"),
            PMStore(op_id, "payload", False,
                    self._addr_bucket(cfg, top, b, slot) + KL * 4, VL * 4,
                    True, (SubWrite(vf, (b, slot), val),)),
            self._log_status(op_id, row, L_FREE, "log_free"),
        ]
        return recs, True, "logged"

    def _trace_delete(self, cfg, st, op_id, key, val, route):
        cand = route[op_id]
        found, j, b, slot = self._lookup(cfg, st, key, cand)
        if not found:
            return [], False, "miss"
        top = j < 2
        tok = self._tok(st, top, b)
        return [self._commit(cfg, op_id, top, b, tok & ~(1 << slot))], \
            True, "plain"

    def visible(self, cfg, st):
        out = {}
        for top, n in ((True, cfg.num_top), (False, cfg.num_bottom)):
            kf, vf, _ = self._REGIONS[0 if top else 1]
            for b in range(n):
                tok = self._tok(st, top, b)
                for s in range(cfg.bucket_slots):
                    if tok >> s & 1:
                        out.setdefault(_key_bytes(st[kf][b, s]),
                                       _key_bytes(st[vf][b, s]))
        return out

    def recover(self, cfg, st):
        """Token scan + undo-log rollback + duplicate scan.

        Rollback first: any COMMITTED undo entry means an in-place update
        may have torn — restore the old value image and free the entry.
        Then a full-table duplicate-key scan repairs interrupted movements
        (the moved item can be committed in two buckets; either copy is
        the same (key, value), keep the probe-order-first one).
        """
        st = copy_state(st)
        rep = RecoveryReport(self.name,
                             commit_words_scanned=cfg.num_top + cfg.num_bottom,
                             log_records_scanned=LOG_ROWS)
        for row in range(LOG_ROWS):
            if int(st[LOG][row, 0]) != L_COMMITTED:
                continue
            top = int(st[LOG][row, LV_REGION]) == 0
            b = int(st[LOG][row, LV_BUCKET])
            s = int(st[LOG][row, LV_SLOT])
            vf = self._REGIONS[0 if top else 1][1]
            st[vf][b, s] = st[LOG][row, LV_OLD:LV_OLD + VL]
            st[LOG][row, 0] = L_FREE
            rep.log_records_used += 1
            rep.repairs += 1
        # duplicate scan (reads payload keys of every live slot)
        seen: Dict[bytes, Tuple] = {}
        for top, n in ((True, cfg.num_top), (False, cfg.num_bottom)):
            kf, _, tf = self._REGIONS[0 if top else 1]
            for b in range(n):
                tok = self._tok(st, top, b)
                for s in range(cfg.bucket_slots):
                    if not tok >> s & 1:
                        continue
                    rep.payload_slots_scanned += 1
                    kb = _key_bytes(st[kf][b, s])
                    if kb in seen:
                        st[tf][b] = np.uint8(self._tok(st, top, b)
                                             & ~(1 << s))
                        rep.duplicates_cleared += 1
                        rep.repairs += 1
                    else:
                        seen[kb] = (top, b, s)
        st = self.rebuild_counts(cfg, st)
        return st, rep

    def rebuild_counts(self, cfg, st):
        st = copy_state(st)
        total = int(popcount(st["ttok"]).sum() + popcount(st["btok"]).sum())
        st["count"] = np.asarray(total, st["count"].dtype)
        return st


# ---------------------------------------------------------------------------
# pfarm — RECIPE redo logging: log entry, commit, stores, invalidate
# ---------------------------------------------------------------------------

# log entry lanes: header [status, kind, ntargets, fresh, home, blk,
# prev_head, pad], then per target: [region, bucket, slot, tok_after,
# key*4, val*4] (12 lanes; up to 2 targets for the displacement path)
PF_KIND, PF_NT, PF_FRESH, PF_HOME, PF_BLK, PF_PREV = 1, 2, 3, 4, 5, 6
PF_T0 = 8
PF_TLANES = 12
K_INS, K_UPD, K_DEL = 1, 2, 3


class PFarmHandler(_Handler):
    name = "pfarm"
    table_cls = pf.PFarmTable
    uses_log = True

    def route(self, cfg, keys):
        return np.asarray(pf._home(cfg, jnp.asarray(keys, jnp.uint32)))

    def _addr_bucket(self, cfg, region, b, slot=0) -> int:
        base = 0 if region == 0 else cfg.num_buckets * cfg.block_bytes
        return base + b * cfg.block_bytes + slot * SLOT_BYTES

    def _fields(self, region):
        return (("keys", "vals", "tok") if region == 0
                else ("okeys", "ovals", "otok"))

    def _target_lanes(self, region, b, slot, tok_after, key, val):
        lanes = np.zeros((PF_TLANES,), U32)
        lanes[0], lanes[1], lanes[2], lanes[3] = region, b, slot, tok_after
        lanes[4:4 + KL] = key
        lanes[4 + KL:4 + KL + VL] = val
        return lanes

    def _entry(self, op_id, row, kind, targets, fresh=0, home=0, blk=0,
               prev=0) -> PMStore:
        lanes = np.zeros((LOG_LANES,), U32)
        lanes[PF_KIND], lanes[PF_NT] = kind, len(targets)
        lanes[PF_FRESH], lanes[PF_HOME] = fresh, home
        lanes[PF_BLK], lanes[PF_PREV] = blk, U32(prev)
        for i, t in enumerate(targets):
            lanes[PF_T0 + i * PF_TLANES:PF_T0 + (i + 1) * PF_TLANES] = t
        return self._log_entry(op_id, row, lanes,
                               PF_T0 + len(targets) * PF_TLANES)

    def _store_target(self, cfg, op_id, region, b, slot, tok_after, key, val,
                      scrub=False):
        """The (payload, token) store pair a logged target performs."""
        kf, vf, tf = self._fields(region)
        return [
            PMStore(op_id, "payload", False,
                    self._addr_bucket(cfg, region, b, slot), SLOT_BYTES, True,
                    (SubWrite(kf, (b, slot), key),
                     SubWrite(vf, (b, slot), val))),
            PMStore(op_id, "token", True,
                    self._addr_bucket(cfg, region, b)
                    + cfg.bucket_slots * SLOT_BYTES, 8, True,
                    (SubWrite(tf, (b,), np.uint8(tok_after)),)),
        ]

    def _trace_insert(self, cfg, st, op_id, key, val, route):
        home = int(route[op_id])
        bs, H, N = cfg.bucket_slots, cfg.window, cfg.num_buckets
        win = [(home + j) % N for j in range(H)]
        row = op_id % LOG_ROWS
        for b in win:
            tok = int(st["tok"][b])
            for s in range(bs):
                if not tok >> s & 1:
                    t = self._target_lanes(0, b, s, tok | 1 << s, key, val)
                    recs = [self._entry(op_id, row, K_INS, [t]),
                            self._log_status(op_id, row, L_COMMITTED,
                                             "log_commit")]
                    recs += self._store_target(cfg, op_id, 0, b, s,
                                               tok | 1 << s, key, val)
                    recs.append(self._log_status(op_id, row, L_FREE,
                                                 "log_free"))
                    return recs, True, "plain"
        # window full: ONE displacement attempt (a window item that can move
        # to a free slot in ITS OWN window), else chain an overflow block
        move = self._find_move(cfg, st, win)
        if move is not None:
            (sb, ss), (db, ds) = move
            mkey = st["keys"][sb, ss].copy()
            mval = st["vals"][sb, ss].copy()
            dtok = int(st["tok"][db]) | 1 << ds
            stok_clear = int(st["tok"][sb]) & ~(1 << ss)
            t0 = self._target_lanes(0, db, ds, dtok, mkey, mval)
            t1 = self._target_lanes(0, sb, ss, stok_clear | 1 << ss, key, val)
            recs = [self._entry(op_id, row, K_INS, [t0, t1]),
                    self._log_status(op_id, row, L_COMMITTED, "log_commit")]
            recs += self._store_target(cfg, op_id, 0, db, ds, dtok, mkey, mval)
            recs.append(PMStore(
                op_id, "token", True,
                self._addr_bucket(cfg, 0, sb) + bs * SLOT_BYTES, 8, True,
                (SubWrite("tok", (sb,), np.uint8(stok_clear)),)))
            recs += self._store_target(cfg, op_id, 0, sb, ss,
                                       stok_clear | 1 << ss, key, val)
            recs.append(self._log_status(op_id, row, L_FREE, "log_free"))
            return recs, True, "displace"
        # chain: append to the head block if it has space, else allocate
        head = int(st["head"][home])
        if head >= 0:
            htok = int(st["otok"][head])
            free = [s for s in range(bs) if not htok >> s & 1]
            if free:
                s = free[0]
                t = self._target_lanes(1, head, s, htok | 1 << s, key, val)
                recs = [self._entry(op_id, row, K_INS, [t]),
                        self._log_status(op_id, row, L_COMMITTED,
                                         "log_commit")]
                recs += self._store_target(cfg, op_id, 1, head, s,
                                           htok | 1 << s, key, val)
                recs.append(self._log_status(op_id, row, L_FREE, "log_free"))
                return recs, True, "chain"
        if int(st["ocount"]) >= cfg.pool_blocks:
            return [], False, "full"
        blk = int(st["ocount"])
        t = self._target_lanes(1, blk, 0, 1, key, val)
        recs = [self._entry(op_id, row, K_INS, [t], fresh=1, home=home,
                            blk=blk, prev=head),
                self._log_status(op_id, row, L_COMMITTED, "log_commit")]
        recs += self._store_target(cfg, op_id, 1, blk, 0, 1, key, val)
        # chain pointers: persistent metadata, re-derived from the log on
        # recovery; RECIPE folds them into its flat 5-write cost
        recs.append(PMStore(
            op_id, "meta", True, 1 << 29 | blk * 8, 8, False,
            (SubWrite("onext", (blk,), np.int32(head)),
             SubWrite("head", (home,), np.int32(blk)),
             SubWrite("ocount", (), np.int32(blk + 1)))))
        recs.append(self._log_status(op_id, row, L_FREE, "log_free"))
        return recs, True, "chain"

    def _find_move(self, cfg, st, win):
        """Twin of pf displacement: first window slot whose item can move to
        a free slot of ITS OWN window; returns ((src_b, src_s), (dst_b,
        dst_s)) or None."""
        bs, H, N = cfg.bucket_slots, cfg.window, cfg.num_buckets
        wkeys = np.stack([st["keys"][b] for b in win]).reshape(H * bs, KL)
        whome = np.asarray(pf._home(cfg, jnp.asarray(wkeys)))
        for m in range(H * bs):
            mwin = [(int(whome[m]) + j) % N for j in range(H)]
            for db in mwin:
                tok = int(st["tok"][db])
                for s in range(bs):
                    if not tok >> s & 1:
                        return (win[m // bs], m % bs), (db, s)
        return None

    def _lookup(self, cfg, st, key, home):
        bs, H, N = cfg.bucket_slots, cfg.window, cfg.num_buckets
        for j in range(H):
            b = (home + j) % N
            tok = int(st["tok"][b])
            for s in range(bs):
                if tok >> s & 1 and (st["keys"][b, s] == key).all():
                    return 0, b, s
        cur, hops = int(st["head"][home]), 0
        while cur >= 0 and hops < cfg.max_chain:
            tok = int(st["otok"][cur])
            for s in range(bs):
                if tok >> s & 1 and (st["okeys"][cur, s] == key).all():
                    return 1, cur, s
            cur, hops = int(st["onext"][cur]), hops + 1
        return -1, -1, -1

    def _trace_update(self, cfg, st, op_id, key, val, route):
        region, b, slot = self._lookup(cfg, st, key, int(route[op_id]))
        if region < 0:
            return [], False, "miss"
        kf, vf, tf = self._fields(region)
        tok = int(st[tf][b])
        row = op_id % LOG_ROWS
        t = self._target_lanes(region, b, slot, tok, key, val)
        recs = [self._entry(op_id, row, K_UPD, [t]),
                self._log_status(op_id, row, L_COMMITTED, "log_commit"),
                # logged in-place value store (the undo/redo log is what
                # makes this multi-byte overwrite of a LIVE slot safe)
                PMStore(op_id, "payload", False,
                        self._addr_bucket(cfg, region, b, slot) + KL * 4,
                        VL * 4, True, (SubWrite(vf, (b, slot), val),)),
                PMStore(op_id, "token", True,
                        self._addr_bucket(cfg, region, b)
                        + cfg.bucket_slots * SLOT_BYTES, 8, True,
                        (SubWrite(tf, (b,), np.uint8(tok)),)),
                self._log_status(op_id, row, L_FREE, "log_free")]
        return recs, True, "logged"

    def _trace_delete(self, cfg, st, op_id, key, val, route):
        region, b, slot = self._lookup(cfg, st, key, int(route[op_id]))
        if region < 0:
            return [], False, "miss"
        kf, vf, tf = self._fields(region)
        tok = int(st[tf][b]) & ~(1 << slot)
        row = op_id % LOG_ROWS
        zero = np.zeros((KL,), U32)
        t = self._target_lanes(region, b, slot, tok, zero, zero)
        recs = [self._entry(op_id, row, K_DEL, [t]),
                self._log_status(op_id, row, L_COMMITTED, "log_commit"),
                PMStore(op_id, "payload", False,
                        self._addr_bucket(cfg, region, b, slot), SLOT_BYTES,
                        True, (SubWrite(kf, (b, slot), zero),
                               SubWrite(vf, (b, slot), zero))),
                PMStore(op_id, "token", True,
                        self._addr_bucket(cfg, region, b)
                        + cfg.bucket_slots * SLOT_BYTES, 8, True,
                        (SubWrite(tf, (b,), np.uint8(tok)),)),
                self._log_status(op_id, row, L_FREE, "log_free")]
        return recs, True, "logged"

    def visible(self, cfg, st):
        out = {}
        for b in range(cfg.num_buckets):
            tok = int(st["tok"][b])
            for s in range(cfg.bucket_slots):
                if tok >> s & 1:
                    out.setdefault(_key_bytes(st["keys"][b, s]),
                                   _key_bytes(st["vals"][b, s]))
        for b in range(cfg.pool_blocks):
            tok = int(st["otok"][b])
            for s in range(cfg.bucket_slots):
                if tok >> s & 1:
                    out.setdefault(_key_bytes(st["okeys"][b, s]),
                                   _key_bytes(st["ovals"][b, s]))
        return out

    def recover(self, cfg, st):
        """RECIPE restart: FULL redo-log replay — every committed,
        non-invalidated entry is reapplied against the table (item stores,
        token stores, chain pointers), then freed."""
        st = copy_state(st)
        rep = RecoveryReport(
            self.name,
            commit_words_scanned=cfg.num_buckets + cfg.pool_blocks,
            log_records_scanned=LOG_ROWS)
        for row in range(LOG_ROWS):
            if int(st[LOG][row, 0]) != L_COMMITTED:
                continue
            lanes = st[LOG][row]
            for i in range(int(lanes[PF_NT])):
                t = lanes[PF_T0 + i * PF_TLANES:PF_T0 + (i + 1) * PF_TLANES]
                region, b, slot, tok = (int(t[0]), int(t[1]), int(t[2]),
                                        int(t[3]))
                kf, vf, tf = self._fields(region)
                st[kf][b, slot] = t[4:4 + KL]
                st[vf][b, slot] = t[4 + KL:4 + KL + VL]
                st[tf][b] = np.uint8(tok)
                rep.repairs += 3
            if int(lanes[PF_FRESH]):
                blk, home = int(lanes[PF_BLK]), int(lanes[PF_HOME])
                st["onext"][blk] = np.int32(lanes[PF_PREV])
                st["head"][home] = blk
                rep.repairs += 2
            st[LOG][row, 0] = L_FREE
            rep.log_records_used += 1
        st = self.rebuild_counts(cfg, st)
        return st, rep

    def rebuild_counts(self, cfg, st):
        """Allocator metadata from the chain pointers + token popcounts."""
        st = copy_state(st)
        refs = set()
        for h in range(cfg.num_buckets):
            cur, hops = int(st["head"][h]), 0
            while cur >= 0 and hops <= cfg.pool_blocks:
                refs.add(cur)
                cur, hops = int(st["onext"][cur]), hops + 1
        st["ocount"] = np.asarray(len(refs), st["ocount"].dtype)
        total = int(popcount(st["tok"]).sum() + popcount(st["otok"]).sum())
        st["count"] = np.asarray(total, st["count"].dtype)
        return st


HANDLERS: Dict[str, _Handler] = {h.name: h for h in (
    ContinuityHandler(), DenseHandler(), LevelHandler(), PFarmHandler())}


# ---------------------------------------------------------------------------
# batch tracing
# ---------------------------------------------------------------------------

def trace_batch(handler: _Handler, cfg, table_or_state, op: str,
                keys, vals=None, mask=None,
                order: str = "serial") -> Tuple[State, PMTrace]:
    """Trace a batch op: returns the fully-applied final state + the trace.

    ``order="serial"`` emits records in batch order (the `lax.scan`
    reference schedule).  ``order="wave"`` (continuity only) reorders
    records into the wave engine's schedule — per wave, all payload
    stores then all one-word commits; per-pair commit order is still
    batch order, so the durable final state is identical (asserted by
    tests/test_crash_consistency.py).
    """
    keys = np.asarray(keys, U32).reshape(-1, KL)
    B = keys.shape[0]
    if vals is not None:
        vals = np.asarray(vals, U32).reshape(-1, VL)
    active = (np.ones((B,), bool) if mask is None
              else np.asarray(mask).reshape(B).astype(bool))
    state = handler.init_state(cfg, table_or_state)
    route = handler.route(cfg, keys)
    records: List[PMStore] = []
    ops_meta: List[TraceOp] = []
    for i in range(B):
        if not active[i]:
            ops_meta.append(TraceOp(i, op, False, "masked", keys[i].tobytes(),
                                    None if vals is None
                                    else vals[i].tobytes()))
            continue
        recs, ok, path = handler.trace_one(
            cfg, state, op, i, keys[i],
            None if vals is None else vals[i], route)
        for r in recs:
            apply_store(state, r)
        records.extend(recs)
        ops_meta.append(TraceOp(i, op, ok, path, keys[i].tobytes(),
                                None if vals is None else vals[i].tobytes()))
    if order == "wave":
        assert hasattr(handler, "wave_ranks"), \
            f"{handler.name} has no wave schedule"
        rank = handler.wave_ranks(cfg, keys, active)
        phase = {"vbump": 1, "indicator": 1, "token": 1,
                 "smeta": 2, "fpcnt": 3}
        records = [r for _, r in sorted(
            enumerate(records),
            key=lambda ir: (int(rank[ir[1].op_id]),
                            phase.get(ir[1].kind, 0), ir[1].op_id, ir[0]))]
    return state, PMTrace(handler.name, op, records, ops_meta, order)

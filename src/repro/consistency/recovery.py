"""Recovery accounting: what a restart has to READ and REPAIR per scheme.

The paper's contrast is not "can the scheme recover" (all of them can with
enough machinery) but what recovery COSTS:

  * continuity — a pure function of the per-pair indicator words: scan P
    words, recompute derived counters, done.  ZERO log records exist, zero
    payload bytes are read (`RecoveryReport.log_records_scanned == 0`).
  * level     — token-word scan + rollback of any committed-but-live undo
    log entry (the logged in-place update fallback) + a duplicate-key scan
    (an interrupted slot movement can leave the moved item visible twice).
  * pfarm     — RECIPE redo: token scan + full log scan; every committed,
    non-invalidated entry is replayed against the table.
  * dense     — live-bit scan; in-place updates are UNPROTECTED (1 PM
    write, no log, no out-of-place commit), so a torn update survives
    recovery — the negative control the crash matrix asserts.

`RecoveryReport` is the per-restart cost ledger the `crash_consistency`
benchmark section aggregates into the recovery-work-per-scheme table
(EXPERIMENTS.md §Crash).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RecoveryReport:
    """What one recovery pass read and repaired."""

    scheme: str
    commit_words_scanned: int = 0     # indicator / token words read
    log_records_scanned: int = 0      # log entries examined
    log_records_used: int = 0         # entries rolled back or replayed
    payload_slots_scanned: int = 0    # slots read beyond commit words
    duplicates_cleared: int = 0       # level movement-crash repair
    repairs: int = 0                  # table stores issued by recovery

    def merge(self, other: "RecoveryReport") -> "RecoveryReport":
        assert other.scheme == self.scheme
        return RecoveryReport(
            self.scheme,
            *(getattr(self, f.name) + getattr(other, f.name)
              for f in dataclasses.fields(self)[1:]))

    def log_free(self) -> bool:
        return self.log_records_scanned == 0 and self.log_records_used == 0


def popcount(a: np.ndarray) -> np.ndarray:
    """Per-element set-bit count of an unsigned integer array."""
    a = np.asarray(a)
    return np.unpackbits(a.view(np.uint8), axis=None).reshape(
        a.size, -1).sum(axis=1).reshape(a.shape)

"""PM write traces: the ordered store sequence a batch op issues to PM.

The paper's consistency claim is about what a crash between INDIVIDUAL PM
stores leaves behind, so the unit here is one PM store, not one op.  A
traced op emits `PMStore` records in issue order; each record carries the
symbolic PM address range it covers, whether the store is a single atomic
8-byte unit (the paper's failure-atomicity granule), whether the paper's
Table I counts it as a PM write, and the concrete table-leaf writes it
performs.  A `PMTrace` is the whole batch's sequence plus per-op metadata.

States under tracing are host-side dicts of numpy arrays (one entry per
table leaf, plus a ``LOG`` region for the logging schemes) — cheap to
snapshot, so the crash injector can materialize EVERY prefix of a trace
(and every torn split of a non-atomic multi-chunk store) as its own
crashed state.  Conversion to/from the schemes' jax pytree tables happens
only at the `repro.api` boundary (`repro.consistency.api_glue`).

Atomicity model (paper §III-C):
  * stores with ``nbytes <= ATOMIC_BYTES`` declared ``atomic=True`` happen
    entirely or not at all (the 8-byte atomic indicator/token commit);
  * larger stores persist in ``ATOMIC_BYTES`` chunks in address order — a
    crash mid-store leaves a TORN value: some leading chunks new, the rest
    old.  ``torn_states`` enumerates every such split.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

ATOMIC_BYTES = 8          # failure-atomicity granule (8-byte atomic store)
LOG = "__log__"           # state key of the PM log region (logging schemes)

State = Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class SubWrite:
    """One table-leaf assignment of a PM store: ``state[field][index] = value``."""

    field: str
    index: tuple
    value: np.ndarray


@dataclasses.dataclass(frozen=True)
class PMStore:
    """One PM store instruction (one would-be flush unit).

    ``kind`` labels the protocol role: ``payload`` (slot key/value bytes),
    ``indicator`` / ``token`` (the scheme's atomic commit word), ``log`` /
    ``log_commit`` / ``log_free`` (RECIPE-style log traffic), ``meta``
    (allocator/pointer metadata the schemes rebuild or re-derive on
    recovery; not Table-I-counted).  ``counts_pm`` mirrors the scheme's
    `CostLedger` accounting so traces and ledgers can be reconciled.
    """

    op_id: int
    kind: str
    atomic: bool
    addr: int
    nbytes: int
    counts_pm: bool
    writes: Tuple[SubWrite, ...]

    def __post_init__(self):
        if self.atomic:
            assert self.nbytes <= ATOMIC_BYTES, (
                f"atomic store of {self.nbytes} B exceeds the "
                f"{ATOMIC_BYTES}-byte atomicity granule")


@dataclasses.dataclass(frozen=True)
class TraceOp:
    """Per-op trace metadata: which records belong to op ``op_id``, whether
    the op succeeded, and which write path it took (``path`` is scheme
    vocabulary: ``plain`` / ``move`` / ``chain`` / ``logged`` / ...)."""

    op_id: int
    op: str              # insert | update | delete
    ok: bool
    path: str
    key: bytes           # 16-byte key image (for the checker's expectations)
    val: Optional[bytes]  # 16-byte value image (None for delete)


@dataclasses.dataclass
class PMTrace:
    """Ordered PM store sequence of one batch op + per-op metadata."""

    scheme: str
    op: str
    records: List[PMStore]
    ops: List[TraceOp]
    order: str = "serial"          # serial | wave

    def pm_writes(self) -> int:
        """Table-I-counted PM writes in this trace (matches the ledger)."""
        return sum(1 for r in self.records if r.counts_pm)

    def log_records(self) -> int:
        """Stores into the PM log region (0 for the log-free schemes)."""
        return sum(1 for r in self.records if r.kind.startswith("log"))

    def crash_points(self) -> int:
        """Whole-store crash boundaries (prefixes, incl. the empty one)."""
        return len(self.records) + 1


# ---------------------------------------------------------------------------
# state plumbing
# ---------------------------------------------------------------------------

def copy_state(state: State) -> State:
    return {k: v.copy() for k, v in state.items()}


def apply_store(state: State, rec: PMStore) -> None:
    """Apply one PM store in place."""
    for w in rec.writes:
        arr = state[w.field]
        if w.index == ():
            state[w.field] = np.asarray(w.value, dtype=arr.dtype).reshape(
                arr.shape)
        else:
            arr[w.index] = np.asarray(w.value, dtype=arr.dtype)


def apply_trace(state: State, trace: PMTrace,
                upto: Optional[int] = None) -> State:
    """Return a copy of ``state`` with the first ``upto`` records applied
    (all of them when ``upto`` is None)."""
    out = copy_state(state)
    for rec in trace.records[:upto]:
        apply_store(out, rec)
    return out


# ---------------------------------------------------------------------------
# crash injection
# ---------------------------------------------------------------------------

def _lane_count(value: np.ndarray) -> int:
    return int(np.asarray(value).size)


def torn_variants(state: State, rec: PMStore) -> Iterator[Tuple[int, PMStore]]:
    """Every torn split of a non-atomic store, given the PRE-store state.

    The store's payload persists in ``ATOMIC_BYTES`` chunks in address
    order; yield ``(chunks_done, partial_record)`` for each proper split.
    Lane granularity is uint32 (4 B), so one chunk = 2 lanes.
    """
    if rec.atomic or rec.nbytes <= ATOMIC_BYTES:
        return
    lanes_per_chunk = max(1, ATOMIC_BYTES // 4)
    total_lanes = sum(_lane_count(w.value) for w in rec.writes)
    nchunks = -(-total_lanes // lanes_per_chunk)
    for j in range(1, nchunks):
        keep = j * lanes_per_chunk          # lanes persisted before the crash
        writes, seen = [], 0
        for w in rec.writes:
            n = _lane_count(w.value)
            old = np.asarray(state[w.field][w.index]).reshape(-1)
            new = np.asarray(w.value).reshape(-1)
            take = int(np.clip(keep - seen, 0, n))
            mixed = np.concatenate([new[:take], old[take:]]).reshape(
                np.asarray(w.value).shape)
            writes.append(SubWrite(w.field, w.index, mixed))
            seen += n
        yield j, dataclasses.replace(rec, writes=tuple(writes))


@dataclasses.dataclass(frozen=True)
class CrashState:
    """One simulated power-loss point: the PM image at that instant."""

    label: str           # e.g. "prefix:7" or "torn:7.2"
    state: State
    records_done: int    # whole records fully persisted
    torn: bool


def crash_states(base: State, trace: PMTrace,
                 include_torn: bool = True) -> Iterator[CrashState]:
    """Enumerate every crash point of ``trace`` starting from ``base``:
    the empty prefix, each whole-record prefix, and (optionally) every
    torn split of each non-atomic multi-chunk store."""
    cur = copy_state(base)
    yield CrashState("prefix:0", copy_state(cur), 0, False)
    for i, rec in enumerate(trace.records):
        if include_torn:
            for j, partial in torn_variants(cur, rec):
                torn = copy_state(cur)
                apply_store(torn, partial)
                yield CrashState(f"torn:{i}.{j}", torn, i, True)
        apply_store(cur, rec)
        yield CrashState(f"prefix:{i + 1}", copy_state(cur), i + 1, False)


# ---------------------------------------------------------------------------
# remote persistence (RDMA writes over the transport layer — DESIGN.md §8)
# ---------------------------------------------------------------------------
# When the stores of a trace arrive as one-sided RDMA WRITEs, a store is
# VISIBLE to concurrent readers as soon as the remote NIC ACKs it (it landed
# in the target's cache hierarchy / DDIO buffer) but only PERSISTED once a
# remote-persist fence — the read-after-WRITE flush of Kashyap et al.,
# "Correct, Fast Remote Persistence" — has drained it to the PM media.  A
# power loss on the server therefore cuts BETWEEN the two: readers may have
# observed state the restarted node no longer has.  ``remote_crash_states``
# materializes exactly that cut for every store boundary.

COMMIT_KINDS = ("indicator", "token", "smeta", "log_commit", "log_free")


@dataclasses.dataclass(frozen=True)
class RemoteCrashState:
    """One remote power-loss point under RDMA-write delivery.

    ``visible``   what concurrent clients could have observed (all stores
                  the NIC ACKed up to the cut);
    ``persisted`` what the restarted server actually has (stores up to the
                  last remote-persist fence) — recovery MUST run on this
                  image, not the visible one.
    """

    label: str
    visible: State
    persisted: State
    records_done: int     # stores NIC-visible at the cut
    fenced_done: int      # stores durably persisted at the cut


def fence_every_store(trace: PMTrace) -> Tuple[int, ...]:
    """The strict discipline: a remote-persist fence after EVERY store
    (each WRITE is flushed before the next issues) — visible == persisted
    at every cut, at one dependent round trip per store."""
    return tuple(range(len(trace.records)))


def fence_after_commits(trace: PMTrace) -> Tuple[int, ...]:
    """The schemes' correctness-minimal discipline: fence after every
    commit-word store (and log commit/free).  Payload stores may be lost
    on power failure — harmless, their commit bit never persisted — but no
    COMMITTED op can be observed and then lost."""
    return tuple(i for i, r in enumerate(trace.records)
                 if r.kind in COMMIT_KINDS)


def remote_crash_states(base: State, trace: PMTrace,
                        fences: Optional[Tuple[int, ...]] = None
                        ) -> Iterator[RemoteCrashState]:
    """Cut the remote node's power after each store's NIC ACK: yield the
    (visible, persisted) image pair per cut.  ``fences`` lists record
    indices AFTER which a remote-persist fence completed (default: the
    commit-fence discipline, `fence_after_commits`)."""
    fset = set(fence_after_commits(trace) if fences is None else fences)
    cur = copy_state(base)
    persisted = copy_state(base)
    fenced = 0
    yield RemoteCrashState("remote:0", copy_state(cur), copy_state(persisted),
                           0, 0)
    for i, rec in enumerate(trace.records):
        apply_store(cur, rec)
        if i in fset:
            persisted = copy_state(cur)
            fenced = i + 1
        yield RemoteCrashState(f"remote:{i + 1}", copy_state(cur),
                               copy_state(persisted), i + 1, fenced)


def unpersisted_commits(trace: PMTrace, cs: RemoteCrashState) -> int:
    """Commit-kind stores a client could have OBSERVED at this cut that the
    restarted server lost — the durability violations an unfenced (write-
    combined) delivery admits.  Zero at every cut under the
    `fence_after_commits` discipline."""
    return sum(1 for i, r in enumerate(trace.records)
               if cs.fenced_done <= i < cs.records_done
               and r.kind in COMMIT_KINDS)

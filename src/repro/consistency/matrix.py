"""The crash/scheme matrix: every scheme x insert/update/delete (plus the
cluster's live-migration cell), swept through every crash point — the CI
gate for the consistency subsystem.

Each cell traces a small batch against a pre-loaded store, injects a crash
at every PM-store boundary (plus every torn split of non-atomic stores),
runs the scheme's recovery, and checks atomic per-op visibility
(`repro.consistency.checker`).  The ``migrate`` cell sweeps a live shard
migration (dest copies -> token cutover -> source deletes,
`repro.cluster.migration`) the same way: dual-read resolution must equal
the original item set at EVERY crash prefix, with zero migration log.
Expectations encode the paper's contrast:

  * ``continuity`` — consistent at every crash point with ZERO log
    records (trace contains none, recovery reads none);
  * ``level``      — consistent; the in-place update fallback must
    exercise the undo log (shapes force a full bucket);
  * ``pfarm``      — consistent; EVERY op is RECIPE-logged, so recovery
    must replay log records at mid-op crash points;
  * ``dense``      — insert/delete consistent (split commit); update is
    the documented negative control: an unprotected in-place store whose
    torn states MUST be detected by the checker (proving the checker can
    see real corruption — a built-in mutation test).

Usage:  python -m repro.consistency.matrix [--json OUT.json] [--quiet]
Exit status 0 iff every cell matches its expectation.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Tuple

import numpy as np

from repro import api
from repro.consistency.checker import CaseResult, run_case
from repro.data import ycsb

OPS = ("insert", "update", "delete")
MIGRATE_SCHEMES = ("continuity",)   # schemes the migrate cell sweeps
RESIZE_SCHEMES = ("continuity",)    # schemes the incremental-resize cell sweeps

# (consistent, log_free) expected per cell; None = don't-care
EXPECT: Dict[Tuple[str, str], Tuple[bool, bool]] = {
    ("continuity", "migrate"): (True, True),
    ("continuity", "resize"): (True, True),
    ("continuity", "insert"): (True, True),
    ("continuity", "update"): (True, True),
    ("continuity", "delete"): (True, True),
    ("level", "insert"): (True, True),
    ("level", "update"): (True, False),   # logged fallback must trigger
    ("level", "delete"): (True, True),
    ("pfarm", "insert"): (True, False),
    ("pfarm", "update"): (True, False),
    ("pfarm", "delete"): (True, False),
    ("dense", "insert"): (True, True),
    ("dense", "update"): (False, True),   # torn in-place update DETECTED
    ("dense", "delete"): (True, True),
}

# per-scheme (table_slots, base_items, batch): level runs near-full so the
# update batch hits a full bucket (the logged in-place fallback)
SHAPES: Dict[str, Tuple[int, int, int]] = {
    "continuity": (240, 24, 8),
    "level": (48, 36, 10),
    "pfarm": (96, 20, 8),
    "dense": (64, 24, 8),
}


def _load(scheme: str):
    slots, n_base, n_ops = SHAPES[scheme]
    store = api.make_store(scheme, table_slots=slots)
    rng = np.random.RandomState(7)
    K = ycsb.make_key(np.arange(n_base))
    V = ycsb.make_value(rng, n_base)
    table = store.create()
    table, res = store.insert(table, K, V)
    okn = np.asarray(res.ok)
    return store, table, K[okn], n_ops, rng


def run_cell(scheme: str, op: str, order: str = "serial") -> CaseResult:
    store, table, live_keys, n_ops, rng = _load(scheme)
    n = min(n_ops, live_keys.shape[0])
    if op == "insert":
        keys = ycsb.make_key(np.arange(1000, 1000 + n))
        vals = ycsb.make_value(rng, n)
    else:
        keys = live_keys[:n]
        vals = ycsb.make_value(rng, n) if op == "update" else None
    return run_case(store, table, op, keys, vals, order=order)


def run_matrix(schemes=None, ops=OPS, order: str = "serial"
               ) -> List[CaseResult]:
    """The scheme x write-op cells.  The migrate cell has a different
    result shape (a summary dict, not a `CaseResult`) — ask for it via
    `run_migration_cell` / `run_rows`, not here."""
    for special in ("migrate", "resize"):
        if special in ops:
            raise ValueError(
                f"run_matrix sweeps write ops only; use "
                f"run_{'migration' if special == 'migrate' else special}"
                f"_cell (or run_rows) for {special}")
    schemes = schemes or [s for s in api.available_schemes() if s in SHAPES]
    return [run_cell(s, op, order) for s in schemes for op in ops]


def run_rows(schemes=None, ops=OPS + ("migrate", "resize"),
             order: str = "serial") -> List[dict]:
    """Summary rows for every requested cell, migrate and resize included
    — the ONE inventory the CLI, CI artifact, and library callers share."""
    rows = [summarize(r) for r in
            run_matrix(schemes,
                       tuple(o for o in ops
                             if o not in ("migrate", "resize")), order)]
    if "migrate" in ops:
        rows += [run_migration_cell(s) for s in MIGRATE_SCHEMES
                 if schemes is None or s in schemes]
    if "resize" in ops:
        rows += [run_resize_cell(s) for s in RESIZE_SCHEMES
                 if schemes is None or s in schemes]
    return rows


def run_migration_cell(scheme: str, n_move: int = 6) -> dict:
    """The cluster's live-migration crash cell: sweep every crash prefix
    of dest-copy -> token-cutover -> source-delete and require the
    dual-read-resolved item set to equal the original at every point
    (`repro.cluster.migration.migration_crash_sweep`)."""
    from repro.cluster.migration import migration_crash_sweep
    store, src_table, _, _, _ = _load(scheme)
    keys, vals, live = store._extract(src_table)
    liven = np.asarray(live)
    K = np.asarray(keys, np.uint32)[liven][:n_move]
    V = np.asarray(vals, np.uint32)[liven][:n_move]
    sweep = migration_crash_sweep(store, src_table, store.create(), K, V)
    want = EXPECT.get((scheme, "migrate"), (None, None))
    ok = ((want[0] is None or want[0] == sweep.consistent)
          and (want[1] is None or want[1] == sweep.log_free))
    return {
        "scheme": scheme, "op": "migrate", "order": "serial",
        "paths": ["migrate"],
        "crash_points": sweep.crash_points,
        "torn_points": sweep.torn_points,
        "violations": len(sweep.violations),
        "consistent": sweep.consistent, "log_free": sweep.log_free,
        "trace_log_records": sweep.log_records_in_trace,
        "log_used_points": int(sweep.report.log_records_used > 0),
        "recovery": dataclasses.asdict(sweep.report),
        "expected": list(want),
        "ok": ok,
    }


def run_resize_cell(scheme: str, factor: int = 2) -> dict:
    """The incremental-resize crash cell: sweep every crash prefix of the
    per-cohort copy -> token-cutover -> cleanup trace and require the
    dual-read-resolved item set to equal the original at every point,
    with zero resize log (`repro.consistency.split.split_crash_sweep`)."""
    from repro.consistency.split import split_crash_sweep
    store, table, _, _, _ = _load(scheme)
    sweep = split_crash_sweep(store, table, factor)
    want = EXPECT.get((scheme, "resize"), (None, None))
    ok = ((want[0] is None or want[0] == sweep.consistent)
          and (want[1] is None or want[1] == sweep.log_free))
    return {
        "scheme": scheme, "op": "resize", "order": "serial",
        "paths": ["resize"],
        "crash_points": sweep.crash_points,
        "torn_points": sweep.torn_points,
        "violations": len(sweep.violations),
        "consistent": sweep.consistent, "log_free": sweep.log_free,
        "trace_log_records": sweep.log_records_in_trace,
        "log_used_points": int(sweep.report.log_records_used > 0),
        "recovery": dataclasses.asdict(sweep.report),
        "expected": list(want),
        "ok": ok,
    }


def cell_ok(r: CaseResult) -> bool:
    want = EXPECT.get((r.scheme, r.op))
    if want is None:
        return True
    want_consistent, want_log_free = want
    if want_consistent != r.consistent:
        return False
    if want_log_free is not None and want_log_free != r.log_free:
        return False
    if not r.consistent and not any("torn" in v for v in r.violations):
        return False          # negative control must come from TORN stores
    return True


def summarize(r: CaseResult) -> dict:
    return {
        "scheme": r.scheme, "op": r.op, "order": r.order,
        "paths": sorted(set(r.paths)),
        "crash_points": r.crash_points, "torn_points": r.torn_points,
        "violations": len(r.violations),
        "consistent": r.consistent, "log_free": r.log_free,
        "trace_log_records": r.log_records_in_trace,
        "log_used_points": r.log_used_points,
        "recovery": dataclasses.asdict(r.report),
        "expected": list(EXPECT.get((r.scheme, r.op), (None, None))),
        "ok": cell_ok(r),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--schemes", default=None,
                   help="comma-separated subset (default: all registered)")
    p.add_argument("--ops", default=",".join(OPS + ("migrate", "resize")))
    p.add_argument("--json", default=None, help="write cell summaries here")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)
    schemes = args.schemes.split(",") if args.schemes else None
    rows = run_rows(schemes, tuple(args.ops.split(",")))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    bad = [r for r in rows if not r["ok"]]
    if not args.quiet:
        hdr = (f"{'scheme':<11} {'op':<7} {'crash':>5} {'torn':>5} "
               f"{'viol':>5} {'log':>4} {'dup':>4}  verdict")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['scheme']:<11} {r['op']:<7} {r['crash_points']:>5} "
                  f"{r['torn_points']:>5} {r['violations']:>5} "
                  f"{r['log_used_points']:>4} "
                  f"{r['recovery']['duplicates_cleared']:>4}  "
                  f"{'PASS' if r['ok'] else 'FAIL'}")
        n = sum(r["crash_points"] for r in rows)
        print(f"\n{len(rows)} cells, {n} crash states injected; "
              f"{len(bad)} unexpected")
    for r in bad:
        print(f"FAIL {r['scheme']}/{r['op']}: consistent={r['consistent']} "
              f"log_free={r['log_free']} expected={r['expected']}",
              file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Crash-matrix checker: inject every crash point, recover, verify.

The verified property is the paper's §III-C claim, stated operationally:

  for EVERY prefix of a batch op's PM store trace (and every torn split
  of each non-atomic store), recovery yields a table in which each batch
  op is atomically visible or invisible — insert: the key maps to its
  exact value or is absent; update: the value is exactly-old or
  exactly-new; delete: present-with-old-value or absent — and no
  untouched key changed.

For serial traces the checker additionally asserts the stronger
batch-prefix property: since commits land in batch order, the recovered
item set must equal the base set plus a PREFIX of the batch's committed
ops.  (Wave traces only guarantee per-pair prefix order, so they get the
all-or-nothing check plus durable-final-state equivalence.)

A `CaseResult` aggregates the sweep for one (scheme, op) cell — crash
point counts, violations (expected to be non-empty ONLY for the dense
in-place-update negative control), and the merged `RecoveryReport` that
feeds the recovery-work-per-scheme table.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import PMTrace, crash_states

Items = Dict[bytes, bytes]


def serial_prefix_items(base: Items, trace: PMTrace) -> List[Items]:
    """Item sets after each committed-op prefix, in batch order."""
    out = [dict(base)]
    cur = dict(base)
    for o in trace.ops:
        if not o.ok:
            continue
        if o.op == "delete":
            cur.pop(o.key, None)
        else:
            cur[o.key] = o.val
        out.append(dict(cur))
    return out


def all_or_nothing_violations(base: Items, trace: PMTrace,
                              vis: Items) -> List[str]:
    """Per-op atomic-visibility violations of a recovered item set.

    Assumes each key appears in at most one batch op (the matrix builds
    its batches that way); a multi-op-per-key batch would need the
    per-key op-order closure instead.
    """
    out = []
    op_keys = set()
    for o in trace.ops:
        op_keys.add(o.key)
        if not o.ok:
            continue
        old = base.get(o.key)
        if o.op == "insert":
            allowed = {None, o.val}
        elif o.op == "update":
            allowed = {old, o.val}
        else:
            allowed = {old, None}
        got = vis.get(o.key)
        if got not in allowed:
            out.append(f"op {o.op_id} ({o.op}) torn/partial: key neither "
                       f"old nor new")
    for k, v in base.items():
        if k not in op_keys and vis.get(k) != v:
            out.append("untouched key changed or lost")
    for k in vis:
        if k not in base and k not in op_keys:
            out.append("phantom key appeared")
    return out


@dataclasses.dataclass
class CaseResult:
    scheme: str
    op: str
    order: str
    paths: List[str]                  # per-op write path taken
    crash_points: int
    torn_points: int
    violations: List[str]
    log_records_in_trace: int
    log_used_points: int              # crash points whose recovery read the log
    report: RecoveryReport            # merged over all crash points
    final_items: Items

    @property
    def consistent(self) -> bool:
        return not self.violations

    @property
    def log_free(self) -> bool:
        return self.log_records_in_trace == 0 and self.log_used_points == 0


def run_case(store, table, op: str, keys, vals=None, mask=None,
             order: str = "serial", include_torn: bool = True) -> CaseResult:
    """Sweep every crash point of one traced batch op through recovery."""
    handler = HANDLERS[store.name]
    cfg = store.cfg
    base_state = handler.init_state(cfg, table)
    base_items = handler.visible(cfg, base_state)
    final_state, trace = trace_batch(handler, cfg, base_state, op, keys,
                                     vals, mask, order)
    prefixes = (serial_prefix_items(base_items, trace)
                if order == "serial" else None)
    violations: List[str] = []
    merged: Optional[RecoveryReport] = None
    n_crash = n_torn = log_pts = 0
    for cs in crash_states(base_state, trace, include_torn=include_torn):
        n_crash += 1
        n_torn += int(cs.torn)
        rec_state, report = handler.recover(cfg, cs.state)
        merged = report if merged is None else merged.merge(report)
        log_pts += int(report.log_records_used > 0)
        vis = handler.visible(cfg, rec_state)
        for v in all_or_nothing_violations(base_items, trace, vis):
            violations.append(f"{cs.label}: {v}")
        if prefixes is not None and vis not in prefixes:
            violations.append(f"{cs.label}: recovered set is not a "
                              f"batch-order prefix")
    # the full trace must land on the last committed prefix
    full_rec, _ = handler.recover(cfg, final_state)
    final_items = handler.visible(cfg, full_rec)
    if prefixes is not None and final_items != prefixes[-1]:
        violations.append("full trace: final state != all-committed prefix")
    return CaseResult(
        scheme=store.name, op=op, order=order,
        paths=[o.path for o in trace.ops],
        crash_points=n_crash, torn_points=n_torn, violations=violations,
        log_records_in_trace=trace.log_records(), log_used_points=log_pts,
        report=merged if merged is not None else RecoveryReport(store.name),
        final_items=final_items)

"""Crash-consistent incremental resize: per-cohort COPY -> TOKEN -> CLEANUP.

The online split (`repro.core.continuity.split_begin/split_step`) grows a
table without stopping the world: each OLD pair (one bucket-group cohort)
is moved on its own, under the same one-word-commit discipline the live
migration uses, but with a per-pair token ARRAY instead of one shard-wide
word:

  COPYING   the cohort's items land in the grown table as ordinary traced
            inserts (each individually crash-atomic).  Reads run DUAL: the
            old pair stays authoritative while its token is 0 — a new-side
            copy is only ever a byte-equal duplicate.
  CUTOVER   ONE atomic 8-byte store of the cohort's token flips ownership
            of exactly that pair.  Other pairs are untouched: the split is
            incremental BECAUSE the commit granule is per-cohort.
  CLEANUP   the old pair's items are deleted (each delete crash-atomic;
            leftovers are byte-equal duplicates under dual-read until the
            cohort's window closes).

`split_crash_sweep` proves the matrix-gated invariant: at EVERY crash
prefix of the composite trace (all cohorts' copy/token/cleanup records in
step order, plus every torn split of non-atomic stores), recovering both
tables and resolving reads per-pair by token yields EXACTLY the original
item set — zero loss, zero phantom, zero resize log.

The composite PM image prefixes the two tables' leaves (``old/``,
``new/``) plus the token array, so the EXISTING injector
(`consistency.trace.crash_states`) sweeps it unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import (PMStore, PMTrace, State, SubWrite,
                                     crash_states)

SPLIT_TOKEN = "__split_token__"   # composite-state key of the token array
TOKEN_BASE = 1 << 30              # symbolic PM base of the token words


def _prefix_records(records, tag: str):
    return [dataclasses.replace(
        r, writes=tuple(SubWrite(tag + w.field, w.index, w.value)
                        for w in r.writes))
        for r in records]


def _split_state(state: State, tag: str) -> State:
    n = len(tag)
    return {f[n:]: v for f, v in state.items() if f.startswith(tag)}


def token_record(op_id: int, pair: int) -> PMStore:
    """The cohort cutover commit: one atomic 8-byte store of pair
    ``pair``'s token word (not Table-I-counted — per COHORT, not per op)."""
    return PMStore(op_id, "token", True, TOKEN_BASE + 8 * pair, 8, False,
                   (SubWrite(SPLIT_TOKEN, (pair,), np.uint64(1)),))


def build_split_trace(store, table, factor: int = 2
                      ) -> Tuple[State, PMTrace]:
    """Compose the full incremental-resize PM trace over the prefixed
    joint image: for each old pair in step order, the cohort's new-side
    traced inserts, its token store, then its old-side traced deletes —
    exactly the order `split_step` issues them."""
    handler = HANDLERS[store.name]
    cfg = store.cfg
    new_cfg = cfg.grow(factor)
    old_state = handler.init_state(cfg, table)
    new_state = handler.init_state(new_cfg, store._mod.create(new_cfg))

    items = handler.visible(cfg, old_state)
    kn = (np.frombuffer(b"".join(items.keys()), np.uint32).reshape(-1, 4)
          if items else np.zeros((0, 4), np.uint32))
    vn = (np.frombuffer(b"".join(items.values()), np.uint32).reshape(-1, 4)
          if items else np.zeros((0, 4), np.uint32))
    pairs = np.asarray(handler.route(cfg, kn)[0]) if len(kn) else \
        np.zeros((0,), np.int32)

    base: State = {SPLIT_TOKEN: np.zeros((cfg.num_pairs,), np.uint64)}
    for f, v in old_state.items():
        base["old/" + f] = v.copy()
    for f, v in new_state.items():
        base["new/" + f] = v.copy()

    records: List[PMStore] = []
    ops = []
    for p in range(cfg.num_pairs):
        sel = pairs == p
        kc, vc = kn[sel], vn[sel]
        if len(kc):
            new_state, ins_tr = trace_batch(handler, new_cfg, new_state,
                                            "insert", kc, vc)
            assert all(o.ok for o in ins_tr.ops), \
                f"grown table too full to receive cohort {p}"
            records += _prefix_records(ins_tr.records, "new/")
            ops += ins_tr.ops
        records.append(token_record(len(ops), p))
        if len(kc):
            old_state, del_tr = trace_batch(handler, cfg, old_state,
                                            "delete", kc)
            records += _prefix_records(del_tr.records, "old/")
            ops += del_tr.ops
    return base, PMTrace(store.name, "resize", records, list(ops))


def resolve_dual_read(handler, cfg, new_cfg, state: State
                      ) -> Dict[bytes, bytes]:
    """What a dual-reading client durably sees in a (recovered) composite
    image: per key, the OLD pair is authoritative while its token is 0,
    the grown table after.  Copies are byte-equal in the in-flight window,
    so precedence only matters for torn edges — which each side's own
    recovery already ruled out."""
    tok = np.asarray(state[SPLIT_TOKEN])
    old = handler.visible(cfg, _split_state(state, "old/"))
    new = handler.visible(new_cfg, _split_state(state, "new/"))
    out: Dict[bytes, bytes] = {}
    for side, want_tok in ((old, 0), (new, 1)):
        ks = list(side.keys())
        if not ks:
            continue
        kn = np.frombuffer(b"".join(ks), np.uint32).reshape(-1, 4)
        homes = np.asarray(handler.route(cfg, kn)[0])
        for k, p in zip(ks, homes):
            if int(tok[int(p)]) == want_tok:
                out[k] = side[k]
    return out


@dataclasses.dataclass
class SplitSweep:
    """Exhaustive crash sweep of one incremental resize."""

    scheme: str
    moved: int
    cohorts: int
    crash_points: int
    torn_points: int
    violations: List[str]
    log_records_in_trace: int
    report: RecoveryReport          # merged recovery work over all points

    @property
    def consistent(self) -> bool:
        return not self.violations

    @property
    def log_free(self) -> bool:
        return self.log_records_in_trace == 0 \
            and self.report.log_records_used == 0


def split_crash_sweep(store, table, factor: int = 2,
                      include_torn: bool = True) -> SplitSweep:
    """Inject a crash at every PM-store boundary of the incremental
    resize (and every torn split), recover BOTH tables, resolve per-pair
    by token, and require the resolved set to equal the pre-resize item
    set at every point."""
    handler = HANDLERS[store.name]
    cfg = store.cfg
    new_cfg = cfg.grow(factor)
    base, trace = build_split_trace(store, table, factor)
    want = resolve_dual_read(handler, cfg, new_cfg, base)

    violations: List[str] = []
    merged = RecoveryReport(store.name)
    n_crash = n_torn = 0
    for cs in crash_states(base, trace, include_torn=include_torn):
        n_crash += 1
        n_torn += int(cs.torn)
        old_rec, r1 = handler.recover(cfg, _split_state(cs.state, "old/"))
        new_rec, r2 = handler.recover(new_cfg, _split_state(cs.state, "new/"))
        merged = merged.merge(r1).merge(r2)
        joined: State = {SPLIT_TOKEN: cs.state[SPLIT_TOKEN]}
        for f, v in old_rec.items():
            joined["old/" + f] = v
        for f, v in new_rec.items():
            joined["new/" + f] = v
        got = resolve_dual_read(handler, cfg, new_cfg, joined)
        if got != want:
            lost = sum(1 for k in want if got.get(k) != want[k])
            phantom = sum(1 for k in got if k not in want)
            violations.append(f"{cs.label}: resolved set diverged "
                              f"({lost} lost/torn, {phantom} phantom)")
    return SplitSweep(
        scheme=store.name, moved=len(want), cohorts=cfg.num_pairs,
        crash_points=n_crash, torn_points=n_torn, violations=violations,
        log_records_in_trace=trace.log_records(), report=merged)

"""`repro.api` <-> `repro.consistency` glue: traced store ops + recovery.

`HashStore` adapters call these from their ``trace_*`` / ``recover``
methods (deferred import on the stores side keeps `repro.api` importable
without this package loaded).  The traced op returns the SAME new table a
normal op would (semantically identical; byte-identical for the
non-scrubbing schemes) plus a `TraceResult` carrying the PM store trace
and a ledger reconciled with the scheme's own `CostLedger` accounting.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import PMTrace
from repro.core.pmem import CostLedger


class TraceResult(NamedTuple):
    """Result of a traced store op.

    ``ok``     (B,) numpy bool — per-op success, as the untraced op;
    ``trace``  the ordered `PMTrace` (records + per-op metadata);
    ``ledger`` a `CostLedger` built from the trace's Table-I-counted
    records — equal to the untraced op's ledger whenever every op took a
    path the scheme's flat per-op cost models (see `schemes`).
    """

    ok: np.ndarray
    trace: PMTrace
    ledger: CostLedger


def trace_store_op(store, table, op: str, keys, vals=None, mask=None):
    """Run ``op`` under PM-write tracing; returns ``(new_table, TraceResult)``.

    The trace order follows the store's `ExecPolicy`: continuity with
    ``engine="wave"`` emits the wave engine's schedule (per wave: payload
    stores then one-word commits), everything else the serial batch order.
    """
    handler = HANDLERS[store.name]
    order = ("wave" if store.name == "continuity"
             and store.policy.engine == "wave" else "serial")
    state, trace = trace_batch(handler, store.cfg, table, op, keys, vals,
                               mask, order=order)
    # rebuild the derived (non-traced) counters — NOT a full recovery: the
    # final state is uncrashed, so repair actions (log rollback, duplicate
    # scan) must not run here (level legitimately holds duplicates after a
    # duplicate-key insert, exactly as the untraced path does)
    state = handler.rebuild_counts(store.cfg, state)
    new_table = handler.state_to_table(store.cfg, state)
    ok = np.array([o.ok for o in trace.ops], bool)
    active = sum(1 for o in trace.ops if o.path != "masked")
    ledger = CostLedger.zero().add(pm_writes=trace.pm_writes(), ops=active)
    return new_table, TraceResult(ok, trace, ledger)


def recover_store(store, table_or_state):
    """Run the scheme's restart procedure; returns ``(table, RecoveryReport)``.

    Accepts a scheme table pytree or a crash-injected numpy state (a
    `CrashState.state`, which carries the PM log region for the logging
    schemes).  Recovering a table that was never crashed is a no-op apart
    from recomputing derived counters — recovery is idempotent.
    """
    handler = HANDLERS[store.name]
    state = handler.init_state(store.cfg, table_or_state)
    state, report = handler.recover(store.cfg, state)
    return handler.state_to_table(store.cfg, state), report

"""`repro.consistency` — PM write tracing, crash injection, recovery.

The paper's "second bird" (log-free PM consistency: every op becomes
durable via ONE atomic 8-byte indicator store) reproduced as actual crash
semantics, not just Table I write counts:

  * `trace`    — `PMStore` records (address range, payload, atomicity),
    `PMTrace`, and the crash injector (`crash_states`: every trace
    prefix + every torn split of non-atomic stores; `remote_crash_states`:
    the RDMA-delivery cut between NIC-visible and PM-persisted under a
    remote-persist fence schedule — DESIGN.md §8);
  * `schemes`  — instrumented write paths + recovery per registered
    scheme (continuity: pure indicator-word recovery, zero log; level:
    undo log + duplicate scan; pfarm: RECIPE redo-log replay; dense:
    split commit, unprotected in-place update as negative control);
  * `checker`  — per-op atomic-visibility verification over every crash
    point (`run_case`);
  * `matrix`   — the scheme x op CI gate
    (``python -m repro.consistency.matrix``).

`repro.api` stores expose this as ``store.trace_insert / trace_update /
trace_delete`` and ``store.recover`` (see `api_glue`); the serving page
table gets `serving.kvcache.open_new_pages_traced`.
"""

from repro.consistency.api_glue import (TraceResult, recover_store,
                                        trace_store_op)
from repro.consistency.checker import (CaseResult, all_or_nothing_violations,
                                       run_case, serial_prefix_items)
from repro.consistency.recovery import RecoveryReport
from repro.consistency.schemes import HANDLERS, trace_batch
from repro.consistency.trace import (ATOMIC_BYTES, COMMIT_KINDS, LOG,
                                     CrashState, PMStore, PMTrace,
                                     RemoteCrashState, SubWrite, TraceOp,
                                     apply_trace, crash_states,
                                     fence_after_commits, fence_every_store,
                                     remote_crash_states, torn_variants,
                                     unpersisted_commits)

__all__ = [
    "ATOMIC_BYTES", "COMMIT_KINDS", "LOG", "CrashState", "PMStore", "PMTrace",
    "RemoteCrashState", "SubWrite",
    "TraceOp", "apply_trace", "crash_states", "torn_variants",
    "fence_after_commits", "fence_every_store", "remote_crash_states",
    "unpersisted_commits",
    "HANDLERS", "trace_batch", "RecoveryReport",
    "CaseResult", "all_or_nothing_violations", "run_case",
    "serial_prefix_items",
    "TraceResult", "recover_store", "trace_store_op",
]

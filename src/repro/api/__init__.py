"""`repro.api` — the scheme-agnostic hash-store interface.

One typed surface over every index this repo implements (the paper's
continuity hashing, its two baselines, and the dense block-table
reference), so that the serving page table, the YCSB harness, the
benchmarks and the tests all program against ONE protocol and the
comparative claims (1 RDMA read per lookup, Table I PM-write counts) fall
out of one shared `CostLedger` instead of per-module counters.

    from repro import api

    store = api.make_store("continuity", table_slots=4096)
    table = store.create()
    table, res = store.insert(table, keys, vals)
    hits = store.lookup(table, keys)
    print(res.ledger.pm_per_op(), hits.ledger.reads_per_op())

Execution strategy is picked at this boundary via `ExecPolicy` (wave
engine vs serial scan oracle; jnp gather vs Pallas probe kernel), and new
schemes plug in through `register_scheme` — see DESIGN.md §6.

Every store also exposes the crash-consistency surface (DESIGN.md §7):
``store.trace_insert/trace_update/trace_delete`` emit the op's ordered PM
store trace for `repro.consistency`'s crash injector, and
``store.recover`` runs the scheme's restart procedure.
"""

from repro.api.registry import (available_schemes, get_scheme, make_store,
                                register_scheme)
from repro.api.stores import (ContinuityStore, DenseStore, LevelStore,
                              PFarmStore, _register_builtin)
from repro.api.types import (CostLedger, ExecPolicy, HashStore, OpResult,
                             store_shard_axes)

_register_builtin(register_scheme)

__all__ = [
    "available_schemes", "get_scheme", "make_store", "register_scheme",
    "ContinuityStore", "DenseStore", "LevelStore", "PFarmStore",
    "CostLedger", "ExecPolicy", "HashStore", "OpResult", "store_shard_axes",
    "ClusterStore",
]


def __getattr__(name):
    # `ClusterStore` (the sharded/replicated multi-node front end over any
    # registered scheme — DESIGN.md §9) lives in `repro.cluster`, which
    # itself programs against this package; the deferred import keeps the
    # layering acyclic while `api.ClusterStore` stays the documented entry.
    if name == "ClusterStore":
        from repro.cluster.store import ClusterStore
        return ClusterStore
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

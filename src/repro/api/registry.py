"""Scheme registry: one name -> store-factory map for every hash scheme.

A factory takes ``(table_slots, policy, **overrides)`` and returns a store
satisfying the `HashStore` protocol, sized so the table offers roughly
``table_slots`` storage units (the cross-scheme fairness knob the paper's
evaluation uses: equal capacity, not equal bucket counts).

    from repro import api
    store = api.make_store("continuity", table_slots=4096)
    table = store.create()

``register_scheme`` is the extension point every future scheme plugs into:
benchmarks, the YCSB harness, the property tests, and the serving page
table all iterate ``available_schemes()`` instead of hard-coding names.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.api.types import ExecPolicy, HashStore

_REGISTRY: Dict[str, Callable[..., HashStore]] = {}


def register_scheme(name: str, factory: Callable[..., HashStore],
                    *, overwrite: bool = False) -> None:
    """Register ``factory(table_slots, policy, **kw) -> store`` under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {name!r} already registered")
    _REGISTRY[name] = factory


def available_schemes() -> tuple:
    """All registered scheme names (deterministic registration order)."""
    return tuple(_REGISTRY)


def get_scheme(name: str) -> Callable[..., HashStore]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {sorted(_REGISTRY)}") from None


def make_store(name: str, *, table_slots: int = 4096,
               policy: Optional[ExecPolicy] = None, **overrides) -> HashStore:
    """Build a ready-to-use store for ``name`` (see module docstring)."""
    return get_scheme(name)(table_slots, policy or ExecPolicy(), **overrides)

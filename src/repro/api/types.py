"""The typed hash-store surface: ``HashStore`` protocol, ``ExecPolicy``,
``OpResult`` and the unified ``CostLedger``.

Every scheme (continuity, level, pfarm, dense, and anything registered
later) is exposed as a *store*: a frozen, hashable dataclass bundling the
static table geometry with an execution policy.  Table STATE stays a pure
pytree (a flat NamedTuple of arrays) that threads through jit/vmap/scan;
the store itself is static — safe to close over in jitted callables, use
as a jit static argument, or embed in other frozen configs (the serving
``PageGeometry`` does exactly that).

Calling convention (uniform across schemes):

    table            = store.create()
    table, res       = store.insert(table, keys, vals[, mask])
    table, res       = store.update(table, keys, vals[, mask])
    table, res       = store.delete(table, keys[, mask])
    res              = store.lookup(table, keys)
    rs               = store.begin_resize(table, factor)
    rs               = store.resize_step(rs, budget)   # incremental
    store2, table2   = store.resize_cutover(rs)
    lf               = store.load_factor(table)
    info             = store.stats(table)          # host-side dict

(``store.resize(table, factor)`` survives as a deprecated one-shot shim
over the begin/step/cutover triple.)  ``ResizeState`` is the maintenance
handle the incremental API threads: continuity advances a real cohort-at-
a-time split (serving reads and writes throughout, routed by its per-pair
cutover tokens); the baselines complete the whole rehash in their first
``resize_step`` — the protocol is uniform, the increment is the paper
scheme's advantage.

    table, tres      = store.trace_insert(table, keys, vals)   # + PM trace
    table2, report   = store.recover(crashed_state)            # restart

``res`` is an `OpResult`; ``res.ledger`` is the `CostLedger` every scheme
reports in the same units, which is what makes the paper's Table I an
apples-to-apples subtraction: ``res.ledger.pm_per_op()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.pmem import CostLedger

ENGINES = ("wave", "serial")
PROBES = ("gather", "pallas", "reference")
MUTATES = ("gather", "pallas", "reference")
TRANSPORTS = ("none", "sim")


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """Execution strategy, selected at the API boundary (not per-call kwargs).

    * ``engine`` — server-side mutation strategy: ``"wave"`` (the batch-
      vectorized wave engine where the scheme has one; continuity does) or
      ``"serial"`` (the ``lax.scan`` reference order).  Schemes with a
      single strategy (level, pfarm, dense) accept either value and run
      their one batched path — results are engine-independent by
      construction.
    * ``probe`` — client-side read strategy for schemes with a kernel:
      ``"gather"`` (pure-jnp vector gather), ``"pallas"`` (the Pallas
      segment-probe kernel), ``"reference"`` (the kernel's jnp oracle).
    * ``mutate`` — match backend of the fused wave-engine update/delete
      (continuity only): same three values, selecting the mutation-plan
      kernel (``kernels/mutate.py``) / its jnp oracle / the vector
      gather.  Ignored by the serial engine and kernel-less schemes.
    * ``use_fp`` — fingerprint pre-filter in the probe path (default ON:
      result-identical — visible slots always carry the correct 2-bit
      field — and cuts negative-search key compares, paper Figs 7/14).
      The mutation plan always filters regardless of this knob.
    * ``qblock`` — queries per Pallas grid step (probe/mutate kernels).
    * ``interpret`` — run Pallas kernels in interpreter mode (True on CPU
      containers; set False on real TPU hardware).
    * ``transport`` — which transport host-side drivers attach to the verb
      plans ops emit: ``"none"`` (plans price the `CostLedger` only) or
      ``"sim"`` (a `repro.rdma.RemoteMemory` endpoint with doorbell
      batching and the analytical latency model;
      ``RemoteMemory.from_policy(policy)`` builds it).  Lookups ALWAYS
      carry their plan on `OpResult.plan`; the policy decides whether
      anything executes/prices it.
    """

    engine: str = "wave"
    probe: str = "gather"
    mutate: str = "gather"
    use_fp: bool = True
    qblock: int = 8
    interpret: bool = True
    transport: str = "none"

    def __post_init__(self):
        assert self.engine in ENGINES, self.engine
        assert self.probe in PROBES, self.probe
        assert self.mutate in MUTATES, self.mutate
        assert self.qblock >= 1
        assert self.transport in TRANSPORTS, self.transport


class OpResult(NamedTuple):
    """Uniform per-batch op result.

    ``ok``     (B,) bool — per-item success (write) / found (lookup).
    ``ledger`` accumulated `CostLedger` for the batch.
    ``values`` (B, VAL_LANES) uint32 — lookup payloads (None on writes).
    ``reads``  (B,) int32 — contiguous fetches per lookup (None on writes).
    ``plan``   `repro.rdma.VerbPlan` — the one-sided verb plan the lookup
               emitted (None on writes); ``ledger``'s read counters are
               derived from it, and host-side drivers post it to the
               transport `ExecPolicy.transport` selects.
    """

    ok: jnp.ndarray
    ledger: CostLedger
    values: Optional[jnp.ndarray] = None
    reads: Optional[jnp.ndarray] = None
    plan: Optional[Any] = None


@dataclasses.dataclass(frozen=True)
class ResizeState:
    """Handle of one in-flight incremental resize (begin -> step* -> cutover).

    ``store``/``table`` are the SOURCE geometry and its (draining) state;
    ``new_store``/``new_table`` the grown target.  ``opaque`` is the
    scheme's private cursor (continuity: its per-pair cutover-token split
    state); ``done`` flips when every cohort has moved; ``moved`` counts
    relocated items and ``n_items`` records the live count at begin (the
    cutover loss check).  ``step_budget`` is the per-step cohort count the
    SLO controller chose at begin (``begin_resize(step_slo_us=...)`` sizes
    it from the `LinkModel` so one step's foreground stall stays under the
    target; None means the caller passes an explicit budget).  The handle
    is immutable — each step returns a new one — so a crash between steps
    simply resumes from the last handle (or from recovery's token scan)."""

    store: "HashStore"
    new_store: "HashStore"
    table: Any
    new_table: Any
    factor: int = 2
    opaque: Any = None
    done: bool = False
    n_items: int = 0
    moved: int = 0
    step_budget: Optional[int] = None


@runtime_checkable
class HashStore(Protocol):
    """Structural type every registered scheme satisfies (see module doc
    for the calling convention).  ``name`` is the registry key; ``policy``
    the store's `ExecPolicy`."""

    name: str
    policy: ExecPolicy

    def create(self) -> Any: ...

    def insert(self, table: Any, keys, vals, mask=None) -> Tuple[Any, OpResult]: ...

    def update(self, table: Any, keys, vals, mask=None) -> Tuple[Any, OpResult]: ...

    def delete(self, table: Any, keys, mask=None) -> Tuple[Any, OpResult]: ...

    def lookup(self, table: Any, keys) -> OpResult: ...

    # incremental maintenance surface: begin one resize, advance it a
    # bounded number of cohorts at a time (foreground traffic keeps
    # flowing between steps), then cut over.  ``resize`` is the deprecated
    # one-shot shim over the triple.
    def begin_resize(self, table: Any, factor: int = 2,
                     step_slo_us: Optional[float] = None) -> ResizeState: ...

    def resize_step(self, state: ResizeState,
                    budget: Optional[int] = None) -> ResizeState: ...

    def resize_cutover(self, state: ResizeState) -> Tuple["HashStore", Any]: ...

    def resize(self, table: Any, factor: int = 2) -> Tuple["HashStore", Any]: ...

    def load_factor(self, table: Any) -> jnp.ndarray: ...

    def stats(self, table: Any) -> dict: ...

    # crash-consistency surface (`repro.consistency`): traced twins of the
    # write ops — same (table, result) contract, but the result carries the
    # ordered PM store trace the crash injector replays — and the scheme's
    # restart procedure (returns (table, RecoveryReport)).
    def trace_insert(self, table: Any, keys, vals, mask=None) -> Tuple[Any, Any]: ...

    def trace_update(self, table: Any, keys, vals, mask=None) -> Tuple[Any, Any]: ...

    def trace_delete(self, table: Any, keys, mask=None) -> Tuple[Any, Any]: ...

    def recover(self, table_or_state: Any) -> Tuple[Any, Any]: ...


def store_shard_axes(table: Any, axis: str):
    """Logical-axis tree for a store state carrying one leading shard dim.

    Every leaf of ``table`` (already broadcast to ``(shards,) + ...``) maps
    to ``(axis, None, ..., None)`` — the generic form of the hand-written
    per-scheme axis trees the serving cache used to maintain."""
    leaves, treedef = jax.tree.flatten(table)
    return jax.tree.unflatten(
        treedef, [(axis,) + (None,) * (leaf.ndim - 1) for leaf in leaves])

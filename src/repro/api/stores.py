"""Registered `HashStore` adapters for the four built-in schemes.

Each adapter is a frozen dataclass (hashable — safe as jit static / inside
other frozen configs) binding a scheme module's pure functions to the
protocol's calling convention, the unified `OpResult`/`CostLedger`, and an
`ExecPolicy`.  Registration happens at import of ``repro.api``:

  * ``continuity`` — the paper's scheme; `ExecPolicy.engine` selects the
    wave-vectorized mutation engine vs the serial ``lax.scan`` oracle, and
    `ExecPolicy.probe` selects the pure-jnp gather vs the Pallas segment-
    probe kernel (vs its jnp reference) for lookups;
  * ``level``  — Level hashing (OSDI'18), the paper's PM-friendly baseline;
  * ``pfarm``  — P-FaRM-KV (FaRM-KV x RECIPE), the paper's RDMA baseline;
  * ``dense``  — the dense block-table reference (vLLM-style), the
    correctness oracle and the non-hashed serving page-table backend.

Factories size the table to ``table_slots`` storage units so cross-scheme
numbers compare at equal capacity (the paper's evaluation setup).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.types import ExecPolicy, OpResult, ResizeState
from repro.core import continuity as ch
from repro.core import dense as dn
from repro.core import level as lv
from repro.core import pfarm as pf
from repro.core.continuity import KEY_LANES, VAL_LANES


# The read-side entry points compile ONCE per (store, shape): stores are
# frozen dataclasses (hashable), so they ride as jit statics and the many
# small per-node / per-client calls the cluster and cache layers make pay
# dispatch, not retracing, after the first call at each batch shape.
@functools.partial(jax.jit, static_argnums=0)
def _jit_lookup(store: "_ModuleStore", table, keys):
    from repro.rdma import verbs as rv
    res = store._lookup_res(table, keys)
    plan = store._mod.lookup_plan(store.cfg, table, keys, res)
    return res, plan, rv.ledger_from_plan(plan)


@functools.partial(jax.jit, static_argnums=0)
def _jit_stamp(store: "_ModuleStore", table, keys):
    return store._stamp_impl(table, keys)


@functools.partial(jax.jit, static_argnums=0)
def _jit_stamp_plan(store: "_ModuleStore", table, keys):
    return store._vplan_impl(table, keys)


def _check_resize_lossless(name: str, old_table, new_table) -> None:
    lost = int(old_table.count) - int(new_table.count)
    if lost:
        raise RuntimeError(
            f"resize dropped {lost} live item(s) from the {name!r} store "
            f"({int(old_table.count)} -> {int(new_table.count)}); grow by a "
            f"larger factor or rehash manually")


@dataclasses.dataclass(frozen=True)
class _ModuleStore:
    """Shared plumbing: scheme-module functions -> protocol methods."""

    cfg: Any
    policy: ExecPolicy = ExecPolicy()

    name: ClassVar[str] = "?"

    # -- per-scheme hooks ---------------------------------------------------
    @property
    def _mod(self):
        raise NotImplementedError

    def _insert_fn(self):
        return self._mod.insert

    def _update_fn(self):
        return self._mod.update

    def _delete_fn(self):
        return self._mod.delete

    def _lookup_res(self, table, keys):
        return self._mod.lookup(self.cfg, table, keys)

    def _extract(self, table):
        """(keys, vals, live_mask) of every storage slot — generic resize."""
        raise NotImplementedError

    def total_slots(self, table=None) -> float:
        raise NotImplementedError

    # -- protocol -----------------------------------------------------------
    def with_policy(self, policy: ExecPolicy) -> "_ModuleStore":
        return dataclasses.replace(self, policy=policy)

    def create(self):
        return self._mod.create(self.cfg)

    def insert(self, table, keys, vals, mask=None) -> Tuple[Any, OpResult]:
        table, ok, ctr = self._insert_fn()(self.cfg, table, keys, vals, mask)
        return table, OpResult(ok=ok, ledger=ctr)

    def update(self, table, keys, vals, mask=None) -> Tuple[Any, OpResult]:
        table, ok, ctr = self._update_fn()(self.cfg, table, keys, vals, mask)
        return table, OpResult(ok=ok, ledger=ctr)

    def delete(self, table, keys, mask=None) -> Tuple[Any, OpResult]:
        table, ok, ctr = self._delete_fn()(self.cfg, table, keys, mask)
        return table, OpResult(ok=ok, ledger=ctr)

    def lookup(self, table, keys) -> OpResult:
        # ONE accounting path for every scheme: the lookup emits its verb
        # plan (continuity: one contiguous segment READ; level: scattered
        # bucket READs; pfarm: window + chained READs; dense: whole-table
        # READ) and the ledger is derived from the plan — this replaced
        # the four per-scheme hand-tallied ``read_counters`` blocks.
        res, plan, ledger = _jit_lookup(self, table, keys)
        return OpResult(ok=res.found, ledger=ledger,
                        values=res.values, reads=res.reads, plan=plan)

    def scan_plan(self, table, keys, spans):
        """Verb plan of a YCSB-E short-scan batch: ``spans[i]`` records
        read starting from ``keys[i]``'s position.  Continuity emits ONE
        contiguous multi-segment READ per scan (its SBuckets are linear
        in PM); the scattered baselines degenerate to one READ per
        record — the asymmetry YCSB-E measures."""
        return self._mod.scan_plan(self.cfg, table, keys, spans)

    # -- incremental maintenance surface ------------------------------------
    # begin_resize/resize_step/resize_cutover: the protocol's resize is a
    # steppable background job.  The generic implementation completes the
    # whole rehash in the FIRST step (a stop-the-world move is all the
    # scattered baselines can offer — their candidate buckets change
    # wholesale at the new size); continuity overrides the triple with a
    # real cohort-at-a-time split.

    def begin_resize(self, table, factor: int = 2,
                     step_slo_us: Optional[float] = None) -> ResizeState:
        # the baselines can't increment (their first step moves everything),
        # so a stall SLO is unsatisfiable — accepted for protocol uniformity
        new = dataclasses.replace(self, cfg=self.cfg.grow(factor))
        return ResizeState(store=self, new_store=new, table=table,
                           new_table=new.create(), factor=factor,
                           n_items=int(table.count))

    def resize_step(self, state: ResizeState,
                    budget: Optional[int] = None) -> ResizeState:
        if state.done:
            return state
        keys, vals, live = self._extract(state.table)
        new_table, _ = state.new_store.insert(state.new_table, keys, vals,
                                              live)
        return dataclasses.replace(
            state, new_table=new_table, done=True,
            moved=int(jnp.asarray(live).sum()))

    def resize_cutover(self, state: ResizeState) -> Tuple["_ModuleStore", Any]:
        """Finish any remaining steps and hand over the grown store.

        Raises if any live item failed to reinsert (possible for the
        bucketed baselines when candidate buckets collide even at the
        larger size) instead of dropping it."""
        while not state.done:
            state = self.resize_step(state, budget=1 << 30)
        _check_resize_lossless(self.name, state.table, state.new_table)
        return state.new_store, state.new_table

    def resize(self, table, factor: int = 2) -> Tuple["_ModuleStore", Any]:
        """DEPRECATED one-shot resize: begin + step-to-completion + cutover.

        Kept as a shim for callers that can afford to block; new code
        should drive ``begin_resize``/``resize_step`` from its maintenance
        loop and ``resize_cutover`` when the split has drained."""
        warnings.warn(
            "HashStore.resize() is deprecated; use begin_resize()/"
            "resize_step()/resize_cutover()", DeprecationWarning,
            stacklevel=2)
        return self.resize_cutover(self.begin_resize(table, factor))

    # -- cache-validation surface (repro.cache) -----------------------------
    # A stamp is an opaque (B, S) integer array, one row per key, compared
    # row-wise: rows equal  <=>  a fresh lookup returns exactly the value
    # observed when the stamp was taken.  The DEFAULT is value-based —
    # ``[found, value lanes]`` — which is correct for every scheme but
    # prices validation at a FULL lookup plan (there is no cheap version
    # word to read).  Continuity overrides both with its 8-byte indicator
    # word; the cost asymmetry is the cache subsystem's whole argument.

    def version_stamp(self, table, keys) -> jnp.ndarray:
        return _jit_stamp(self, table, keys)

    def version_read_plan(self, table, keys):
        """Verb plan pricing ONE stamp-validation batch."""
        return _jit_stamp_plan(self, table, keys)

    def _stamp_impl(self, table, keys) -> jnp.ndarray:
        res = self._lookup_res(table, keys)
        return jnp.concatenate(
            [res.found[:, None].astype(jnp.uint32),
             res.values.astype(jnp.uint32)], axis=-1)

    def _vplan_impl(self, table, keys):
        # uniform delegation: every scheme module exposes the unified
        # ``version_read_plan(cfg, table, keys)`` (continuity: one depth-0
        # 8-byte word READ per key; the value-stamp baselines: their full
        # lookup plan — there is no cheap version word to poll)
        return self._mod.version_read_plan(self.cfg, table, keys)

    # -- crash-consistency surface (repro.consistency) ----------------------
    # Traced twins of the write ops: same table-out/ok-out contract, plus
    # the ordered PM store trace (`TraceResult.trace`) the crash injector
    # replays.  ``recover`` is the scheme's restart procedure; it accepts a
    # table pytree or a crash-injected state (`CrashState.state`).

    def trace_insert(self, table, keys, vals, mask=None):
        from repro import consistency
        return consistency.trace_store_op(self, table, "insert", keys, vals,
                                          mask)

    def trace_update(self, table, keys, vals, mask=None):
        from repro import consistency
        return consistency.trace_store_op(self, table, "update", keys, vals,
                                          mask)

    def trace_delete(self, table, keys, mask=None):
        from repro import consistency
        return consistency.trace_store_op(self, table, "delete", keys, None,
                                          mask)

    def recover(self, table_or_state):
        from repro import consistency
        return consistency.recover_store(self, table_or_state)

    def load_factor(self, table) -> jnp.ndarray:
        return self._mod.load_factor(self.cfg, table)

    def stats(self, table) -> dict:
        """Host-side diagnostics (blocks on device values)."""
        return {
            "scheme": self.name,
            "count": int(table.count),
            "total_slots": float(self.total_slots(table)),
            "load_factor": float(self.load_factor(table)),
        }


@dataclasses.dataclass(frozen=True)
class ContinuityStore(_ModuleStore):
    """The paper's continuity hashing behind the protocol.

    ``policy.engine``: ``wave`` -> the fused wave-vectorized mutation
    engine; ``serial`` -> the byte-identical ``lax.scan`` reference.
    ``policy.probe``: ``gather`` -> pure-jnp lookup; ``pallas`` /
    ``reference`` -> the Pallas segment-probe kernel / its jnp oracle
    (`repro.kernels.ops.probe_lookup`), fingerprint pre-filter per
    ``policy.use_fp`` (default on).  ``policy.mutate`` picks the match
    backend of the fused update/delete the same way (the Pallas
    mutation-plan kernel / its oracle / the jnp gather)."""

    cfg: ch.ContinuityConfig = ch.ContinuityConfig(num_buckets=256)
    name: ClassVar[str] = "continuity"

    @property
    def _mod(self):
        return ch

    def _insert_fn(self):
        return ch.insert_serial if self.policy.engine == "serial" else ch.insert

    def _update_fn(self):
        if self.policy.engine == "serial":
            return ch.update_serial
        return functools.partial(ch.update, probe=self.policy.mutate,
                                 qblock=self.policy.qblock,
                                 interpret=self.policy.interpret)

    def _delete_fn(self):
        if self.policy.engine == "serial":
            return ch.delete_serial
        return functools.partial(ch.delete, probe=self.policy.mutate,
                                 qblock=self.policy.qblock,
                                 interpret=self.policy.interpret)

    def _lookup_res(self, table, keys):
        if self.policy.probe == "gather":
            return ch.lookup(self.cfg, table, keys)
        from repro.kernels import ops as K          # deferred: pallas import
        return K.probe_lookup(
            self.cfg, table, keys,
            use_kernel=self.policy.probe == "pallas",
            interpret=self.policy.interpret, qblock=self.policy.qblock,
            use_fp=self.policy.use_fp)

    def _extract(self, table):
        return ch.extract_items(self.cfg, table)

    def _stamp_impl(self, table, keys) -> jnp.ndarray:
        # (B, 2) [version, indicator]: the ONE 8-byte word every committed
        # mutation on the key's pair atomically rewrites — ABA-proof via
        # the counter half (see ch.version_stamp)
        return ch.version_stamp(self.cfg, table, keys)

    def begin_resize(self, table, factor: int = 2,
                     step_slo_us: Optional[float] = None) -> ResizeState:
        # the paper's log-free resize as an ONLINE split: per-pair cutover
        # tokens route traffic while cohorts move one at a time
        new_cfg, new_table, split = ch.split_begin(self.cfg, table, factor)
        step_budget = None
        if step_slo_us is not None:
            # SLO controller: cohorts per step = how many single-cohort
            # moves fit in the stall budget under the calibrated LinkModel
            # (each move reads one source row and writes its items + words
            # + the cutover token); always >= 1 so the split progresses
            from repro.rdma.transport import LinkModel
            per = LinkModel().cohort_move_us(
                read_bytes=float(self.cfg.row_bytes),
                write_bytes=float(self.cfg.row_bytes + 16))
            step_budget = max(1, int(step_slo_us / per))
        return ResizeState(
            store=self, new_store=dataclasses.replace(self, cfg=new_cfg),
            table=table, new_table=new_table, factor=factor, opaque=split,
            n_items=int(table.count), step_budget=step_budget)

    def resize_step(self, state: ResizeState,
                    budget: Optional[int] = None) -> ResizeState:
        if state.done:
            return state
        if budget is None:
            budget = state.step_budget or 1
        table, new_table, split, moved = ch.split_step(
            self.cfg, state.table, state.new_store.cfg, state.new_table,
            state.opaque, budget)
        return dataclasses.replace(
            state, table=table, new_table=new_table, opaque=split,
            moved=state.moved + moved,
            done=bool(ch.split_done(self.cfg, split)))

    def resize_cutover(self, state: ResizeState):
        while not state.done:
            state = self.resize_step(state, budget=self.cfg.num_pairs)
        left = int(state.table.count)
        if left:
            raise RuntimeError(
                f"resize cutover with {left} item(s) still in the source "
                f"{self.name!r} table — the split did not drain")
        return state.new_store, state.new_table

    # -- mid-split routing (the maintenance loop's read/write path) ---------
    def resize_lookup(self, state: ResizeState, keys) -> OpResult:
        """Dual-read during a split: probe old and new, pick by the
        cohort's cutover token (one extra READ only for in-flight pairs)."""
        res = ch.split_lookup(self.cfg, state.table,
                              state.new_store.cfg, state.new_table,
                              state.opaque, keys)
        from repro.rdma import verbs as rv
        plan = ch.lookup_plan(self.cfg, state.table, keys,
                              ch.lookup(self.cfg, state.table, keys))
        return OpResult(ok=res.found, ledger=rv.ledger_from_plan(plan),
                        values=res.values, reads=res.reads, plan=plan)

    def resize_write(self, state: ResizeState, op: str, keys, vals=None,
                     mask=None) -> Tuple[ResizeState, OpResult]:
        """Route one write batch by the split tokens: moved cohorts write
        the new table, unmoved the old (whose items the split will carry
        over).  Keeps insert-during-split lossless and duplicate-free."""
        keys = jnp.asarray(keys, jnp.uint32).reshape(-1, KEY_LANES)
        to_new = ch.split_route(self.cfg, state.opaque, keys)
        m = (jnp.ones(keys.shape[0], bool) if mask is None
             else jnp.asarray(mask).reshape(-1))
        fn = {"insert": self.insert, "update": self.update,
              "delete": self.delete}[op]
        nfn = {"insert": state.new_store.insert,
               "update": state.new_store.update,
               "delete": state.new_store.delete}[op]
        args_old = (keys,) if op == "delete" else (keys, vals)
        table, r_old = fn(state.table, *args_old, mask=m & ~to_new)
        new_table, r_new = nfn(state.new_table, *args_old, mask=m & to_new)
        ok = jnp.where(to_new, r_new.ok, r_old.ok)
        return (dataclasses.replace(state, table=table, new_table=new_table),
                OpResult(ok=ok, ledger=r_old.ledger.merge(r_new.ledger)))

    def total_slots(self, table=None) -> float:
        if table is None:
            return float(self.cfg.num_pairs * self.cfg.slots_per_pair)
        return float(ch.capacity(self.cfg, table))

    def stats(self, table) -> dict:
        out = super().stats(table)
        out["ext_groups"] = int(table.ext_count)
        return out

    @classmethod
    def from_slots(cls, table_slots: int, policy: ExecPolicy = ExecPolicy(),
                   **overrides) -> "ContinuityStore":
        per_pair = ch.ContinuityConfig(2).slots_per_pair
        pairs = max(2, -(-table_slots // per_pair))   # ceil: >= table_slots
        # a 1/8 stash tier by default: costs nothing until the main slots
        # fill (the lane stays NOOP while the count byte is 0) and lifts
        # the first-trigger load factor past the paper's ~0.85 band
        overrides.setdefault("stash_frac", 1 / 8)
        cfg = dataclasses.replace(
            ch.ContinuityConfig(num_buckets=2 * pairs), **overrides)
        return cls(cfg=cfg, policy=policy)


def _token_mask(tok: jnp.ndarray, bucket_slots: int) -> jnp.ndarray:
    bits = (tok[:, None] >> jnp.arange(bucket_slots, dtype=jnp.uint8)) \
        & jnp.uint8(1)
    return (bits == 1).reshape(-1)


@dataclasses.dataclass(frozen=True)
class LevelStore(_ModuleStore):
    """Level hashing baseline (single batched strategy: the scan order —
    ``policy.engine`` is accepted and irrelevant by construction)."""

    cfg: lv.LevelConfig = lv.LevelConfig(num_top=64)
    name: ClassVar[str] = "level"

    @property
    def _mod(self):
        return lv

    def _extract(self, table):
        keys = jnp.concatenate([table.tkeys.reshape(-1, KEY_LANES),
                                table.bkeys.reshape(-1, KEY_LANES)])
        vals = jnp.concatenate([table.tvals.reshape(-1, VAL_LANES),
                                table.bvals.reshape(-1, VAL_LANES)])
        live = jnp.concatenate([_token_mask(table.ttok, self.cfg.bucket_slots),
                                _token_mask(table.btok, self.cfg.bucket_slots)])
        return keys, vals, live

    def total_slots(self, table=None) -> float:
        return float(self.cfg.total_slots)

    @classmethod
    def from_slots(cls, table_slots: int, policy: ExecPolicy = ExecPolicy(),
                   **overrides) -> "LevelStore":
        top = int(table_slots / 1.5 / 4)
        cfg = dataclasses.replace(
            lv.LevelConfig(num_top=top + top % 2), **overrides)
        return cls(cfg=cfg, policy=policy)


@dataclasses.dataclass(frozen=True)
class PFarmStore(_ModuleStore):
    """P-FaRM-KV baseline (RECIPE logging: 5 PM writes per mutation)."""

    cfg: pf.PFarmConfig = pf.PFarmConfig(num_buckets=64)
    name: ClassVar[str] = "pfarm"

    @property
    def _mod(self):
        return pf

    def _extract(self, table):
        keys = jnp.concatenate([table.keys.reshape(-1, KEY_LANES),
                                table.okeys.reshape(-1, KEY_LANES)])
        vals = jnp.concatenate([table.vals.reshape(-1, VAL_LANES),
                                table.ovals.reshape(-1, VAL_LANES)])
        live = jnp.concatenate([_token_mask(table.tok, self.cfg.bucket_slots),
                                _token_mask(table.otok, self.cfg.bucket_slots)])
        return keys, vals, live

    def total_slots(self, table=None) -> float:
        return float(self.cfg.total_slots)

    @classmethod
    def from_slots(cls, table_slots: int, policy: ExecPolicy = ExecPolicy(),
                   **overrides) -> "PFarmStore":
        cfg = dataclasses.replace(
            pf.PFarmConfig(num_buckets=int(table_slots / 1.25 / 4)),
            **overrides)
        return cls(cfg=cfg, policy=policy)


@dataclasses.dataclass(frozen=True)
class DenseStore(_ModuleStore):
    """Dense block-table reference (no hashing; whole-table lookups)."""

    cfg: dn.DenseConfig = dn.DenseConfig(capacity=256)
    name: ClassVar[str] = "dense"

    @property
    def _mod(self):
        return dn

    def _extract(self, table):
        return dn.extract_items(self.cfg, table)

    def total_slots(self, table=None) -> float:
        return float(self.cfg.capacity)

    @classmethod
    def from_slots(cls, table_slots: int, policy: ExecPolicy = ExecPolicy(),
                   **overrides) -> "DenseStore":
        cfg = dataclasses.replace(dn.DenseConfig(capacity=table_slots),
                                  **overrides)
        return cls(cfg=cfg, policy=policy)


def _register_builtin(registry_register) -> None:
    for cls in (ContinuityStore, LevelStore, PFarmStore, DenseStore):
        registry_register(cls.name, cls.from_slots)

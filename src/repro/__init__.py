"""repro — Consistent RDMA-Friendly Hashing on Remote Persistent Memory,
grown into a jax/pallas serving system.

Stable import surface (everything else is internal layout):

    from repro import api                  # the hash-store interface
    from repro.api import make_store, ExecPolicy, CostLedger

Deep imports of ``repro.core.continuity`` et al. keep working but are the
module-level API; new code should go through ``repro.api`` (see DESIGN.md).
The lazy ``__getattr__`` keeps ``import repro`` free of jax initialization.
"""

_SUBMODULES = ("api", "core", "kernels", "rdma", "serving", "data",
               "configs", "models", "launch", "distribution", "training",
               "checkpoint", "runtime", "consistency")


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(_SUBMODULES)

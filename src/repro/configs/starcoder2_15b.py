"""starcoder2-15b [dense] — GQA, RoPE, LayerNorm + gelu MLP, learned-abs+rope
hybrid in HF; backbone here uses RoPE. [arXiv:2402.19173; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    norm="ln",
    mlp="gelu",
    qkv_bias=True,       # starcoder2 uses attention bias
    rope=True,
)

"""Architecture registry: one module per assigned arch + reduced smoke twins."""

from repro.configs.registry import ARCHS, get_arch, smoke_config  # noqa: F401

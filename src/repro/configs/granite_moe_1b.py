"""granite-moe-1b-a400m [moe] — 32 experts top-8, per-expert d_ff=512,
GQA kv=8. Expert-parallel over the model axis (32 % 16 == 0).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    norm="rms",
    mlp="swiglu",
    rope=True,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, expert_dff=512),
)

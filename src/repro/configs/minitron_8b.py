"""minitron-8b [dense] — width-pruned nemotron-4; GQA kv=8, huge 256k vocab
stresses embedding/vocab sharding. [arXiv:2407.14679; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    norm="rms",
    mlp="gelu",          # nemotron uses squared-relu; gelu is the close stand-in
    rope=True,
)

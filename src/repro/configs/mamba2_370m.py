"""mamba2-370m [ssm] — attention-free SSD (state-space duality), 48 layers,
d_model=1024, ssm_state=128, no MLP (d_ff=0). Runs long_500k (O(1) state).
[arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    norm="rms",
    rope=False,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
)

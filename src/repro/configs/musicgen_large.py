"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens
(MHA kv=32, LayerNorm+gelu). The EnCodec frontend is a STUB: train/prefill
``input_specs()`` provide precomputed frame embeddings; the 4-codebook
interleaving is collapsed to a single token stream (DESIGN.md).
[arXiv:2306.05284; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    norm="ln",
    mlp="gelu",
    rope=False,           # musicgen uses sinusoidal absolute embeddings
    frontend="embed",
)

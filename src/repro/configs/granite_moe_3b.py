"""granite-moe-3b-a800m [moe] — 40 experts top-8 (40 % 16 != 0: expert dim
degrades to replication, d_ff sharding documented in DESIGN.md), GQA kv=8.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    norm="rms",
    mlp="swiglu",
    rope=True,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, expert_dff=512),
)

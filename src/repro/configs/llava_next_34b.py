"""llava-next-34b [vlm] — language backbone (Yi-34B-shaped: 60L, d=7168,
56H/kv=8). The vision tower + anyres tiling is a STUB: ``input_specs()``
provides precomputed patch embeddings prepended to the prompt.
[hf:llava-hf/llava-v1.6; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    norm="rms",
    mlp="swiglu",
    rope=True,
    frontend="embed",
)

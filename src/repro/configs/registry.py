"""Registry of assigned architectures + reduced smoke twins.

``get_arch(id)`` returns the FULL config (exercised only via the dry-run,
ShapeDtypeStruct, no allocation). ``smoke_config(id)`` returns a reduced
same-family config small enough for a CPU forward/train step.
"""

from __future__ import annotations

import dataclasses

from repro.configs import (granite_moe_1b, granite_moe_3b, hymba_1_5b,
                           llava_next_34b, mamba2_370m, minitron_8b,
                           musicgen_large, qwen1_5_32b, starcoder2_15b, yi_6b)
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

ARCHS = {
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "yi-6b": yi_6b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "llava-next-34b": llava_next_34b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family twin: few layers, narrow width, tiny vocab."""
    full = get_arch(name)
    kv = min(full.n_kv_heads, 2) if full.n_kv_heads else 0
    heads = 0
    if full.n_heads:
        # keep the GQA group structure (heads multiple of kv heads)
        group = max(full.n_heads // max(full.n_kv_heads, 1), 1)
        heads = kv * group if kv else 4
        heads = min(heads, 8) or 4
        kv = max(heads // group, 1)
    updates = dict(
        n_layers=4 if full.family == "hybrid" else 3,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32 if full.n_heads else 0,
        d_ff=256 if full.d_ff else 0,
        vocab=512,
        attn_chunk=64,
        remat="none",
        dtype="float32",
        window=full.window and 64,
    )
    if full.moe is not None:
        updates["moe"] = MoEConfig(num_experts=8, top_k=2, expert_dff=64)
    if full.ssm is not None:
        updates["ssm"] = SSMConfig(
            d_state=min(full.ssm.d_state, 16), head_dim=32,
            expand=full.ssm.expand, conv_width=4, chunk=32)
    if full.family == "hybrid":
        # parallel-head constraint: n_heads * head_dim == expand * d_model
        updates["n_heads"] = (full.ssm.expand * 128) // 32
        updates["n_kv_heads"] = 2
        updates["head_dim"] = 32
    cfg = dataclasses.replace(full, **updates)
    return cfg

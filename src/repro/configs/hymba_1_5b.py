"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every block,
sliding-window attention except at layers {0, L/2, L-1} (full/global), GQA
kv=5, ssm_state=16. Meta-tokens are omitted (DESIGN.md deviation note).
[arXiv:2411.13676; hf]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    norm="rms",
    mlp="swiglu",
    rope=True,
    window=1024,
    global_every=16,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=1, conv_width=4, chunk=256),
)

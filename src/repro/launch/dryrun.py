import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) sees 512 placeholder CPU devices so the
# production meshes can be built; smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. builds the step function (train_step / prefill / serve_step) and
     ShapeDtypeStruct stand-ins for params, optimizer state, caches, inputs
     (jax.eval_shape — no allocation);
  3. ``jit(...).lower(...).compile()`` with explicit NamedShardings derived
     from the logical-axis rules;
  4. records memory_analysis (bytes/device), cost_analysis (FLOPs + bytes
     accessed, per device), and the collective bytes parsed from the
     compiled HLO — the three §Roofline inputs — into one JSON per cell
     under experiments/dryrun/.

Also dry-runs the paper's own artifact (the distributed continuity KV
service) as pseudo-arch ``continuity-kv`` with read/write "shapes".

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

# hardware constants: TPU v5e
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective accounting from the per-device optimized HLO.

    Optimized HLO prints operands as bare names, so sizes are derived from
    the RESULT shape + replica-group size g:
      operand bytes: all-gather = result/g; reduce-scatter = result*g;
                     others = result.
      wire bytes (ring model, per device): all-reduce 2*r*(g-1)/g;
        all-gather r*(g-1)/g; reduce-scatter r*(g-1); all-to-all r*(g-1)/g;
        collective-permute r.
    The roofline collective term uses wire bytes.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        op = m.group(1)
        shapes = [_shape_bytes(d, s)
                  for d, s in _SHAPE_RE.findall(line[:m.start()])]
        if not shapes:
            continue
        r = max(shapes)
        g = _group_size(line)
        if op == "all-gather":
            operand, wire = r // g, r * (g - 1) // g
        elif op == "reduce-scatter":
            operand, wire = r * g, r * (g - 1)
        elif op == "all-reduce":
            operand, wire = r, 2 * r * (g - 1) // g
        elif op == "all-to-all":
            operand, wire = r, r * (g - 1) // g
        else:  # collective-permute
            operand, wire = r, r
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        rec["count"] += 1
        rec["bytes"] += operand
        rec["wire_bytes"] += wire
    return out


_COMP_RE = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*"
                       r"body=%?([\w\.\-]+)")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|branch_computations)="
                        r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(text: str):
    """HLO text -> ({name: [lines]}, entry_name)."""
    comps, cur, entry = {}, None, None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and ("{" in line):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines) -> int:
    """Scan-style while conditions compare the induction var to a constant:
    the largest (sane) integer constant in the condition is the trip count."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            v = int(m.group(1))
            if v <= 1_000_000:           # ignore sentinel/mask constants
                best = max(best, v)
    return best


def collective_bytes_weighted(text: str) -> dict:
    """Collective accounting with while-bodies weighted by their trip counts
    (cost_analysis and naive text scans count scan bodies once — see
    EXPERIMENTS.md §Methodology)."""
    comps, entry = _split_computations(text)
    if entry is None:
        return collective_bytes(text)
    out = {}

    def add(line, mult):
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            return
        op = m.group(1)
        shapes = [_shape_bytes(d, s)
                  for d, s in _SHAPE_RE.findall(line[:m.start()])]
        if not shapes:
            return
        r = max(shapes)
        g = _group_size(line)
        if op == "all-gather":
            operand, wire = r // g, r * (g - 1) // g
        elif op == "reduce-scatter":
            operand, wire = r * g, r * (g - 1)
        elif op == "all-reduce":
            operand, wire = r, 2 * r * (g - 1) // g
        elif op == "all-to-all":
            operand, wire = r, r * (g - 1) // g
        else:
            operand, wire = r, r
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        rec["count"] += mult
        rec["bytes"] += operand * mult
        rec["wire_bytes"] += wire * mult

    def walk(name, mult, depth=0):
        if name not in comps or depth > 32:   # HLO call graphs are DAGs
            return
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []))
                walk(body, mult * trip, depth + 1)
                continue
            add(line, mult)
            cm = _CALLEE_RE.search(line)
            if cm and "while(" not in line:
                for callee in cm.group(1).replace("%", "").split(","):
                    walk(callee.strip(), mult, depth + 1)

    walk(entry, 1)
    return out


def build_mesh(multi_pod: bool):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=multi_pod)


def _named(tree_axes, tree_structs):
    from repro.distribution.sharding import named_sharding
    return jax.tree.map(
        lambda ax, s: None if s is None else named_sharding(
            *(ax if ax is not None else (None,) * s.ndim), size_of=s.shape),
        tree_axes, tree_structs,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and
                                        all(isinstance(e, (str, type(None)))
                                            for e in x)))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Build + lower + compile one cell; returns (record, compiled)."""
    from repro.configs import get_arch
    from repro.distribution.sharding import use_mesh, named_sharding
    from repro.models import transformer as T
    from repro.models.config import SHAPES, input_specs, shape_applicable
    from repro.serving import engine as E
    from repro.serving import kvcache as KC
    from repro.training import optimizer as O
    from repro.training.train_step import make_train_step

    cfg = get_arch(arch)
    if overrides:
        fields = {f.name for f in dataclasses.fields(cfg)}
        cfg_over = {k: v for k, v in overrides.items() if k in fields}
        if "moe_impl" in overrides and cfg.moe is not None:
            cfg_over["moe"] = dataclasses.replace(
                cfg.moe, impl=overrides["moe_impl"])
        if cfg_over:
            cfg = dataclasses.replace(cfg, **cfg_over)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}, None

    mesh = build_mesh(multi_pod)
    chips = mesh.devices.size
    dp = chips // 16                      # pod x data extent

    # sequence parallelism (Megatron-SP): shard the residual stream's seq
    # dim over the model axis -> GSPMD decomposes the TP all-reduces into
    # reduce-scatter + all-gather (half the wire bytes) and distributes norms
    rules = ({"seq": ("model",)} if (overrides or {}).get("seq_parallel")
             else None)
    with use_mesh(mesh, rules):
        params_s = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        p_axes = T.param_logical_axes(cfg, params_s)
        p_shard = _named(p_axes, params_s)
        batch_s = input_specs(cfg, shape)
        t0 = time.time()

        if shape.kind == "train":
            opt_cfg = O.OptConfig()
            opt_s = jax.eval_shape(O.init, params_s)
            o_axes = O.OptState(
                m=O.opt_logical_axes(p_axes, params_s, dp, opt_cfg.zero1),
                v=O.opt_logical_axes(p_axes, params_s, dp, opt_cfg.zero1),
                step=())
            o_shard = _named(o_axes, opt_s)
            b_axes = {k: ("batch",) + (None,) * (v.ndim - 1)
                      for k, v in batch_s.items()}
            b_shard = _named(b_axes, batch_s)
            step = make_train_step(cfg, opt_cfg,
                                   num_micro=(overrides or {}).get("num_micro", 1))
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)

        elif shape.kind == "prefill" and cfg.family in ("ssm", "hybrid"):
            # recurrent archs: prefill = full forward (state extraction is a
            # free by-product; no paged pool exists for these families)
            x_ax = ("batch",) + (None,) * (batch_s["inputs"].ndim - 1)
            x_shard = named_sharding(*x_ax, size_of=batch_s["inputs"].shape)
            fn = lambda p, x: T.logits_fn(cfg, p, T.forward(cfg, p, x)[0][:, -1])
            jitted = jax.jit(fn, in_shardings=(p_shard, x_shard))
            lowered = jitted.lower(params_s, batch_s["inputs"])

        elif shape.kind == "prefill":
            geom = KC.make_geometry(cfg, shape, shards=dp,
                                    page_size=(overrides or {}).get("page_size", 512),
                                    oversub=(overrides or {}).get("oversub", 1.0),
                                    kv_dtype=(overrides or {}).get("kv_dtype"))
            cache_s = jax.eval_shape(lambda: KC.create_cache(geom))
            c_axes = KC.cache_logical_axes(geom, cache_s)
            c_shard = _named(c_axes, cache_s)
            x_ax = ("batch",) + (None,) * (batch_s["inputs"].ndim - 1)
            b_shard = {"inputs": named_sharding(*x_ax,
                                                size_of=batch_s["inputs"].shape)}
            fn = lambda p, x, c: E.prefill(cfg, geom, p, x, c)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard["inputs"], c_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_s, batch_s["inputs"], cache_s)

        else:  # decode
            if cfg.family in ("ssm", "hybrid"):
                cache_s = jax.eval_shape(
                    lambda: KC.create_state_cache(cfg, shape.global_batch,
                                                  shape.seq_len,
                                                  dtype=jnp.bfloat16))
                c_axes = KC.state_cache_logical_axes(cfg, cache_s)
                c_shard = _named(c_axes, cache_s)
                geom = None
            else:
                geom = KC.make_geometry(cfg, shape, shards=dp,
                                        page_size=(overrides or {}).get("page_size", 512),
                                        oversub=(overrides or {}).get("oversub", 1.0),
                                        kv_dtype=(overrides or {}).get("kv_dtype"),
                                        merged_attn=(overrides or {}).get("paged_merged", False))
                cache_s = jax.eval_shape(lambda: KC.create_cache(geom))
                c_axes = KC.cache_logical_axes(geom, cache_s)
                c_shard = _named(c_axes, cache_s)
            tok_shard = named_sharding("batch",
                                       size_of=batch_s["inputs"].shape)
            if (overrides or {}).get("serve_bf16"):
                # serving reads bf16 weights (no optimizer here; the f32
                # masters live with the trainer)
                params_s = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        s.shape, jnp.bfloat16 if s.dtype == jnp.float32
                        else s.dtype), params_s)
                p_shard = _named(p_axes, params_s)
            fn = lambda p, t, c: E.serve_step(cfg, geom, p, t, c)
            jitted = jax.jit(fn, in_shardings=(p_shard, tok_shard, c_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_s, batch_s["inputs"], cache_s)

        compiled = lowered.compile()
        compile_s = time.time() - t0

    from repro.launch.analytic import model_cell
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    colls = collective_bytes_weighted(compiled.as_text())
    coll_total = sum(v["wire_bytes"] for v in colls.values())

    # analytic model is the primary compute/memory input: cost_analysis
    # counts scan bodies ONCE (recorded below as the per-iteration floor)
    kvb = 1 if (overrides or {}).get("kv_dtype") == "int8" else 2
    am = model_cell(cfg, shape, chips, tp=16, kv_bytes=kvb)
    flops_dev = am.flops_total / chips
    bytes_dev = am.hbm_bytes_dev
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(sum(terms.values()), 1e-30)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "overrides": overrides or {},
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_per_device":
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        },
        "cost_hlo_floor": {"flops_per_device": float(cost.get("flops", 0.0)),
                           "bytes_accessed_per_device":
                               float(cost.get("bytes accessed", 0.0))},
        "analytic": {"flops_total": am.flops_total,
                     "flops_useful": am.flops_useful,
                     "hbm_bytes_per_device": am.hbm_bytes_dev,
                     "notes": am.notes},
        "collectives": colls,
        "collective_wire_bytes_per_device": coll_total,
        "roofline": {**terms, "dominant": dominant,
                     "bound_fraction": terms[dominant] / step_s},
        "model_flops": am.flops_useful,
        "useful_flops_ratio": am.flops_useful / max(am.flops_total, 1.0),
        # fraction of hardware peak the USEFUL flops achieve at the modeled
        # step time (the §Perf score: higher = closer to roofline)
        "roofline_fraction": am.flops_useful / chips / PEAK_FLOPS / step_s,
    }
    return rec, compiled


def lower_kv_cell(shape_name: str, multi_pod: bool):
    """Dry-run the distributed continuity KV service itself."""
    import repro.core.distributed as D
    from repro.core import continuity as ch

    mesh = build_mesh(multi_pod)
    chips = mesh.devices.size
    dp = chips // 16
    # production-scale service: 2^22 buckets (~42M slot capacity), 4096
    # requests per client device batch
    scfg = D.StoreConfig(
        table=ch.ContinuityConfig(num_buckets=1 << 22, ext_frac=0.0),
        num_shards=dp,
        axis_names=("pod", "data") if multi_pod else ("data",))
    table_s = jax.eval_shape(lambda: D.create_sharded(scfg))
    B = 4096 * dp
    keys_s = jax.ShapeDtypeStruct((B, 4), jnp.uint32)
    vals_s = jax.ShapeDtypeStruct((B, 4), jnp.uint32)
    ops_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    t0 = time.time()
    with mesh:
        if shape_name == "kv_read":
            fn = D.make_lookup(scfg, mesh)
            mask_s = jax.ShapeDtypeStruct((B,), jnp.bool_)
            lowered = jax.jit(fn).lower(table_s, keys_s, mask_s)
        elif shape_name == "kv_read_level":
            # level-hashing-style 4-fetch lookup: the access-amplification
            # comparison measured as collective wire bytes at pod scale
            fn = D.make_lookup_multifetch(scfg, mesh, fetches=4)
            mask_s = jax.ShapeDtypeStruct((B,), jnp.bool_)
            lowered = jax.jit(fn).lower(table_s, keys_s, mask_s)
        else:
            fn = D.make_write(scfg, mesh)
            lowered = fn.lower(table_s, ops_s, keys_s, vals_s)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    colls = collective_bytes_weighted(compiled.as_text())
    coll_total = sum(v["wire_bytes"] for v in colls.values())
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    terms = {"compute_s": flops_dev / PEAK_FLOPS,
             "memory_s": bytes_dev / HBM_BW,
             "collective_s": coll_total / ICI_BW}
    rec = {
        "arch": "continuity-kv", "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok", "compile_seconds": round(time.time() - t0, 1),
        "memory": {"argument_bytes_per_device": mem.argument_size_in_bytes,
                   "temp_bytes_per_device": mem.temp_size_in_bytes},
        "cost": {"flops_per_device": flops_dev,
                 "bytes_accessed_per_device": bytes_dev},
        "collectives": colls,
        "collective_bytes_per_device": coll_total,
        "roofline": {**terms, "dominant": max(terms, key=terms.get)},
    }
    return rec, compiled


def run_cell(arch, shape, multi_pod, outdir, force=False, overrides=None,
             tag=""):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}_{shape}_{mesh_tag}{tag}.json"
    path = os.path.join(outdir, name)
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {name}")
        return json.load(open(path))
    t0 = time.time()
    try:
        if arch == "continuity-kv":
            rec, _ = lower_kv_cell(shape, multi_pod)
        else:
            rec, _ = lower_cell(arch, shape, multi_pod, overrides)
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dom={r['dominant']} comp={r['compute_s']:.2e}s "
                 f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s")
    print(f"[{status}] {name} ({time.time()-t0:.0f}s){extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCHS
    from repro.models.config import SHAPES

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
        cells += [("continuity-kv", "kv_read"), ("continuity-kv", "kv_write"),
                  ("continuity-kv", "kv_read_level")]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for mp in meshes:
        for arch, shape in cells:
            run_cell(arch, shape, mp, args.out, force=args.force)


if __name__ == "__main__":
    main()

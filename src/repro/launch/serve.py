"""Serving launcher: batched decode against the continuity-hash paged cache.

CPU scale:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--kv-dtype", default=None, choices=[None, "int8"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch, smoke_config
    from repro.models import transformer as T
    from repro.models.config import ShapeConfig
    from repro.serving import engine as E
    from repro.serving import kvcache as KC

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.gen
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab, size=(args.batch, args.prompt_len)
                          ).astype(np.int32)

    if cfg.family in ("ssm", "hybrid"):
        cache = KC.create_state_cache(cfg, args.batch, max_seq,
                                      dtype=jnp.float32)
        step = jax.jit(lambda p, t, c: E.serve_step(cfg, None, p, t, c))
        t0 = time.time()
        lg = None
        for t in range(args.prompt_len):      # recurrent prefill
            lg, cache = step(params, jnp.asarray(prompts[:, t]), cache)
        prefill_s = time.time() - t0
        geom = None
    else:
        shape = ShapeConfig("serve", seq_len=max(
            max_seq, args.page_size * 2), global_batch=args.batch,
            kind="decode")
        geom = KC.make_geometry(cfg, shape, shards=args.shards,
                                page_size=args.page_size,
                                kv_dtype=args.kv_dtype)
        cache = KC.create_cache(geom)
        pl = args.prompt_len - args.prompt_len % args.page_size
        pl = max(pl, args.page_size)
        t0 = time.time()
        lg, cache = E.prefill(cfg, geom, params, jnp.asarray(prompts[:, :pl]),
                              cache)
        step = jax.jit(lambda p, t, c: E.serve_step(cfg, geom, p, t, c))
        for t in range(pl, args.prompt_len):  # tail of the prompt, stepwise
            lg, cache = step(params, jnp.asarray(prompts[:, t]), cache)
        prefill_s = time.time() - t0

    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    if geom is None:
        step = jax.jit(lambda p, t, c: E.serve_step(cfg, None, p, t, c))
    t0 = time.time()
    for _ in range(args.gen - 1):
        lg, cache = step(params, tok, cache)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(lg)
    decode_s = time.time() - t0
    toks = np.stack(out, 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s:.2f}s  decode: {decode_s:.2f}s "
          f"({args.batch * (args.gen - 1) / max(decode_s, 1e-9):.1f} tok/s)")
    if geom is not None:
        print(f"page table: {int(cache.table.count.sum())} mappings, "
              f"{int(cache.next_free.sum())} pages allocated, "
              f"pool={geom.pool_pages}/shard x {geom.shards} shards")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {toks[b, :16].tolist()}")


if __name__ == "__main__":
    main()

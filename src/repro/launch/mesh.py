"""Production meshes. A FUNCTION (not module-level state) so importing never
touches jax device initialization."""

from __future__ import annotations

import jax

try:  # jax >= 0.5 wants explicit axis types; 0.4.x has no AxisType at all
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pre-AxisType jax: every axis is implicitly "auto"
    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips).

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod —
    DP spans pod x data, TP stays within a pod (ICI), the pod axis crosses
    DCI. The dry-run (launch/dryrun.py) must set
    XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax.
    """
    if multi_pod:
        shape, axes = (2, 16, 16), ("pod", "data", "model")
    else:
        shape, axes = (16, 16), ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for fake-device tests (device count must already allow it)."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))

"""Analytic per-cell FLOPs / HBM-traffic model for the roofline.

WHY: XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so anything under
``lax.scan`` (our layer stacks, microbatches, attention chunks) is
undercounted by its trip count. The dry-run therefore records BOTH the raw
HLO numbers (labeled as per-iteration floors) and this transparent analytic
model, which the §Roofline table uses as its primary compute/memory inputs.
Collectives are handled separately by trip-count-weighted HLO parsing in
dryrun.py.

Conventions (documented in EXPERIMENTS.md):
  * matmul FLOPs = 2·M·N·K; backward = 2x forward; remat="full" recomputes
    the forward once more (+1x);
  * attention is counted as IMPLEMENTED: the blockwise-masked causal path
    computes the full S x T score matrix (2x the useful causal half) — the
    gap is visible as useful/computed and is a hillclimb target;
  * HBM traffic is a floor model: weights + optimizer streams, activation
    reads/writes per layer at 2 B, K/V re-reads once per query chunk
    (the blockwise loop re-streams K/V), KV-pool reads at decode;
  * per-device = global / (dp·tp) for sharded dims, with replication where
    the config's dims don't divide the mesh (mirrors logical_spec).
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _div(n: int, shards: int) -> float:
    """Shard a dim if divisible, else replicated (matches logical_spec)."""
    return n / shards if n % shards == 0 else n


@dataclasses.dataclass
class CellModel:
    flops_total: float          # computed FLOPs, whole step, all devices
    flops_useful: float         # model FLOPs (6/2·N_active·D convention)
    hbm_bytes_dev: float        # HBM traffic per device
    notes: str = ""


def _attn_flops(cfg, B, S, T, causal_full_matrix=True):
    """q·k^T + p·v for all layers; counts the masked full matrix when the
    implementation computes it (blockwise "masked" path) and the causal half
    (+ diagonal chunk) under "causal_skip"."""
    H, D = cfg.n_heads, cfg.hd
    if not cfg.has_attention:
        return 0.0
    nC = max(S // max(cfg.attn_chunk, 1), 1)
    causal_factor = (nC + 1) / (2.0 * nC) \
        if cfg.attn_mode == "causal_skip" else 1.0
    if cfg.family == "hybrid":
        glob_layers = 3
        win_layers = cfg.n_layers - 3
        win = min(cfg.window + 512, T)              # banded slice width
        return (4.0 * B * S * win * H * D * win_layers
                + 4.0 * B * S * T * H * D * glob_layers * causal_factor)
    return 4.0 * B * S * T * H * D * cfg.n_layers * causal_factor


def _ssd_flops(cfg, B, S):
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    Q = min(s.chunk, S)
    # CB (Q²N) + G·x (Q²P per head) + state in/out (N·P per head per token)
    per_tok = 2.0 * Q * s.d_state + 2.0 * Q * s.head_dim * nheads \
        + 4.0 * s.d_state * s.head_dim * nheads
    return B * S * per_tok * cfg.n_layers


def _matmul_params(cfg) -> int:
    """Parameters participating in per-token matmuls (excludes embeddings).
    Dense-MoE computes EVERY expert per token, so its matmul params are the
    full expert set."""
    n = (cfg.param_count if (cfg.moe and cfg.moe.impl == "dense")
         else cfg.active_param_count)
    return max(n - cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2),
               0)


def _active_matmul_params(cfg) -> int:
    """Active (top-k) matmul params — the 'useful' numerator, impl-agnostic."""
    return max(cfg.active_param_count
               - cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2), 0)


def _useful_flops(cfg, toks_mm: float, toks_head: float, mult: float) -> float:
    """mult·N·D convention with the LM head counted at its true token count
    (embedding GATHERS are not matmuls and are excluded)."""
    return mult * (_active_matmul_params(cfg) * toks_mm
                   + cfg.vocab * cfg.d_model * toks_head)


def train_cell(cfg: ModelConfig, shape: ShapeConfig, chips: int, tp: int = 16):
    B, S = shape.global_batch, shape.seq_len
    dp = chips // tp
    toks = B * S
    # remat="full" recomputes the whole forward (incl. matmuls) in the
    # backward; "dots" saves matmul outputs (no matmul recompute, but the
    # saved activations still stream through HBM)
    remat_fwd = 1.0 if cfg.remat == "none" else 2.0   # activation traffic
    remat_flops = 2.0 if cfg.remat == "full" else 1.0  # matmul recompute

    mm = 2.0 * _matmul_params(cfg) * toks            # fwd matmul flops
    attn = _attn_flops(cfg, B, S, S)
    ssd = _ssd_flops(cfg, B, S)
    logits = 2.0 * cfg.d_model * cfg.padded_vocab * toks
    fwd = mm + attn + ssd + logits
    total = fwd * (1.0 + 2.0) + (fwd - logits) * (remat_flops - 1.0)
    useful = _useful_flops(cfg, toks, toks, 6.0)

    # HBM floor per device
    N_dev = _div_params(cfg, tp)
    Bd, E = B / dp, cfg.d_model
    w = N_dev * (F32 * 3 + F32 * 4)                  # fwd+bwd+grad, m/v rw
    # residual-stream widths replicate; head/mlp widths shard over TP
    if cfg.moe and cfg.moe.impl == "dense":
        dff = cfg.moe.expert_dff * cfg.moe.num_experts   # all experts stream
    else:
        dff = (cfg.moe.expert_dff * cfg.moe.top_k if cfg.moe else cfg.d_ff)
    act_width = (2 * E
                 + (2 * cfg.n_heads * cfg.hd + 2 * cfg.n_kv_heads * cfg.hd
                    + 3 * dff) / tp)
    if cfg.moe and cfg.moe.impl != "dense":
        act_width += 4 * cfg.moe.top_k * E / tp          # dispatch rw
    act = Bd * S * act_width * BF16 * cfg.n_layers * (remat_fwd + 2.0)
    kv_restream = 0.0
    if cfg.has_attention:
        nC = max(S // cfg.attn_chunk, 1)
        cf = (nC + 1) / (2.0 * nC) if cfg.attn_mode == "causal_skip" else 1.0
        kvd = _div(cfg.n_kv_heads, tp) * cfg.hd
        kv_restream = (Bd * S * kvd * BF16 * nC * cf * 2
                       * cfg.n_layers * (remat_fwd + 1.0))
    logit_traffic = Bd * S * _div(cfg.padded_vocab, tp) * F32 * 3
    hbm = w + act + kv_restream + logit_traffic
    return CellModel(total, useful, hbm,
                     notes=f"remat_fwd={remat_fwd} dp={dp} tp={tp}")


def _div_params(cfg: ModelConfig, tp: int) -> float:
    """Per-device parameter count under the TP rules (approx: matmul params
    shard; norms/ssm-scalars replicate; embeddings shard if vocab divides)."""
    mm = _matmul_params(cfg)
    emb = cfg.active_param_count - mm
    return mm / tp + _div(emb, tp)


def prefill_cell(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                 tp: int = 16):
    B, S = shape.global_batch, shape.seq_len
    dp = chips // tp
    toks = B * S
    mm = 2.0 * _matmul_params(cfg) * toks
    attn = _attn_flops(cfg, B, S, S)
    ssd = _ssd_flops(cfg, B, S)
    logits = 2.0 * cfg.d_model * cfg.padded_vocab * B   # last position only
    total = mm + attn + ssd + logits
    useful = _useful_flops(cfg, toks, B, 2.0)

    N_dev = _div_params(cfg, tp)
    Bd, E = B / dp, cfg.d_model
    dff = (cfg.moe.expert_dff * cfg.moe.top_k if cfg.moe else cfg.d_ff)
    act_width = (2 * E
                 + (2 * cfg.n_heads * cfg.hd + 2 * cfg.n_kv_heads * cfg.hd
                    + dff) / tp)
    if cfg.moe:
        act_width += 4 * cfg.moe.top_k * E / tp
    act = Bd * S * act_width * BF16 * cfg.n_layers
    kv_restream = 0.0
    if cfg.has_attention:
        nC = max(S // cfg.attn_chunk, 1)
        cf = (nC + 1) / (2.0 * nC) if cfg.attn_mode == "causal_skip" else 1.0
        kvd = _div(cfg.n_kv_heads, tp) * cfg.hd
        kv_restream = Bd * S * kvd * BF16 * nC * cf * 2 * cfg.n_layers
    pool_write = (Bd * S * cfg.n_kv_heads * cfg.hd * BF16 * 2
                  * cfg.n_layers / tp if cfg.has_attention else 0)
    hbm = N_dev * BF16 + act + kv_restream + pool_write
    return CellModel(total, useful, hbm, notes=f"dp={dp} tp={tp}")


def decode_cell(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                tp: int = 16, kv_bytes: int = BF16):
    B, T = shape.global_batch, shape.seq_len
    dp = chips // tp
    mm = 2.0 * _matmul_params(cfg) * B
    logits = 2.0 * cfg.d_model * cfg.padded_vocab * B
    ssd = 0.0
    if cfg.ssm is not None:
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nheads = d_inner // s.head_dim
        ssd = 4.0 * B * s.d_state * s.head_dim * nheads * cfg.n_layers
    attn = 0.0
    cache_bytes_dev = 0.0
    if cfg.has_attention:
        if cfg.family == "hybrid":
            Tw = min(cfg.window, T)
            attn = (4.0 * B * Tw * cfg.n_heads * cfg.hd * (cfg.n_layers - 3)
                    + 4.0 * B * T * cfg.n_heads * cfg.hd * 3)
            cache = (B * Tw * cfg.n_kv_heads * cfg.hd * 2 * BF16
                     * (cfg.n_layers - 3)
                     + B * T * cfg.n_kv_heads * cfg.hd * 2 * BF16 * 3)
        else:
            attn = 4.0 * B * T * cfg.n_heads * cfg.hd * cfg.n_layers
            cache = B * T * cfg.n_kv_heads * cfg.hd * 2 * kv_bytes \
                * cfg.n_layers
        cache_bytes_dev = cache / (dp * tp)          # split-KV layout
    if cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nheads = d_inner // s.head_dim
        cache_bytes_dev = (B * nheads * s.d_state * s.head_dim * F32
                           * cfg.n_layers) / dp
    total = mm + logits + ssd + attn
    useful = _useful_flops(cfg, B, B, 2.0)
    w_dev = _div_params(cfg, tp) * F32               # f32 master read
    hbm = w_dev + cache_bytes_dev * 2 + B / dp * cfg.d_model * BF16 * \
        cfg.n_layers * 8
    return CellModel(total, useful, hbm,
                     notes=f"cache_dev={cache_bytes_dev/1e9:.2f}GB")


def model_cell(cfg: ModelConfig, shape: ShapeConfig, chips: int,
               tp: int = 16, kv_bytes: int = BF16) -> CellModel:
    if shape.kind == "train":
        return train_cell(cfg, shape, chips, tp)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, chips, tp)
    return decode_cell(cfg, shape, chips, tp, kv_bytes)

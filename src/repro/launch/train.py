"""Training launcher.

At CPU scale (this container):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --batch 4 --seq 128 --ckpt /tmp/ckpt

On a real cluster the same entry point runs the full config on the
production mesh (``--mesh single|multi``); jax.distributed.initialize() is
called when JAX_COORDINATOR is set (one process per host).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    from repro.configs import get_arch, smoke_config
    from repro.checkpoint import CheckpointManager
    from repro.distribution.sharding import use_mesh
    from repro.models import transformer as T
    from repro.runtime.fault import DeterministicSchedule
    from repro.training import optimizer as O
    from repro.training.train_step import make_train_step

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count/1e6:.1f}M (smoke={args.smoke})")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_cfg = O.OptConfig(lr=args.lr, warmup=min(20, args.steps // 5 + 1),
                          decay_steps=args.steps)
    state = O.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, num_micro=args.micro))

    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, start, _ = mgr.restore({"p": params, "o": state})
        params, state = restored["p"], restored["o"]
        print(f"restored checkpoint at step {start}")

    sched = DeterministicSchedule(args.seed, args.batch)
    mesh_ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh_ctx = make_production_mesh(multi_pod=args.mesh == "multi")

    def batch_for(step):
        # deterministic synthetic LM data (replayable after restart)
        ids = sched.batch_indices(step, 0, 1)
        rng = np.random.Generator(np.random.Philox(key=args.seed,
                                                   counter=[0, 0, step, 7]))
        toks = rng.integers(0, cfg.vocab, size=(args.batch, args.seq),
                            dtype=np.int32)
        del ids
        if cfg.frontend == "embed":
            emb = rng.standard_normal(
                (args.batch, args.seq, cfg.d_model)).astype(np.float32)
            return {"inputs": jnp.asarray(emb),
                    "labels": jnp.asarray(np.roll(toks, -1, 1))}
        return {"inputs": jnp.asarray(toks),
                "labels": jnp.asarray(np.roll(toks, -1, 1))}

    def run():
        nonlocal params, state
        t0 = time.time()
        for s in range(start, args.steps):
            params, state, stats = step_fn(params, state, batch_for(s))
            if s % 10 == 0 or s == args.steps - 1:
                dt = time.time() - t0
                tok_s = (s - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(f"step {s:5d} loss {float(stats['loss']):.4f} "
                      f"gnorm {float(stats['grad_norm']):.3f} "
                      f"lr {float(stats['lr']):.2e} tok/s {tok_s:.0f}",
                      flush=True)
            if mgr is not None and (s + 1) % args.ckpt_every == 0:
                mgr.save(s + 1, {"p": params, "o": state})
        if mgr is not None:
            mgr.save(args.steps, {"p": params, "o": state})
            mgr.wait()

    if mesh_ctx is not None:
        with use_mesh(mesh_ctx):
            run()
    else:
        run()


if __name__ == "__main__":
    main()

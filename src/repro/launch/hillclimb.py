import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (§Perf): runs the three chosen cells through their
iteration ladders, each variant as a tagged dry-run record. The hypothesis ->
change -> measure -> verdict narrative lives in EXPERIMENTS.md §Perf; this
script produces the measurements.

Usage: python -m repro.launch.hillclimb [--cell yi|granite|qwen|all]
"""

import argparse
import json

LADDERS = {
    # worst-roofline dense train cell: fit memory, halve attention waste,
    # then trade remat recompute back once memory allows
    "yi": [
        ("yi-6b", "train_4k", {}, "_hc0_base"),
        ("yi-6b", "train_4k", {"num_micro": 8}, "_hc1_micro8"),
        ("yi-6b", "train_4k", {"num_micro": 8, "attn_mode": "causal_skip"},
         "_hc2_causal"),
        ("yi-6b", "train_4k",
         {"num_micro": 8, "attn_mode": "causal_skip", "remat": "dots"},
         "_hc3_dots"),
        ("yi-6b", "train_4k",
         {"num_micro": 16, "attn_mode": "causal_skip", "remat": "dots"},
         "_hc4_micro16"),
        # drop explicit qkv constraints: the kv_heads degrade-to-replicated
        # constraint forces ~14 resharding all-reduces per layer
        ("yi-6b", "train_4k",
         {"num_micro": 16, "attn_mode": "causal_skip", "remat": "dots",
          "constrain_qkv": False}, "_hc5_noqkv"),
        # Megatron-style sequence parallelism: residual stream seq-sharded
        # over the model axis; the TP all-reduce pairs decompose into
        # reduce-scatter + all-gather (~half the wire bytes)
        ("yi-6b", "train_4k",
         {"num_micro": 16, "attn_mode": "causal_skip", "remat": "dots",
          "seq_parallel": True}, "_hc6_seqpar"),
    ],
    # most collective-bound cell: dense-MoE kills the dispatch collectives
    "granite": [
        ("granite-moe-1b-a400m", "train_4k", {}, "_hc0_base"),
        ("granite-moe-1b-a400m", "train_4k", {"moe_impl": "dense"},
         "_hc1_dense"),
        ("granite-moe-1b-a400m", "train_4k",
         {"moe_impl": "dense", "num_micro": 8}, "_hc2_micro8"),
        ("granite-moe-1b-a400m", "train_4k",
         {"moe_impl": "dense", "num_micro": 8, "attn_mode": "causal_skip"},
         "_hc3_causal"),
        # vocab 49155 doesn't divide TP=16 -> logits replicate; pad to 49168
        ("granite-moe-1b-a400m", "train_4k",
         {"moe_impl": "dense", "num_micro": 8, "attn_mode": "causal_skip",
          "vocab_pad_to": 16}, "_hc4_vpad"),
        # refutation follow-up: micro8 DUPLICATED per-microbatch collectives;
        # revert to num_micro=1 with the other wins kept
        ("granite-moe-1b-a400m", "train_4k",
         {"moe_impl": "dense", "attn_mode": "causal_skip",
          "vocab_pad_to": 16, "constrain_qkv": False}, "_hc5_micro1"),
    ],
    # paper-representative cell (hash-paged KV serving): un-merge the page
    # dims (kill the involuntary remat), quantize the pool, oversubscribe,
    # then tune the segment/page size (the paper's own size_se trade-off)
    "qwen": [
        ("qwen1.5-32b", "decode_32k", {"paged_merged": True}, "_hc0_merged"),
        ("qwen1.5-32b", "decode_32k", {}, "_hc1_unmerged"),
        ("qwen1.5-32b", "decode_32k", {"kv_dtype": "int8"}, "_hc2_int8"),
        ("qwen1.5-32b", "decode_32k", {"kv_dtype": "int8", "oversub": 0.5},
         "_hc3_oversub"),
        ("qwen1.5-32b", "decode_32k",
         {"kv_dtype": "int8", "oversub": 0.5, "page_size": 1024},
         "_hc4_page1k"),
        ("qwen1.5-32b", "decode_32k",
         {"kv_dtype": "int8", "oversub": 0.5, "page_size": 256},
         "_hc5_page256"),
        # serving weights in bf16 (masters stay with the trainer)
        ("qwen1.5-32b", "decode_32k",
         {"kv_dtype": "int8", "oversub": 0.5, "serve_bf16": True},
         "_hc6_bf16w"),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all"] + sorted(LADDERS))
    ap.add_argument("--out", default="experiments/hillclimb")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    cells = (LADDERS.keys() if args.cell == "all" else [args.cell])
    summary = []
    for name in cells:
        for arch, shape, over, tag in LADDERS[name]:
            rec = run_cell(arch, shape, False, args.out, force=args.force,
                           overrides=over, tag=tag)
            if rec.get("status") == "ok":
                summary.append({
                    "cell": name, "tag": tag, "overrides": over,
                    "dominant": rec["roofline"]["dominant"],
                    "compute_s": rec["roofline"]["compute_s"],
                    "memory_s": rec["roofline"]["memory_s"],
                    "collective_s": rec["roofline"]["collective_s"],
                    "peak_gb": rec["memory"]["peak_estimate_per_device"] / 1e9,
                    "roofline_fraction": rec.get("roofline_fraction", 0),
                })
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    for s in summary:
        print(f"{s['cell']:8s}{s['tag']:14s} dom={s['dominant'][:-2]:10s} "
              f"step={(s['compute_s']+s['memory_s']+s['collective_s'])*1e3:9.1f}ms "
              f"peak={s['peak_gb']:6.1f}GB rf={s['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()

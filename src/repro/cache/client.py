"""`ClientCache`: a per-client hot-key cache validated by version stamps.

The paper's lookups are one-sided — the server never sees a read, so it
can never invalidate a client cache.  Continuity hashing makes that a
feature instead of a bug: every committed mutation on a bucket pair
rewrites ONE 8-byte word (indicator bits + the per-pair op counter in its
upper half, `core.continuity.ContinuityTable.version`), so a client that
cached ``(value, stamp)`` at fill time can later prove freshness with a
single 8-byte READ: stamp unchanged => no mutation committed on the pair
since the fill => the cached value IS what a full lookup would return.
Invalidation is log-free and protocol-free; its entire cost is one verb.

Correctness contract (the property the tests drive): a validating read
NEVER serves a value a committed mutation has replaced.  Three rules
enforce it, each mapped to a counter:

  * stamps are compared row-wise and exactly; any mismatch evicts
    (``stamp_invalidations``);
  * a stamp is only comparable against the endpoint that produced it
    (replica histories diverge across resync); an answer from a
    different node evicts too (``source_invalidations``), and an
    UNRESOLVED validation (partition, migration window, delivery
    timeout) is never served — the entry survives, unservable, until a
    future validation proves or disproves it
    (``unresolved_validations``);
  * shed reads (the `Backpressure` valve) are refused outright — a shed
    op is never quietly served from cache.

Within one round a validated/filled entry is served without re-checking:
reads of round t begin after round t's writes committed, so serving the
value fetched this round is a legal linearization.  ``trust_window > 0``
extends that trust across rounds — cheaper, but a mutation committing
inside the window CAN then be missed, which is why the gated zero-stale
runs use ``trust_window=0`` (validate on every cross-round hit).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, NamedTuple, Optional

import numpy as np

from repro.cache.policy import Backpressure, FrequencySketch, key_hash
from repro.core.pmem import CostLedger

U32 = np.uint32


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Knobs for one client's cache (all seeded / deterministic)."""

    capacity: int = 128          # resident entries
    trust_window: int = 0        # rounds a validation is trusted for
    #                              (0 = validate every cross-round hit: the
    #                              zero-stale configuration the CI gates)
    sketch_width: int = 1024     # TinyLFU count-min width (power of two)
    sketch_depth: int = 4
    sketch_sample: Optional[int] = None   # halve counters every N adds
    admission: bool = True       # False = plain LRU fill (no TinyLFU)
    budget: Optional[int] = None          # per-round backend-fetch valve
    seed: int = 0


@dataclasses.dataclass
class _Entry:
    value: np.ndarray            # (4,) uint32
    stamp: np.ndarray            # (S,) int64 — endpoint version stamp
    source: str                  # the node that produced the stamp
    validated_round: int


class RoundResult(NamedTuple):
    """One client round of reads through the cache."""

    values: np.ndarray           # (B, 4) uint32 (zeros where not served)
    found: np.ndarray            # (B,) bool — served with a live value
    served: np.ndarray           # (B,) bool — False only for shed ops
    hit: np.ndarray              # (B,) bool — served from cache
    op_us: np.ndarray            # (B,) simulated wire latency (0 = local)


class ClientCache:
    """One client's cache in front of a `CacheBackend`.

    ``read_round(keys)`` is the unit of work: the round's reads are
    deduplicated, cached keys are validated in ONE batch, misses are
    fetched in ONE batch (after the admission sketch and the backpressure
    valve see them) — the request-coalescing that collapses per-node
    doorbells in the fan-in sim.  Writes don't pass through the cache;
    call ``invalidate(keys)`` for the client's own writes (remote writers
    need nothing: their commits bump the version word the next validation
    reads).
    """

    def __init__(self, config: CacheConfig, backend: Any):
        self.config = config
        self.backend = backend
        self.entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self.sketch = FrequencySketch(config.sketch_width,
                                      config.sketch_depth,
                                      config.sketch_sample, config.seed)
        self.valve = Backpressure(config.budget)
        self.round = 0
        self.stats = {
            "rounds": 0, "ops": 0, "hits": 0, "trusted_hits": 0,
            "misses": 0, "fills": 0, "validations": 0,
            "stamp_invalidations": 0, "source_invalidations": 0,
            "unresolved_validations": 0,
            "shed": 0, "admit_rejects": 0, "evictions": 0,
        }

    # -- internals ----------------------------------------------------------
    def _touch(self, kb: bytes) -> None:
        self.entries.move_to_end(kb)

    def _admit(self, kb: bytes, entry: _Entry) -> None:
        cfg = self.config
        if kb in self.entries:
            self.entries[kb] = entry
            self._touch(kb)
            return
        if len(self.entries) < cfg.capacity:
            self.entries[kb] = entry
            self.stats["fills"] += 1
            return
        victim = next(iter(self.entries))
        if cfg.admission and (self.sketch.estimate(key_hash(kb))
                              <= self.sketch.estimate(key_hash(victim))):
            # TinyLFU: a one-hit wonder may not displace a hotter resident
            self.stats["admit_rejects"] += 1
            return
        del self.entries[victim]
        self.stats["evictions"] += 1
        self.entries[kb] = entry
        self.stats["fills"] += 1

    def invalidate(self, keys) -> int:
        """Drop entries for the client's OWN writes (write-through)."""
        keys = np.asarray(keys, U32).reshape(-1, 4)
        n = 0
        for k in keys:
            n += self.entries.pop(k.tobytes(), None) is not None
        return n

    # -- the round ----------------------------------------------------------
    def read_round(self, keys) -> RoundResult:
        cfg = self.config
        self.round += 1
        keys = np.asarray(keys, U32).reshape(-1, 4)
        B = keys.shape[0]
        self.stats["rounds"] += 1
        self.stats["ops"] += B

        kb = [k.tobytes() for k in keys]
        uniq: "OrderedDict[bytes, int]" = OrderedDict()
        for b in kb:
            if b not in uniq:
                uniq[b] = len(uniq)
        ukeys = np.frombuffer(b"".join(uniq), U32).reshape(-1, 4)
        for b in uniq:                       # request frequency, hits included
            self.sketch.add(key_hash(b))

        u_val = np.zeros((len(uniq), 4), U32)    # per-uniq served value
        u_fnd = np.zeros(len(uniq), bool)
        u_hit = np.zeros(len(uniq), bool)
        u_srv = np.ones(len(uniq), bool)
        u_us = np.zeros(len(uniq))

        need_check, need_fetch = [], []
        for b, i in uniq.items():
            e = self.entries.get(b)
            if e is None:
                need_fetch.append(i)
            elif self.round - e.validated_round <= cfg.trust_window:
                u_val[i], u_fnd[i], u_hit[i] = e.value, True, True
                self.stats["hits"] += 1
                self.stats["trusted_hits"] += 1
                self._touch(b)
            else:
                need_check.append(i)

        if need_check:
            idx = np.array(need_check)
            stamps, source, resolved, op_us = self.backend.validate(
                ukeys[idx])
            self.stats["validations"] += len(idx)
            for j, i in enumerate(idx):
                b = ukeys[i].tobytes()
                e = self.entries[b]
                ok = bool(resolved[j]) and str(source[j]) == e.source \
                    and np.array_equal(stamps[j], e.stamp)
                u_us[i] = op_us[j]
                if ok:
                    e.validated_round = self.round
                    u_val[i], u_fnd[i], u_hit[i] = e.value, True, True
                    self.stats["hits"] += 1
                    self._touch(b)
                elif bool(resolved[j]):
                    # disproven: a committed mutation moved the pair's
                    # version word, or the keyspace re-routed the key to a
                    # different answerer whose history the stamp cannot
                    # vouch against — evict
                    del self.entries[b]
                    if str(source[j]) == e.source:
                        self.stats["stamp_invalidations"] += 1
                    else:
                        self.stats["source_invalidations"] += 1
                    need_fetch.append(i)
                else:
                    # nobody COULD answer (partition, migration window,
                    # delivery timeout): the entry is not disproven, just
                    # unservable this round — keep it (it is only ever
                    # served after a future successful validation) and
                    # fall back to a backend fetch for this op
                    self.stats["unresolved_validations"] += 1
                    need_fetch.append(i)

        if need_fetch:
            idx = np.array(sorted(need_fetch))
            freqs = np.array([self.sketch.estimate(key_hash(ukeys[i].tobytes()))
                              for i in idx])
            grant = self.valve.grant(freqs)
            self.stats["shed"] += int((~grant).sum())
            u_srv[idx[~grant]] = False
            idx = idx[grant]
            if len(idx):
                self.stats["misses"] += len(idx)
                values, found, stamps, source, op_us = self.backend.fetch(
                    ukeys[idx])
                for j, i in enumerate(idx):
                    u_val[i], u_fnd[i], u_us[i] = values[j], found[j], op_us[j]
                    ok_stamp = bool(found[j]) and str(source[j]) != "" \
                        and not (np.asarray(stamps[j]) < 0).any()
                    if ok_stamp:
                        self._admit(ukeys[i].tobytes(),
                                    _Entry(np.array(values[j], U32),
                                           np.array(stamps[j], np.int64),
                                           str(source[j]), self.round))

        inv = np.array([uniq[b] for b in kb])
        return RoundResult(u_val[inv], u_fnd[inv], u_srv[inv], u_hit[inv],
                           u_us[inv])

    def hit_rate(self) -> float:
        tot = self.stats["hits"] + self.stats["misses"] + self.stats["shed"]
        return self.stats["hits"] / tot if tot else 0.0


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class StoreBackend:
    """Single-store backend: an `repro.api` store + table, priced through
    `ledger_from_plan` (and an optional `RemoteMemory` endpoint).  Whoever
    mutates the store updates ``.table`` in place — the property tests'
    harness.  The accumulated `CostLedger` prices validation READs and
    miss lookups honestly from their verb plans."""

    def __init__(self, store, table, mem=None, name: str = "local"):
        self.store = store
        self.table = table
        self.mem = mem
        self.name = name
        self.ledger = CostLedger.zero()

    def validate(self, keys):
        from repro.rdma import verbs as rv
        keys = np.asarray(keys, U32).reshape(-1, 4)
        stamps = np.asarray(self.store.version_stamp(self.table, keys),
                            np.int64)
        plan = self.store.version_read_plan(self.table, keys)
        self.ledger = self.ledger.merge(rv.ledger_from_plan(plan))
        op_us = np.zeros(keys.shape[0])
        if self.mem is not None:
            comp = self.mem.post(plan, tag="validate")
            op_us = comp.op_us
        return (stamps, np.full(keys.shape[0], self.name, object),
                np.ones(keys.shape[0], bool), op_us)

    def fetch(self, keys):
        from repro.rdma import verbs as rv
        keys = np.asarray(keys, U32).reshape(-1, 4)
        res = self.store.lookup(self.table, keys)
        stamps = np.asarray(self.store.version_stamp(self.table, keys),
                            np.int64)
        self.ledger = self.ledger.merge(rv.ledger_from_plan(res.plan))
        op_us = np.zeros(keys.shape[0])
        if self.mem is not None:
            comp = self.mem.post(res.plan, tag="fill")
            op_us = comp.op_us
        return (np.asarray(res.values, U32), np.asarray(res.ok, bool),
                stamps, np.full(keys.shape[0], self.name, object), op_us)


class ClusterBackend:
    """`ClusterStore` backend: validations are `version_read` rounds
    (tagged 8-byte READs to each key's serving member), fetches are
    `lookup_stamped` rounds; both inherit the cluster's fencing rules, so
    partitioned/lagging/migrating answers surface as unresolved and the
    cache degrades to misses instead of trusting anything stale."""

    def __init__(self, cluster):
        self.cluster = cluster
        # (kind, touched-node-set, round_us) per backend call since the
        # caller last cleared it — the fan-in sim's per-node queue model
        # reads this to charge each round's wire time to the nodes it hit
        self.last: list = []

    def validate(self, keys):
        r = self.cluster.version_read(keys)
        self.last.append(("validate",
                          {str(s) for s in r.source if str(s)},
                          float(r.round_us)))
        return r.stamps, r.source, r.resolved, r.op_us

    def fetch(self, keys):
        r = self.cluster.lookup_stamped(keys)
        self.last.append(("fetch",
                          {str(s) for s in r.source if str(s)},
                          float(r.round_us)))
        return r.values, r.found, r.stamps, r.source, r.op_us

"""Admission + backpressure policies for the client hot-key cache.

Under a zipf hotspot a naive cache churns: every cold key that passes
through evicts something hot, and the hot set never stabilizes.  TinyLFU
(Einziger et al.) fixes that with a tiny frequency sketch consulted at
admission time — a candidate only displaces the eviction victim if it has
been REQUESTED more often — so one-hit wonders bounce off and the resident
set converges to the true hot set.  The sketch is a count-min with
periodic halving (aging), so yesterday's hot keys decay instead of
squatting forever.

`Backpressure` is the shedding valve: a per-round budget of backend
fetches per client.  When a hotspot storm floods a client with more cold
misses than the budget, the COLDEST misses (by sketch estimate) are shed —
refused, never served stale — which caps the per-node fan-in while the
hot keys (cache hits + the hottest misses) keep flowing.  Everything is
seeded and deterministic.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

U64 = np.uint64

# splitmix64-style avalanche constants: spread 16-byte keys into 64-bit
# hashes whose low bits are well-mixed for the per-row slots
_MIX = U64(0x9E3779B97F4A7C15)
_AV1, _AV2 = U64(0xBF58476D1CE4E5B9), U64(0x94D049BB133111EB)


def key_hash(key_bytes: bytes) -> int:
    """Deterministic 64-bit hash of a key's raw bytes (no PYTHONHASHSEED)."""
    with np.errstate(over="ignore"):
        h = U64(int.from_bytes(key_bytes[:8], "little")) * _MIX
        h ^= U64(int.from_bytes(key_bytes[8:16].ljust(8, b"\0"), "little"))
        h = (h ^ (h >> U64(30))) * _AV1
        h = (h ^ (h >> U64(27))) * _AV2
    return int(h ^ (h >> U64(31)))


class FrequencySketch:
    """Count-min sketch with halving decay — TinyLFU's frequency oracle.

    ``depth`` salted rows of ``width`` 8-bit counters; an estimate is the
    row minimum.  After ``sample`` total increments every counter halves
    (aging), so estimates track the RECENT request distribution and the
    admission filter adapts when the hot set drifts.
    """

    def __init__(self, width: int = 1024, depth: int = 4,
                 sample: Optional[int] = None, seed: int = 0):
        assert width > 0 and width & (width - 1) == 0, "width: power of two"
        self.width = width
        self.depth = depth
        self.rows = np.zeros((depth, width), np.uint8)
        rng = np.random.RandomState(seed)
        self.salts = rng.randint(1, 2 ** 62, size=depth).astype(U64) | U64(1)
        self.sample = sample if sample is not None else 8 * width
        self.adds = 0
        self.ages = 0

    def _slots(self, h: int) -> np.ndarray:
        with np.errstate(over="ignore"):
            mixed = (U64(h) * self.salts) >> U64(17)
        return (mixed & U64(self.width - 1)).astype(np.int64)

    def add(self, h: int) -> None:
        s = self._slots(h)
        cur = self.rows[np.arange(self.depth), s]
        self.rows[np.arange(self.depth), s] = np.minimum(
            cur.astype(np.int64) + 1, 255).astype(np.uint8)
        self.adds += 1
        if self.adds >= self.sample:
            self.rows >>= 1          # halving decay: recency over history
            self.adds = 0
            self.ages += 1

    def estimate(self, h: int) -> int:
        return int(self.rows[np.arange(self.depth), self._slots(h)].min())


class Backpressure:
    """Per-round backend-fetch budget: the hotspot shedding valve.

    ``budget=None`` disables the valve (every miss fetches).  Otherwise at
    most ``budget`` backend fetches are granted per round; the caller
    offers misses with their sketch frequencies and the valve keeps the
    hottest ``budget`` of them.  Shed ops are REFUSED — counted, reported
    to the caller, and never served from a stale entry.
    """

    def __init__(self, budget: Optional[int] = None):
        assert budget is None or budget >= 0
        self.budget = budget
        self.shed = 0
        self.granted = 0

    def grant(self, freqs: np.ndarray) -> np.ndarray:
        """(n,) bool: which of the offered misses may fetch this round.
        ``freqs[i]`` is the i-th miss's sketch estimate; ties keep the
        earlier offer (stable ordering keeps runs deterministic)."""
        n = len(freqs)
        if self.budget is None or n <= self.budget:
            self.granted += n
            return np.ones(n, bool)
        keep = np.argsort(-np.asarray(freqs), kind="stable")[: self.budget]
        out = np.zeros(n, bool)
        out[keep] = True
        self.granted += int(self.budget)
        self.shed += n - int(self.budget)
        return out

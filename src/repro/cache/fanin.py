"""100-client fan-in simulation: hot-key caching vs the uncached edge.

The scenario the cache exists for: O(100) independent clients hammer a
zipf/hotspot key set on a small `ClusterStore` while membership chaos
(partition -> stale writes -> heal -> resync, a live join, a primary
kill + failover) runs underneath.  The same seeded request stream is
replayed twice over two identically-built clusters:

  * **uncached** — the request-per-post serving edge: every read is
    routed and POSTED individually (compute is batched per node, the
    wire is not), the status quo before a cache tier;
  * **cached** — every client owns a `ClientCache`; a round's reads are
    deduplicated, cached keys revalidate in one batched 8-byte-per-key
    stamp READ, and only granted misses fetch.

Two effects are measured and CI-gated:

  * **per-node doorbell collapse** — read-tagged doorbells per node drop
    >= 2x because a client round coalesces into ~(one validate post per
    touched node + one miss post) instead of one post per op;
  * **p99 collapse** — per-op latency includes a per-round FIFO queue
    at each node (posts serialize on the NIC: an op waits out the wire
    time of every post that reached its node earlier that round), so
    fan-in pressure inflates the uncached tail and the cache's fewer,
    smaller posts deflate it.

Correctness is gated harder than performance: every served value is
compared against the ground truth of committed writes AT SERVE TIME.
With ``trust_window=0`` (the gated configuration) a cached read NEVER
serves a pre-mutation value — ``stale_served`` must be exactly zero
across the full chaos schedule — and the uncached pass must show zero
wrong reads too (the cluster's own fencing).

Round model: reads of round t begin after round t's writes committed
(the serving edge's request/commit epochs), so serving a value fetched
or validated this round is a legal linearization; ``trust_window > 0``
relaxes this across rounds and is deliberately NOT the gated default.

``python -m repro.cache.fanin --smoke --json OUT.json`` runs the CI
cell; exit status 0 iff every gate holds.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.cache.client import CacheConfig, ClientCache, ClusterBackend
from repro.cluster.store import ClusterStore
from repro.data import ycsb
from repro.rdma import verbs as rv

U32 = np.uint32

# (round, kind, name): kinds partition|stale|heal|resync|join|kill|failover;
# name "primary"/"" resolves at fire time (hottest primary / last target)
RoundEvent = Tuple[int, str, str]


def default_events(rounds: int) -> List[RoundEvent]:
    """The standard chaos schedule, scaled to the round count: a
    partition/stale/heal/resync cycle in the first half, a live join at
    the midpoint, a primary kill + explicit failover in the last
    quarter.  On tiny round counts the later events are DROPPED instead
    of squeezed: a kill landing before the cycle's resync would leave
    resync targeting a dead node — tiny runs keep the early cycle only."""
    p = max(2, rounds // 5)
    j = rounds // 2 + 1
    k = (3 * rounds) // 4 + 1
    out = [(p, "partition", "primary"), (p, "stale", ""),
           (p + 2, "heal", ""), (p + 3, "resync", "")]
    if j > p + 3:
        out.append((j, "join", "pmJ"))
    if k > max(j, p + 3):
        out += [(k, "kill", "primary"), (k + 1, "failover", "")]
    return out


def _uncached_round(cluster: ClusterStore, keys: np.ndarray,
                    q: Dict[str, float]):
    """One client's round at the uncached edge: batch the COMPUTE per
    node (the jitted lookup), POST one single-row plan per op — the
    request-per-post wire pattern.  ``q`` is the per-node FIFO queue
    (microseconds of wire time already committed to that node this
    round); each op's latency = queue on its node + its own unloaded
    cost, and its post's wall time joins the queue behind it."""
    B = keys.shape[0]
    values = np.zeros((B, 4), U32)
    found = np.zeros(B, bool)
    lat = np.zeros(B)
    posted = np.zeros(B, bool)
    target, has = cluster._route_serving(keys)
    per_node: Dict[str, tuple] = {}
    where: Dict[int, Tuple[str, int]] = {}
    for name in np.unique(target[has]):
        node = cluster._nodes[name]
        m = has & (target == name)
        vs, fs, res = cluster._padded_lookup(node, keys[m])
        pl = [np.asarray(leaf) for leaf in res.plan]
        per_node[name] = (vs, fs, pl, node)
        for j, i in enumerate(np.flatnonzero(m)):
            where[int(i)] = (name, j)
    for i in range(B):
        if i not in where:
            continue                       # no serving member right now
        name, j = where[i]
        vs, fs, pl, node = per_node[name]
        values[i], found[i], posted[i] = vs[j], fs[j], True
        if node.mem is not None:
            comp = node.mem.post(
                rv.VerbPlan(*(leaf[j:j + 1] for leaf in pl)), tag="read")
            lat[i] = q.get(name, 0.0) + float(comp.op_us[0])
            q[name] = q.get(name, 0.0) + float(comp.batch_us)
    return values, found, lat, posted


def _run_pass(cached: bool, *, scheme: str, clients: int, rounds: int,
              ops_per_round: int, writes_per_round: int, num_records: int,
              nodes: int, replicas: int, node_slots: int, dist: str,
              theta: float, hot_frac: float, hot_op_frac: float,
              cache_cfg: CacheConfig, events: Sequence[RoundEvent],
              seed: int) -> Dict:
    """One full pass (identical stream + chaos, cache on or off) over a
    freshly built cluster.  Deterministic given the seed: both passes
    draw the same rng sequence in the same order, so they replay the
    SAME requests, values, and chaos injections."""
    cluster = ClusterStore(scheme, nodes=nodes, replicas=replicas,
                           node_slots=node_slots)
    rng = np.random.RandomState(seed)
    truth: Dict[int, np.ndarray] = {}      # id -> last committed value
    for lo in range(0, num_records, 256):
        ids = np.arange(lo, min(lo + 256, num_records))
        vals = ycsb.make_value(rng, len(ids))
        okn = np.asarray(cluster.insert(ycsb.make_key(ids), vals).ok)
        for i, v in zip(ids[okn], vals[okn]):
            truth[int(i)] = v
    order = np.array(sorted(truth))
    stream = ycsb.request_stream(dist, len(order), theta=theta,
                                 hot_frac=hot_frac, hot_op_frac=hot_op_frac)
    scramble = rng.permutation(len(order))

    backend = ClusterBackend(cluster)
    caches = [ClientCache(dataclasses.replace(cache_cfg,
                                              seed=cache_cfg.seed + c),
                          backend) for c in range(clients)] if cached else []

    mode = "cached" if cached else "uncached"
    reg = obs.get_registry()
    h_lat = obs.Histogram()          # per-op serve latency (queue + wire)
    reports: List[dict] = []
    partitioned: List[str] = []
    killed: List[str] = []
    stale_served = wrong_reads = unserved = 0
    pending = sorted(events, key=lambda e: e[0])
    pending_complete = False

    def hottest_primary() -> str:
        hot = ycsb.make_key(np.array([order[scramble[0] % len(order)]]))
        return str(cluster.directory.replica_names(hot)[0, 0])

    for rnd in range(1, rounds + 1):
        if pending_complete:
            if cluster.migrating:    # cutover one round after COPY: the
                rb = cluster.complete_join()     # dual-read window was live
                reports.append({"round": rnd, "event": "join",
                                "node": rb.node, "moved_frac": rb.moved_frac,
                                "bound": rb.bound,
                                "within_bound": rb.within_bound})
            pending_complete = False
        while pending and pending[0][0] <= rnd:
            _, kind, name = pending.pop(0)
            if kind == "partition":
                name = hottest_primary() if name in ("", "primary") else name
                cluster.partition(name)
                partitioned.append(name)
                reports.append({"round": rnd, "event": "partition",
                                "node": name})
            elif kind == "stale":
                name = name or partitioned[-1]
                ranks = stream.sample(rng, 16) % len(scramble)
                sids = order[scramble[ranks] % len(order)]
                n = cluster.stale_write(name, ycsb.make_key(sids),
                                        ycsb.make_value(rng, len(sids)))
                reports.append({"round": rnd, "event": "stale",
                                "node": name, "acks_injected": n})
            elif kind == "heal":
                name = name or partitioned[-1]
                cluster.heal(name)
                reports.append({"round": rnd, "event": "heal", "node": name})
            elif kind == "resync":
                name = name or partitioned[-1]
                hr = cluster.resync(name)
                reports.append({"round": rnd, "event": "resync",
                                "node": hr.node,
                                "stale_acks_detected": hr.stale_acks_detected,
                                "resynced": hr.resynced})
            elif kind == "join":
                cluster.begin_join(name, node_slots)
                pending_complete = True
            elif kind == "kill":
                name = hottest_primary() if name in ("", "primary") else name
                cluster.kill(name)
                killed.append(name)
                reports.append({"round": rnd, "event": "kill", "node": name})
            else:
                assert kind == "failover", kind
                name = name or killed[-1]
                rep = cluster.failover(name)
                reports.append({"round": rnd, "event": "failover",
                                "dead": name,
                                "promoted_keys": rep.promoted_keys,
                                "recopied": rep.recopied,
                                "recovery_log_free": rep.recovery_log_free()})

        # writes commit BEFORE this round's reads begin (the round model)
        if writes_per_round:
            ranks = stream.sample(rng, writes_per_round) % len(scramble)
            wids = order[scramble[ranks] % len(order)]
            vals = ycsb.make_value(rng, len(wids))
            res = cluster.update(ycsb.make_key(wids), vals)
            okn = np.asarray(res.ok)
            for i, v in zip(wids[okn], vals[okn]):
                truth[int(i)] = v

        q: Dict[str, float] = {}           # per-node round FIFO queue (us)
        with obs.span("fanin.round", round=rnd, mode=mode):
            for c in range(clients):
                ranks = stream.sample(rng, ops_per_round) % len(scramble)
                ids = order[scramble[ranks] % len(order)]
                keys = ycsb.make_key(ids)
                if cached:
                    backend.last.clear()
                    r = caches[c].read_round(keys)
                    touched: set = set()
                    for _, srcs, _ in backend.last:
                        touched |= srcs
                    before = max((q.get(n, 0.0) for n in touched),
                                 default=0.0)
                    for _, srcs, rus in backend.last:
                        for nm in srcs:
                            q[nm] = q.get(nm, 0.0) + rus
                    for i in range(len(ids)):
                        if not r.served[i]:
                            continue       # shed: counted by the valve
                        if not r.found[i]:
                            unserved += 1
                            continue
                        h_lat.record(before + float(r.op_us[i]))
                        if not np.array_equal(r.values[i],
                                              truth[int(ids[i])]):
                            if r.hit[i]:
                                stale_served += 1   # the cardinal sin:
                            else:                   # gated == 0
                                wrong_reads += 1
                else:
                    values, found, lat, posted = _uncached_round(cluster,
                                                                 keys, q)
                    for i in range(len(ids)):
                        if not (posted[i] and found[i]):
                            unserved += 1
                            continue
                        h_lat.record(float(lat[i]))
                        if not np.array_equal(values[i],
                                              truth[int(ids[i])]):
                            wrong_reads += 1
        # the round's deepest per-node NIC backlog, as a gauge lane:
        # .value is the LAST round's depth, .max the worst across the run
        for nm in sorted(q):
            reg.gauge("fanin.queue_us", node=nm, mode=mode).set(q[nm])

    # read-tagged wire counters per node (writes/load are untagged, so the
    # comparison isolates exactly the read path the cache replaces)
    tags = ("fill", "validate") if cached else ("read",)
    per_node: Dict[str, dict] = {}
    tot = {"posts": 0, "doorbells": 0, "verbs": 0, "bytes": 0}
    for name, st in cluster.stats()["nodes"].items():
        bt = st.get("wire", {}).get("by_tag", {})
        row = {k: sum(bt.get(t, {}).get(k, 0) for t in tags) for k in tot}
        row["total_doorbells"] = st.get("wire", {}).get("doorbells", 0)
        per_node[name] = row
        for k in tot:
            tot[k] += row[k]

    # percentiles come from the shared obs sketch (the same buckets the
    # export carries), and the sketch is folded into the installed
    # registry so a traced run exports it under fanin.op_us{mode=...}
    reg.histogram("fanin.op_us", mode=mode).merge(h_lat)
    reg.counter("fanin.unserved", mode=mode).inc(unserved)
    reg.counter("fanin.wrong_reads", mode=mode).inc(wrong_reads)
    out = {
        "read_posts": tot["posts"], "read_doorbells": tot["doorbells"],
        "read_verbs": tot["verbs"], "read_bytes": tot["bytes"],
        "per_node": per_node,
        "p50_us": h_lat.percentile(50) if h_lat.count else 0.0,
        "p99_us": h_lat.percentile(99) if h_lat.count else 0.0,
        "reads_served": h_lat.count, "unserved": unserved,
        "wrong_reads": wrong_reads,
        "chaos": dict(cluster.chaos), "events": reports,
    }
    if cached:
        agg = {k: sum(c.stats[k] for c in caches) for k in caches[0].stats}
        denom = agg["hits"] + agg["misses"] + agg["shed"]
        out["cache"] = agg
        out["hit_rate"] = agg["hits"] / max(1, denom)
        out["stale_served"] = stale_served
        reg.counter("fanin.hits").inc(agg["hits"])
        reg.counter("fanin.misses").inc(agg["misses"])
        reg.counter("fanin.shed").inc(agg["shed"])
        reg.counter("fanin.unresolved").inc(agg["unresolved_validations"])
        reg.counter("fanin.stale_served").inc(stale_served)
    return out


def run_fanin(scheme: str = "continuity", *, clients: int = 100,
              rounds: int = 14, ops_per_round: int = 16,
              writes_per_round: int = 2, num_records: int = 1200,
              nodes: int = 4, replicas: int = 2,
              node_slots: Optional[int] = None, dist: str = "hotspot",
              theta: float = 0.99, hot_frac: float = 0.02,
              hot_op_frac: float = 0.95, capacity: int = 128,
              trust_window: int = 0, budget: Optional[int] = 12,
              admission: bool = True,
              events: Optional[Sequence[RoundEvent]] = None,
              seed: int = 0) -> Dict:
    """The fan-in cell: the same seeded run uncached then cached, plus
    the request-stream self-check and the derived reduction ratios the
    bench bands gate on."""
    if node_slots is None:
        node_slots = int(num_records * replicas / nodes * 2.5) + 256
    if events is None:
        events = default_events(rounds)
    cache_cfg = CacheConfig(capacity=capacity, trust_window=trust_window,
                            budget=budget, admission=admission, seed=seed)
    common = dict(scheme=scheme, clients=clients, rounds=rounds,
                  ops_per_round=ops_per_round,
                  writes_per_round=writes_per_round,
                  num_records=num_records, nodes=nodes, replicas=replicas,
                  node_slots=node_slots, dist=dist, theta=theta,
                  hot_frac=hot_frac, hot_op_frac=hot_op_frac,
                  cache_cfg=cache_cfg, events=events, seed=seed)
    uncached = _run_pass(False, **common)
    cached = _run_pass(True, **common)
    check = ycsb.stream_self_check(
        ycsb.request_stream(dist, num_records, theta=theta,
                            hot_frac=hot_frac, hot_op_frac=hot_op_frac),
        np.random.RandomState(seed + 97))
    return {
        "scheme": scheme, "clients": clients, "rounds": rounds,
        "ops_per_round": ops_per_round, "writes_per_round": writes_per_round,
        "num_records": num_records, "nodes": nodes, "replicas": replicas,
        "dist": dist, "theta": theta, "hot_frac": hot_frac,
        "hot_op_frac": hot_op_frac, "trust_window": trust_window,
        "capacity": capacity, "budget": budget, "seed": seed,
        "stream_check": check,
        "uncached": uncached, "cached": cached,
        "doorbell_reduction": uncached["read_doorbells"]
        / max(1, cached["read_doorbells"]),
        "bytes_reduction": uncached["read_bytes"]
        / max(1, cached["read_bytes"]),
        "p99_ratio": cached["p99_us"] / max(1e-9, uncached["p99_us"]),
    }


# The hit-rate floor is deliberately below the steady-state rate (~0.6):
# the schedule spends ~4 of 14 rounds in active chaos (partition cycle +
# migration window) where the cache correctly refuses to trust itself,
# and every committed hot-key write necessarily costs one miss per
# caching client — the floor prices honesty, not a tuned best case.
GATES = {"hit_rate_floor": 0.45, "doorbell_reduction_floor": 2.0}


def check_gates(payload: Dict) -> List[str]:
    """The CI gates (shared with `validate_bench`): returns the list of
    violated gates, empty == pass."""
    bad = []
    ca, un = payload["cached"], payload["uncached"]
    if ca.get("stale_served", 0) != 0:
        bad.append(f"cache served {ca['stale_served']} stale read(s) "
                   "(must be exactly 0)")
    if ca["wrong_reads"] or un["wrong_reads"]:
        bad.append(f"wrong reads: cached={ca['wrong_reads']} "
                   f"uncached={un['wrong_reads']} (must be 0)")
    if payload["doorbell_reduction"] < GATES["doorbell_reduction_floor"]:
        bad.append(f"doorbell reduction {payload['doorbell_reduction']:.2f}x "
                   f"< {GATES['doorbell_reduction_floor']}x")
    if ca["p99_us"] > un["p99_us"]:
        bad.append(f"cached p99 {ca['p99_us']:.1f}us > uncached "
                   f"{un['p99_us']:.1f}us")
    if ca["hit_rate"] < GATES["hit_rate_floor"]:
        bad.append(f"hit rate {ca['hit_rate']:.3f} < "
                   f"{GATES['hit_rate_floor']}")
    if not payload["stream_check"]["ok"]:
        bad.append(f"request stream failed its self-check: "
                   f"{payload['stream_check']}")
    return bad


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scheme", default="continuity")
    p.add_argument("--clients", type=int, default=100)
    p.add_argument("--dist", default="hotspot", choices=("zipf", "hotspot"))
    p.add_argument("--theta", type=float, default=0.99)
    p.add_argument("--hot-frac", type=float, default=0.02)
    p.add_argument("--hot-op-frac", type=float, default=0.95)
    p.add_argument("--trust-window", type=int, default=0,
                   help="rounds a validation is trusted; gated runs use 0")
    p.add_argument("--budget", type=int, default=12,
                   help="per-client per-round backend-fetch budget")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="CI sizes (100 clients x 14 rounds)")
    p.add_argument("--json", default=None, help="write the payload here")
    args = p.parse_args(argv)

    kw = (dict(rounds=14, ops_per_round=16, writes_per_round=2,
               num_records=1200) if args.smoke
          else dict(rounds=18, ops_per_round=16, writes_per_round=2,
                    num_records=2000))
    payload = run_fanin(args.scheme, clients=args.clients, dist=args.dist,
                        theta=args.theta, hot_frac=args.hot_frac,
                        hot_op_frac=args.hot_op_frac,
                        trust_window=args.trust_window, budget=args.budget,
                        seed=args.seed, **kw)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=str)

    un, ca = payload["uncached"], payload["cached"]
    print(f"fanin {payload['scheme']} x{payload['clients']} clients "
          f"({payload['dist']}, seed={payload['seed']}): "
          f"doorbells {un['read_doorbells']} -> {ca['read_doorbells']} "
          f"({payload['doorbell_reduction']:.2f}x), bytes "
          f"{un['read_bytes']} -> {ca['read_bytes']} "
          f"({payload['bytes_reduction']:.2f}x)")
    print(f"  p50 {un['p50_us']:.2f} -> {ca['p50_us']:.2f}us, "
          f"p99 {un['p99_us']:.2f} -> {ca['p99_us']:.2f}us "
          f"(ratio {payload['p99_ratio']:.3f})")
    print(f"  hit_rate={ca['hit_rate']:.3f} stale_served="
          f"{ca['stale_served']} shed={ca['cache']['shed']} "
          f"validations={ca['cache']['validations']} "
          f"stamp_inval={ca['cache']['stamp_invalidations']} "
          f"source_inval={ca['cache']['source_invalidations']} "
          f"unresolved={ca['cache']['unresolved_validations']}")
    for r in ca["events"]:
        print(f"  event: {r}")
    bad = check_gates(payload)
    for b in bad:
        print(f"FAIL: {b}", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Client-side hot-key caching for the remote hash table.

The paper's reads are one-sided (the server never sees them), so servers
cannot invalidate client caches.  This package turns continuity hashing's
commit discipline into the invalidation protocol instead: every committed
mutation rewrites ONE 8-byte word per bucket pair (indicator bits + the
per-pair version counter), so a cached entry is revalidated by a single
8-byte READ of that word — log-free, protocol-free, one verb.

  `policy`   TinyLFU admission sketch + backpressure shedding valve
  `client`   `ClientCache` (per-client cache + round protocol) and its
             backends (`StoreBackend` single store, `ClusterBackend`
             over `cluster.ClusterStore`)
  `fanin`    the 100-client fan-in simulation: hotspot storm through
             independent caches with membership chaos underneath,
             cached vs uncached per-node doorbells and p99
"""

from repro.cache.client import (CacheConfig, ClientCache, ClusterBackend,
                                RoundResult, StoreBackend)
from repro.cache.policy import Backpressure, FrequencySketch, key_hash

__all__ = [
    "Backpressure", "CacheConfig", "ClientCache", "ClusterBackend",
    "FrequencySketch", "RoundResult", "StoreBackend", "key_hash",
]

"""One-sided verb plans: the typed unit of remote access every scheme emits.

The paper's comparative claims are about WHAT a lookup puts on the wire —
continuity: ONE contiguous segment READ; level hashing: up to four
scattered bucket READs; P-FaRM-KV: a window READ plus chained dependent
block READs; a dense table: one degenerate whole-region READ.  A
`VerbPlan` makes that explicit and machine-checkable: a batch of B ops
compiles to a (B, M) lane grid of verbs (lane m of row b = the m-th verb
op b would post to its QP), and everything downstream — the `CostLedger`
the benchmarks and Table-I-style gates read, the doorbell batching the
transport applies, the analytical latency model — is DERIVED from the
plan instead of hand-tallied per scheme.

Address model: a verb targets ``(region, offset, nbytes)`` where region is
a symbolic remote MR id (`REGION_TABLE` = the scheme's main table rows /
buckets / windows, `REGION_EXT` = its extension / overflow pool,
`REGION_LOG` = the PM log area the logging schemes write).  Offsets are
byte offsets within the region, derived from the scheme's own geometry —
the plan is exactly the scatter/gather list an RDMA client would build.

Dependency model: ``depth`` is the round-trip the verb can issue in.  All
depth-0 verbs of a batch coalesce into ONE doorbell (the transport's
doorbell batching); a verb at depth k depends on a depth-(k-1) completion
(continuity's rare extension probe, pfarm's chain walk, an ordered
remote-persist WRITE sequence) and costs an extra round trip.  ``fence``
marks WRITE verbs that must be remotely PERSISTED (not merely NIC-visible)
before the next depth may issue — see `repro.consistency`'s
remote-persistence injector and DESIGN.md §8.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax.numpy as jnp

from repro.core.pmem import CostLedger

I32 = jnp.int32

# verb opcodes
NOOP, READ, WRITE, CAS = 0, 1, 2, 3
VERB_NAMES = {NOOP: "noop", READ: "read", WRITE: "write", CAS: "cas"}

# symbolic remote memory regions
REGION_TABLE, REGION_EXT, REGION_LOG, REGION_STASH = 0, 1, 2, 3
REGION_NAMES = {REGION_TABLE: "table", REGION_EXT: "ext", REGION_LOG: "log",
                REGION_STASH: "stash"}


class VerbPlan(NamedTuple):
    """Batched verb grid: every field is (B, M) — B ops, M verb lanes.

    Inactive lanes carry ``verb == NOOP`` and are ignored by every
    consumer; a row's active lanes, ordered by ``depth``, are the one-sided
    operations that op posts.
    """

    verb: jnp.ndarray    # (B, M) int32 — NOOP/READ/WRITE/CAS
    region: jnp.ndarray  # (B, M) int32 — symbolic MR id
    offset: jnp.ndarray  # (B, M) int32 — byte offset within the region
    nbytes: jnp.ndarray  # (B, M) int32 — wire payload of the verb
    depth: jnp.ndarray   # (B, M) int32 — round-trip dependency depth
    fence: jnp.ndarray   # (B, M) bool  — remote-persist fence after (writes)

    @property
    def batch(self) -> int:
        return self.verb.shape[0]

    @property
    def lanes(self) -> int:
        return self.verb.shape[1]


Lane = Tuple  # (verb, region, offset, nbytes, depth, fence) — (B,)-broadcastable


def pack(B: int, lane_list: Sequence[Lane]) -> VerbPlan:
    """Stack per-lane column tuples into a (B, M) `VerbPlan`.

    Each lane is ``(verb, region, offset, nbytes, depth, fence)`` with
    every element either a scalar or a (B,) array.
    """
    cols = []
    for i, dtype in enumerate((I32, I32, I32, I32, I32, jnp.bool_)):
        cols.append(jnp.stack(
            [jnp.broadcast_to(jnp.asarray(lane[i], dtype), (B,))
             for lane in lane_list], axis=1))
    return VerbPlan(*cols)


def single_read_plan(B: int, region, offset, nbytes) -> VerbPlan:
    """(B, 1) plan of independent depth-0 READs — one contiguous fetch per
    op, the whole batch behind ONE doorbell.  The shape cache validation
    traffic takes: `offset`/`nbytes` broadcast over the batch."""
    return pack(B, [(READ, region, offset, nbytes, 0, False)])


def flatten(plan: VerbPlan) -> VerbPlan:
    """Collapse leading batch dims (e.g. a vmapped (S, B, M) plan) to (B', M)."""
    return VerbPlan(*(leaf.reshape(-1, leaf.shape[-1]) for leaf in plan))


def ledger_from_plan(plan: VerbPlan) -> CostLedger:
    """The shared lookup-accounting helper: one `CostLedger` derived from a
    read plan, replacing the per-scheme hand-tallied ``read_counters``
    blocks.  One READ verb == one one-sided contiguous fetch; bytes are the
    summed wire payloads; ops is the batch size (masked-off rows are
    all-NOOP and count no reads, matching the old per-scheme accounting)."""
    is_read = plan.verb == READ
    return CostLedger.zero().add(
        rdma_reads=jnp.sum(is_read.astype(I32)),
        bytes_fetched=jnp.sum(jnp.where(is_read, plan.nbytes, 0)),
        ops=plan.batch)


def reads_per_op(plan: VerbPlan) -> jnp.ndarray:
    """(B,) one-sided READ count per op — the access-amplification trace,
    read off the plan instead of a scheme-internal counter."""
    return jnp.sum((plan.verb == READ).astype(I32), axis=1)


def round_trips(plan: VerbPlan) -> jnp.ndarray:
    """() dependent round trips the batch needs under doorbell batching:
    1 + the maximum depth of any active verb (0 for an empty plan)."""
    active = plan.verb != NOOP
    return jnp.max(jnp.where(active, plan.depth + 1, 0))

"""`repro.rdma` — the one-sided transport layer (DESIGN.md §8).

Three pieces:

  * `verbs`     — `VerbPlan` (the (B, M) verb grid a scheme's lookup
    emits: READ/WRITE/CAS over symbolic region descriptors, with
    dependency depths and remote-persist fences) and the shared
    `ledger_from_plan` accounting helper that replaced the four
    per-scheme hand-tallied ``read_counters`` blocks;
  * `transport` — `RemoteMemory` (doorbell batching: one round trip per
    dependency depth) + `LinkModel` (every calibrated latency constant
    in one place);
  * `sim`       — the end-to-end YCSB client/server simulation producing
    per-scheme throughput and p50/p99 latency
    (``benchmarks/run.py --sections end_to_end``).

Schemes emit plans from inside jit (`OpResult.plan` is a pure pytree);
the transport executes host-side.  `api.ExecPolicy(transport="sim")`
selects the endpoint (`RemoteMemory.from_policy`).
"""

from repro.rdma.transport import Completion, LinkModel, RemoteMemory
from repro.rdma.verbs import (CAS, NOOP, READ, REGION_EXT, REGION_LOG,
                              REGION_TABLE, WRITE, VerbPlan, flatten,
                              ledger_from_plan, pack, reads_per_op,
                              round_trips)

__all__ = [
    "Completion", "LinkModel", "RemoteMemory",
    "NOOP", "READ", "WRITE", "CAS",
    "REGION_TABLE", "REGION_EXT", "REGION_LOG",
    "VerbPlan", "flatten", "ledger_from_plan", "pack", "reads_per_op",
    "round_trips",
]
